"""Mesh-sharded measurement engine (beyond-paper scale-out of Algorithm 1).

Records are sharded over the ('pod','data') axes; every device builds partial
marginal tables for the plan's closure via a one-hot matmul (MXU-friendly —
no scatters), partial tables are psum'd, and the residual transform + noise
run replicated (noise keys are identical across devices, so each device holds
the same noisy answers — measurement is read-only on the records).

The paper notes base mechanisms "can be run in parallel" (§5.2); this module
is that observation turned into a pjit/shard_map program.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.domain import Clique, Domain
from repro.core.mechanism import Measurement, noise_dtype, residual_answer
from repro.core.select import Plan


# Engines cached per (plan identity, path, dtype): repeated sharded_measure
# calls on one plan reuse the jitted group transforms instead of re-tracing.
# The engine holds the plan strongly, so a cached id() cannot be recycled
# while its entry lives; the size bound caps retained memory.
_PLUS_ENGINE_CACHE: Dict[tuple, object] = {}
_PLUS_ENGINE_CACHE_MAX = 16


def _plus_engine_for(plan, use_kernel: bool, dtype):
    from repro.engine.plus_engine import PlusEngine
    ck = (id(plan), bool(use_kernel), jnp.dtype(dtype).name)
    eng = _PLUS_ENGINE_CACHE.get(ck)
    if eng is None or eng.plan is not plan:
        if len(_PLUS_ENGINE_CACHE) >= _PLUS_ENGINE_CACHE_MAX:
            _PLUS_ENGINE_CACHE.clear()
        eng = _PLUS_ENGINE_CACHE[ck] = PlusEngine(
            plan, use_kernel=use_kernel, precompile=False, dtype=dtype)
    return eng


def _clique_strides(domain: Domain, clique: Clique) -> Tuple[np.ndarray, int]:
    sizes = [domain.attributes[i].size for i in clique]
    strides = np.ones(len(clique), np.int32)
    for j in range(len(clique) - 2, -1, -1):
        strides[j] = strides[j + 1] * sizes[j + 1]
    return strides, int(np.prod(sizes)) if clique else 1


def _local_marginal(records, cols, strides, n_cells, dtype=jnp.float32):
    """One-hot-matmul histogram of the clique columns (records: (N, n_attrs))."""
    if len(cols) == 0:
        return jnp.asarray([records.shape[0]], dtype)
    flat = jnp.zeros((records.shape[0],), jnp.int32)
    for c, s in zip(cols, strides):
        flat = flat + records[:, c] * int(s)
    oh = jax.nn.one_hot(flat, n_cells, dtype=dtype)
    return jnp.sum(oh, axis=0)


def sharded_marginals(domain: Domain, cliques: Sequence[Clique],
                      records: jnp.ndarray, mesh: Optional[Mesh] = None,
                      dtype=None) -> Dict[Clique, jnp.ndarray]:
    """Exact marginal tables for every clique, records sharded over data axes.

    ``dtype=None`` resolves to :func:`repro.core.mechanism.noise_dtype` so the
    tables match the precision of the residual transform consuming them.
    """
    dtype = noise_dtype() if dtype is None else dtype
    cliques = list(cliques)
    meta = [(_clique_strides(domain, c)) for c in cliques]

    if mesh is None:
        return {c: _local_marginal(records, list(c), meta[i][0], meta[i][1],
                                   dtype)
                for i, c in enumerate(cliques)}

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(rec):
        outs = []
        for i, c in enumerate(cliques):
            h = _local_marginal(rec, list(c), meta[i][0], meta[i][1], dtype)
            outs.append(jax.lax.psum(h, data_axes + tuple(
                a for a in mesh.axis_names if a not in data_axes)))
        return tuple(outs)

    in_spec = P(data_axes, None)
    out_specs = tuple(P() for _ in cliques)
    fn = shard_map(body, mesh=mesh, in_specs=(in_spec,), out_specs=out_specs,
                   check_rep=False)
    outs = jax.jit(fn)(records)
    return {c: o for c, o in zip(cliques, outs)}


def sharded_measure(plan, records: jnp.ndarray,
                    key: jax.Array, mesh: Optional[Mesh] = None,
                    use_kernel: bool = False,
                    dtype=None) -> Dict[Clique, Measurement]:
    """Distributed Algorithms 1/5: sharded marginalization + residual transform.

    ``plan`` is either a plain :class:`~repro.core.select.Plan` or a
    ResidualPlanner+ :class:`~repro.core.plus.PlusPlan` — the + path routes
    the replicated transform through the signature-batched
    :class:`~repro.engine.plus_engine.PlusEngine` with the generalized
    ``(Sub_i, Γ_i)`` factors.  ``dtype`` governs the marginal tables and the
    noise draws; ``None`` resolves to
    :func:`repro.core.mechanism.noise_dtype` (float64 under jax x64) rather
    than the historical hard-coded float32, so the distributed path matches
    the core path's precision.
    """
    from repro.core.plus import PlusPlan
    dtype = noise_dtype() if dtype is None else dtype
    domain = plan.schema.domain if isinstance(plan, PlusPlan) else plan.domain
    margs = sharded_marginals(domain, plan.cliques, records, mesh, dtype=dtype)
    if isinstance(plan, PlusPlan):
        return _plus_engine_for(plan, use_kernel, dtype).measure(margs, key)
    out: Dict[Clique, Measurement] = {}
    keys = jax.random.split(key, len(plan.cliques))
    for k, clique in zip(keys, plan.cliques):
        dims = [domain.attributes[i].size for i in clique]
        m = int(np.prod(dims)) if clique else 1
        sigma = math.sqrt(plan.sigmas[clique])
        z = jax.random.normal(k, (m,), dtype)
        hv = residual_answer(domain, clique, margs[clique], use_kernel)
        hz = residual_answer(domain, clique, z, use_kernel)
        out[clique] = Measurement(clique, np.asarray(hv + sigma * hz),
                                  plan.sigmas[clique])
    return out
