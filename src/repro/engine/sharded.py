"""Mesh-sharded measurement engine (beyond-paper scale-out of Algorithm 1).

Records are sharded over the ('pod','data') axes; every device builds partial
marginal tables for the plan's closure via a one-hot matmul (MXU-friendly —
no scatters), partial tables are psum'd, and the residual transform + noise
run replicated (noise keys are identical across devices, so each device holds
the same noisy answers — measurement is read-only on the records).

The paper notes base mechanisms "can be run in parallel" (§5.2); this module
is that observation turned into a pjit/shard_map program.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.domain import Clique, Domain
from repro.core.mechanism import Measurement, residual_answer
from repro.core.select import Plan


def _clique_strides(domain: Domain, clique: Clique) -> Tuple[np.ndarray, int]:
    sizes = [domain.attributes[i].size for i in clique]
    strides = np.ones(len(clique), np.int32)
    for j in range(len(clique) - 2, -1, -1):
        strides[j] = strides[j + 1] * sizes[j + 1]
    return strides, int(np.prod(sizes)) if clique else 1


def _local_marginal(records, cols, strides, n_cells):
    """One-hot-matmul histogram of the clique columns (records: (N, n_attrs))."""
    if len(cols) == 0:
        return jnp.asarray([records.shape[0]], jnp.float32)
    flat = jnp.zeros((records.shape[0],), jnp.int32)
    for c, s in zip(cols, strides):
        flat = flat + records[:, c] * int(s)
    oh = jax.nn.one_hot(flat, n_cells, dtype=jnp.float32)
    return jnp.sum(oh, axis=0)


def sharded_marginals(domain: Domain, cliques: Sequence[Clique],
                      records: jnp.ndarray, mesh: Optional[Mesh] = None
                      ) -> Dict[Clique, jnp.ndarray]:
    """Exact marginal tables for every clique, records sharded over data axes."""
    cliques = list(cliques)
    meta = [(_clique_strides(domain, c)) for c in cliques]

    if mesh is None:
        return {c: _local_marginal(records, list(c), meta[i][0], meta[i][1])
                for i, c in enumerate(cliques)}

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(rec):
        outs = []
        for i, c in enumerate(cliques):
            h = _local_marginal(rec, list(c), meta[i][0], meta[i][1])
            outs.append(jax.lax.psum(h, data_axes + tuple(
                a for a in mesh.axis_names if a not in data_axes)))
        return tuple(outs)

    in_spec = P(data_axes, None)
    out_specs = tuple(P() for _ in cliques)
    fn = shard_map(body, mesh=mesh, in_specs=(in_spec,), out_specs=out_specs,
                   check_rep=False)
    outs = jax.jit(fn)(records)
    return {c: o for c, o in zip(cliques, outs)}


def sharded_measure(plan: Plan, records: jnp.ndarray,
                    key: jax.Array, mesh: Optional[Mesh] = None,
                    use_kernel: bool = False) -> Dict[Clique, Measurement]:
    """Distributed Algorithm 1: sharded marginalization + residual transform."""
    margs = sharded_marginals(plan.domain, plan.cliques, records, mesh)
    out: Dict[Clique, Measurement] = {}
    keys = jax.random.split(key, len(plan.cliques))
    for k, clique in zip(keys, plan.cliques):
        dims = [plan.domain.attributes[i].size for i in clique]
        m = int(np.prod(dims)) if clique else 1
        sigma = math.sqrt(plan.sigmas[clique])
        z = jax.random.normal(k, (m,), jnp.float32)
        hv = residual_answer(plan.domain, clique, margs[clique], use_kernel)
        hz = residual_answer(plan.domain, clique, z, use_kernel)
        out[clique] = Measurement(clique, np.asarray(hv + sigma * hz),
                                  plan.sigmas[clique])
    return out
