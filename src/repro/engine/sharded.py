"""Mesh-sharded measurement engine (beyond-paper scale-out of Algorithm 1).

Records are sharded over the ('pod','data') axes; every device builds partial
marginal tables for the plan's closure via a one-hot matmul (MXU-friendly —
no scatters), partial tables are psum'd, and the residual transform + noise
run replicated (noise keys are identical across devices, so each device holds
the same noisy answers — measurement is read-only on the records).

The paper notes base mechanisms "can be run in parallel" (§5.2); this module
is that observation turned into a pjit/shard_map program.  The replicated
transform is served by whatever engine the plan's family provides via the
unified plan protocol (``plan.engine(...)``, docs/DESIGN.md §9) — plain
plans route through :class:`~repro.engine.engine.MarginalEngine`, RP+ plans
through :class:`~repro.engine.plus_engine.PlusEngine`; this module never
branches on the concrete plan type.
"""
from __future__ import annotations

import os
import weakref
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.domain import Clique, Domain
from repro.core.mechanism import Measurement, noise_dtype
from repro.core.plantable import BasePlan
from repro.obs import REGISTRY

# Process-wide engine-cache event feed for /metrics (per-cache ints stay on
# each _EngineCache instance; this family aggregates across caches).
_CACHE_EVENTS = REGISTRY.counter(
    "repro_engine_cache_events_total",
    "Engine-cache events (hit, miss, eviction, forced_eviction)",
    labels=("event",))


def _env_cache_size(default: int = 16) -> int:
    """REPRO_ENGINE_CACHE_SIZE env override of the engine-cache capacity."""
    raw = os.environ.get("REPRO_ENGINE_CACHE_SIZE", "")
    try:
        return max(1, int(raw))
    except ValueError:
        return default


class _EngineCache:
    """LRU cache of compiled serving engines, weak-safely keyed on the plan.

    Entries are keyed on ``(id(plan), use_kernel, dtype)`` but each holds a
    ``weakref`` to its plan and is validated with an identity check on every
    hit — a recycled ``id`` can never alias a stale engine.  A full cache
    evicts exactly the least-recently-used entry (the historical wholesale
    ``.clear()`` threw away every warm engine on the 17th plan).  Cached
    engines pin their plan (``engine.plan``), so entries normally leave via
    LRU eviction; the per-plan ``weakref.finalize`` additionally drops
    entries whose values don't pin the plan the moment it is collected.

    Capacity is configurable: constructor arg, else the
    ``REPRO_ENGINE_CACHE_SIZE`` environment variable, else 16.  ``hits`` /
    ``misses`` aggregate across entries; each served engine's own
    ``EngineStats`` additionally records its per-engine ``cache_hits`` /
    ``cache_misses`` provenance.

    Warm-pool hooks (docs/DESIGN.md §13): ``pin``/``unpin`` exempt an entry
    from eviction (a full cache of pinned entries still evicts LRU — pins are
    advisory, counted in ``forced_evictions``), and an ``evict_score``
    callback, when set, picks the victim with the LOWEST score among unpinned
    entries (ties broken LRU) instead of pure LRU — the release server's
    :class:`~repro.serve.pool.EnginePool` scores by tenant-weighted use.
    """

    def __init__(self, maxsize: Optional[int] = None):
        self.maxsize = _env_cache_size() if maxsize is None else int(maxsize)
        if self.maxsize < 1:
            raise ValueError(f"cache maxsize must be >= 1, got {maxsize}")
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.forced_evictions = 0
        self.evict_score = None        # Optional[Callable[[tuple], float]]
        self._pinned: set = set()
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._finalized: set = set()

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _child_plans(plan) -> tuple:
        """Child plans of a composite plan (empty for monolithic plans)."""
        return tuple(getattr(plan, "block_plans", None) or ())

    def _key(self, plan, use_kernel: bool, dtype, secure: bool = False,
             digits: int = 4) -> tuple:
        # digits is part of the key: a secure engine's σ̄/γ² are baked in at
        # construction, so two rationalizations must never share an engine
        # (the noise served would disagree with the privacy charged).
        # Composite plans additionally key on their child-plan identities:
        # a composite entry is only valid while the exact block plans it was
        # compiled against are alive, and _drop_plan distinguishes "this id
        # is the entry's own plan" (drop it) from "this id is one of its
        # children" (drop the parent, never the siblings).
        return ((id(plan), tuple(map(id, self._child_plans(plan)))),
                bool(use_kernel), jnp.dtype(dtype).name,
                bool(secure), int(digits) if secure else None)

    def get(self, plan, use_kernel: bool, dtype, secure: bool = False,
            digits: int = 4):
        key = self._key(plan, use_kernel, dtype, secure, digits)
        ent = self._entries.get(key)
        if ent is None:
            self.misses += 1
            _CACHE_EVENTS.labels(event="miss").inc()
            return None
        ref, child_refs, engine = ent
        stale = ref() is not plan      # id recycled: stale entry
        if not stale:
            children = self._child_plans(plan)
            stale = len(child_refs) != len(children) or any(
                r() is not c for r, c in zip(child_refs, children))
        if stale:
            del self._entries[key]
            self._pinned.discard(key)
            self.misses += 1
            _CACHE_EVENTS.labels(event="miss").inc()
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        _CACHE_EVENTS.labels(event="hit").inc()
        stats = getattr(engine, "stats", None)
        if stats is not None:          # cache values are engines in serving;
            stats.bump("cache_hits")   # tests may stash sentinels
        return engine

    def put(self, plan, use_kernel: bool, dtype, engine,
            secure: bool = False, digits: int = 4) -> None:
        key = self._key(plan, use_kernel, dtype, secure, digits)
        while len(self._entries) >= self.maxsize:
            self._evict_one()
        self._entries[key] = (weakref.ref(plan),
                              tuple(weakref.ref(c)
                                    for c in self._child_plans(plan)),
                              engine)
        if id(plan) not in self._finalized:
            self._finalized.add(id(plan))
            weakref.finalize(plan, self._drop_plan, id(plan))

    def _evict_one(self) -> None:
        """Evict one entry: lowest evict_score among unpinned (ties → LRU),
        else LRU among unpinned, else LRU outright (advisory pins)."""
        candidates = [k for k in self._entries if k not in self._pinned]
        if not candidates:                          # everything pinned
            self.forced_evictions += 1
            _CACHE_EVENTS.labels(event="forced_eviction").inc()
            victim = next(iter(self._entries))      # oldest = LRU
        elif self.evict_score is not None:
            victim = min(candidates, key=lambda k: (
                self.evict_score(k), list(self._entries).index(k)))
        else:
            victim = candidates[0]                  # LRU among unpinned
        del self._entries[victim]
        self._pinned.discard(victim)
        self.evictions += 1
        _CACHE_EVENTS.labels(event="eviction").inc()

    # ---------------------------------------------------------- warm pool
    def pin(self, plan, use_kernel: bool, dtype, secure: bool = False,
            digits: int = 4) -> None:
        self._pinned.add(self._key(plan, use_kernel, dtype, secure, digits))

    def unpin(self, plan, use_kernel: bool, dtype, secure: bool = False,
              digits: int = 4) -> None:
        self._pinned.discard(self._key(plan, use_kernel, dtype, secure,
                                       digits))

    def snapshot(self) -> list:
        """One dict per live entry (for /stats): key fields + pin state."""
        rows = []
        for key in self._entries:
            (pid, child_ids), use_kernel, dtype, secure, digits = key
            rows.append(dict(plan_id=pid, n_children=len(child_ids),
                             use_kernel=use_kernel, dtype=dtype,
                             secure=secure, pinned=key in self._pinned))
        return rows

    def _drop_plan(self, pid: int) -> None:
        # Drop entries OWNED by this plan id, and composite entries that held
        # it as a child (their engine references a dead block plan).  A dying
        # composite parent matches only its own entries — the children's
        # entries key on (child_id, ()) and survive, still serving any other
        # owner of those block plans (they were never orphaned *stale*; they
        # are independently validated on every hit).
        self._finalized.discard(pid)
        for k in [k for k in self._entries
                  if k[0][0] == pid or pid in k[0][1]]:
            del self._entries[k]
            self._pinned.discard(k)


# Engines cached per (plan, path, dtype, secure): repeated sharded_measure
# calls on one plan reuse the jitted group transforms instead of re-tracing.
# Capacity from REPRO_ENGINE_CACHE_SIZE (default 16).
_ENGINE_CACHE = _EngineCache()


def _engine_for(plan: BasePlan, use_kernel: bool, dtype,
                secure: bool = False, digits: int = 4):
    eng = _ENGINE_CACHE.get(plan, use_kernel, dtype, secure, digits)
    if eng is None:
        eng = plan.engine(use_kernel=use_kernel, precompile=False, dtype=dtype,
                          secure=secure, digits=digits)
        eng.stats.bump("cache_misses")
        _ENGINE_CACHE.put(plan, use_kernel, dtype, eng, secure, digits)
    return eng


def _clique_strides(domain: Domain, clique: Clique) -> Tuple[np.ndarray, int]:
    sizes = [domain.attributes[i].size for i in clique]
    strides = np.ones(len(clique), np.int32)
    for j in range(len(clique) - 2, -1, -1):
        strides[j] = strides[j + 1] * sizes[j + 1]
    return strides, int(np.prod(sizes)) if clique else 1


def _local_marginal(records, cols, strides, n_cells, dtype=None):
    """One-hot-matmul histogram of the clique columns (records: (N, n_attrs)).

    ``dtype=None`` resolves to :func:`repro.core.mechanism.noise_dtype` —
    the historical hard-coded float32 default silently capped histogram
    exactness at 2²⁴ counts per cell even when the engine path threaded
    float64 everywhere else.
    """
    dtype = noise_dtype() if dtype is None else dtype
    if len(cols) == 0:
        return jnp.asarray([records.shape[0]], dtype)
    flat = jnp.zeros((records.shape[0],), jnp.int32)
    for c, s in zip(cols, strides):
        flat = flat + records[:, c] * int(s)
    oh = jax.nn.one_hot(flat, n_cells, dtype=dtype)
    return jnp.sum(oh, axis=0)


def sharded_marginals(domain: Domain, cliques: Sequence[Clique],
                      records: jnp.ndarray, mesh: Optional[Mesh] = None,
                      dtype=None) -> Dict[Clique, jnp.ndarray]:
    """Exact marginal tables for every clique, records sharded over data axes.

    ``dtype=None`` resolves to :func:`repro.core.mechanism.noise_dtype` so the
    tables match the precision of the residual transform consuming them.
    """
    dtype = noise_dtype() if dtype is None else dtype
    cliques = list(cliques)
    meta = [(_clique_strides(domain, c)) for c in cliques]

    if mesh is None:
        return {c: _local_marginal(records, list(c), meta[i][0], meta[i][1],
                                   dtype)
                for i, c in enumerate(cliques)}

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(rec):
        outs = []
        for i, c in enumerate(cliques):
            h = _local_marginal(rec, list(c), meta[i][0], meta[i][1], dtype)
            outs.append(jax.lax.psum(h, data_axes + tuple(
                a for a in mesh.axis_names if a not in data_axes)))
        return tuple(outs)

    in_spec = P(data_axes, None)
    out_specs = tuple(P() for _ in cliques)
    fn = shard_map(body, mesh=mesh, in_specs=(in_spec,), out_specs=out_specs,
                   check_rep=False)
    outs = jax.jit(fn)(records)
    return {c: o for c, o in zip(cliques, outs)}


def sharded_measure(plan: BasePlan, records: jnp.ndarray,
                    key: jax.Array, mesh: Optional[Mesh] = None,
                    use_kernel: bool = False,
                    dtype=None, secure: bool = False,
                    digits: int = 4) -> Dict[Clique, Measurement]:
    """Distributed Algorithms 1/5 (and 3): sharded marginalization + transform.

    ``plan`` is any :class:`~repro.core.plantable.BasePlan` — plain
    :class:`~repro.core.select.Plan` or ResidualPlanner+
    :class:`~repro.core.plus.PlusPlan`; the replicated transform runs on the
    signature-batched engine the plan provides (``plan.engine``), cached per
    (plan, path, dtype, secure).  ``dtype`` governs the marginal tables and
    the noise draws; ``None`` resolves to
    :func:`repro.core.mechanism.noise_dtype` (float64 under jax x64), so the
    distributed path matches the core path's precision.

    ``secure=True`` serves the numerically secure release (Alg 3) through
    :class:`~repro.engine.discrete_engine.DiscreteEngine`: same sharded
    marginalization, integer-query H/Y† transforms on the fused engine tier,
    exact discrete Gaussian noise seeded deterministically from ``key``
    (``digits`` sets the σ̄ rationalization).  Plans without an integer-query
    rotation (RP+) raise ``ValueError``.
    """
    dtype = noise_dtype() if dtype is None else dtype
    margs = sharded_marginals(plan.domain, plan.cliques, records, mesh,
                              dtype=dtype)
    return _engine_for(plan, use_kernel, dtype, secure, digits).measure(
        margs, key)
