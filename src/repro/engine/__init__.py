from .engine import EngineStats, MarginalEngine
from .plus_engine import PlusEngine
from .discrete_engine import DiscreteEngine
from .sharded import sharded_marginals, sharded_measure
from .corpus_stats import corpus_marginal_release
