from .engine import EngineStats, MarginalEngine
from .plus_engine import PlusEngine
from .sharded import sharded_marginals, sharded_measure
from .corpus_stats import corpus_marginal_release
