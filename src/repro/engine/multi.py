"""Cross-request signature-batched measurement (the serving tier's fuse point).

``measure`` (core/mechanism.py) batches the cliques of ONE plan by per-axis
signature; this module generalizes the same trick across *requests*: the
``[v; z]`` pairs of every (request, clique) whose signature matches — even
when the requests come from different tenants with different plans and
different budgets — stack into the batch axis of a single fused chain launch.
Eight tenants asking for the same ≤2-way workload shape cost the same number
of kernel launches as one tenant (docs/DESIGN.md §13).

Bit-exactness contract: each request's noise is drawn from its own key with
the exact fold order of the per-request path (``jax.random.split(key,
len(plan.cliques))`` indexed by clique position), and vmapped threefry draws
match per-key draws exactly — so ``measure_multi(items)`` returns
measurement-for-measurement the same bits as calling ``measure(plan, margs,
key)`` once per item.  The cross-tenant batching test and the serve benchmark
both assert this.

Only plain-marginal plans qualify (their chain is determined by the
attribute-size signature alone); RP+/composite/secure plans are served
per-request through their cached engines by the caller.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique
from repro.core.kron import kron_matvec_batched
from repro.core.mechanism import Measurement, noise_dtype
from repro.core.residual import sub_matrix
from repro.core.select import Plan
from repro.obs import TRACER

MultiItem = Tuple[Plan, Mapping[Clique, jnp.ndarray], jax.Array]


def can_fuse(plan) -> bool:
    """True iff this plan's measurement chains are cross-request fusable.

    Plain :class:`~repro.core.select.Plan` chains are fully determined by the
    attribute-size signature, so two requests with equal signatures share one
    chain.  RP+ plans carry per-attribute (Sub, Γ) factors and composite
    plans fan out to block engines — both are served per-request.
    """
    return type(plan) is Plan


def measure_multi(items: Sequence[MultiItem], use_kernel: bool = False,
                  dtype=None) -> List[Dict[Clique, Measurement]]:
    """Algorithm 1 for many requests at once: one chain launch per signature.

    ``items[i] = (plan, marginals, key)`` exactly as the per-request
    ``measure(plan, marginals, key)`` would receive them; the return value is
    the list of per-request measurement dicts, bit-identical to the
    per-request path.  Requests are grouped by attribute-size signature
    ACROSS items, so the launch count is the number of distinct signatures in
    the union — not the sum of per-request signature counts.
    """
    dtype = noise_dtype() if dtype is None else dtype
    for plan, _m, _k in items:
        if not can_fuse(plan):
            raise ValueError(
                f"measure_multi serves plain marginal plans only, got "
                f"{type(plan).__name__}; route this request through "
                f"plan.engine().measure")

    # (signature dims) -> list of (item_idx, clique, per-clique key row).
    # Keys are pulled host-side once per item; per-lane jax-array indexing
    # would pay one dispatch per lane.
    groups: Dict[tuple, List[tuple]] = defaultdict(list)
    for i, (plan, _margs, key) in enumerate(items):
        keys = np.asarray(jax.random.split(key, len(plan.cliques)))
        for pos, c in enumerate(plan.cliques):
            dims = tuple(plan.domain.attributes[a].size for a in c)
            groups[dims].append((i, c, keys[pos]))

    out: List[Dict[Clique, Measurement]] = [dict() for _ in items]
    for dims, members in groups.items():
        with TRACER.span("measure.multi.group").set(
                dims="x".join(map(str, dims)) if dims else "scalar",
                lanes=len(members)):
            om_host, sig2s = _measure_group(items, dims, members,
                                            use_kernel, dtype)
        for j, (i, c, _k) in enumerate(members):
            out[i][c] = Measurement(c, om_host[j], sig2s[j])
    return out


def _measure_group(items, dims, members, use_kernel, dtype):
    """One signature group: assemble lanes, launch once, slice back.

    Returns ``(om_host, sig2s)`` — the (g, m) noisy outputs on host and the
    per-lane σ² list in member order.
    """
    m = int(np.prod(dims)) if dims else 1
    # Lane assembly happens HOST-SIDE in one numpy stack + ONE device
    # transfer per group: a per-lane jnp.asarray/jnp.stack loop costs
    # ~0.5 ms of eager dispatch per lane, which at hundreds of lanes per
    # batch would swamp the launch savings the fusion exists to deliver.
    vs, sig2s = [], []
    for i, c, _k in members:
        v = np.asarray(items[i][1][c]).reshape(-1)
        if v.shape[0] != m:
            raise ValueError(
                f"marginal for {c} (request {i}) has {v.shape[0]} cells, "
                f"want {m}")
        vs.append(v)
        sig2s.append(items[i][0].sigmas[c])
    # Lane-count bucketing: pad g up to a power of two (min 8) so the
    # chain shapes repeat across drains of different sizes — otherwise
    # every new batch size pays a fresh per-shape XLA compile (~1 s for
    # a 16-request drain) that dwarfs the launch savings.  Pad lanes are
    # zero marginals with a recycled key; their outputs are sliced away,
    # and row-independence of the batched contraction keeps the real
    # lanes bit-identical to the unpadded launch (test-enforced).
    g = len(members)
    g_pad = 8
    while g_pad < g:
        g_pad *= 2
    vnp = np.stack(vs)
    if g_pad > g:
        vnp = np.concatenate(
            [vnp, np.zeros((g_pad - g, m), vnp.dtype)], axis=0)
    vstack = jnp.asarray(vnp, dtype=dtype)                   # (g_pad, m)
    keys_np = np.stack([k for _i, _c, k in members])
    if g_pad > g:
        keys_np = np.concatenate(
            [keys_np, np.repeat(keys_np[:1], g_pad - g, axis=0)], axis=0)
    z = jax.vmap(lambda k: jax.random.normal(k, (m,), dtype=dtype))(
        jnp.asarray(keys_np))
    sig = jnp.asarray(np.sqrt(np.asarray(sig2s))[:, None], dtype=dtype)
    if not dims:
        om = vstack[:g] + sig * z[:g]
    else:
        x = jnp.concatenate([vstack, z], axis=0)             # (2·g_pad, m)
        factors = [sub_matrix(n) for n in dims]
        if use_kernel:
            from repro.kernels.kron_matvec.fused import fused_chain_matvec
            y = fused_chain_matvec(factors, x, dims)
        else:
            y = kron_matvec_batched(factors, x, dims)
        om = y[:g] + sig * y[g_pad:g_pad + g]
    return np.asarray(om), sig2s
