"""MarginalEngine: a plan compiled once, served many times.

The ROADMAP's north star is serving heavy marginal-query traffic; this module
is the seed of that server.  At construction the engine walks the plan's
signature groups (docs/DESIGN.md §4–5), plans every fused kernel chain it will
ever need — the measurement chains ⊗ Sub_{n_i} over the closure and the
reconstruction chains ⊗ T_i over the workload — and warms the jit cache so
that ``measure`` / ``reconstruct`` calls on the hot path never trace or
compile.  The jit cache is keyed on the chain *signature* (per-axis factor
shapes + batch padding), so domains with repeated attribute sizes share
compilations.

Usage::

    engine = MarginalEngine(plan)
    meas   = engine.measure(marginals, key)      # one fused chain per signature
    tables = engine.reconstruct(meas)            # one fused chain per signature
    # or end-to-end (optionally through the release subsystem, §11):
    tables, meas = engine.release(marginals, key, postprocess="nonneg")
    records = engine.synthesize(1_000_000, key2)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique
from repro.core.mechanism import Measurement, measure, signature_groups
from repro.core.reconstruct import reconstruct_all_batched, u_chain_factors
from repro.core.residual import sub_matrix
from repro.core.select import Plan
from repro.kernels.kron_matvec._layout import pad_to
from repro.kernels.kron_matvec.fused import fused_chain_matvec, plan_chain


@dataclass
class EngineStats:
    measure_calls: int = 0
    reconstruct_calls: int = 0
    measure_signatures: int = 0
    reconstruct_signatures: int = 0
    fused_chains: int = 0          # chains that fit the fused VMEM budget
    fallback_chains: int = 0       # chains planned onto the per-axis path
    compile_warmups: int = 0
    tuned_chains: int = 0          # chains whose launch config came from the
    #                                autotuner (docs/DESIGN.md §14)
    # DiscreteEngine exactness-boundary counters (docs/DESIGN.md §10):
    device_h_groups: int = 0       # H groups served by the device chain + rint
    exact_h_groups: int = 0        # H groups on the exact int64/big-int path
    host_y_groups: int = 0         # Y† groups on the float64 host fallback
    # release subsystem (docs/DESIGN.md §11):
    postprocess_calls: int = 0     # release(..., postprocess=...) invocations
    synthesize_calls: int = 0      # synthesize(...) invocations
    # sharded engine-cache provenance (engine/sharded.py): how often this
    # engine was served from / constructed into the cross-call cache.
    cache_hits: int = 0
    cache_misses: int = 0


class ChainRegistry:
    """Chain-plan bookkeeping shared by MarginalEngine and PlusEngine.

    One definition of the plan key (dims, signature, padded batch) and the
    layout report keeps the two engines' stats and warmup coverage in exact
    agreement.  Subclasses provide ``self.stats`` (EngineStats) and their own
    warmup loops over ``self._chain_plans``, whose values are
    ``(ChainPlan, factors, batch, epilogue)`` tuples.

    Registration is where the autotuner hooks in (docs/DESIGN.md §14): when
    ``REPRO_KERNEL_AUTOTUNE`` is not ``off``, every chain group is tuned up
    front — in ``measure`` mode this times real kernels, safely outside any
    serving request — and the plan row reflects the tuned launch config the
    serving path will resolve.  ``role`` tags the chain's serving duty:
    ``"measure"`` chains carry Gaussian noise lanes and are always planned at
    float32; ``"reconstruct"`` chains may adopt a tuned narrow compute dtype
    (fp32 accumulation) when one is enabled.
    """

    _chain_plans: Dict[tuple, tuple]
    _chain_tune: Dict[tuple, object]
    _chain_roles: Dict[tuple, str]

    def _register_chain(self, factors: List, dims: Tuple[int, ...],
                        batch: int, epilogue: Optional[tuple] = None,
                        role: str = "measure") -> None:
        from repro.kernels.autotune import autotune_mode, tune_chain
        cfg = None
        if autotune_mode() != "off":
            cfg = tune_chain(factors, dims, batch=batch, epilogue=epilogue)
            dt = cfg.compute_dtype if role == "reconstruct" else "float32"
            cp = plan_chain(factors, dims, batch=batch, block_l=cfg.block_l,
                            vmem_budget=cfg.vmem_budget, epilogue=epilogue,
                            compute_dtype=dt)
            fused = cfg.fused and cp.fused_ok
        else:
            cp = plan_chain(factors, dims, batch=batch, epilogue=epilogue)
            fused = cp.fused_ok
        key = (tuple(dims), cp.signature, pad_to(batch, cp.block_l))
        if key not in self._chain_plans:
            self._chain_plans[key] = (cp, factors, batch, epilogue)
            if not hasattr(self, "_chain_tune"):
                self._chain_tune = {}
                self._chain_roles = {}
            self._chain_tune[key] = cfg
            self._chain_roles[key] = role
            if fused:
                self.stats.fused_chains += 1
            else:
                self.stats.fallback_chains += 1
            if cfg is not None:
                self.stats.tuned_chains += 1

    def _chain_allow_narrow(self, key: tuple) -> bool:
        """Reconstruct-role chains may serve at a tuned narrow dtype."""
        return getattr(self, "_chain_roles", {}).get(key) == "reconstruct"

    def chain_plans(self) -> List[dict]:
        """Layout report: one row per compiled chain (for ops/debugging)."""
        rows = []
        tune = getattr(self, "_chain_tune", {})
        for key, (cp, _f, batch, _e) in self._chain_plans.items():
            (dims, sig, b_p) = key
            cfg = tune.get(key)
            rows.append(dict(dims=dims, batch=batch, batch_padded=b_p,
                             w_in=cp.w_in, w_out=cp.w_out, block_l=cp.block_l,
                             vmem_bytes=cp.vmem_bytes,
                             fused=(cfg.fused and cp.fused_ok) if cfg
                             else cp.fused_ok,
                             epilogue=sig[3],
                             compute_dtype=cp.compute_dtype,
                             tuned=cfg is not None,
                             tune_source=cfg.source if cfg else "default",
                             intensity=cfg.intensity if cfg else None))
        return rows


class ReleaseServing:
    """release/postprocess/synthesize surface shared by all serving engines.

    ``release(..., postprocess="consistent"|"nonneg")`` routes the raw
    reconstruction through :mod:`repro.release` (docs/DESIGN.md §11):
    covariance-weighted consistency (precision weights straight off the
    plan's IR) and, for ``"nonneg"``, the signature-batched simplex
    projection with exact total preservation.  ``synthesize`` samples
    records from the last non-negative release (or explicit ``tables``).
    Engines override ``_postprocess_total`` (the secure path pins the
    measured integer total) and ``_check_postprocess`` (RP+ restricts to
    identity-basis schemas).
    """

    _synth_tables: Optional[Dict[Clique, np.ndarray]] = None

    def _postprocess_total(self, measurements) -> Optional[float]:
        """Total-count pin for the consistency fit (None: fit it)."""

    def _check_postprocess(self) -> None:
        """Raise when this plan family's tables are not plain marginals."""

    def release(self, marginals, key, postprocess: Optional[str] = None,
                total: Optional[float] = None, weights=None,
                mw_rounds: int = 0, **post_opts):
        """measure → reconstruct (→ postprocess); returns (tables, meas).

        ``postprocess=None`` is the historical raw unbiased release;
        ``"consistent"`` / ``"nonneg"`` run the release subsystem with
        ``total``/``weights``/``mw_rounds`` forwarded to
        :func:`repro.release.postprocess_release`.
        """
        meas = self.measure(marginals, key)
        tables = self.reconstruct(meas)
        if postprocess is not None:
            self._check_postprocess()
            from repro.release import postprocess_release
            if total is None:
                total = self._postprocess_total(meas)
            tables = postprocess_release(self.plan, tables, postprocess,
                                         total=total, weights=weights,
                                         mw_rounds=mw_rounds, **post_opts)
            self.stats.postprocess_calls += 1
            if postprocess == "nonneg":
                self._synth_tables = tables
        return tables, meas

    def synthesize(self, n_records: int, key, tables=None, order=None,
                   batch: Optional[int] = None) -> np.ndarray:
        """Sample (n_records, n_attrs) synthetic records from the marginals.

        ``tables=None`` uses the engine's last ``postprocess="nonneg"``
        release; junction-order conditional sampling is fully vectorized
        (:func:`repro.release.synthesize_records`) and never touches the
        contingency table.
        """
        if tables is None:
            tables = self._synth_tables
            if tables is None:
                raise ValueError(
                    "no non-negative release to sample from: call "
                    "release(..., postprocess=\"nonneg\") first or pass "
                    "tables=")
        from repro.release import synthesize_records
        self.stats.synthesize_calls += 1
        return synthesize_records(self.plan.domain, tables, n_records, key,
                                  order=order, batch=batch)


class MarginalEngine(ReleaseServing, ChainRegistry):
    """Compile a plan's kernel chains once; serve measure/reconstruct traffic.

    Parameters
    ----------
    plan:        selection-phase output (σ²_A per closure clique).
    use_kernel:  route chains through the fused Pallas kernel or the batched
                 jnp path (still signature-batched, no pallas_call).  The
                 default ``None`` resolves per backend — Pallas on TPU,
                 batched jnp elsewhere, where interpret-mode kernels would
                 only add Python overhead.
    precompile:  trace/compile every chain at construction so serving calls
                 are cache hits (set False for tiny one-shot jobs).
    dtype:       noise-draw dtype; ``None`` resolves to
                 :func:`repro.core.mechanism.noise_dtype`.
    """

    def __init__(self, plan: Plan, use_kernel: Optional[bool] = None,
                 precompile: bool = True, dtype=None):
        from repro.core.mechanism import noise_dtype
        from repro.kernels.kron_matvec._layout import interpret_default
        self.plan = plan
        self.use_kernel = (not interpret_default()) if use_kernel is None \
            else use_kernel
        self.dtype = noise_dtype() if dtype is None else dtype
        self.stats = EngineStats()
        self._measure_groups = signature_groups(plan.domain, plan.cliques)
        self._reconstruct_groups = signature_groups(plan.domain,
                                                    plan.workload.cliques)
        self.stats.measure_signatures = len(self._measure_groups)
        self.stats.reconstruct_signatures = len(self._reconstruct_groups)
        self._chain_plans: Dict[tuple, object] = {}
        for dims, cliques in self._measure_groups.items():
            if dims:
                self._register_chain([sub_matrix(n) for n in dims], dims,
                                     2 * len(cliques), role="measure")
        for dims, cliques in self._reconstruct_groups.items():
            if dims:
                self._register_chain(
                    u_chain_factors(plan.domain, cliques[0]), dims,
                    len(cliques), role="reconstruct")
        if precompile and self.use_kernel:
            self._warmup()

    def _warmup(self) -> None:
        """Run every planned chain once on zeros — fills the pallas/jit cache
        for the exact batch paddings the serving path will request."""
        for key, (cp, factors, batch, _epi) in self._chain_plans.items():
            dims = key[0]
            x = jnp.zeros((batch, cp.n_in), jnp.float32)
            fused_chain_matvec(
                factors, x, dims,
                allow_narrow=self._chain_allow_narrow(key)).block_until_ready()
            self.stats.compile_warmups += 1

    # ------------------------------------------------------------------ serve
    def measure(self, marginals: Mapping[Clique, jnp.ndarray],
                key: jax.Array) -> Dict[Clique, Measurement]:
        """Algorithm 1 over the whole closure: one fused chain per signature."""
        self.stats.measure_calls += 1
        return measure(self.plan, marginals, key, use_kernel=self.use_kernel,
                       batched=True, dtype=self.dtype)

    def reconstruct(self, measurements: Mapping[Clique, Measurement],
                    cliques: Optional[Sequence[Clique]] = None
                    ) -> Dict[Clique, np.ndarray]:
        """Algorithm 2 for the workload (or ``cliques``): batched merged chains."""
        self.stats.reconstruct_calls += 1
        return reconstruct_all_batched(self.plan, measurements, cliques,
                                       use_kernel=self.use_kernel)

    # release()/synthesize() come from ReleaseServing (postprocess-aware).

    # ------------------------------------------------------------- introspect
    def variances(self) -> Dict[Clique, float]:
        return self.plan.workload_variances()
