"""MarginalEngine: a plan compiled once, served many times.

The ROADMAP's north star is serving heavy marginal-query traffic; this module
is the seed of that server.  At construction the engine walks the plan's
signature groups (docs/DESIGN.md §4–5), plans every fused kernel chain it will
ever need — the measurement chains ⊗ Sub_{n_i} over the closure and the
reconstruction chains ⊗ T_i over the workload — and warms the jit cache so
that ``measure`` / ``reconstruct`` calls on the hot path never trace or
compile.  The jit cache is keyed on the chain *signature* (per-axis factor
shapes + batch padding), so domains with repeated attribute sizes share
compilations.

Usage::

    engine = MarginalEngine(plan)
    meas   = engine.measure(marginals, key)      # one fused chain per signature
    tables = engine.reconstruct(meas)            # one fused chain per signature
    # or end-to-end (optionally through the release subsystem, §11):
    tables, meas = engine.release(marginals, key, postprocess="nonneg")
    records = engine.synthesize(1_000_000, key2)
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique
from repro.core.mechanism import Measurement, measure, signature_groups
from repro.core.reconstruct import reconstruct_all_batched, u_chain_factors
from repro.core.residual import sub_matrix
from repro.core.select import Plan
from repro.kernels.kron_matvec._layout import pad_to
from repro.kernels.kron_matvec.fused import fused_chain_matvec, plan_chain
from repro.obs import REGISTRY, TRACER, AtomicCounter

# Process-wide aggregate of every EngineStats bump, labeled by counter name —
# the /metrics view of engine activity across all engines in the process
# (per-engine values stay on each EngineStats instance).
_ENGINE_EVENTS = REGISTRY.counter(
    "repro_engine_events_total",
    "Engine counter bumps aggregated across all engines", labels=("counter",))


class EngineStats:
    """Per-engine counters, backed by the obs metrics registry.

    Historically a plain dataclass of ints; engines shared through
    ``EnginePool`` are bumped from the serve worker *and* warmup/HTTP-reader
    paths, so each field is now an :class:`~repro.obs.AtomicCounter`.  Field
    access keeps the dataclass surface (``stats.measure_calls`` reads,
    ``stats.measure_signatures = n`` level-sets), while hot mutation sites
    use :meth:`bump`, which is atomic and mirrors the event into the global
    ``repro_engine_events_total{counter=...}`` family for ``/metrics``.

    Field inventory (docs/DESIGN.md §10/§11/§14):

    * measure/reconstruct_calls, measure/reconstruct_signatures
    * fused_chains / fallback_chains / tuned_chains — chain planning outcome
    * compile_warmups — warmup launches at construction
    * device_h_groups / exact_h_groups / host_y_groups — DiscreteEngine
      exactness boundary
    * postprocess_calls / synthesize_calls — release subsystem
    * cache_hits / cache_misses — sharded engine-cache provenance
    """

    _FIELDS = (
        "measure_calls", "reconstruct_calls",
        "measure_signatures", "reconstruct_signatures",
        "fused_chains", "fallback_chains", "compile_warmups", "tuned_chains",
        "device_h_groups", "exact_h_groups", "host_y_groups",
        "postprocess_calls", "synthesize_calls",
        "cache_hits", "cache_misses",
    )

    __slots__ = ("_cells",)

    def __init__(self, **initial):
        self._cells = {f: AtomicCounter(initial.pop(f, 0))
                       for f in self._FIELDS}
        if initial:
            raise TypeError(f"unknown EngineStats fields: {tuple(initial)}")

    def bump(self, name: str, n: int = 1) -> None:
        """Atomically increment ``name`` and mirror it to /metrics."""
        self._cells[name].inc(n)
        _ENGINE_EVENTS.labels(counter=name).inc(n)

    def to_dict(self) -> Dict[str, int]:
        return {f: int(self._cells[f].value) for f in self._FIELDS}

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v}" for k, v in self.to_dict().items())
        return f"EngineStats({body})"

    def __eq__(self, other) -> bool:
        if isinstance(other, EngineStats):
            return self.to_dict() == other.to_dict()
        return NotImplemented


def _stats_field(name: str) -> property:
    def _get(self) -> int:
        return int(self._cells[name].value)

    def _set(self, v: int) -> None:
        self._cells[name].set(v)

    return property(_get, _set)


for _f in EngineStats._FIELDS:
    setattr(EngineStats, _f, _stats_field(_f))
del _f


class ChainRegistry:
    """Chain-plan bookkeeping shared by MarginalEngine and PlusEngine.

    One definition of the plan key (dims, signature, padded batch) and the
    layout report keeps the two engines' stats and warmup coverage in exact
    agreement.  Subclasses provide ``self.stats`` (EngineStats) and their own
    warmup loops over ``self._chain_plans``, whose values are
    ``(ChainPlan, factors, batch, epilogue)`` tuples.

    Registration is where the autotuner hooks in (docs/DESIGN.md §14): when
    ``REPRO_KERNEL_AUTOTUNE`` is not ``off``, every chain group is tuned up
    front — in ``measure`` mode this times real kernels, safely outside any
    serving request — and the plan row reflects the tuned launch config the
    serving path will resolve.  ``role`` tags the chain's serving duty:
    ``"measure"`` chains carry Gaussian noise lanes and are always planned at
    float32; ``"reconstruct"`` chains may adopt a tuned narrow compute dtype
    (fp32 accumulation) when one is enabled.
    """

    _chain_plans: Dict[tuple, tuple]
    _chain_tune: Dict[tuple, object]
    _chain_roles: Dict[tuple, str]

    def _register_chain(self, factors: List, dims: Tuple[int, ...],
                        batch: int, epilogue: Optional[tuple] = None,
                        role: str = "measure") -> None:
        from repro.kernels.autotune import autotune_mode, tune_chain
        cfg = None
        if autotune_mode() != "off":
            cfg = tune_chain(factors, dims, batch=batch, epilogue=epilogue)
            dt = cfg.compute_dtype if role == "reconstruct" else "float32"
            cp = plan_chain(factors, dims, batch=batch, block_l=cfg.block_l,
                            vmem_budget=cfg.vmem_budget, epilogue=epilogue,
                            compute_dtype=dt)
            fused = cfg.fused and cp.fused_ok
        else:
            cp = plan_chain(factors, dims, batch=batch, epilogue=epilogue)
            fused = cp.fused_ok
        key = (tuple(dims), cp.signature, pad_to(batch, cp.block_l))
        if key not in self._chain_plans:
            self._chain_plans[key] = (cp, factors, batch, epilogue)
            if not hasattr(self, "_chain_tune"):
                self._chain_tune = {}
                self._chain_roles = {}
            self._chain_tune[key] = cfg
            self._chain_roles[key] = role
            if fused:
                self.stats.bump("fused_chains")
            else:
                self.stats.bump("fallback_chains")
            if cfg is not None:
                self.stats.bump("tuned_chains")
            self._publish_roofline(key, cp, batch)

    def _publish_roofline(self, key: tuple, cp, batch: int) -> None:
        """Export the chain's roofline predictions as gauges.

        Predicted arithmetic intensity, VMEM footprint, and runtime
        (roofline/cost_model.py) sit next to the measured
        ``repro_kernel_launch_seconds`` histogram under the same ``chain``
        label, so predicted-vs-measured drift is a single /metrics query.
        """
        try:
            from repro.obs.naming import chain_label
            from repro.roofline.cost_model import CostModel
            cost = CostModel().chain_cost(cp, batch)
            label = chain_label(key[0], batch, cp.compute_dtype)
            REGISTRY.gauge(
                "repro_chain_predicted_intensity",
                "Roofline-predicted arithmetic intensity (FLOP/byte)",
                labels=("chain",)).labels(chain=label).set(cost.intensity)
            REGISTRY.gauge(
                "repro_chain_vmem_bytes",
                "Planned VMEM footprint of the fused chain kernel",
                labels=("chain",)).labels(chain=label).set(cp.vmem_bytes)
            REGISTRY.gauge(
                "repro_chain_predicted_seconds",
                "Roofline-predicted single-launch runtime",
                labels=("chain",)).labels(chain=label).set(cost.predicted_s)
        except Exception:   # cost model is advisory; never fail registration
            pass

    def _chain_allow_narrow(self, key: tuple) -> bool:
        """Reconstruct-role chains may serve at a tuned narrow dtype."""
        return getattr(self, "_chain_roles", {}).get(key) == "reconstruct"

    def chain_plans(self) -> List[dict]:
        """Layout report: one row per compiled chain (for ops/debugging)."""
        rows = []
        tune = getattr(self, "_chain_tune", {})
        for key, (cp, _f, batch, _e) in self._chain_plans.items():
            (dims, sig, b_p) = key
            cfg = tune.get(key)
            rows.append(dict(dims=dims, batch=batch, batch_padded=b_p,
                             w_in=cp.w_in, w_out=cp.w_out, block_l=cp.block_l,
                             vmem_bytes=cp.vmem_bytes,
                             fused=(cfg.fused and cp.fused_ok) if cfg
                             else cp.fused_ok,
                             epilogue=sig[3],
                             compute_dtype=cp.compute_dtype,
                             tuned=cfg is not None,
                             tune_source=cfg.source if cfg else "default",
                             intensity=cfg.intensity if cfg else None))
        return rows


class ReleaseServing:
    """release/postprocess/synthesize surface shared by all serving engines.

    ``release(..., postprocess="consistent"|"nonneg")`` routes the raw
    reconstruction through :mod:`repro.release` (docs/DESIGN.md §11):
    covariance-weighted consistency (precision weights straight off the
    plan's IR) and, for ``"nonneg"``, the signature-batched simplex
    projection with exact total preservation.  ``synthesize`` samples
    records from the last non-negative release (or explicit ``tables``).
    Engines override ``_postprocess_total`` (the secure path pins the
    measured integer total) and ``_check_postprocess`` (RP+ restricts to
    identity-basis schemas).
    """

    _synth_tables: Optional[Dict[Clique, np.ndarray]] = None

    def _postprocess_total(self, measurements) -> Optional[float]:
        """Total-count pin for the consistency fit (None: fit it)."""

    def _check_postprocess(self) -> None:
        """Raise when this plan family's tables are not plain marginals."""

    def release(self, marginals, key, postprocess: Optional[str] = None,
                total: Optional[float] = None, weights=None,
                mw_rounds: int = 0, **post_opts):
        """measure → reconstruct (→ postprocess); returns (tables, meas).

        ``postprocess=None`` is the historical raw unbiased release;
        ``"consistent"`` / ``"nonneg"`` run the release subsystem with
        ``total``/``weights``/``mw_rounds`` forwarded to
        :func:`repro.release.postprocess_release`.
        """
        meas = self.measure(marginals, key)
        tables = self.reconstruct(meas)
        if postprocess is not None:
            self._check_postprocess()
            from repro.release import postprocess_release
            if total is None:
                total = self._postprocess_total(meas)
            tables = postprocess_release(self.plan, tables, postprocess,
                                         total=total, weights=weights,
                                         mw_rounds=mw_rounds, **post_opts)
            self.stats.bump("postprocess_calls")
            if postprocess == "nonneg":
                self._synth_tables = tables
        return tables, meas

    def synthesize(self, n_records: int, key, tables=None, order=None,
                   batch: Optional[int] = None) -> np.ndarray:
        """Sample (n_records, n_attrs) synthetic records from the marginals.

        ``tables=None`` uses the engine's last ``postprocess="nonneg"``
        release; junction-order conditional sampling is fully vectorized
        (:func:`repro.release.synthesize_records`) and never touches the
        contingency table.
        """
        if tables is None:
            tables = self._synth_tables
            if tables is None:
                raise ValueError(
                    "no non-negative release to sample from: call "
                    "release(..., postprocess=\"nonneg\") first or pass "
                    "tables=")
        from repro.release import synthesize_records
        self.stats.bump("synthesize_calls")
        return synthesize_records(self.plan.domain, tables, n_records, key,
                                  order=order, batch=batch)


class MarginalEngine(ReleaseServing, ChainRegistry):
    """Compile a plan's kernel chains once; serve measure/reconstruct traffic.

    Parameters
    ----------
    plan:        selection-phase output (σ²_A per closure clique).
    use_kernel:  route chains through the fused Pallas kernel or the batched
                 jnp path (still signature-batched, no pallas_call).  The
                 default ``None`` resolves per backend — Pallas on TPU,
                 batched jnp elsewhere, where interpret-mode kernels would
                 only add Python overhead.
    precompile:  trace/compile every chain at construction so serving calls
                 are cache hits (set False for tiny one-shot jobs).
    dtype:       noise-draw dtype; ``None`` resolves to
                 :func:`repro.core.mechanism.noise_dtype`.
    """

    def __init__(self, plan: Plan, use_kernel: Optional[bool] = None,
                 precompile: bool = True, dtype=None):
        from repro.core.mechanism import noise_dtype
        from repro.kernels.kron_matvec._layout import interpret_default
        self.plan = plan
        self.use_kernel = (not interpret_default()) if use_kernel is None \
            else use_kernel
        self.dtype = noise_dtype() if dtype is None else dtype
        self.stats = EngineStats()
        self._measure_groups = signature_groups(plan.domain, plan.cliques)
        self._reconstruct_groups = signature_groups(plan.domain,
                                                    plan.workload.cliques)
        self.stats.measure_signatures = len(self._measure_groups)
        self.stats.reconstruct_signatures = len(self._reconstruct_groups)
        self._chain_plans: Dict[tuple, object] = {}
        for dims, cliques in self._measure_groups.items():
            if dims:
                self._register_chain([sub_matrix(n) for n in dims], dims,
                                     2 * len(cliques), role="measure")
        for dims, cliques in self._reconstruct_groups.items():
            if dims:
                self._register_chain(
                    u_chain_factors(plan.domain, cliques[0]), dims,
                    len(cliques), role="reconstruct")
        if precompile and self.use_kernel:
            self._warmup()

    def _warmup(self) -> None:
        """Run every planned chain once on zeros — fills the pallas/jit cache
        for the exact batch paddings the serving path will request."""
        for key, (cp, factors, batch, _epi) in self._chain_plans.items():
            dims = key[0]
            x = jnp.zeros((batch, cp.n_in), jnp.float32)
            fused_chain_matvec(
                factors, x, dims,
                allow_narrow=self._chain_allow_narrow(key)).block_until_ready()
            self.stats.bump("compile_warmups")

    # ------------------------------------------------------------------ serve
    def measure(self, marginals: Mapping[Clique, jnp.ndarray],
                key: jax.Array) -> Dict[Clique, Measurement]:
        """Algorithm 1 over the whole closure: one fused chain per signature."""
        self.stats.bump("measure_calls")
        with TRACER.span("engine.measure").set(
                engine="marginal", cliques=len(self.plan.cliques),
                use_kernel=self.use_kernel):
            return measure(self.plan, marginals, key,
                           use_kernel=self.use_kernel, batched=True,
                           dtype=self.dtype)

    def reconstruct(self, measurements: Mapping[Clique, Measurement],
                    cliques: Optional[Sequence[Clique]] = None
                    ) -> Dict[Clique, np.ndarray]:
        """Algorithm 2 for the workload (or ``cliques``): batched merged chains."""
        self.stats.bump("reconstruct_calls")
        with TRACER.span("engine.reconstruct").set(
                engine="marginal", use_kernel=self.use_kernel):
            return reconstruct_all_batched(self.plan, measurements, cliques,
                                           use_kernel=self.use_kernel)

    # release()/synthesize() come from ReleaseServing (postprocess-aware).

    # ------------------------------------------------------------- introspect
    def variances(self) -> Dict[Clique, float]:
        return self.plan.workload_variances()
