"""PlusEngine: compile-once, signature-batched serving of ResidualPlanner+.

The pure-marginal path got the fused Kron-chain kernel, signature batching
and compile-once serving in PR 1 (engine/engine.py); this module closes the
gap for the paper's "+" workloads (§7, Algs 5/6): marginals mixed with
range / prefix-sum / custom per-attribute bases.

Three generalizations over :class:`repro.engine.engine.MarginalEngine`
(docs/DESIGN.md §8):

* **Generalized signatures** — cliques batch by per-axis ``(Sub_i, Γ_i, W_i)``
  factor shape + value tokens (``plus_signature_groups``), not attribute
  sizes: Γ_i ≠ Sub_i for non-identity bases, so equal sizes no longer imply
  equal chains.
* **Staged [v; z] measurement** — ω = (⊗Sub_i) v + σ(⊗Γ_i) z runs as at most
  two chains per group: stage A applies the general-axis ``Sub_i`` to the
  v rows (Γ_i = I there, so the noise stream skips those axes), stage B rides
  the stacked ``[v'; z]`` pairs of the whole group down the identity-axis
  chain.  All-identity groups degenerate to PR 1's single chain; all-general
  groups need no stage B chain at all.
* **Merged reconstruction with an implicit-W epilogue** — Algorithm 6's
  2^|A| subset matvecs collapse into ONE chain per workload clique via the
  generalized T_i = [Sub_i† | (1/n_i)·1] embedding, with W_i folded into the
  chain factor (identity/total/custom) or applied implicitly: prefix as a
  cumsum epilogue, range as cumsum + prefix-difference gathers — the
  O(n²)-row ``w_range`` matrix never enters a dense matvec on the hot path.

Every per-group transform is compiled exactly once: on the batched-jnp path
(CPU/GPU default) the whole group pipeline — chains, epilogue, range
expansion, [v; z] noise combine — is one ``jax.jit`` cache entry keyed on the
group signature; on the Pallas path the fused chains go through the
``fused_chain_matvec`` kernel cache (with in-kernel epilogues) and only the
shape-changing range expansion is jitted separately.  Noise is drawn as one
vectorized per-group fold gather, never one dispatch per clique.

Usage::

    engine = PlusEngine(plan)                    # plan: core.plus.select_plus
    meas   = engine.measure(marginals, key)      # Alg 5, batched on device
    tables = engine.reconstruct(meas)            # Alg 6, batched on device
    tables, meas = engine.release(marginals, key)
"""
from __future__ import annotations

import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique
from repro.core.kron import kron_matvec_batched, kron_out_dims
from repro.core.mechanism import Measurement, noise_dtype
from repro.core.plus import (PlusPlan, measure_chain_split,
                             plus_signature_groups, t_chain_factors_plus)
from repro.core.reconstruct import subset_slot_region
from repro.engine.engine import ChainRegistry, EngineStats, ReleaseServing
from repro.kernels.kron_matvec._layout import interpret_default
from repro.kernels.kron_matvec.fused import apply_epilogue, fused_chain_matvec
from repro.kernels.kron_matvec.stats import CHAIN_STATS
from repro.obs import TRACER


def expand_range_axis(t: jnp.ndarray, axis: int, n: int) -> jnp.ndarray:
    """Implicit ``w_range`` from per-axis prefix sums: rows p[b] − p[a−1].

    ``t`` carries cumulative sums along ``axis`` (the cumsum epilogue output,
    size n); n static slice-subtracts expand them to all n(n+1)/2 contiguous
    ranges in ``w_range`` row order (a-major) without ever touching the dense
    O(n²)-row matrix.  Contiguous slices beat a 2×n(n+1)/2 gather on every
    backend.
    """
    p = jnp.moveaxis(t, axis, -1)
    parts = [p]                                      # a = 0: p[b]
    for a in range(1, n):
        parts.append(p[..., a:] - p[..., a - 1:a])   # p[b] − p[a−1], b ≥ a
    return jnp.moveaxis(jnp.concatenate(parts, axis=-1), -1, axis)


class PlusEngine(ReleaseServing, ChainRegistry):
    """Compile a PlusPlan's kernel chains once; serve Alg 5/6 traffic.

    Parameters
    ----------
    plan:        ``core.plus.select_plus`` output — a
                 :class:`~repro.core.plantable.BasePlan` carrying the RP+
                 PlanTable IR plus the per-attribute generalized bases
                 (``plan.schema``); σ² access goes through the unified
                 protocol (``plan.sigma2``).
    use_kernel:  route chains through the fused Pallas kernel or the jitted
                 batched jnp path.  The default ``None`` resolves per
                 backend — Pallas on TPU, batched jnp elsewhere.
    precompile:  trace/compile every chain at construction so serving calls
                 are cache hits (set False for tiny one-shot jobs).
    dtype:       noise-draw dtype; ``None`` resolves to
                 :func:`repro.core.mechanism.noise_dtype`.
    """

    def __init__(self, plan: PlusPlan, use_kernel: Optional[bool] = None,
                 precompile: bool = True, dtype=None):
        self.plan = plan
        self.schema = plan.schema
        self.use_kernel = (not interpret_default()) if use_kernel is None \
            else use_kernel
        self.dtype = noise_dtype() if dtype is None else dtype
        self.stats = EngineStats()
        self._pos = {c: i for i, c in enumerate(plan.cliques)}
        self._measure_groups = plus_signature_groups(self.schema, plan.cliques)
        self._reconstruct_groups = plus_signature_groups(
            self.schema, plan.workload.cliques)
        self.stats.measure_signatures = len(self._measure_groups)
        self.stats.reconstruct_signatures = len(self._reconstruct_groups)
        self._measure_specs = {
            tok: self._build_measure_spec(tok, cliques)
            for tok, cliques in self._measure_groups.items() if tok}
        # reconstruction state is built on first use (or at precompile):
        # measure-only consumers (e.g. sharded_measure) never pay for it.
        self._reconstruct_specs: Optional[Dict[tuple, dict]] = None
        self._chain_plans: Dict[tuple, object] = {}
        for tok, cliques in self._measure_groups.items():
            if not tok:
                continue
            spec = self._measure_specs[tok]
            dims, zdims, stage_a, stage_b = spec["split"]
            if any(f is not None for f in stage_a):
                self._register_chain(stage_a, dims, len(cliques),
                                     role="measure")
            if any(f is not None for f in stage_b):
                self._register_chain(stage_b, zdims, 2 * len(cliques),
                                     role="measure")
        if precompile:
            self._warmup()

    def _ensure_reconstruct_state(self) -> Dict[tuple, dict]:
        if self._reconstruct_specs is None:
            self._reconstruct_specs = {
                tok: self._build_reconstruct_spec(cliques[0])
                for tok, cliques in self._reconstruct_groups.items() if tok}
            for tok, cliques in self._reconstruct_groups.items():
                if tok:
                    spec = self._reconstruct_specs[tok]
                    self._register_chain(spec["factors"], spec["in_dims"],
                                         len(cliques), spec["epilogue"],
                                         role="reconstruct")
        return self._reconstruct_specs

    # ------------------------------------------------------------ group prep
    def _build_measure_spec(self, tok: tuple, cliques: List[Clique]) -> dict:
        dims, zdims, stage_a, stage_b = measure_chain_split(self.schema,
                                                            cliques[0])
        g = len(cliques)
        m = int(np.prod(dims)) if dims else 1
        mz = int(np.prod(zdims)) if zdims else 1
        sig = np.sqrt([self.plan.sigma2(c) for c in cliques])[:, None]
        has_a = any(f is not None for f in stage_a)
        has_b = any(f is not None for f in stage_b)
        a_facs = [None if f is None else jnp.asarray(f, jnp.float32)
                  for f in stage_a]
        b_facs = [None if f is None else jnp.asarray(f, jnp.float32)
                  for f in stage_b]
        sig_j = jnp.asarray(sig, jnp.float32)

        def combine(v_stack, z):
            """Staged Alg 5 for the whole group, one trace (jnp path)."""
            if has_a:
                v_stack = kron_matvec_batched(a_facs, v_stack, dims)
            x = jnp.concatenate([v_stack.astype(z.dtype), z], axis=0)
            if has_b:
                x = kron_matvec_batched(b_facs, x, zdims)
            return x[:g] + sig_j * x[g:]

        dtype = self.dtype

        def draw(ks):
            return jax.vmap(lambda k: jax.random.normal(k, (mz,), dtype))(ks)

        return dict(split=(dims, zdims, stage_a, stage_b), g=g, m=m, mz=mz,
                    sig=sig, has_a=has_a, has_b=has_b,
                    key_idx=np.asarray([self._pos[c] for c in cliques]),
                    combine=jax.jit(combine), draw=jax.jit(draw))

    def _build_reconstruct_spec(self, clique: Clique) -> dict:
        """Merged-chain layout for one reconstruction signature group.

        Per axis: the chain factor (T_i, or W_i·T_i when W_i is folded in),
        the in-chain epilogue op, and the post-chain range expansion indices
        (None unless kind == 'range').
        """
        factors: List[np.ndarray] = []
        in_dims: List[int] = []
        epilogue: List[Optional[str]] = []
        posts: List[Optional[int]] = []   # range axes: n (expansion size)
        for i, t_i in zip(clique, t_chain_factors_plus(self.schema, clique)):
            b = self.schema.bases[i]
            in_dims.append(t_i.shape[1])
            if b.kind in ("prefix", "range"):
                factors.append(t_i)
                epilogue.append("cumsum")
                posts.append(b.n if b.kind == "range" else None)
            else:   # identity / total / custom: fold W into the chain factor
                factors.append(b.W @ t_i)
                epilogue.append(None)
                posts.append(None)
        chain_out = kron_out_dims(factors, in_dims)
        facs_j = [jnp.asarray(f, jnp.float32) for f in factors]
        epilogue = tuple(epilogue)

        def expand(t):
            for axis, post in enumerate(posts):
                if post is not None:
                    t = expand_range_axis(t, axis + 1, post)
            return t.reshape(t.shape[0], -1)

        def full(x):
            """Chain + epilogue + expansion, one trace (jnp path)."""
            y = kron_matvec_batched(facs_j, x, in_dims)
            y = apply_epilogue(y, chain_out, epilogue)
            return expand(y.reshape((x.shape[0],) + tuple(chain_out)))

        return dict(factors=factors, in_dims=in_dims, epilogue=epilogue,
                    chain_out=chain_out, posts=posts,
                    expand=jax.jit(expand), full=jax.jit(full))

    def _warmup(self) -> None:
        """Trace/compile every per-group transform on zeros, so serving calls
        are jit/pallas cache hits at the exact shapes traffic will use."""
        self._ensure_reconstruct_state()
        if self.use_kernel:
            for key, (cp, factors, batch, epi) in self._chain_plans.items():
                dims = key[0]
                x = jnp.zeros((batch, cp.n_in), jnp.float32)
                fused_chain_matvec(
                    factors, x, dims, epilogue=epi,
                    allow_narrow=self._chain_allow_narrow(key)
                ).block_until_ready()
                self.stats.bump("compile_warmups")
        for tok in self._measure_groups:
            if not tok:
                continue
            s = self._measure_specs[tok]
            s["draw"](jnp.zeros((s["g"], 2), jnp.uint32))
            if not self.use_kernel:
                s["combine"](jnp.zeros((s["g"], s["m"]), jnp.float32),
                             jnp.zeros((s["g"], s["mz"]), self.dtype))
                self.stats.bump("compile_warmups")
        for tok, cliques in self._reconstruct_groups.items():
            if not tok:
                continue
            s = self._reconstruct_specs[tok]
            g = len(cliques)
            if self.use_kernel:
                s["expand"](jnp.zeros((g,) + tuple(s["chain_out"]),
                                      jnp.float32))
            else:
                s["full"](jnp.zeros((g, int(np.prod(s["in_dims"]))),
                                    jnp.float32))
                self.stats.bump("compile_warmups")

    # ---------------------------------------------------------------- noise
    def _fold_keys(self, key: jax.Array) -> jax.Array:
        """One key fold per base mechanism, in ``plan.cliques`` order."""
        return jax.random.split(key, len(self.plan.cliques))

    def _draw_empty(self, all_keys: jax.Array, clique: Clique) -> jnp.ndarray:
        return jax.random.normal(all_keys[self._pos[clique]], (1,), self.dtype)

    def _draw_group(self, all_keys: jax.Array, spec: dict) -> jnp.ndarray:
        return spec["draw"](all_keys[spec["key_idx"]])

    def noise_draws(self, key: jax.Array) -> Dict[Clique, np.ndarray]:
        """The per-clique Gaussian draws ``measure(·, key)`` consumes.

        Shares the exact fold/draw helpers with :meth:`measure`, so the
        values are identical whether serving runs the kernel or the jnp
        path.  Exposed so tests can replay the exact noise into the numpy
        oracle ``measure_plus_np``.
        """
        all_keys = self._fold_keys(key)
        out: Dict[Clique, np.ndarray] = {}
        for tok, cliques in self._measure_groups.items():
            if not tok:
                for c in cliques:
                    out[c] = np.asarray(self._draw_empty(all_keys, c),
                                        np.float64)
                continue
            z = np.asarray(self._draw_group(all_keys,
                                            self._measure_specs[tok]),
                           np.float64)
            for i, c in enumerate(cliques):
                out[c] = z[i]
        return out

    # ------------------------------------------------------------------ serve
    def measure(self, marginals: Mapping[Clique, jnp.ndarray],
                key: jax.Array) -> Dict[Clique, Measurement]:
        """Algorithm 5 over the whole closure, signature-batched on device.

        ``marginals[A]`` must hold the exact marginal table for every A in
        the plan's closure (flattened or tensor shaped).
        """
        self.stats.bump("measure_calls")
        with TRACER.span("engine.measure").set(
                engine="plus", cliques=len(self.plan.cliques),
                use_kernel=self.use_kernel):
            return self._measure_impl(marginals, key)

    def _measure_impl(self, marginals, key):
        all_keys = self._fold_keys(key)
        out: Dict[Clique, Measurement] = {}
        for tok, cliques in self._measure_groups.items():
            if not tok:
                for c in cliques:
                    v = np.asarray(marginals[c], np.float64).reshape(-1)
                    z = np.asarray(self._draw_empty(all_keys, c))
                    s2 = self.plan.sigma2(c)
                    out[c] = Measurement(c, v + math.sqrt(s2) * z, s2)
                continue
            s = self._measure_specs[tok]
            g, m = s["g"], s["m"]
            vs = np.empty((g, m), np.float64)
            for i, c in enumerate(cliques):
                v = np.asarray(marginals[c], np.float64).reshape(-1)
                if v.shape[0] != m:
                    raise ValueError(
                        f"marginal for {c} has {v.shape[0]} cells, want {m}")
                vs[i] = v
            z = self._draw_group(all_keys, s)
            if self.use_kernel:
                om = self._measure_group_kernel(s, jnp.asarray(vs), z)
            else:
                om = s["combine"](jnp.asarray(vs), z)
            om = np.asarray(om)
            for i, c in enumerate(cliques):
                out[c] = Measurement(c, om[i], self.plan.sigma2(c))
        return out

    def _measure_group_kernel(self, s: dict, v_stack, z):
        """Staged Alg 5 through the fused Pallas chains (stats instrumented)."""
        dims, zdims, stage_a, stage_b = s["split"]
        if s["has_a"]:
            v_stack = fused_chain_matvec(stage_a, v_stack, dims)
        x = jnp.concatenate([v_stack.astype(z.dtype), z], axis=0)
        if s["has_b"]:
            x = fused_chain_matvec(stage_b, x, zdims)
        g = s["g"]
        return x[:g] + jnp.asarray(s["sig"], x.dtype) * x[g:]

    def _embed_group(self, measurements: Mapping[Clique, Measurement],
                     group: List[Clique], in_dims: Sequence[int]) -> np.ndarray:
        """Batched Σ_{A'⊆A} e_{A'} embeddings for a whole signature group.

        All cliques of a group share the slot layout (it depends only on the
        per-axis ranks), so each of the 2^k subset patterns is filled with one
        vectorized assignment across the group instead of per clique.
        """
        import itertools
        g, k = len(group), len(in_dims)
        t = np.zeros((g,) + tuple(in_dims), np.float64)
        c0 = group[0]
        for mask in itertools.product((False, True), repeat=k):
            region, shape = subset_slot_region(
                c0, tuple(a for a, inc in zip(c0, mask) if inc), in_dims)
            block = np.empty((g,) + shape, np.float64)
            for i, c in enumerate(group):
                sub = tuple(a for a, inc in zip(c, mask) if inc)
                block[i] = np.asarray(measurements[sub].omega,
                                      np.float64).reshape(shape)
            t[(slice(None),) + region] = block
        return t.reshape(g, -1)

    def reconstruct(self, measurements: Mapping[Clique, Measurement],
                    cliques: Optional[Sequence[Clique]] = None
                    ) -> Dict[Clique, np.ndarray]:
        """Algorithm 6 for the workload (or ``cliques``): one merged chain
        per signature group, with prefix/range W_i applied implicitly."""
        self.stats.bump("reconstruct_calls")
        with TRACER.span("engine.reconstruct").set(
                engine="plus", use_kernel=self.use_kernel):
            return self._reconstruct_impl(measurements, cliques)

    def _reconstruct_impl(self, measurements, cliques=None):
        specs = self._ensure_reconstruct_state()
        if cliques is None:
            groups = self._reconstruct_groups
        else:
            groups = plus_signature_groups(self.schema, cliques)
        out: Dict[Clique, np.ndarray] = {}
        for tok, group in groups.items():
            if not tok:
                for c in group:
                    out[c] = np.asarray(measurements[()].omega,
                                        dtype=float).reshape(-1)
                continue
            s = specs.get(tok)
            if s is None:   # ad-hoc clique outside the workload's signatures
                s = specs[tok] = self._build_reconstruct_spec(group[0])
            x = self._embed_group(measurements, group, s["in_dims"])
            if self.use_kernel:
                y = fused_chain_matvec(s["factors"], jnp.asarray(x),
                                       s["in_dims"], epilogue=s["epilogue"],
                                       allow_narrow=True)
                y = s["expand"](y.reshape((len(group),)
                                          + tuple(s["chain_out"])))
            else:
                y = s["full"](jnp.asarray(x, jnp.float32))
                CHAIN_STATS.inc("epilogue_axes",
                                sum(1 for op in s["epilogue"] if op))
            y = np.asarray(y)
            for i, c in enumerate(group):
                out[c] = y[i]
        return out

    # release()/synthesize() come from ReleaseServing.  Postprocessing and
    # synthesis operate on *marginal tables*: they are available exactly when
    # every attribute basis is the identity (W_i = I, so Alg 6's answers ARE
    # the marginals); generalized range/prefix answers are not a consistent-
    # marginal family and are rejected up front.
    def _check_postprocess(self) -> None:
        bad = [i for i, b in enumerate(self.schema.bases)
               if b.kind != "identity"]
        if bad:
            raise ValueError(
                "postprocess/synthesize require identity-basis marginals; "
                f"attributes {bad} use non-identity bases "
                f"({[self.schema.bases[i].kind for i in bad]})")
