r"""DiscreteEngine: the secure release path at fused-engine tier (Alg 3).

``measure_discrete`` (core/discrete.py) is the host-exact reference: per
clique, ``kron_matvec_np`` for H = ⊗(n_i·I − 11ᵀ) and Y† = ⊗ Sub†/n_i around
a serial noise draw.  This engine is the serving-grade rebuild
(docs/DESIGN.md §10): the same mechanism, but

* **signature-batched device transforms** — cliques with equal attribute-size
  signatures stack into the batch axis of ONE fused Kron chain per group for
  both H (forward) and Y† (reconstruction), exactly like
  :class:`~repro.engine.engine.MarginalEngine` batches Algorithm 1.  No
  per-clique ``kron_matvec_np`` remains on the hot path (test-enforced);
* **host-exact noise only** — the discrete Gaussian draw runs through the
  batched integer-lane sampler (:mod:`repro.core.dgauss`), pooled across the
  cliques of a group that share γ².  Exactness of the *noise* is what the
  privacy proof needs; it never leaves the host;
* **an explicit exactness boundary for H** — Ξx = Hv must be released as
  exact integers.  The engine bounds ‖Hv‖∞ from the actual tables
  (ℓ1-growth: ‖v‖₁·Π 2n_i, times max n_i for intermediates) and routes the
  group to the device chain + ``rint`` only while every intermediate is
  exactly representable in the chain dtype's mantissa; beyond that the group
  falls back to an *exact integer* batched tensordot (int64, then Python
  big-int lanes) — still one transform per group, never per clique.
  Y† is post-processing (Thm 6): device floats are always acceptable there,
  with a float64 host fallback only to keep huge-γ² lanes finite in f32.

Usage::

    engine = plan.engine(secure=True)        # or DiscreteEngine(plan)
    meas   = engine.measure(marginals, key)  # key: jax key / np Generator /
    tables = engine.reconstruct(meas)        #      random.Random
    tables, meas = engine.release(marginals, key)
"""
from __future__ import annotations

import random
from collections import defaultdict
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import dgauss
from repro.core.discrete import (DiscreteMeasurement, clique_gamma2,
                                 discrete_pcost_of_plan, h_factors,
                                 ypinv_factors)
from repro.core.domain import Clique
from repro.core.kron import kron_matvec_batched, kron_matvec_np_batched
from repro.core.mechanism import noise_dtype, signature_groups
from repro.core.plantable import BasePlan
from repro.core.reconstruct import reconstruct_all_batched, u_chain_factors
from repro.engine.engine import ChainRegistry, EngineStats, ReleaseServing
from repro.obs import TRACER
from repro.kernels.kron_matvec._layout import interpret_default
from repro.kernels.kron_matvec.fused import fused_chain_matvec

# f32 chains hold integers exactly below 2^24, f64 below 2^53.
_MANTISSA_BITS = {"float32": 24, "float64": 53}


# Exact host fallback: one batched tensordot chain per group, any dtype
# (int64 / object big-int / float64) — batched, never per clique.
_np_chain_batched = kron_matvec_np_batched


def as_np_rng(key) -> np.random.Generator:
    """Normalize a randomness source (jax key / Generator / Random).

    jax keys seed a ``SeedSequence`` from their raw key data, so the secure
    path keeps the engines' key-passing convention (``measure(margs, key)``)
    while the draws stay host-side and exact.
    """
    if isinstance(key, (np.random.Generator, random.Random)):
        return dgauss.as_np_rng(key)
    try:
        data = np.asarray(jax.random.key_data(key))
    except (TypeError, AttributeError):
        data = np.asarray(key)
    data = np.atleast_1d(data).reshape(-1).astype(np.uint32)
    return np.random.default_rng(np.random.SeedSequence(data.tolist()))


class DiscreteEngine(ReleaseServing, ChainRegistry):
    """Compile a plan's secure-release chains once; serve Alg 3 traffic.

    Parameters
    ----------
    plan:        selection-phase output over a *plain* (identity-basis) IR —
                 the integer-query rotation does not exist for RP+ bases.
    use_kernel:  route chains through the fused Pallas kernel or the batched
                 jnp path; ``None`` resolves per backend like the other
                 engines (Pallas on TPU, batched jnp elsewhere).
    precompile:  trace/compile every chain at construction.
    dtype:       device-transform dtype; ``None`` resolves to
                 :func:`repro.core.mechanism.noise_dtype`.  Only the H
                 exactness bound and Y† precision depend on it — the noise
                 itself is integer-exact regardless.
    digits:      σ̄ rationalization digits (Alg 3 line 1 / §5.2).
    """

    def __init__(self, plan: BasePlan, use_kernel: Optional[bool] = None,
                 precompile: bool = True, dtype=None, digits: int = 4):
        if not getattr(plan.table, "plain", True):
            raise ValueError("DiscreteEngine requires a plain (identity-basis)"
                             " plan; RP+ plans have no integer-query rotation")
        self.plan = plan
        self.digits = digits
        self.use_kernel = (not interpret_default()) if use_kernel is None \
            else use_kernel
        self.dtype = noise_dtype() if dtype is None else dtype
        self.stats = EngineStats()
        # Exact per-clique σ̄/γ² (Alg 3 lines 1-2), computed once.
        self.sigma_bars: Dict[Clique, object] = {}
        self.gamma2s: Dict[Clique, object] = {}
        for c in plan.cliques:
            sb, g2, _ = clique_gamma2(plan, c, digits)
            self.sigma_bars[c] = sb
            self.gamma2s[c] = g2
        self._groups = signature_groups(plan.domain, plan.cliques)
        self._reconstruct_groups = signature_groups(plan.domain,
                                                    plan.workload.cliques)
        self.stats.measure_signatures = len(self._groups)
        self.stats.reconstruct_signatures = len(self._reconstruct_groups)
        self._chain_plans: Dict[tuple, object] = {}
        for dims, cliques in self._groups.items():
            if dims:
                self._register_chain(h_factors(dims), dims,
                                     len(cliques))
                self._register_chain(ypinv_factors(dims), dims, len(cliques))
        for dims, cliques in self._reconstruct_groups.items():
            if dims:
                self._register_chain(u_chain_factors(plan.domain, cliques[0]),
                                     dims, len(cliques))
        if precompile and self.use_kernel:
            self._warmup()

    def _warmup(self) -> None:
        for key, (cp, factors, batch, _epi) in self._chain_plans.items():
            x = jnp.zeros((batch, cp.n_in), jnp.float32)
            fused_chain_matvec(factors, x, key[0]).block_until_ready()
            self.stats.bump("compile_warmups")

    # ------------------------------------------------------------ transforms
    def _device_chain(self, factors: List[np.ndarray], x: np.ndarray,
                      dims: Tuple[int, ...]) -> np.ndarray:
        if self.use_kernel:
            y = fused_chain_matvec(factors, jnp.asarray(x, jnp.float32), dims)
        else:
            y = kron_matvec_batched(
                [jnp.asarray(f, self.dtype) for f in factors],
                jnp.asarray(x, self.dtype), dims)
        return np.asarray(y, np.float64)

    def _chain_dtype_name(self) -> str:
        return "float32" if self.use_kernel else jnp.dtype(self.dtype).name

    def _h_transform(self, vs: np.ndarray, dims: Tuple[int, ...]) -> np.ndarray:
        """Exact Ξx = Hv for a stacked group of marginal tables (counts).

        Device chain + ``rint`` while every intermediate provably stays
        inside the chain dtype's exact-integer range; exact host int64 /
        big-int batched tensordot beyond (stats-counted).  Every tier returns
        *exact integers* — as int64 when they fit, object (Python big-int)
        lanes beyond — so the noise addition downstream is exact too.
        """
        # ℓ1 growth bound: per axis ‖(nI-11ᵀ)u‖₁ ≤ 2n‖u‖₁, and intermediates
        # inside a dot are ≤ max(n)·running bound.
        l1 = float(np.abs(vs).sum(axis=1).max(initial=0.0))
        growth = 1.0
        for n in dims:
            growth *= 2 * n
        bound = l1 * growth * max(dims)
        mant = _MANTISSA_BITS[self._chain_dtype_name()]
        if bound < float(1 << mant):
            self.stats.bump("device_h_groups")
            hv = np.rint(self._device_chain(
                h_factors(dims), vs, dims))
            return hv.astype(np.int64)
        self.stats.bump("exact_h_groups")
        facs = h_factors(dims, np.int64)
        if bound < float(1 << 62):
            return _np_chain_batched(facs, np.rint(vs).astype(np.int64), dims)
        obj = np.array([[int(v) for v in row] for row in np.rint(vs)],
                       dtype=object)
        return _np_chain_batched([f.astype(object) for f in facs], obj, dims)

    def _y_transform(self, noisy: np.ndarray, dims: Tuple[int, ...]
                     ) -> np.ndarray:
        """Y† = ⊗ Sub†/n on the noisy integers — post-processing (Thm 6),
        device floats by design; float64 host fallback only when huge-γ²
        lanes would overflow a float32 chain."""
        if self._chain_dtype_name() == "float32" and \
                float(np.abs(noisy).max(initial=0.0)) >= 3e38:
            self.stats.bump("host_y_groups")
            return _np_chain_batched(ypinv_factors(dims),
                                     np.asarray(noisy, np.float64), dims)
        return self._device_chain(ypinv_factors(dims), noisy, dims)

    # ----------------------------------------------------------------- noise
    def _draw_group(self, cliques: List[Clique], n_prod: int,
                    rng: np.random.Generator) -> Dict[Clique, np.ndarray]:
        """Pooled integer-lane draws: cliques sharing γ² share one batched
        ``dgauss.sample`` call (γ² differs only when σ̄ does)."""
        by_gamma2 = defaultdict(list)
        for c in cliques:
            by_gamma2[self.gamma2s[c]].append(c)
        out: Dict[Clique, np.ndarray] = {}
        for g2, cs in by_gamma2.items():
            z = dgauss.sample(g2, n_prod * len(cs), rng)
            for i, c in enumerate(cs):
                out[c] = z[i * n_prod:(i + 1) * n_prod]
        return out

    # ----------------------------------------------------------------- serve
    def measure(self, marginals: Mapping[Clique, np.ndarray], key,
                _noise_override=None) -> Dict[Clique, DiscreteMeasurement]:
        """Algorithm 3 over the whole closure: one fused H chain and one
        fused Y† chain per signature group, host-exact noise in between.

        ``key`` may be a jax PRNG key, an ``np.random.Generator`` or a
        ``random.Random`` (see :func:`as_np_rng`); draws are
        seed-deterministic per key.
        """
        self.stats.bump("measure_calls")
        with TRACER.span("engine.measure").set(
                engine="discrete", cliques=len(self.plan.cliques),
                use_kernel=self.use_kernel):
            return self._measure_impl(marginals, key, _noise_override)

    def _measure_impl(self, marginals, key, _noise_override=None):
        rng = as_np_rng(key)
        out: Dict[Clique, DiscreteMeasurement] = {}
        for dims, cliques in self._groups.items():
            if not dims:
                for c in cliques:
                    v = np.asarray(marginals[c], np.float64).reshape(-1)
                    z = (_noise_override(self.gamma2s[c], 1, rng)
                         if _noise_override is not None
                         else dgauss.sample(self.gamma2s[c], 1, rng))
                    sb = self.sigma_bars[c]
                    out[c] = DiscreteMeasurement(
                        c, v + np.asarray(z, np.float64), float(sb ** 2),
                        sb, self.gamma2s[c])
                continue
            m = int(np.prod(dims))
            g = len(cliques)
            vs = np.empty((g, m), np.float64)
            for i, c in enumerate(cliques):
                v = np.asarray(marginals[c], np.float64).reshape(-1)
                if v.shape[0] != m:
                    raise ValueError(
                        f"marginal for {c} has {v.shape[0]} cells, want {m}")
                vs[i] = v
            hv = self._h_transform(vs, dims)                       # = Ξx, exact
            if _noise_override is not None:
                zs = {c: _noise_override(self.gamma2s[c], m, rng)
                      for c in cliques}
            else:
                zs = self._draw_group(cliques, m, rng)
            # M'(x) = Ξx + z summed in exact integer arithmetic; the single
            # float64 conversion of the sum is post-processing (DESIGN §10).
            noisy = np.empty((g, m), np.float64)
            for i, c in enumerate(cliques):
                z = np.asarray(zs[c])
                if hv.dtype == object or z.dtype == object:
                    s = hv[i].astype(object) + z.astype(object)
                else:
                    s = hv[i] + z                  # int64, |Ξx| + |z| < 2^63
                noisy[i] = s.astype(np.float64)
            om = self._y_transform(noisy, dims)
            for i, c in enumerate(cliques):
                sb = self.sigma_bars[c]
                out[c] = DiscreteMeasurement(c, om[i], float(sb ** 2),
                                             sb, self.gamma2s[c])
        return out

    def reconstruct(self, measurements: Mapping[Clique, DiscreteMeasurement],
                    cliques: Optional[Sequence[Clique]] = None
                    ) -> Dict[Clique, np.ndarray]:
        """Algorithm 2 on the discrete measurements (drop-in ω): batched
        merged U-chains, shared with the continuous engine."""
        self.stats.bump("reconstruct_calls")
        with TRACER.span("engine.reconstruct").set(
                engine="discrete", use_kernel=self.use_kernel):
            return reconstruct_all_batched(self.plan, measurements, cliques,
                                           use_kernel=self.use_kernel)

    # release()/synthesize() come from ReleaseServing; the secure path pins
    # the consistency fit to the *measured integer total*, so postprocessed
    # families preserve it integer-exactly (DESIGN.md §11).
    def _postprocess_total(self, measurements) -> float:
        from repro.release import measured_integer_total
        return measured_integer_total(measurements)

    # ------------------------------------------------------------ accounting
    def rho(self) -> float:
        """Total ρ-zCDP actually spent at the rationalized σ̄ (Thm 6)."""
        return discrete_pcost_of_plan(self.plan, self.digits) / 2.0

    def pcost(self) -> float:
        """pcost (= 2ρ) for :class:`~repro.core.accountant.PrivacyBudget`."""
        return discrete_pcost_of_plan(self.plan, self.digits)
