"""DP release of training-corpus statistics through ResidualPlanner.

The plane-A ↔ plane-B integration: document-level attributes of the LM
training stream (source, language bucket, length bucket, quality bucket,
expert-routing bucket, …) form a tabular domain; curators get unbiased noisy
marginals over it — e.g. source × length tables, or expert × domain tables
for MoE routing audits — with the optimal mechanism and exact variances,
while the privacy budget is shared with DP-SGD via the common accountant.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (Domain, MarginalWorkload, PrivacyBudget,
                        reconstruct_all, select)
from repro.core.mechanism import pcost_of_plan
from .sharded import sharded_measure


def corpus_marginal_release(domain: Domain, workload: MarginalWorkload,
                            records: jnp.ndarray, budget: PrivacyBudget,
                            pcost: float, key: jax.Array,
                            objective: str = "sum_of_variances",
                            mesh=None, secure: bool = False,
                            digits: int = 4,
                            postprocess: Optional[str] = None,
                            mw_rounds: int = 0) -> Tuple[Dict, Dict, Dict]:
    """Select → (sharded) measure → reconstruct; charges the shared budget.

    ``secure=True`` releases through the numerically secure path (Alg 3,
    :class:`~repro.engine.discrete_engine.DiscreteEngine`): integer queries
    plus exact discrete Gaussian noise at the rationalized σ̄ ≥ σ, with the
    budget charged the *exact* discrete pcost 2·Σ_A ρ_A
    (:func:`repro.core.discrete.discrete_pcost_of_plan` — never more than
    the continuous ``pcost_of_plan``, Thm 6).

    ``postprocess`` is the sharded passthrough into the release subsystem
    (docs/DESIGN.md §11): ``"consistent"`` / ``"nonneg"`` run the
    covariance-weighted postprocessor on the reconstructed tables — pure
    post-processing, so the privacy charge is unchanged; the secure path
    pins the family total to the measured integer.

    Returns (noisy marginal tables, per-marginal variances, privacy report).
    """
    plan = select(workload, pcost_budget=pcost, objective=objective)
    if secure:
        from repro.core.discrete import discrete_pcost_of_plan
        budget.charge(discrete_pcost_of_plan(plan, digits))
    else:
        budget.charge(pcost_of_plan(plan))
    meas = sharded_measure(plan, records, key, mesh, secure=secure,
                           digits=digits)
    tables = reconstruct_all(plan, meas)
    if postprocess is not None:
        from repro.release import measured_integer_total, postprocess_release
        total = measured_integer_total(meas) if secure else None
        tables = postprocess_release(plan, tables, postprocess, total=total,
                                     mw_rounds=mw_rounds)
    variances = plan.workload_variances()
    return tables, variances, budget.report()
