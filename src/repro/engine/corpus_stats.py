"""DP release of training-corpus statistics through ResidualPlanner.

The plane-A ↔ plane-B integration: document-level attributes of the LM
training stream (source, language bucket, length bucket, quality bucket,
expert-routing bucket, …) form a tabular domain; curators get unbiased noisy
marginals over it — e.g. source × length tables, or expert × domain tables
for MoE routing audits — with the optimal mechanism and exact variances,
while the privacy budget is shared with DP-SGD via the common accountant.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (Domain, MarginalWorkload, PrivacyBudget,
                        reconstruct_all, select)
from repro.core.mechanism import pcost_of_plan
from .sharded import sharded_measure


def corpus_marginal_release(domain: Domain, workload: MarginalWorkload,
                            records: jnp.ndarray, budget: PrivacyBudget,
                            pcost: float, key: jax.Array,
                            objective: str = "sum_of_variances",
                            mesh=None) -> Tuple[Dict, Dict, Dict]:
    """Select → (sharded) measure → reconstruct; charges the shared budget.

    Returns (noisy marginal tables, per-marginal variances, privacy report).
    """
    plan = select(workload, pcost_budget=pcost, objective=objective)
    budget.charge(pcost_of_plan(plan))
    meas = sharded_measure(plan, records, key, mesh)
    tables = reconstruct_all(plan, meas)
    variances = plan.workload_variances()
    return tables, variances, budget.report()
