"""CompositeEngine: serve a block-decomposed plan through the fused engines.

Measurement and reconstruction dispatch per block to ordinary
:class:`~repro.engine.engine.MarginalEngine` instances obtained through the
sharded engine cache (:func:`repro.engine.sharded._engine_for`), so block
engines are shared across composite engines, sharded calls and repeated
releases — a block planned twice compiles once.

The one cross-block subtlety is the shared empty clique (docs/DESIGN.md
§12): every block closure contains ∅, but the composite charges its pcost
once, so the noisy total is **measured once** (by block 0) and injected into
every other block's measurement dict before reconstruction.  (Later blocks
still draw their own ∅ noise — discarding an unreleased draw costs nothing —
which keeps each block engine's key-fold order, and therefore its released
noise, bit-identical to serving that block standalone.)

Cut-straddling workload cliques are reconstructed by the product-of-blocks
correction: the normalized outer product of their per-block part tables,
``(⊗_p M̂_p) / T̂^{n_parts−1}`` with T̂ the shared noisy total.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique
from repro.core.mechanism import Measurement, noise_dtype
from repro.core.partition import ROW_EMPTY
from repro.engine.engine import EngineStats, ReleaseServing
from repro.obs import TRACER


class CompositeEngine(ReleaseServing):
    """Measurement/reconstruction/release for a CompositePlan."""

    def __init__(self, plan, use_kernel: Optional[bool] = None,
                 precompile: bool = True, dtype=None):
        from repro.kernels.kron_matvec._layout import interpret_default
        self.plan = plan
        self.use_kernel = (not interpret_default()) if use_kernel is None \
            else use_kernel
        self.dtype = noise_dtype() if dtype is None else dtype
        self.stats = EngineStats()
        self._engines = [self._child_engine(bp, precompile)
                         for bp in plan.block_plans]
        self.stats.measure_signatures = sum(
            e.stats.measure_signatures for e in self._engines)
        self.stats.reconstruct_signatures = sum(
            e.stats.reconstruct_signatures for e in self._engines)

    def _child_engine(self, block_plan, precompile: bool):
        # Through the sharded engine cache: block engines are shared with
        # sharded_measure and with any other composite over the same blocks.
        from repro.engine.sharded import _engine_for
        return _engine_for(block_plan, self.use_kernel, self.dtype)

    # ------------------------------------------------------------------ serve
    def measure(self, marginals: Mapping[Clique, jnp.ndarray],
                key: jax.Array) -> Dict[Clique, Measurement]:
        """Per-block Algorithm 1; the shared ∅ is block 0's measurement."""
        self.stats.bump("measure_calls")
        keys = jax.random.split(key, len(self._engines))
        out: Dict[Clique, Measurement] = {}
        with TRACER.span("engine.measure").set(
                engine="composite", blocks=len(self._engines),
                use_kernel=self.use_kernel):
            for b, eng in enumerate(self._engines):
                mb = dict(eng.measure(marginals, keys[b]))
                if b > 0:
                    mb[()] = out[()]
                out.update(mb)
        return out

    def _block_tables(self, measurements: Mapping[Clique, Measurement]
                      ) -> List[Dict[Clique, np.ndarray]]:
        """Each block's reconstructed sub-workload (in-block rows + parts)."""
        return [eng.reconstruct(measurements) for eng in self._engines]

    def _assemble(self, block_tables: List[Dict[Clique, np.ndarray]],
                  total: float, cliques: Sequence[Clique]
                  ) -> Dict[Clique, np.ndarray]:
        """Original-workload tables from block tables (+ straddler products)."""
        d = self.plan.decomposition
        dom = d.workload.domain
        rows = {c: r for r, c in enumerate(d.workload.cliques)}
        out: Dict[Clique, np.ndarray] = {}
        for c in cliques:
            r = rows[c]
            b = int(d.row_block[r])
            if b >= 0:
                out[c] = block_tables[b][c]
            elif b == ROW_EMPTY:
                out[c] = np.asarray([total], dtype=float)
            else:
                parts = d.parts_of(r)
                tab = None
                attrs: List[int] = []
                for pb, pc in parts:
                    pt = np.asarray(block_tables[pb][pc], float).reshape(
                        dom.clique_sizes(pc))
                    tab = pt if tab is None else np.multiply.outer(tab, pt)
                    attrs.extend(pc)
                denom = float(total) ** (len(parts) - 1)
                if len(parts) > 1:
                    tiny = np.finfo(np.float64).tiny
                    if abs(denom) < tiny:
                        denom = np.copysign(tiny, denom if denom else 1.0)
                    tab = tab / denom
                perm = np.argsort(np.asarray(attrs))
                out[c] = np.ascontiguousarray(
                    np.transpose(tab, perm)).reshape(-1)
        return out

    def reconstruct(self, measurements: Mapping[Clique, Measurement],
                    cliques: Optional[Sequence[Clique]] = None
                    ) -> Dict[Clique, np.ndarray]:
        """Per-block Algorithm 2, then stitch the original workload's tables."""
        self.stats.bump("reconstruct_calls")
        d = self.plan.decomposition
        total = float(np.asarray(measurements[()].omega,
                                 float).reshape(-1)[0])
        cliques = list(d.workload.cliques if cliques is None else cliques)
        with TRACER.span("engine.reconstruct").set(
                engine="composite", blocks=len(self._engines),
                use_kernel=self.use_kernel):
            return self._assemble(self._block_tables(measurements), total,
                                  cliques)

    # ---------------------------------------------------------------- release
    def release(self, marginals, key, postprocess: Optional[str] = None,
                total: Optional[float] = None, weights=None,
                mw_rounds: int = 0, **post_opts):
        """measure → per-block reconstruct (→ per-block postprocess) → stitch.

        Postprocessing runs the release subsystem independently on each
        block's plan and tables (consistency/non-negativity are per-block
        properties; the blocks only share the total, which ``total=`` pins
        for every block).  Straddler products are rebuilt from the
        *postprocessed* part tables, so ``"nonneg"`` straddler marginals are
        products of non-negative factors — non-negative themselves — and
        ``synthesize`` works end-to-end.
        """
        if postprocess is None:
            meas = self.measure(marginals, key)
            return self.reconstruct(meas), meas
        if weights is not None:
            raise ValueError("per-marginal postprocess weights are not "
                             "supported on a composite plan; postprocess the "
                             "block plans directly instead")
        from repro.release import postprocess_release
        meas = self.measure(marginals, key)
        bt = self._block_tables(meas)
        t_meas = float(np.asarray(meas[()].omega, float).reshape(-1)[0])
        t_pin = t_meas if total is None else float(total)
        post = [postprocess_release(bp, tables, postprocess, total=t_pin,
                                    mw_rounds=mw_rounds, **post_opts)
                for bp, tables in zip(self.plan.block_plans, bt)]
        out = self._assemble(post, t_pin, list(self.plan.workload.cliques))
        self.stats.bump("postprocess_calls")
        if postprocess == "nonneg":
            self._synth_tables = out
        return out, meas

    # ------------------------------------------------------------- introspect
    def variances(self) -> Dict[Clique, float]:
        return self.plan.workload_variances()

    def block_engines(self) -> List:
        """The per-block fused engines (shared via the engine cache)."""
        return list(self._engines)
