"""Exact(er) FLOP / byte / collective accounting from post-SPMD HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts each while-loop *body once*,
which under-reports scanned-layer / microbatched graphs by orders of
magnitude (layers × microbatches).  This walker fixes that:

  1. split the HLO module into computations, build a per-computation symbol
     table (%name → output shape) and a call graph
     (while body/condition, fusion `calls=`, `to_apply=`, conditional
     branches) with while trip counts taken from the
     ``backend_config={"known_trip_count":{"n":...}}`` JAX emits for scans;
  2. propagate execution multipliers from ENTRY;
  3. FLOPs: 2 · |out| · Π(lhs contracting dims) per `dot` (dots dominate all
     our graphs; elementwise FLOPs are ignored, consistent with MXU roofline);
  4. bytes: Σ (operand + output buffer bytes) over executable instructions —
     the XLA bytes-accessed convention at fusion granularity;
  5. collectives: output-buffer bytes per op kind, × multiplier.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
                    r"([a-z0-9-]+)\(")
_CALL_ATTR_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=(%[\w.-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.-]+)\s*(?:\([^)]*\))?.*\{\s*$")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "after-all", "while", "conditional", "call", "bitcast",
               "partition-id", "replica-id", "rng-get-and-update-state"}
# Fusion-boundary traffic model for the roofline memory term: only ops that
# move data at TPU fusion granularity are charged; slicing ops are charged for
# the *slice* moved, not the loop-carried buffer they index into (XLA aliases
# those in place).  Everything else (top-level elementwise, layout/relayout
# artifacts of the CPU backend) would be fused on TPU and is charged 0 in the
# essential count (still present in bytes_raw).
_FULL_COST_OPS = {"dot", "convolution", "fusion", "reduce", "reduce-window",
                  "sort", "select-and-scatter", "all-gather", "all-reduce",
                  "reduce-scatter", "all-to-all", "collective-permute",
                  "all-gather-start", "all-reduce-start", "cholesky",
                  "triangular-solve"}
_LAYOUT_OPS = {"copy", "transpose", "convert", "broadcast", "reshape",
               "bitcast-convert", "concatenate", "pad", "reverse"}


_LAYOUT_FUSION_TOKENS = ("transpose", "copy", "convert", "bitcast", "reshape",
                         "broadcast")


def _op_bytes(op: str, type_str: str, operand_types: List[Optional[str]],
              name: str = "") -> float:
    out_b = _shape_bytes(type_str)
    if op == "fusion":
        stem = name.lstrip("%").split(".")[0]
        if "dynamic-update-slice" in stem or "dynamic_update_slice" in stem:
            # in-place DUS on TPU: traffic = the update(s), not the buffer(s).
            # Exclude every operand at least as large as the output (aliased
            # destination buffers and their dtype-emulation twins).
            small = [b for b in (_shape_bytes(t) for t in operand_types if t)
                     if b < out_b]
            return 2.0 * sum(small)
        parts = [p for p in stem.split("_") if p and p != "fusion"]
        if parts and all(p in _LAYOUT_FUSION_TOKENS for p in parts):
            return 0.0                           # pure layout fusion (CPU artifact)
    if op in _FULL_COST_OPS:
        return out_b + sum(_shape_bytes(t) for t in operand_types if t)
    if op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b                       # read slice + write slice
    if op == "dynamic-update-slice":
        upd = operand_types[1] if len(operand_types) > 1 else None
        return 2.0 * (_shape_bytes(upd) if upd else out_b)
    if op == "scatter":
        upd = operand_types[2] if len(operand_types) > 2 else None
        idx = operand_types[1] if len(operand_types) > 1 else None
        return 2.0 * (_shape_bytes(upd) if upd else 0.0) + \
            (_shape_bytes(idx) if idx else 0.0)
    return 0.0
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def _operands(rest: str) -> List[str]:
    """Operand names from the first top-level paren group after the op name."""
    i = rest.find("(")
    if i < 0:
        return []
    depth = 0
    out, cur = [], []
    for ch in rest[i:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                out.append("".join(cur).strip())
                break
        elif ch == "," and depth == 1:
            out.append("".join(cur).strip())
            cur = []
            continue
        cur.append(ch)
    # Scheduled HLO prints operands with inline types ("f32[64,128]{1,0}
    # %Arg_0.1"); older dumps print bare "%name".  The operand name is the
    # last whitespace token either way.
    names = []
    for o in out:
        tok = o.strip().split(" ")[-1]
        if tok.startswith("%"):
            names.append(tok)
    return names


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.shapes: Dict[str, str] = {}        # %instr -> type string
        self.flops = 0.0
        self.bytes = 0.0                        # essential (roofline) bytes
        self.bytes_raw = 0.0                    # incl. CPU layout artifacts
        self.coll = defaultdict(float)          # kind -> bytes
        self.coll_n = defaultdict(int)
        self.calls: List[Tuple[str, float]] = []  # (callee, weight)


_COMMENT_RE = re.compile(r"/\*.*?\*/")
_ASSIGN_RE = re.compile(r"^(?:ROOT\s+)?%[\w.-]+\s*=")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)
        if cur is None:
            stripped = line.strip()
            m = _COMP_HDR_RE.match(stripped)
            if m and not _ASSIGN_RE.match(stripped):
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = _OP_RE.match(rest)
        if not om:
            continue
        type_str, op = om.group(1), om.group(2)
        cur.shapes[name] = type_str
        # call edges
        weight = 1.0
        if op == "while":
            tm = _TRIP_RE.search(rest)
            weight = float(tm.group(1)) if tm else 1.0
        for cm in _CALL_ATTR_RE.finditer(rest):
            # while body runs `trip` times; condition trip+1 (≈ trip); others once
            w = weight if (op == "while" and
                           cm.group(0).startswith(("body=", "condition="))) else 1.0
            cur.calls.append((cm.group(1), w))
        bm = _BRANCHES_RE.search(rest)
        if bm:
            for b in bm.group(1).split(","):
                b = b.strip()
                if b.startswith("%"):
                    cur.calls.append((b, 1.0))
        # FLOPs: dots
        if op == "dot":
            out_dims = _first_shape_dims(type_str) or []
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
            ops = _operands(rest)
            k = 1
            if lc and ops:
                lhs_type = cur.shapes.get(ops[0])
                lhs_dims = _first_shape_dims(lhs_type) if lhs_type else None
                if lhs_dims:
                    for idx in lc.group(1).split(","):
                        if idx:
                            k *= lhs_dims[int(idx)]
            cur.flops += 2.0 * out_elems * k
        # bytes
        if op not in _SKIP_BYTES:
            operand_types = [cur.shapes.get(o) for o in _operands(rest)]
            raw = _shape_bytes(type_str) + sum(
                _shape_bytes(t) for t in operand_types if t)
            cur.bytes_raw += raw
            cur.bytes += _op_bytes(op, type_str, operand_types, name)
        # collectives
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            cur.coll[base] += _shape_bytes(type_str)
            cur.coll_n[base] += 1
    comps["__entry__"] = comps.get(entry) if entry else None  # type: ignore
    return comps


def hlo_stats(text: str) -> Dict:
    comps = parse_hlo(text)
    entry = comps.pop("__entry__")
    if entry is None:
        return {"error": "no ENTRY computation found"}
    mult: Dict[str, float] = defaultdict(float)

    def visit(comp: Computation, m: float, depth=0):
        if depth > 50:
            return
        mult[comp.name] += m
        for callee, w in comp.calls:
            c = comps.get(callee)
            if c is not None:
                visit(c, m * w, depth + 1)

    visit(entry, 1.0)
    flops = sum(c.flops * mult[c.name] for c in comps.values())
    nbytes = sum(c.bytes * mult[c.name] for c in comps.values())
    nbytes_raw = sum(c.bytes_raw * mult[c.name] for c in comps.values())
    coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_n: Dict[str, float] = {k: 0 for k in _COLLECTIVES}
    for c in comps.values():
        for k, v in c.coll.items():
            coll[k] += v * mult[c.name]
        for k, v in c.coll_n.items():
            coll_n[k] += v * mult[c.name]
    return {"flops": flops, "bytes": nbytes, "bytes_raw": nbytes_raw,
            "collective_bytes": coll, "collective_counts": coll_n,
            "n_computations": len(comps)}
