"""Roofline analysis from dry-run artifacts (deliverable g).

Three terms per (arch × shape) on the single-pod mesh, with TPU v5e constants:

    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s)
    memory     = HLO_bytes   / (chips × 819e9  B/s)
    collective = coll_bytes  / (chips × 50e9   B/s per ICI link)

cost_analysis() numbers from an SPMD executable are *per device*, so global
quantities are per-device × chips (the two conventions cancel in the terms).
MODEL_FLOPS is the 6·N·D (train) / 2·N·D (inference) convention with N =
active params; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/causal-waste
and redundant compute.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

HW = {
    "peak_flops": 197e12,      # bf16 / chip
    "hbm_bw": 819e9,           # B/s / chip
    "ici_bw": 50e9,            # B/s / link
}

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    status: str
    flops_global: float = 0.0
    bytes_global: float = 0.0
    coll_bytes_global: float = 0.0
    coll_breakdown: Optional[Dict[str, int]] = None
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    bottleneck: str = ""
    mfu_bound: float = 0.0      # model_flops / (chips·peak·t_dominant)
    reason: str = ""
    memory_bytes_per_device: int = 0
    bytes_raw_global: float = 0.0   # incl. XLA:CPU layout artifacts

    def row(self) -> str:
        if self.status != "ok":
            return (f"| {self.arch} | {self.shape} | {self.status}: "
                    f"{self.reason[:60]} | | | | | | |")
        return ("| {a} | {s} | {tc:.2e} | {tm:.2e} | {tl:.2e} | {b} | "
                "{ur:.2f} | {mfu:.1%} | {mem:.1f} |").format(
            a=self.arch, s=self.shape, tc=self.t_compute, tm=self.t_memory,
            tl=self.t_collective, b=self.bottleneck, ur=self.useful_ratio,
            mfu=self.mfu_bound, mem=self.memory_bytes_per_device / 2**30)


def analyze_cell(rec: dict) -> CellRoofline:
    cell = CellRoofline(rec["arch"], rec["shape"], rec["mesh"],
                        rec.get("chips", 256), rec["status"],
                        reason=rec.get("reason", rec.get("error", "")))
    if rec["status"] != "ok":
        return cell
    chips = cell.chips
    hs = rec.get("hlo_stats") or {}
    if "flops" in hs:
        # loop-aware HLO walk (preferred — cost_analysis counts scan bodies once)
        flops_dev = float(hs["flops"])
        bytes_dev = float(hs["bytes"])
        coll_dev = float(sum(hs["collective_bytes"].values()))
        cell.coll_breakdown = hs["collective_bytes"]
        cell.bytes_raw_global = float(hs.get("bytes_raw", 0.0)) * chips
    else:
        ca = rec.get("cost_analysis", {})
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        coll_dev = float(sum(rec.get("collective_bytes_per_device", {}).values()))
    cell.flops_global = flops_dev * chips
    cell.bytes_global = bytes_dev * chips
    cell.coll_bytes_global = coll_dev * chips
    cell.coll_breakdown = rec.get("collective_bytes_per_device")
    cell.t_compute = cell.flops_global / (chips * HW["peak_flops"])
    cell.t_memory = cell.bytes_global / (chips * HW["hbm_bw"])
    cell.t_collective = cell.coll_bytes_global / (chips * HW["ici_bw"])
    cell.model_flops = float(rec.get("model_flops", 0.0))
    cell.useful_ratio = (cell.model_flops / cell.flops_global
                         if cell.flops_global else 0.0)
    terms = {"compute": cell.t_compute, "memory": cell.t_memory,
             "collective": cell.t_collective}
    cell.bottleneck = max(terms, key=terms.get)
    t_dom = max(terms.values())
    cell.mfu_bound = (cell.model_flops / (chips * HW["peak_flops"] * t_dom)
                      if t_dom else 0.0)
    ma = rec.get("memory_analysis", {})
    cell.memory_bytes_per_device = int(
        ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0)
        + ma.get("output_size_in_bytes", 0))
    return cell


def load_records(artifact_dir: str = ARTIFACT_DIR, mesh: str = "single"
                 ) -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(artifact_dir, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def analyze_all(artifact_dir: str = ARTIFACT_DIR, mesh: str = "single"
                ) -> List[CellRoofline]:
    return [analyze_cell(r) for r in load_records(artifact_dir, mesh)]


def markdown_table(cells: List[CellRoofline]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "bottleneck | useful FLOP ratio | MFU bound | HBM GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    cells = sorted(cells, key=lambda c: (c.arch, order.get(c.shape, 9)))
    return "\n".join([hdr] + [c.row() for c in cells])


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--dir", default=ARTIFACT_DIR)
    args = ap.parse_args()
    cells = analyze_all(args.dir, args.mesh)
    print(markdown_table(cells))
    for c in cells:
        if c.status == "ok":
            print(f"\n{c.arch} {c.shape}: dominant={c.bottleneck} "
                  f"t={max(c.t_compute, c.t_memory, c.t_collective):.3e}s "
                  f"coll={c.coll_breakdown}")


if __name__ == "__main__":
    main()
