"""Roofline analysis from dry-run artifacts (deliverable g).

Three terms per (arch × shape) on the single-pod mesh, computed by the shared
:class:`~repro.roofline.cost_model.CostModel` (docs/DESIGN.md §14) against
the artifact mesh's device — TPU v5e for the committed dry runs:

    compute    = HLO_FLOPs   / (chips × peak FLOP/s)
    memory     = HLO_bytes   / (chips × HBM B/s)
    collective = coll_bytes  / (chips × ICI B/s per link)

cost_analysis() numbers from an SPMD executable are *per device*, so global
quantities are per-device × chips (the two conventions cancel in the terms).
MODEL_FLOPS is the 6·N·D (train) / 2·N·D (inference) convention with N =
active params; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/causal-waste
and redundant compute.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from .cost_model import DEVICE_TABLE, CostModel, DeviceSpec

# The committed dry-run artifacts were produced on a v5e mesh; HW stays the
# published back-compat view of those constants (bf16 peak / HBM / ICI).
_ARTIFACT_DEVICE: DeviceSpec = DEVICE_TABLE["tpu v5 lite"]
HW = {
    "peak_flops": _ARTIFACT_DEVICE.peak_flops,
    "hbm_bw": _ARTIFACT_DEVICE.hbm_bw,
    "ici_bw": _ARTIFACT_DEVICE.ici_bw,
}


def artifact_dir() -> str:
    """Resolve the dry-run artifact directory at call time.

    The historical module-level ``os.path.dirname(__file__) + ../../..``
    construction only worked from a source checkout — installed packages
    live under site-packages, where three-parents-up is garbage.  Resolution
    order: ``REPRO_ARTIFACT_DIR`` env override → ``artifacts/dryrun`` under
    the current working directory → the source-checkout relative path (kept
    last so editable installs still find committed artifacts).
    """
    env = os.environ.get("REPRO_ARTIFACT_DIR", "")
    if env:
        return env
    cwd = os.path.join(os.getcwd(), "artifacts", "dryrun")
    if os.path.isdir(cwd):
        return cwd
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "..", "..", "artifacts", "dryrun")


# Back-compat module constant (benchmarks/roofline_bench.py imports it);
# resolved through artifact_dir() so installed packages get a sane value.
ARTIFACT_DIR = artifact_dir()


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    status: str
    flops_global: float = 0.0
    bytes_global: float = 0.0
    coll_bytes_global: float = 0.0
    coll_breakdown: Optional[Dict[str, int]] = None
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    bottleneck: str = ""
    mfu_bound: float = 0.0      # model_flops / (chips·peak·t_dominant)
    reason: str = ""
    memory_bytes_per_device: int = 0
    bytes_raw_global: float = 0.0   # incl. XLA:CPU layout artifacts

    def row(self) -> str:
        if self.status != "ok":
            return (f"| {self.arch} | {self.shape} | {self.status}: "
                    f"{self.reason[:60]} | | | | | | |")
        return ("| {a} | {s} | {tc:.2e} | {tm:.2e} | {tl:.2e} | {b} | "
                "{ur:.2f} | {mfu:.1%} | {mem:.1f} |").format(
            a=self.arch, s=self.shape, tc=self.t_compute, tm=self.t_memory,
            tl=self.t_collective, b=self.bottleneck, ur=self.useful_ratio,
            mfu=self.mfu_bound, mem=self.memory_bytes_per_device / 2**30)


def analyze_cell(rec: dict, device: Optional[DeviceSpec] = None
                 ) -> CellRoofline:
    """Roofline terms for one dry-run record via the shared CostModel."""
    device = _ARTIFACT_DEVICE if device is None else device
    model = CostModel(device)
    cell = CellRoofline(rec["arch"], rec["shape"], rec["mesh"],
                        rec.get("chips", 256), rec["status"],
                        reason=rec.get("reason", rec.get("error", "")))
    if rec["status"] != "ok":
        return cell
    chips = cell.chips
    hs = rec.get("hlo_stats") or {}
    if "flops" in hs:
        # loop-aware HLO walk (preferred — cost_analysis counts scan bodies once)
        flops_dev = float(hs["flops"])
        bytes_dev = float(hs["bytes"])
        coll_dev = float(sum(hs["collective_bytes"].values()))
        cell.coll_breakdown = hs["collective_bytes"]
        cell.bytes_raw_global = float(hs.get("bytes_raw", 0.0)) * chips
    else:
        ca = rec.get("cost_analysis", {})
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        coll_dev = float(sum(rec.get("collective_bytes_per_device", {}).values()))
        cell.coll_breakdown = rec.get("collective_bytes_per_device")
    cell.flops_global = flops_dev * chips
    cell.bytes_global = bytes_dev * chips
    cell.coll_bytes_global = coll_dev * chips
    terms = model.roofline_terms(cell.flops_global, cell.bytes_global,
                                 cell.coll_bytes_global, chips)
    cell.t_compute = terms["t_compute"]
    cell.t_memory = terms["t_memory"]
    cell.t_collective = terms["t_collective"]
    cell.bottleneck = terms["bottleneck"]
    cell.model_flops = float(rec.get("model_flops", 0.0))
    cell.useful_ratio = (cell.model_flops / cell.flops_global
                         if cell.flops_global else 0.0)
    t_dom = terms["t_dominant"]
    cell.mfu_bound = (cell.model_flops / (chips * device.peak_flops * t_dom)
                      if t_dom else 0.0)
    ma = rec.get("memory_analysis", {})
    cell.memory_bytes_per_device = int(
        ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0)
        + ma.get("output_size_in_bytes", 0))
    return cell


def load_records(artifact_dir_: Optional[str] = None, mesh: str = "single"
                 ) -> List[dict]:
    d = artifact_dir() if artifact_dir_ is None else artifact_dir_
    recs = []
    for f in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def analyze_all(artifact_dir_: Optional[str] = None, mesh: str = "single"
                ) -> List[CellRoofline]:
    return [analyze_cell(r) for r in load_records(artifact_dir_, mesh)]


def markdown_table(cells: List[CellRoofline]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "bottleneck | useful FLOP ratio | MFU bound | HBM GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    cells = sorted(cells, key=lambda c: (c.arch, order.get(c.shape, 9)))
    return "\n".join([hdr] + [c.row() for c in cells])


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--dir", default=None)
    args = ap.parse_args()
    cells = analyze_all(args.dir, args.mesh)
    print(markdown_table(cells))
    for c in cells:
        if c.status == "ok":
            print(f"\n{c.arch} {c.shape}: dominant={c.bottleneck} "
                  f"t={max(c.t_compute, c.t_memory, c.t_collective):.3e}s "
                  f"coll={c.coll_breakdown}")


if __name__ == "__main__":
    main()
