from .analyze import analyze_all, analyze_cell, HW
