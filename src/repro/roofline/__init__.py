from .analyze import HW, analyze_all, analyze_cell, artifact_dir
from .cost_model import (DEVICE_TABLE, ChainCost, CostModel, DeviceSpec,
                         detect_device, device_spec)

__all__ = ["HW", "analyze_all", "analyze_cell", "artifact_dir",
           "DEVICE_TABLE", "ChainCost", "CostModel", "DeviceSpec",
           "detect_device", "device_spec"]
