"""Reusable analytic cost model shared by the roofline reports and the
kernel autotuner (docs/DESIGN.md §14).

The dormant dry-run analyzer (analyze.py) hard-coded TPU-v5e constants and
only consumed offline HLO artifacts.  This module factors the hardware
knowledge into a small device table + runtime detection, and adds a *chain*
cost model: predicted FLOPs / HBM bytes / arithmetic intensity / wall time
for one fused Kron-chain launch under a candidate ``(block_l, vmem_budget,
compute_dtype, fused-vs-per-axis)`` config.  The tuner
(``repro.kernels.autotune``) ranks candidate configs with it; the roofline
report (analyze.py) reuses the same roofline terms for dry-run artifacts.

Two regimes matter:

* **real accelerator** — per-step launch overhead is negligible; the model is
  the classic roofline ``max(flops/peak, bytes/bw)`` with the VMEM ceiling as
  a hard feasibility constraint on the fused working tile;
* **interpret mode (CPU CI)** — the Pallas kernel body is executed by a
  Python interpreter once per grid step, so per-step overhead dominates and
  the model's job is to minimize grid steps subject to padding waste.  The
  "VMEM" limit is a host-cache working-set bound, not a hardware register
  file, so it is far looser than on TPU.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

_MIB = 1024 * 1024


@dataclass(frozen=True)
class DeviceSpec:
    """Per-device-kind constants the cost model and tuner consume.

    ``peak_flops`` is the narrow-dtype (bf16) MXU peak; ``peak_flops_f32``
    the fp32 peak.  ``vmem_limit`` is the hard ceiling a fused working tile
    may occupy; ``default_vmem_budget`` is the conservative *untuned* budget
    (the historical 4 MiB stays the CPU/interpret fallback so default plans
    are unchanged).  ``step_overhead_s`` is the per-grid-step launch cost —
    microseconds on real hardware, milliseconds for the Python interpreter.
    """

    kind: str
    peak_flops: float            # narrow (bf16) FLOP/s per chip
    peak_flops_f32: float        # fp32 FLOP/s per chip
    hbm_bw: float                # HBM bytes/s per chip
    ici_bw: float                # bytes/s per ICI link
    vmem_limit: int              # hard ceiling for a fused working tile
    default_vmem_budget: int     # untuned plan_chain budget
    step_overhead_s: float       # per grid-step launch overhead
    interpret: bool = False      # Pallas interpret mode (kernel body in Python)

    def peak_for(self, compute_dtype: str) -> float:
        return self.peak_flops_f32 if compute_dtype == "float32" \
            else self.peak_flops


# Known device kinds (``jax.devices()[0].device_kind``), matched by
# normalized substring.  TPU VMEM is ~16 MiB/core on v4/v5e (pallas guide);
# budgets leave headroom for the compiler's own temporaries.
DEVICE_TABLE = {
    "cpu": DeviceSpec("cpu", peak_flops=2e11, peak_flops_f32=1e11,
                      hbm_bw=5e10, ici_bw=1e10,
                      vmem_limit=256 * _MIB, default_vmem_budget=4 * _MIB,
                      step_overhead_s=2e-3, interpret=True),
    "tpu v4": DeviceSpec("tpu v4", peak_flops=275e12, peak_flops_f32=137e12,
                         hbm_bw=1228e9, ici_bw=50e9,
                         vmem_limit=16 * _MIB, default_vmem_budget=8 * _MIB,
                         step_overhead_s=2e-6),
    "tpu v5 lite": DeviceSpec("tpu v5 lite", peak_flops=197e12,
                              peak_flops_f32=98e12,
                              hbm_bw=819e9, ici_bw=50e9,
                              vmem_limit=16 * _MIB,
                              default_vmem_budget=8 * _MIB,
                              step_overhead_s=2e-6),
    "tpu v5p": DeviceSpec("tpu v5p", peak_flops=459e12, peak_flops_f32=229e12,
                          hbm_bw=2765e9, ici_bw=100e9,
                          vmem_limit=16 * _MIB, default_vmem_budget=8 * _MIB,
                          step_overhead_s=2e-6),
    "tpu v6 lite": DeviceSpec("tpu v6 lite", peak_flops=918e12,
                              peak_flops_f32=459e12,
                              hbm_bw=1640e9, ici_bw=100e9,
                              vmem_limit=32 * _MIB,
                              default_vmem_budget=16 * _MIB,
                              step_overhead_s=2e-6),
    "gpu": DeviceSpec("gpu", peak_flops=1e14, peak_flops_f32=5e13,
                      hbm_bw=2e12, ici_bw=9e11,
                      vmem_limit=16 * _MIB, default_vmem_budget=4 * _MIB,
                      step_overhead_s=5e-6),
}

_ALIASES = {"tpu v5e": "tpu v5 lite", "tpu v5litepod": "tpu v5 lite",
            "tpu v6e": "tpu v6 lite"}


def device_spec(kind: str) -> DeviceSpec:
    """Best-match :class:`DeviceSpec` for a ``device_kind`` string."""
    k = kind.strip().lower()
    k = _ALIASES.get(k, k)
    if k in DEVICE_TABLE:
        return DEVICE_TABLE[k]
    for name, spec in DEVICE_TABLE.items():
        if name != "cpu" and name in k:
            return spec
    if "tpu" in k:        # unknown TPU generation: v5e-ish conservative specs
        return DEVICE_TABLE["tpu v5 lite"]
    if "gpu" in k or "cuda" in k or "rocm" in k:
        return DEVICE_TABLE["gpu"]
    return DEVICE_TABLE["cpu"]


_DETECTED: Optional[DeviceSpec] = None


def detect_device(refresh: bool = False) -> DeviceSpec:
    """DeviceSpec of the runtime's default jax device (cached per process)."""
    global _DETECTED
    if _DETECTED is None or refresh:
        try:
            import jax
            kind = jax.devices()[0].device_kind
        except Exception:                      # pragma: no cover - no backend
            kind = "cpu"
        _DETECTED = device_spec(kind)
    return _DETECTED


@dataclass(frozen=True)
class ChainCost:
    """Predicted cost of ONE fused Kron-chain launch under a config."""

    flops: float                 # MXU FLOPs over the padded batch
    hbm_bytes: float             # pad-in + factor loads + slice-out traffic
    intensity: float             # flops / hbm_bytes
    grid_steps: int
    tile_bytes: int              # fused working tile (ChainPlan.vmem_bytes)
    fits: bool                   # tile_bytes <= device vmem_limit
    t_compute: float
    t_memory: float
    t_overhead: float
    predicted_s: float           # max(compute, memory) + overhead

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "intensity": round(self.intensity, 3),
                "grid_steps": self.grid_steps,
                "tile_bytes": self.tile_bytes, "fits": self.fits,
                "predicted_s": self.predicted_s}


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


class CostModel:
    """Analytic roofline scorer for chain launch configs and HLO artifacts.

    One instance per :class:`DeviceSpec`; stateless beyond the spec, so a
    module-level instance per device is safe to share between the tuner and
    the report paths.
    """

    def __init__(self, device: Optional[DeviceSpec] = None):
        self.device = detect_device() if device is None else device

    # ------------------------------------------------------------ fused chain
    def chain_flops(self, in_dims: Sequence[int],
                    fshapes: Sequence[Optional[Tuple[int, int]]],
                    epilogue: Sequence[Optional[str]] = ()) -> float:
        """MXU FLOPs for ONE batch row of the chain (2·m·n per contraction,
        times the surrounding free dims; cumsum epilogues contract with the
        (n, n) triangular operand)."""
        cur = list(in_dims)
        flops = 0.0
        for axis, spec in enumerate(fshapes):
            if spec is None:
                continue
            m, n = spec
            others = math.prod(cur) // cur[axis]
            flops += 2.0 * m * n * others
            cur[axis] = m
        for axis, op in enumerate(epilogue or ()):
            if op == "cumsum":
                n = cur[axis]
                flops += 2.0 * n * n * (math.prod(cur) // n)
        return flops

    def chain_cost(self, plan, batch: int) -> ChainCost:
        """Cost of one fused launch of ``plan`` (a ChainPlan) at ``batch``.

        HBM traffic: the zero-pad materialization + kernel read of the input
        tile, the factor loads (once — they stay VMEM-resident across grid
        steps), the kernel write + slice-back of the output.  All widths are
        the *padded* widths: padding waste is a real cost the tuner must see,
        which is what stops it from rounding a 2280-row batch up to a
        4096-row power of two.
        """
        dev = self.device
        isz = _itemsize(plan.compute_dtype)
        b_p = _pad_to(max(batch, 1), plan.block_l)
        steps = b_p // plan.block_l
        factor_bytes = sum(m * n * isz for s in plan.fshapes
                           if s is not None for m, n in [s])
        in_bytes = 2.0 * b_p * plan.w_in * isz          # pad write + read
        out_bytes = 2.0 * b_p * plan.w_out * 4          # write + slice (fp32)
        hbm = in_bytes + out_bytes + factor_bytes
        flops = self.chain_flops(plan.in_dims, plan.fshapes,
                                 plan.epilogue) * b_p
        t_c = flops / dev.peak_for(plan.compute_dtype)
        t_m = hbm / dev.hbm_bw
        t_o = steps * dev.step_overhead_s
        return ChainCost(flops=flops, hbm_bytes=hbm,
                         intensity=flops / hbm if hbm else 0.0,
                         grid_steps=steps, tile_bytes=plan.vmem_bytes,
                         fits=plan.vmem_bytes <= dev.vmem_limit,
                         t_compute=t_c, t_memory=t_m, t_overhead=t_o,
                         predicted_s=max(t_c, t_m) + t_o)

    def per_axis_cost(self, in_dims: Sequence[int],
                      fshapes: Sequence[Optional[Tuple[int, int]]],
                      batch: int) -> float:
        """Predicted seconds for the per-axis fallback path: one pad → HBM
        round-trip → slice per non-trivial factor, with the per-axis kernel's
        own (8 × 512) grid blocking driving the step count."""
        dev = self.device
        cur = list(in_dims)
        total = 0.0
        for axis, spec in enumerate(fshapes):
            if spec is None:
                continue
            m, n = spec
            left = max(batch, 1) * (math.prod(cur[:axis]) if axis else 1)
            right = math.prod(cur[axis + 1:]) if axis + 1 < len(cur) else 1
            l_p, r_p = _pad_to(left, 8), _pad_to(right, 512)
            n_p, m_p = _pad_to(n, 8), _pad_to(m, 8)
            in_b = 2.0 * l_p * n_p * r_p * 4
            out_b = 2.0 * l_p * m_p * r_p * 4
            flops = 2.0 * m * n * left * right
            steps = (l_p // 8) * (r_p // 512)
            total += max(flops / dev.peak_flops_f32,
                         (in_b + out_b) / dev.hbm_bw) \
                + steps * dev.step_overhead_s
            cur[axis] = m
        return total

    # -------------------------------------------------------- roofline terms
    def roofline_terms(self, flops: float, hbm_bytes: float,
                       coll_bytes: float = 0.0, chips: int = 1) -> dict:
        """The three classic terms for a global (all-chip) workload."""
        dev = self.device
        t_compute = flops / (chips * dev.peak_flops)
        t_memory = hbm_bytes / (chips * dev.hbm_bw)
        t_collective = coll_bytes / (chips * dev.ici_bw)
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_collective}
        bottleneck = max(terms, key=terms.get)
        return {"t_compute": t_compute, "t_memory": t_memory,
                "t_collective": t_collective, "bottleneck": bottleneck,
                "t_dominant": terms[bottleneck]}


def _itemsize(dtype_name: str) -> int:
    return {"float64": 8, "float32": 4, "bfloat16": 2, "float16": 2}.get(
        str(dtype_name), 4)
