"""Shared AST plumbing for the repro-lint passes.

Every pass consumes a :class:`ModuleInfo`: the parsed tree with parent
links, the comment map (``# guarded-by:`` / ``# requires-lock:`` /
``# repro-lint:`` pragmas live in comments, which ``ast`` drops), and the
repo-relative path the scoping rules key on.  Helpers here are purely
syntactic — name resolution is "last dotted component" matching, constant
evaluation folds integer literals only — so the passes stay honest about
being static approximations.
"""
from __future__ import annotations

import ast
import contextlib
import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set, Tuple

PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*(.+?)\s*$")
IGNORE_RE = re.compile(r"ignore\[([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)\]")
SCOPE_RE = re.compile(r"scope\s*=\s*([\w-]+)")
# No '#' anchor: these are only ever searched inside the comment map, and
# annotations must be able to ride along in an existing trailing comment
# ("# worker drains (guarded-by: _lock)").
GUARDED_RE = re.compile(r"guarded-by:\s*([\w.]+)")
REQUIRES_RE = re.compile(r"requires-lock:\s*([\w.]+)")


@dataclass
class ModuleInfo:
    """One parsed source file plus the comment-level annotations."""

    path: str                       # repo-relative, '/'-separated
    text: str
    tree: ast.Module
    comments: Dict[int, str] = field(default_factory=dict)  # line -> comment
    scopes: Set[str] = field(default_factory=set)           # pragma scopes

    def comment_in_span(self, lo: int, hi: int, regex: re.Pattern
                        ) -> Optional[str]:
        """First regex group matched in any comment on lines [lo, hi]."""
        for line in range(lo, hi + 1):
            c = self.comments.get(line)
            if c:
                m = regex.search(c)
                if m:
                    return m.group(1)
        return None

    def ignored_rules(self, line: int) -> Set[str]:
        """Rule ids waived by an inline ``# repro-lint: ignore[XX000]``."""
        c = self.comments.get(line, "")
        m = PRAGMA_RE.search(c)
        if not m:
            return set()
        ig = IGNORE_RE.search(m.group(1))
        if not ig:
            return set()
        return {r.strip() for r in ig.group(1).split(",")}

    def in_scope(self, name: str) -> bool:
        """True when the module belongs to a named scope: either a path
        directory component matches (``serve`` for ``src/repro/serve/*``)
        or a module-level ``# repro-lint: scope=<name>`` pragma opted in
        (how the fixture corpus exercises scoped rules)."""
        parts = self.path.split("/")
        return name in self.scopes or name in parts[:-1]


def parse_module(text: str, path: str) -> ModuleInfo:
    tree = ast.parse(text)
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._rl_parent = parent          # type: ignore[attr-defined]
    info = ModuleInfo(path=path.replace("\\", "/"), text=text, tree=tree)
    with contextlib.suppress(tokenize.TokenError):  # pragma: no cover
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                info.comments[tok.start[0]] = tok.string
    for c in info.comments.values():
        m = PRAGMA_RE.search(c)
        if m:
            s = SCOPE_RE.search(m.group(1))
            if s:
                info.scopes.add(s.group(1))
    return info


def parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_rl_parent", None)


def ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = parent(node)
    while cur is not None:
        yield cur
        cur = parent(cur)


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def last_component(name: Optional[str]) -> Optional[str]:
    return name.rsplit(".", 1)[-1] if name else None


def const_int(node: ast.AST) -> Optional[int]:
    """Fold an integer-literal expression (``64 * 1024 * 1024``), else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand)
        return -v if v is not None else None
    if isinstance(node, ast.BinOp):
        left, right = const_int(node.left), const_int(node.right)
        if left is None or right is None:
            return None
        if isinstance(node.op, ast.Add):
            return left + right
        if isinstance(node.op, ast.Sub):
            return left - right
        if isinstance(node.op, ast.Mult):
            return left * right
        if isinstance(node.op, ast.FloorDiv) and right != 0:
            return left // right
        if isinstance(node.op, ast.Pow) and 0 <= right < 64:
            return left ** right
    return None


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def enclosing_function(node: ast.AST) -> Optional[ast.FunctionDef]:
    for a in ancestors(node):
        if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return a
    return None


def enclosing_class(node: ast.AST) -> Optional[ast.ClassDef]:
    for a in ancestors(node):
        if isinstance(a, ast.ClassDef):
            return a
    return None


def qualname(node: ast.AST) -> str:
    """Dotted Class.method context for a node (module level -> '<module>')."""
    parts: List[str] = []
    for a in ancestors(node):
        if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            parts.append(a.name)
    return ".".join(reversed(parts)) or "<module>"


def walk_in_order(node: ast.AST) -> Iterator[ast.AST]:
    """Depth-first, source-order traversal (ast.walk is breadth-first)."""
    for child in ast.iter_child_nodes(node):
        yield child
        yield from walk_in_order(child)


def class_methods(cls: ast.ClassDef) -> List[ast.FunctionDef]:
    return [n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def with_locks_held(node: ast.AST) -> Set[str]:
    """Lock attribute names for every enclosing ``with self.<lock>:`` block."""
    held: Set[str] = set()
    for a in ancestors(node):
        if isinstance(a, (ast.With, ast.AsyncWith)):
            for item in a.items:
                name = dotted_name(item.context_expr)
                if name and name.startswith("self."):
                    held.add(name[len("self."):])
                elif name:
                    held.add(name)
    return held


def self_attr(node: ast.AST) -> Optional[str]:
    """'X' when node is ``self.X``, else None."""
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def span(node: ast.AST) -> Tuple[int, int]:
    return node.lineno, getattr(node, "end_lineno", node.lineno)
