"""Findings and the checked-in waiver baseline.

A :class:`Finding` is one rule violation at a file:line with a fix hint.
Its *fingerprint* deliberately omits the line number — waivers must survive
unrelated edits above the finding — and instead keys on
``rule::path::symbol`` where ``symbol`` is the enclosing qualname plus the
violating token (field name, sink name, kwarg).  The :class:`Baseline` is a
JSON file of fingerprints with justification strings; the CI gate is
zero-new-findings: anything not in the baseline fails the build, and stale
waivers are reported so they get pruned.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass
from typing import Dict, Iterable, List

BASELINE_VERSION = 1


@dataclass(frozen=True)
class Finding:
    """One rule violation: where, what, and how to fix it."""

    rule: str                 # e.g. "LK001"
    path: str                 # repo-relative, '/'-separated
    line: int
    symbol: str               # "Class.method:token" — the fingerprint anchor
    message: str
    hint: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.path}::{self.symbol}"

    def render(self) -> str:
        s = f"{self.path}:{self.line}: {self.rule} {self.message}"
        if self.hint:
            s += f"\n    hint: {self.hint}"
        return s

    def as_dict(self) -> dict:
        d = asdict(self)
        d["fingerprint"] = self.fingerprint
        return d


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule, f.symbol))


class Baseline:
    """Waived findings: ``{fingerprint: justification}`` with JSON round-trip."""

    def __init__(self, waivers: Dict[str, str] = None):
        self.waivers: Dict[str, str] = dict(waivers or {})

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls()
        with open(path, encoding="utf-8") as fh:
            blob = json.load(fh)
        if blob.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"{path}: baseline version {blob.get('version')!r} != "
                f"{BASELINE_VERSION}; regenerate with --write-baseline")
        waivers = {}
        for ent in blob.get("waivers", []):
            waivers[ent["fingerprint"]] = ent.get("reason", "")
        return cls(waivers)

    def save(self, path: str) -> None:
        blob = {"version": BASELINE_VERSION,
                "waivers": [{"fingerprint": fp, "reason": reason}
                            for fp, reason in sorted(self.waivers.items())]}
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(blob, fh, indent=1, sort_keys=False)
            fh.write("\n")

    @classmethod
    def from_findings(cls, findings: Iterable[Finding],
                      reason: str = "baselined") -> "Baseline":
        return cls({f.fingerprint: reason for f in findings})

    def is_waived(self, finding: Finding) -> bool:
        return finding.fingerprint in self.waivers

    def split(self, findings: Iterable[Finding]):
        """(new, waived) partition of ``findings``."""
        new, waived = [], []
        for f in findings:
            (waived if self.is_waived(f) else new).append(f)
        return new, waived

    def stale(self, findings: Iterable[Finding]) -> List[str]:
        """Waiver fingerprints that no current finding matches."""
        live = {f.fingerprint for f in findings}
        return sorted(fp for fp in self.waivers if fp not in live)
