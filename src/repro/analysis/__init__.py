"""repro-lint: AST/dataflow static analysis for the repo's load-bearing
invariants (docs/ANALYSIS.md, docs/DESIGN.md §15).

Three pass families, each proving a property the rest of the stack only
defended by convention and after-the-fact regression tests:

* **privacy-flow** (:mod:`repro.analysis.privacy`) — intraprocedural taint
  analysis from raw-data sources through Gaussian-noise sanitizers to
  release sinks, plus the charge-before-measure protocol check over the
  serving tier (``PF*`` rules);
* **kernel-invariant** (:mod:`repro.analysis.kernels`) — launch-config
  literals checked against the :mod:`repro.roofline.cost_model` DeviceSpec
  table, the noise-stays-fp32 ``allow_narrow`` policy, and host-effect
  hygiene inside jitted/Pallas kernel bodies (``KN*`` rules);
* **lock-discipline** (:mod:`repro.analysis.locks`) — ``# guarded-by:``
  annotated fields may only be touched under their lock (``LK*`` rules).

Drive it with ``python tools/repro_lint.py [--gate]`` or programmatically
via :func:`analyze_paths` / :func:`analyze_source`.
"""
from .driver import (DEFAULT_ROOTS, analyze_file, analyze_paths,
                     analyze_source, iter_py_files, main)
from .findings import Baseline, Finding
from .registry import (DEFAULT_PRIVACY, ALL_RULES, KernelLimits,
                       PrivacyRegistry, kernel_limits)

__all__ = ["DEFAULT_ROOTS", "analyze_file", "analyze_paths",
           "analyze_source", "iter_py_files", "main",
           "Baseline", "Finding",
           "DEFAULT_PRIVACY", "ALL_RULES", "KernelLimits",
           "PrivacyRegistry", "kernel_limits"]
