"""Lock-discipline pass (LK rules).

Annotation syntax (comments, because they must not change runtime
behavior):

* ``self.field = ...   # guarded-by: _lock`` — every later ``self.field``
  access in the class must sit inside ``with self._lock:`` (directly or via
  an enclosing block).  Dataclass-style class-body fields
  (``field: T = ...  # guarded-by: _lock``) work the same way.
* ``def helper(self):   # requires-lock: _lock`` — the method asserts its
  callers hold the lock; accesses inside it are exempt (the runtime
  contract is the caller's, as with ``_EngineCache``-style helpers).

``__init__``/``__post_init__`` are exempt: the object is not yet published
to other threads while it is being constructed.  The pass is lexical on
purpose — a field that escapes via aliasing (``d = self._entries``) taints
nothing once aliased, which is exactly the hygiene the annotation is meant
to discourage.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from .astutils import (GUARDED_RE, REQUIRES_RE, ModuleInfo, class_methods,
                       enclosing_function, qualname, self_attr, span,
                       walk_in_order, with_locks_held)
from .findings import Finding

_CTOR_NAMES = {"__init__", "__post_init__", "__new__"}


def _guarded_fields(info: ModuleInfo, cls: ast.ClassDef) -> Dict[str, str]:
    """{field_name: lock_name} from ``# guarded-by:`` annotations."""
    guards: Dict[str, str] = {}
    for node in walk_in_order(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            lo, hi = span(node)
            lock = info.comment_in_span(lo, hi, GUARDED_RE)
            if not lock:
                continue
            lock = lock.removeprefix("self.")
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                name = self_attr(t)
                if name is None and isinstance(t, ast.Name):
                    name = t.id            # dataclass class-body field
                if name:
                    guards[name] = lock
    return guards


def _declared_locks(cls: ast.ClassDef) -> set:
    """Attribute names assigned a value anywhere in the class (lock homes)."""
    names = set()
    for node in walk_in_order(cls):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                name = self_attr(t)
                if name:
                    names.add(name)
                elif isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign):
            name = self_attr(node.target)
            if name:
                names.add(name)
            elif isinstance(node.target, ast.Name):
                names.add(node.target.id)
    return names


def _required_locks(info: ModuleInfo, fn: ast.FunctionDef) -> set:
    """Locks a method declares its callers hold (``# requires-lock:``)."""
    first = fn.body[0].lineno if fn.body else fn.lineno
    locks = set()
    for line in range(fn.lineno, first + 1):
        c = info.comments.get(line)
        if c:
            m = REQUIRES_RE.search(c)
            if m:
                locks.add(m.group(1).removeprefix("self."))
    return locks


def check_locks(info: ModuleInfo) -> List[Finding]:
    findings: List[Finding] = []
    for cls in [n for n in ast.walk(info.tree) if isinstance(n, ast.ClassDef)]:
        guards = _guarded_fields(info, cls)
        if not guards:
            continue
        declared = _declared_locks(cls)
        for fld, lock in sorted(guards.items()):
            if lock not in declared:
                findings.append(Finding(
                    "LK002", info.path, cls.lineno,
                    f"{cls.name}:{fld}",
                    f"field {fld!r} is guarded-by {lock!r} but the class "
                    f"never creates self.{lock}",
                    hint=f"add self.{lock} = threading.Lock() in __init__ "
                         f"or fix the annotation"))
        method_requires = {m.name: _required_locks(info, m)
                           for m in class_methods(cls)}
        for method in class_methods(cls):
            if method.name in _CTOR_NAMES:
                continue
            required = _required_locks(info, method)
            reported = set()
            for node in walk_in_order(method):
                # caller side of the requires-lock contract: invoking a
                # helper that asserts "caller holds L" without holding L
                if isinstance(node, ast.Call):
                    callee = self_attr(node.func)
                    for lock in method_requires.get(callee, ()):
                        if lock in required or lock in with_locks_held(node):
                            continue
                        if "LK001" in info.ignored_rules(node.lineno):
                            continue
                        symkey = (method.name, callee)
                        if symkey in reported:
                            continue
                        reported.add(symkey)
                        findings.append(Finding(
                            "LK001", info.path, node.lineno,
                            f"{qualname(node)}:{callee}",
                            f"call to {callee!r} (requires-lock {lock!r}) "
                            f"outside 'with self.{lock}'",
                            hint=f"acquire self.{lock} before calling "
                                 f"self.{callee}(), or drop the "
                                 f"requires-lock annotation"))
                name = self_attr(node)
                if name is None or name not in guards:
                    continue
                lock = guards[name]
                if lock in required or lock in with_locks_held(node):
                    continue
                if "LK001" in info.ignored_rules(node.lineno):
                    continue
                fn = enclosing_function(node)
                if fn is not method and fn is not None \
                        and lock in _required_locks(info, fn):
                    continue               # nested helper with its own contract
                symkey = (method.name, name)
                if symkey in reported:
                    continue               # one finding per (method, field)
                reported.add(symkey)
                findings.append(Finding(
                    "LK001", info.path, node.lineno,
                    f"{qualname(node)}:{name}",
                    f"field {name!r} (guarded-by {lock!r}) accessed outside "
                    f"'with self.{lock}'",
                    hint=f"wrap the access in 'with self.{lock}:' or mark "
                         f"the method '# requires-lock: {lock}' if callers "
                         f"hold it"))
    return findings
