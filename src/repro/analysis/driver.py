"""repro-lint driver: file discovery, pass dispatch, baseline gate, CLI.

Exit codes (the CI contract):

* ``0`` — no findings beyond the baseline,
* ``1`` — at least one new (un-waived) finding, and
* ``2`` — bad usage (unreadable baseline, no such path).

``--gate`` is the CI mode: machine-terse output, zero-new-findings policy,
and stale baseline waivers are reported (so they get pruned) without
failing the build.  ``--write-baseline`` waives everything currently
firing — the escape hatch for landing the analyzer ahead of the last fix —
and the reviewable artifact is the diff of ``tools/repro_lint_baseline.json``.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
from typing import Iterable, Iterator, List, Optional

from .astutils import parse_module
from .findings import Baseline, Finding, sort_findings
from .kernels import check_kernels
from .locks import check_locks
from .privacy import check_privacy
from .registry import ALL_RULES

#: Analyzed by default when the CLI gets no paths (repo-relative).
DEFAULT_ROOTS = ("src/repro",)

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "build", "dist"}


def iter_py_files(root: str) -> Iterator[str]:
    """Yield ``.py`` files under ``root`` (or ``root`` itself), sorted."""
    if os.path.isfile(root):
        if root.endswith(".py"):
            yield root
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def _rel(path: str, repo_root: Optional[str]) -> str:
    if repo_root:
        # ValueError: different drives on Windows — fall through to abspath
        with contextlib.suppress(ValueError):
            return os.path.relpath(path, repo_root).replace(os.sep, "/")
    return path.replace(os.sep, "/")


def analyze_source(text: str, path: str) -> List[Finding]:
    """Run all three pass families over one source string."""
    try:
        info = parse_module(text, path)
    except SyntaxError as exc:
        return [Finding("LINT000", path, exc.lineno or 1, "<parse>",
                        f"could not parse: {exc.msg}",
                        hint="repro-lint only analyzes files that compile")]
    return sort_findings(
        check_privacy(info) + check_kernels(info) + check_locks(info))


def analyze_file(path: str, repo_root: Optional[str] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    return analyze_source(text, _rel(path, repo_root))


def analyze_paths(paths: Iterable[str],
                  repo_root: Optional[str] = None) -> List[Finding]:
    findings: List[Finding] = []
    for root in paths:
        for py in iter_py_files(root):
            findings.extend(analyze_file(py, repo_root))
    return sort_findings(findings)


def _print_rules() -> None:
    width = max(len(r) for r in ALL_RULES)
    for rule, desc in sorted(ALL_RULES.items()):
        print(f"{rule:<{width}}  {desc}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="privacy-flow, kernel-invariant, and lock-discipline "
                    "static analysis for the repro tree")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to analyze (default: {DEFAULT_ROOTS})")
    ap.add_argument("--gate", action="store_true",
                    help="CI mode: fail on any finding not in the baseline")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="waiver baseline JSON (default: "
                         "tools/repro_lint_baseline.json if present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="waive every current finding into the baseline file")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as a JSON array")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.rules:
        _print_rules()
        return 0

    repo_root = os.getcwd()
    paths = args.paths or [os.path.join(repo_root, p) for p in DEFAULT_ROOTS]
    for p in paths:
        if not os.path.exists(p):
            print(f"repro-lint: no such path: {p}", file=sys.stderr)
            return 2

    baseline_path = args.baseline
    if baseline_path is None:
        cand = os.path.join(repo_root, "tools", "repro_lint_baseline.json")
        baseline_path = cand if os.path.exists(cand) else None
    try:
        baseline = Baseline.load(baseline_path) if baseline_path else Baseline()
    except (ValueError, OSError, json.JSONDecodeError) as exc:
        print(f"repro-lint: bad baseline: {exc}", file=sys.stderr)
        return 2

    findings = analyze_paths(paths, repo_root=repo_root)

    if args.write_baseline:
        out = baseline_path or os.path.join(
            repo_root, "tools", "repro_lint_baseline.json")
        Baseline.from_findings(findings).save(out)
        print(f"repro-lint: wrote {len(findings)} waiver(s) to {out}")
        return 0

    new, waived = baseline.split(findings)

    if args.as_json:
        print(json.dumps([f.as_dict() for f in new], indent=1))
    else:
        for f in new:
            print(f.render())
    for fp in baseline.stale(findings):
        print(f"repro-lint: stale waiver (prune it): {fp}", file=sys.stderr)
    if waived and not args.as_json:
        print(f"repro-lint: {len(waived)} baselined finding(s) suppressed",
              file=sys.stderr)

    if new:
        tail = " (gate)" if args.gate else ""
        print(f"repro-lint: {len(new)} new finding(s){tail}", file=sys.stderr)
        return 1
    if not args.as_json:
        print("repro-lint: clean")
    return 0


if __name__ == "__main__":                     # pragma: no cover
    sys.exit(main())
