"""Kernel-invariant pass (KN rules).

Checks launch-config literals at ``plan_chain`` / ``fused_chain_matvec`` /
``tune_chain`` call sites against the live device limits (sublane quantum
per compute dtype, lane width, the DeviceSpec VMEM table), plus two
structural rules: no narrow compute dtype on a chain launched from a
noise-drawing function (the ``allow_narrow`` contract — Gaussian noise must
stay float32 end to end), and no host side effects (Python RNG, clock,
I/O) inside jitted or Pallas kernel bodies, where they would either trace
to a constant or silently desync across launches.

Only *literal* arguments are judged.  A computed ``block_l`` is the
autotuner's job at runtime; a literal one is a reviewable claim the
analyzer can check at commit time.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .astutils import (ModuleInfo, call_name, const_int, const_str,
                       dotted_name, enclosing_function, keyword_arg, qualname,
                       walk_in_order)
from .findings import Finding
from .registry import KernelLimits, kernel_limits

_JIT_NAMES = {"jit", "pallas_call"}


def _dtype_of(call: ast.Call) -> Optional[str]:
    """Literal compute dtype at a chain call site, if spelled out."""
    for kw_name in ("dtype", "compute_dtype"):
        node = keyword_arg(call, kw_name)
        if node is None:
            continue
        s = const_str(node)
        if s is not None:
            return s
        name = dotted_name(node)
        if name:
            return name.rsplit(".", 1)[-1]
    return None


def _draws_noise(fn: Optional[ast.AST], limits: KernelLimits) -> bool:
    if fn is None:
        return False
    return any(isinstance(n, ast.Call) and
               (call_name(n) or "").rsplit(".", 1)[-1] in limits.noise_calls
               for n in ast.walk(fn))


def _kernel_body_names(tree: ast.Module) -> Set[str]:
    """Names of functions handed to jit()/pallas_call() as kernel bodies."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if (call_name(node) or "").rsplit(".", 1)[-1] not in _JIT_NAMES:
            continue
        if node.args and isinstance(node.args[0], ast.Name):
            names.add(node.args[0].id)
    return names


def _is_jit_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        name = dotted_name(dec if not isinstance(dec, ast.Call) else dec.func)
        if name and name.rsplit(".", 1)[-1] in {"jit", "partial"}:
            if name.rsplit(".", 1)[-1] == "jit":
                return True
            if isinstance(dec, ast.Call) and any(
                    (dotted_name(a) or "").rsplit(".", 1)[-1] == "jit"
                    for a in dec.args):
                return True
    return False


def _chain_site_findings(info: ModuleInfo, call: ast.Call,
                         limits: KernelLimits) -> List[Finding]:
    out: List[Finding] = []
    fn_name = (call_name(call) or "").rsplit(".", 1)[-1]
    ignored = info.ignored_rules(call.lineno)
    where = f"{qualname(call)}:{fn_name}"

    dtype = _dtype_of(call)
    block_l = keyword_arg(call, "block_l")
    lit_block = const_int(block_l) if block_l is not None else None
    if lit_block is not None and "KN001" not in ignored:
        quantum = limits.sublane_for(dtype or "float32")
        if lit_block <= 0 or lit_block % quantum != 0:
            out.append(Finding(
                "KN001", info.path, block_l.lineno, where,
                f"block_l={lit_block} is not a positive multiple of the "
                f"sublane quantum {quantum} for dtype "
                f"{dtype or 'float32'}",
                hint=f"round block_l up to a multiple of {quantum} (or drop "
                     f"the literal and let plan_chain pad it)"))

    budget = keyword_arg(call, "vmem_budget")
    lit_budget = const_int(budget) if budget is not None else None
    if lit_budget is not None and "KN002" not in ignored \
            and lit_budget > limits.vmem_limit_real:
        out.append(Finding(
            "KN002", info.path, budget.lineno, where,
            f"vmem_budget={lit_budget} exceeds the largest real-accelerator "
            f"VMEM ceiling ({limits.vmem_limit_real} bytes) in the "
            f"DeviceSpec table",
            hint="budgets above the device ceiling make the planner pick "
                 "block shapes that cannot compile; use a table entry's "
                 "vmem_limit"))

    if "KN003" not in ignored:
        narrow = keyword_arg(call, "allow_narrow")
        is_narrow = (isinstance(narrow, ast.Constant)
                     and narrow.value is True) \
            or (dtype in limits.narrow_dtypes)
        if is_narrow and _draws_noise(enclosing_function(call), limits):
            node = narrow if narrow is not None else call
            out.append(Finding(
                "KN003", info.path, node.lineno, where,
                "narrow compute dtype requested on a chain inside a "
                "noise-drawing function; calibrated noise must stay float32",
                hint="keep allow_narrow=False wherever the function draws "
                     "noise (reconstruction-only paths may opt in)"))
    return out


def _blockspec_findings(info: ModuleInfo, call: ast.Call,
                        limits: KernelLimits) -> List[Finding]:
    if (call_name(call) or "").rsplit(".", 1)[-1] != "BlockSpec":
        return []
    if "KN005" in info.ignored_rules(call.lineno):
        return []
    shape = call.args[0] if call.args else keyword_arg(call, "block_shape")
    if not isinstance(shape, ast.Tuple) or not shape.elts:
        return []
    minor = const_int(shape.elts[-1])
    if minor is None or minor % limits.lane == 0:
        return []
    return [Finding(
        "KN005", info.path, shape.lineno,
        f"{qualname(call)}:BlockSpec",
        f"BlockSpec minor dimension {minor} is not a multiple of the lane "
        f"quantum ({limits.lane})",
        hint=f"pad the minor block dimension to a multiple of "
             f"{limits.lane}; partial lanes waste the whole vector register")]


def _host_effect_findings(info: ModuleInfo, limits: KernelLimits
                          ) -> List[Finding]:
    out: List[Finding] = []
    kernel_names = _kernel_body_names(info.tree)
    for fn in [n for n in ast.walk(info.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        if not (_is_jit_decorated(fn) or fn.name in kernel_names):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if not name:
                continue
            bad = name in limits.host_effect_exact or any(
                name.startswith(p) for p in limits.host_effect_prefixes)
            if not bad:
                continue
            if "KN004" in info.ignored_rules(node.lineno):
                continue
            out.append(Finding(
                "KN004", info.path, node.lineno,
                f"{qualname(node)}:{name}",
                f"host side effect {name!r} inside jitted/kernel body "
                f"{fn.name!r}",
                hint="host calls trace to a constant (RNG/clock) or break "
                     "the kernel; hoist them out and pass values in as "
                     "arguments"))
    return out


def check_kernels(info: ModuleInfo,
                  limits: Optional[KernelLimits] = None) -> List[Finding]:
    limits = limits or kernel_limits()
    findings: List[Finding] = []
    chain = limits.chain_calls
    for node in walk_in_order(info.tree):
        if not isinstance(node, ast.Call):
            continue
        if (call_name(node) or "").rsplit(".", 1)[-1] in chain:
            findings.extend(_chain_site_findings(info, node, limits))
        findings.extend(_blockspec_findings(info, node, limits))
    findings.extend(_host_effect_findings(info, limits))
    return findings
