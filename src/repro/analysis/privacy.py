"""Privacy-flow pass (PF rules).

**PF001 — taint tracking.**  An intraprocedural, fixed-point taint analysis
per function scope: raw-data *sources* (registry: histogram builders,
``req.marginals`` payload reads, data-plane parameters) taint the values
derived from them; *sanitizer* calls (the ``measure*`` family — every one
of them draws calibrated Gaussian/discrete-Gaussian noise before
returning) produce clean values; *declassifiers* (``.shape``/``.size``/
``len``) stop taint, since shape-class metadata is workload- not
data-dependent.  A tainted value reaching a *sink* (future resolution,
ledger journal append, serve-scope response assembly) is a privacy bug: a
release path that never paid for noise.

**PF002 — charge-before-measure.**  Inside serve-scope classes, every
method that (transitively, within the class) performs a measurement must be
dominated by a ``*.charge(...)`` call: either earlier in its own body, or
earlier than the call site in *every* intra-class caller chain.  This is
the static form of the ledger's charge-before-measure theorem
(:mod:`repro.serve.ledger`): deleting the charge in ``_serve_batch`` flips
this rule, and with it the CI gate.

Both rules are approximations in the safe direction for a lint (no alias
tracking, no interprocedural taint): they prove the *annotated protocol*,
and the fixture corpus pins the behaviors they must and must not flag.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .astutils import (ModuleInfo, call_name, class_methods, last_component,
                       qualname)
from .findings import Finding
from .registry import DEFAULT_PRIVACY, PrivacyRegistry

_MAX_TAINT_ITERS = 4


def _walk_scope(scope: ast.AST):
    """Source-order traversal that does NOT descend into nested defs —
    each function body is its own taint scope."""
    def rec(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            yield child
            yield from rec(child)
    return rec(scope)


class _Taint:
    """Taint environment + expression evaluation for one function scope."""

    def __init__(self, reg: PrivacyRegistry):
        self.reg = reg
        self.env: Set[str] = set()

    # ------------------------------------------------------------ expression
    def tainted(self, node: ast.AST) -> bool:                  # noqa: C901
        reg = self.reg
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.env
        if isinstance(node, ast.Call):
            name = call_name(node)
            last = last_component(name)
            if last in reg.sanitizer_calls:
                return False
            if last in reg.declassifier_calls:
                return False
            if last in reg.source_calls:
                return True
            args_tainted = any(self.tainted(a) for a in node.args) or \
                any(self.tainted(kw.value) for kw in node.keywords)
            # a method call on a tainted object yields tainted data
            recv_tainted = isinstance(node.func, ast.Attribute) and \
                self.tainted(node.func.value)
            return args_tainted or recv_tainted
        if isinstance(node, ast.Attribute):
            if node.attr in reg.source_attrs:
                return True
            if node.attr in reg.declassifier_attrs:
                return False
            return self.tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.tainted(node.value)
        if isinstance(node, ast.BinOp):
            return self.tainted(node.left) or self.tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return False                       # booleans are shape-class info
        if isinstance(node, ast.IfExp):
            return self.tainted(node.body) or self.tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.tainted(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(self.tainted(v) for v in node.values if v is not None)
        if isinstance(node, ast.JoinedStr):
            return any(self.tainted(v.value) for v in node.values
                       if isinstance(v, ast.FormattedValue))
        if isinstance(node, ast.FormattedValue):
            return self.tainted(node.value)
        if isinstance(node, ast.Starred):
            return self.tainted(node.value)
        if isinstance(node, (ast.Await, ast.YieldFrom)):
            return self.tainted(node.value)
        if isinstance(node, ast.NamedExpr):
            return self.tainted(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return self._comp_tainted(node, node.elt)
        if isinstance(node, ast.DictComp):
            return self._comp_tainted(node, node.value) \
                or self._comp_tainted(node, node.key)
        return False

    def _comp_tainted(self, comp: ast.AST, elt: ast.AST) -> bool:
        bound: Set[str] = set()
        for gen in comp.generators:
            if self.tainted(gen.iter):
                for t in ast.walk(gen.target):
                    if isinstance(t, ast.Name):
                        bound.add(t.id)
        added = bound - self.env
        self.env |= added
        try:
            return self.tainted(elt)
        finally:
            self.env -= added

    # ------------------------------------------------------------ statements
    def _bind(self, target: ast.AST, is_tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if is_tainted:
                self.env.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, is_tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, is_tainted)
        # attribute/subscript stores: field-level taint is out of scope

    def run(self, scope: ast.AST, params: Optional[ast.arguments]) -> None:
        if params is not None:
            for a in (params.posonlyargs + params.args + params.kwonlyargs):
                if a.arg in self.reg.source_params:
                    self.env.add(a.arg)
        for _ in range(_MAX_TAINT_ITERS):
            before = set(self.env)
            for node in _walk_scope(scope):
                if isinstance(node, ast.Assign):
                    t = self.tainted(node.value)
                    for tgt in node.targets:
                        self._bind(tgt, t)
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    self._bind(node.target, self.tainted(node.value))
                elif isinstance(node, ast.AugAssign):
                    if self.tainted(node.value):
                        self._bind(node.target, True)
                elif isinstance(node, ast.For):
                    self._bind(node.target, self.tainted(node.iter))
                elif isinstance(node, (ast.With, ast.AsyncWith)):
                    for item in node.items:
                        if item.optional_vars is not None:
                            self._bind(item.optional_vars,
                                       self.tainted(item.context_expr))
                elif isinstance(node, ast.NamedExpr):
                    self._bind(node.target, self.tainted(node.value))
            if self.env == before:
                break


def _function_scopes(tree: ast.Module):
    """(scope_node, arguments|None) for the module body + every function."""
    yield tree, None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.args


def _check_taint(info: ModuleInfo, reg: PrivacyRegistry) -> List[Finding]:
    findings: List[Finding] = []
    in_serve = info.in_scope(reg.serve_scope)
    for scope, params in _function_scopes(info.tree):
        taint = _Taint(reg)
        taint.run(scope, params)
        if not taint.env and not _has_source_expr(scope, reg):
            continue
        for node in _walk_scope(scope):
            if not isinstance(node, ast.Call):
                continue
            last = last_component(call_name(node))
            is_sink = last in reg.sink_calls \
                or last in reg.sink_constructors \
                or (in_serve and last in reg.serve_sink_calls)
            if not is_sink:
                continue
            if "PF001" in info.ignored_rules(node.lineno):
                continue
            hot = [a for a in node.args if taint.tainted(a)]
            hot += [kw.value for kw in node.keywords if taint.tainted(kw.value)]
            if not hot:
                continue
            findings.append(Finding(
                "PF001", info.path, node.lineno,
                f"{qualname(node)}:{last}",
                f"raw (un-noised) data flows into sink {last!r}",
                hint="route the value through a measure*/release sanitizer "
                     "(Gaussian or discrete-Gaussian noise) before it can "
                     "reach a release surface"))
    return findings


def _has_source_expr(scope: ast.AST, reg: PrivacyRegistry) -> bool:
    """Cheap pre-filter: does this scope mention any source at all?"""
    for node in ast.walk(scope):
        if isinstance(node, ast.Attribute) and node.attr in reg.source_attrs:
            return True
        if isinstance(node, ast.Call) and \
                last_component(call_name(node)) in reg.source_calls:
            return True
    return False


# --------------------------------------------------------------------- PF002
def _method_events(method: ast.AST, reg: PrivacyRegistry
                   ) -> List[Tuple[int, str, str]]:
    """Ordered (line, kind, name) events: charge / measure / self-calls."""
    events = []
    for node in ast.walk(method):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        last = last_component(name)
        if last in reg.charge_calls:
            events.append((node.lineno, "charge", last))
        elif last in reg.measure_calls:
            events.append((node.lineno, "measure", last))
        elif name and name.startswith("self."):
            events.append((node.lineno, "call", name.split(".", 1)[1]))
    events.sort()
    return events


def _check_charge_protocol(info: ModuleInfo, reg: PrivacyRegistry
                           ) -> List[Finding]:
    if not info.in_scope(reg.serve_scope):
        return []
    findings: List[Finding] = []
    for cls in [n for n in ast.walk(info.tree) if isinstance(n, ast.ClassDef)]:
        events: Dict[str, List[Tuple[int, str, str]]] = {
            m.name: _method_events(m, reg) for m in class_methods(cls)}
        lines = {m.name: m.lineno for m in class_methods(cls)}

        def charged_before(method: str, line: int) -> bool:
            return any(kind == "charge" and ln < line
                       for ln, kind, _n in events.get(method, []))

        def dominated(method: str, line: int, seen: frozenset) -> bool:
            """Is (method, line) preceded by a charge on every caller path?"""
            if charged_before(method, line):
                return True
            if method in seen:
                return True                # cycle: judged at the entry edge
            callers = [(m, ln) for m, evs in events.items()
                       for ln, kind, name in evs
                       if kind == "call" and name.split(".")[0] == method]
            if not callers:
                return False               # an entry point that never charged
            return all(dominated(m, ln, seen | {method})
                       for m, ln in callers)

        for method, evs in events.items():
            first = next(((ln, nm) for ln, kind, nm in evs
                          if kind == "measure"), None)
            if first is None:
                continue
            line, name = first
            if "PF002" in info.ignored_rules(line):
                continue
            if dominated(method, line, frozenset()):
                continue
            findings.append(Finding(
                "PF002", info.path, line,
                f"{cls.name}.{method}:{name}",
                f"measurement call {name!r} is not dominated by a budget "
                f"charge on every path into {cls.name}.{method}",
                hint="charge the ledger (charge-before-measure) before any "
                     "noise is drawn; see repro/serve/ledger.py"))
        del lines
    return findings


def check_privacy(info: ModuleInfo,
                  reg: PrivacyRegistry = DEFAULT_PRIVACY) -> List[Finding]:
    return _check_taint(info, reg) + _check_charge_protocol(info, reg)
