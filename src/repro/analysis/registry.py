"""Declarative rule registries: what counts as a source, sanitizer, sink,
charge, or device limit.

The passes are generic dataflow machines; everything repo-specific lives
here so adding a rule (or pointing the analyzer at a different codebase) is
a registry edit, not a pass rewrite (docs/ANALYSIS.md §How to add a rule).

Name matching is by *last dotted component* — ``self.ledger.charge`` and
``ledger.charge`` both match ``charge`` — which is the right granularity
for an intraprocedural analysis that cannot resolve imports.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, Optional, Tuple

# Rule catalog (docs/ANALYSIS.md mirrors this — keep the two in sync).
ALL_RULES: Dict[str, str] = {
    "PF001": "raw-data taint reaches a release sink without a noise "
             "sanitizer on the path",
    "PF002": "measurement in a serve-scope class is not dominated by a "
             "budget-ledger charge (charge-before-measure)",
    "KN001": "literal block_l is not a positive multiple of the sublane "
             "quantum for the chain's compute dtype",
    "KN002": "literal vmem_budget exceeds every real accelerator's VMEM "
             "ceiling in the DeviceSpec table",
    "KN003": "narrow compute dtype / allow_narrow=True on a chain inside a "
             "noise-drawing function (noise must stay float32)",
    "KN004": "host side effect (RNG, clock, I/O, env) inside a jitted or "
             "Pallas kernel body",
    "KN005": "literal BlockSpec minor dimension is not a multiple of the "
             "lane quantum (128)",
    "LK001": "field annotated '# guarded-by: <lock>' accessed outside a "
             "'with self.<lock>' block",
    "LK002": "'# guarded-by:' names a lock never created in this class",
    "LINT000": "file could not be parsed",
}


@dataclass(frozen=True)
class PrivacyRegistry:
    """Source/sanitizer/sink vocabulary for the privacy-flow pass."""

    # Calls whose RESULT is raw (pre-noise) data.
    source_calls: FrozenSet[str] = frozenset({
        "exact_marginals_from_x", "sharded_marginals", "_local_marginal",
        "marginals_from_records", "synthetic_records",
    })
    # Attribute reads that yield raw data wherever they appear
    # (request payloads: ``req.marginals``).
    source_attrs: FrozenSet[str] = frozenset({"marginals"})
    # Parameters of these names are raw on entry (data-plane helpers).
    source_params: FrozenSet[str] = frozenset({"records", "marginals"})
    # Calls whose result is differentially private — taint stops here.
    sanitizer_calls: FrozenSet[str] = frozenset({
        "measure", "measure_multi", "measure_np", "measure_np_batched",
        "measure_discrete", "sharded_measure", "release",
        "corpus_marginal_release",
    })
    # Metadata projections: shape-class information, not data.
    declassifier_attrs: FrozenSet[str] = frozenset({
        "size", "shape", "ndim", "dtype", "nbytes", "itemsize",
    })
    declassifier_calls: FrozenSet[str] = frozenset({
        "len", "isinstance", "type", "id", "hash",
    })
    # Sinks: raw taint must never reach these (checked everywhere).
    sink_calls: FrozenSet[str] = frozenset({
        "set_result", "set_exception", "_append",
    })
    # Sinks only enforced inside serve-scope modules (response assembly).
    serve_sink_calls: FrozenSet[str] = frozenset({
        "dumps", "write", "sendall",
    })
    # Constructors whose fields ship to tenants.
    sink_constructors: FrozenSet[str] = frozenset({"ReleaseResult"})
    # PF002 protocol vocabulary.
    charge_calls: FrozenSet[str] = frozenset({"charge"})
    measure_calls: FrozenSet[str] = frozenset({"measure", "measure_multi"})
    serve_scope: str = "serve"


DEFAULT_PRIVACY = PrivacyRegistry()


@dataclass(frozen=True)
class KernelLimits:
    """Launch-config constants the kernel-invariant pass enforces.

    Sourced live from :mod:`repro.kernels.kron_matvec.fused` and the
    :mod:`repro.roofline.cost_model` DeviceSpec table so the analyzer can
    never drift from the kernels it checks; the literals below are only the
    fallback when the package is analyzed from a checkout where those
    imports are unavailable.
    """

    sublane: Tuple[Tuple[str, int], ...] = (
        ("float32", 8), ("bfloat16", 16), ("float16", 16))
    lane: int = 128
    # Largest VMEM ceiling across real (non-interpret) accelerators: a
    # literal budget above this cannot fit ANY device in the table.
    vmem_limit_real: int = 32 * 1024 * 1024
    narrow_dtypes: FrozenSet[str] = frozenset({"bfloat16", "float16"})
    chain_calls: FrozenSet[str] = frozenset({
        "plan_chain", "fused_chain_matvec", "tune_chain"})
    noise_calls: FrozenSet[str] = frozenset({
        "normal", "standard_normal", "sample", "sample_discrete_gaussian"})
    host_effect_exact: FrozenSet[str] = frozenset({
        "print", "open", "input", "breakpoint"})
    host_effect_prefixes: Tuple[str, ...] = (
        "np.random.", "numpy.random.", "random.", "os.", "time.", "sys.")

    def sublane_for(self, dtype: str) -> int:
        return dict(self.sublane).get(dtype, 8)


_LIMITS: Optional[KernelLimits] = None


def kernel_limits() -> KernelLimits:
    """KernelLimits bound to the live kernel/cost-model constants."""
    global _LIMITS
    if _LIMITS is not None:
        return _LIMITS
    try:
        from repro.kernels.kron_matvec.fused import _LANE, _SUBLANE
        from repro.roofline.cost_model import DEVICE_TABLE
        vmem = max(spec.vmem_limit for spec in DEVICE_TABLE.values()
                   if not spec.interpret)
        _LIMITS = KernelLimits(
            sublane=tuple(sorted(_SUBLANE.items())), lane=_LANE,
            vmem_limit_real=vmem)
    except Exception:                      # pragma: no cover - no jax runtime
        _LIMITS = KernelLimits()
    return _LIMITS
