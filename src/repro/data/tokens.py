"""Synthetic LM token pipeline (plane B): deterministic, shardable batches.

A Markov-ish synthetic stream gives non-trivial next-token structure so small
training runs show decreasing loss; batches come with document attributes for
the DP corpus-statistics release (engine/corpus_stats.py).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


def synthetic_lm_batches(vocab_size: int, batch: int, seq_len: int,
                         seed: int = 0, n_sources: int = 8) -> Iterator[Dict]:
    rng = np.random.default_rng(seed)
    # low-rank bigram structure → learnable
    r = 16
    a = rng.standard_normal((min(vocab_size, 2048), r))
    b = rng.standard_normal((r, min(vocab_size, 2048)))
    logits = (a @ b) * 1.5
    probs = np.exp(logits - logits.max(1, keepdims=True))
    probs /= probs.sum(1, keepdims=True)
    v_eff = probs.shape[0]
    while True:
        toks = np.zeros((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, v_eff, batch)
        for t in range(seq_len):
            p = probs[toks[:, t]]
            c = p.cumsum(axis=1)
            u = rng.random((batch, 1))
            toks[:, t + 1] = (u > c).sum(axis=1)
        yield {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            # document attributes for DP corpus stats: (source, length bucket)
            "doc_attrs": np.stack([
                rng.integers(0, n_sources, batch),
                np.full(batch, min(seq_len // 512, 7)),
            ], axis=1).astype(np.int32),
        }
