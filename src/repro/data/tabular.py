"""Tabular data plane: the paper's benchmark schemas + record generators.

Domain sizes are the paper's exactly (§8): Adult (14 attrs, universe
6.41e17), CPS (5 attrs), Loans (12 attrs), and Synth-n^d.  Accuracy metrics
in the paper are data-independent, so synthetic records suffice for
end-to-end runs; real data would be dropped in via the same (N, n_attrs)
integer-matrix format.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.domain import Clique, Domain

ADULT_SIZES = [100, 100, 100, 99, 85, 42, 16, 15, 9, 7, 6, 5, 2, 2]
CPS_SIZES = [100, 50, 7, 4, 2]
LOANS_SIZES = [101, 101, 101, 101, 3, 8, 36, 6, 51, 4, 5, 15]


def adult_domain() -> Domain:
    return Domain.create(ADULT_SIZES, names=[f"adult{i}" for i in range(14)])


def cps_domain() -> Domain:
    return Domain.create(CPS_SIZES, names=[f"cps{i}" for i in range(5)])


def loans_domain() -> Domain:
    return Domain.create(LOANS_SIZES, names=[f"loans{i}" for i in range(12)])


def synth_domain(n: int, d: int, kind: str = "categorical") -> Domain:
    return Domain.create([n] * d, names=[f"x{i}" for i in range(d)],
                         kinds=[kind] * d)


def synthetic_records(domain: Domain, n_records: int, seed: int = 0,
                      skew: float = 1.2) -> np.ndarray:
    """(N, n_attrs) int32 records with mildly Zipfian per-attribute values."""
    rng = np.random.default_rng(seed)
    cols = []
    for a in domain.attributes:
        w = 1.0 / np.arange(1, a.size + 1) ** skew
        w /= w.sum()
        cols.append(rng.choice(a.size, size=n_records, p=w))
    return np.stack(cols, axis=1).astype(np.int32)


def marginals_from_records(domain: Domain, cliques: Sequence[Clique],
                           records: np.ndarray) -> Dict[Clique, np.ndarray]:
    """Exact marginal tables (host/NumPy path)."""
    out: Dict[Clique, np.ndarray] = {}
    for c in cliques:
        if not c:
            out[c] = np.array([records.shape[0]], dtype=np.float64)
            continue
        sizes = [domain.attributes[i].size for i in c]
        flat = np.zeros(records.shape[0], dtype=np.int64)
        for i, col in enumerate(c):
            flat = flat * sizes[i] + records[:, col]
        out[c] = np.bincount(flat, minlength=int(np.prod(sizes))).astype(np.float64)
    return out
