from .tabular import (ADULT_SIZES, CPS_SIZES, LOANS_SIZES, adult_domain,
                      cps_domain, loans_domain, marginals_from_records,
                      synth_domain, synthetic_records)
from .tokens import synthetic_lm_batches
