r"""Pallas TPU kernel for the paper's compute hot spot: Kronecker-factor matvec.

Every ResidualPlanner phase (measurement Alg 1/5, reconstruction Alg 2/6)
reduces to chains of  y = (I_L ⊗ S ⊗ I_R) x  applications — a *batched small
GEMM*: view x as (L, n, R) and contract the small per-attribute matrix
S (m, n) with the middle axis.

TPU adaptation (docs/DESIGN.md §3): attribute sizes n are far below the 128×128
MXU tile, so the kernel gets its arithmetic intensity from the (L, R) batch
layout instead:

  * grid over (L/bl, R/br) blocks; R is the minor axis, br = 512 lanes
    (4×128) so the VREG lanes are dense;
  * S (m, n) is loaded into VMEM once per block column and reused across the
    whole (bl × br) tile — m·n·bl·br MACs per (n·bl·br + m·bl·br) transfers,
    i.e. intensity ≈ m FLOP/byte vs O(1) for the naive gather formulation;
  * m and n are zero-padded to multiples of 8 (sublane) by ops.py so the
    dot_general maps onto the MXU without relayouts.

Validated in interpret mode on CPU against ref.py (the pure-jnp oracle used
by the rest of the library).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kron_axis_kernel(s_ref, x_ref, o_ref):
    """o[bl, m, br] = Σ_n s[m, n] · x[bl, n, br]."""
    s = s_ref[...]
    x = x_ref[...]
    # (m, n) × (bl, n, br) -> (m, bl, br): contract axis 1 with axis 1.
    o = jax.lax.dot_general(
        s, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] = o.transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_l", "block_r", "interpret"))
def kron_axis_matvec(s: jnp.ndarray, x: jnp.ndarray, *, block_l: int = 8,
                     block_r: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Apply S (m, n) along the middle axis of x (L, n, R) → (L, m, R).

    L and R must be multiples of block_l / block_r (ops.py pads).
    """
    L, n, R = x.shape
    m = s.shape[0]
    assert s.shape[1] == n
    assert L % block_l == 0 and R % block_r == 0, (L, R, block_l, block_r)
    grid = (L // block_l, R // block_r)
    return pl.pallas_call(
        _kron_axis_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, n), lambda i, j: (0, 0)),
            pl.BlockSpec((block_l, n, block_r), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_l, m, block_r), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((L, m, R), x.dtype),
        interpret=interpret,
    )(s, x)
