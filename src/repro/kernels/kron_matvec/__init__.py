from .fused import (ChainPlan, fused_cache_info, fused_chain_matvec,
                    plan_chain)
from .ops import kron_matvec_kernel, residual_measure_kernel
from .ref import kron_matvec_ref, residual_measure_ref
from .stats import CHAIN_STATS, chain_stats, reset_chain_stats
