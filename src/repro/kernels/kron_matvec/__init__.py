from .ops import kron_matvec_kernel, residual_measure_kernel
from .ref import kron_matvec_ref, residual_measure_ref
