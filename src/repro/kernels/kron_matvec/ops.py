"""jit'd wrappers around the per-axis Pallas kron kernel.

``kron_matvec_kernel`` applies a full chain ⊗_i S_i by invoking the per-axis
kernel once per non-trivial factor, padding (m, n) to sublane multiples of 8
and R to lane multiples of 512, then slicing back (docs/DESIGN.md §3.2).
``residual_measure_kernel`` fuses the measurement Hv + σHz by stacking [v, z]
into the L (batch) axis so both transforms share every S tile — the
Alg 1/Alg 5 hot path in one sweep.

This is the *fallback and oracle* path: it pays one pad → HBM round-trip →
slice per factor.  The production chain path is fused.py, which plans the
layout once and keeps the working tile in VMEM across all factors
(docs/DESIGN.md §3.3–3.4).

interpret=True (automatic on CPU) runs the kernel body in Python for
correctness validation; on TPU backends the real Mosaic lowering is used.
"""
from __future__ import annotations

import math
from functools import partial
from typing import List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ._layout import interpret_default as _interpret_default
from ._layout import normalize_factor as _normalize_factor
from ._layout import pad_to as _pad_to
from .kron_matvec import kron_axis_matvec
from .stats import CHAIN_STATS

_LANE = 512
_SUB = 8


def _apply_axis(s: np.ndarray, x: jnp.ndarray, L: int, n: int, R: int,
                interpret: bool) -> jnp.ndarray:
    m = s.shape[0]
    n_p, m_p = _pad_to(n, _SUB), _pad_to(m, _SUB)
    L_p, R_p = _pad_to(L, _SUB), _pad_to(R, _LANE)
    s_p = jnp.zeros((m_p, n_p), x.dtype).at[:m, :n].set(jnp.asarray(s, x.dtype))
    xr = x.reshape(L, n, R)
    x_p = jnp.zeros((L_p, n_p, R_p), x.dtype).at[:L, :n, :R].set(xr)
    CHAIN_STATS.inc("pads")
    block_l = min(_SUB, L_p)
    block_r = min(_LANE, R_p)
    y = kron_axis_matvec(s_p, x_p, block_l=block_l, block_r=block_r,
                         interpret=interpret)
    CHAIN_STATS.inc("pallas_calls")
    out = y[:L, :m, :R].reshape(L * m * R)
    CHAIN_STATS.inc("slices")
    return out


def kron_matvec_kernel(factors: Sequence, x: jnp.ndarray, dims: Sequence[int],
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """(⊗_i factors[i]) x with the Pallas per-axis kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    dims = [int(d) for d in dims]
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    cur = list(dims)
    for axis, f in enumerate(factors):
        s = _normalize_factor(f, cur[axis])
        if s is None:
            continue
        L = math.prod(cur[:axis]) if axis else 1
        R = math.prod(cur[axis + 1:]) if axis + 1 < len(cur) else 1
        x = _apply_axis(s, x, L, cur[axis], R, interpret)
        cur[axis] = s.shape[0]
    return x


def residual_measure_kernel(factors: Sequence, v: jnp.ndarray, z: jnp.ndarray,
                            sigma: float, dims: Sequence[int],
                            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused measurement  H v + σ H z  (Algorithm 1 / 5 hot path).

    [v; z] ride the batch (L) axis of the same kernel invocations, so every
    S-tile load is shared between the data pass and the noise pass.
    """
    interpret = _interpret_default() if interpret is None else interpret
    dims = [int(d) for d in dims]
    stacked = jnp.stack([jnp.asarray(v, jnp.float32).reshape(-1),
                         jnp.asarray(z, jnp.float32).reshape(-1)])
    x = stacked.reshape(-1)
    cur = list(dims)
    for axis, f in enumerate(factors):
        s = _normalize_factor(f, cur[axis])
        if s is None:
            continue
        L = 2 * (math.prod(cur[:axis]) if axis else 1)
        R = math.prod(cur[axis + 1:]) if axis + 1 < len(cur) else 1
        x = _apply_axis(s, x, L, cur[axis], R, interpret)
        cur[axis] = s.shape[0]
    out = x.reshape(2, -1)
    return out[0] + sigma * out[1]
