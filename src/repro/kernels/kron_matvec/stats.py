"""HBM-traffic instrumentation for the kron kernel chains.

Counters are bumped by the host-side wrappers (ops.py / fused.py) every time
an array is zero-padded into a kernel layout, sliced back out of one, or a
``pallas_call`` is issued.  They exist so tests and benchmarks can *assert*
the layout contract of docs/DESIGN.md §3.4 — the fused chain performs exactly
one pad and one slice per chain, while the per-axis fallback pays one of each
per non-trivial factor.

Since the obs subsystem (docs/OBSERVABILITY.md) the store is two-tier:

* Each :class:`ChainStats` instance holds resettable
  :class:`~repro.obs.AtomicCounter` cells — ``reset_chain_stats()`` /
  ``chain_stats()`` keep their historical window semantics for tests and
  benchmarks, and bumps from concurrent serve workers no longer race.
* Every :meth:`ChainStats.inc` on the global :data:`CHAIN_STATS` also feeds
  the monotone ``repro_kernel_events_total{event=...}`` family in the global
  metrics registry, which is what ``/metrics`` exposes (Prometheus counters
  must never go backwards, so the resettable window stays local).
"""
from __future__ import annotations

from typing import Dict

from repro.obs import REGISTRY, AtomicCounter

_FIELDS = (
    "pads",             # HBM zero-pad materializations
    "slices",           # HBM slice-backs
    "pallas_calls",     # pallas_call invocations
    "fused_chains",     # chains served by the fused kernel
    "fallback_chains",  # chains that fell back to the per-axis kernel
    "epilogue_axes",    # implicit-W (cumsum) epilogue axes applied
)

_KERNEL_EVENTS = REGISTRY.counter(
    "repro_kernel_events_total",
    "Kron-chain kernel events (pads, slices, pallas calls, path choices)",
    labels=("event",))


class ChainStats:
    """Atomic kernel-event counters with a resettable window.

    ``mirror=True`` (the process-global :data:`CHAIN_STATS`) forwards every
    increment to the registry's monotone family; ad-hoc instances (tests)
    stay local.
    """

    __slots__ = ("_cells", "_mirror")

    def __init__(self, mirror: bool = False):
        self._cells = {f: AtomicCounter() for f in _FIELDS}
        self._mirror = mirror

    def inc(self, name: str, n: int = 1) -> None:
        if n:
            self._cells[name].inc(n)
            if self._mirror:
                _KERNEL_EVENTS.labels(event=name).inc(n)

    def reset(self) -> None:
        for c in self._cells.values():
            c.set(0)

    def snapshot(self) -> Dict[str, int]:
        return {f: int(self._cells[f].value) for f in _FIELDS}


def _chain_field(name: str) -> property:
    def _get(self) -> int:
        return int(self._cells[name].value)

    def _set(self, v: int) -> None:
        self._cells[name].set(v)

    return property(_get, _set)


for _f in _FIELDS:
    setattr(ChainStats, _f, _chain_field(_f))
del _f


CHAIN_STATS = ChainStats(mirror=True)


def reset_chain_stats() -> None:
    CHAIN_STATS.reset()


def chain_stats() -> dict:
    return CHAIN_STATS.snapshot()
