"""HBM-traffic instrumentation for the kron kernel chains.

Counters are bumped by the host-side wrappers (ops.py / fused.py) every time
an array is zero-padded into a kernel layout, sliced back out of one, or a
``pallas_call`` is issued.  They exist so tests and benchmarks can *assert*
the layout contract of docs/DESIGN.md §3.4 — the fused chain performs exactly
one pad and one slice per chain, while the per-axis fallback pays one of each
per non-trivial factor.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class ChainStats:
    pads: int = 0            # HBM zero-pad materializations
    slices: int = 0          # HBM slice-backs
    pallas_calls: int = 0    # pallas_call invocations
    fused_chains: int = 0    # chains served by the fused kernel
    fallback_chains: int = 0  # chains that fell back to the per-axis kernel
    epilogue_axes: int = 0   # implicit-W (cumsum) epilogue axes applied

    def snapshot(self) -> dict:
        return dict(pads=self.pads, slices=self.slices,
                    pallas_calls=self.pallas_calls,
                    fused_chains=self.fused_chains,
                    fallback_chains=self.fallback_chains,
                    epilogue_axes=self.epilogue_axes)


CHAIN_STATS = ChainStats()


def reset_chain_stats() -> None:
    CHAIN_STATS.pads = 0
    CHAIN_STATS.slices = 0
    CHAIN_STATS.pallas_calls = 0
    CHAIN_STATS.fused_chains = 0
    CHAIN_STATS.fallback_chains = 0
    CHAIN_STATS.epilogue_axes = 0


def chain_stats() -> dict:
    return CHAIN_STATS.snapshot()
