"""Pure-jnp oracles for the kron_matvec kernels."""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp

from repro.core.kron import kron_matvec


def kron_matvec_ref(factors: Sequence, x: jnp.ndarray,
                    dims: Sequence[int]) -> jnp.ndarray:
    """(⊗_i factors[i]) x — reshape + tensordot reference implementation."""
    return kron_matvec(factors, x, dims)


def residual_measure_ref(factors: Sequence, v: jnp.ndarray, z: jnp.ndarray,
                         sigma: float, dims: Sequence[int]) -> jnp.ndarray:
    """H v + σ H z  (Alg 1 measurement) via two reference matvecs."""
    return kron_matvec(factors, v, dims) + sigma * kron_matvec(factors, z, dims)
