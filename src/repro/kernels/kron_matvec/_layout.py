"""Layout helpers shared by the per-axis (ops.py) and fused (fused.py) paths.

One definition of padding, backend detection and factor normalization keeps
the two kernel paths in exact agreement about what a factor *means* — an
identity matrix, ``None`` and a skipped axis must be the same thing on both.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax


def interpret_default() -> bool:
    """Interpret-mode Pallas everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def normalize_factor(f, n: int) -> Optional[np.ndarray]:
    """None/identity → None (axis untouched); 'ones' → (1, n) row; else matrix."""
    if f is None:
        return None
    if isinstance(f, str):
        if f == "ones":
            return np.ones((1, n), dtype=np.float32)
        raise ValueError(f)
    f = np.asarray(f, dtype=np.float32)
    if f.shape == (n, n) and np.allclose(f, np.eye(n)):
        return None   # explicit identity: skip the contraction
    return f
