r"""Fused multi-axis Pallas kernel: a whole Kronecker factor chain per call.

The per-axis kernel (kron_matvec.py) pays a full zero-pad → HBM round-trip →
slice for every factor of ``⊗_i S_i``.  This module plans the layout of the
*entire* chain up front and runs it as ONE ``pallas_call``:

  * the batch axis B (stacked [v; z] pairs, stacked same-signature cliques —
    see docs/DESIGN.md §4) is the only gridded axis; each grid step owns a
    ``(block_l, W)`` tile;
  * the tile is loaded into VMEM once, reshaped to ``(block_l, n_1, …, n_k)``
    and contracted with every factor *in registers/VMEM* — factors are tiny
    (attribute-sized) and ride along whole;
  * exactly one zero-pad on entry (B → B_p sublane multiple, flat width
    N → W_in lane multiple) and one slice on exit (docs/DESIGN.md §3.4);
    the pad/slice/pallas_call counts are instrumented in stats.py so tests
    can assert the contract.

Launch configs are no longer one-size-fits-all: ``plan_chain`` is
dtype-aware (compute dtype ∈ {float32, bfloat16, float16} with fp32
accumulation, itemsize-correct VMEM accounting, device-derived budgets with
the historical 4 MiB as the CPU/interpret fallback), and when the
per-signature autotuner is enabled (``REPRO_KERNEL_AUTOTUNE``, docs/TUNING.md)
``fused_chain_matvec`` resolves the tuned ``(block_l, vmem_budget,
compute_dtype, fused)`` config for the chain signature instead of the fixed
default (docs/DESIGN.md §14).  Explicitly passed config kwargs always win and
bypass the tuner (that is also how the tuner's own measured refinement calls
avoid recursion).

Chains whose working tile would overflow the VMEM budget fall back to the
per-axis kernel (ops.py), which tiles R and is correct at any size — the
fused path is the fast path, not the only path.

Validated in interpret mode on CPU against the float64 numpy oracle
(core.kron.kron_matvec_np); on TPU backends the real Mosaic lowering is used.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.obs import REGISTRY, TRACER
from repro.obs.naming import chain_label

from ._layout import interpret_default as _interpret_default
from ._layout import normalize_factor as _normalize_factor
from ._layout import pad_to as _pad_to
from .stats import CHAIN_STATS

# Measured dispatch time per chain launch, labeled like the roofline gauges
# (obs/naming.py) so predicted-vs-measured is one /metrics join.  Host-side
# dispatch timing: JAX execution is async, so this bounds launch overhead and
# any synchronous work, not device busy time.
_LAUNCH_SECONDS = REGISTRY.histogram(
    "repro_kernel_launch_seconds",
    "Host-side dispatch time of one kron-chain launch",
    labels=("chain",),
    buckets=(1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0))

_LANE = 128          # minor-axis (lane) padding quantum
_SUB = 8             # sublane padding quantum (float32)
_MAX_BLOCK_L = 128   # batch rows per grid step (untuned default)
_VMEM_BUDGET = 4 * 1024 * 1024   # untuned CPU/interpret fallback budget

# Sublane quantum per compute dtype (pallas guide: min tile second-to-last
# dim is 8 for fp32, 16 for bf16/fp16).
_SUBLANE = {"float32": 8, "bfloat16": 16, "float16": 16}
_ACC_BYTES = 4       # accumulation / output dtype is always float32


def _sublane(compute_dtype: str) -> int:
    return _SUBLANE.get(str(compute_dtype), _SUB)


def default_vmem_budget() -> int:
    """Device-derived untuned budget: 4 MiB on CPU/interpret (the historical
    constant), the device table's conservative budget on real accelerators."""
    from repro.roofline.cost_model import detect_device
    dev = detect_device()
    return _VMEM_BUDGET if dev.interpret else dev.default_vmem_budget


@dataclass(frozen=True)
class ChainPlan:
    """Static layout plan for one fused chain (docs/DESIGN.md §3.3).

    The plan is the jit-cache key: chains with the same signature — per-axis
    (m_i, n_i) shapes, batch padding and tile widths, compute dtype — share
    one compiled kernel regardless of the factor *values*.
    """

    in_dims: Tuple[int, ...]                       # per-axis input sizes n_i
    fshapes: Tuple[Optional[Tuple[int, int]], ...]  # (m_i, n_i) or None (identity)
    out_dims: Tuple[int, ...]                      # per-axis output sizes
    n_in: int                                      # prod(in_dims)
    n_out: int                                     # prod(out_dims)
    w_in: int                                      # lane-padded input width
    w_out: int                                     # lane-padded output width
    block_l: int                                   # batch rows per grid step
    vmem_bytes: int                                # working-tile footprint
    fused_ok: bool                                 # fits the VMEM budget?
    epilogue: Tuple[Optional[str], ...] = ()       # per-axis implicit-W op
    compute_dtype: str = "float32"                 # operand dtype (fp32 accum)

    @property
    def signature(self) -> tuple:
        return (self.in_dims, self.fshapes, self.block_l, self.epilogue,
                self.compute_dtype)


def plan_chain(factors: Sequence, dims: Sequence[int], batch: int = 1,
               block_l: Optional[int] = None,
               vmem_budget: Optional[int] = None,
               epilogue: Optional[Sequence[Optional[str]]] = None,
               compute_dtype: str = "float32") -> ChainPlan:
    """Plan the fused layout of ``(⊗_i factors[i])`` applied to a (batch, N) stack.

    ``epilogue[i]`` is an optional shape-preserving implicit-W op applied to
    axis i after the chain: ``'cumsum'`` (prefix-sum along the axis, the
    implicit form of the lower-triangular prefix matrix — docs/DESIGN.md §8).

    ``compute_dtype`` narrows the *operands* (input tile + factors); every
    contraction still accumulates in float32 (``preferred_element_type``) and
    the output tile is float32.  VMEM accounting is itemsize-correct: the
    input tile at the compute dtype's itemsize, output + intermediates at the
    fp32 accumulator width, the tril epilogue operand at its own (compute)
    dtype.  ``vmem_budget=None`` resolves to the device default — the
    historical 4 MiB on CPU/interpret.
    """
    compute_dtype = str(jnp.dtype(compute_dtype).name)
    if compute_dtype not in _SUBLANE:
        raise ValueError(f"unsupported compute dtype {compute_dtype!r}; "
                         f"expected one of {sorted(_SUBLANE)}")
    if vmem_budget is None:
        vmem_budget = default_vmem_budget()
    dims = tuple(int(d) for d in dims)
    epilogue = tuple(epilogue) if epilogue is not None else (None,) * len(dims)
    if len(epilogue) != len(dims):
        raise ValueError(f"epilogue length {len(epilogue)} != {len(dims)} axes")
    if any(op not in (None, "cumsum") for op in epilogue):
        raise ValueError(f"unknown epilogue op in {epilogue}")
    specs: List[Optional[Tuple[int, int]]] = []
    out_dims: List[int] = []
    for f, n in zip(factors, dims):
        s = _normalize_factor(f, n)
        if s is None:
            specs.append(None)
            out_dims.append(n)
        else:
            if s.shape[1] != n:
                raise ValueError(f"factor {s.shape} does not match axis size {n}")
            specs.append((int(s.shape[0]), n))
            out_dims.append(int(s.shape[0]))
    n_in = math.prod(dims) if dims else 1
    n_out = math.prod(out_dims) if out_dims else 1
    sub = _sublane(compute_dtype)
    if block_l is None:
        block_l = min(_MAX_BLOCK_L, _pad_to(max(batch, 1), sub))
    block_l = _pad_to(int(block_l), sub)
    w_in = _pad_to(n_in, _LANE)
    w_out = _pad_to(n_out, _LANE)
    # Peak per-step tensor while the chain runs in VMEM: input tile at the
    # compute itemsize + output tile and largest fp32 intermediate (dot
    # outputs accumulate in fp32 before narrowing for the next factor).
    isz = jnp.dtype(compute_dtype).itemsize
    sizes = [n_in]
    cur = list(dims)
    for axis, spec in enumerate(specs):
        if spec is None:
            continue
        cur[axis] = spec[0]
        sizes.append(math.prod(cur))
    vmem = block_l * (isz * w_in + _ACC_BYTES * (w_out + max(sizes)))
    # Factors ride along whole, at the compute dtype.
    vmem += isz * sum(m * n for s in specs if s is not None for m, n in [s])
    # The in-kernel cumsum epilogue contracts with an iota-built (n, n)
    # triangular operand at its own (compute) dtype; it lives in VMEM
    # alongside the tile.
    vmem += isz * sum(out_dims[a] ** 2 for a, op in enumerate(epilogue)
                      if op == "cumsum")
    return ChainPlan(dims, tuple(specs), tuple(out_dims), n_in, n_out,
                     w_in, w_out, block_l, vmem, vmem <= vmem_budget,
                     epilogue, compute_dtype)


def _tril_ones(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """(n, n) lower-triangular ones, built from iotas inside the kernel.

    ``y = x @ trilᵀ`` is the cumsum along the contracted axis — the implicit
    MXU form of the dense prefix matrix: the operand is synthesized in
    VMEM/registers and never materialized in HBM (docs/DESIGN.md §8).
    """
    r = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return (c <= r).astype(dtype)


def _make_fused_kernel(plan: ChainPlan):
    """Kernel body: the whole chain on one VMEM-resident (block_l, W) tile."""
    dims, specs, epilogue = plan.in_dims, plan.fshapes, plan.epilogue
    n_in, n_out, w_out, bl = plan.n_in, plan.n_out, plan.w_out, plan.block_l
    cd = jnp.dtype(plan.compute_dtype)
    narrow = cd != jnp.float32

    def _contract(x, s, axis):
        # Contract axis ``axis+1`` with S by rotating it to the minor
        # position — the dot_general then maps onto the MXU with the
        # (block_l × leading-dims) batch as rows (docs/DESIGN.md §3.2).
        # Operands are at the compute dtype; accumulation is fp32, and the
        # result narrows back for the next factor (mixed-precision policy,
        # docs/DESIGN.md §14).
        x = jnp.moveaxis(x, axis + 1, x.ndim - 1)
        x = jax.lax.dot_general(
            x, s, dimension_numbers=(((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        if narrow:
            x = x.astype(cd)
        return jnp.moveaxis(x, x.ndim - 1, axis + 1)

    def kernel(*refs):
        s_refs, x_ref, o_ref = refs[:-2], refs[-2], refs[-1]
        x = x_ref[:, :n_in].reshape((bl,) + dims)
        si = 0
        for axis, spec in enumerate(specs):
            if spec is None:
                continue
            s = s_refs[si][...]
            si += 1
            x = _contract(x, s, axis)
        for axis, op in enumerate(epilogue):
            if op == "cumsum":
                x = _contract(x, _tril_ones(x.shape[axis + 1], cd), axis)
        y = x.reshape(bl, n_out).astype(jnp.float32)
        o_ref[...] = jnp.zeros((bl, w_out), y.dtype).at[:, :n_out].set(
            y).astype(o_ref.dtype)

    return kernel


@lru_cache(maxsize=None)
def _build_fused_call(signature: tuple, b_p: int, interpret: bool):
    """Compile (and cache, keyed on the chain signature) the fused pallas_call."""
    in_dims, fshapes, block_l, epilogue, compute_dtype = signature
    plan = plan_chain([np.zeros(s) if s else None for s in fshapes],
                      in_dims, batch=b_p, block_l=block_l, epilogue=epilogue,
                      compute_dtype=compute_dtype)
    kernel = _make_fused_kernel(plan)
    grid = (b_p // block_l,)
    in_specs = [pl.BlockSpec(s, lambda i: (0, 0))
                for s in fshapes if s is not None]
    in_specs.append(pl.BlockSpec((block_l, plan.w_in), lambda i: (i, 0)))

    def call(*args):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block_l, plan.w_out), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((b_p, plan.w_out), jnp.float32),
            interpret=interpret,
        )(*args)

    return jax.jit(call), plan


def fused_cache_info():
    return _build_fused_call.cache_info()


def _fallback_per_axis(s_facs: List[Optional[np.ndarray]], x: jnp.ndarray,
                       dims: Tuple[int, ...], interpret: bool) -> jnp.ndarray:
    """Per-axis kernel on the batched stack: identity on the batch axis."""
    from .ops import kron_matvec_kernel   # lazy: ops imports stats, not fused
    b = x.shape[0]
    y = kron_matvec_kernel([None] + list(s_facs), x.reshape(-1),
                           (b,) + dims, interpret=interpret)
    return y.reshape(b, -1)


def apply_epilogue(y, out_dims: Sequence[int],
                   epilogue: Sequence[Optional[str]]) -> jnp.ndarray:
    """Implicit-W epilogue: cumsum along marked axes of a (B, Π out_dims) stack.

    Used by the non-fused (batched jnp / per-axis fallback) paths; the fused
    kernel applies the same ops in-kernel (docs/DESIGN.md §8).  Pure — safe
    to jit; callers on the host bump ``CHAIN_STATS.epilogue_axes`` themselves
    so the counter reflects serving calls, not traces.
    """
    if not epilogue or all(op is None for op in epilogue):
        return y
    b = y.shape[0]
    t = jnp.asarray(y).reshape((b,) + tuple(out_dims))
    for axis, op in enumerate(epilogue):
        if op == "cumsum":
            t = jnp.cumsum(t, axis=axis + 1)
    return t.reshape(b, -1)


def fused_chain_matvec(factors: Sequence, x, dims: Sequence[int],
                       interpret: Optional[bool] = None,
                       block_l: Optional[int] = None,
                       vmem_budget: Optional[int] = None,
                       epilogue: Optional[Sequence[Optional[str]]] = None,
                       compute_dtype: Optional[str] = None,
                       allow_narrow: bool = False) -> jnp.ndarray:
    """Apply ``⊗_i factors[i]`` to a stack ``x`` of shape (B, N) (or flat (N,)).

    One pad, one pallas_call, one slice per chain (stats.py instruments the
    contract).  Chains too large for VMEM fall back to the per-axis kernel.
    ``epilogue`` marks axes for in-kernel implicit-W ops (``'cumsum'``), see
    :func:`plan_chain`.  Returns shape (B, n_out) — or flat (n_out,) if the
    input was flat; the output dtype is always float32.

    Launch-config resolution (docs/DESIGN.md §14): if any of ``block_l`` /
    ``vmem_budget`` / ``compute_dtype`` is passed explicitly, exactly those
    values are used (unset ones take the untuned defaults) and the autotuner
    is bypassed.  Otherwise, when ``REPRO_KERNEL_AUTOTUNE`` is not ``off``,
    the tuned config for this chain signature is looked up (tuning it on the
    fly with the analytic cost model on a first miss).  ``allow_narrow``
    gates the mixed-precision policy: chains that carry Gaussian noise lanes
    keep the default ``False`` so a tuned narrow compute dtype is clamped
    back to float32 — noise stays fp32, only the data path may narrow.
    """
    interpret = _interpret_default() if interpret is None else interpret
    x = jnp.asarray(x, jnp.float32)
    flat_in = x.ndim == 1
    if flat_in:
        x = x[None, :]
    b = x.shape[0]
    explicit = (block_l is not None or vmem_budget is not None
                or compute_dtype is not None)
    s_facs = [_normalize_factor(f, n) for f, n in zip(factors, dims)]
    force_fallback = False
    if not explicit:
        from repro.kernels.autotune import resolve_config
        cfg = resolve_config(s_facs, dims, batch=b, epilogue=epilogue,
                             interpret=interpret)
        if cfg is not None:
            block_l = cfg.block_l
            vmem_budget = cfg.vmem_budget
            compute_dtype = cfg.compute_dtype if allow_narrow else "float32"
            force_fallback = not cfg.fused
    if compute_dtype is None:
        compute_dtype = "float32"
    plan = plan_chain(s_facs, dims, batch=b, block_l=block_l,
                      vmem_budget=vmem_budget, epilogue=epilogue,
                      compute_dtype=compute_dtype)
    if x.shape[1] != plan.n_in:
        raise ValueError(f"x width {x.shape[1]} != prod(dims) {plan.n_in}")
    live = [s for s in s_facs if s is not None]
    has_epi = any(op is not None for op in plan.epilogue)
    if not live and not has_epi:
        return x[0] if flat_in else x
    if not live:
        y = apply_epilogue(x, plan.out_dims, plan.epilogue)
        CHAIN_STATS.inc("epilogue_axes", sum(1 for op in plan.epilogue if op))
        return y[0] if flat_in else y

    tune_source = "explicit" if explicit else \
        (cfg.source if cfg is not None else "default")
    label = chain_label(plan.in_dims, b, plan.compute_dtype)
    t0 = time.monotonic()
    if force_fallback or not plan.fused_ok:
        CHAIN_STATS.inc("fallback_chains")
        with TRACER.span("kernel.chain").set(
                chain=label, fused=False, block_l=plan.block_l,
                compute_dtype=plan.compute_dtype, tune_source=tune_source):
            y = _fallback_per_axis(s_facs, x, plan.in_dims, interpret)
            y = apply_epilogue(y, plan.out_dims, plan.epilogue)
        CHAIN_STATS.inc("epilogue_axes", sum(1 for op in plan.epilogue if op))
        _LAUNCH_SECONDS.labels(chain=label).observe(time.monotonic() - t0)
        return y[0] if flat_in else y

    with TRACER.span("kernel.chain").set(
            chain=label, fused=True, block_l=plan.block_l,
            compute_dtype=plan.compute_dtype, tune_source=tune_source,
            vmem_bytes=plan.vmem_bytes):
        cd = jnp.dtype(plan.compute_dtype)
        b_p = _pad_to(b, plan.block_l)
        # ONE pad: batch to the sublane grid, flat width to the lane grid;
        # the tile narrows to the compute dtype here so VMEM sees the planned
        # bytes.
        x_p = jnp.zeros((b_p, plan.w_in), cd).at[:b, :plan.n_in].set(
            x.astype(cd))
        CHAIN_STATS.inc("pads")
        call, _ = _build_fused_call(plan.signature, b_p, interpret)
        out = call(*[jnp.asarray(s, cd) for s in live], x_p)
        CHAIN_STATS.inc("pallas_calls")
        CHAIN_STATS.inc("fused_chains")
        CHAIN_STATS.inc("epilogue_axes",
                        sum(1 for op in plan.epilogue if op))
        # ONE slice back to the true (B, n_out) extent.
        y = out[:b, :plan.n_out]
        CHAIN_STATS.inc("slices")
    _LAUNCH_SECONDS.labels(chain=label).observe(time.monotonic() - t0)
    return y[0] if flat_in else y
