"""Launch-config autotuner for the fused Kron-chain kernel.

See docs/TUNING.md for the env knobs (``REPRO_KERNEL_AUTOTUNE``,
``REPRO_AUTOTUNE_CACHE``, ``REPRO_KERNEL_COMPUTE_DTYPES``) and
docs/DESIGN.md §14 for the cost model and resolution rules.
"""
from .cache import CACHE_VERSION, TuningCache, default_cache_dir
from .tuner import (TunedConfig, autotune_mode, chain_key, pretune,
                    registry_snapshot, reset_registry, resolve_config,
                    tune_chain)

__all__ = ["CACHE_VERSION", "TuningCache", "default_cache_dir",
           "TunedConfig", "autotune_mode", "chain_key", "pretune",
           "registry_snapshot", "reset_registry", "resolve_config",
           "tune_chain"]
