"""Per-signature-group launch-config autotuner for the fused Kron-chain
kernel (docs/DESIGN.md §14, docs/TUNING.md).

For each chain signature group — same per-axis factor shapes, epilogue and
(padded) batch — the tuner enumerates a small candidate lattice of
``(block_l, compute_dtype)`` launch configs, scores each with the analytic
roofline cost model (:class:`repro.roofline.cost_model.CostModel`), compares
the best fused candidate against the modeled per-axis fallback, and caches
the winner.  ``REPRO_KERNEL_AUTOTUNE`` selects the mode:

* ``off``     — fixed untuned defaults everywhere (the pre-tuner behavior);
* ``model``   — analytic pick only (the default; zero kernel launches);
* ``measure`` — analytic shortlist refined by on-device timing of the top
  candidates (launches real kernels; used by engine pre-tuning and CI bench).

Winners live in a per-process registry and, when tuned through
:func:`tune_chain`/:func:`pretune` (the engine pre-tuning path), in an
on-disk JSON cache keyed by ``(device_kind, chain signature)`` so serving
restarts skip re-tuning.  On-the-fly resolution inside a kernel call
(:func:`resolve_config` miss) uses the analytic model only and does not
persist — measurement from inside a serving request would stall it.

Mixed-precision candidates (bf16/fp16 operands, fp32 accumulation) are only
enumerated when ``REPRO_KERNEL_COMPUTE_DTYPES`` lists them or a caller asks
explicitly; call sites that carry Gaussian noise clamp narrow configs back
to fp32 (``allow_narrow=False`` in ``fused_chain_matvec``) — noise stays
fp32, only the data path may narrow.
"""
from __future__ import annotations

import os
import time
from dataclasses import asdict, dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.roofline.cost_model import CostModel, DeviceSpec, detect_device

from .cache import TuningCache

# Fused must beat the modeled per-axis fallback by this margin before the
# tuner abandons the one-pad/one-call contract — near-ties keep the fused
# path (its stats contract is what the engine tier is built around).
_FALLBACK_MARGIN = 0.9

# measure mode: number of analytically best candidates to time for real.
_MEASURE_TOP_K = 3
_MEASURE_REPS = 3


@dataclass(frozen=True)
class TunedConfig:
    """Winner for one chain signature group — what the kernel launches with."""

    block_l: int
    vmem_budget: int
    compute_dtype: str = "float32"
    fused: bool = True               # False: per-axis fallback predicted faster
    predicted_s: float = 0.0
    intensity: float = 0.0           # predicted flops / HBM byte
    grid_steps: int = 0
    source: str = "model"            # model | measure | cache | default

    def as_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TunedConfig":
        fields = {f for f in cls.__dataclass_fields__}  # noqa: C416
        return cls(**{k: v for k, v in d.items() if k in fields})


def autotune_mode() -> str:
    m = os.environ.get("REPRO_KERNEL_AUTOTUNE", "model").strip().lower()
    return m if m in ("off", "model", "measure") else "model"


def _dtype_candidates(dtypes: Optional[Sequence[str]]) -> Tuple[str, ...]:
    if dtypes:
        return tuple(dtypes)
    env = os.environ.get("REPRO_KERNEL_COMPUTE_DTYPES", "")
    if env:
        out = tuple(d.strip() for d in env.split(",") if d.strip())
        return out or ("float32",)
    return ("float32",)


def chain_key(device_kind: str, dims: Sequence[int],
              fshapes: Sequence[Optional[Tuple[int, int]]],
              epilogue: Optional[Sequence[Optional[str]]],
              batch: int) -> str:
    """Stable string key for one (device, chain signature, batch) group."""
    f = ",".join("-" if s is None else f"{s[0]}x{s[1]}" for s in fshapes)
    e = ",".join("-" if op is None else str(op)
                 for op in (epilogue or (None,) * len(tuple(dims))))
    d = ",".join(str(int(n)) for n in dims)
    return f"{device_kind}|d={d}|f={f}|e={e}|b={int(batch)}"


# Per-process registry: chain_key -> TunedConfig.  Every resolution path
# lands here so /stats can report the decisions actually in effect.
_REGISTRY: Dict[str, TunedConfig] = {}


def reset_registry() -> None:
    _REGISTRY.clear()


def registry_snapshot() -> dict:
    dev = detect_device()
    return {"mode": autotune_mode(), "device": dev.kind,
            "entries": {k: cfg.as_dict() for k, cfg in _REGISTRY.items()}}


def _fshapes(factors: Sequence, dims: Sequence[int]
             ) -> Tuple[Optional[Tuple[int, int]], ...]:
    from repro.kernels.kron_matvec._layout import normalize_factor
    out = []
    for f, n in zip(factors, dims):
        s = normalize_factor(f, int(n))
        out.append(None if s is None else (int(s.shape[0]), int(s.shape[1])))
    return tuple(out)


def _block_lattice(batch: int, sub: int, max_exact: int) -> List[int]:
    """Candidate block_l values: sublane-multiple powers of two up to the
    padded batch, plus the exact padded batch itself (grid == 1 with zero
    rounding waste — the interpret-mode winner for awkward batch sizes)."""
    from repro.kernels.kron_matvec._layout import pad_to
    b_p = pad_to(max(batch, 1), sub)
    cands = []
    bl = sub
    while bl < min(b_p, max_exact):
        cands.append(bl)
        bl *= 2
    cands.append(min(b_p, max_exact))
    return sorted(set(cands))


def tune_chain(factors: Sequence, dims: Sequence[int], batch: int = 1,
               epilogue: Optional[Sequence[Optional[str]]] = None,
               dtypes: Optional[Sequence[str]] = None,
               device: Optional[DeviceSpec] = None,
               mode: Optional[str] = None,
               persist: bool = True,
               interpret: Optional[bool] = None) -> TunedConfig:
    """Tune ONE chain signature group and register (and persist) the winner.

    ``dtypes`` widens the candidate lattice beyond fp32 (callers opt into
    narrowing; see module docstring).  ``mode`` overrides the env mode —
    ``resolve_config`` passes ``"model"`` for on-the-fly misses.
    """
    from repro.kernels.kron_matvec.fused import _SUBLANE, plan_chain

    dev = detect_device() if device is None else device
    mode = autotune_mode() if mode is None else mode
    model = CostModel(dev)
    dims = tuple(int(d) for d in dims)
    fshapes = _fshapes(factors, dims)
    epi = tuple(epilogue) if epilogue is not None else (None,) * len(dims)
    key = chain_key(dev.kind, dims, fshapes, epi, batch)

    # A batch large enough that even one grid row overflows VMEM caps the
    # exact-batch candidate; 2**16 rows is far past any signature group.
    scored = []   # (cost, plan)
    for dt in _dtype_candidates(dtypes):
        sub = _SUBLANE.get(dt, 8)
        for bl in _block_lattice(batch, sub, max_exact=2 ** 16):
            plan = plan_chain(factors, dims, batch=batch, block_l=bl,
                              vmem_budget=dev.vmem_limit, epilogue=epi,
                              compute_dtype=dt)
            if not plan.fused_ok:      # tile would overflow the device ceiling
                continue
            scored.append((model.chain_cost(plan, batch), plan))

    per_axis_s = model.per_axis_cost(dims, fshapes, batch)
    if not scored:
        from repro.kernels.kron_matvec._layout import pad_to
        cfg = TunedConfig(block_l=min(128, pad_to(max(batch, 1), 8)),
                          vmem_budget=dev.default_vmem_budget,
                          fused=False, predicted_s=per_axis_s,
                          source="model")
        _REGISTRY[key] = cfg
        return cfg

    scored.sort(key=lambda cp: cp[0].predicted_s)
    best_cost, best_plan = scored[0]

    if mode == "measure":
        best_cost, best_plan = _refine_by_timing(
            scored[:_MEASURE_TOP_K], factors, dims, batch, epi, interpret)

    if per_axis_s < _FALLBACK_MARGIN * best_cost.predicted_s:
        cfg = TunedConfig(block_l=best_plan.block_l,
                          vmem_budget=best_plan.vmem_bytes,
                          compute_dtype=best_plan.compute_dtype, fused=False,
                          predicted_s=per_axis_s,
                          intensity=best_cost.intensity,
                          grid_steps=best_cost.grid_steps,
                          source="measure" if mode == "measure" else "model")
    else:
        cfg = TunedConfig(block_l=best_plan.block_l,
                          vmem_budget=best_plan.vmem_bytes,
                          compute_dtype=best_plan.compute_dtype, fused=True,
                          predicted_s=best_cost.predicted_s,
                          intensity=best_cost.intensity,
                          grid_steps=best_cost.grid_steps,
                          source="measure" if mode == "measure" else "model")
    _REGISTRY[key] = cfg
    if persist:
        TuningCache(dev.kind).put(key, cfg.as_dict())
    return cfg


def _refine_by_timing(shortlist, factors, dims, batch, epilogue, interpret):
    """Time the analytically-best candidates for real and keep the fastest.

    Every call passes the candidate config EXPLICITLY, which bypasses the
    tuner in ``fused_chain_matvec`` — no recursion, and the measurement
    exercises exactly the launch being scored.
    """
    import jax.numpy as jnp

    from repro.kernels.kron_matvec.fused import fused_chain_matvec

    n_in = int(np.prod([int(d) for d in dims])) if dims else 1
    x = jnp.zeros((max(batch, 1), n_in), jnp.float32)
    best = None
    for cost, plan in shortlist:
        def run(plan=plan):
            fused_chain_matvec(
                factors, x, dims, interpret=interpret,
                block_l=plan.block_l, vmem_budget=plan.vmem_bytes,
                epilogue=plan.epilogue, compute_dtype=plan.compute_dtype,
                allow_narrow=True).block_until_ready()
        try:
            run()                                  # warm the jit cache
            t = min(_timed(run) for _ in range(_MEASURE_REPS))
        except Exception:                          # pragma: no cover - backend
            continue
        # Replace the analytic time with the measured one; keep the rest of
        # the analytic cost fields (intensity etc.) for reporting.
        measured = replace(cost, predicted_s=t)
        if best is None or measured.predicted_s < best[0].predicted_s:
            best = (measured, plan)
    return best if best is not None else shortlist[0]


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def resolve_config(factors: Sequence, dims: Sequence[int], batch: int,
                   epilogue: Optional[Sequence[Optional[str]]] = None,
                   interpret: Optional[bool] = None) -> Optional[TunedConfig]:
    """Tuned config for a chain call, or None when tuning is off.

    Resolution order: env mode gate → per-process registry → on-disk cache →
    on-the-fly analytic tune (model only, not persisted — see module
    docstring).  Called by ``fused_chain_matvec`` only when the caller passed
    no explicit launch kwargs.
    """
    mode = autotune_mode()
    if mode == "off":
        return None
    dev = detect_device()
    dims_t = tuple(int(d) for d in dims)
    fshapes = _fshapes(factors, dims_t)
    epi = tuple(epilogue) if epilogue is not None \
        else (None,) * len(dims_t)
    key = chain_key(dev.kind, dims_t, fshapes, epi, batch)
    cfg = _REGISTRY.get(key)
    if cfg is not None:
        return cfg
    blob = TuningCache(dev.kind).get(key)
    if blob is not None:
        cfg = TunedConfig.from_dict({**blob, "source": "cache"})
        _REGISTRY[key] = cfg
        return cfg
    return tune_chain(factors, dims_t, batch=batch, epilogue=epi,
                      device=dev, mode="model", persist=False,
                      interpret=interpret)


def pretune(chains: Sequence[tuple],
            device: Optional[DeviceSpec] = None,
            mode: Optional[str] = None) -> List[TunedConfig]:
    """Tune a batch of chain groups up front (engine construction path).

    ``chains`` holds ``(factors, dims, batch, epilogue)`` tuples.  Winners
    are persisted to the on-disk cache; in ``measure`` mode this is where
    real kernels get timed, safely outside any serving request.
    """
    out = []
    for factors, dims, batch, epilogue in chains:
        out.append(tune_chain(factors, dims, batch=batch, epilogue=epilogue,
                              device=device, mode=mode, persist=True))
    return out
