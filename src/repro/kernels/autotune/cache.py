"""On-disk persistence for tuned kernel launch configs (docs/TUNING.md).

One small JSON file per device kind, keyed by the chain signature string the
tuner builds (``tuner.chain_key``).  The file carries its schema version and
the device kind it was tuned on; a mismatch on either invalidates the whole
file (configs tuned for one device are meaningless on another, and schema
bumps must not resurrect stale entries).  Writes are atomic (tmp + rename)
so concurrent serving processes never observe a torn file.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import threading
from typing import Dict, Optional

CACHE_VERSION = 1


def default_cache_dir() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE", "")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune")


def _slug(device_kind: str) -> str:
    return "".join(c if c.isalnum() else "_" for c in device_kind.lower())


class TuningCache:
    """Load/store tuned configs for one device kind.

    ``get``/``put`` operate on plain dicts (the tuner owns the TunedConfig
    dataclass); the cache only enforces the version/device envelope.

    Thread-safe: the serve worker and a tenant-registration warmup can tune
    concurrently, so the lazy first load and every mutation serialize on one
    lock; ``load`` returns a snapshot copy rather than the live dict.
    """

    def __init__(self, device_kind: str, path: Optional[str] = None):
        self.device_kind = device_kind
        self.path = path or os.path.join(default_cache_dir(),
                                         f"{_slug(device_kind)}.json")
        self._lock = threading.Lock()
        self._entries: Optional[Dict[str, dict]] = None  # guarded-by: _lock

    # ------------------------------------------------------------------ load
    def load(self) -> Dict[str, dict]:
        with self._lock:
            return dict(self._load_locked())

    def _load_locked(self) -> Dict[str, dict]:  # requires-lock: _lock
        if self._entries is not None:
            return self._entries
        self._entries = {}
        # missing/corrupt file == empty cache
        with contextlib.suppress(OSError, ValueError):
            with open(self.path) as f:
                blob = json.load(f)
            if (blob.get("version") == CACHE_VERSION
                    and blob.get("device_kind") == self.device_kind
                    and isinstance(blob.get("entries"), dict)):
                self._entries = dict(blob["entries"])
        return self._entries

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            return self._load_locked().get(key)

    # ----------------------------------------------------------------- store
    def put(self, key: str, config: dict) -> None:
        with self._lock:
            entries = self._load_locked()
            entries[key] = config
            self._write(dict(entries))

    def _write(self, entries: Dict[str, dict]) -> None:
        blob = {"version": CACHE_VERSION, "device_kind": self.device_kind,
                "entries": entries}
        d = os.path.dirname(self.path)
        # read-only FS: keep the in-memory view
        with contextlib.suppress(OSError):
            os.makedirs(d, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)

    def clear(self) -> None:
        with self._lock:
            self._entries = {}
        with contextlib.suppress(OSError):
            os.unlink(self.path)
