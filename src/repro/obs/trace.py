"""Request-scoped tracing: context-manager spans, contextvar propagation,
a bounded in-memory ring, and an fsync-free JSONL sink.

Model (docs/OBSERVABILITY.md):

* A **span** is one timed operation: name, monotonic start/end, attributes,
  a span ID, a trace ID shared by every span of one request, and a parent
  span ID linking the tree together.
* The **current span** rides a :mod:`contextvars` variable, so nested
  ``with tracer.span(...)`` calls parent automatically.  Crossing a thread
  boundary (serve worker picking up a queued request) is explicit:
  :meth:`Tracer.activate` re-installs a span as the ambient parent inside
  the worker.
* **Zero-cost-when-off**: ``Tracer.span()`` checks one attribute and returns
  a shared no-op singleton when tracing is disabled — no allocation, no
  clock read, no lock.  The no-op span is falsy so call sites can guard
  optional attribute work with ``if sp:``.  The serve-bench overhead gate
  (≤2% disabled) holds the fast path to that contract.
* **Sink**: finished spans land in a bounded ring (``deque(maxlen=...)``)
  and, when a path is configured (``REPRO_TRACE=/path`` or
  ``enable(path=...)``), are appended as one JSON object per line.  Writes
  are buffered and never fsynced — tracing must not serialize the worker on
  disk latency — and a hard cap on spans-per-file guards against unbounded
  logs from a long-lived server; overflow increments a ``dropped`` counter
  instead of writing.

Span JSON schema (one line each)::

    {"trace": "8f3c...", "span": "02ab...", "parent": "f1d0..." | null,
     "name": "serve.request", "t0": 1234.5678, "t1": 1234.5690,
     "dur_us": 1200.0, "attrs": {...}}

``t0``/``t1`` are *monotonic* seconds (durations are exact; absolute wall
time is not recorded).  ``tools/repro_trace.py`` consumes this format.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import threading
import time
from collections import deque
from typing import Optional

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_span", default=None)

# Hard cap on spans written to one JSONL sink file (ring keeps the newest
# spans in memory regardless; the file cap bounds disk growth only).
MAX_FILE_SPANS = 200_000


def _new_id() -> str:
    return os.urandom(8).hex()


class _NoopSpan:
    """Shared do-nothing span returned when tracing is off.

    Falsy, so ``if sp: sp.set(...)`` skips attribute building entirely.
    """

    __slots__ = ()

    def __bool__(self) -> bool:
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def end(self) -> None:
        pass

    @property
    def trace_id(self) -> None:
        return None

    @property
    def span_id(self) -> None:
        return None


NOOP_SPAN = _NoopSpan()


class Span:
    """One timed operation in a trace tree.

    Use as a context manager (normal case) or call :meth:`end` explicitly
    (root spans that outlive the scope that minted them, e.g. the
    ``serve.request`` span created in ``submit()`` and ended by the worker).
    """

    __slots__ = ("tracer", "name", "trace_id", "span_id", "parent_id",
                 "t0", "t1", "attrs", "_token", "_ended")

    def __init__(self, tracer: "Tracer", name: str,
                 trace_id: Optional[str] = None,
                 parent_id: Optional[str] = None,
                 t0: Optional[float] = None):
        self.tracer = tracer
        self.name = name
        self.trace_id = trace_id or _new_id()
        self.span_id = _new_id()
        self.parent_id = parent_id
        self.t0 = time.monotonic() if t0 is None else t0
        self.t1: Optional[float] = None
        self.attrs: dict = {}
        self._token = None
        self._ended = False

    def __bool__(self) -> bool:
        return True

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._token = _CTX.set(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._token is not None:
            _CTX.reset(self._token)
            self._token = None
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
            if exc is not None:
                self.attrs["error_msg"] = str(exc)[:200]
        self.end()
        return False

    def end(self, t1: Optional[float] = None) -> None:
        if self._ended:
            return
        self._ended = True
        self.t1 = time.monotonic() if t1 is None else t1
        self.tracer._record(self)

    def to_dict(self) -> dict:
        t1 = self.t1 if self.t1 is not None else self.t0
        return {
            "trace": self.trace_id,
            "span": self.span_id,
            "parent": self.parent_id,
            "name": self.name,
            "t0": self.t0,
            "t1": t1,
            "dur_us": (t1 - self.t0) * 1e6,
            "attrs": self.attrs,
        }


class Tracer:
    """Span factory + sink.  One process-global instance (:data:`TRACER`).

    ``enabled`` is the single fast-path check: when False, :meth:`span`
    returns :data:`NOOP_SPAN` immediately.
    """

    def __init__(self, ring_size: int = 4096):
        self.enabled = False
        self._lock = threading.Lock()
        self._ring = deque(maxlen=ring_size)   # guarded-by: _lock
        self._fh = None                        # guarded-by: _lock
        self._path: Optional[str] = None       # guarded-by: _lock
        self._written = 0                      # guarded-by: _lock
        self._dropped = 0                      # guarded-by: _lock

    # ------------------------------------------------------------ control
    def enable(self, path: Optional[str] = None,
               max_file_spans: int = MAX_FILE_SPANS) -> None:
        """Turn tracing on, optionally appending spans to ``path`` (JSONL)."""
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            self._path = path
            self._written = 0
            self._dropped = 0
            self._max_file_spans = max_file_spans
            if path:
                self._fh = open(path, "a", encoding="utf-8")  # noqa: SIM115
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None
            self._path = None

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    # ------------------------------------------------------------ factory
    def span(self, name: str, *, trace_id: Optional[str] = None,
             parent: Optional[Span] = None,
             t0: Optional[float] = None):
        """Create a span, or the no-op singleton when tracing is off.

        Parent resolution: explicit ``parent`` arg wins, else the ambient
        context span; trace ID inherits from the parent unless given.
        ``t0`` backdates the start (cross-thread queue-wait spans measure
        an interval that began before the span object could exist).
        """
        if not self.enabled:
            return NOOP_SPAN
        if parent is None:
            parent = _CTX.get()
        if isinstance(parent, _NoopSpan):
            parent = None
        pid = parent.span_id if parent is not None else None
        if trace_id is None and parent is not None:
            trace_id = parent.trace_id
        return Span(self, name, trace_id=trace_id, parent_id=pid, t0=t0)

    @contextlib.contextmanager
    def activate(self, span):
        """Install ``span`` as the ambient parent for this thread/context.

        Used at thread boundaries: the serve worker re-activates the root
        span minted by ``submit()`` so engine/kernel spans parent correctly.
        A falsy (no-op) span deactivates any inherited context instead.
        """
        token = _CTX.set(span if span else None)
        try:
            yield span
        finally:
            _CTX.reset(token)

    def current(self):
        return _CTX.get()

    # ------------------------------------------------------------- sink
    def _record(self, span: Span) -> None:
        line = None
        with self._lock:
            self._ring.append(span)
            if self._fh is not None:
                if self._written < getattr(self, "_max_file_spans",
                                           MAX_FILE_SPANS):
                    self._written += 1
                    line = json.dumps(span.to_dict(), separators=(",", ":"))
                else:
                    self._dropped += 1
            if line is not None:
                self._fh.write(line + "\n")

    def drain(self) -> list:
        """Return and clear the in-memory ring (tests, ad-hoc inspection)."""
        with self._lock:
            out = list(self._ring)
            self._ring.clear()
            return out

    def stats(self) -> dict:
        with self._lock:
            return {"enabled": self.enabled, "path": self._path,
                    "written": self._written, "dropped": self._dropped,
                    "ring": len(self._ring)}


TRACER = Tracer()

# REPRO_TRACE=/path/to/trace.jsonl activates tracing at import time;
# REPRO_TRACE=1 enables the in-memory ring without a file sink.
_env = os.environ.get("REPRO_TRACE")
if _env:
    TRACER.enable(None if _env in ("1", "true", "ring") else _env)
