"""Observability: metrics registry + request-scoped tracing.

This package is a *leaf* — it imports only the standard library — so every
other layer (serve, engine, kernels, release) can depend on it without
cycles.  See docs/OBSERVABILITY.md for the span model, metric naming, and
the trace CLI walkthrough.
"""
from .metrics import (
    REGISTRY,
    AtomicCounter,
    MetricFamily,
    MetricsRegistry,
    exposition,
    parse_exposition,
)
from .trace import NOOP_SPAN, TRACER, Span, Tracer

__all__ = [
    "AtomicCounter",
    "MetricFamily",
    "MetricsRegistry",
    "REGISTRY",
    "exposition",
    "parse_exposition",
    "Span",
    "Tracer",
    "TRACER",
    "NOOP_SPAN",
]
