"""Typed metrics primitives + Prometheus text exposition (docs/OBSERVABILITY.md).

One :class:`MetricsRegistry` is the single backing store for every runtime
counter the system used to scatter across ad-hoc dataclasses: engine
``EngineStats``, kernel ``ChainStats``, engine-cache hit/eviction counters,
ledger charge/reject events, and the per-tenant latency rings.  The legacy
dataclass fields survive as thin views over registry-owned cells, so existing
call sites (``stats.measure_calls``, ``chain_stats()["pads"]``, ``/stats``)
keep working while ``/metrics`` renders the same values in Prometheus text
format — the two endpoints can never disagree because there is only one
store.

Primitives:

* :class:`AtomicCounter` — the raw lock-guarded cell every metric builds on.
  Also used standalone (unregistered) where per-instance counters must be
  race-free but aggregate elsewhere (``EngineStats``).
* :class:`Counter` / :class:`Gauge` — monotone events / settable levels.
* :class:`Histogram` — cumulative fixed buckets (+Inf implicit), sum, count.
* :class:`Summary` — a bounded latency ring (the former ``TenantStats``
  deque) rendered as quantile samples; p50/p99 are computed over the ring on
  demand, exactly as ``/stats`` always did.

All families are labeled; a family with no declared labels has one implicit
child.  Creation is idempotent per registry (get-or-create by name), and a
name re-registered with a different kind or label set raises — the exposition
must stay self-consistent.

Thread-safety: every mutable cell is guarded by its own lock, so metric
updates from the serve worker, the HTTP reader threads, and warmup paths
never race (the lock-discipline lint, docs/ANALYSIS.md LK001, polices the
annotations).
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Dict, Iterable, Optional, Sequence, Tuple


class AtomicCounter:
    """A lock-guarded numeric cell: the primitive under every metric."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: float = 0):
        self._lock = threading.Lock()
        self._value = value                      # guarded-by: _lock

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def set_max(self, v: float) -> None:
        """Atomically raise the cell to ``v`` if ``v`` is larger."""
        with self._lock:
            if v > self._value:
                self._value = v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


def _fmt(v: float) -> str:
    """Prometheus sample value: integers render bare, floats via repr."""
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _render_labels(names: Sequence[str], values: Sequence[str],
                   extra: Sequence[Tuple[str, str]] = ()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


class _CounterChild(AtomicCounter):
    pass


class _GaugeChild(AtomicCounter):
    pass


class _HistogramChild:
    """Cumulative-bucket histogram cell (one label combination)."""

    def __init__(self, buckets: Sequence[float]):
        self._lock = threading.Lock()
        self.buckets = tuple(buckets)
        self._counts = [0] * (len(self.buckets) + 1)   # guarded-by: _lock
        self._sum = 0.0                                # guarded-by: _lock
        self._count = 0                                # guarded-by: _lock

    def observe(self, v: float) -> None:
        with self._lock:
            self._sum += v
            self._count += 1
            for i, le in enumerate(self.buckets):
                if v <= le:
                    self._counts[i] += 1
                    return
            self._counts[-1] += 1

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            return {"buckets": self.buckets, "counts": counts,
                    "sum": self._sum, "count": self._count}

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum


class _SummaryChild:
    """Bounded sample ring rendered as quantiles (the tenant latency ring).

    The ring is the registry-owned replacement for the per-tenant latency
    deque that used to live inside ``TenantStats``: O(1) memory for a
    long-lived server, exact percentiles over the most recent ``maxlen``
    observations.
    """

    def __init__(self, maxlen: int = 4096,
                 quantiles: Sequence[float] = (0.5, 0.99)):
        self._lock = threading.Lock()
        self.quantiles = tuple(quantiles)
        self._ring = deque(maxlen=maxlen)    # guarded-by: _lock
        self._sum = 0.0                      # guarded-by: _lock
        self._count = 0                      # guarded-by: _lock

    def observe(self, v: float) -> None:
        with self._lock:
            self._ring.append(float(v))
            self._sum += v
            self._count += 1

    def samples(self) -> list:
        with self._lock:
            return list(self._ring)

    def quantile(self, q: float) -> Optional[float]:
        vals = sorted(self.samples())
        if not vals:
            return None
        idx = min(len(vals) - 1, max(0, round(q * (len(vals) - 1))))
        return vals[idx]

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum


# Default histogram buckets: latency-flavored, in seconds.
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class MetricFamily:
    """One named metric with a fixed label set and per-label-value children.

    A family with no declared labels proxies its single implicit child, so
    ``registry.counter("x").inc()`` works without a ``labels()`` hop.
    """

    def __init__(self, name: str, kind: str, help: str = "",
                 labels: Sequence[str] = (), **child_opts):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = tuple(labels)
        self._child_opts = child_opts
        self._lock = threading.Lock()
        self._children: Dict[tuple, object] = {}   # guarded-by: _lock

    def _make_child(self):
        if self.kind == "counter":
            return _CounterChild()
        if self.kind == "gauge":
            return _GaugeChild()
        if self.kind == "histogram":
            return _HistogramChild(
                self._child_opts.get("buckets") or DEFAULT_BUCKETS)
        if self.kind == "summary":
            return _SummaryChild(
                maxlen=self._child_opts.get("maxlen", 4096),
                quantiles=self._child_opts.get("quantiles", (0.5, 0.99)))
        raise ValueError(f"unknown metric kind {self.kind!r}")

    def labels(self, **labelvalues):
        if set(labelvalues) != set(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(labelvalues)}")
        key = tuple(str(labelvalues[n]) for n in self.label_names)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make_child()
            return child

    def _implicit(self):
        if self.label_names:
            raise ValueError(
                f"{self.name} declares labels {self.label_names}; "
                f"use .labels(...)")
        return self.labels()

    # -- no-label conveniences ------------------------------------------
    def inc(self, n: float = 1) -> None:
        self._implicit().inc(n)

    def set(self, v: float) -> None:
        self._implicit().set(v)

    def set_max(self, v: float) -> None:
        self._implicit().set_max(v)

    def observe(self, v: float) -> None:
        self._implicit().observe(v)

    @property
    def value(self):
        return self._implicit().value

    def children(self) -> Dict[tuple, object]:
        with self._lock:
            return dict(self._children)

    # ----------------------------------------------------------- render
    def render(self) -> str:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        for key, child in sorted(self.children().items()):
            lab = _render_labels(self.label_names, key)
            if self.kind in ("counter", "gauge"):
                lines.append(f"{self.name}{lab} {_fmt(child.value)}")
            elif self.kind == "histogram":
                snap = child.snapshot()
                acc = 0
                for le, n in zip(snap["buckets"], snap["counts"]):
                    acc += n
                    bl = _render_labels(self.label_names, key,
                                        extra=[("le", _fmt(le))])
                    lines.append(f"{self.name}_bucket{bl} {acc}")
                acc += snap["counts"][-1]
                bl = _render_labels(self.label_names, key,
                                    extra=[("le", "+Inf")])
                lines.append(f"{self.name}_bucket{bl} {acc}")
                lines.append(f"{self.name}_sum{lab} {_fmt(snap['sum'])}")
                lines.append(f"{self.name}_count{lab} {snap['count']}")
            elif self.kind == "summary":
                for q in child.quantiles:
                    v = child.quantile(q)
                    if v is None:
                        continue
                    ql = _render_labels(self.label_names, key,
                                        extra=[("quantile", _fmt(q))])
                    lines.append(f"{self.name}{ql} {_fmt(v)}")
                lines.append(f"{self.name}_sum{lab} {_fmt(child.sum)}")
                lines.append(f"{self.name}_count{lab} {child.count}")
        return "\n".join(lines)


class MetricsRegistry:
    """Get-or-create registry of :class:`MetricFamily` by name.

    The process-global :data:`REGISTRY` backs process-wide stores (kernel
    chain counters, engine aggregates, autotune decisions); each
    :class:`~repro.serve.server.ReleaseServer` additionally owns a private
    registry for its tenant-scoped series so two servers in one process (or
    one test session) never cross-pollute.  ``/metrics`` renders the server
    registry merged with the global one (:func:`exposition`).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, MetricFamily] = {}   # guarded-by: _lock

    def _family(self, name: str, kind: str, help: str,
                labels: Sequence[str], **child_opts) -> MetricFamily:
        labels = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = MetricFamily(
                    name, kind, help, labels, **child_opts)
            elif fam.kind != kind or fam.label_names != labels:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind} "
                    f"with labels {fam.label_names}; cannot re-register as "
                    f"{kind} with labels {labels}")
            return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> MetricFamily:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> MetricFamily:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> MetricFamily:
        return self._family(name, "histogram", help, labels, buckets=buckets)

    def summary(self, name: str, help: str = "",
                labels: Sequence[str] = (), maxlen: int = 4096,
                quantiles: Sequence[float] = (0.5, 0.99)) -> MetricFamily:
        return self._family(name, "summary", help, labels, maxlen=maxlen,
                            quantiles=quantiles)

    def get(self, name: str) -> Optional[MetricFamily]:
        with self._lock:
            return self._families.get(name)

    def collect(self) -> list:
        with self._lock:
            return [self._families[n] for n in sorted(self._families)]

    def sample_value(self, name: str, **labels):
        """Test/debug convenience: current value of one counter/gauge child."""
        fam = self.get(name)
        if fam is None:
            return None
        key = tuple(str(labels[n]) for n in fam.label_names)
        child = fam.children().get(key)
        return None if child is None else child.value

    def exposition(self) -> str:
        return exposition(self)


def exposition(*registries: MetricsRegistry) -> str:
    """Prometheus text format (version 0.0.4) over one or more registries.

    Later registries skip families whose name an earlier registry already
    rendered, so merging a server registry with the global registry can never
    emit a duplicate ``# TYPE``.
    """
    seen: set = set()
    chunks = []
    for reg in registries:
        for fam in reg.collect():
            if fam.name in seen:
                continue
            seen.add(fam.name)
            chunks.append(fam.render())
    body = "\n".join(c for c in chunks if c)
    return body + "\n" if body else ""


def parse_exposition(text: str) -> Dict[str, Dict[str, float]]:
    """Tiny exposition parser (tests): {metric_name: {label_str: value}}.

    Accepts exactly what :func:`exposition` emits; raises on malformed
    sample lines so tests can assert the endpoint stays parseable.
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        name_part, _, value = line.rpartition(" ")
        if not name_part:
            raise ValueError(f"malformed sample line: {line!r}")
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            if not rest.endswith("}"):
                raise ValueError(f"malformed labels: {line!r}")
            labels = rest[:-1]
        else:
            name, labels = name_part, ""
        v = float(value)
        out.setdefault(name, {})[labels] = v
    return out


# Process-global default registry (kernel counters, engine aggregates).
REGISTRY = MetricsRegistry()


def label_values(fam: MetricFamily) -> Iterable[tuple]:
    return fam.children().keys()
