"""Shared label construction so predicted and measured series line up.

The roofline gauges (emitted at chain registration) and the kernel launch
histogram (emitted at every launch) must carry the *same* ``chain`` label
value, or predicted-vs-measured drift stops being a single join.  Keep the
format here, in one place.
"""
from __future__ import annotations

from typing import Sequence


def chain_label(dims: Sequence[int], batch: int, compute_dtype=None) -> str:
    """Canonical chain identity: ``5x5x5/b16/f32``-style.

    ``dims`` are the per-axis sizes of the Kronecker chain, ``batch`` the
    (unpadded) lane count, ``compute_dtype`` the kernel compute dtype
    (None → f32, the default).
    """
    d = "x".join(str(int(n)) for n in dims) if dims else "scalar"
    dt = str(compute_dtype or "float32")
    dt = {"float32": "f32", "bfloat16": "bf16", "float64": "f64"}.get(dt, dt)
    return f"{d}/b{int(batch)}/{dt}"
