"""ResidualPlanner(+) core: the paper's contribution as a composable JAX library."""
from .domain import (Attribute, Clique, Domain, MarginalWorkload, all_kway,
                     as_clique, closure, subsets)
from .residual import (expand_marginal, expand_residual, marginal_factors,
                       p_coeff, residual_factors, sub_gram, sub_matrix,
                       sub_pinv, variance_coeff)
from .plantable import BasePlan, PlanTable, SigmaView, plan_table, sov_closed_form
from .select import (Plan, select, select_convex, select_max_variance,
                     select_sum_of_variances, select_utility_constrained)
from .partition import (DEFAULT_MAX_BLOCK, Decomposition, Partition,
                        decompose, interaction_weights, partition_attributes)
from .composite import (CompositePlan, allocate_budget,
                        compare_with_monolithic, select_dnc)
from .mechanism import (Measurement, exact_marginals_from_x, measure,
                        measure_np, measure_np_batched, pcost_of_plan,
                        residual_answer, signature_groups)
from .reconstruct import (cross_marginal_covariance_dense,
                          embed_subset_answers, marginal_covariance_dense,
                          marginal_variance, reconstruct_all,
                          reconstruct_all_batched, reconstruct_marginal,
                          reconstruct_marginal_fast, subset_slot_region,
                          u_chain_factors)
from .accountant import (BudgetExhausted, PrivacyBudget, approx_dp_delta,
                         approx_dp_eps, gdp_mu, pcost_for_eps_delta,
                         pcost_for_mu, pcost_for_rho, zcdp_rho)

__all__ = [n for n in dir() if not n.startswith("_")]
