"""The selection phase: choose noise scales σ²_A for every A in closure(Wkload).

Two optimizers, matching Section 4.4 / 6.1 of the paper:

* ``select_sum_of_variances`` — the closed form of Lemma 2 (no iterations);
* ``select_convex``           — a JAX solver for any *regular*, positively
  1-homogeneous loss of the per-marginal variances (covers the paper's
  weighted-SoV and max-variance objectives).  The paper uses CVXPY/ECOS;
  this container has neither, so we exploit the scale-invariance of
  ``pcost(σ²)·L(Var(σ²))`` (pcost is (-1)-homogeneous, L is 1-homogeneous)
  to solve the *unconstrained* problem ``min_u pcost(u)·L(u)`` in log-space
  with Adam + temperature-annealed smooth-max, then rescale so the privacy
  constraint is tight.  Validated against Lemma 2 closed forms and the SVD
  bound in tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .domain import Clique, Domain, MarginalWorkload, closure, subsets
from .residual import p_coeff, variance_coeff


@dataclass
class Plan:
    """Output of the selection phase: which base mechanisms to run, at what scale."""

    domain: Domain
    workload: MarginalWorkload
    cliques: List[Clique]                    # closure(Wkload), sorted
    sigmas: Dict[Clique, float]              # σ²_A for each A in closure
    objective: str
    pcost: float
    loss_value: float

    def sigma2(self, clique: Clique) -> float:
        return self.sigmas[clique]

    def marginal_variance(self, clique: Clique) -> float:
        """Per-cell variance of the reconstructed marginal on ``clique`` (Thm 4)."""
        v = 0.0
        for sub in subsets(clique):
            v += self.sigmas[sub] * variance_coeff(self.domain, sub, clique)
        return v

    def workload_variances(self) -> Dict[Clique, float]:
        return {c: self.marginal_variance(c) for c in self.workload.cliques}

    def total_variance(self) -> float:
        """Sum over workload marginals of (#cells × per-cell variance)."""
        return sum(self.domain.n_cells(c) * v for c, v in self.workload_variances().items())

    def rmse(self) -> float:
        """Root mean squared error over all workload cells (paper's RMSE metric)."""
        return math.sqrt(self.total_variance() / self.workload.total_cells())

    def max_variance(self, weights: Optional[Mapping[Clique, float]] = None) -> float:
        wv = self.workload_variances()
        if weights is None:
            return max(wv.values())
        return max(v / float(weights.get(c, 1.0)) for c, v in wv.items())


def _coefficients(workload: MarginalWorkload,
                  weights: Optional[Mapping[Clique, float]] = None
                  ) -> Tuple[List[Clique], np.ndarray, np.ndarray]:
    """Closure cliques, pcost coefficients p_A, and SoV coefficients v_A (§6.1)."""
    dom = workload.domain
    cl = closure(workload.cliques)
    index = {c: i for i, c in enumerate(cl)}
    p = np.array([p_coeff(dom, c) for c in cl])
    v = np.zeros(len(cl))
    for wc in workload.cliques:
        imp = float(weights.get(wc, 1.0)) if weights is not None else workload.weight(wc)
        for sub in subsets(wc):
            v[index[sub]] += imp * variance_coeff(dom, sub, wc)
    return cl, p, v


def select_sum_of_variances(workload: MarginalWorkload, pcost_budget: float = 1.0,
                            weights: Optional[Mapping[Clique, float]] = None) -> Plan:
    """Closed-form optimum for weighted sum of per-cell variances (Lemma 2).

    Cliques with v_A == 0 (needed for reconstruction completeness but receiving
    zero objective weight) are handled by the standard limit argument: they get
    vanishing budget; we give them a tiny share so reconstruction stays unbiased.
    """
    cl, p, v = _coefficients(workload, weights)
    c = float(pcost_budget)
    pos = v > 0
    # Reserve a sliver of budget for zero-weight cliques so every base mechanism runs.
    n_zero = int((~pos).sum())
    eps_share = 1e-9 * c if n_zero else 0.0
    c_eff = c - eps_share * n_zero
    sq = np.sqrt(v[pos] * p[pos])
    T = float(sq.sum()) ** 2 / c_eff
    sig = np.zeros(len(cl))
    sig[pos] = np.sqrt(T * p[pos] / (c_eff * v[pos]))
    if n_zero:
        sig[~pos] = p[~pos] / eps_share  # pcost share eps_share each
    sigmas = {c_: float(s) for c_, s in zip(cl, sig)}
    plan = Plan(workload.domain, workload, cl, sigmas, "sum_of_variances",
                pcost=float(np.sum(p / sig)), loss_value=float(np.dot(v, sig)))
    return plan


def _variance_matrix(workload: MarginalWorkload, cl: List[Clique]
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """COO (rows → workload idx, cols → closure idx, coef) for Var_A(σ²) (Thm 4)."""
    dom = workload.domain
    index = {c: i for i, c in enumerate(cl)}
    rows, cols, vals = [], [], []
    for wi, wc in enumerate(workload.cliques):
        for sub in subsets(wc):
            rows.append(wi)
            cols.append(index[sub])
            vals.append(variance_coeff(dom, sub, wc))
    return np.array(rows, np.int32), np.array(cols, np.int32), np.array(vals)


def select_convex(workload: MarginalWorkload, pcost_budget: float = 1.0,
                  loss: str = "max_variance",
                  weights: Optional[Mapping[Clique, float]] = None,
                  steps: int = 3000, lr: float = 0.05, seed: int = 0) -> Plan:
    """Solve privacy-constrained selection for a regular 1-homogeneous loss.

    loss: 'max_variance' (max_A Var_A / c_A)  or 'sum_of_variances' (sanity path).
    """
    cl, p, v_lin = _coefficients(workload, weights)
    rows, cols, vals = _variance_matrix(workload, cl)
    n, m = len(cl), len(workload.cliques)
    w = np.array([float((weights or {}).get(c, workload.weight(c))) for c in workload.cliques])

    p_j = jnp.asarray(p)
    rows_j, cols_j, vals_j = jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals)
    w_j = jnp.asarray(w)
    v_lin_j = jnp.asarray(v_lin)

    def variances(u):
        contrib = vals_j * u[cols_j]
        return jax.ops.segment_sum(contrib, rows_j, num_segments=m)

    def loss_fn(u, tau):
        var = variances(u) / w_j
        if loss == "max_variance":
            L = tau * jax.scipy.special.logsumexp(var / tau)
        elif loss == "sum_of_variances":
            L = jnp.dot(v_lin_j, u)
        else:
            raise ValueError(loss)
        P = jnp.sum(p_j / u)
        return jnp.log(P) + jnp.log(L)  # scale-invariant product objective

    # Init from the SoV closed form (good warm start).
    warm = select_sum_of_variances(workload, pcost_budget, weights)
    theta0 = jnp.log(jnp.asarray([max(warm.sigmas[c], 1e-12) for c in cl]))

    tau_scale = float(np.mean([warm.marginal_variance(c) /
                               float((weights or {}).get(c, workload.weight(c)))
                               for c in workload.cliques]))

    @jax.jit
    def run(theta0):
        def adam_step(carry, i):
            theta, mom, vel = carry
            tau = 10.0 ** (-3.0 * i / steps) * tau_scale
            g = jax.grad(lambda t: loss_fn(jnp.exp(t), tau))(theta)
            mom = 0.9 * mom + 0.1 * g
            vel = 0.999 * vel + 0.001 * g * g
            mh = mom / (1 - 0.9 ** (i + 1.0))
            vh = vel / (1 - 0.999 ** (i + 1.0))
            theta = theta - lr * mh / (jnp.sqrt(vh) + 1e-9)
            return (theta, mom, vel), None

        (theta, _, _), _ = jax.lax.scan(
            adam_step, (theta0, jnp.zeros_like(theta0), jnp.zeros_like(theta0)),
            jnp.arange(steps))
        return theta

    theta = np.asarray(run(theta0), dtype=np.float64)
    u = np.exp(theta)
    # Rescale so pcost is exactly the budget (tight at the optimum).
    scale = float(np.sum(p / u)) / float(pcost_budget)
    u = u * scale
    sigmas = {c_: float(s) for c_, s in zip(cl, u)}
    plan = Plan(workload.domain, workload, cl, sigmas, loss,
                pcost=float(np.sum(p / u)), loss_value=0.0)
    if loss == "max_variance":
        plan.loss_value = plan.max_variance(weights)
    else:
        plan.loss_value = float(np.dot(v_lin, u))
    return plan


def select_max_variance(workload: MarginalWorkload, pcost_budget: float = 1.0,
                        weights: Optional[Mapping[Clique, float]] = None,
                        iters: int = 4000, tol: float = 1e-9) -> Plan:
    """Exact max-variance selection via the concave dual (beyond-paper solver).

    min_σ max_A Var_A/c_A  s.t. pcost ≤ c  has Lagrangian dual
        max_{μ ∈ Δ} g(μ),   g(μ) = (Σ_{A'} sqrt(p_{A'} v_{A'}(μ)))² / c
    where v(μ) are the Lemma-2 SoV coefficients under workload weights μ/c_A:
    the inner minimization *is* the closed form of Lemma 2.  We run
    exponentiated-gradient ascent on μ (∇g = per-marginal variances of the
    closed-form solution) and certify optimality by the primal–dual gap.
    """
    dom = workload.domain
    cl = closure(workload.cliques)
    index = {c: i for i, c in enumerate(cl)}
    p = np.array([p_coeff(dom, c) for c in cl])
    m = len(workload.cliques)
    cw = np.array([float((weights or {}).get(c, workload.weight(c)))
                   for c in workload.cliques])
    rows, cols, vals = _variance_matrix(workload, cl)
    c = float(pcost_budget)

    mu = np.full(m, 1.0 / m)
    best = None
    for t in range(iters):
        # v(μ): closure-space coefficients under weights μ_A / c_A
        v = np.zeros(len(cl))
        np.add.at(v, cols, vals * (mu / cw)[rows])
        sq = np.sqrt(np.maximum(v, 0.0) * p)
        T = sq.sum() ** 2 / c                    # dual value g(μ)
        with np.errstate(divide="ignore"):
            u = np.sqrt(T * p / (c * np.maximum(v, 1e-300)))
        var = np.zeros(m)
        np.add.at(var, rows, vals * u[cols])
        var = var / cw                           # ∇g(μ)
        primal = float(var.max())
        gap = primal - T
        if best is None or primal < best[0]:
            best = (primal, u.copy(), T)
        if gap <= tol * max(primal, 1e-300):
            break
        eta = 2.0 * math.log(max(m, 2)) / (primal * math.sqrt(t + 1.0))
        mu = mu * np.exp(eta * (var - primal))
        mu = np.maximum(mu, 1e-300)
        mu /= mu.sum()

    primal, u, T = best
    sigmas = {c_: float(s) for c_, s in zip(cl, u)}
    plan = Plan(dom, workload, cl, sigmas, "max_variance",
                pcost=float(np.sum(p / u)), loss_value=primal)
    return plan


def select(workload: MarginalWorkload, pcost_budget: float = 1.0,
           objective: str = "sum_of_variances",
           weights: Optional[Mapping[Clique, float]] = None, **kw) -> Plan:
    if objective in ("sum_of_variances", "sov", "rmse"):
        return select_sum_of_variances(workload, pcost_budget, weights)
    if objective in ("max_variance", "maxvar"):
        return select_max_variance(workload, pcost_budget, weights, **kw)
    raise ValueError(objective)


def select_utility_constrained(workload: MarginalWorkload, loss_budget: float,
                               objective: str = "sum_of_variances",
                               weights: Optional[Mapping[Clique, float]] = None,
                               **kw) -> Plan:
    """Equation 2 of the paper: minimize pcost subject to loss ≤ γ.

    Both paper objectives are positively 1-homogeneous in the σ², and pcost is
    (−1)-homogeneous, so the Eq.-1 solution at any budget rescales exactly onto
    the Eq.-2 constraint:  if Plan(c=1) attains loss L₁, then scaling every
    σ²_A by L₁/γ attains loss γ at pcost L₁/γ — and this is optimal, since a
    cheaper mechanism meeting the loss bound would rescale back to beat the
    Eq.-1 optimum.
    """
    base = select(workload, pcost_budget=1.0, objective=objective,
                  weights=weights, **kw)
    if objective in ("sum_of_variances", "sov", "rmse"):
        l1 = sum(float((weights or {}).get(c, workload.weight(c)))
                 * base.marginal_variance(c) for c in workload.cliques)
    else:
        l1 = base.max_variance(weights)
    scale = float(loss_budget) / l1          # loss is 1-homogeneous in σ²
    sigmas = {c: s * scale for c, s in base.sigmas.items()}
    plan = Plan(workload.domain, base.workload, base.cliques, sigmas,
                base.objective + "_utility_constrained",
                pcost=base.pcost / scale, loss_value=float(loss_budget))
    return plan
