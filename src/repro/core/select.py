"""The selection phase: choose noise scales σ²_A for every A in closure(Wkload).

All three optimizers run against the arrayized PlanTable IR
(:mod:`repro.core.plantable`, docs/DESIGN.md §9) — the closure, the Thm-3/4
coefficient vectors and the workload↔closure incidence are flat arrays built
once per workload, and every objective is segment-sums over them:

* ``select_sum_of_variances`` — the closed form of Lemma 2 (no iterations);
* ``select_max_variance``    — exact max-variance via the concave dual; the
  exponentiated-gradient ascent runs as a ``lax.scan`` over
  ``jax.ops.segment_sum`` on device (chunked, with fp64 host checkpoints
  certifying the primal–dual gap), replacing the historical 4000-iteration
  ``np.add.at`` host loop;
* ``select_convex``          — a JAX solver for any *regular*, positively
  1-homogeneous loss of the per-marginal variances, including user-supplied
  callables.  The paper uses CVXPY/ECOS; this container has neither, so we
  exploit the scale-invariance of ``pcost(σ²)·L(Var(σ²))`` to solve the
  unconstrained product objective in log-space with Adam, then rescale so
  the privacy constraint is tight.

The legacy dict/itertools coefficient path survives as ``_coefficients`` /
``legacy_*_sigmas`` — the fp64 reference the property tests and the
planner-bench speedup gate compare against.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from .domain import Clique, Domain, MarginalWorkload, closure, subsets
from .plantable import (BasePlan, PlanTable, plan_table, sov_closed_form)
from .residual import p_coeff, variance_coeff

LossSpec = Union[str, Callable]


@dataclass(eq=False)
class Plan(BasePlan):
    """Output of the selection phase: which base mechanisms to run, at what scale.

    Carried by the PlanTable IR; ``plan.sigmas[A]`` and the legacy accessors
    are thin views over the σ² array (docs/DESIGN.md §9).  ``mu`` is the
    max-variance dual point that produced the plan (None for other
    objectives) — feed it back via ``select_max_variance(..., mu0=...)`` to
    warm-start a re-plan of a structurally similar workload (the D&C
    per-block loop does exactly that, docs/DESIGN.md §12).
    """

    mu: Optional[np.ndarray] = None

    def marginal_variance(self, clique: Clique) -> float:
        """Per-cell variance of the reconstructed marginal on ``clique`` (Thm 4)."""
        return self.table.variance_of(self.sigma, clique)

    def total_variance(self) -> float:
        """Sum over workload marginals of (#cells × per-cell variance)."""
        cells = np.array([self.domain.n_cells(c) for c in self.workload.cliques])
        return float(np.dot(cells, self.variances_array()))

    def rmse(self) -> float:
        """Root mean squared error over all workload cells (paper's RMSE metric)."""
        return math.sqrt(self.total_variance() / self.workload.total_cells())

    def max_variance(self, weights: Optional[Mapping[Clique, float]] = None) -> float:
        wv = self.variances_array()
        if weights is None:
            return float(wv.max())
        w = self.table.weight_vector(weights, default_to_workload=False)
        return float((wv / w).max())

    def marginal_covariance(self, a: Clique, b: Clique) -> float:
        """Aligned-cell covariance between reconstructed marginals A and B."""
        return self.table.cross_covariance(self.sigma, a, b)

    def workload_covariances(self, pairs: Sequence[Tuple[Clique, Clique]]
                             ) -> np.ndarray:
        """Batched cross-marginal covariances: one segment-sum for all pairs."""
        return self.table.cross_covariances(self.sigma, pairs)

    def engine(self, use_kernel=None, precompile: bool = True, dtype=None,
               secure: bool = False, digits: int = 4):
        if secure:
            from repro.engine.discrete_engine import DiscreteEngine
            return DiscreteEngine(self, use_kernel=use_kernel,
                                  precompile=precompile, dtype=dtype,
                                  digits=digits)
        from repro.engine.engine import MarginalEngine
        return MarginalEngine(self, use_kernel=use_kernel,
                              precompile=precompile, dtype=dtype)


# ---------------------------------------------------------------------------
# Legacy dict/itertools coefficient path (fp64 reference; property tests and
# the planner bench compare the IR against these)
# ---------------------------------------------------------------------------

def _coefficients(workload: MarginalWorkload,
                  weights: Optional[Mapping[Clique, float]] = None
                  ) -> Tuple[List[Clique], np.ndarray, np.ndarray]:
    """Closure cliques, pcost coefficients p_A, and SoV coefficients v_A (§6.1)."""
    dom = workload.domain
    cl = closure(workload.cliques)
    index = {c: i for i, c in enumerate(cl)}
    p = np.array([p_coeff(dom, c) for c in cl])
    v = np.zeros(len(cl))
    for wc in workload.cliques:
        imp = float(weights.get(wc, 1.0)) if weights is not None else workload.weight(wc)
        for sub in subsets(wc):
            v[index[sub]] += imp * variance_coeff(dom, sub, wc)
    return cl, p, v


def _variance_matrix(workload: MarginalWorkload, cl: List[Clique]
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """COO (rows → workload idx, cols → closure idx, coef) for Var_A(σ²) (Thm 4)."""
    dom = workload.domain
    index = {c: i for i, c in enumerate(cl)}
    rows, cols, vals = [], [], []
    for wi, wc in enumerate(workload.cliques):
        for sub in subsets(wc):
            rows.append(wi)
            cols.append(index[sub])
            vals.append(variance_coeff(dom, sub, wc))
    return np.array(rows, np.int32), np.array(cols, np.int32), np.array(vals)


def legacy_sov_sigmas(workload: MarginalWorkload, pcost_budget: float = 1.0,
                      weights: Optional[Mapping[Clique, float]] = None
                      ) -> Dict[Clique, float]:
    """Lemma-2 closed form over the dict/itertools coefficients (reference)."""
    cl, p, v = _coefficients(workload, weights)
    sig = sov_closed_form(p, v, pcost_budget)
    return dict(zip(cl, map(float, sig)))


def legacy_maxvar_sigmas(workload: MarginalWorkload, pcost_budget: float = 1.0,
                         weights: Optional[Mapping[Clique, float]] = None,
                         iters: int = 4000, tol: float = 1e-9
                         ) -> Tuple[Dict[Clique, float], float]:
    """Historical host-loop dual ascent (``np.add.at`` per iteration)."""
    dom = workload.domain
    cl = closure(workload.cliques)
    p = np.array([p_coeff(dom, c) for c in cl])
    m = len(workload.cliques)
    cw = np.array([float((weights or {}).get(c, workload.weight(c)))
                   for c in workload.cliques])
    rows, cols, vals = _variance_matrix(workload, cl)
    c = float(pcost_budget)
    mu = np.full(m, 1.0 / m)
    best = None
    for t in range(iters):
        v = np.zeros(len(cl))
        np.add.at(v, cols, vals * (mu / cw)[rows])
        sq = np.sqrt(np.maximum(v, 0.0) * p)
        T = sq.sum() ** 2 / c
        with np.errstate(divide="ignore"):
            u = np.sqrt(T * p / (c * np.maximum(v, 1e-300)))
        var = np.zeros(m)
        np.add.at(var, rows, vals * u[cols])
        var = var / cw
        primal = float(var.max())
        gap = primal - T
        if best is None or primal < best[0]:
            best = (primal, u.copy())
        if gap <= tol * max(primal, 1e-300):
            break
        eta = 2.0 * math.log(max(m, 2)) / (primal * math.sqrt(t + 1.0))
        mu = mu * np.exp(eta * (var - primal))
        mu = np.maximum(mu, 1e-300)
        mu /= mu.sum()
    primal, u = best
    return dict(zip(cl, map(float, u))), primal


# ---------------------------------------------------------------------------
# SoV: Lemma 2 closed form on the IR
# ---------------------------------------------------------------------------

def _route_strategy(strategy: str, workload: MarginalWorkload, objective: str,
                    pcost_budget, weights, blocks, max_block, kw):
    """Resolve the ``strategy`` switch shared by all select entry points.

    Returns a :class:`~repro.core.composite.CompositePlan` when the
    divide-and-conquer route is taken, ``None`` when the caller should run
    the monolithic path.  ``"auto"`` stays monolithic whenever the closure is
    comfortably in-memory (every historical call is bit-for-bit unchanged)
    and switches to D&C only past :data:`AUTO_DNC_NNZ` incidence entries —
    the regime where the monolithic closure would not fit.
    """
    if strategy == "monolithic":
        if blocks is not None or max_block is not None:
            raise ValueError("blocks=/max_block= require strategy='dnc' "
                             "(or 'auto')")
        return None
    if strategy == "auto":
        est_nnz = sum(1 << len(c) for c in workload.cliques)
        if est_nnz <= AUTO_DNC_NNZ and blocks is None and max_block is None:
            return None
    elif strategy != "dnc":
        raise ValueError(f"unknown strategy {strategy!r}")
    from .composite import select_dnc
    return select_dnc(workload, pcost_budget, objective=objective,
                      weights=weights, blocks=blocks, max_block=max_block,
                      **kw)


#: strategy="auto" switches to divide-and-conquer past this estimated
#: closure-incidence size (the d=100 all-<=3-way headline is ~1.3M).
AUTO_DNC_NNZ = 4_000_000


def select_sum_of_variances(workload: MarginalWorkload, pcost_budget: float = 1.0,
                            weights: Optional[Mapping[Clique, float]] = None,
                            table: Optional[PlanTable] = None,
                            strategy: str = "monolithic",
                            blocks=None, max_block=None) -> BasePlan:
    """Closed-form optimum for weighted sum of per-cell variances (Lemma 2).

    Cliques with v_A == 0 (needed for reconstruction completeness but receiving
    zero objective weight) get a vanishing budget sliver, computed overflow-safe
    (see :func:`repro.core.plantable.sov_closed_form`).
    """
    routed = _route_strategy(strategy, workload, "sum_of_variances",
                             pcost_budget, weights, blocks, max_block, {})
    if routed is not None:
        return routed
    table = plan_table(workload) if table is None else table
    v = table.sov_coeffs(weights)
    sig = sov_closed_form(table.p, v, pcost_budget)
    return Plan(table, sig, "sum_of_variances",
                pcost=table.pcost(sig), loss_value=float(np.dot(v, sig)))


# ---------------------------------------------------------------------------
# Max-variance: dual ascent as a device lax.scan over segment-sums
# ---------------------------------------------------------------------------

def _maxvar_eval_fp64(mu, p, rows, cols, vals, cw, c, n, m):
    """Closed-form (primal σ², primal value, dual value) at dual point μ."""
    mu = mu / mu.sum()
    v = np.bincount(cols, weights=vals * (mu / cw)[rows], minlength=n)
    sq = np.sqrt(np.maximum(v, 0.0) * p)
    T = sq.sum() ** 2 / c
    with np.errstate(divide="ignore"):
        u = np.sqrt(T * p / (c * np.maximum(v, 1e-300)))
    var = np.bincount(rows, weights=vals * u[cols], minlength=m) / cw
    return float(var.max()), u, float(T)


def _normalize_mu0(mu0, m) -> np.ndarray:
    """Validate/normalize a warm-start dual point onto the simplex."""
    mu = np.asarray(mu0, np.float64).reshape(-1)
    if mu.shape != (m,):
        raise ValueError(f"mu0 has shape {mu.shape}, workload has {m} "
                         "marginals")
    mu = np.maximum(mu, 1e-300)
    return mu / mu.sum()


def _maxvar_numpy(p, rows, cols, vals, cw, c, iters, tol, n, m, mu0=None):
    """Arrayized host loop: two bincount segment-sums per iteration.

    ``mu0`` warm-starts the dual ascent; the fp64 primal–dual gap certificate
    exits the loop the moment optimality is proven, so a good warm start
    (e.g. the previous block of a D&C sweep) pays for itself immediately.
    """
    mu = np.full(m, 1.0 / m) if mu0 is None else _normalize_mu0(mu0, m)
    best_primal, best_u, best_mu, dual_best = math.inf, None, mu, -math.inf
    logm = 2.0 * math.log(max(m, 2))
    for t in range(iters):
        v = np.bincount(cols, weights=vals * (mu / cw)[rows], minlength=n)
        sq = np.sqrt(np.maximum(v, 0.0) * p)
        T = sq.sum() ** 2 / c
        with np.errstate(divide="ignore"):
            u = np.sqrt(T * p / (c * np.maximum(v, 1e-300)))
        var = np.bincount(rows, weights=vals * u[cols], minlength=m) / cw
        primal = float(var.max())
        dual_best = max(dual_best, float(T))
        if primal < best_primal:
            best_primal, best_u, best_mu = primal, u, mu
        if best_primal - dual_best <= tol * max(best_primal, 1e-300):
            break
        eta = logm / (primal * math.sqrt(t + 1.0))
        mu = mu * np.exp(eta * (var - primal))
        mu = np.maximum(mu, 1e-300)
        mu /= mu.sum()
    return best_u, best_primal, best_mu


@partial(jax.jit, static_argnames=("n", "m", "chunk"))
def _maxvar_run_chunk(mu, bp, bmu, t0, p_j, rows_j, cols_j, vals_j, icw,
                      cc, tiny, logm, *, n, m, chunk):
    """``chunk`` exp-gradient iterations as one ``lax.scan`` on device.

    Module-level and jitted on (shapes, n, m, chunk) only, so repeated
    selections over same-shaped IRs reuse the compilation.
    """
    dt = mu.dtype

    def step(carry, t):
        mu, bp, bmu = carry
        v = jax.ops.segment_sum(vals_j * (mu * icw)[rows_j], cols_j,
                                num_segments=n)
        sq = jnp.sqrt(jnp.maximum(v, 0.0) * p_j)
        T = sq.sum() ** 2 / cc
        u = jnp.sqrt(T * p_j / (cc * jnp.maximum(v, tiny)))
        var = jax.ops.segment_sum(vals_j * u[cols_j], rows_j,
                                  num_segments=m) * icw
        primal = var.max()
        better = primal < bp
        bp2 = jnp.where(better, primal, bp)
        bmu2 = jnp.where(better, mu, bmu)
        eta = logm / (primal * jnp.sqrt(t + 1.0))
        mu2 = mu * jnp.exp(eta * (var - primal))
        mu2 = jnp.maximum(mu2, tiny)
        return (mu2 / mu2.sum(), bp2, bmu2), None

    carry, _ = jax.lax.scan(step, (mu, bp, bmu),
                            jnp.arange(chunk, dtype=dt) + t0)
    return carry


def _maxvar_device(table, cw, c, iters, tol, chunk, mu0=None):
    """Chunked ``lax.scan`` dual ascent: every iteration is two
    ``jax.ops.segment_sum`` contractions over the IR incidence; fp64 host
    checkpoints at chunk boundaries track the best primal and certify the
    primal–dual gap.

    ``mu0`` warm-starts the dual point; a warm start also shrinks the first
    chunk so the gap certificate is consulted early — a re-plan that is
    already (near-)optimal exits after a handful of iterations instead of
    burning the full ``iters`` budget."""
    n, m = table.n, table.m
    p, rows, cols, vals = table.p, table.inc_rows, table.inc_cols, table.inc_vals
    p_j, rows_j, cols_j, vals_j = table.device_arrays()
    dt = p_j.dtype
    icw = jnp.asarray(1.0 / cw, dt)
    tiny = float(np.finfo(np.dtype(dt.name)).tiny)
    logm = 2.0 * math.log(max(m, 2))
    cc = float(c)

    mu_h = np.full(m, 1.0 / m) if mu0 is None else _normalize_mu0(mu0, m)
    mu_j = jnp.asarray(mu_h, dt)
    bp_j = jnp.asarray(np.inf, dt)
    bmu_j = mu_j
    best_primal, best_u, best_mu, dual_best = math.inf, None, mu_h, -math.inf
    t0 = 0
    first_chunk = min(chunk, 25) if mu0 is not None else chunk
    while t0 < iters:
        # Exact iteration count: the tail chunk shrinks instead of overrunning
        # (at most one extra compilation per distinct remainder size).
        k = min(first_chunk if t0 == 0 else chunk, iters - t0)
        mu_j, bp_j, bmu_j = _maxvar_run_chunk(
            mu_j, bp_j, bmu_j, float(t0), p_j, rows_j, cols_j, vals_j, icw,
            cc, tiny, logm, n=n, m=m, chunk=k)
        t0 += k
        for cand in (np.asarray(mu_j, np.float64),
                     np.asarray(bmu_j, np.float64)):
            primal, u, T = _maxvar_eval_fp64(cand, p, rows, cols, vals,
                                             cw, cc, n, m)
            dual_best = max(dual_best, T)
            if primal < best_primal:
                best_primal, best_u, best_mu = primal, u, cand
        if best_primal - dual_best <= tol * max(best_primal, 1e-300):
            break
    return best_u, best_primal, best_mu


def select_max_variance(workload: MarginalWorkload, pcost_budget: float = 1.0,
                        weights: Optional[Mapping[Clique, float]] = None,
                        iters: int = 4000, tol: float = 1e-9,
                        table: Optional[PlanTable] = None,
                        backend: str = "auto", chunk: int = 250,
                        mu0: Optional[np.ndarray] = None,
                        strategy: str = "monolithic",
                        blocks=None, max_block=None) -> BasePlan:
    """Exact max-variance selection via the concave dual (beyond-paper solver).

    min_σ max_A Var_A/c_A  s.t. pcost ≤ c  has Lagrangian dual
        max_{μ ∈ Δ} g(μ),   g(μ) = (Σ_{A'} sqrt(p_{A'} v_{A'}(μ)))² / c
    where v(μ) are the Lemma-2 SoV coefficients under workload weights μ/c_A:
    the inner minimization *is* the closed form of Lemma 2.  Exponentiated-
    gradient ascent on μ (∇g = per-marginal variances of the closed-form
    solution) runs as segment-sums over the IR incidence — a chunked
    ``lax.scan`` over ``jax.ops.segment_sum`` on accelerators, a vectorized
    ``np.bincount`` loop on CPU (XLA's CPU scatter is ~100× slower than
    bincount, same story as interpret-mode Pallas; ``backend='auto'``
    resolves per jax backend like the kernel paths do) — and optimality is
    certified by the primal–dual gap.

    ``mu0`` warm-starts the dual ascent from a previous solution's dual point
    (``plan.mu``); the gap certificate then exits as soon as optimality is
    proven instead of running the full ``iters`` budget.
    """
    routed = _route_strategy(strategy, workload, "max_variance", pcost_budget,
                             weights, blocks, max_block,
                             dict(iters=iters, tol=tol, backend=backend,
                                  chunk=chunk))
    if routed is not None:
        return routed
    table = plan_table(workload) if table is None else table
    cw = table.weight_vector(weights, default_to_workload=True)
    c = float(pcost_budget)
    if backend == "auto":
        backend = "device" if (jax.default_backend() != "cpu"
                               and table.inc_vals.size >= 20_000) else "numpy"
    if backend == "device":
        u, primal, mu = _maxvar_device(table, cw, c, iters, tol, chunk, mu0)
    elif backend == "numpy":
        u, primal, mu = _maxvar_numpy(table.p, table.inc_rows, table.inc_cols,
                                      table.inc_vals, cw, c, iters, tol,
                                      table.n, table.m, mu0)
    else:
        raise ValueError(backend)
    return Plan(table, u, "max_variance",
                pcost=table.pcost(u), loss_value=primal, mu=mu)


# ---------------------------------------------------------------------------
# Generic 1-homogeneous convex losses (built-in or user-supplied callables)
# ---------------------------------------------------------------------------

def select_convex(workload: MarginalWorkload, pcost_budget: float = 1.0,
                  loss: LossSpec = "max_variance",
                  weights: Optional[Mapping[Clique, float]] = None,
                  steps: int = 3000, lr: float = 0.05, seed: int = 0,
                  table: Optional[PlanTable] = None,
                  strategy: str = "monolithic",
                  blocks=None, max_block=None) -> BasePlan:
    """Solve privacy-constrained selection for a regular 1-homogeneous loss.

    ``loss`` is ``'max_variance'`` (max_A Var_A / c_A), ``'sum_of_variances'``
    (sanity path), or any positively 1-homogeneous jnp-traceable callable
    ``L(var)`` of the weight-normalized per-marginal variance vector
    ``var = Var(σ²)/c`` (shape (m,), strictly positive).  The final
    ``loss_value`` is computed before the plan is constructed — in fp64 for
    the built-in losses, in the callable's own precision otherwise.
    """
    routed = _route_strategy(strategy, workload, "convex", pcost_budget,
                             weights, blocks, max_block,
                             dict(loss=loss, steps=steps, lr=lr, seed=seed))
    if routed is not None:
        return routed
    table = plan_table(workload) if table is None else table
    v_lin = table.sov_coeffs(weights)       # historical default-1.0 weighting
    w = table.weight_vector(weights, default_to_workload=True)
    m = table.m

    p_j, rows_j, cols_j, vals_j = table.device_arrays()
    w_j = jnp.asarray(w, p_j.dtype)
    v_lin_j = jnp.asarray(v_lin, p_j.dtype)

    def variances(u):
        return jax.ops.segment_sum(vals_j * u[cols_j], rows_j, num_segments=m)

    def loss_fn(u, tau):
        var = variances(u) / w_j
        if callable(loss):
            L = loss(var)
        elif loss == "max_variance":
            L = tau * jax.scipy.special.logsumexp(var / tau)
        elif loss == "sum_of_variances":
            L = jnp.dot(v_lin_j, u)
        else:
            raise ValueError(loss)
        P = jnp.sum(p_j / u)
        return jnp.log(P) + jnp.log(L)  # scale-invariant product objective

    # Init from the SoV closed form (good warm start).
    warm = select_sum_of_variances(workload, pcost_budget, weights, table=table)
    theta0 = jnp.log(jnp.asarray(np.maximum(warm.sigma, 1e-12), p_j.dtype))
    tau_scale = float(np.mean(table.variances(warm.sigma) / w))

    @jax.jit
    def run(theta0):
        def adam_step(carry, i):
            theta, mom, vel = carry
            tau = 10.0 ** (-3.0 * i / steps) * tau_scale
            g = jax.grad(lambda t: loss_fn(jnp.exp(t), tau))(theta)
            mom = 0.9 * mom + 0.1 * g
            vel = 0.999 * vel + 0.001 * g * g
            mh = mom / (1 - 0.9 ** (i + 1.0))
            vh = vel / (1 - 0.999 ** (i + 1.0))
            theta = theta - lr * mh / (jnp.sqrt(vh) + 1e-9)
            return (theta, mom, vel), None

        (theta, _, _), _ = jax.lax.scan(
            adam_step, (theta0, jnp.zeros_like(theta0), jnp.zeros_like(theta0)),
            jnp.arange(steps))
        return theta

    theta = np.asarray(run(theta0), dtype=np.float64)
    u = np.exp(theta)
    # Rescale so pcost is exactly the budget (tight at the optimum).
    u = u * (table.pcost(u) / float(pcost_budget))
    # fp64 loss at the solution — set at construction, never patched after.
    var64 = table.variances(u) / w
    if callable(loss):
        loss_value = float(np.asarray(loss(var64)))
        objective = getattr(loss, "__name__", "convex")
    elif loss == "max_variance":
        loss_value = float(var64.max())
        objective = loss
    else:
        loss_value = float(np.dot(v_lin, u))
        objective = loss
    return Plan(table, u, objective, pcost=table.pcost(u),
                loss_value=loss_value)


def select(workload: MarginalWorkload, pcost_budget: float = 1.0,
           objective: str = "sum_of_variances",
           weights: Optional[Mapping[Clique, float]] = None,
           loss: Optional[LossSpec] = None, strategy: str = "auto",
           **kw) -> BasePlan:
    """Dispatch on objective: sov | maxvar | convex (user losses welcome).

    ``objective='convex'`` routes to :func:`select_convex`; pass the loss via
    ``loss=`` (a name or a positively 1-homogeneous callable).  A callable
    ``objective`` is shorthand for the same thing.

    ``strategy`` picks the planning route (docs/DESIGN.md §12):
    ``"monolithic"`` builds one PlanTable over the whole closure (the
    historical path), ``"dnc"`` partitions the attributes and plans each
    block independently (:func:`repro.core.composite.select_dnc`, returning a
    :class:`~repro.core.composite.CompositePlan`), and the default
    ``"auto"`` stays monolithic until the closure would outgrow memory
    (:data:`AUTO_DNC_NNZ` incidence entries) — so every small workload keeps
    its exact historical behavior while d=500-scale workloads plan at all.
    ``blocks=`` / ``max_block=`` (forwarded to the partitioner) force the
    D&C route when given.
    """
    if callable(objective):
        return select_convex(workload, pcost_budget, loss=objective,
                             weights=weights, strategy=strategy, **kw)
    if objective in ("sum_of_variances", "sov", "rmse"):
        return select_sum_of_variances(workload, pcost_budget, weights,
                                       strategy=strategy, **kw)
    if objective in ("max_variance", "maxvar"):
        return select_max_variance(workload, pcost_budget, weights,
                                   strategy=strategy, **kw)
    if objective == "convex":
        return select_convex(workload, pcost_budget,
                             loss="max_variance" if loss is None else loss,
                             weights=weights, strategy=strategy, **kw)
    raise ValueError(objective)


def select_utility_constrained(workload: MarginalWorkload, loss_budget: float,
                               objective: str = "sum_of_variances",
                               weights: Optional[Mapping[Clique, float]] = None,
                               **kw) -> Plan:
    """Equation 2 of the paper: minimize pcost subject to loss ≤ γ.

    Both paper objectives are positively 1-homogeneous in the σ², and pcost is
    (−1)-homogeneous, so the Eq.-1 solution at any budget rescales exactly onto
    the Eq.-2 constraint:  if Plan(c=1) attains loss L₁, then scaling every
    σ²_A by L₁/γ attains loss γ at pcost L₁/γ — and this is optimal, since a
    cheaper mechanism meeting the loss bound would rescale back to beat the
    Eq.-1 optimum.
    """
    base = select(workload, pcost_budget=1.0, objective=objective,
                  weights=weights, **kw)
    if objective in ("sum_of_variances", "sov", "rmse"):
        w = base.table.weight_vector(weights, default_to_workload=True)
        l1 = float(np.dot(w, base.variances_array()))
    else:
        l1 = base.max_variance(weights)
    scale = float(loss_budget) / l1          # loss is 1-homogeneous in σ²
    return Plan(base.table, base.sigma * scale,
                base.objective + "_utility_constrained",
                pcost=base.pcost / scale, loss_value=float(loss_budget))
