r"""Reconstruction phase (Algorithm 2) and closed-form variances (Theorem 4).

Reconstruction of the marginal on A uses only the noisy residual answers
ω_{A'} for A' ⊆ A, independently of every other attribute and marginal — the
marginals can therefore be reconstructed in parallel, on demand, and they are
mutually consistent.  The per-axis factors of U_{A←A'} are:

    Sub_{n_i}^†     for i ∈ A'          (Lemma 1 closed form)
    (1/n_i)·1       for i ∈ A \ A'      (column vector)
    [1]             for i ∉ A           (axis absent)
"""
from __future__ import annotations

import math
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

import jax.numpy as jnp

from .domain import Clique, Domain, subsets
from .kron import kron_matvec, kron_matvec_np
from .mechanism import Measurement
from .residual import sub_pinv, variance_coeff
from .select import Plan


def _u_factors(domain: Domain, clique: Clique, sub_clique: Clique):
    """Per-axis factors and input dims of U_{A←A'} restricted to A's axes."""
    sc = set(sub_clique)
    factors, in_dims = [], []
    for i in clique:
        n = domain.attributes[i].size
        if i in sc:
            factors.append(sub_pinv(n))
            in_dims.append(n - 1)
        else:
            factors.append(np.full((n, 1), 1.0 / n))
            in_dims.append(1)
    return factors, in_dims


def u_chain_factors(domain: Domain, clique: Clique) -> List[np.ndarray]:
    """Per-axis factors T_i = [ Sub_{n_i}^† | (1/n_i)·1 ]  (n_i × n_i).

    The key identity behind batched reconstruction (docs/DESIGN.md §5): for
    every A' ⊆ A, U_{A←A'} ω_{A'} equals (⊗_{i∈A} T_i) e_{A'}, where e_{A'}
    embeds ω_{A'} into the (n_i)_{i∈A} tensor at axis-i slots 0..n_i-2 when
    i ∈ A' and slot n_i-1 otherwise.  Distinct subsets occupy *disjoint*
    slot regions, so Algorithm 2's sum over 2^|A| subset matvecs collapses to
    ONE Kronecker chain applied to the sum of embeddings.
    """
    out = []
    for i in clique:
        n = domain.attributes[i].size
        out.append(np.hstack([sub_pinv(n), np.full((n, 1), 1.0 / n)]))
    return out


def subset_slot_region(clique: Clique, sub_clique: Clique,
                       slot_dims: Sequence[int]):
    """(region, shape) of subset A' in the merged-chain slot tensor.

    Axis i has ``slot_dims[i]`` slots: the measured part occupies slots
    ``0..slot_dims[i]−2`` when i ∈ A', the marginalized part the single last
    slot otherwise.  Distinct subsets occupy disjoint regions — the identity
    behind merged reconstruction (§5), shared by the plain path
    (slot_dims = n_i), the RP+ path (slot_dims = r_i+1,
    ``core/plus.py``) and the batched engine embedding
    (``engine/plus_engine.py``): one definition, three consumers.
    """
    sc = set(sub_clique)
    region = tuple(slice(0, r - 1) if i in sc else slice(r - 1, r)
                   for i, r in zip(clique, slot_dims))
    shape = tuple(r - 1 if i in sc else 1 for i, r in zip(clique, slot_dims))
    return region, shape


def embed_subset_answers(plan: Plan, measurements: Mapping[Clique, Measurement],
                         clique: Clique, dtype=np.float64) -> np.ndarray:
    """Sum of subset embeddings Σ_{A'⊆A} e_{A'} — input of the merged U-chain."""
    sizes = plan.domain.clique_sizes(clique)
    t = np.zeros(sizes, dtype=dtype)
    for sub in subsets(clique):
        region, shape = subset_slot_region(clique, sub, sizes)
        t[region] = np.asarray(measurements[sub].omega, dtype=dtype).reshape(shape)
    return t


def reconstruct_marginal(plan: Plan, measurements: Mapping[Clique, Measurement],
                         clique: Clique, xp=np) -> np.ndarray:
    """Unbiased noisy answer to the marginal on ``clique`` (Algorithm 2).

    xp: np for the float64 host path, jnp for the device path.
    """
    n_cells = plan.domain.n_cells(clique)
    q = None
    matvec = kron_matvec_np if xp is np else kron_matvec
    for sub in subsets(clique):
        omega = measurements[sub].omega
        if not clique:
            term = xp.asarray(omega, dtype=float).reshape(-1)
        else:
            factors, in_dims = _u_factors(plan.domain, clique, sub)
            term = matvec(factors, xp.asarray(omega).reshape(-1), in_dims)
        q = term if q is None else q + term
    assert q is not None and q.shape[0] == n_cells
    return q


def reconstruct_marginal_fast(plan: Plan, measurements: Mapping[Clique, Measurement],
                              clique: Clique, use_kernel: bool = False,
                              xp=np) -> np.ndarray:
    """Algorithm 2 as ONE Kronecker chain instead of 2^|A| subset matvecs.

    Embeds all subset answers into disjoint slots of one (n_i)_{i∈A} tensor
    (see :func:`u_chain_factors`) and applies the merged chain ⊗ T_i once —
    on the fused Pallas path when ``use_kernel``.
    """
    if not clique:
        return xp.asarray(measurements[()].omega, dtype=float).reshape(-1)
    sizes = plan.domain.clique_sizes(clique)
    t = embed_subset_answers(plan, measurements, clique)
    factors = u_chain_factors(plan.domain, clique)
    if use_kernel:
        from repro.kernels.kron_matvec.fused import fused_chain_matvec
        # Reconstruction carries no noise lanes: a tuned narrow compute dtype
        # (fp32 accumulation) may serve it (docs/DESIGN.md §14).
        return np.asarray(fused_chain_matvec(factors, t.reshape(-1), sizes,
                                             allow_narrow=True))
    matvec = kron_matvec_np if xp is np else kron_matvec
    return matvec(factors, t.reshape(-1), sizes)


def reconstruct_all(plan: Plan, measurements: Mapping[Clique, Measurement],
                    xp=np) -> Dict[Clique, np.ndarray]:
    return {c: reconstruct_marginal(plan, measurements, c, xp) for c in plan.workload.cliques}


def reconstruct_all_batched(plan: Plan, measurements: Mapping[Clique, Measurement],
                            cliques: Optional[Sequence[Clique]] = None,
                            use_kernel: Optional[bool] = None
                            ) -> Dict[Clique, np.ndarray]:
    """Batched Algorithm 2: same-signature marginals share one kernel chain.

    Marginals are grouped by attribute-size signature (they share the merged
    U-chain ⊗ T_i exactly), their embedded subset-answer tensors are stacked
    into the batch axis, and each group runs as a single fused chain
    (docs/DESIGN.md §5) — 2^|A| × #cliques matvecs collapse to one pallas_call
    per signature.

    ``use_kernel=None`` resolves per backend: the fused Pallas chain on TPU,
    the batched jnp path elsewhere (interpret-mode Pallas is a correctness
    vehicle, not a CPU fast path — see benchmarks/kernels_bench.py).
    """
    from .mechanism import signature_groups
    from .kron import kron_matvec_batched
    if use_kernel is None:
        from repro.kernels.kron_matvec._layout import interpret_default
        use_kernel = not interpret_default()
    cliques = list(plan.workload.cliques if cliques is None else cliques)
    out: Dict[Clique, np.ndarray] = {}
    for sizes, group in signature_groups(plan.domain, cliques).items():
        if not sizes:
            for c in group:
                out[c] = np.asarray(measurements[()].omega, dtype=float).reshape(-1)
            continue
        x = np.stack([embed_subset_answers(plan, measurements, c).reshape(-1)
                      for c in group])
        factors = u_chain_factors(plan.domain, group[0])
        if use_kernel:
            from repro.kernels.kron_matvec.fused import fused_chain_matvec
            y = np.asarray(fused_chain_matvec(factors, x, sizes,
                                              allow_narrow=True))
        else:
            y = np.asarray(kron_matvec_batched(factors, x, sizes))
        for i, c in enumerate(group):
            out[c] = y[i]
    return out


def marginal_variance(plan: Plan, clique: Clique) -> float:
    """Per-cell variance of the reconstructed marginal (Theorem 4) — all cells equal."""
    return plan.marginal_variance(clique)


def marginal_covariance_dense(plan: Plan, clique: Clique) -> np.ndarray:
    """Full covariance matrix of the reconstructed marginal on ``clique``.

    Cov = Σ_{A'⊆A} σ²_{A'} · ⊗_{i∈A} G_i   with
        G_i = Sub† (Sub Subᵀ) Sub†ᵀ   for i ∈ A'
        G_i = (1/n²) 11ᵀ              for i ∈ A \\ A'

    Materializes the n_cells × n_cells matrix — small cliques only.  The paper
    emphasises that per-cell variances and within-marginal covariances are
    available in closed form; this is that closed form, used for CI tests.
    """
    from .kron import kron_expand
    from .residual import sub_gram, sub_pinv

    dom = plan.domain
    n = dom.n_cells(clique)
    cov = np.zeros((n, n))
    for sub in subsets(clique):
        facs = []
        for i in clique:
            sz = dom.attributes[i].size
            if i in set(sub):
                sp = sub_pinv(sz)
                facs.append(sp @ sub_gram(sz) @ sp.T)
            else:
                facs.append(np.full((sz, sz), 1.0 / sz ** 2))
        cov += plan.sigmas[sub] * (kron_expand(facs) if facs else np.ones((1, 1)))
    return cov


def cross_marginal_covariance_dense(plan: Plan, a: Clique, b: Clique
                                    ) -> np.ndarray:
    """Full cross-covariance matrix of reconstructed marginals A and B.

    Only the measurements on shared subsets A' ⊆ A∩B correlate the two
    reconstructions:

        Cov(Q̂_A, Q̂_B) = Σ_{A'⊆A∩B} σ²_{A'} · U_{A←A'} H_{A'} H_{A'}ᵀ U_{B←A'}ᵀ

    with H_{A'} = ⊗_{i∈A'} Sub_{n_i}.  Materializes n_cells(A) × n_cells(B) —
    small cliques only; the fp64 oracle behind the IR's aligned-cell
    ``cross_covariance`` (docs/DESIGN.md §9).
    """
    from .kron import kron_expand
    from .residual import sub_matrix

    dom = plan.domain
    inter = tuple(sorted(set(a) & set(b)))
    cov = np.zeros((dom.n_cells(a), dom.n_cells(b)))
    for sub in subsets(inter):
        ua = kron_expand(_u_factors(dom, a, sub)[0]) if a else np.ones((1, 1))
        ub = kron_expand(_u_factors(dom, b, sub)[0]) if b else np.ones((1, 1))
        h = kron_expand([sub_matrix(dom.attributes[i].size) for i in sub]) \
            if sub else np.ones((1, 1))
        cov += plan.sigmas[sub] * ua @ h @ h.T @ ub.T
    return cov
