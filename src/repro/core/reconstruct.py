r"""Reconstruction phase (Algorithm 2) and closed-form variances (Theorem 4).

Reconstruction of the marginal on A uses only the noisy residual answers
ω_{A'} for A' ⊆ A, independently of every other attribute and marginal — the
marginals can therefore be reconstructed in parallel, on demand, and they are
mutually consistent.  The per-axis factors of U_{A←A'} are:

    Sub_{n_i}^†     for i ∈ A'          (Lemma 1 closed form)
    (1/n_i)·1       for i ∈ A \ A'      (column vector)
    [1]             for i ∉ A           (axis absent)
"""
from __future__ import annotations

import math
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

import jax.numpy as jnp

from .domain import Clique, Domain, subsets
from .kron import kron_matvec, kron_matvec_np
from .mechanism import Measurement
from .residual import sub_pinv, variance_coeff
from .select import Plan


def _u_factors(domain: Domain, clique: Clique, sub_clique: Clique):
    """Per-axis factors and input dims of U_{A←A'} restricted to A's axes."""
    sc = set(sub_clique)
    factors, in_dims = [], []
    for i in clique:
        n = domain.attributes[i].size
        if i in sc:
            factors.append(sub_pinv(n))
            in_dims.append(n - 1)
        else:
            factors.append(np.full((n, 1), 1.0 / n))
            in_dims.append(1)
    return factors, in_dims


def reconstruct_marginal(plan: Plan, measurements: Mapping[Clique, Measurement],
                         clique: Clique, xp=np) -> np.ndarray:
    """Unbiased noisy answer to the marginal on ``clique`` (Algorithm 2).

    xp: np for the float64 host path, jnp for the device path.
    """
    n_cells = plan.domain.n_cells(clique)
    q = None
    matvec = kron_matvec_np if xp is np else kron_matvec
    for sub in subsets(clique):
        omega = measurements[sub].omega
        if not clique:
            term = xp.asarray(omega, dtype=float).reshape(-1)
        else:
            factors, in_dims = _u_factors(plan.domain, clique, sub)
            term = matvec(factors, xp.asarray(omega).reshape(-1), in_dims)
        q = term if q is None else q + term
    assert q is not None and q.shape[0] == n_cells
    return q


def reconstruct_all(plan: Plan, measurements: Mapping[Clique, Measurement],
                    xp=np) -> Dict[Clique, np.ndarray]:
    return {c: reconstruct_marginal(plan, measurements, c, xp) for c in plan.workload.cliques}


def marginal_variance(plan: Plan, clique: Clique) -> float:
    """Per-cell variance of the reconstructed marginal (Theorem 4) — all cells equal."""
    return plan.marginal_variance(clique)


def marginal_covariance_dense(plan: Plan, clique: Clique) -> np.ndarray:
    """Full covariance matrix of the reconstructed marginal on ``clique``.

    Cov = Σ_{A'⊆A} σ²_{A'} · ⊗_{i∈A} G_i   with
        G_i = Sub† (Sub Subᵀ) Sub†ᵀ   for i ∈ A'
        G_i = (1/n²) 11ᵀ              for i ∈ A \\ A'

    Materializes the n_cells × n_cells matrix — small cliques only.  The paper
    emphasises that per-cell variances and within-marginal covariances are
    available in closed form; this is that closed form, used for CI tests.
    """
    from .kron import kron_expand
    from .residual import sub_gram, sub_pinv

    dom = plan.domain
    n = dom.n_cells(clique)
    cov = np.zeros((n, n))
    for sub in subsets(clique):
        facs = []
        for i in clique:
            sz = dom.attributes[i].size
            if i in set(sub):
                sp = sub_pinv(sz)
                facs.append(sp @ sub_gram(sz) @ sp.T)
            else:
                facs.append(np.full((sz, sz), 1.0 / sz ** 2))
        cov += plan.sigmas[sub] * (kron_expand(facs) if facs else np.ones((1, 1)))
    return cov
