"""Measurement phase: run the base mechanisms  M_A(x; σ²_A) = R_A x + N(0, σ²_A Σ_A).

Implements Algorithm 1 of the paper: the residual answer is computed from the
*marginal table* on A (never from the full data vector):

    v  = Q_A x                      (marginal on A, shape Π_{i∈A} n_i)
    H  = ⊗_{i∈A} Sub_{n_i}          (implicit Kronecker factors)
    ω  = H v + σ_A · H z,   z ~ N(0, I)

so the noise H z has exactly the covariance σ²_A Σ_A = σ²_A H Hᵀ.

The device path (`measure`) uses jnp + the Pallas kron kernels when enabled;
`measure_np` is the float64 host oracle used by tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from .domain import Clique, Domain
from .kron import kron_matvec, kron_matvec_batched, kron_matvec_np
from .residual import p_coeff, sub_matrix
from .select import Plan


@dataclass
class Measurement:
    clique: Clique
    omega: np.ndarray          # noisy residual answer, shape Π_{i∈A}(n_i - 1)
    sigma2: float


def pcost_of_plan(plan: Plan) -> float:
    """Total privacy cost Σ_A p_A / σ²_A (Thm 3)."""
    return sum(p_coeff(plan.domain, c) / s for c, s in plan.sigmas.items())


def _clique_dims(domain: Domain, clique: Clique) -> List[int]:
    return [domain.attributes[i].size for i in clique]


def residual_answer(domain: Domain, clique: Clique, marginal: jnp.ndarray,
                    use_kernel: bool = False) -> jnp.ndarray:
    """H v — the exact residual query answer from the marginal table on ``clique``."""
    dims = _clique_dims(domain, clique)
    if not clique:
        return jnp.asarray(marginal).reshape(-1)
    factors = [sub_matrix(n) for n in dims]
    if use_kernel:
        from repro.kernels.kron_matvec.ops import kron_matvec_kernel
        return kron_matvec_kernel(factors, jnp.asarray(marginal), dims)
    return kron_matvec(factors, jnp.asarray(marginal), dims)


def signature_groups(domain: Domain, cliques: Sequence[Clique],
                     axis_key=None) -> Dict[tuple, List[Clique]]:
    """Group cliques by per-axis signature (docs/DESIGN.md §4, §8).

    ``axis_key(i)`` maps an attribute index to a hashable per-axis token; the
    default is the attribute size, under which cliques with equal signatures
    share the exact same Kronecker factor chain ``⊗_i Sub_{n_i}`` (the
    plain-marginal chain is fully determined by the size).  ResidualPlanner+
    passes a token that also carries the per-attribute ``(Sub_i, Γ_i)`` factor
    shapes and values (``plus_axis_token`` in ``core/plus.py``), since
    Γ_i ≠ Sub_i for non-identity bases and equal sizes no longer imply equal
    chains.  Cliques in one group stack into the batch axis of a single kernel
    chain.  Insertion order preserves the input clique order within each group.
    """
    from collections import defaultdict
    if axis_key is None:
        axis_key = lambda i: domain.attributes[i].size  # noqa: E731
    groups: Dict[tuple, List[Clique]] = defaultdict(list)
    for clique in cliques:
        groups[tuple(axis_key(i) for i in clique)].append(clique)
    return dict(groups)


def noise_dtype():
    """Default dtype for Gaussian noise draws: float64 iff jax x64 is enabled.

    Every measurement path (core, engine, sharded) threads its noise dtype
    from here unless explicitly overridden, so device and host draws agree.
    """
    return jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32


_noise_dtype = noise_dtype   # backward-compat alias


def measure(plan: Plan, marginals: Mapping[Clique, jnp.ndarray],
            key: jax.Array, use_kernel: bool = False,
            batched: bool = True, dtype=None) -> Dict[Clique, Measurement]:
    """Run every base mechanism in the plan (Algorithm 1, continuous Gaussian).

    ``marginals[A]`` must hold the exact marginal table for every A in the
    plan's closure (flattened or tensor shaped).  Base mechanisms are
    independent; each consumes its own fold of ``key`` — the fold order is
    fixed by ``plan.cliques`` so batched and loop execution draw identical
    noise.

    ``batched=True`` (default) groups cliques by attribute-size signature and
    stacks all ``[v; z]`` pairs of a group into the batch axis of ONE kernel
    chain per group (fused Pallas chain when ``use_kernel``, batched jnp
    otherwise) instead of launching one chain per clique.  ``batched=False``
    keeps the historical per-clique loop (oracle / benchmark baseline).

    ``dtype`` governs the noise draws; ``None`` resolves to
    :func:`noise_dtype` (float64 under jax x64).
    """
    dtype = _noise_dtype() if dtype is None else dtype
    keys = jax.random.split(key, len(plan.cliques))
    if not batched:
        return _measure_loop(plan, marginals, dict(zip(plan.cliques, keys)),
                             use_kernel, dtype)

    out: Dict[Clique, Measurement] = {}
    pos = {c: i for i, c in enumerate(plan.cliques)}
    for dims, cliques in signature_groups(plan.domain, plan.cliques).items():
        m = int(np.prod(dims)) if dims else 1
        g = len(cliques)
        vs = []
        for c in cliques:
            v = jnp.asarray(marginals[c]).reshape(-1)
            if v.shape[0] != m:
                raise ValueError(f"marginal for {c} has {v.shape[0]} cells, want {m}")
            vs.append(v)
        # One vectorized draw per group (bit-identical to the per-clique
        # loop: vmapped threefry matches per-key normal draws exactly).
        z = jax.vmap(lambda k: jax.random.normal(k, (m,), dtype=dtype))(
            keys[jnp.asarray([pos[c] for c in cliques])])
        sig = jnp.asarray([math.sqrt(plan.sigmas[c]) for c in cliques])[:, None]
        if not dims:
            om = jnp.stack(vs) + sig * z
        else:
            x = jnp.concatenate([jnp.stack(vs), z], axis=0)   # (2g, m)
            factors = [sub_matrix(n) for n in dims]
            if use_kernel:
                from repro.kernels.kron_matvec.fused import fused_chain_matvec
                y = fused_chain_matvec(factors, x, dims)
            else:
                y = kron_matvec_batched(factors, x, dims)
            om = y[:g] + sig * y[g:]
        for i, c in enumerate(cliques):
            out[c] = Measurement(c, np.asarray(om[i]), plan.sigmas[c])
    return out


def _measure_loop(plan: Plan, marginals: Mapping[Clique, jnp.ndarray],
                  keymap: Mapping[Clique, jax.Array],
                  use_kernel: bool, dtype=None) -> Dict[Clique, Measurement]:
    """Historical per-clique device loop — one chain per clique (bench baseline)."""
    out: Dict[Clique, Measurement] = {}
    dtype = _noise_dtype() if dtype is None else dtype
    for clique in plan.cliques:
        dims = _clique_dims(plan.domain, clique)
        v = jnp.asarray(marginals[clique]).reshape(-1)
        m = int(np.prod(dims)) if clique else 1
        if v.shape[0] != m:
            raise ValueError(f"marginal for {clique} has {v.shape[0]} cells, want {m}")
        sigma = math.sqrt(plan.sigmas[clique])
        z = jax.random.normal(keymap[clique], (m,), dtype=dtype)
        hv = residual_answer(plan.domain, clique, v, use_kernel)
        hz = residual_answer(plan.domain, clique, z, use_kernel)
        out[clique] = Measurement(clique, np.asarray(hv + sigma * hz), plan.sigmas[clique])
    return out


def measure_np(plan: Plan, marginals: Mapping[Clique, np.ndarray],
               rng: np.random.Generator) -> Dict[Clique, Measurement]:
    """Host float64 oracle of `measure` (tests, tiny problems)."""
    out: Dict[Clique, Measurement] = {}
    for clique in plan.cliques:
        dims = _clique_dims(plan.domain, clique)
        v = np.asarray(marginals[clique], dtype=np.float64).reshape(-1)
        if not clique:
            out[clique] = Measurement(clique, v + math.sqrt(plan.sigmas[clique])
                                      * rng.standard_normal(1), plan.sigmas[clique])
            continue
        factors = [sub_matrix(n) for n in dims]
        z = rng.standard_normal(int(np.prod(dims)))
        hv = kron_matvec_np(factors, v, dims)
        hz = kron_matvec_np(factors, z, dims)
        out[clique] = Measurement(clique, hv + math.sqrt(plan.sigmas[clique]) * hz,
                                  plan.sigmas[clique])
    return out


def measure_np_batched(plan: Plan, marginals: Mapping[Clique, np.ndarray],
                       rng: np.random.Generator, chunk: int = 64
                       ) -> Dict[Clique, Measurement]:
    """Batched measurement (§Perf iteration M1/M2): base mechanisms with the
    same attribute-size signature share stacked kron-matvecs, processed in
    cache-resident chunks.

    Measured on this container (Synth-10^d, all ≤3-way): 5.1× (d=20) and
    4.1× (d=50) over the per-clique loop at chunk=64; a single monolithic
    batch is only ~1.2× (refuted hypothesis M1 — the 300 MB stack thrashes
    cache; see EXPERIMENTS.md §Perf).  The batch axis is the same "left"
    dimension the Pallas kernel tiles on TPU.
    """
    out: Dict[Clique, Measurement] = {}
    for dims, cliques in signature_groups(plan.domain, plan.cliques).items():
        m = int(np.prod(dims)) if dims else 1
        for s0 in range(0, len(cliques), chunk):
            cs = cliques[s0:s0 + chunk]
            g = len(cs)
            v = np.stack([np.asarray(marginals[c], dtype=np.float64).reshape(-1)
                          for c in cs])
            z = rng.standard_normal((g, m))
            if dims:
                x = np.concatenate([v, z], axis=0).reshape((2 * g,) + dims)
                for axis, n in enumerate(dims):
                    s = sub_matrix(n)
                    x = np.moveaxis(
                        np.tensordot(s, np.moveaxis(x, axis + 1, 0),
                                     axes=([1], [0])), 0, axis + 1)
                x = x.reshape(2 * g, -1)
                hv, hz = x[:g], x[g:]
            else:
                hv, hz = v, z
            sig = np.array([math.sqrt(plan.sigmas[c]) for c in cs])[:, None]
            om = hv + sig * hz
            for i, c in enumerate(cs):
                out[c] = Measurement(c, om[i], plan.sigmas[c])
    return out


def exact_marginals_from_x(domain: Domain, cliques: Sequence[Clique],
                           x: np.ndarray) -> Dict[Clique, np.ndarray]:
    """Marginal tables Q_A x from a full contingency vector (small domains/tests)."""
    x = np.asarray(x, dtype=np.float64).reshape(domain.sizes)
    out = {}
    for c in cliques:
        keep = set(c)
        axes = tuple(i for i in range(domain.n_attrs) if i not in keep)
        out[c] = x.sum(axis=axes).reshape(-1)
    return out
