r"""Numerically secure measurement with discrete Gaussian noise (Section 5, Alg 3).

The pitfall (Example 2): naively swapping the correlated continuous noise
``N(0, σ²Σ_A)`` for independent discrete Gaussians costs up to 2^k in privacy
for k-way marginals.  The fix rotates the base mechanism into an equivalent
*integer-query, independent-noise* mechanism:

    Y   = ⊗_i |Att_i|·Sub_i^†
    Ξ   = Y R_A              (integer matrix;  Ξx = H v  with
                              H = ⊗_i (n_i·I - 1 1ᵀ)  applied to the marginal v)
    γ²  = (s/t)² · Π n_i²    (σ̄ = s/t ≥ σ_A rounded up to a rational)
    M'(x) = Ξ x + N_Z(0, γ² I)      →  release  Y† M'(x)

M' and M_A(·; σ̄²) are mutual post-processings (Thm 6), so the discrete version
inherits the continuous ρ-zCDP guarantee exactly.

The sampler is the exact rejection sampler of Canonne–Kamath–Steinke (2020) —
no floating point touches the noise path (host-side by design; see
docs/DESIGN.md §10).  Two implementations share the distribution exactly:
the scalar ``fractions.Fraction`` reference below, and the batched
integer-lane sampler in :mod:`repro.core.dgauss` that ``measure_discrete``
and the :class:`~repro.engine.discrete_engine.DiscreteEngine` draw through.

This module is the *host-exact reference* implementation of Algorithm 3
(per-clique ``kron_matvec_np`` transforms, small problems / tests); the
serving hot path is ``plan.engine(secure=True)`` — signature-batched fused
H/Y† chains with only the noise draw staying host-side (docs/DESIGN.md §10).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from fractions import Fraction
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from . import dgauss
from .domain import Clique, Domain
from .kron import kron_matvec_np
from .mechanism import Measurement
from .plantable import BasePlan
from .residual import sub_matrix
from .select import Plan


# ---------------------------------------------------------------------------
# Exact discrete Gaussian sampling (CKS'20)
# ---------------------------------------------------------------------------

def _bernoulli(p: Fraction, rng: "random.Random") -> bool:
    """Exact Bernoulli(p) for rational p via arbitrary-precision integer uniform.

    ``random.Random.randrange`` is used (not numpy) because Fraction
    denominators routinely exceed 2**63 on the exact noise path.
    """
    return rng.randrange(p.denominator) < p.numerator


def _bernoulli_exp(gamma: Fraction, rng: "random.Random") -> bool:
    """Exact Bernoulli(exp(-gamma)) for rational gamma >= 0 (CKS Alg. 1)."""
    if gamma <= 1:
        k = 1
        while _bernoulli(gamma / k, rng):
            k += 1
        return k % 2 == 1
    for _ in range(math.floor(gamma)):
        if not _bernoulli_exp(Fraction(1), rng):
            return False
    return _bernoulli_exp(gamma - math.floor(gamma), rng)


def _sample_dlaplace(t: int, rng: "random.Random") -> int:
    """Exact discrete Laplace with scale t:  P(x) ∝ exp(-|x|/t)  (CKS Alg. 2)."""
    while True:
        u = rng.randrange(t)
        if not _bernoulli_exp(Fraction(u, t), rng):
            continue
        v = 0
        while _bernoulli_exp(Fraction(1), rng):
            v += 1
        x = u + t * v
        if _bernoulli(Fraction(1, 2), rng):  # sign
            if x == 0:
                continue
            return -x
        return x


def sample_discrete_gaussian(sigma2: Fraction, rng: "random.Random") -> int:
    """Exact discrete Gaussian N_Z(0, σ²):  P(x) ∝ exp(-x²/2σ²)  (CKS Alg. 3).

    The proposal scale t = ⌊√σ²⌋ + 1 is computed with pure integer
    ``math.isqrt`` on ``numerator // denominator``: the historical
    ``math.sqrt(float(sigma2))`` raised ``OverflowError`` (or silently lost
    precision) once γ² = σ̄²·Π n_i² left float64 range — exactly the large
    cliques where the secure path is mandatory.
    """
    sigma2 = Fraction(sigma2)
    if sigma2 <= 0:
        raise ValueError("sigma2 must be positive")
    t = math.isqrt(sigma2.numerator // sigma2.denominator) + 1
    while True:
        y = _sample_dlaplace(t, rng)
        num = (Fraction(abs(y)) - sigma2 / t) ** 2
        if _bernoulli_exp(num / (2 * sigma2), rng):
            return y


def sample_discrete_gaussian_vec(sigma2: Fraction, size: int,
                                 rng: "random.Random") -> np.ndarray:
    """Legacy serial draw: one scalar rejection loop per lane (bench baseline).

    The hot paths call :func:`repro.core.dgauss.sample` instead — identical
    distribution, vectorized rejection over integer lanes.
    """
    return np.array([sample_discrete_gaussian(sigma2, rng) for _ in range(size)],
                    dtype=object)


# ---------------------------------------------------------------------------
# Algorithm 3
# ---------------------------------------------------------------------------

def rationalize_sigma(sigma: float, digits: int = 4) -> Fraction:
    """Round σ *up* to a rational s/t with ``digits`` decimal digits (§5.2)."""
    scale = 10 ** digits
    return Fraction(math.ceil(sigma * scale), scale)


def clique_gamma2(plan: BasePlan, clique: Clique, digits: int = 4
                  ) -> Tuple[Fraction, Fraction, int]:
    """Exact ``(σ̄_A, γ²_A, Π n_i)`` of one base mechanism (Alg 3 lines 1–2).

    One definition shared by ``measure_discrete``, the
    :class:`~repro.engine.discrete_engine.DiscreteEngine` and the accounting
    helpers, so the served noise and the charged privacy always agree.
    """
    sigma_bar = rationalize_sigma(math.sqrt(plan.sigma2(clique)), digits)
    n_prod = 1
    for i in clique:
        n_prod *= plan.domain.attributes[i].size
    return sigma_bar, sigma_bar ** 2 * n_prod ** 2, n_prod


def discrete_pcost_of_plan(plan: BasePlan, digits: int = 4) -> float:
    """pcost (= 2ρ) actually spent by the discrete release of a whole plan.

    Σ_A 2·ρ_A with ρ_A = sens²(Ξ_A)/(2γ²_A) (Thm 6), computed exactly over
    the *rationalized* σ̄_A ≥ σ_A the mechanism really runs at — never more
    than the continuous plan's ``pcost_of_plan`` (rounding σ up only adds
    noise).  This is what ``corpus_marginal_release(..., secure=True)``
    charges against the shared :class:`~repro.core.accountant.PrivacyBudget`.
    """
    total = Fraction(0)
    for c in plan.cliques:
        sigma_bar, _, _ = clique_gamma2(plan, c, digits)
        total += discrete_zcdp_rho(plan.domain, c, sigma_bar)
    return float(2 * total)


@dataclass
class DiscreteMeasurement(Measurement):
    sigma_bar: Fraction = Fraction(0)
    gamma2: Fraction = Fraction(0)


def h_factors(dims: Sequence[int], dtype=np.float64) -> List[np.ndarray]:
    """H = ⊗_i (n_i·I - 1 1ᵀ):  H v = Ξ x, all-integer (Alg 3 line 4).

    The single definition of the rotation's forward factors — the host
    oracle below and the :class:`~repro.engine.discrete_engine.DiscreteEngine`
    both build from here (``dtype=np.int64`` for the engine's exact tiers).
    """
    return [(n * np.eye(n) - np.ones((n, n))).astype(dtype) for n in dims]


def ypinv_factors(dims: Sequence[int]) -> List[np.ndarray]:
    """Y† = ⊗_i (1/n_i)·Sub_{n_i} (Alg 3 line 3) — shared like
    :func:`h_factors`."""
    return [sub_matrix(n) / n for n in dims]


def _h_factors(domain: Domain, clique: Clique) -> List[np.ndarray]:
    return h_factors([domain.attributes[i].size for i in clique])


def _ypinv_factors(domain: Domain, clique: Clique) -> List[np.ndarray]:
    return ypinv_factors([domain.attributes[i].size for i in clique])


def measure_discrete(plan: BasePlan, marginals: Mapping[Clique, np.ndarray],
                     rng, digits: int = 4,
                     sampler: str = "batched",
                     _noise_override=None) -> Dict[Clique, DiscreteMeasurement]:
    """Algorithm 3 for every base mechanism in the plan (host-exact reference).

    Outputs are drop-in replacements for the continuous measurements: same
    shapes, same unbiasedness, and (Thm 6) the same ρ-zCDP parameter as the
    continuous mechanism run at σ̄_A ≥ σ_A.

    ``rng`` is a ``random.Random`` or ``np.random.Generator``; ``sampler``
    picks the noise source — ``"batched"`` (default) draws every clique's
    lanes through :func:`repro.core.dgauss.sample`, ``"legacy"`` keeps the
    historical one-value-at-a-time Fraction sampler (requires
    ``random.Random``; bench baseline).  Both are exact and seed-
    deterministic; their random streams differ.

    Transforms here are per-clique ``kron_matvec_np`` — the float64 oracle.
    Serving traffic goes through ``plan.engine(secure=True)``
    (:class:`~repro.engine.discrete_engine.DiscreteEngine`), which runs H and
    Y† as signature-batched fused chains.

    Consumes the unified plan protocol (``plan.domain`` / ``plan.cliques`` /
    ``plan.sigma2``); the rotation into integer queries is specific to
    identity bases, so RP+ plans (non-plain IR) are rejected.
    """
    if not getattr(plan.table, "plain", True):
        raise ValueError("measure_discrete requires a plain (identity-basis) "
                         "plan; RP+ plans have no integer-query rotation")
    if sampler not in ("batched", "legacy"):
        raise ValueError(f"unknown sampler {sampler!r}")
    if _noise_override is not None:
        draw = _noise_override
    elif sampler == "legacy":
        if not isinstance(rng, random.Random):
            raise TypeError("sampler='legacy' requires a random.Random")
        draw = sample_discrete_gaussian_vec
    else:
        nrng = dgauss.as_np_rng(rng)
        draw = lambda g2, size, _r: dgauss.sample(g2, size, nrng)  # noqa: E731
    out: Dict[Clique, DiscreteMeasurement] = {}
    for clique in plan.cliques:
        dims = [plan.domain.attributes[i].size for i in clique]
        v = np.asarray(marginals[clique], dtype=np.float64).reshape(-1)
        sigma_bar, gamma2, n_prod = clique_gamma2(plan, clique, digits)
        if not clique:
            z = draw(gamma2, 1, rng)
            omega = v + np.asarray(z, dtype=np.float64)
            out[clique] = DiscreteMeasurement(clique, omega, float(sigma_bar ** 2),
                                              sigma_bar, gamma2)
            continue
        hv = kron_matvec_np(_h_factors(plan.domain, clique), v, dims)  # = Ξx
        z = draw(gamma2, n_prod, rng)
        noisy = hv + np.asarray(z, dtype=np.float64)
        omega = kron_matvec_np(_ypinv_factors(plan.domain, clique), noisy, dims)
        out[clique] = DiscreteMeasurement(clique, omega, float(sigma_bar ** 2),
                                          sigma_bar, gamma2)
    return out


def xi_l2_sensitivity2(domain: Domain, clique: Clique) -> int:
    """Squared L2 sensitivity of Ξ = Y R_A: Π_i n_i (n_i - 1) (integer, exact).

    Each record's column of Ξ is ⊗_i (n_i e_j - 1), with squared norm
    (n_i-1)² + (n_i-1) = n_i(n_i-1) per axis.
    """
    out = 1
    for i in clique:
        n = domain.attributes[i].size
        out *= n * (n - 1)
    return out


def discrete_zcdp_rho(domain: Domain, clique: Clique, sigma_bar: Fraction) -> Fraction:
    """ρ for the discrete mechanism: sens²/(2γ²) — equals p_A/(2σ̄²) (Thm 6)."""
    n_prod = 1
    for i in clique:
        n_prod *= domain.attributes[i].size
    gamma2 = sigma_bar ** 2 * n_prod ** 2
    return Fraction(xi_l2_sensitivity2(domain, clique)) / (2 * gamma2)


def naive_discrete_rho(plan: Plan, digits: int = 4) -> float:
    """ρ of the *naive* swap (Example 2): each M_A treated as sensitivity-1
    discrete-Gaussian marginal + post-processing ⇒ ρ_A = 1/(2σ̄²_A), losing the
    Π (n_i-1)/n_i factor (up to 2^k for k binary attributes).

    σ̄_A is rounded through :func:`rationalize_sigma` exactly like
    ``measure_discrete`` runs it (the historical version read the continuous
    ``plan.sigmas[A]``, making the Example-2 comparison slightly optimistic);
    with matching σ̄ the naive ρ dominates Σ_A ``discrete_zcdp_rho`` term by
    term.
    """
    total = Fraction(0)
    for c in plan.cliques:
        sigma_bar = rationalize_sigma(math.sqrt(plan.sigmas[c]), digits)
        total += Fraction(1) / (2 * sigma_bar ** 2)
    return float(total)
