r"""Numerically secure measurement with discrete Gaussian noise (Section 5, Alg 3).

The pitfall (Example 2): naively swapping the correlated continuous noise
``N(0, σ²Σ_A)`` for independent discrete Gaussians costs up to 2^k in privacy
for k-way marginals.  The fix rotates the base mechanism into an equivalent
*integer-query, independent-noise* mechanism:

    Y   = ⊗_i |Att_i|·Sub_i^†
    Ξ   = Y R_A              (integer matrix;  Ξx = H v  with
                              H = ⊗_i (n_i·I - 1 1ᵀ)  applied to the marginal v)
    γ²  = (s/t)² · Π n_i²    (σ̄ = s/t ≥ σ_A rounded up to a rational)
    M'(x) = Ξ x + N_Z(0, γ² I)      →  release  Y† M'(x)

M' and M_A(·; σ̄²) are mutual post-processings (Thm 6), so the discrete version
inherits the continuous ρ-zCDP guarantee exactly.

The sampler is the exact rejection sampler of Canonne–Kamath–Steinke (2020),
implemented over ``fractions.Fraction`` — no floating point touches the noise
path (host-side by design; see docs/DESIGN.md §3).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from fractions import Fraction
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .domain import Clique, Domain
from .kron import kron_matvec_np
from .mechanism import Measurement
from .plantable import BasePlan
from .residual import sub_matrix
from .select import Plan


# ---------------------------------------------------------------------------
# Exact discrete Gaussian sampling (CKS'20)
# ---------------------------------------------------------------------------

def _bernoulli(p: Fraction, rng: "random.Random") -> bool:
    """Exact Bernoulli(p) for rational p via arbitrary-precision integer uniform.

    ``random.Random.randrange`` is used (not numpy) because Fraction
    denominators routinely exceed 2**63 on the exact noise path.
    """
    return rng.randrange(p.denominator) < p.numerator


def _bernoulli_exp(gamma: Fraction, rng: "random.Random") -> bool:
    """Exact Bernoulli(exp(-gamma)) for rational gamma >= 0 (CKS Alg. 1)."""
    if gamma <= 1:
        k = 1
        while _bernoulli(gamma / k, rng):
            k += 1
        return k % 2 == 1
    for _ in range(math.floor(gamma)):
        if not _bernoulli_exp(Fraction(1), rng):
            return False
    return _bernoulli_exp(gamma - math.floor(gamma), rng)


def _sample_dlaplace(t: int, rng: "random.Random") -> int:
    """Exact discrete Laplace with scale t:  P(x) ∝ exp(-|x|/t)  (CKS Alg. 2)."""
    while True:
        u = rng.randrange(t)
        if not _bernoulli_exp(Fraction(u, t), rng):
            continue
        v = 0
        while _bernoulli_exp(Fraction(1), rng):
            v += 1
        x = u + t * v
        if _bernoulli(Fraction(1, 2), rng):  # sign
            if x == 0:
                continue
            return -x
        return x


def sample_discrete_gaussian(sigma2: Fraction, rng: "random.Random") -> int:
    """Exact discrete Gaussian N_Z(0, σ²):  P(x) ∝ exp(-x²/2σ²)  (CKS Alg. 3)."""
    if sigma2 <= 0:
        raise ValueError("sigma2 must be positive")
    t = math.floor(math.isqrt(int(sigma2)) if sigma2.denominator == 1
                   else math.sqrt(float(sigma2))) + 1
    while True:
        y = _sample_dlaplace(t, rng)
        num = (Fraction(abs(y)) - sigma2 / t) ** 2
        if _bernoulli_exp(num / (2 * sigma2), rng):
            return y


def sample_discrete_gaussian_vec(sigma2: Fraction, size: int,
                                 rng: "random.Random") -> np.ndarray:
    return np.array([sample_discrete_gaussian(sigma2, rng) for _ in range(size)],
                    dtype=object)


# ---------------------------------------------------------------------------
# Algorithm 3
# ---------------------------------------------------------------------------

def rationalize_sigma(sigma: float, digits: int = 4) -> Fraction:
    """Round σ *up* to a rational s/t with ``digits`` decimal digits (§5.2)."""
    scale = 10 ** digits
    return Fraction(math.ceil(sigma * scale), scale)


@dataclass
class DiscreteMeasurement(Measurement):
    sigma_bar: Fraction = Fraction(0)
    gamma2: Fraction = Fraction(0)


def _h_factors(domain: Domain, clique: Clique) -> List[np.ndarray]:
    """H = ⊗_i (n_i·I - 1 1ᵀ):  H v = Ξ x, all-integer (Alg 3 line 4)."""
    facs = []
    for i in clique:
        n = domain.attributes[i].size
        facs.append(n * np.eye(n) - np.ones((n, n)))
    return facs


def _ypinv_factors(domain: Domain, clique: Clique) -> List[np.ndarray]:
    """Y† = ⊗_i (1/n_i)·Sub_{n_i} (Alg 3 line 3)."""
    return [sub_matrix(domain.attributes[i].size) / domain.attributes[i].size
            for i in clique]


def measure_discrete(plan: BasePlan, marginals: Mapping[Clique, np.ndarray],
                     rng: "random.Random", digits: int = 4,
                     _noise_override=None) -> Dict[Clique, DiscreteMeasurement]:
    """Algorithm 3 for every base mechanism in the plan.

    Outputs are drop-in replacements for the continuous measurements: same
    shapes, same unbiasedness, and (Thm 6) the same ρ-zCDP parameter as the
    continuous mechanism run at σ̄_A ≥ σ_A.

    Consumes the unified plan protocol (``plan.domain`` / ``plan.cliques`` /
    ``plan.sigma2``); the rotation into integer queries is specific to
    identity bases, so RP+ plans (non-plain IR) are rejected.
    """
    if not getattr(plan.table, "plain", True):
        raise ValueError("measure_discrete requires a plain (identity-basis) "
                         "plan; RP+ plans have no integer-query rotation")
    out: Dict[Clique, DiscreteMeasurement] = {}
    for clique in plan.cliques:
        dims = [plan.domain.attributes[i].size for i in clique]
        v = np.asarray(marginals[clique], dtype=np.float64).reshape(-1)
        sigma_bar = rationalize_sigma(math.sqrt(plan.sigma2(clique)), digits)
        n_prod = int(np.prod(dims)) if clique else 1
        gamma2 = sigma_bar ** 2 * n_prod ** 2
        if not clique:
            z = (_noise_override(gamma2, 1, rng) if _noise_override is not None
                 else sample_discrete_gaussian_vec(gamma2, 1, rng))
            omega = v + np.asarray(z, dtype=np.float64)
            out[clique] = DiscreteMeasurement(clique, omega, float(sigma_bar ** 2),
                                              sigma_bar, gamma2)
            continue
        hv = kron_matvec_np(_h_factors(plan.domain, clique), v, dims)  # = Ξx
        z = (_noise_override(gamma2, n_prod, rng) if _noise_override is not None
             else sample_discrete_gaussian_vec(gamma2, n_prod, rng))
        noisy = hv + np.asarray(z, dtype=np.float64)
        omega = kron_matvec_np(_ypinv_factors(plan.domain, clique), noisy, dims)
        out[clique] = DiscreteMeasurement(clique, omega, float(sigma_bar ** 2),
                                          sigma_bar, gamma2)
    return out


def xi_l2_sensitivity2(domain: Domain, clique: Clique) -> int:
    """Squared L2 sensitivity of Ξ = Y R_A: Π_i n_i (n_i - 1) (integer, exact).

    Each record's column of Ξ is ⊗_i (n_i e_j - 1), with squared norm
    (n_i-1)² + (n_i-1) = n_i(n_i-1) per axis.
    """
    out = 1
    for i in clique:
        n = domain.attributes[i].size
        out *= n * (n - 1)
    return out


def discrete_zcdp_rho(domain: Domain, clique: Clique, sigma_bar: Fraction) -> Fraction:
    """ρ for the discrete mechanism: sens²/(2γ²) — equals p_A/(2σ̄²) (Thm 6)."""
    n_prod = 1
    for i in clique:
        n_prod *= domain.attributes[i].size
    gamma2 = sigma_bar ** 2 * n_prod ** 2
    return Fraction(xi_l2_sensitivity2(domain, clique)) / (2 * gamma2)


def naive_discrete_rho(plan: Plan) -> float:
    """ρ of the *naive* swap (Example 2): each M_A treated as sensitivity-1
    discrete-Gaussian marginal + post-processing ⇒ ρ_A = 1/(2σ̄²_A), losing the
    Π (n_i-1)/n_i factor (up to 2^k for k binary attributes)."""
    return sum(1.0 / (2.0 * plan.sigmas[c]) for c in plan.cliques)
