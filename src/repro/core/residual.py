"""Residual bases: subtraction matrices, their pseudo-inverses, residual matrices.

Section 4.2 of the paper.  ``Sub_m`` is the (m-1) x m matrix with first column
all ones and -1 on the (i, i+1) superdiagonal; ``R_A = ⊗_i V_i`` with
``V_i = 1ᵀ`` for attributes outside A and ``Sub_{|Att_i|}`` inside A.
All objects here are tiny (per-attribute); they are the Kronecker *factors*
used by the implicit algebra in :mod:`repro.core.kron`.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from .domain import Clique, Domain


def sub_matrix(m: int) -> np.ndarray:
    """Sub_m: (m-1) x m, first column 1, entries (i, i+1) = -1."""
    s = np.zeros((m - 1, m), dtype=np.float64)
    s[:, 0] = 1.0
    s[np.arange(m - 1), np.arange(1, m)] = -1.0
    return s


def sub_pinv(m: int) -> np.ndarray:
    """Sub_m^† in closed form (Lemma 1): (1/m) [[1ᵀ], [11ᵀ - m·I]], shape m x (m-1)."""
    top = np.ones((1, m - 1), dtype=np.float64)
    bot = np.ones((m - 1, m - 1), dtype=np.float64) - m * np.eye(m - 1)
    return np.vstack([top, bot]) / m


def sub_gram(m: int) -> np.ndarray:
    """Sub_m Sub_mᵀ = I + 11ᵀ  ((m-1) x (m-1)); the per-attribute covariance factor."""
    return np.eye(m - 1) + np.ones((m - 1, m - 1))


def residual_factors(domain: Domain, clique: Clique) -> List:
    """Kronecker factors of R_A: 'ones' outside the clique, Sub inside."""
    facs: List = []
    cl = set(clique)
    for i, attr in enumerate(domain.attributes):
        facs.append(sub_matrix(attr.size) if i in cl else "ones")
    return facs


def marginal_factors(domain: Domain, clique: Clique) -> List:
    """Kronecker factors of Q_A: 'ones' outside the clique, identity (None) inside."""
    cl = set(clique)
    return [None if i in cl else "ones" for i in range(domain.n_attrs)]


def p_coeff(domain: Domain, clique: Clique) -> float:
    """p_A = Π_{i∈A} (|Att_i|-1)/|Att_i| — the pcost coefficient of M_A (Thm 3)."""
    out = 1.0
    for s in domain.clique_sizes(clique):
        out *= (s - 1) / s
    return out


def variance_coeff(domain: Domain, sub_clique: Clique, clique: Clique) -> float:
    """Coefficient of σ²_{A'} in the per-cell variance of the marginal on A (Thm 4):

        p_{A'} · Π_{j ∈ A \\ A'} 1/|Att_j|²     (requires A' ⊆ A).
    """
    if not set(sub_clique) <= set(clique):
        raise ValueError(f"{sub_clique} is not a subset of {clique}")
    out = p_coeff(domain, sub_clique)
    for j in set(clique) - set(sub_clique):
        out /= domain.attributes[j].size ** 2
    return out


def axis_coeff_vectors(domain: Domain
                       ) -> "tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]":
    """Per-attribute coefficient vectors ``(pcost, meas, marg, cross)`` for the
    arrayized planner IR (:mod:`repro.core.plantable`).

    Thm 3/4 factor per axis: a clique's pcost coefficient is
    ``Π_{i∈A} (n_i−1)/n_i``; the coefficient of σ²_{A'} in the per-cell
    variance of the marginal on A is ``Π_{i∈A'} (n_i−1)/n_i ·
    Π_{i∈A∖A'} 1/n_i²``; the aligned-cell cross-marginal covariance adds a
    ``1/n_i`` factor for every axis in the symmetric difference A△B.
    """
    sizes = np.asarray(domain.sizes, dtype=np.float64)
    frac = (sizes - 1.0) / sizes
    return frac, frac, sizes ** -2.0, sizes ** -1.0


def sigma_cov_factors(domain: Domain, clique: Clique) -> List[np.ndarray]:
    """Kronecker factors of Σ_A = ⊗_{i∈A} Sub_i Sub_iᵀ (1x1 [1] for empty clique)."""
    if not clique:
        return [np.ones((1, 1))]
    return [sub_gram(domain.attributes[i].size) for i in clique]


def expand_residual(domain: Domain, clique: Clique) -> np.ndarray:
    """Materialize R_A (tests / tiny domains only)."""
    from .kron import kron_expand
    facs = []
    cl = set(clique)
    for i, attr in enumerate(domain.attributes):
        facs.append(sub_matrix(attr.size) if i in cl else np.ones((1, attr.size)))
    return kron_expand(facs)


def expand_marginal(domain: Domain, clique: Clique) -> np.ndarray:
    """Materialize Q_A (tests / tiny domains only)."""
    from .kron import kron_expand
    facs = []
    cl = set(clique)
    for i, attr in enumerate(domain.attributes):
        facs.append(np.eye(attr.size) if i in cl else np.ones((1, attr.size)))
    return kron_expand(facs)
