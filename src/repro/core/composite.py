r"""Divide-and-conquer selection: per-block plans stitched into one plan.

``select_dnc`` is the planning route behind ``strategy="dnc"`` (and the
``"auto"`` overflow route) of the select entry points (docs/DESIGN.md §12):

1. partition the attributes from the workload's clique-interaction graph
   (:mod:`repro.core.partition`);
2. build one PlanTable per block — each a few hundred closure cliques even
   when the monolithic closure would hold millions — and run the existing
   selector on it unchanged (maxvar dual ascent warm-starts each block from
   the previous same-shaped block's dual point);
3. allocate the privacy budget across blocks: one *unified* Lemma-2 closed
   form for SoV (exactly the monolithic optimum when no clique straddles a
   cut), bisection on the per-block value function for maxvar/convex;
4. return a :class:`CompositePlan` that answers the whole plan protocol by
   delegating to its block plans.

The **shared empty clique** is the one coupling between blocks: every block
closure contains ∅ (the noisy total), the composite measures it ONCE, and its
σ²_∅ is optimized jointly — for SoV by concatenating the per-block (p, v)
arrays with ``v_∅ = Σ_b v_b[∅]`` into a single closed form, for maxvar/convex
by an ∅-repair step (pin σ²_∅ to the tightest block's choice, then rescale so
pcost is tight again; both steps only ever lower variances).  Because ∅ is
shared, reconstructed marginals in *different* blocks are not independent:
their aligned-cell covariance is exactly ``σ²_∅ · Π_{i∈A∪B} 1/n_i`` — the
monolithic Thm-4 value — which is what makes disjoint-block D&C *exact*, not
merely close.  (The issue text says "zero across blocks"; zero is what you
get only if each block buys its own total.  We keep the shared total and
report the exact covariance instead — documented in DESIGN.md §12.)

Cliques that straddle a cut are answered by the *product-of-blocks
correction* (:mod:`repro.core.partition`): the marginal is the normalized
outer product of its per-block projections, and ``variances_array`` reports
the independence-proxy variance
``Var_A ≈ Σ_p Var_p · Π_{p'≠p} n_cells(p')^{-2}`` (exact for one part,
heuristic otherwise — the total-count factors cancel when every other part's
cell mass is spread uniformly).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .domain import Clique, Domain, MarginalWorkload
from .partition import (DEFAULT_MAX_BLOCK, Decomposition, Partition,
                        ROW_EMPTY, ROW_STRADDLER, decompose,
                        partition_attributes)
from .plantable import BasePlan, PlanTable, plan_table, sov_closed_form


# ---------------------------------------------------------------------------
# Cross-block budget allocation
# ---------------------------------------------------------------------------

def allocate_budget(values: np.ndarray, budget: float,
                    combine: str = "max") -> np.ndarray:
    """Split ``budget`` across blocks given unit-budget block losses V_b.

    Every selector loss is positively 1-homogeneous in σ² and pcost is
    (−1)-homogeneous, so a block planned at unit budget rescales exactly:
    at budget c_b its loss is V_b / c_b.  The allocator solves

    * ``combine="max"``:  min max_b V_b/c_b   s.t. Σ c_b = budget
    * ``combine="sum"``:  min Σ_b V_b/c_b     s.t. Σ c_b = budget

    by bisection on the dual multiplier λ (c_b(λ) = V_b/λ resp. √(V_b/λ);
    Σ c_b(λ) is strictly decreasing in λ), then normalizes so the budget is
    met exactly.  Blocks with V_b = 0 (degenerate, nothing to lose) get a
    vanishing sliver.
    """
    V = np.asarray(values, np.float64)
    c = float(budget)
    if not c > 0:
        raise ValueError(f"pcost budget must be positive, got {c}")
    if (V < 0).any():
        raise ValueError("block losses must be non-negative")
    pos = V > 0
    if not pos.any():
        return np.full(len(V), c / max(len(V), 1))
    Vp = np.where(pos, V, V[pos].min() * 1e-12)

    def total(lam: float) -> float:
        return float((Vp / lam).sum() if combine == "max"
                     else np.sqrt(Vp / lam).sum())

    if combine not in ("max", "sum"):
        raise ValueError(f"combine must be 'max' or 'sum', got {combine!r}")
    lo = hi = 1.0
    while total(hi) > c:
        hi *= 2.0
    while total(lo) < c:
        lo *= 0.5
    for _ in range(200):
        mid = math.sqrt(lo * hi)
        if total(mid) > c:
            lo = mid
        else:
            hi = mid
        if hi / lo < 1.0 + 1e-14:
            break
    lam = math.sqrt(lo * hi)
    cb = Vp / lam if combine == "max" else np.sqrt(Vp / lam)
    return cb * (c / cb.sum())


# ---------------------------------------------------------------------------
# CompositePlan: the plan protocol over stitched block plans
# ---------------------------------------------------------------------------

@dataclass(eq=False)
class CompositePlan(BasePlan):
    """Block plans behind the unified plan protocol (docs/DESIGN.md §12).

    ``table`` is None — there is no monolithic closure; ``sigma`` is the
    global σ² vector over the composite closure ``[∅] + Σ_b closure_b∖∅``
    (the shared ∅ first, then each block's non-empty cliques in block
    order), and every protocol query delegates to the block plans.
    """

    block_plans: Tuple[BasePlan, ...] = ()
    decomposition: Optional[Decomposition] = None
    _cliques: Optional[List[Clique]] = field(default=None, repr=False)
    _sigma_index: Optional[Dict[Clique, float]] = field(default=None,
                                                        repr=False)

    # ----------------------------------------------------------- identity
    @property
    def partition(self) -> Partition:
        return self.decomposition.partition

    @property
    def n_blocks(self) -> int:
        return len(self.block_plans)

    @property
    def domain(self) -> Domain:
        return self.decomposition.workload.domain

    @property
    def workload(self) -> MarginalWorkload:
        return self.decomposition.workload

    @property
    def cliques(self) -> List[Clique]:
        """Composite closure: shared ∅ first, then per-block non-∅ cliques."""
        if self._cliques is None:
            cl: List[Clique] = [()]
            for bp in self.block_plans:
                cl.extend(bp.table.cliques[1:])
            self._cliques = cl
        return self._cliques

    @property
    def sigmas(self) -> Dict[Clique, float]:
        if self._sigma_index is None:
            self._sigma_index = dict(zip(self.cliques,
                                         map(float, self.sigma)))
        return self._sigma_index

    def sigma2(self, clique: Clique) -> float:
        return self.sigmas[clique]

    # ---------------------------------------------------------- variances
    def variances_array(self) -> np.ndarray:
        """Per-workload-marginal variance: block delegation + straddler proxy.

        In-block rows are the block plan's exact Thm-4 variances; ∅ rows are
        σ²_∅; straddling rows report the product-of-blocks proxy
        ``Σ_p Var_p · Π_{p'≠p} n_cells(p')⁻²`` (module docstring).
        """
        d = self.decomposition
        m = len(d.workload.cliques)
        out = np.zeros(m)
        block_vars = [bp.variances_array() for bp in self.block_plans]
        for b, bv in enumerate(block_vars):
            sel = d.row_block == b
            if sel.any():
                out[sel] = bv[d.row_pos[sel]]
        out[d.row_block == ROW_EMPTY] = float(self.sigma[0])
        if d.part_row.size:
            pv = np.zeros(len(d.part_row))
            for b, bv in enumerate(block_vars):
                sel = d.part_block == b
                if sel.any():
                    pv[sel] = bv[d.part_pos[sel]]
            logc = np.log(d.part_cells)
            S = np.bincount(d.part_row, weights=logc, minlength=m)
            contrib = pv * np.exp(-2.0 * (S[d.part_row] - logc))
            out += np.bincount(d.part_row, weights=contrib, minlength=m)
        return out

    def marginal_variance(self, clique: Clique) -> float:
        """Variance of one workload marginal (straddlers: the product proxy)."""
        try:
            row = self.workload.cliques.index(clique)
        except ValueError:
            raise KeyError(clique) from None
        return float(self.variances_array()[row])

    def total_variance(self) -> float:
        cells = np.array([self.domain.n_cells(c)
                          for c in self.workload.cliques])
        return float(np.dot(cells, self.variances_array()))

    def rmse(self) -> float:
        return math.sqrt(self.total_variance() / self.workload.total_cells())

    def max_variance(self, weights: Optional[Mapping[Clique, float]] = None
                     ) -> float:
        wv = self.variances_array()
        if weights is None:
            return float(wv.max())
        w = np.array([float(weights.get(c, self.workload.weight(c)))
                      for c in self.workload.cliques])
        return float((wv / w).max())

    # --------------------------------------------------------- covariances
    def _block_of_clique(self, clique: Clique) -> int:
        """Owning block of a clique, or raise for cut-straddling cliques."""
        if not clique:
            return -1
        block_of = self.partition.block_of_array()
        bids = {int(block_of[a]) for a in clique}
        if len(bids) > 1 or -1 in bids:
            raise ValueError(f"clique {clique} straddles the partition; "
                             "covariance of product-corrected marginals is "
                             "not defined on the composite plan")
        return bids.pop()

    def marginal_covariance(self, a: Clique, b: Clique) -> float:
        """Aligned-cell covariance of reconstructed marginals A and B.

        Same block: the block plan's exact Thm-4 value.  Different blocks:
        only the shared ∅ measurement correlates them, so the covariance is
        exactly ``σ²_∅ · Π_{i∈A∪B} 1/n_i`` — identical to the monolithic
        planner's value for disjoint cliques.
        """
        ba, bb = self._block_of_clique(a), self._block_of_clique(b)
        if ba == bb and ba >= 0:
            return self.block_plans[ba].marginal_covariance(a, b)
        if ba < 0 or bb < 0 or not (set(a) & set(b)):
            table = self.block_plans[0].table
            cross = table.axis_cross
            outer = float(np.prod(cross[sorted(set(a) ^ set(b))])) \
                if (set(a) ^ set(b)) else 1.0
            return float(self.sigma[0]) * outer
        raise ValueError(f"cliques {a} and {b} overlap across blocks")

    def workload_covariances(self, pairs: Sequence[Tuple[Clique, Clique]]
                             ) -> np.ndarray:
        return np.array([self.marginal_covariance(a, b) for a, b in pairs])

    # -------------------------------------------------------------- engine
    def engine(self, use_kernel=None, precompile: bool = True, dtype=None,
               secure: bool = False, digits: int = 4):
        if secure:
            raise ValueError(
                "secure discrete release is not supported for CompositePlan: "
                "the integer-query rotation is defined per monolithic "
                "closure; plan the blocks separately or use the continuous "
                "engine")
        from repro.engine.composite import CompositeEngine
        return CompositeEngine(self, use_kernel=use_kernel,
                               precompile=precompile, dtype=dtype)


# ---------------------------------------------------------------------------
# The D&C selector
# ---------------------------------------------------------------------------

def _split_sigma(sig_all: np.ndarray, tables: Sequence[PlanTable]
                 ) -> Tuple[float, List[np.ndarray]]:
    """Unified σ² vector → (shared σ²_∅, per-block σ² vectors)."""
    s0 = float(sig_all[0])
    out, at = [], 1
    for t in tables:
        k = t.n - 1
        out.append(np.concatenate([[s0], sig_all[at:at + k]]))
        at += k
    return s0, out


def _composite_pcost(tables: Sequence[PlanTable],
                     sigmas: Sequence[np.ndarray]) -> float:
    """Total pcost counting the shared ∅ mechanism exactly once."""
    p0 = float(tables[0].p[0])
    s0 = float(sigmas[0][0])
    return float(sum(t.pcost(s) for t, s in zip(tables, sigmas))
                 - (len(tables) - 1) * p0 / s0)


def select_dnc(workload: MarginalWorkload, pcost_budget: float = 1.0,
               objective: str = "sum_of_variances",
               weights: Optional[Mapping[Clique, float]] = None,
               blocks=None, max_block: Optional[int] = None,
               partition: Optional[Partition] = None,
               **kw) -> CompositePlan:
    """Partition → per-block select → cross-block allocation → CompositePlan.

    ``blocks=`` / ``max_block=`` forward to
    :func:`repro.core.partition.partition_attributes`; when neither is given,
    connected components are used with oversized components split at
    :data:`~repro.core.partition.DEFAULT_MAX_BLOCK` attributes.  ``kw`` is
    forwarded to the per-block selector (``iters``/``tol``/``backend``/
    ``chunk`` for maxvar, ``loss``/``steps``/``lr``/``seed`` for convex).

    SoV runs ONE closed form over the concatenated per-block coefficient
    arrays (sharing ∅), so a workload whose interaction graph is
    disconnected gets the *exact* monolithic optimum.  Maxvar/convex solve
    each block at unit budget (warm-starting the dual ascent from the
    previous same-shaped block), split the budget by
    :func:`allocate_budget`, and repair the shared σ²_∅ (module docstring).
    """
    if partition is None:
        mb = DEFAULT_MAX_BLOCK if (blocks is None and max_block is None) \
            else max_block
        partition = partition_attributes(workload, blocks=blocks,
                                         max_block=mb)
    if partition.n_blocks == 0:
        # degenerate: only ∅ cliques — nothing to decompose
        from .select import select
        return select(workload, pcost_budget, objective=objective,
                      weights=weights, strategy="monolithic", **kw)
    d = decompose(workload, partition, weights)
    tables = [plan_table(bw) for bw in d.block_workloads]
    c = float(pcost_budget)

    if objective in ("sum_of_variances", "sov", "rmse"):
        return _dnc_sov(d, tables, c)
    if objective in ("max_variance", "maxvar"):
        return _dnc_iterative(d, tables, c, "max_variance", kw)
    if objective == "convex":
        return _dnc_iterative(d, tables, c, "convex", kw)
    raise ValueError(objective)


def _dnc_sov(d: Decomposition, tables: List[PlanTable], c: float
             ) -> CompositePlan:
    """One unified Lemma-2 closed form over all blocks (shared ∅)."""
    from .select import Plan
    p0 = float(tables[0].p[0])
    p_all = np.concatenate([[p0]] + [t.p[1:] for t in tables])
    v_all = np.concatenate(
        [[sum(float(t.v[0]) for t in tables) + d.empty_weight]]
        + [t.v[1:] for t in tables])
    sig_all = sov_closed_form(p_all, v_all, c)
    s0, sigmas = _split_sigma(sig_all, tables)
    block_plans = tuple(
        Plan(t, s, "sum_of_variances", pcost=t.pcost(s),
             loss_value=float(np.dot(t.v, s)))
        for t, s in zip(tables, sigmas))
    return CompositePlan(
        None, sig_all, "sum_of_variances",
        pcost=_composite_pcost(tables, sigmas),
        loss_value=float(np.dot(v_all, sig_all)),
        block_plans=block_plans, decomposition=d)


def _dnc_iterative(d: Decomposition, tables: List[PlanTable], c: float,
                   objective: str, kw: dict) -> CompositePlan:
    """Unit-budget block solves (warm-started) + bisection allocation."""
    from .select import select_convex, select_max_variance
    unit: List[BasePlan] = []
    warm_mu: Dict[int, np.ndarray] = {}
    for t, bw in zip(tables, d.block_workloads):
        if objective == "max_variance":
            bp = select_max_variance(bw, 1.0, table=t,
                                     mu0=warm_mu.get(t.m), **kw)
            if getattr(bp, "mu", None) is not None:
                warm_mu[t.m] = bp.mu
        else:
            bp = select_convex(bw, 1.0, table=t, **kw)
        unit.append(bp)

    loss = kw.get("loss", "max_variance")
    combine = "sum" if (objective == "convex"
                        and loss == "sum_of_variances") else "max"
    V = np.array([bp.loss_value for bp in unit])
    cb = allocate_budget(V, c, combine)

    # 1-homogeneity: block b at budget c_b is the unit plan scaled by 1/c_b.
    sigmas = [bp.sigma / cb[b] for b, bp in enumerate(unit)]
    # ∅-repair: pin the shared σ²_∅ to the tightest block's choice (variances
    # only drop), then rescale so the once-counted pcost is tight again.
    s0 = min(float(s[0]) for s in sigmas)
    for s in sigmas:
        s[0] = s0
    total = _composite_pcost(tables, sigmas)
    scale = total / c                     # ≤ 1: shrinking σ² tightens pcost
    sigmas = [s * scale for s in sigmas]

    from .select import Plan
    block_plans = tuple(
        Plan(t, s, objective, pcost=t.pcost(s),
             loss_value=float((t.variances(s)
                               / t.weight_vector(None)).max()),
             mu=getattr(bp, "mu", None))
        for t, s, bp in zip(tables, sigmas, unit))
    sig_all = np.concatenate([[sigmas[0][0]]] + [s[1:] for s in sigmas])
    plan = CompositePlan(
        None, sig_all, objective, pcost=_composite_pcost(tables, sigmas),
        loss_value=0.0, block_plans=block_plans, decomposition=d)
    # same convention as the monolithic maxvar loss: max_r Var_r / Imp_r
    plan.loss_value = float((plan.variances_array() / d.row_weight).max())
    return plan


# ---------------------------------------------------------------------------
# Accuracy harness: D&C vs monolithic where both are feasible
# ---------------------------------------------------------------------------

def compare_with_monolithic(workload: MarginalWorkload,
                            pcost_budget: float = 1.0,
                            objective: str = "sum_of_variances",
                            weights: Optional[Mapping[Clique, float]] = None,
                            blocks=None, max_block: Optional[int] = None,
                            **kw) -> Dict[str, float]:
    """Plan both routes and report total-variance parity (CI gates on this).

    Returns total variances, their ratio (D&C / monolithic), the worst
    per-marginal relative deviation, and whether the partition was exact
    (no straddling cliques — where SoV parity must be 1.0 to fp accuracy).
    """
    from .select import select
    mono = select(workload, pcost_budget, objective=objective,
                  weights=weights, strategy="monolithic", **kw)
    dnc = select_dnc(workload, pcost_budget, objective=objective,
                     weights=weights, blocks=blocks, max_block=max_block,
                     **kw)
    tv_m, tv_d = mono.total_variance(), dnc.total_variance()
    vm, vd = mono.variances_array(), dnc.variances_array()
    rel = float(np.max(np.abs(vd - vm) / np.maximum(vm, 1e-300)))
    return dict(total_monolithic=tv_m, total_dnc=tv_d,
                ratio=tv_d / tv_m, max_rel_marginal_diff=rel,
                n_blocks=float(dnc.n_blocks),
                exact_partition=float(dnc.decomposition.n_straddlers == 0),
                pcost_monolithic=mono.pcost, pcost_dnc=dnc.pcost)
