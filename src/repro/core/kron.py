"""Implicit Kronecker-product linear algebra.

The whole paper rests on never materializing ``⊗_i V_i``.  A Kronecker matvec
``(V_1 ⊗ … ⊗ V_k) x`` is evaluated by reshaping ``x`` to the tensor
``(n_1, …, n_k)`` and contracting each factor along its own axis — the fast
kron-vector multiplication of McKenna et al. [40] referenced by Algs 1/2/5/6.

Two implementations are provided:
  * ``kron_matvec``      — jax/jnp, jit- and vmap-friendly (device path);
  * ``kron_matvec_np``   — numpy (planning / host path, exact float64).

``None`` factors mean "identity on that axis" and are skipped.
A factor may also be the string ``"ones"`` meaning the all-ones row vector
(marginalize the axis out) — the most common non-identity factor in the paper.
"""
from __future__ import annotations

from functools import reduce
from typing import List, Optional, Sequence, Union

import numpy as np

import jax.numpy as jnp

Factor = Union[None, str, np.ndarray, "jnp.ndarray"]


def _apply_axis_jnp(x, mat, axis: int):
    x = jnp.moveaxis(x, axis, 0)
    y = jnp.tensordot(mat, x, axes=([1], [0]))
    return jnp.moveaxis(y, 0, axis)


def kron_matvec(factors: Sequence[Factor], x, dims: Sequence[int]):
    """Apply ``⊗_i factors[i]`` to ``x`` (any leading layout, flattened ok) with jnp.

    dims: the per-axis input sizes n_i (needed to reshape a flat x).
    Returns the result flattened to 1-D.
    """
    x = jnp.asarray(x).reshape(tuple(dims))
    for axis, f in enumerate(factors):
        if f is None:
            continue
        if isinstance(f, str):
            if f == "ones":
                x = jnp.sum(x, axis=axis, keepdims=True)
                continue
            raise ValueError(f)
        x = _apply_axis_jnp(x, jnp.asarray(f), axis)
    return x.reshape(-1)


def kron_matvec_batched(factors: Sequence[Factor], x, dims: Sequence[int]):
    """Apply ``⊗_i factors[i]`` to every row of a stack ``x`` (B, Π dims) with jnp.

    The batch axis is the same "left" dimension the Pallas kernels tile; this
    is the device-side analogue of the signature-batched numpy path
    (docs/DESIGN.md §4).  Returns shape (B, Π out_dims).
    """
    x = jnp.asarray(x)
    b = x.shape[0]
    x = x.reshape((b,) + tuple(dims))
    for axis, f in enumerate(factors):
        if f is None:
            continue
        if isinstance(f, str):
            if f == "ones":
                x = jnp.sum(x, axis=axis + 1, keepdims=True)
                continue
            raise ValueError(f)
        x = _apply_axis_jnp(x, jnp.asarray(f), axis + 1)
    return x.reshape(b, -1)


def kron_matvec_np(factors: Sequence[Factor], x: np.ndarray,
                   dims: Sequence[int]) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64).reshape(tuple(dims))
    for axis, f in enumerate(factors):
        if f is None:
            continue
        if isinstance(f, str):
            if f == "ones":
                x = np.sum(x, axis=axis, keepdims=True)
                continue
            raise ValueError(f)
        f = np.asarray(f, dtype=np.float64)
        x = np.moveaxis(np.tensordot(f, np.moveaxis(x, axis, 0), axes=([1], [0])), 0, axis)
    return x.reshape(-1)


def kron_matvec_np_batched(factors: Sequence[np.ndarray], x: np.ndarray,
                           dims: Sequence[int]) -> np.ndarray:
    """Batched host Kron chain: apply ``⊗_i factors[i]`` to every row of
    ``x`` (B, Π dims) with numpy tensordots.

    Deliberately dtype-preserving — the secure path routes int64 and object
    (big-int) lanes through it; float callers cast their inputs first.
    """
    b = x.shape[0]
    x = x.reshape((b,) + tuple(dims))
    for axis, f in enumerate(factors):
        x = np.moveaxis(np.tensordot(f, np.moveaxis(x, axis + 1, 0),
                                     axes=([1], [0])), 0, axis + 1)
    return x.reshape(b, -1)


def kron_expand(factors: Sequence[np.ndarray]) -> np.ndarray:
    """Materialize a small Kronecker product (tests / tiny domains only)."""
    mats = [np.asarray(f, dtype=np.float64) for f in factors]
    return reduce(np.kron, mats) if mats else np.ones((1, 1))


def kron_out_dims(factors: Sequence[Factor], dims: Sequence[int]) -> List[int]:
    out = []
    for f, n in zip(factors, dims):
        if f is None:
            out.append(n)
        elif isinstance(f, str):
            out.append(1)
        else:
            out.append(int(np.asarray(f).shape[0]))
    return out
