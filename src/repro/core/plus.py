r"""ResidualPlanner+ (Section 7): generalized marginals beyond identity queries.

Every attribute i carries a *basic matrix* W_i (identity / prefix-sum / range /
custom; the only requirement is that 1ᵀ lies in W_i's row space) and an optional
*strategy replacement* S_i with row space ⊇ row space of W_i.  Algorithm 4
builds a generalized subtraction matrix Sub_i whose rows span the part of S_i's
row space orthogonal to 1, plus a noise factor Γ_i:

    identity attribute:  Sub_i = Sub_{n}   (Section 4.2),  Γ_i = Sub_i
    otherwise:           P₁ = S_i - S_i 11ᵀ/n,  P₁ᵀP₁ = L Lᵀ (eigh-based
                         factorization; Cholesky is rank-deficient here),
                         Sub_i = P₂ᵀ (independent columns of L),  Γ_i = I.

Base mechanisms, measurement (Alg 5), reconstruction (Alg 6) and the SoV
formula (Thm 8) then follow the ResidualPlanner pattern with these factors.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .domain import Clique, Domain, MarginalWorkload, closure, subsets
from .kron import kron_matvec, kron_matvec_np
from .mechanism import Measurement
from .residual import sub_matrix, sub_pinv

# ---------------------------------------------------------------------------
# Basic (workload) matrices
# ---------------------------------------------------------------------------

def w_identity(n: int) -> np.ndarray:
    return np.eye(n)


def w_prefix(n: int) -> np.ndarray:
    """All prefix sums: row i answers 'value <= i' (lower-triangular ones)."""
    return np.tril(np.ones((n, n)))


def w_range(n: int) -> np.ndarray:
    """All n(n+1)/2 contiguous ranges [a, b]."""
    rows = []
    for a in range(n):
        for b in range(a, n):
            r = np.zeros(n)
            r[a:b + 1] = 1.0
            rows.append(r)
    return np.array(rows)


def w_total(n: int) -> np.ndarray:
    return np.ones((1, n))


def build_w(kind: str, n: int) -> np.ndarray:
    return {"identity": w_identity, "prefix": w_prefix,
            "range": w_range, "total": w_total}[kind](n)


def s_hierarchical(n: int, branching: int = 2) -> np.ndarray:
    """Hierarchical (H-tree) strategy: identity leaves + interval sums per level.

    A classic strategy replacement for range/prefix workloads [Hay et al.].
    """
    rows = [np.eye(n)]
    width = branching
    while width < n:
        lvl = np.zeros(((n + width - 1) // width, n))
        for j in range(lvl.shape[0]):
            lvl[j, j * width:(j + 1) * width] = 1.0
        rows.append(lvl)
        width *= branching
    rows.append(np.ones((1, n)))
    return np.vstack(rows)


# ---------------------------------------------------------------------------
# Algorithm 4: generalized subtraction matrices
# ---------------------------------------------------------------------------

@dataclass
class AttrBasis:
    """Per-attribute generalized residual data for ResidualPlanner+."""

    n: int
    W: np.ndarray                # basic matrix (rows x n)
    S: np.ndarray                # strategy replacement
    Sub: np.ndarray              # generalized subtraction matrix (r x n), Sub·1 = 0
    Gamma: np.ndarray            # noise factor; cov factor = Γ Γᵀ
    identity: bool
    beta: float                  # max diag of Subᵀ (ΓΓᵀ)⁻¹ Sub  (Thm 7)
    sub_pinv: np.ndarray         # Sub^† (n x r)

    @property
    def fnorm2(self) -> float:
        """‖W Sub† Γ‖_F² — the measured-part variance factor in Thm 8."""
        return float(np.linalg.norm(self.W @ self.sub_pinv @ self.Gamma, ord="fro") ** 2)

    @property
    def wones2(self) -> float:
        """‖W 1‖² / n² — the marginalized-part variance factor in Thm 8."""
        return float(np.linalg.norm(self.W @ np.ones(self.n)) ** 2) / self.n ** 2


def attr_basis(W: np.ndarray, S: Optional[np.ndarray] = None,
               tol: float = 1e-9) -> AttrBasis:
    W = np.asarray(W, dtype=np.float64)
    n = W.shape[1]
    S = W if S is None else np.asarray(S, dtype=np.float64)
    # sanity: 1ᵀ must be in the row space of W (paper's only restriction).
    ones = np.ones(n)
    resid = ones - W.T @ np.linalg.lstsq(W.T, ones, rcond=None)[0]
    if np.linalg.norm(resid) > 1e-6 * math.sqrt(n):
        raise ValueError("1ᵀ is not in the row space of W")
    is_identity = W.shape == (n, n) and np.allclose(W, np.eye(n))
    if is_identity and S is W:
        Sub = sub_matrix(n)
        Gamma = Sub.copy()
        spinv = sub_pinv(n)
        gram_inv = np.linalg.inv(Sub @ Sub.T)
        beta = float(np.max(np.diag(Sub.T @ gram_inv @ Sub)))
        return AttrBasis(n, W, S, Sub, Gamma, True, beta, spinv)
    # Algorithm 4 general branch (eigh replaces rank-deficient Cholesky).
    P1 = S - (S @ np.ones((n, 1))) @ np.ones((1, n)) / n
    M = P1.T @ P1
    evals, evecs = np.linalg.eigh(M)
    keep = evals > tol * max(evals.max(), 1.0)
    L = evecs[:, keep] * np.sqrt(evals[keep])          # M = L Lᵀ
    Sub = L.T                                          # rows span rowspace(P1), ⟂ 1
    Gamma = np.eye(Sub.shape[0])
    spinv = np.linalg.pinv(Sub)
    beta = float(np.max(np.einsum("ij,ij->j", Sub, Sub)))   # Γ=I ⇒ diag SubᵀSub
    return AttrBasis(n, W, S, Sub, Gamma, False, beta, spinv)


@dataclass
class PlusSchema:
    """Domain + per-attribute (W_i, S_i) bases for ResidualPlanner+."""

    domain: Domain
    bases: Tuple[AttrBasis, ...]

    @staticmethod
    def create(domain: Domain, kinds: Sequence[str],
               strategies: Optional[Sequence[Optional[np.ndarray]]] = None,
               strategy_mode: str = "auto") -> "PlusSchema":
        """kinds[i] ∈ {identity, prefix, range, total}; strategy_mode ∈
        {w (S=W), hier, auto (p-Identity optimizer, as in the paper §9)}."""
        bases = []
        for i, attr in enumerate(domain.attributes):
            W = build_w(kinds[i], attr.size)
            S = None if strategies is None else strategies[i]
            if S is None and kinds[i] != "identity":
                if strategy_mode == "hier":
                    S = s_hierarchical(attr.size)
                elif strategy_mode == "auto":
                    from repro.baselines.hdmm import opt_pidentity_projected
                    S = opt_pidentity_projected(W)
                # "w": S stays None -> W
            bases.append(attr_basis(W, S))
        return PlusSchema(domain, tuple(bases))

    def residual_size(self, clique: Clique) -> int:
        out = 1
        for i in clique:
            out *= self.bases[i].Sub.shape[0]
        return out

    def query_rows(self, clique: Clique) -> int:
        out = 1
        for i in clique:
            out *= self.bases[i].W.shape[0]
        return out


# ---------------------------------------------------------------------------
# pcost / variance coefficients (Thms 7 & 8) and selection
# ---------------------------------------------------------------------------

def p_coeff_plus(schema: PlusSchema, clique: Clique) -> float:
    out = 1.0
    for i in clique:
        out *= schema.bases[i].beta
    return out


def sov_coeff_plus(schema: PlusSchema, sub_clique: Clique, clique: Clique) -> float:
    """Coefficient of σ²_{A'} in SoV(Q_Ã) (Thm 8)."""
    if not set(sub_clique) <= set(clique):
        raise ValueError("not a subset")
    out = 1.0
    for i in sub_clique:
        out *= schema.bases[i].fnorm2
    for j in set(clique) - set(sub_clique):
        out *= schema.bases[j].wones2
    return out


def cell_variances_plus(schema: PlusSchema, sigmas: Mapping[Clique, float],
                        clique: Clique) -> np.ndarray:
    """Exact per-cell variance vector of the reconstructed answer to Q_Ã.

    diag(⊗_i Ψ_i Ψ_iᵀ) = ⊗_i diag(Ψ_i Ψ_iᵀ): per-axis diagonal vectors kron'd.
    """
    n_rows = schema.query_rows(clique)
    out = np.zeros(n_rows)
    for sub in subsets(clique):
        diag = np.ones(1)
        for i in clique:
            b = schema.bases[i]
            if i in set(sub):
                psi = b.W @ b.sub_pinv @ b.Gamma
            else:
                psi = (b.W @ np.ones((b.n, 1))) / b.n
            diag = np.kron(diag, np.einsum("ij,ij->i", psi, psi))
        out += sigmas[sub] * diag
    return out


@dataclass
class PlusPlan:
    schema: PlusSchema
    workload: MarginalWorkload
    cliques: List[Clique]
    sigmas: Dict[Clique, float]
    objective: str
    pcost: float
    loss_value: float

    def sov(self, clique: Clique) -> float:
        return sum(self.sigmas[sub] * sov_coeff_plus(self.schema, sub, clique)
                   for sub in subsets(clique))

    def rmse(self) -> float:
        tot = sum(self.sov(c) for c in self.workload.cliques)
        cells = sum(self.schema.query_rows(c) for c in self.workload.cliques)
        return math.sqrt(tot / cells)

    def max_cell_variance(self) -> float:
        return max(float(cell_variances_plus(self.schema, self.sigmas, c).max())
                   for c in self.workload.cliques)


def select_plus(workload: MarginalWorkload, schema: PlusSchema,
                pcost_budget: float = 1.0, objective: str = "sum_of_variances",
                weights: Optional[Mapping[Clique, float]] = None,
                steps: int = 3000, lr: float = 0.05) -> PlusPlan:
    """Selection for RP+ workloads.  SoV is closed form (Lemma 2 applies verbatim
    with generalized p_A, v_A); max_variance uses the scale-invariant solver on
    the exact per-cell variance diagonals."""
    cl = closure(workload.cliques)
    index = {c: i for i, c in enumerate(cl)}
    p = np.array([p_coeff_plus(schema, c) for c in cl])
    v = np.zeros(len(cl))
    for wc in workload.cliques:
        imp = float((weights or {}).get(wc, workload.weight(wc)))
        for sub in subsets(wc):
            v[index[sub]] += imp * sov_coeff_plus(schema, sub, wc)

    if objective in ("sum_of_variances", "sov", "rmse"):
        pos = v > 0
        n_zero = int((~pos).sum())
        eps_share = 1e-9 * pcost_budget if n_zero else 0.0
        c_eff = pcost_budget - eps_share * n_zero
        T = float(np.sqrt(v[pos] * p[pos]).sum()) ** 2 / c_eff
        sig = np.zeros(len(cl))
        sig[pos] = np.sqrt(T * p[pos] / (c_eff * v[pos]))
        if n_zero:
            sig[~pos] = p[~pos] / eps_share
        sigmas = {c_: float(s) for c_, s in zip(cl, sig)}
        plan = PlusPlan(schema, workload, cl, sigmas, objective,
                        pcost=float(np.sum(p / sig)), loss_value=float(np.dot(v, sig)))
        return plan

    if objective in ("max_variance", "maxvar"):
        # Per-cell variance rows: Var_cell = D u with D (total_cells x |closure|).
        rows, cols, vals = [], [], []
        row0 = 0
        for wc in workload.cliques:
            imp = float((weights or {}).get(wc, workload.weight(wc)))
            ncells = schema.query_rows(wc)
            for sub in subsets(wc):
                diag = np.ones(1)
                for i in wc:
                    b = schema.bases[i]
                    psi = (b.W @ b.sub_pinv @ b.Gamma) if i in set(sub) \
                        else (b.W @ np.ones((b.n, 1))) / b.n
                    diag = np.kron(diag, np.einsum("ij,ij->i", psi, psi))
                for r in range(ncells):
                    if diag[r] != 0.0:
                        rows.append(row0 + r)
                        cols.append(index[sub])
                        vals.append(diag[r] / imp)
            row0 += ncells
        m = row0
        rows_j = jnp.asarray(np.array(rows, np.int32))
        cols_j = jnp.asarray(np.array(cols, np.int32))
        vals_j = jnp.asarray(np.array(vals))
        p_j = jnp.asarray(p)

        warm_sig = np.sqrt(np.maximum(p, 1e-12) / np.maximum(v, 1e-12))
        warm_sig *= float(np.sum(p / warm_sig))  # normalize pcost to 1 then scale
        theta0 = jnp.log(jnp.asarray(warm_sig / pcost_budget))
        tau0 = float(np.median(vals)) * float(np.exp(theta0).mean()) + 1e-12

        def smooth_obj(theta, tau):
            u = jnp.exp(theta)
            var = jax.ops.segment_sum(vals_j * u[cols_j], rows_j, num_segments=m)
            L = tau * jax.scipy.special.logsumexp(var / tau)
            return jnp.log(jnp.sum(p_j / u)) + jnp.log(L)

        @jax.jit
        def run(theta0):
            def step(carry, i):
                theta, mo, ve = carry
                tau = tau0 * 10.0 ** (-3.0 * i / steps)
                g = jax.grad(smooth_obj)(theta, tau)
                mo = 0.9 * mo + 0.1 * g
                ve = 0.999 * ve + 0.001 * g * g
                mh = mo / (1 - 0.9 ** (i + 1.0))
                vh = ve / (1 - 0.999 ** (i + 1.0))
                return (theta - lr * mh / (jnp.sqrt(vh) + 1e-9), mo, ve), None
            (theta, _, _), _ = jax.lax.scan(step, (theta0, jnp.zeros_like(theta0),
                                                   jnp.zeros_like(theta0)),
                                            jnp.arange(steps))
            return theta

        u = np.exp(np.asarray(run(theta0), dtype=np.float64))
        u *= float(np.sum(p / u)) / pcost_budget
        sigmas = {c_: float(s) for c_, s in zip(cl, u)}
        plan = PlusPlan(schema, workload, cl, sigmas, objective,
                        pcost=float(np.sum(p / u)), loss_value=0.0)
        plan.loss_value = plan.max_cell_variance()
        return plan

    raise ValueError(objective)


# ---------------------------------------------------------------------------
# Measurement (Alg 5) and reconstruction (Alg 6)
# ---------------------------------------------------------------------------

def measure_plus_np(plan: PlusPlan, marginals: Mapping[Clique, np.ndarray],
                    rng) -> Dict[Clique, Measurement]:
    out: Dict[Clique, Measurement] = {}
    schema = plan.schema
    for clique in plan.cliques:
        dims = [schema.bases[i].n for i in clique]
        v = np.asarray(marginals[clique], dtype=np.float64).reshape(-1)
        sigma = math.sqrt(plan.sigmas[clique])
        if not clique:
            out[clique] = Measurement(clique, v + sigma * rng.standard_normal(1),
                                      plan.sigmas[clique])
            continue
        h1 = [schema.bases[i].Sub for i in clique]
        h2 = [schema.bases[i].Gamma for i in clique]
        zdims = [g.shape[1] for g in h2]
        z = rng.standard_normal(int(np.prod(zdims)))
        hv = kron_matvec_np(h1, v, dims)
        hz = kron_matvec_np(h2, z, zdims)
        out[clique] = Measurement(clique, hv + sigma * hz, plan.sigmas[clique])
    return out


def reconstruct_plus(plan: PlusPlan, measurements: Mapping[Clique, Measurement],
                     clique: Clique) -> np.ndarray:
    """Algorithm 6: residual combine (as in Alg 2) then apply Ŵ = ⊗ W_i."""
    schema = plan.schema
    q = None
    for sub in subsets(clique):
        omega = np.asarray(measurements[sub].omega, dtype=np.float64).reshape(-1)
        if not clique:
            term = omega
        else:
            factors, in_dims = [], []
            for i in clique:
                b = schema.bases[i]
                if i in set(sub):
                    factors.append(b.sub_pinv)
                    in_dims.append(b.Sub.shape[0])
                else:
                    factors.append(np.full((b.n, 1), 1.0 / b.n))
                    in_dims.append(1)
            term = kron_matvec_np(factors, omega, in_dims)
        q = term if q is None else q + term
    if not clique:
        return q
    wfacs = [schema.bases[i].W for i in clique]
    return kron_matvec_np(wfacs, q, [schema.bases[i].n for i in clique])
