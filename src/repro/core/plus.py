r"""ResidualPlanner+ (Section 7): generalized marginals beyond identity queries.

Every attribute i carries a *basic matrix* W_i (identity / prefix-sum / range /
custom; the only requirement is that 1ᵀ lies in W_i's row space) and an optional
*strategy replacement* S_i with row space ⊇ row space of W_i.  Algorithm 4
builds a generalized subtraction matrix Sub_i whose rows span the part of S_i's
row space orthogonal to 1, plus a noise factor Γ_i:

    identity attribute:  Sub_i = Sub_{n}   (Section 4.2),  Γ_i = Sub_i
    otherwise:           P₁ = S_i - S_i 11ᵀ/n,  P₁ᵀP₁ = L Lᵀ (eigh-based
                         factorization; Cholesky is rank-deficient here),
                         Sub_i = P₂ᵀ (independent columns of L),  Γ_i = I.

Base mechanisms, measurement (Alg 5), reconstruction (Alg 6) and the SoV
formula (Thm 8) then follow the ResidualPlanner pattern with these factors.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .domain import Clique, Domain, MarginalWorkload, closure, subsets
from .kron import kron_matvec, kron_matvec_np
from .mechanism import Measurement
from .plantable import BasePlan, PlanTable, sov_closed_form
from .residual import sub_matrix, sub_pinv

# ---------------------------------------------------------------------------
# Basic (workload) matrices
# ---------------------------------------------------------------------------

def w_identity(n: int) -> np.ndarray:
    return np.eye(n)


def w_prefix(n: int) -> np.ndarray:
    """All prefix sums: row i answers 'value <= i' (lower-triangular ones)."""
    return np.tril(np.ones((n, n)))


def w_range(n: int) -> np.ndarray:
    """All n(n+1)/2 contiguous ranges [a, b]."""
    rows = []
    for a in range(n):
        for b in range(a, n):
            r = np.zeros(n)
            r[a:b + 1] = 1.0
            rows.append(r)
    return np.array(rows)


def w_total(n: int) -> np.ndarray:
    return np.ones((1, n))


def build_w(kind: str, n: int) -> np.ndarray:
    return {"identity": w_identity, "prefix": w_prefix,
            "range": w_range, "total": w_total}[kind](n)


def classify_w(W: np.ndarray) -> str:
    """Structural kind of a basic matrix: identity | prefix | range | total | custom.

    The device reconstruction path (engine/plus_engine.py) uses the kind to
    apply W_i *implicitly* — prefix as a cumsum epilogue, range as cumsum +
    prefix-difference — so the O(n²)-row ``w_range`` never enters a dense
    matvec on the hot path (docs/DESIGN.md §8).  Detection is structural, so
    a custom-passed matrix that happens to be a prefix/range matrix still gets
    the implicit path.
    """
    W = np.asarray(W)
    m, n = W.shape
    if m == 1 and np.array_equal(W, np.ones((1, n))):
        return "total"
    if m == n:
        if np.array_equal(W, np.eye(n)):
            return "identity"
        if np.array_equal(W, np.tril(np.ones((n, n)))):
            return "prefix"
    if m == n * (n + 1) // 2 and np.array_equal(W, w_range(n)):
        return "range"
    return "custom"


def s_hierarchical(n: int, branching: int = 2) -> np.ndarray:
    """Hierarchical (H-tree) strategy: identity leaves + interval sums per level.

    A classic strategy replacement for range/prefix workloads [Hay et al.].
    """
    rows = [np.eye(n)]
    width = branching
    while width < n:
        lvl = np.zeros(((n + width - 1) // width, n))
        for j in range(lvl.shape[0]):
            lvl[j, j * width:(j + 1) * width] = 1.0
        rows.append(lvl)
        width *= branching
    rows.append(np.ones((1, n)))
    return np.vstack(rows)


# ---------------------------------------------------------------------------
# Algorithm 4: generalized subtraction matrices
# ---------------------------------------------------------------------------

@dataclass
class AttrBasis:
    """Per-attribute generalized residual data for ResidualPlanner+."""

    n: int
    W: np.ndarray                # basic matrix (rows x n)
    S: np.ndarray                # strategy replacement
    Sub: np.ndarray              # generalized subtraction matrix (r x n), Sub·1 = 0
    Gamma: np.ndarray            # noise factor; cov factor = Γ Γᵀ
    identity: bool
    beta: float                  # max diag of Subᵀ (ΓΓᵀ)⁻¹ Sub  (Thm 7)
    sub_pinv: np.ndarray         # Sub^† (n x r)
    kind: str = "custom"         # classify_w(W): drives the implicit-W epilogue

    @property
    def fnorm2(self) -> float:
        """‖W Sub† Γ‖_F² — the measured-part variance factor in Thm 8."""
        return float(np.linalg.norm(self.W @ self.sub_pinv @ self.Gamma, ord="fro") ** 2)

    @property
    def wones2(self) -> float:
        """‖W 1‖² / n² — the marginalized-part variance factor in Thm 8."""
        return float(np.linalg.norm(self.W @ np.ones(self.n)) ** 2) / self.n ** 2


def attr_basis(W: np.ndarray, S: Optional[np.ndarray] = None,
               tol: float = 1e-9) -> AttrBasis:
    W = np.asarray(W, dtype=np.float64)
    n = W.shape[1]
    S = W if S is None else np.asarray(S, dtype=np.float64)
    # sanity: 1ᵀ must be in the row space of W (paper's only restriction).
    ones = np.ones(n)
    resid = ones - W.T @ np.linalg.lstsq(W.T, ones, rcond=None)[0]
    if np.linalg.norm(resid) > 1e-6 * math.sqrt(n):
        raise ValueError("1ᵀ is not in the row space of W")
    is_identity = W.shape == (n, n) and np.allclose(W, np.eye(n))
    if is_identity and S is W:
        Sub = sub_matrix(n)
        Gamma = Sub.copy()
        spinv = sub_pinv(n)
        gram_inv = np.linalg.inv(Sub @ Sub.T)
        beta = float(np.max(np.diag(Sub.T @ gram_inv @ Sub)))
        return AttrBasis(n, W, S, Sub, Gamma, True, beta, spinv, kind="identity")
    # Algorithm 4 general branch (eigh replaces rank-deficient Cholesky).
    P1 = S - (S @ np.ones((n, 1))) @ np.ones((1, n)) / n
    M = P1.T @ P1
    evals, evecs = np.linalg.eigh(M)
    keep = evals > tol * max(evals.max(), 1.0)
    L = evecs[:, keep] * np.sqrt(evals[keep])          # M = L Lᵀ
    Sub = L.T                                          # rows span rowspace(P1), ⟂ 1
    Gamma = np.eye(Sub.shape[0])
    spinv = np.linalg.pinv(Sub)
    beta = float(np.max(np.einsum("ij,ij->j", Sub, Sub)))   # Γ=I ⇒ diag SubᵀSub
    return AttrBasis(n, W, S, Sub, Gamma, False, beta, spinv, kind=classify_w(W))


@dataclass
class PlusSchema:
    """Domain + per-attribute (W_i, S_i) bases for ResidualPlanner+."""

    domain: Domain
    bases: Tuple[AttrBasis, ...]

    @staticmethod
    def create(domain: Domain, kinds: Sequence[str],
               strategies: Optional[Sequence[Optional[np.ndarray]]] = None,
               strategy_mode: str = "auto") -> "PlusSchema":
        """kinds[i] ∈ {identity, prefix, range, total}; strategy_mode ∈
        {w (S=W), hier, auto (p-Identity optimizer, as in the paper §9)}."""
        bases = []
        for i, attr in enumerate(domain.attributes):
            W = build_w(kinds[i], attr.size)
            S = None if strategies is None else strategies[i]
            if S is None and kinds[i] != "identity":
                if strategy_mode == "hier":
                    S = s_hierarchical(attr.size)
                elif strategy_mode == "auto":
                    from repro.baselines.hdmm import opt_pidentity_projected
                    S = opt_pidentity_projected(W)
                # "w": S stays None -> W
            bases.append(attr_basis(W, S))
        return PlusSchema(domain, tuple(bases))

    def residual_size(self, clique: Clique) -> int:
        out = 1
        for i in clique:
            out *= self.bases[i].Sub.shape[0]
        return out

    def query_rows(self, clique: Clique) -> int:
        out = 1
        for i in clique:
            out *= self.bases[i].W.shape[0]
        return out


# ---------------------------------------------------------------------------
# pcost / variance coefficients (Thms 7 & 8) and selection
# ---------------------------------------------------------------------------

def p_coeff_plus(schema: PlusSchema, clique: Clique) -> float:
    out = 1.0
    for i in clique:
        out *= schema.bases[i].beta
    return out


def sov_coeff_plus(schema: PlusSchema, sub_clique: Clique, clique: Clique) -> float:
    """Coefficient of σ²_{A'} in SoV(Q_Ã) (Thm 8)."""
    if not set(sub_clique) <= set(clique):
        raise ValueError("not a subset")
    out = 1.0
    for i in sub_clique:
        out *= schema.bases[i].fnorm2
    for j in set(clique) - set(sub_clique):
        out *= schema.bases[j].wones2
    return out


def cell_variances_plus(schema: PlusSchema, sigmas: Mapping[Clique, float],
                        clique: Clique) -> np.ndarray:
    """Exact per-cell variance vector of the reconstructed answer to Q_Ã.

    diag(⊗_i Ψ_i Ψ_iᵀ) = ⊗_i diag(Ψ_i Ψ_iᵀ): per-axis diagonal vectors kron'd.
    """
    n_rows = schema.query_rows(clique)
    out = np.zeros(n_rows)
    for sub in subsets(clique):
        diag = np.ones(1)
        for i in clique:
            b = schema.bases[i]
            if i in set(sub):
                psi = b.W @ b.sub_pinv @ b.Gamma
            else:
                psi = (b.W @ np.ones((b.n, 1))) / b.n
            diag = np.kron(diag, np.einsum("ij,ij->i", psi, psi))
        out += sigmas[sub] * diag
    return out


@dataclass(eq=False)
class PlusPlan(BasePlan):
    """A ResidualPlanner+ plan: the unified IR protocol plus the schema.

    ``table`` carries the Thm-7/8 per-axis factors (β_i, ‖W Sub†Γ‖²_F,
    ‖W1‖²/n²), so every SoV/variance query is the same segment-sum the plain
    path uses; ``plan.sigmas[A]`` stays a thin dict view.
    """

    schema: PlusSchema = None

    def sov(self, clique: Clique) -> float:
        return self.table.variance_of(self.sigma, clique)

    def rmse(self) -> float:
        tot = float(self.variances_array().sum())
        cells = sum(self.schema.query_rows(c) for c in self.workload.cliques)
        return math.sqrt(tot / cells)

    def max_cell_variance(self) -> float:
        return max(float(cell_variances_plus(self.schema, self.sigmas, c).max())
                   for c in self.workload.cliques)

    def engine(self, use_kernel=None, precompile: bool = True, dtype=None,
               secure: bool = False, digits: int = 4):
        if secure:
            raise ValueError("secure release (Alg 3) requires a plain "
                             "identity-basis plan; RP+ plans have no "
                             "integer-query rotation")
        from repro.engine.plus_engine import PlusEngine
        return PlusEngine(self, use_kernel=use_kernel,
                          precompile=precompile, dtype=dtype)


def plus_axis_vectors(schema: PlusSchema
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-attribute (β, ‖W Sub†Γ‖²_F, ‖W1‖²/n²) vectors for the IR (Thm 7/8)."""
    beta = np.array([b.beta for b in schema.bases])
    fn2 = np.array([b.fnorm2 for b in schema.bases])
    wo2 = np.array([b.wones2 for b in schema.bases])
    return beta, fn2, wo2


def plan_table_plus(workload: MarginalWorkload, schema: PlusSchema) -> PlanTable:
    """The RP+ PlanTable: same IR, Thm-7/8 per-axis coefficient vectors."""
    beta, fn2, wo2 = plus_axis_vectors(schema)
    return PlanTable.build(workload, axis_pcost=beta, axis_meas=fn2,
                           axis_marg=wo2, axis_cross=None, plain=False)


def select_plus(workload: MarginalWorkload, schema: PlusSchema,
                pcost_budget: float = 1.0, objective: str = "sum_of_variances",
                weights: Optional[Mapping[Clique, float]] = None,
                steps: int = 3000, lr: float = 0.05,
                table: Optional[PlanTable] = None) -> PlusPlan:
    """Selection for RP+ workloads.  SoV is closed form (Lemma 2 applies verbatim
    with generalized p_A, v_A, both straight off the IR); max_variance uses the
    scale-invariant solver on the exact per-cell variance diagonals."""
    table = plan_table_plus(workload, schema) if table is None else table
    cl = table.cliques
    index = table.index
    p = table.p
    if weights is None:
        v = table.v
    else:
        w = table.weight_vector(weights, default_to_workload=True)
        v = np.bincount(table.inc_cols,
                        weights=w[table.inc_rows] * table.inc_vals,
                        minlength=table.n)

    if objective in ("sum_of_variances", "sov", "rmse"):
        sig = sov_closed_form(p, v, pcost_budget)
        return PlusPlan(table, sig, objective, pcost=table.pcost(sig),
                        loss_value=float(np.dot(v, sig)), schema=schema)

    if objective in ("max_variance", "maxvar"):
        # Per-cell variance rows: Var_cell = D u with D (total_cells x |closure|).
        rows, cols, vals = [], [], []
        row0 = 0
        for wc in workload.cliques:
            imp = float((weights or {}).get(wc, workload.weight(wc)))
            ncells = schema.query_rows(wc)
            for sub in subsets(wc):
                diag = np.ones(1)
                for i in wc:
                    b = schema.bases[i]
                    psi = (b.W @ b.sub_pinv @ b.Gamma) if i in set(sub) \
                        else (b.W @ np.ones((b.n, 1))) / b.n
                    diag = np.kron(diag, np.einsum("ij,ij->i", psi, psi))
                for r in range(ncells):
                    if diag[r] != 0.0:
                        rows.append(row0 + r)
                        cols.append(index[sub])
                        vals.append(diag[r] / imp)
            row0 += ncells
        m = row0
        rows_j = jnp.asarray(np.array(rows, np.int32))
        cols_j = jnp.asarray(np.array(cols, np.int32))
        vals_j = jnp.asarray(np.array(vals))
        p_j = jnp.asarray(p)

        warm_sig = np.sqrt(np.maximum(p, 1e-12) / np.maximum(v, 1e-12))
        warm_sig *= float(np.sum(p / warm_sig))  # normalize pcost to 1 then scale
        theta0 = jnp.log(jnp.asarray(warm_sig / pcost_budget))
        tau0 = float(np.median(vals)) * float(np.exp(theta0).mean()) + 1e-12

        def smooth_obj(theta, tau):
            u = jnp.exp(theta)
            var = jax.ops.segment_sum(vals_j * u[cols_j], rows_j, num_segments=m)
            L = tau * jax.scipy.special.logsumexp(var / tau)
            return jnp.log(jnp.sum(p_j / u)) + jnp.log(L)

        @jax.jit
        def run(theta0):
            def step(carry, i):
                theta, mo, ve = carry
                tau = tau0 * 10.0 ** (-3.0 * i / steps)
                g = jax.grad(smooth_obj)(theta, tau)
                mo = 0.9 * mo + 0.1 * g
                ve = 0.999 * ve + 0.001 * g * g
                mh = mo / (1 - 0.9 ** (i + 1.0))
                vh = ve / (1 - 0.999 ** (i + 1.0))
                return (theta - lr * mh / (jnp.sqrt(vh) + 1e-9), mo, ve), None
            (theta, _, _), _ = jax.lax.scan(step, (theta0, jnp.zeros_like(theta0),
                                                   jnp.zeros_like(theta0)),
                                            jnp.arange(steps))
            return theta

        u = np.exp(np.asarray(run(theta0), dtype=np.float64))
        u *= float(np.sum(p / u)) / pcost_budget
        # fp64 loss at the solution, set at construction (never patched after).
        sig_map = dict(zip(cl, map(float, u)))
        loss_value = max(float(cell_variances_plus(schema, sig_map, c).max())
                         for c in workload.cliques)
        return PlusPlan(table, u, objective, pcost=table.pcost(u),
                        loss_value=loss_value, schema=schema)

    raise ValueError(objective)


# ---------------------------------------------------------------------------
# Measurement (Alg 5) and reconstruction (Alg 6)
# ---------------------------------------------------------------------------

def measure_plus_np(plan: PlusPlan, marginals: Mapping[Clique, np.ndarray],
                    rng) -> Dict[Clique, Measurement]:
    out: Dict[Clique, Measurement] = {}
    schema = plan.schema
    for clique in plan.cliques:
        dims = [schema.bases[i].n for i in clique]
        v = np.asarray(marginals[clique], dtype=np.float64).reshape(-1)
        sigma = math.sqrt(plan.sigmas[clique])
        if not clique:
            out[clique] = Measurement(clique, v + sigma * rng.standard_normal(1),
                                      plan.sigmas[clique])
            continue
        h1 = [schema.bases[i].Sub for i in clique]
        h2 = [schema.bases[i].Gamma for i in clique]
        zdims = [g.shape[1] for g in h2]
        z = rng.standard_normal(int(np.prod(zdims)))
        hv = kron_matvec_np(h1, v, dims)
        hz = kron_matvec_np(h2, z, zdims)
        out[clique] = Measurement(clique, hv + sigma * hz, plan.sigmas[clique])
    return out


def reconstruct_plus(plan: PlusPlan, measurements: Mapping[Clique, Measurement],
                     clique: Clique) -> np.ndarray:
    """Algorithm 6: residual combine (as in Alg 2) then apply Ŵ = ⊗ W_i."""
    schema = plan.schema
    q = None
    for sub in subsets(clique):
        omega = np.asarray(measurements[sub].omega, dtype=np.float64).reshape(-1)
        if not clique:
            term = omega
        else:
            factors, in_dims = [], []
            for i in clique:
                b = schema.bases[i]
                if i in set(sub):
                    factors.append(b.sub_pinv)
                    in_dims.append(b.Sub.shape[0])
                else:
                    factors.append(np.full((b.n, 1), 1.0 / b.n))
                    in_dims.append(1)
            term = kron_matvec_np(factors, omega, in_dims)
        q = term if q is None else q + term
    if not clique:
        return q
    wfacs = [schema.bases[i].W for i in clique]
    return kron_matvec_np(wfacs, q, [schema.bases[i].n for i in clique])


# ---------------------------------------------------------------------------
# Chain factors for the device engine (docs/DESIGN.md §8)
# ---------------------------------------------------------------------------

def plus_axis_token(basis: AttrBasis) -> tuple:
    """Hashable per-axis signature token for generalized batching.

    Plain marginals batch on attribute *size* because ``Sub_n`` is fully
    determined by n.  Here Γ_i ≠ Sub_i for non-identity bases and the factor
    values depend on (W_i, S_i), so the token carries the factor shapes (the
    kernel jit-cache key) plus value digests (stacking rows into one chain
    additionally requires equal factor *values* — a digest collision would
    silently measure cliques with the wrong factors, so the digest is
    cryptographic, not a checksum).  Construction is deterministic, so equal
    (W, S) inputs yield equal tokens.
    """
    import hashlib

    def _dig(a: np.ndarray) -> bytes:
        return hashlib.blake2b(
            np.ascontiguousarray(a, dtype=np.float64).tobytes(),
            digest_size=16).digest()

    return (basis.n, basis.kind, basis.Sub.shape, basis.Gamma.shape,
            basis.W.shape, _dig(basis.Sub), _dig(basis.Gamma), _dig(basis.W))


def plus_signature_groups(schema: PlusSchema, cliques: Sequence[Clique]
                          ) -> Dict[tuple, List[Clique]]:
    """Group cliques by generalized per-axis ``(Sub_i, Γ_i, W_i)`` signature."""
    from .mechanism import signature_groups
    tokens = [plus_axis_token(b) for b in schema.bases]
    return signature_groups(schema.domain, cliques,
                            axis_key=lambda i: tokens[i])


def measure_chain_split(schema: PlusSchema, clique: Clique):
    """Factors of the staged Alg 5 measurement chains (docs/DESIGN.md §8).

    ω = (⊗ Sub_i) v + σ (⊗ Γ_i) z splits per axis: identity-basis axes have
    Γ_i = Sub_i (both streams share the factor), general axes have Γ_i = I
    (the noise stream skips the axis).  Stage A applies the general-axis
    ``Sub_i`` to the v rows only (input dims ``dims`` → ``zdims``); stage B
    applies the identity-axis ``Sub_i`` to the stacked [v'; z] rows at input
    dims ``zdims``.  All-identity cliques degenerate to the plain-marginal
    single chain; all-general cliques need no stage B chain at all.

    Returns ``(dims, zdims, stage_a, stage_b)``.
    """
    dims: List[int] = []
    zdims: List[int] = []
    stage_a: List[Optional[np.ndarray]] = []
    stage_b: List[Optional[np.ndarray]] = []
    for i in clique:
        b = schema.bases[i]
        dims.append(b.n)
        zdims.append(b.Gamma.shape[1])
        if b.identity:
            stage_a.append(None)
            stage_b.append(b.Sub)
        else:
            stage_a.append(b.Sub)
            stage_b.append(None)
    return dims, zdims, stage_a, stage_b


def t_chain_factors_plus(schema: PlusSchema, clique: Clique) -> List[np.ndarray]:
    """Per-axis factors T_i = [ Sub_i^† | (1/n_i)·1 ]  (n_i × (r_i+1)).

    The PR-1 merged-subset identity (core/reconstruct.py, docs/DESIGN.md §5)
    generalizes verbatim: for every A' ⊆ A, U_{A←A'} ω_{A'} equals
    (⊗_{i∈A} T_i) e_{A'} with ω_{A'} embedded at axis-i slots 0..r_i−1 when
    i ∈ A' and slot r_i otherwise — distinct subsets occupy disjoint slot
    regions, so Algorithm 6's 2^|A| subset matvecs collapse into ONE chain.
    """
    out = []
    for i in clique:
        b = schema.bases[i]
        out.append(np.hstack([b.sub_pinv, np.full((b.n, 1), 1.0 / b.n)]))
    return out


def embed_subset_answers_plus(plan: PlusPlan,
                              measurements: Mapping[Clique, Measurement],
                              clique: Clique, dtype=np.float64) -> np.ndarray:
    """Sum of subset embeddings Σ_{A'⊆A} e_{A'} — input of the merged T-chain."""
    from .reconstruct import subset_slot_region
    schema = plan.schema
    rdims = tuple(schema.bases[i].Sub.shape[0] + 1 for i in clique)
    t = np.zeros(rdims, dtype=dtype)
    for sub in subsets(clique):
        region, shape = subset_slot_region(clique, sub, rdims)
        t[region] = np.asarray(measurements[sub].omega,
                               dtype=dtype).reshape(shape)
    return t


def reconstruct_plus_merged(plan: PlusPlan,
                            measurements: Mapping[Clique, Measurement],
                            clique: Clique) -> np.ndarray:
    """Float64 oracle of the merged-chain Algorithm 6: one chain ⊗ (W_i T_i).

    Numerically identical (1e-9) to :func:`reconstruct_plus`; the device
    engine (engine/plus_engine.py) runs the same merged chain batched, with
    prefix/range W_i applied implicitly instead of via the dense product.
    """
    if not clique:
        return np.asarray(measurements[()].omega, dtype=np.float64).reshape(-1)
    schema = plan.schema
    t = embed_subset_answers_plus(plan, measurements, clique)
    facs = [schema.bases[i].W @ tf
            for i, tf in zip(clique, t_chain_factors_plus(schema, clique))]
    return kron_matvec_np(facs, t.reshape(-1), t.shape)
