r"""Batched exact discrete Gaussian sampling (CKS'20) over integer lanes.

The secure release path (Section 5 / Algorithm 3, :mod:`repro.core.discrete`)
adds exact discrete Gaussian noise ``N_Z(0, γ²)`` with γ² = σ̄²·Π n_i² — a
*rational* variance whose numerator routinely exceeds both float64 range and
int64 range on large cliques.  The seed-era sampler drew one value at a time
through a recursive ``fractions.Fraction`` implementation; this module is the
same CKS'20 rejection scheme (dLaplace proposal + Bernoulli-exp acceptance)
re-expressed as **vectorized rejection rounds over numpy integer lanes**:

* all probabilities are exact rationals ``num/den`` held as integer arrays —
  no floating point ever touches the noise path;
* uniform integers below a bound come from pooled numpy draws:
  ``Generator.integers`` (Lemire, unbiased) while the bound fits int64, and a
  mask-and-reject composition of 32-bit words on an object-dtype (Python
  big-int) array beyond that — the **automatic big-int fallback** that makes
  γ² at Πn_i ~ 10²⁰ scale (γ² ≳ 10⁴⁰) work instead of overflowing;
* each CKS subroutine (Bernoulli(p), Bernoulli(exp(-γ)), discrete Laplace,
  the final accept/reject) runs as a while-any-lane-active loop whose rounds
  shrink geometrically, so the expected number of numpy calls is
  O(log lanes + 1) regardless of ``size``.

The distribution is *identical* to the serial sampler's (both are exact);
only the consumption order of the underlying randomness differs, so the two
paths are seed-deterministic individually but not bit-aligned with each
other.  ``sample`` is the single entry point; ``measure_discrete`` and the
:class:`~repro.engine.discrete_engine.DiscreteEngine` both draw through it.
"""
from __future__ import annotations

import math
import numbers
import random
from fractions import Fraction
from typing import Tuple, Union

import numpy as np

# Bounds strictly below 2**62 stay on the int64 lane path; beyond it every
# uniform is composed from 32-bit words on an object-dtype array.
_INT62 = 1 << 62
_WORD = 32


def as_integer_ratio(sigma2: Union[int, Fraction]) -> Tuple[int, int]:
    """Exact ``(numerator, denominator)`` of a positive variance.

    Floats are rejected: a float γ² silently changes the sampled distribution
    (the privacy proof needs the *exact* rational), and overflowing γ² is the
    very bug this module fixes.
    """
    if isinstance(sigma2, float) or not isinstance(sigma2, numbers.Rational):
        raise TypeError(
            f"sigma2 must be an exact int or Fraction, got {type(sigma2).__name__}")
    a, b = int(sigma2.numerator), int(sigma2.denominator)
    if a <= 0 or b <= 0:
        raise ValueError(f"sigma2 must be positive, got {sigma2}")
    return a, b


def _uniform_below(bound: int, size: int, rng: np.random.Generator) -> np.ndarray:
    """``size`` exact uniform integers in [0, bound), vectorized.

    int64 lanes while the bound allows; otherwise big-int lanes built from
    pooled 32-bit words with top-word masking + rejection (≤ 2 expected
    rounds).  Both paths are unbiased.
    """
    if bound <= 0:
        raise ValueError(f"bound must be positive, got {bound}")
    if bound <= _INT62:
        return rng.integers(0, bound, size=size, dtype=np.int64)
    bits = bound.bit_length()
    nwords = -(-bits // _WORD)
    top_mask = (1 << (bits - _WORD * (nwords - 1))) - 1
    out = np.empty(size, dtype=object)
    pending = np.arange(size)
    while pending.size:
        words = rng.integers(0, 1 << _WORD, size=(pending.size, nwords),
                             dtype=np.int64)
        words[:, 0] &= top_mask
        val = words[:, 0].astype(object)
        for j in range(1, nwords):
            val = val * (1 << _WORD) + words[:, j]
        ok = val < bound
        out[pending[ok]] = val[ok]
        pending = pending[~ok]
    return out


def _bernoulli(num: np.ndarray, den: int, rng: np.random.Generator) -> np.ndarray:
    """Exact per-lane Bernoulli(num_i/den) (shared denominator)."""
    u = _uniform_below(den, len(num), rng)
    return np.asarray(u < num, dtype=bool)


def _bernoulli_exp_frac(num: np.ndarray, den: int,
                        rng: np.random.Generator) -> np.ndarray:
    """Per-lane Bernoulli(exp(-num_i/den)) for 0 ≤ num_i ≤ den (CKS Alg 1).

    The serial algorithm draws Bernoulli(γ/k) for k = 1, 2, … until the first
    failure and returns "k is odd"; here every round serves all still-active
    lanes with one pooled draw.  Active lanes halve at least geometrically
    (the continue probability at round k is γ/k ≤ 1/k), so rounds are few.
    """
    n = len(num)
    result = np.zeros(n, dtype=bool)
    active = np.arange(n)
    num = np.asarray(num)
    k = 1
    while active.size:
        a = _bernoulli(num[active], den * k, rng)
        result[active[~a]] = (k % 2 == 1)
        active = active[a]
        k += 1
    return result


def _bernoulli_exp1(size: int, rng: np.random.Generator) -> np.ndarray:
    """Bernoulli(exp(-1)) lanes — the γ = 1 boundary case of Alg 1."""
    return _bernoulli_exp_frac(np.ones(size, dtype=np.int64), 1, rng)


def _bernoulli_exp(num: np.ndarray, den: int,
                   rng: np.random.Generator) -> np.ndarray:
    """Per-lane Bernoulli(exp(-num_i/den)) for arbitrary num_i ≥ 0.

    Integer part: lane i must survive ⌊num_i/den⌋ independent
    Bernoulli(exp(-1)) draws — run as rounds over the lanes still alive and
    still owing draws (each dies with probability 1-1/e per round, so the
    loop ends long before pathological ⌊γ⌋ values are exhausted).
    Fractional part: one Alg-1 call on the survivors.
    """
    num = np.asarray(num)
    q = num // den
    r = num - q * den
    alive = np.ones(len(num), dtype=bool)
    rounds = 0
    while True:
        idx = np.flatnonzero(alive & (q > rounds))
        if not idx.size:
            break
        a = _bernoulli_exp1(idx.size, rng)
        alive[idx[~a]] = False
        rounds += 1
    idx = np.flatnonzero(alive & (r > 0))
    if idx.size:
        a = _bernoulli_exp_frac(r[idx], den, rng)
        alive[idx[~a]] = False
    return alive


def _sample_dlaplace(t: int, size: int, rng: np.random.Generator) -> np.ndarray:
    """Vectorized exact discrete Laplace, P(x) ∝ exp(-|x|/t) (CKS Alg 2).

    Returns int64 lanes when every magnitude provably fits, object lanes
    otherwise (t beyond ~2⁴⁰ — magnitudes are u + t·v with v geometric).
    """
    small = t < (1 << 40)
    out = np.empty(size, dtype=np.int64 if small else object)
    filled = 0
    while filled < size:
        # Candidates are iid, so surplus accepted values can be discarded and
        # shortfalls refilled: oversampling (~1/0.6 acceptance) collapses the
        # shrinking-lane tail into ~1-2 full-width rounds of numpy calls.
        m = size - filled + (size - filled) // 2 + 16
        u = _uniform_below(t, m, rng)
        ok = _bernoulli_exp_frac(u, t, rng)
        v = np.zeros(m, dtype=np.int64)
        act = np.flatnonzero(ok)
        while act.size:                       # geometric run of exp(-1) successes
            a = _bernoulli_exp1(act.size, rng)
            v[act[a]] += 1
            act = act[a]
        if small:
            x = u + t * v
        else:
            x = u.astype(object) + t * v.astype(object)
        neg = rng.integers(0, 2, size=m, dtype=np.int64).astype(bool)
        good = ok & ~(neg & (x == 0))         # resample "-0"
        x = np.where(good & neg, -x, x)       # object arrays negate elementwise
        vals = x[good]
        k = min(len(vals), size - filled)
        out[filled:filled + k] = vals[:k]
        filled += k
    return out


def as_np_rng(rng) -> np.random.Generator:
    """Normalize a randomness source to ``np.random.Generator``.

    ``random.Random`` seeds a Generator from its stream (deterministic given
    the Random's state); a Generator passes through.  jax keys are handled by
    the engine layer, which owns the key→seed convention.
    """
    if isinstance(rng, np.random.Generator):
        return rng
    if isinstance(rng, random.Random):
        return np.random.default_rng(rng.getrandbits(128))
    raise TypeError(f"expected np.random.Generator or random.Random, "
                    f"got {type(rng).__name__}")


def sample(sigma2: Union[int, Fraction], size: int, rng) -> np.ndarray:
    """``size`` exact draws from N_Z(0, σ²): P(x) ∝ exp(-x²/2σ²) (CKS Alg 3).

    The single batched entry point of the secure noise path.  σ² is an exact
    int/Fraction (floats are rejected); ``rng`` is an ``np.random.Generator``
    (or ``random.Random``, from which a Generator is seeded).  Candidates come
    from the vectorized discrete Laplace at scale t = ⌊√σ²⌋+1 and are accepted
    with probability exp(-(|y| - σ²/t)²/(2σ²)); with σ² = a/b the acceptance
    odds are the exact rational

        (|y|·b·t - a)² / (2·a·b·t²)

    evaluated per lane in integer arithmetic (object dtype for the numerator:
    its square exceeds int64 even at modest γ²).  Returns int64 when every
    accepted value fits, object (Python big-int) lanes otherwise.
    """
    a, b = as_integer_ratio(sigma2)
    if size < 0:
        raise ValueError(f"size must be >= 0, got {size}")
    rng = as_np_rng(rng)
    if size == 0:
        return np.empty(0, dtype=np.int64)
    t = math.isqrt(a // b) + 1
    bt = b * t
    den = 2 * a * b * t * t
    out = np.empty(size, dtype=object)
    filled = 0
    while filled < size:
        # Oversample for the ~e^{-1/2} Alg-3 acceptance rate; candidates are
        # iid so surplus accepts are dropped and shortfalls refilled.
        m = 2 * (size - filled) + 16
        y = _sample_dlaplace(t, m, rng)
        num = (np.abs(y).astype(object) * bt - a) ** 2
        acc = _bernoulli_exp(num, den, rng)
        vals = y[acc]
        k = min(len(vals), size - filled)
        out[filled:filled + k] = vals[:k]
        filled += k
    if t < (1 << 40):                         # dLaplace lanes were int64 already
        return out.astype(np.int64)
    if max(abs(int(v)) for v in out) < _INT62:
        return out.astype(np.int64)
    return out
