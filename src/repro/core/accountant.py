"""Privacy accounting: pcost → ρ-zCDP / (ε,δ)-approximate DP / μ-GDP (Def. 2).

The privacy cost of a linear Gaussian mechanism is the largest diagonal of
``Bᵀ Σ⁻¹ B``; the paper's Definition 2 converts it to the three DP flavours.
This module is also used by the DP-SGD integration (train/dp.py): clipped
per-example gradients with Gaussian noise are a linear Gaussian mechanism
with ``pcost = (C/σ)²`` per step, composed additively.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def _phi(x: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def _log_phi(x: float) -> float:
    """log Φ(x), finite for arbitrarily negative x.

    ``erfc`` underflows to 0 near x ≈ -37.5; below that the standard
    asymptotic Φ(x) ≈ φ(x)/(-x) takes over (relative error < 1/x² there).
    """
    if x > -37.0:
        return math.log(0.5 * math.erfc(-x / math.sqrt(2.0)))
    return -0.5 * x * x - math.log(-x) - 0.5 * math.log(2.0 * math.pi)


def zcdp_rho(pcost: float) -> float:
    return pcost / 2.0


def gdp_mu(pcost: float) -> float:
    return math.sqrt(pcost)


def approx_dp_delta(pcost: float, eps: float) -> float:
    """δ as a function of ε for a mechanism with the given pcost (Def. 2, [5]).

    The ``exp(ε)·Φ(·)`` term is evaluated in log space — the naive product is
    ``inf · 0 = nan`` for ε ≳ 709 — and the result is clamped to [0, 1]:
    the two Φ terms cancel catastrophically at large pcost/ε and used to
    return small negative δ.
    """
    if pcost <= 0:
        return 0.0
    r = math.sqrt(pcost)
    # term2 = exp(eps)·Φ(-r/2 - eps/r) ≤ δ's first term ≤ 1 mathematically;
    # the exponent cap only guards float round-up at the boundary.
    term2 = math.exp(min(eps + _log_phi(-r / 2.0 - eps / r), 1.0))
    delta = _phi(r / 2.0 - eps / r) - term2
    return min(1.0, max(0.0, delta))


def approx_dp_eps(pcost: float, delta: float, hi: float = 200.0) -> float:
    """Invert δ(ε) by bisection (δ is decreasing in ε)."""
    if pcost <= 0:
        return 0.0
    lo = 0.0
    if approx_dp_delta(pcost, lo) <= delta:
        return 0.0
    for _ in range(200):
        mid = (lo + hi) / 2.0
        if approx_dp_delta(pcost, mid) > delta:
            lo = mid
        else:
            hi = mid
    return hi


def pcost_for_rho(rho: float) -> float:
    return 2.0 * rho


def pcost_for_mu(mu: float) -> float:
    return mu * mu


def pcost_for_eps_delta(eps: float, delta: float, hi_cap: float = 1e12) -> float:
    """Largest pcost whose (ε,δ) curve passes under the target (bisection).

    Contract: ``delta`` must lie strictly inside (0, 1) and ``eps`` must be
    non-negative; a target the δ(pcost) curve cannot reach below ``hi_cap``
    raises ``ValueError`` (the historical version broke out of the doubling
    loop silently and bisected against an unreachable target, returning an
    arbitrary interior point).
    """
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    if eps < 0.0:
        raise ValueError(f"eps must be >= 0, got {eps}")
    lo, hi = 0.0, 1.0
    while approx_dp_delta(hi, eps) < delta:
        hi *= 2.0
        if hi > hi_cap:
            raise ValueError(
                f"(eps={eps}, delta={delta}) unreachable: delta({hi_cap:g}, "
                f"eps) = {approx_dp_delta(hi_cap, eps):g} < delta")
    for _ in range(200):
        mid = (lo + hi) / 2.0
        if approx_dp_delta(mid, eps) < delta:
            lo = mid
        else:
            hi = mid
    return lo


class BudgetExhausted(ValueError):
    """A charge would exceed the remaining privacy budget.

    Subclasses ``ValueError`` for backward compatibility with callers that
    catch the historical exception.  Carries the exact remaining budget in
    both pcost and ρ-zCDP units so serving layers (the ledger, the release
    server) can surface an actionable rejection without re-deriving it.
    """

    def __init__(self, requested_pcost: float, remaining_pcost: float,
                 tenant: str = ""):
        self.requested_pcost = float(requested_pcost)
        self.remaining_pcost = float(remaining_pcost)
        self.tenant = tenant
        who = f" for tenant {tenant!r}" if tenant else ""
        super().__init__(
            f"privacy budget exhausted{who}: need pcost={self.requested_pcost:.12g} "
            f"(rho={self.requested_rho:.12g}), have pcost={self.remaining_pcost:.12g} "
            f"(rho={self.remaining_rho:.12g})")

    @property
    def requested_rho(self) -> float:
        return zcdp_rho(self.requested_pcost)

    @property
    def remaining_rho(self) -> float:
        return zcdp_rho(self.remaining_pcost)


@dataclass
class PrivacyBudget:
    """A total pcost budget with sequential-composition tracking."""

    total_pcost: float
    spent: float = 0.0

    @staticmethod
    def from_zcdp(rho: float) -> "PrivacyBudget":
        return PrivacyBudget(pcost_for_rho(rho))

    @staticmethod
    def from_gdp(mu: float) -> "PrivacyBudget":
        return PrivacyBudget(pcost_for_mu(mu))

    @staticmethod
    def from_approx_dp(eps: float, delta: float) -> "PrivacyBudget":
        return PrivacyBudget(pcost_for_eps_delta(eps, delta))

    @property
    def remaining(self) -> float:
        return max(0.0, self.total_pcost - self.spent)

    @property
    def remaining_rho(self) -> float:
        return zcdp_rho(self.remaining)

    def can_charge(self, pcost: float) -> bool:
        return pcost <= self.remaining + 1e-12

    def charge(self, pcost: float, tenant: str = "") -> None:
        if not self.can_charge(pcost):
            raise BudgetExhausted(pcost, self.remaining, tenant)
        self.spent += pcost

    def report(self) -> dict:
        return {
            "pcost_total": self.total_pcost,
            "pcost_spent": self.spent,
            "rho_zcdp": zcdp_rho(self.spent),
            "mu_gdp": gdp_mu(self.spent),
            "eps_at_delta_1e-6": approx_dp_eps(self.spent, 1e-6) if self.spent else 0.0,
        }
