"""Privacy accounting: pcost → ρ-zCDP / (ε,δ)-approximate DP / μ-GDP (Def. 2).

The privacy cost of a linear Gaussian mechanism is the largest diagonal of
``Bᵀ Σ⁻¹ B``; the paper's Definition 2 converts it to the three DP flavours.
This module is also used by the DP-SGD integration (train/dp.py): clipped
per-example gradients with Gaussian noise are a linear Gaussian mechanism
with ``pcost = (C/σ)²`` per step, composed additively.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def _phi(x: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def zcdp_rho(pcost: float) -> float:
    return pcost / 2.0


def gdp_mu(pcost: float) -> float:
    return math.sqrt(pcost)


def approx_dp_delta(pcost: float, eps: float) -> float:
    """δ as a function of ε for a mechanism with the given pcost (Def. 2, [5])."""
    if pcost <= 0:
        return 0.0
    r = math.sqrt(pcost)
    return _phi(r / 2.0 - eps / r) - math.exp(eps) * _phi(-r / 2.0 - eps / r)


def approx_dp_eps(pcost: float, delta: float, hi: float = 200.0) -> float:
    """Invert δ(ε) by bisection (δ is decreasing in ε)."""
    if pcost <= 0:
        return 0.0
    lo = 0.0
    if approx_dp_delta(pcost, lo) <= delta:
        return 0.0
    for _ in range(200):
        mid = (lo + hi) / 2.0
        if approx_dp_delta(pcost, mid) > delta:
            lo = mid
        else:
            hi = mid
    return hi


def pcost_for_rho(rho: float) -> float:
    return 2.0 * rho


def pcost_for_mu(mu: float) -> float:
    return mu * mu


def pcost_for_eps_delta(eps: float, delta: float) -> float:
    """Largest pcost whose (ε,δ) curve passes under the target (bisection)."""
    lo, hi = 0.0, 1.0
    while approx_dp_delta(hi, eps) < delta:
        hi *= 2.0
        if hi > 1e9:
            break
    for _ in range(200):
        mid = (lo + hi) / 2.0
        if approx_dp_delta(mid, eps) < delta:
            lo = mid
        else:
            hi = mid
    return lo


@dataclass
class PrivacyBudget:
    """A total pcost budget with sequential-composition tracking."""

    total_pcost: float
    spent: float = 0.0

    @staticmethod
    def from_zcdp(rho: float) -> "PrivacyBudget":
        return PrivacyBudget(pcost_for_rho(rho))

    @staticmethod
    def from_gdp(mu: float) -> "PrivacyBudget":
        return PrivacyBudget(pcost_for_mu(mu))

    @staticmethod
    def from_approx_dp(eps: float, delta: float) -> "PrivacyBudget":
        return PrivacyBudget(pcost_for_eps_delta(eps, delta))

    @property
    def remaining(self) -> float:
        return max(0.0, self.total_pcost - self.spent)

    def charge(self, pcost: float) -> None:
        if pcost > self.remaining + 1e-12:
            raise ValueError(f"privacy budget exhausted: need {pcost}, have {self.remaining}")
        self.spent += pcost

    def report(self) -> dict:
        return {
            "pcost_total": self.total_pcost,
            "pcost_spent": self.spent,
            "rho_zcdp": zcdp_rho(self.spent),
            "mu_gdp": gdp_mu(self.spent),
            "eps_at_delta_1e-6": approx_dp_eps(self.spent, 1e-6) if self.spent else 0.0,
        }
