r"""Attribute partitioning for divide-and-conquer planning (DESIGN.md §12).

The monolithic PlanTable IR tops out where the downward closure stops fitting
in memory (d=100 all-≤3-way is 166k cliques / 1.3M incidence entries; d=500
would be 20M+).  Following "Accurate and Scalable Matrix Mechanisms via
Divide and Conquer" (PAPERS.md, arXiv 2604.00868), this module splits the
attribute set into *blocks* so each block's sub-workload closes over a small
clique set and can be planned independently:

* :func:`partition_attributes` — blocks from the workload's
  clique-interaction graph.  Connected components are used *exactly* (no
  workload clique straddles a component cut, so D&C is lossless there); when
  the graph is connected — or the user passes ``blocks=`` / ``max_block=`` —
  oversized components are split by a greedy min-cut heuristic (weighted
  greedy graph-growing: repeatedly attach the attribute with the heaviest
  edge weight into an open block, ties toward the emptiest block).

* :func:`decompose` — the workload restricted to each block.  A clique fully
  inside a block keeps its importance; a clique that straddles a cut is
  *projected*: each nonempty intersection with a block joins that block's
  sub-workload (importance accumulated), and the full marginal is later
  re-assembled by the **product-of-blocks correction** — the straddling
  marginal is estimated as the normalized outer product of its per-block
  projections (an independence approximation across the cut; DESIGN.md §12
  documents the variance proxy).  All bookkeeping (which row lives where,
  which flat parts belong to which straddler) is emitted as index arrays so
  the composite plan's variance assembly is pure segment-sums — the
  straddler scan itself is vectorized per size class, never a per-clique
  Python loop.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .domain import Clique, Domain, MarginalWorkload
from .plantable import _group_by_len

BlocksSpec = Union[None, int, Sequence[Sequence[int]]]

#: default block-size cap when a forced split must pick one (≈ the largest
#: all-≤3-way closure that still builds in tens of milliseconds).
DEFAULT_MAX_BLOCK = 32

# row_block markers for workload rows that are not plain in-block cliques
ROW_STRADDLER = -1
ROW_EMPTY = -2


@dataclass(frozen=True)
class Partition:
    """Disjoint attribute blocks covering every attribute the workload uses."""

    domain: Domain
    blocks: Tuple[Clique, ...]        # sorted attr tuples, disjoint
    cut_weight: float                 # Σ Imp_A over straddling cliques

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def block_of_array(self) -> np.ndarray:
        """(n_attrs,) block id per attribute (-1: unused by the workload)."""
        out = np.full(self.domain.n_attrs, -1, np.int64)
        for b, attrs in enumerate(self.blocks):
            out[list(attrs)] = b
        return out


def interaction_weights(workload: MarginalWorkload
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """(active-attribute mask, dense symmetric co-occurrence weight matrix).

    Edge (i, j) accumulates Imp_A over every workload clique containing both
    attributes — vectorized per size class (one ``np.add.at`` per column
    pair), so a d=500 all-≤2-way workload scans in milliseconds.
    """
    d = workload.domain.n_attrs
    adj = np.zeros((d, d))
    active = np.zeros(d, bool)
    w = workload.weight_array()
    for k, (ridx, mat) in _group_by_len(workload.cliques).items():
        if k == 0:
            continue
        active[np.unique(mat)] = True
        wk = w[ridx]
        for j1 in range(k):
            for j2 in range(j1 + 1, k):
                np.add.at(adj, (mat[:, j1], mat[:, j2]), wk)
    adj += adj.T
    return active, adj


def _connected_components(active: np.ndarray, adj: np.ndarray) -> List[List[int]]:
    """Union-find over the nonzero edges among active attributes."""
    parent = np.arange(len(active))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ei, ej = np.nonzero(np.triu(adj, 1))
    for a, b in zip(ei.tolist(), ej.tolist()):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    comps: Dict[int, List[int]] = {}
    for a in np.nonzero(active)[0].tolist():
        comps.setdefault(find(a), []).append(a)
    return sorted(comps.values(), key=lambda c: c[0])


def _greedy_split(comp: List[int], adj: np.ndarray, g: int) -> List[List[int]]:
    """Split one component into ``g`` balanced blocks, greedily minimizing cut.

    Weighted greedy graph-growing: seed each block with the heaviest-degree
    unassigned attribute, then repeatedly place the attribute with the
    largest total edge weight into any non-full block (ties toward the
    emptiest block).  O(|comp|² · g) — fine for the ≤ thousands of
    attributes this planner targets.
    """
    comp = sorted(comp)
    nc = len(comp)
    g = max(1, min(g, nc))
    if g == 1:
        return [comp]
    cap = math.ceil(nc / g)
    sub = adj[np.ix_(comp, comp)]
    degree = sub.sum(axis=1)
    unassigned = set(range(nc))
    blocks: List[List[int]] = [[] for _ in range(g)]
    # attach[i, b] = total edge weight from local attr i into block b
    attach = np.zeros((nc, g))
    for b in range(g):
        if not unassigned:
            break
        seed = max(unassigned, key=lambda i: (degree[i], -i))
        blocks[b].append(seed)
        unassigned.discard(seed)
        attach[:, b] += sub[:, seed]
    while unassigned:
        open_b = [b for b in range(g) if len(blocks[b]) < cap]
        fill = np.array([len(blocks[b]) for b in open_b], dtype=float)
        cand = np.fromiter(unassigned, np.int64, count=len(unassigned))
        gain = attach[np.ix_(cand, open_b)] - 1e-12 * fill
        ci, bi = np.unravel_index(int(np.argmax(gain)), gain.shape)
        i, b = int(cand[ci]), open_b[int(bi)]
        blocks[b].append(i)
        unassigned.discard(i)
        attach[:, b] += sub[:, i]
    return [sorted(comp[i] for i in blk) for blk in blocks if blk]


def partition_attributes(workload: MarginalWorkload, blocks: BlocksSpec = None,
                         max_block: Optional[int] = None) -> Partition:
    """Blocks from the clique-interaction graph (DESIGN.md §12).

    * default: the connected components, exactly — no clique straddles a cut;
    * ``max_block=s``: components larger than ``s`` are split by the greedy
      min-cut heuristic into ``ceil(size/s)`` blocks;
    * ``blocks=g`` (int): components are split (largest first) until at least
      ``g`` blocks exist; components are never merged;
    * ``blocks=[[...], ...]`` (explicit): user-supplied attribute groups —
      validated disjoint and covering every workload attribute.
    """
    dom = workload.domain
    active, adj = interaction_weights(workload)
    for c in workload.cliques:          # 1-cliques have no edges; still active
        for a in c:
            active[a] = True

    if blocks is not None and not isinstance(blocks, int):
        seen: set = set()
        out = []
        for grp in blocks:
            grp = tuple(sorted(int(a) for a in grp))
            if not grp:
                raise ValueError("empty block in explicit blocks=")
            if seen & set(grp):
                raise ValueError(f"explicit blocks overlap on "
                                 f"{sorted(seen & set(grp))}")
            seen.update(grp)
            out.append(grp)
        missing = set(np.nonzero(active)[0].tolist()) - seen
        if missing:
            raise ValueError(f"explicit blocks= do not cover workload "
                             f"attributes {sorted(missing)}")
        return Partition(dom, tuple(out), _cut_weight(workload, out))

    comps = _connected_components(active, adj)
    if max_block is not None:
        if max_block < 1:
            raise ValueError("max_block must be >= 1")
        split = []
        for comp in comps:
            split.extend(_greedy_split(comp, adj,
                                       math.ceil(len(comp) / max_block)))
        comps = split
    if isinstance(blocks, int):
        target = max(1, blocks)
        comps = [list(c) for c in comps]
        while len(comps) < target:
            big = max(range(len(comps)), key=lambda i: len(comps[i]))
            if len(comps[big]) < 2:
                break
            halves = _greedy_split(comps[big], adj, 2)
            comps[big:big + 1] = [list(h) for h in halves]
        comps.sort(key=lambda c: c[0])
    out = tuple(tuple(sorted(c)) for c in comps)
    return Partition(dom, out, _cut_weight(workload, out))


def _cut_weight(workload: MarginalWorkload, blocks: Sequence[Clique]) -> float:
    block_of = {}
    for b, grp in enumerate(blocks):
        for a in grp:
            block_of[a] = b
    return float(sum(workload.weight(c) for c in workload.cliques
                     if len({block_of[a] for a in c}) > 1))


# ---------------------------------------------------------------------------
# Workload decomposition
# ---------------------------------------------------------------------------

@dataclass(eq=False)
class Decomposition:
    """The workload split across a partition, with flat re-assembly indices.

    ``row_block[r]`` places original workload row ``r``: a block id for a
    clique fully inside one block, ``ROW_STRADDLER`` for a clique crossing a
    cut, ``ROW_EMPTY`` for the empty clique.  In-block rows carry ``row_pos``
    (their position in the owning block's sub-workload).  Straddlers explode
    into *parts* — flat arrays ``part_row / part_block / part_pos /
    part_cells`` with one entry per nonempty block-intersection, grouped by
    row — that drive the product-of-blocks variance proxy and
    reconstruction; part ``i``'s clique is
    ``block_workloads[part_block[i]].cliques[part_pos[i]]``.
    """

    workload: MarginalWorkload
    partition: Partition
    block_workloads: List[MarginalWorkload]
    row_block: np.ndarray
    row_pos: np.ndarray
    part_row: np.ndarray
    part_block: np.ndarray
    part_pos: np.ndarray
    part_cells: np.ndarray
    #: Σ importance over ∅ workload rows — no block sub-workload carries
    #: them, but the shared σ²_∅ serves them, so the SoV closed form adds
    #: this straight onto v_∅ (variance_coeff(∅, ∅) = 1).
    empty_weight: float = 0.0
    #: (m,) importance per original workload row (overrides folded in) —
    #: the weight convention of the composite's loss reporting.
    row_weight: Optional[np.ndarray] = None

    @property
    def n_straddlers(self) -> int:
        return int((self.row_block == ROW_STRADDLER).sum())

    def part_clique(self, i: int) -> Clique:
        return self.block_workloads[int(self.part_block[i])] \
            .cliques[int(self.part_pos[i])]

    def parts_of(self, row: int) -> List[Tuple[int, Clique]]:
        """(block, part clique) pairs of one straddling workload row."""
        sel = np.nonzero(self.part_row == row)[0]
        return [(int(self.part_block[i]), self.part_clique(i)) for i in sel]


def decompose(workload: MarginalWorkload, partition: Partition,
              weights=None) -> Decomposition:
    """Split ``workload`` across ``partition`` (vectorized per size class).

    ``weights`` optionally overrides per-clique importances (same mapping
    convention the selectors take).  Block sub-workload cliques are deduped
    per (block, width) with importances accumulated — a straddler's weight
    lands on each of its projections, merging with any in-block clique it
    coincides with.
    """
    dom = workload.domain
    wk = workload.cliques
    m = len(wk)
    if weights is None:
        w_row = workload.weight_array()
    else:
        w_row = np.array([float(weights.get(c, workload.weight(c)))
                          for c in wk])
    block_of = partition.block_of_array()
    nb = partition.n_blocks
    base = max(dom.n_attrs, 2)

    row_block = np.empty(m, np.int64)
    row_pos = np.full(m, -1, np.int64)
    # per block, per width: list of candidate chunks
    #   ("row",  global row-idx array,  (g, width) attr matrix, weights)
    #   ("part", global part-idx array, (g, width) attr matrix, weights)
    cand: List[Dict[int, list]] = [dict() for _ in range(nb)]
    part_row_l: List[np.ndarray] = []
    part_block_l: List[np.ndarray] = []
    n_parts = 0

    for k, (ridx, mat) in sorted(_group_by_len(wk).items()):
        if k == 0:
            # ∅ workload rows ride with block 0 (∅ is in every block's
            # closure; block 0 measures the shared total) so its importance
            # constrains σ²_∅ in the block-0 selection.  ROW_EMPTY survives
            # only for the degenerate no-blocks workload.
            if nb:
                row_block[ridx] = 0
                cand[0].setdefault(0, []).append(
                    ("row", ridx, mat, w_row[ridx]))
            else:
                row_block[ridx] = ROW_EMPTY
            continue
        blk = block_of[mat]
        inb = (blk == blk[:, :1]).all(axis=1)
        row_block[ridx] = np.where(inb, blk[:, 0], ROW_STRADDLER)
        if inb.any():
            for b in np.unique(blk[inb, 0]):
                sel = inb & (blk[:, 0] == b)
                cand[int(b)].setdefault(k, []).append(
                    ("row", ridx[sel], mat[sel], w_row[ridx[sel]]))
        if inb.all():
            continue
        # straddlers: sort each row's attrs by block id, find part boundaries
        srows = ridx[~inb]
        sa = mat[~inb]
        sblk = blk[~inb]
        order = np.argsort(sblk, axis=1, kind="stable")
        sb = np.take_along_axis(sblk, order, 1)
        sa = np.take_along_axis(sa, order, 1)
        new_part = np.ones_like(sb, bool)
        new_part[:, 1:] = sb[:, 1:] != sb[:, :-1]
        firsts = np.nonzero(new_part.ravel())[0]      # flat start of each part
        widths = np.diff(np.append(firsts, sb.size))  # parts never cross rows
        prow = srows[firsts // k]
        pblock = sb.ravel()[firsts]
        pw = w_row[prow]
        sa_flat = sa.ravel()
        for w_ in np.unique(widths):
            wsel = widths == w_
            mats = sa_flat[firsts[wsel][:, None]
                           + np.arange(int(w_), dtype=np.int64)]
            gidx = n_parts + np.nonzero(wsel)[0]
            for b in np.unique(pblock[wsel]):
                bsel = pblock[wsel] == b
                cand[int(b)].setdefault(int(w_), []).append(
                    ("part", gidx[bsel], mats[bsel], pw[wsel][bsel]))
        part_row_l.append(prow)
        part_block_l.append(pblock)
        n_parts += len(prow)

    part_row = (np.concatenate(part_row_l) if part_row_l
                else np.zeros(0, np.int64))
    part_block = (np.concatenate(part_block_l) if part_block_l
                  else np.zeros(0, np.int64))
    part_pos = np.full(n_parts, -1, np.int64)
    part_cells = np.ones(n_parts)

    # per block: dedupe candidates per width, accumulate weights, and build
    # the sub-workload over the FULL domain (global attribute ids) so
    # PlanTable and the fused engines apply unchanged
    block_workloads: List[MarginalWorkload] = []
    shape = np.asarray(dom.sizes, np.float64)
    for b in range(nb):
        cliques_b: List[Clique] = []
        weights_b: Dict[Clique, float] = {}
        cells_b: List[float] = []
        for width in sorted(cand[b]):
            chunks = cand[b][width]
            allk = []
            for _, _, mat_, _ in chunks:
                key = np.zeros(len(mat_), np.int64)
                for off in range(width):
                    key = key * base + mat_[:, off]
                allk.append(key)
            allk = np.concatenate(allk)
            uk, first, inv = np.unique(allk, return_index=True,
                                       return_inverse=True)
            umat = np.concatenate([c[2] for c in chunks], axis=0)[first]
            uw = np.zeros(len(uk))
            np.add.at(uw, inv, np.concatenate([c[3] for c in chunks]))
            pos0 = len(cliques_b)
            new_cl = [tuple(r) for r in umat.tolist()]
            cliques_b.extend(new_cl)
            for c, wt in zip(new_cl, uw.tolist()):
                weights_b[c] = wt
            cells_b.extend(np.prod(shape[umat], axis=1).tolist())
            at = 0
            for kind, idx, mat_, _ in chunks:
                g = len(mat_)
                upos = pos0 + inv[at:at + g]
                if kind == "row":
                    row_pos[idx] = upos
                else:
                    part_pos[idx] = upos
                at += g
        if part_block.size:
            bsel = part_block == b
            if bsel.any():
                part_cells[bsel] = np.asarray(cells_b)[part_pos[bsel]]
        block_workloads.append(
            MarginalWorkload(dom, tuple(cliques_b), weights_b))

    return Decomposition(workload, partition, block_workloads, row_block,
                         row_pos, part_row, part_block, part_pos, part_cells,
                         float(w_row[row_block == ROW_EMPTY].sum()), w_row)
