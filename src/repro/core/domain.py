"""Attribute domains, cliques (attribute subsets) and marginal workloads.

A clique is a sorted tuple of attribute indices; the marginal on clique ``A``
is the table of counts over the cross-product of those attributes' values.
Everything downstream (residual bases, noise planning, reconstruction) is
keyed on cliques, never on the exponentially-large record universe.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

Clique = Tuple[int, ...]


def as_clique(attrs: Iterable[int]) -> Clique:
    return tuple(sorted(set(int(a) for a in attrs)))


@dataclass(frozen=True)
class Attribute:
    """A single column of the tabular domain."""

    name: str
    size: int
    kind: str = "categorical"  # categorical | numeric

    def __post_init__(self):
        if self.size < 2:
            raise ValueError(f"attribute {self.name!r} must have size >= 2, got {self.size}")


@dataclass(frozen=True)
class Domain:
    """An ordered collection of attributes; the record universe is their product."""

    attributes: Tuple[Attribute, ...]

    @staticmethod
    def create(sizes: Sequence[int], names: Optional[Sequence[str]] = None,
               kinds: Optional[Sequence[str]] = None) -> "Domain":
        names = names or [f"attr{i}" for i in range(len(sizes))]
        kinds = kinds or ["categorical"] * len(sizes)
        return Domain(tuple(Attribute(n, int(s), k) for n, s, k in zip(names, sizes, kinds)))

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(a.size for a in self.attributes)

    @property
    def n_attrs(self) -> int:
        return len(self.attributes)

    def universe_size(self) -> int:
        return math.prod(self.sizes)

    def clique_sizes(self, clique: Clique) -> Tuple[int, ...]:
        return tuple(self.attributes[i].size for i in clique)

    def n_cells(self, clique: Clique) -> int:
        """Number of cells in the marginal on ``clique`` (1 for the empty clique)."""
        return math.prod(self.clique_sizes(clique)) if clique else 1

    def residual_size(self, clique: Clique) -> int:
        """Rows of the residual matrix R_A:  prod (|Att_i| - 1)."""
        return math.prod(s - 1 for s in self.clique_sizes(clique)) if clique else 1

    def index(self, name: str) -> int:
        for i, a in enumerate(self.attributes):
            if a.name == name:
                return i
        raise KeyError(name)

    def clique_by_names(self, names: Iterable[str]) -> Clique:
        return as_clique(self.index(n) for n in names)


def subsets(clique: Clique) -> List[Clique]:
    """All subsets of a clique, including the empty clique, sorted by (len, value)."""
    out: List[Clique] = []
    for r in range(len(clique) + 1):
        out.extend(itertools.combinations(clique, r))
    return out


def closure(cliques: Iterable[Clique]) -> List[Clique]:
    """Downward closure: every subset of every workload clique (Thm 1/2)."""
    seen = set()
    for c in cliques:
        for s in subsets(as_clique(c)):
            seen.add(s)
    return sorted(seen, key=lambda c: (len(c), c))


@dataclass(frozen=True)
class MarginalWorkload:
    """A weighted collection of marginal queries.

    ``weights[A]`` is the importance Imp_A from Section 6 of the paper.
    """

    domain: Domain
    cliques: Tuple[Clique, ...]
    weights: Mapping[Clique, float] = field(default_factory=dict)

    def __post_init__(self):
        for c in self.cliques:
            for i in c:
                if not (0 <= i < self.domain.n_attrs):
                    raise ValueError(f"clique {c} out of range for domain with "
                                     f"{self.domain.n_attrs} attributes")

    def weight(self, clique: Clique) -> float:
        return float(self.weights.get(clique, 1.0))

    def weight_array(self) -> "np.ndarray":
        """Importance Imp_A per workload clique, in ``self.cliques`` order —
        the row-weight vector of the arrayized planner IR."""
        import numpy as np
        return np.array([self.weight(c) for c in self.cliques])

    def closure(self) -> List[Clique]:
        return closure(self.cliques)

    def total_cells(self) -> int:
        return sum(self.domain.n_cells(c) for c in self.cliques)

    def reweighted(self, scheme: str) -> "MarginalWorkload":
        """Weighting schemes from §6.2: equi | cells | sqrt_cells."""
        if scheme == "equi":
            w = {c: 1.0 for c in self.cliques}
        elif scheme == "cells":
            w = {c: float(self.domain.n_cells(c)) for c in self.cliques}
        elif scheme == "sqrt_cells":
            w = {c: math.sqrt(self.domain.n_cells(c)) for c in self.cliques}
        else:
            raise ValueError(scheme)
        return MarginalWorkload(self.domain, self.cliques, w)


def all_kway(domain: Domain, k: int, include_lower: bool = False,
             include_empty: bool = False) -> MarginalWorkload:
    """The workload of all k-way marginals (or all <=k-way with include_lower)."""
    cliques: List[Clique] = []
    ks = range(0 if include_empty else 1, k + 1) if include_lower else [k]
    for kk in ks:
        cliques.extend(itertools.combinations(range(domain.n_attrs), kk))
    return MarginalWorkload(domain, tuple(cliques))
