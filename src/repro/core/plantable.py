r"""PlanTable: the arrayized planner IR (docs/DESIGN.md §9).

The paper's scalability claims are about the *planner*: selection "in
seconds" at 100 attributes, and per-marginal variance/covariance where "prior
methods quickly run out of memory".  The dict-of-cliques planner re-enumerated
``subsets(A)`` with ``itertools`` on every coefficient query; at the
100-attribute all-≤3-way closure (166 751 cliques, ~1.3M subset pairs) that
Python loop dominates end-to-end time.  This module flattens the whole
closure into indexed arrays, built ONCE per workload:

* ``cliques`` — the downward closure, sorted by (len, lex) exactly like
  :func:`repro.core.domain.closure`;
* ``inc_rows/inc_cols/inc_vals`` — COO incidence between workload marginals
  (rows) and closure cliques (cols) with the Thm-4 variance coefficients as
  values.  Built by *rank-indexed combinatorics*: subset cliques are encoded
  as fixed-width integer keys and located with ``searchsorted`` — no repeated
  ``itertools`` enumeration, no per-pair Python calls;
* ``p`` — the Thm-3 pcost coefficients, a vectorized product gather;
* ``axis_*`` — per-attribute factor vectors.  Plain marginals use
  ``(n−1)/n`` (pcost & measured), ``1/n²`` (marginalized) and ``1/n``
  (cross); ResidualPlanner+ substitutes the Thm-7/8 factors
  ``β_i / ‖W Sub†Γ‖²_F / ‖W1‖²/n²`` — one IR, both plan families.

Every selection objective and every variance/covariance query is then a
segment-sum (``np.bincount`` on host, ``jax.ops.segment_sum`` on device)
over these arrays.  :class:`BasePlan` is the unified plan protocol carried by
the IR: ``Plan`` (plain marginals) and ``PlusPlan`` (generalized bases) both
hold ``(table, sigma)`` and expose the legacy dict accessors
(``plan.sigmas[A]``, ``marginal_variance``) as thin views over the arrays, so
``MarginalEngine``, ``PlusEngine``, ``sharded_measure`` and ``discrete.py``
consume one interface with no ``isinstance`` branching.
"""
from __future__ import annotations

import contextlib
import itertools
import math
import weakref
from collections import OrderedDict
from collections.abc import Mapping as _MappingABC
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .domain import Clique, Domain, MarginalWorkload, closure, subsets

_SIGMA_MAX = 1e300   # sliver clamp: zero-weight cliques never overflow to inf


def _encode(mat: np.ndarray, base: int) -> np.ndarray:
    """Order-preserving int64 key of sorted-attribute rows (fixed width).

    Rows of ``mat`` are cliques of one size class; the polynomial-in-``base``
    key sorts exactly like the clique tuples, so per-size ``np.unique`` /
    ``searchsorted`` reproduce the (len, lex) closure order.
    """
    key = np.zeros(mat.shape[0], dtype=np.int64)
    for j in range(mat.shape[1]):
        key = key * base + mat[:, j]
    return key


def _group_by_len(cliques: Sequence[Clique]):
    """{k: (workload row indices, (g, k) attr-index matrix)}.

    Vectorized: one ``fromiter`` pass over the flattened attribute stream and
    one over the lengths, then per-size row gathers — no per-clique Python
    appends (the historical append loop dominated ``build`` at d=100).
    """
    m = len(cliques)
    lens = np.fromiter(map(len, cliques), np.int64, count=m)
    flat = np.fromiter(itertools.chain.from_iterable(cliques), np.int64,
                       count=int(lens.sum()))
    starts = np.concatenate([np.zeros(1, np.int64), np.cumsum(lens)])
    out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for k in map(int, np.unique(lens)):
        ridx = np.nonzero(lens == k)[0]
        mat = flat[starts[ridx][:, None] + np.arange(k, dtype=np.int64)] \
            if k else np.zeros((len(ridx), 0), np.int64)
        out[k] = (ridx, mat)
    return out


@dataclass(eq=False)
class PlanTable:
    """Flat arrayized closure of one workload (built once, queried many times).

    The closure is stored as per-size attribute matrices (``_members``,
    ``_offsets``) — the tuple list ``cliques`` and the dict ``index`` are
    *lazy*: materialized (and cached) on first access.  Selection, variance
    and covariance queries run on the flat arrays alone, so a d=100 build no
    longer pays for 166k Python tuples it may never look at.
    """

    domain: Domain
    workload: MarginalWorkload
    n_closure: int                   # closure size
    p: np.ndarray                    # (n,) pcost coefficients (Thm 3 / Thm 7)
    weights: np.ndarray              # (m,) workload importance Imp_A
    wk_index: np.ndarray             # (m,) closure index of each workload clique
    inc_rows: np.ndarray             # (nnz,) workload row
    inc_cols: np.ndarray             # (nnz,) closure col
    inc_vals: np.ndarray             # (nnz,) unweighted variance coefficients
    v: np.ndarray                    # (n,) default-weight SoV coefficients
    axis_pcost: np.ndarray
    axis_meas: np.ndarray
    axis_marg: np.ndarray
    axis_cross: Optional[np.ndarray]  # None for RP+ tables (plain-only queries)
    plain: bool
    _members: Optional[Dict[int, np.ndarray]] = field(default=None, repr=False)
    _offsets: Optional[Dict[int, int]] = field(default=None, repr=False)
    _cliques: Optional[List[Clique]] = field(default=None, repr=False)
    _index: Optional[Dict[Clique, int]] = field(default=None, repr=False)
    _device: Dict[str, tuple] = field(default_factory=dict, repr=False)

    # ------------------------------------------------------------ dimensions
    @property
    def n(self) -> int:
        """Closure size (number of base mechanisms)."""
        return self.n_closure

    @property
    def m(self) -> int:
        """Workload size (number of marginal queries)."""
        return len(self.workload.cliques)

    # -------------------------------------------------- lazy clique material
    @property
    def cliques(self) -> List[Clique]:
        """Closure as (len, lex)-sorted tuples (materialized on first use)."""
        if self._cliques is None:
            cl: List[Clique] = []
            for s in sorted(self._members):
                cl.extend(map(tuple, self._members[s].tolist()))
            self._cliques = cl
        return self._cliques

    @property
    def index(self) -> Dict[Clique, int]:
        """Clique → closure position (materialized on first use)."""
        if self._index is None:
            self._index = {c: i for i, c in enumerate(self.cliques)}
        return self._index

    # -------------------------------------------------------------- builders
    @staticmethod
    def build(workload: MarginalWorkload, *, axis_pcost: np.ndarray,
              axis_meas: np.ndarray, axis_marg: np.ndarray,
              axis_cross: Optional[np.ndarray] = None,
              plain: bool = True) -> "PlanTable":
        """Build the IR from per-axis factor vectors.

        A clique's pcost coefficient is ``Π_{i∈A} axis_pcost[i]`` and the
        variance coefficient of σ²_{A'} in the marginal on A is
        ``Π_{i∈A'} axis_meas[i] · Π_{i∈A∖A'} axis_marg[i]`` — both Thm 4 and
        Thm 8 factor per axis, which is what makes the IR exact for plain
        and RP+ plans alike.
        """
        dom = workload.domain
        wk = workload.cliques
        if not wk:
            raise ValueError("empty workload")
        m = len(wk)
        base = max(dom.n_attrs, 2)
        groups = _group_by_len(wk)
        kmax = max(groups)
        weights = workload.weight_array()
        if kmax * math.log2(base) > 62:   # huge cliques: dict closure (rare)
            return PlanTable._build_dict(workload, weights, axis_pcost,
                                         axis_meas, axis_marg, axis_cross,
                                         plain)

        # Single pass over (size-class, subset-mask): the encoded key, the
        # Π axis_meas (selected) and Π axis_marg (unselected) products are
        # each a mask-DP reusing the mask-minus-highest-bit value — no
        # re-encoding, no fancy-index ``np.prod`` gathers per mask.  The
        # closure AND the incidence columns then come out of ONE
        # ``np.unique(..., return_inverse=True)`` per subset size.
        cand: Dict[int, list] = {}
        for k, (ridx, mat) in sorted(groups.items()):
            nk = len(mat)
            meas_col = [axis_meas[mat[:, j]] for j in range(k)]
            marg_col = [axis_marg[mat[:, j]] for j in range(k)]
            key_dp = [np.zeros(nk, np.int64)] + [None] * ((1 << k) - 1)
            meas_dp = [np.ones(nk)] + [None] * ((1 << k) - 1)
            marg_dp = [np.ones(nk)] + [None] * ((1 << k) - 1)
            full = (1 << k) - 1
            for mask in range(1, 1 << k):
                hb = mask.bit_length() - 1
                rest = mask ^ (1 << hb)
                key_dp[mask] = key_dp[rest] * base + mat[:, hb]
                meas_dp[mask] = meas_dp[rest] * meas_col[hb]
                marg_dp[mask] = marg_dp[rest] * marg_col[hb]
            for mask in range(1 << k):
                s = bin(mask).count("1")
                sel = [j for j in range(k) if mask >> j & 1]
                cand.setdefault(s, []).append(
                    (key_dp[mask], ridx, meas_dp[mask] * marg_dp[full ^ mask],
                     mat[:, sel], mask == full))

        nnz = sum(len(e[0]) for ch in cand.values() for e in ch)
        inc_rows = np.empty(nnz, np.int64)
        inc_cols = np.empty(nnz, np.int64)
        inc_vals = np.empty(nnz)
        wk_index = np.empty(m, np.int64)
        members: Dict[int, np.ndarray] = {}
        offsets: Dict[int, int] = {}
        p_segs: List[np.ndarray] = []
        n = pos = 0
        for s in sorted(cand):
            chunks = cand[s]
            keys = np.concatenate([c[0] for c in chunks])
            uk, first, inv = np.unique(keys, return_index=True,
                                       return_inverse=True)
            offsets[s] = n
            members[s] = np.concatenate([c[3] for c in chunks], axis=0)[first]
            p_segs.append(np.prod(axis_pcost[members[s]], axis=1)
                          if s else np.ones(len(uk)))
            cols = n + inv
            at = 0
            for _keys, ridx, vals, _sub, is_full in chunks:
                g = len(ridx)
                sl = slice(pos, pos + g)
                inc_rows[sl] = ridx
                inc_cols[sl] = cols[at:at + g]
                inc_vals[sl] = vals
                if is_full:
                    wk_index[ridx] = cols[at:at + g]
                pos += g
                at += g
            n += len(uk)
        p = np.concatenate(p_segs)
        v = np.bincount(inc_cols, weights=weights[inc_rows] * inc_vals,
                        minlength=n)
        return PlanTable(dom, workload, n, p, weights, wk_index,
                         inc_rows, inc_cols, inc_vals, v, axis_pcost,
                         axis_meas, axis_marg, axis_cross, plain,
                         _members=members, _offsets=offsets)

    @staticmethod
    def _build_dict(workload, weights, axis_pcost, axis_meas, axis_marg,
                    axis_cross, plain) -> "PlanTable":
        """Fallback for cliques too wide for int64 keys: dict closure."""
        dom = workload.domain
        wk = workload.cliques
        cliques = closure(wk)
        index = {c: i for i, c in enumerate(cliques)}
        n = len(cliques)
        p = np.ones(n)
        for i, c in enumerate(cliques):
            p[i] = float(np.prod(axis_pcost[list(c)])) if c else 1.0
        rows_l, cols_l, vals_l = [], [], []
        wk_index = np.empty(len(wk), np.int64)
        for r, wc in enumerate(wk):
            wk_index[r] = index[wc]
            for sub in subsets(wc):
                rows_l.append(r)
                cols_l.append(index[sub])
                rest = [i for i in wc if i not in set(sub)]
                val = float(np.prod(axis_meas[list(sub)])) if sub else 1.0
                if rest:
                    val *= float(np.prod(axis_marg[rest]))
                vals_l.append(val)
        inc_rows = np.asarray(rows_l, np.int64)
        inc_cols = np.asarray(cols_l, np.int64)
        inc_vals = np.asarray(vals_l)
        v = np.bincount(inc_cols, weights=weights[inc_rows] * inc_vals,
                        minlength=n)
        table = PlanTable(dom, workload, n, p, weights, wk_index,
                          inc_rows, inc_cols, inc_vals, v, axis_pcost,
                          axis_meas, axis_marg, axis_cross, plain)
        table._cliques = cliques
        table._index = index
        return table

    @staticmethod
    def for_workload(workload: MarginalWorkload) -> "PlanTable":
        """Plain-marginal IR: Thm 3/4 per-axis factors from the domain sizes."""
        from .residual import axis_coeff_vectors
        pc, meas, marg, cross = axis_coeff_vectors(workload.domain)
        return PlanTable.build(workload, axis_pcost=pc, axis_meas=meas,
                               axis_marg=marg, axis_cross=cross, plain=True)

    # ------------------------------------------------------------- weighting
    def weight_vector(self, weights: Optional[Mapping[Clique, float]] = None,
                      default_to_workload: bool = True) -> np.ndarray:
        """Importance per workload row under an optional override mapping.

        ``default_to_workload`` keeps the two historical conventions apart:
        the SoV coefficient path defaulted missing cliques to 1.0, the
        maxvar/convex paths to ``workload.weight``.
        """
        if weights is None:
            return self.weights
        if default_to_workload:
            return np.array([float(weights.get(c, self.workload.weight(c)))
                             for c in self.workload.cliques])
        return np.array([float(weights.get(c, 1.0))
                         for c in self.workload.cliques])

    def sov_coeffs(self, weights: Optional[Mapping[Clique, float]] = None
                   ) -> np.ndarray:
        """SoV coefficients v_A (§6.1) under optional weight override."""
        if weights is None:
            return self.v
        w = self.weight_vector(weights, default_to_workload=False)
        return np.bincount(self.inc_cols,
                           weights=w[self.inc_rows] * self.inc_vals,
                           minlength=self.n)

    # --------------------------------------------------------------- queries
    def pcost(self, sigma: np.ndarray) -> float:
        """Σ_A p_A / σ²_A (Thm 3)."""
        return float(np.sum(self.p / sigma))

    def variances(self, sigma: np.ndarray) -> np.ndarray:
        """Variance of EVERY workload marginal in one segment-sum (Thm 4/8).

        Plain tables: per-cell variance of each reconstructed marginal.
        RP+ tables: SoV (cell-sum) of each generalized query — the Thm 8
        convention.
        """
        sigma = np.asarray(sigma, np.float64)
        return np.bincount(self.inc_rows,
                           weights=self.inc_vals * sigma[self.inc_cols],
                           minlength=self.m)

    def variance_of(self, sigma: np.ndarray, clique: Clique) -> float:
        """Single-marginal variance for any clique inside the closure."""
        am, ag = self.axis_meas, self.axis_marg
        out = 0.0
        for sub in subsets(clique):
            coef = float(np.prod(am[list(sub)])) if sub else 1.0
            rest = [i for i in clique if i not in set(sub)]
            if rest:
                coef *= float(np.prod(ag[rest]))
            out += coef * float(sigma[self.index[sub]])
        return out

    def covariance_coeffs(self, a: Clique, b: Clique
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """(closure cols, coefficients) of the aligned-cell covariance of
        reconstructed marginals A and B (the Thm-4 machinery extended across
        marginals; plain tables only).

        Cov(Q̂_A[u], Q̂_B[w]) for cells agreeing on A∩B is
        ``Σ_{A'⊆A∩B} σ²_{A'} · Π_{i∈A'} (1−1/n_i) · Π_{i∈(A∩B)∖A'} 1/n_i²
        · Π_{i∈AΔB} 1/n_i`` — only the shared measurements correlate.
        """
        if self.axis_cross is None:
            raise ValueError("cross-marginal covariance requires a plain "
                             "(identity-basis) PlanTable")
        inter = tuple(sorted(set(a) & set(b)))
        symdiff = sorted(set(a) ^ set(b))
        outer = float(np.prod(self.axis_cross[symdiff])) if symdiff else 1.0
        cols, coefs = [], []
        for sub in subsets(inter):
            coef = outer
            if sub:
                coef *= float(np.prod(self.axis_meas[list(sub)]))
            rest = [i for i in inter if i not in set(sub)]
            if rest:
                coef *= float(np.prod(self.axis_marg[rest]))
            cols.append(self.index[sub])
            coefs.append(coef)
        return np.asarray(cols, np.int64), np.asarray(coefs)

    def cross_covariance(self, sigma: np.ndarray, a: Clique, b: Clique) -> float:
        cols, coefs = self.covariance_coeffs(a, b)
        return float(np.dot(coefs, np.asarray(sigma, np.float64)[cols]))

    def cross_covariances(self, sigma: np.ndarray,
                          pairs: Sequence[Tuple[Clique, Clique]]) -> np.ndarray:
        """Aligned-cell covariance for a batch of marginal pairs: the COO rows
        of all pairs concatenate into ONE segment-sum."""
        sigma = np.asarray(sigma, np.float64)
        rows_l, cols_l, vals_l = [], [], []
        for r, (a, b) in enumerate(pairs):
            cols, coefs = self.covariance_coeffs(a, b)
            rows_l.append(np.full(len(cols), r, np.int64))
            cols_l.append(cols)
            vals_l.append(coefs)
        if not rows_l:
            return np.zeros(0)
        rows = np.concatenate(rows_l)
        return np.bincount(rows,
                           weights=np.concatenate(vals_l)
                           * sigma[np.concatenate(cols_l)],
                           minlength=len(pairs))

    def device_arrays(self):
        """(p, inc_rows, inc_cols, inc_vals) as jnp arrays, cached per dtype."""
        import jax
        import jax.numpy as jnp
        dt = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
        key = jnp.dtype(dt).name
        ent = self._device.get(key)
        if ent is None:
            ent = (jnp.asarray(self.p, dt),
                   jnp.asarray(self.inc_rows, jnp.int32),
                   jnp.asarray(self.inc_cols, jnp.int32),
                   jnp.asarray(self.inc_vals, dt))
            self._device[key] = ent
        return ent


# ---------------------------------------------------------------------------
# Closed-form SoV (Lemma 2) — shared by plain and RP+ selection
# ---------------------------------------------------------------------------

def sov_closed_form(p: np.ndarray, v: np.ndarray, pcost_budget: float
                    ) -> np.ndarray:
    """σ²_A = (Σ √(p v))·√(p_A/v_A)/c — the Lemma 2 optimum, overflow-safe.

    Cliques with v_A == 0 (needed for reconstruction completeness, zero
    objective weight) get a 1e-9 sliver of the budget each, computed in a
    factorization that cannot overflow to inf for tiny budgets (the historic
    ``p/eps_share`` sliver hit inf once ``eps_share`` went denormal); the
    sliver σ² is additionally clamped at 1e300.
    """
    c = float(pcost_budget)
    if not c > 0:
        raise ValueError(f"pcost budget must be positive, got {c}")
    pos = v > 0
    n_zero = int((~pos).sum())
    eps_frac = 1e-9 if n_zero else 0.0          # budget fraction per sliver
    c_eff = c * (1.0 - eps_frac * n_zero)
    sig = np.zeros(len(v))
    ssum = float(np.sqrt(v[pos] * p[pos]).sum())
    # σ = (S/c_eff)·√(p/v): no S²/c intermediate, stable down to c ~ 1e-300.
    sig[pos] = (ssum / c_eff) * np.sqrt(p[pos] / v[pos])
    if n_zero:
        with np.errstate(over="ignore", divide="ignore"):
            sliver = p[~pos] / (eps_frac * c)
        sig[~pos] = np.minimum(sliver, _SIGMA_MAX)
        total = float(np.sum(p / sig))
        if total > c:       # clamp bound: rescale so pcost ≤ budget exactly
            sig *= total / c
    return sig


# ---------------------------------------------------------------------------
# The unified plan protocol
# ---------------------------------------------------------------------------

class SigmaView(_MappingABC):
    """``Dict[Clique, float]`` view over the σ² array (legacy accessor)."""

    __slots__ = ("_table", "_sigma")

    def __init__(self, table: PlanTable, sigma: np.ndarray):
        self._table = table
        self._sigma = sigma

    def __getitem__(self, clique: Clique) -> float:
        return float(self._sigma[self._table.index[clique]])

    def __iter__(self):
        return iter(self._table.cliques)

    def __len__(self) -> int:
        return len(self._table.cliques)


@dataclass(eq=False)
class BasePlan:
    """What every selection output is: an IR + a σ² vector over its closure.

    ``Plan`` (plain marginals) and ``PlusPlan`` (generalized bases) both
    subclass this; engines and the measurement/reconstruction layers consume
    only this protocol — ``domain``, ``cliques``, ``sigmas``/``sigma2`` and
    ``engine()`` — so no caller branches on the concrete plan type.
    """

    table: PlanTable
    sigma: np.ndarray            # (n_closure,) σ²_A in table.cliques order
    objective: str
    pcost: float
    loss_value: float

    @property
    def domain(self) -> Domain:
        return self.table.domain

    @property
    def workload(self) -> MarginalWorkload:
        return self.table.workload

    @property
    def cliques(self) -> List[Clique]:
        return self.table.cliques

    @property
    def sigmas(self) -> SigmaView:
        return SigmaView(self.table, self.sigma)

    def sigma2(self, clique: Clique) -> float:
        return float(self.sigma[self.table.index[clique]])

    def variances_array(self) -> np.ndarray:
        """Per-workload-marginal variance, one segment-sum (Thm 4/8)."""
        return self.table.variances(self.sigma)

    def workload_variances(self) -> Dict[Clique, float]:
        return dict(zip(self.workload.cliques,
                        map(float, self.variances_array())))

    def engine(self, use_kernel=None, precompile: bool = True, dtype=None,
               secure: bool = False, digits: int = 4):
        """The measurement/reconstruction engine serving this plan family.

        ``secure=True`` requests the numerically secure release path
        (Alg 3 — integer queries + exact discrete Gaussian noise,
        :class:`~repro.engine.discrete_engine.DiscreteEngine`); plan
        families without an integer-query rotation raise ``ValueError``.
        ``digits`` is the σ̄ rationalization of the secure path.
        """
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Memoized table per workload (built once, shared by all selectors)
# ---------------------------------------------------------------------------

_TABLE_CACHE: "OrderedDict[int, PlanTable]" = OrderedDict()
_TABLE_CACHE_MAX = 64


def plan_table(workload: MarginalWorkload) -> PlanTable:
    """The plain-marginal PlanTable of a workload, built once per object.

    LRU-bounded (single-entry eviction, never a wholesale clear) and
    identity-validated on every hit, so a recycled ``id`` can never return a
    stale table.  Cached tables pin their workload (``table.workload``), so
    entries normally leave via LRU eviction; the ``weakref.finalize`` is a
    belt-and-braces cleanup for ids freed after eviction.
    """
    key = id(workload)
    t = _TABLE_CACHE.get(key)
    if t is not None and t.workload is workload:
        _TABLE_CACHE.move_to_end(key)
        return t
    t = PlanTable.for_workload(workload)
    while len(_TABLE_CACHE) >= _TABLE_CACHE_MAX:
        _TABLE_CACHE.popitem(last=False)
    _TABLE_CACHE[key] = t
    with contextlib.suppress(TypeError):
        weakref.finalize(workload, _TABLE_CACHE.pop, key, None)
    return t
