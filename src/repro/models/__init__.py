from .config import ModelConfig, MoEConfig, get_config, list_configs, register
from .transformer import (Model, cache_axes, cache_defs, cache_shape_structs,
                          init_cache, model_defs)

__all__ = [n for n in dir() if not n.startswith("_")]
