"""Logical-axis sharding rules (plane B distribution).

Model code annotates activations/params with *logical* axis names; a rule set
maps them to mesh axes.  One rule set is divisibility-safe for all 10 assigned
architectures (see docs/DESIGN.md §6): feature dims shard over ``model``, batch over
(``pod``, ``data``), sequence over ``model`` in attention/FFN compute regions
(sequence parallelism), vocab over ``model``, experts over ``model``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

# Default logical→mesh rules (single- and multi-pod; 'pod' silently dropped
# when absent from the mesh).
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,                # embedding-layer seq: replicated
    "seq_shard": "model",       # sequence-parallel regions (attention/FFN acts)
    "dmodel": None,
    "dmodel_fsdp": "data",      # parameter storage: d_model sharded over data
    "qkv": "model",             # flattened head*head_dim projections
    "heads": None,              # head axis in attention math: replicated
    "heads_shard": "model",     # §Perf T1c: padded-head attention sharding
    "kv_seq": "model",          # decode split-K: cache length over model
    "dff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_dff": "data",   # expert weights: d_ff slice per data shard
    "rnn_state": "model",
    "lora": None,
}

_local = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_local, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_local, "mesh", None)


@contextlib.contextmanager
def sharding_rules(mesh: Optional[Mesh], rules: Optional[Rules] = None):
    """Activate logical-axis sharding for model code inside this context."""
    prev = (current_mesh(), current_rules())
    _local.mesh = mesh
    _local.rules = dict(DEFAULT_RULES, **(rules or {})) if mesh is not None else None
    try:
        yield
    finally:
        _local.mesh, _local.rules = prev


def _resolve(names: Sequence[Optional[str]], mesh: Mesh, rules: Rules) -> P:
    axes = []
    for n in names:
        if n is None:
            axes.append(None)
            continue
        tgt = rules.get(n, None)
        if tgt is None:
            axes.append(None)
        elif isinstance(tgt, tuple):
            present = tuple(t for t in tgt if t in mesh.axis_names)
            axes.append(present if present else None)
        else:
            axes.append(tgt if tgt in mesh.axis_names else None)
    return P(*axes)


def logical(x, *names: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op outside a mesh ctx)."""
    mesh, rules = current_mesh(), current_rules()
    if mesh is None or rules is None:
        return x
    spec = _resolve(names, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_for(mesh: Mesh, *names: Optional[str], rules: Optional[Rules] = None) -> NamedSharding:
    r = dict(DEFAULT_RULES, **(rules or {}))
    return NamedSharding(mesh, _resolve(names, mesh, r))


def param_spec(mesh: Mesh, logical_axes: Sequence[Optional[str]],
               rules: Optional[Rules] = None) -> NamedSharding:
    return spec_for(mesh, *logical_axes, rules=rules)


def batch_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
