"""Mixture-of-Experts FFN with expert parallelism (shard_map + all_to_all).

Layout (see docs/DESIGN.md §6):
  * tokens sequence-sharded over ('pod','data') × 'model' going in;
  * experts sharded over 'model' (kimi 384/16 = 24 per shard, deepseek 160/16 = 10);
  * each expert's d_ff sharded over 'data' (per-shard weight slice), producing a
    partial-sum output that is psum'd over 'data' *after* the return all_to_all
    (the un-dispatch deflates tokens k·cf-fold first — a deliberate collective-
    volume optimization, see EXPERIMENTS.md §Perf).

Dispatch is capacity-bounded (GShard-style token dropping) and implemented with
sort-free bucket slots (argsort + searchsorted) — static shapes throughout.
Without a mesh (CPU smoke tests) a dense fallback computes every expert.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .layers import PDef
from .sharding import batch_axis_names, current_mesh, logical


def moe_defs(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    mo = cfg.moe
    defs = {
        "router": PDef((d, mo.n_experts), (None, None)),
        "w_g": PDef((mo.n_experts, d, mo.d_expert), ("experts", None, "expert_dff")),
        "w_u": PDef((mo.n_experts, d, mo.d_expert), ("experts", None, "expert_dff")),
        "w_o": PDef((mo.n_experts, mo.d_expert, d), ("experts", "expert_dff", None)),
    }
    if mo.n_shared:
        f_sh = mo.n_shared * mo.d_expert
        defs["sh_g"] = PDef((d, f_sh), (None, "expert_dff"))
        defs["sh_u"] = PDef((d, f_sh), (None, "expert_dff"))
        defs["sh_o"] = PDef((f_sh, d), ("expert_dff", None))
    return defs


def bucket_slots(ids: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """slot[i] = rank of element i within its bucket (stable, static shapes)."""
    n = ids.shape[0]
    order = jnp.argsort(ids)
    sorted_ids = ids[order]
    first = jnp.searchsorted(sorted_ids, jnp.arange(n_buckets), side="left")
    pos = jnp.arange(n) - first[sorted_ids]
    return jnp.zeros(n, jnp.int32).at[order].set(pos.astype(jnp.int32))


def _route(x_flat, router_w, mo):
    logits = (x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, mo.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss (local stats).
    E = mo.n_experts
    density = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=0)
    prob_mean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * prob_mean)
    return top_w, top_e, aux


def _expert_ffn(buf, w_g, w_u, w_o, cdt):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_g.astype(cdt)))
    u = jnp.einsum("ecd,edf->ecf", buf, w_u.astype(cdt))
    return jnp.einsum("ecf,efd->ecd", h * u, w_o.astype(cdt))


def _moe_dense_fallback(p, x, cfg):
    """No-mesh path: every expert on every token (reduced configs only)."""
    B, S, d = x.shape
    mo = cfg.moe
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    xf = x.reshape(-1, d).astype(cdt)
    top_w, top_e, aux = _route(xf, p["router"], mo)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["w_g"].astype(cdt)))
    u = jnp.einsum("td,edf->tef", xf, p["w_u"].astype(cdt))
    outs = jnp.einsum("tef,efd->ted", h * u, p["w_o"].astype(cdt))
    gates = jnp.zeros((xf.shape[0], mo.n_experts), cdt).at[
        jnp.arange(xf.shape[0])[:, None], top_e].set(top_w.astype(cdt))
    y = jnp.einsum("te,ted->td", gates, outs)
    if mo.n_shared:
        y = y + (jax.nn.silu(xf @ p["sh_g"].astype(cdt))
                 * (xf @ p["sh_u"].astype(cdt))) @ p["sh_o"].astype(cdt)
    return y.reshape(B, S, d).astype(x.dtype), aux


def _moe_local(p, x, *, cfg, n_shards: int, e_loc: int, axis: str,
               data_axes: Tuple[str, ...], all_axes: Tuple[str, ...]):
    """Per-device body under shard_map (full mesh)."""
    mo = cfg.moe
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    b_loc, s_loc, d = x.shape
    t = b_loc * s_loc
    xf = x.reshape(t, d).astype(cdt)
    top_w, top_e, aux = _route(xf, p["router"], mo)

    flat_e = top_e.reshape(-1)                              # (t*k,)
    src = jnp.repeat(jnp.arange(t, dtype=jnp.int32), mo.top_k)
    dest_shard = (flat_e // e_loc).astype(jnp.int32)
    local_e = (flat_e % e_loc).astype(jnp.int32)

    cap1 = int(math.ceil(t * mo.top_k / n_shards * mo.capacity_factor))
    slot1 = bucket_slots(dest_shard, n_shards)
    keep1 = slot1 < cap1
    send_idx = jnp.where(keep1, dest_shard * cap1 + slot1, n_shards * cap1)
    send = jnp.zeros((n_shards * cap1, d), cdt).at[send_idx].set(
        xf[src], mode="drop")
    send_e = jnp.full((n_shards * cap1,), 0, jnp.int32).at[send_idx].set(
        local_e, mode="drop")
    send_valid = jnp.zeros((n_shards * cap1,), jnp.bool_).at[send_idx].set(
        True, mode="drop")

    recv = jax.lax.all_to_all(send.reshape(n_shards, cap1, d), axis, 0, 0,
                              tiled=False).reshape(-1, d)
    recv_e = jax.lax.all_to_all(send_e.reshape(n_shards, cap1), axis, 0, 0,
                                tiled=False).reshape(-1)
    recv_valid = jax.lax.all_to_all(send_valid.reshape(n_shards, cap1), axis,
                                    0, 0, tiled=False).reshape(-1)

    n_recv = n_shards * cap1
    cap2 = int(math.ceil(n_recv / e_loc * mo.capacity_factor))
    eid = jnp.where(recv_valid, recv_e, e_loc)              # invalid → overflow
    slot2 = bucket_slots(eid, e_loc + 1)
    keep2 = (slot2 < cap2) & recv_valid
    buf_idx = jnp.where(keep2, eid * cap2 + slot2, e_loc * cap2)
    buf = jnp.zeros((e_loc * cap2 + 1, d), cdt).at[buf_idx].set(recv, mode="drop")
    buf = buf[:-1].reshape(e_loc, cap2, d)

    out = _expert_ffn(buf, p["w_g"], p["w_u"], p["w_o"], cdt)   # partial over f

    back = out.reshape(-1, d)[jnp.minimum(buf_idx, e_loc * cap2 - 1)]
    back = jnp.where(keep2[:, None], back, 0.0)
    ret = jax.lax.all_to_all(back.reshape(n_shards, cap1, d), axis, 0, 0,
                             tiled=False).reshape(-1, d)

    gathered = ret[jnp.minimum(send_idx, n_shards * cap1 - 1)]
    gathered = jnp.where(keep1[:, None], gathered, 0.0)
    y = jnp.zeros((t, d), cdt).at[src].add(
        gathered * top_w.reshape(-1)[:, None].astype(cdt))

    if mo.n_shared:
        y = y + (jax.nn.silu(xf @ p["sh_g"].astype(cdt))
                 * (xf @ p["sh_u"].astype(cdt))) @ p["sh_o"].astype(cdt)
    # d_ff slices are data-sharded → outputs are partial sums over 'data'.
    if data_axes:
        y = jax.lax.psum(y, data_axes)
    aux = jax.lax.pmean(aux, all_axes)
    return y.reshape(b_loc, s_loc, d).astype(x.dtype), aux


def _moe_replicated_local(p, x, *, cfg, n_shards: int, e_loc: int, axis: str,
                          data_axes: Tuple[str, ...], all_axes: Tuple[str, ...]):
    """Decode-shape path: tokens replicated over 'model' (S=1 cannot be
    sequence-sharded).  Replication substitutes the dispatch broadcast: every
    shard routes the full token set, computes only its *own* experts, and the
    expert outputs are combined with a psum over 'model' — the canonical
    all-gather + local-expert + reduce decode EP."""
    mo = cfg.moe
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    b_loc, s_loc, d = x.shape
    t = b_loc * s_loc
    xf = x.reshape(t, d).astype(cdt)
    top_w, top_e, aux = _route(xf, p["router"], mo)
    my_shard = jax.lax.axis_index(axis)

    flat_e = top_e.reshape(-1)
    src = jnp.repeat(jnp.arange(t, dtype=jnp.int32), mo.top_k)
    mine = (flat_e // e_loc) == my_shard
    local_e = jnp.where(mine, flat_e % e_loc, e_loc)        # foreign → overflow
    cap = int(math.ceil(t * mo.top_k / e_loc * mo.capacity_factor))
    slot = bucket_slots(local_e, e_loc + 1)
    keep = (slot < cap) & mine
    idx = jnp.where(keep, local_e * cap + slot, e_loc * cap)
    buf = jnp.zeros((e_loc * cap + 1, d), cdt).at[idx].set(xf[src], mode="drop")
    buf = buf[:-1].reshape(e_loc, cap, d)
    out = _expert_ffn(buf, p["w_g"], p["w_u"], p["w_o"], cdt)
    gathered = out.reshape(-1, d)[jnp.minimum(idx, e_loc * cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    y = jnp.zeros((t, d), cdt).at[src].add(
        gathered * top_w.reshape(-1)[:, None].astype(cdt))
    y = jax.lax.psum(y, (axis,))                            # combine experts
    if mo.n_shared:
        y = y + (jax.nn.silu(xf @ p["sh_g"].astype(cdt))
                 * (xf @ p["sh_u"].astype(cdt))) @ p["sh_o"].astype(cdt)
    if data_axes:
        y = jax.lax.psum(y, data_axes)                      # d_ff partial sums
    aux = jax.lax.pmean(aux, all_axes)
    return y.reshape(b_loc, s_loc, d).astype(x.dtype), aux


def moe_apply(p, x, *, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (y, aux_loss)."""
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        return _moe_dense_fallback(p, x, cfg)
    n_shards = mesh.shape["model"]
    e_loc = cfg.moe.n_experts // n_shards
    assert cfg.moe.n_experts % n_shards == 0
    batch_axes = batch_axis_names(mesh)
    data_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
    pspecs = {
        "router": P(None, None),
        "w_g": P("model", None, "data"),
        "w_u": P("model", None, "data"),
        "w_o": P("model", "data", None),
    }
    if cfg.moe.n_shared:
        pspecs.update({"sh_g": P(None, "data"), "sh_u": P(None, "data"),
                       "sh_o": P("data", None)})
    seq_shardable = x.shape[1] % n_shards == 0
    body = _moe_local if seq_shardable else _moe_replicated_local
    x_spec = P(batch_axes, "model" if seq_shardable else None, None)
    fn = shard_map(
        partial(body, cfg=cfg, n_shards=n_shards, e_loc=e_loc,
                axis="model", data_axes=data_axes,
                all_axes=tuple(mesh.axis_names)),
        mesh=mesh,
        in_specs=({k: pspecs[k] for k in p}, x_spec),
        out_specs=(x_spec, P()),
        check_rep=False)
    return fn(p, x)
