"""Recurrent mixers: RG-LRU (RecurrentGemma/Griffin), mLSTM and sLSTM (xLSTM).

Training/prefill paths are parallel over sequence where the math allows
(associative scan for RG-LRU, chunkwise-parallel for mLSTM); sLSTM is
inherently sequential (hidden-state feedback into the gates) and uses a
compact lax.scan.  Decode is a single recurrent step with O(1) state — this
is what makes these archs eligible for the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import PDef
from .sharding import logical


# ---------------------------------------------------------------------------
# RG-LRU (Griffin): h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
# ---------------------------------------------------------------------------

RGLRU_C = 8.0


def rglru_defs(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    w = cfg.rnn_state_dim or d
    return {
        "w_in": PDef((d, w), ("dmodel_fsdp", "rnn_state")),
        "w_gate": PDef((d, w), ("dmodel_fsdp", "rnn_state")),
        "w_rec_gate": PDef((d, w), ("dmodel_fsdp", "rnn_state")),
        "w_inp_gate": PDef((d, w), ("dmodel_fsdp", "rnn_state")),
        "lam": PDef((w,), ("rnn_state",), init="ones"),
        "w_out": PDef((w, d), ("rnn_state", "dmodel_fsdp")),
    }


def _rglru_coeffs(p, u, cdt):
    """Per-step (a_t, b_t) of the linear recurrence h = a⊙h_prev + b."""
    r = jax.nn.sigmoid((u @ p["w_rec_gate"].astype(cdt)).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_inp_gate"].astype(cdt)).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = i * u.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def rglru_apply(p, x, *, cfg, mode: str, cache=None, pos=None):
    """x: (B, S, D) → (y, new_cache);  cache = {'h': (B, w)} fp32."""
    B, S, D = x.shape
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    xq = x.astype(cdt)
    u = xq @ p["w_in"].astype(cdt)                       # (B, S, w)
    gate = jax.nn.gelu(xq @ p["w_gate"].astype(cdt))
    a, b = _rglru_coeffs(p, u, cdt)                      # fp32 (B, S, w)

    if mode == "decode":
        h_prev = cache["h"]
        h = a[:, 0] * h_prev + b[:, 0]
        hs = h[:, None]
        new_cache = {"h": h}
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
        h0 = cache["h"] if cache is not None else jnp.zeros((B, a.shape[-1]),
                                                            jnp.float32)
        hs = a_s * h0[:, None] + b_s                     # (B, S, w)
        new_cache = {"h": hs[:, -1]} if mode == "prefill" else None
    y = (hs.astype(cdt) * gate) @ p["w_out"].astype(cdt)
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix memory  C_t = f_t C_{t-1} + i_t v_t k_tᵀ
# ---------------------------------------------------------------------------

def mlstm_defs(cfg) -> Dict[str, Any]:
    d, qd = cfg.d_model, cfg.q_dim
    H = cfg.n_heads
    return {
        "wq": PDef((d, qd), ("dmodel_fsdp", "qkv")),
        "wk": PDef((d, qd), ("dmodel_fsdp", "qkv")),
        "wv": PDef((d, qd), ("dmodel_fsdp", "qkv")),
        "w_if": PDef((d, 2 * H), ("dmodel_fsdp", None)),
        "b_if": PDef((2 * H,), (None,), init="zeros"),
        "wo": PDef((qd, d), ("qkv", "dmodel_fsdp")),
    }


def _mlstm_chunk(q, k, v, ilog, flog, state):
    """One chunk of the stabilized chunkwise-parallel mLSTM.

    q,k,v: (B, H, W, dh); ilog/flog: (B, H, W) log input gate / log forget.
    state: (C, n, m) with C (B,H,dh,dh), n (B,H,dh), m (B,H) — C, n stored at
    scale exp(m).  Returns (h, new_state), h (B, H, W, dh).
    """
    B, H, W, dh = q.shape
    C, n, m = state
    b = jnp.cumsum(flog, axis=-1)                         # (B,H,W) inclusive
    btot = b[..., -1]
    # intra-chunk log decay: logD[i,j] = b_i - b_j + ilog_j for j <= i
    logD = b[..., :, None] - b[..., None, :] + ilog[..., None, :]
    tri = jnp.tril(jnp.ones((W, W), bool))
    logD = jnp.where(tri, logD, -jnp.inf)
    inter_log = b + m[..., None]                          # (B,H,W)
    m_i = jnp.maximum(jnp.max(logD, axis=-1), inter_log)  # (B,H,W)
    wgt = jnp.exp(logD - m_i[..., None])                  # (B,H,W,W)
    inter_scale = jnp.exp(inter_log - m_i)                # (B,H,W)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bhwd,bhtd->bhwt", q, k) * scale
    num = jnp.einsum("bhwt,bhtd->bhwd", wgt * scores, v) \
        + inter_scale[..., None] * jnp.einsum("bhwd,bhde->bhwe", q * scale, C)
    # C is stored k-major: C[d, e] = Σ i_t k_d v_e, so q·C = (q·k)·v
    den_vec = jnp.einsum("bhwt,bhtd->bhwd", wgt, k) + inter_scale[..., None] * n[..., None, :]
    den = jnp.abs(jnp.einsum("bhwd,bhwd->bhw", q * scale, den_vec))
    h = num / jnp.maximum(den, jnp.exp(-m_i))[..., None]
    # state update (stored at scale exp(m_new))
    upd_log = btot[..., None] - b + ilog                  # (B,H,W)
    m_new = jnp.maximum(m + btot, jnp.max(upd_log, axis=-1))
    upd = jnp.exp(upd_log - m_new[..., None])
    C_new = C * jnp.exp(m + btot - m_new)[..., None, None] \
        + jnp.einsum("bhw,bhwd,bhwe->bhde", upd, k, v)
    n_new = n * jnp.exp(m + btot - m_new)[..., None] \
        + jnp.einsum("bhw,bhwd->bhd", upd, k)
    return h, (C_new, n_new, m_new)


def mlstm_apply(p, x, *, cfg, mode: str, cache=None, pos=None, chunk: int = 128):
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    xq = x.astype(cdt)
    q = (xq @ p["wq"].astype(cdt)).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = (xq @ p["wk"].astype(cdt)).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    v = (xq @ p["wv"].astype(cdt)).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    gates = (xq @ p["w_if"].astype(cdt) + p["b_if"].astype(cdt)).astype(jnp.float32)
    ilog = gates[..., :H].transpose(0, 2, 1)              # (B,H,S) input pre-act
    flog = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)
    q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))

    if cache is not None:
        state = (cache["C"], cache["n"], cache["m"])
    else:
        state = (jnp.zeros((B, H, dh, dh), jnp.float32),
                 jnp.zeros((B, H, dh), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))

    if mode == "decode":
        h, state = _mlstm_chunk(q32, k32, v32, ilog, flog, state)
        hs = h.transpose(0, 2, 1, 3)                      # (B,1,H,dh)
    else:
        W = min(chunk, S)
        assert S % W == 0
        nc = S // W
        qs = q32.reshape(B, H, nc, W, dh).transpose(2, 0, 1, 3, 4)
        ks = k32.reshape(B, H, nc, W, dh).transpose(2, 0, 1, 3, 4)
        vs = v32.reshape(B, H, nc, W, dh).transpose(2, 0, 1, 3, 4)
        ils = ilog.reshape(B, H, nc, W).transpose(2, 0, 1, 3)
        fls = flog.reshape(B, H, nc, W).transpose(2, 0, 1, 3)

        def step(st, inp):
            h, st = _mlstm_chunk(*inp, st)
            return st, h
        state, hs = jax.lax.scan(step, state, (qs, ks, vs, ils, fls))
        # (nc, B, H, W, dh) → (B, S, H, dh)
        hs = jnp.moveaxis(hs, 0, 2).reshape(B, H, S, dh).transpose(0, 2, 1, 3)

    new_cache = {"C": state[0], "n": state[1], "m": state[2]} \
        if mode in ("prefill", "decode") else None
    y = hs.astype(cdt).reshape(B, S, H * dh) @ p["wo"].astype(cdt)
    return y.astype(x.dtype), new_cache


def mlstm_recurrent_oracle(p, x, *, cfg):
    """Step-by-step recurrent mLSTM (float32) — test oracle for the chunkwise path."""
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    x32 = x.astype(jnp.float32)
    q = (x32 @ p["wq"].astype(jnp.float32)).reshape(B, S, H, dh)
    k = (x32 @ p["wk"].astype(jnp.float32)).reshape(B, S, H, dh)
    v = (x32 @ p["wv"].astype(jnp.float32)).reshape(B, S, H, dh)
    gates = x32 @ p["w_if"].astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    ilog = gates[..., :H]
    flog = jax.nn.log_sigmoid(gates[..., H:])
    scale = 1.0 / math.sqrt(dh)
    C = jnp.zeros((B, H, dh, dh))
    n = jnp.zeros((B, H, dh))
    m = jnp.full((B, H), -1e30)
    hs = []
    for t in range(S):
        m_new = jnp.maximum(flog[:, t] + m, ilog[:, t])
        f_ = jnp.exp(flog[:, t] + m - m_new)
        i_ = jnp.exp(ilog[:, t] - m_new)
        C = f_[..., None, None] * C + i_[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", k[:, t], v[:, t])
        n = f_[..., None] * n + i_[..., None] * k[:, t]
        num = jnp.einsum("bhd,bhde->bhe", q[:, t] * scale, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, t] * scale, n))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        hs.append(h)
        m = m_new
    hs = jnp.stack(hs, axis=1)                            # (B,S,H,dh)
    return hs.reshape(B, S, H * dh) @ p["wo"].astype(jnp.float32)


# ---------------------------------------------------------------------------
# sLSTM (xLSTM): scalar memory with hidden-state feedback (sequential)
# ---------------------------------------------------------------------------

def slstm_defs(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    H, dh = cfg.n_heads, cfg.head_dim
    hd = H * dh
    return {
        "w_x": PDef((d, 4 * hd), ("dmodel_fsdp", "qkv")),
        "r_h": PDef((H, dh, 4 * dh), (None, None, None), scale=0.5),
        "b": PDef((4 * hd,), (None,), init="zeros"),
        "wo": PDef((hd, d), ("qkv", "dmodel_fsdp")),
    }


def slstm_apply(p, x, *, cfg, mode: str, cache=None, pos=None):
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    hd = H * dh
    x32 = x.astype(jnp.float32)
    pre = x32 @ p["w_x"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    pre = pre.reshape(B, S, H, 4 * dh)
    r_h = p["r_h"].astype(jnp.float32)

    if cache is not None:
        c0, n0, m0, h0 = cache["c"], cache["n"], cache["m"], cache["h"]
    else:
        c0 = jnp.zeros((B, H, dh))
        n0 = jnp.full((B, H, dh), 1e-6)
        m0 = jnp.full((B, H, dh), -1e30)
        h0 = jnp.zeros((B, H, dh))

    def step(carry, pre_t):
        c, n, m, h = carry
        g = pre_t + jnp.einsum("bhd,hde->bhe", h, r_h)
        z_, i_, f_, o_ = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(z_)
        o = jax.nn.sigmoid(o_)
        flog = jax.nn.log_sigmoid(f_)
        m_new = jnp.maximum(flog + m, i_)
        fs = jnp.exp(flog + m - m_new)
        is_ = jnp.exp(i_ - m_new)
        c_new = fs * c + is_ * z
        n_new = fs * n + is_
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    if mode == "decode":
        carry, h = step((c0, n0, m0, h0), pre[:, 0])
        hs = h[:, None]
    else:
        carry, hs = jax.lax.scan(step, (c0, n0, m0, h0),
                                 jnp.moveaxis(pre, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)                        # (B,S,H,dh)
    new_cache = ({"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
                 if mode in ("prefill", "decode") else None)
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    y = hs.reshape(B, S, hd).astype(cdt) @ p["wo"].astype(cdt)
    return y.astype(x.dtype), new_cache
