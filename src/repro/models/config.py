"""Model configuration for the assigned architectures (plane B of the framework).

Every architecture is a ``ModelConfig``; layer mixing is described by a
repeating ``pattern`` of block kinds (+ optional tail), which lets a single
scan-over-layers implementation cover dense, MoE, SSM and hybrid families
with a compact HLO regardless of depth.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

# block kinds: attn | attn_local | mla | mlstm | slstm | rglru
# ffn kinds:   swiglu | moe | none


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    pattern: Tuple[str, ...] = ("attn",)
    tail_pattern: Tuple[str, ...] = ()
    n_tail: int = 0                  # number of repeats of tail_pattern
    ffn: str = "swiglu"              # swiglu | moe | none
    moe: Optional[MoEConfig] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 0            # for attn_local blocks
    # MLA (deepseek-style compressed KV)
    kv_lora_rank: int = 0
    # recurrent dims
    rnn_state_dim: int = 0           # rglru width (defaults to d_model)
    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 0             # fixed encoder length (whisper frames)
    frontend: str = "none"           # none | embed_stub (precomputed embeddings)
    norm_eps: float = 1e-6
    param_dtype: str = "float32"     # float32 | bfloat16
    compute_dtype: str = "bfloat16"
    sub_quadratic: bool = False      # supports long_500k decode
    notes: str = ""

    @property
    def n_pattern_groups(self) -> int:
        main = self.n_layers - self.n_tail * len(self.tail_pattern)
        assert main % len(self.pattern) == 0, (
            f"{self.name}: {main} main layers not divisible by pattern "
            f"{self.pattern}")
        return main // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def block_kinds(self) -> Tuple[str, ...]:
        """The full per-layer block-kind sequence."""
        return self.pattern * self.n_pattern_groups + self.tail_pattern * self.n_tail

    def n_params_estimate(self) -> int:
        """Rough parameter count (embeddings + blocks), for roofline MODEL_FLOPS."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.frontend == "none" else 2)
        total = emb + d  # final norm
        for kind in self.block_kinds():
            total += 2 * d  # norms
            if kind in ("attn", "attn_local"):
                total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    total += self.q_dim + 2 * self.kv_dim
            elif kind == "mla":
                r = self.kv_lora_rank
                total += d * self.q_dim + d * r + r * self.kv_dim * 2 + self.q_dim * d
            elif kind == "rglru":
                w = self.rnn_state_dim or d
                total += 2 * d * w + 3 * w + w * d  # in-proj(x2 gates), lambda/gates, out
            elif kind == "mlstm":
                total += 4 * d * self.q_dim + self.q_dim * d
            elif kind == "slstm":
                h = self.n_heads * self.head_dim
                total += 4 * d * h + 4 * h * self.head_dim + h * d
            if self.ffn == "swiglu" and self.d_ff:
                total += 3 * d * self.d_ff
            elif self.ffn == "moe" and self.moe:
                total += d * self.moe.n_experts
                total += self.moe.n_experts * 3 * d * self.moe.d_expert
                total += self.moe.n_shared * 3 * d * self.moe.d_expert
        # encoder
        if self.encoder_layers:
            per = 4 * d * self.q_dim + 3 * d * self.d_ff + 2 * d
            total += self.encoder_layers * per
            total += self.n_layers * (2 * d * self.kv_dim + d * self.q_dim + self.q_dim * d + d)  # cross attn
        return total

    def active_params_estimate(self) -> int:
        """Active (per-token) parameters — differs from total only for MoE."""
        if self.ffn != "moe" or self.moe is None:
            return self.n_params_estimate()
        d = self.d_model
        dense_like = replace(self, ffn="none", moe=None).n_params_estimate()
        per_layer = (d * self.moe.n_experts
                     + (self.moe.top_k + self.moe.n_shared) * 3 * d * self.moe.d_expert)
        return dense_like + len(self.block_kinds()) * per_layer


_REGISTRY: Dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # configs register themselves on import
        import importlib
        importlib.import_module(
            "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
