"""Core layers: norms, RoPE, memory-efficient attention (causal / local / decode
split-K), GQA / MLA blocks, SwiGLU — pure functions over param pytrees.

Parameter definitions are single-sourced as ``PDef`` leaves (shape, logical
axes, init scale); ``init_from_defs`` materializes real arrays, dry-runs use
``jax.eval_shape`` over the same function, and shardings come from the axes.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
import numpy as np

from .sharding import logical


class PDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    scale: float = 1.0          # stddev multiplier on 1/sqrt(fan_in)
    init: str = "normal"        # normal | zeros | ones


def is_pdef(x) -> bool:
    return isinstance(x, PDef)


def init_from_defs(defs, key: jax.Array, dtype=jnp.float32):
    """Materialize a param pytree from PDef leaves (deterministic per-path)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_pdef)
    out = []
    for i, pd in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if pd.init == "zeros":
            out.append(jnp.zeros(pd.shape, dtype))
        elif pd.init == "ones":
            out.append(jnp.ones(pd.shape, dtype))
        else:
            fan_in = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
            std = pd.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, pd.shape, jnp.float32) * std).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def shape_structs_from_defs(defs, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, dtype), defs, is_leaf=is_pdef)


def axes_from_defs(defs):
    return jax.tree_util.tree_map(lambda pd: pd.axes, defs, is_leaf=is_pdef)


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def head_rms_norm(x, w, eps: float = 1e-6):
    """qk-norm: RMS over the head_dim axis of (..., H, dh)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, dh) or (..., H, dh) with matching positions (..., S) / scalar."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                          # broadcast over H
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin, x1f * sin + x2f * cos],
                           axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Memory-efficient attention (pure XLA): online-softmax scan over KV blocks
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                        window: int = 0, kv_block: int = 1024,
                        banded: bool = False):
    """softmax(QKᵀ/√dh)V without materializing the S×S score matrix.

    q: (B, S, H, dh);  k, v: (B, T, Hkv, dh);  GQA via head grouping.
    q_pos: (S,), kv_pos: (T,) absolute positions for causal/local masks.
    ``banded=True`` skips KV blocks that are entirely masked for every query
    (the §Perf causal-FLOPs optimization) by zeroing their contribution with a
    block-level predicate — XLA-visible FLOPs are still spent unless the block
    loop itself is shortened, so banded mode *restructures the loop per
    diagonal*; see ``banded_causal_attention``.
    """
    B, S, H, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    nkv = -(-T // kv_block)
    pad = nkv * kv_block - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-(10 ** 9))
    qg = q.reshape(B, S, Hkv, G, dh)
    kb = k.reshape(B, nkv, kv_block, Hkv, dh)
    vb = v.reshape(B, nkv, kv_block, Hkv, dh)
    pb = kv_pos.reshape(nkv, kv_block)

    def step(carry, inp):
        m, l, acc = carry
        kj, vj, pj = inp
        s = jnp.einsum("bskgd,btkd->bskgt", qg, kj,
                       preferred_element_type=jnp.float32) * scale
        mask_qt = None
        if causal:
            mask_qt = pj[None, :] <= q_pos[:, None]              # (S, kvb)
        if window:
            w_mask = pj[None, :] > q_pos[:, None] - window
            mask_qt = w_mask if mask_qt is None else (mask_qt & w_mask)
        if pad and not causal:
            v_mask = (pj >= 0)[None, :] | jnp.zeros((S, 1), bool)
            mask_qt = v_mask if mask_qt is None else (mask_qt & v_mask)
        if mask_qt is not None:
            s = jnp.where(mask_qt[None, :, None, None, :], s, -jnp.inf)
        mj = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, mj)
        # fully-masked blocks keep m_new = -inf: guard exp(-inf - -inf)
        corr = jnp.where(jnp.isfinite(m_new), jnp.exp(m - m_new), 0.0)
        p = jnp.exp(s - jnp.where(jnp.isfinite(m_new), m_new, 0.0)[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, Hkv, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, G, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, dh).astype(q.dtype)


def local_attention(q, k, v, q_pos, kv_pos, *, window: int):
    """Chunked sliding-window causal attention: O(S·2W) compute and memory.

    Sequence is cut into W-sized chunks; chunk i attends to chunks {i-1, i}
    with an exact (q_pos - kv_pos) ∈ [0, W) mask.
    """
    B, S0, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    W = window
    pad = (-S0) % W
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-(10 ** 9))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-(2 * 10 ** 9))
    S = S0 + pad
    nc = S // W
    scale = 1.0 / math.sqrt(dh)
    qc = q.reshape(B, nc, W, Hkv, G, dh)
    kc = k.reshape(B, nc, W, Hkv, dh)
    vc = v.reshape(B, nc, W, Hkv, dh)
    kprev = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vprev = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([kprev, kc], axis=2)       # (B, nc, 2W, Hkv, dh)
    v2 = jnp.concatenate([vprev, vc], axis=2)
    s = jnp.einsum("bcwkgd,bctkd->bckgwt", qc, k2,
                   preferred_element_type=jnp.float32) * scale
    qp = q_pos.reshape(nc, W)
    kp = kv_pos.reshape(nc, W)
    kp2 = jnp.concatenate([jnp.pad(kp, ((1, 0), (0, 0)),
                                   constant_values=-(10 ** 9))[:-1], kp], axis=1)
    diff = qp[:, :, None] - kp2[:, None, :]          # (nc, W, 2W)
    mask = (diff >= 0) & (diff < W)
    s = jnp.where(mask[None, :, None, None, :, :], s, -jnp.inf)
    # pad-safe softmax (fully-masked rows → 0, not NaN)
    smax = jnp.max(s, axis=-1, keepdims=True, initial=-1e30)
    p = jnp.exp(s - smax)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bckgwt,bctkd->bcwkgd", p.astype(v2.dtype), v2,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, dh)[:, :S0].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """Single-step attention against a cache (split-K under the mesh: the cache
    length axis carries the ``kv_seq → model`` sharding; GSPMD turns the
    softmax/sum reductions into cross-shard collectives)."""
    B, _, H, dh = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, Hkv, G, dh)
    # caches may be stored quantized (fp8 hillclimb); upcast at the MXU edge
    k_cache = k_cache.astype(q.dtype)
    v_cache = v_cache.astype(q.dtype)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(T)
    mask = idx[None, None, None, :] <= pos
    if window:
        mask = jnp.logical_and(mask, idx[None, None, None, :] > pos - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention blocks (GQA and MLA)
# ---------------------------------------------------------------------------

def attn_defs(cfg) -> Dict[str, Any]:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    defs: Dict[str, Any] = {
        "wq": PDef((d, qd), ("dmodel_fsdp", "qkv")),
        "wk": PDef((d, kvd), ("dmodel_fsdp", "qkv")),
        "wv": PDef((d, kvd), ("dmodel_fsdp", "qkv")),
        "wo": PDef((qd, d), ("qkv", "dmodel_fsdp")),
    }
    if cfg.qkv_bias:
        defs["bq"] = PDef((qd,), ("qkv",), init="zeros")
        defs["bk"] = PDef((kvd,), ("qkv",), init="zeros")
        defs["bv"] = PDef((kvd,), ("qkv",), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = PDef((cfg.head_dim,), (None,), init="ones")
        defs["k_norm"] = PDef((cfg.head_dim,), (None,), init="ones")
    return defs


# §Perf T1c: attention-region sharding mode.  'seq' (default) shards the
# query sequence over 'model' (divisibility-safe everywhere, but pays 4
# residual-stream reshards per layer).  'heads' pads the GQA group count so
# (Hkv · G') divides the model axis and shards *heads* instead — no
# activation reshard, + (G'/G − 1) extra attention FLOPs.  Falls back to
# 'seq' when padding waste would exceed 25%.
ATTN_SHARDING = ["seq"]


def set_attn_sharding(mode: str):
    assert mode in ("seq", "heads")
    ATTN_SHARDING[0] = mode


def _heads_padding(H: int, Hkv: int, msize: int):
    """Smallest padded group count G' with (Hkv·G') % msize == 0, or None."""
    G = H // Hkv
    gp = G
    while (Hkv * gp) % msize != 0:
        gp += 1
        if gp > 2 * G:
            return None
    return gp if gp / G <= 1.25 else None


def attn_apply(p, x, *, cfg, mode: str, cache=None, pos=None,
               local: bool = False):
    """x: (B, S, D).  Returns (y, new_cache)."""
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    xq = x.astype(cdt)
    q = xq @ p["wq"].astype(cdt)
    k = xq @ p["wk"].astype(cdt)
    v = xq @ p["wv"].astype(cdt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)

    if mode == "decode":
        positions = pos  # scalar
        q = rope(q, jnp.asarray(pos)[None], cfg.rope_theta) \
            if cfg.rope_theta else q
        k = rope(k, jnp.asarray(pos)[None], cfg.rope_theta) \
            if cfg.rope_theta else k
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        k_cache = logical(k_cache, "batch", "kv_seq", "heads", None)
        v_cache = logical(v_cache, "batch", "kv_seq", "heads", None)
        o = decode_attention(q, k_cache, v_cache, pos,
                             window=cfg.local_window if local else 0)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        positions = jnp.arange(S)
        if cfg.rope_theta:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        # name the (to-be-all-gathered) K/V so the 'dots+kv' remat policy can
        # save them across the backward pass (§Perf hillclimb B)
        k = jax.ad_checkpoint.checkpoint_name(k, "kv")
        v = jax.ad_checkpoint.checkpoint_name(v, "kv")
        from .sharding import current_mesh
        mesh = current_mesh()
        gp = None
        if (ATTN_SHARDING[0] == "heads" and not local and mesh is not None
                and "model" in mesh.axis_names):
            gp = _heads_padding(H, Hkv, mesh.shape["model"])
        if gp is not None:
            G = H // Hkv
            Hp = Hkv * gp
            qg = q.reshape(B, S, Hkv, G, dh)
            qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, gp - G), (0, 0)))
            q = qg.reshape(B, S, Hp, dh)
            q = logical(q, "batch", None, "heads_shard", None)
            # repeat KV to the padded head count so the single head axis
            # shards |model|-ways cleanly (a (Hkv, G') reshape cannot)
            k = jnp.repeat(k, gp, axis=2)
            v = jnp.repeat(v, gp, axis=2)
            k = logical(k, "batch", None, "heads_shard", None)
            v = logical(v, "batch", None, "heads_shard", None)
            o = blockwise_attention(q, k, v, positions, positions, causal=True)
            o = logical(o, "batch", None, "heads_shard", None)
            # zero-padded wo rows kill the (uniform-softmax) padded-head output
            wo = p["wo"].astype(cdt).reshape(H, dh, -1)
            wo = jnp.pad(wo, ((0, Hp - H), (0, 0), (0, 0))).reshape(Hp * dh, -1)
            y = o.reshape(B, S, Hp * dh) @ wo
            new_cache = {"k": k, "v": v} if mode == "prefill" else None
            return y.astype(x.dtype), new_cache
        q = logical(q, "batch", "seq_shard", "heads", None)
        if local:
            o = local_attention(q, k, v, positions, positions,
                                window=cfg.local_window)
        else:
            o = blockwise_attention(q, k, v, positions, positions, causal=True)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    o = logical(o, "batch", "seq_shard", "heads", None)
    y = o.reshape(B, S, H * dh) @ p["wo"].astype(cdt)
    return y.astype(x.dtype), new_cache


def mla_defs(cfg) -> Dict[str, Any]:
    d, qd, r = cfg.d_model, cfg.q_dim, cfg.kv_lora_rank
    return {
        "wq": PDef((d, qd), ("dmodel_fsdp", "qkv")),
        "w_dkv": PDef((d, r), ("dmodel_fsdp", "lora")),
        "w_uk": PDef((r, qd), ("lora", "qkv")),
        "w_uv": PDef((r, qd), ("lora", "qkv")),
        "wo": PDef((qd, d), ("qkv", "dmodel_fsdp")),
    }


def mla_apply(p, x, *, cfg, mode: str, cache=None, pos=None):
    """DeepSeek-style Multi-head Latent Attention with compressed KV cache.

    Train/prefill: decompress K/V and run blockwise attention.
    Decode: *absorbed* form — scores and values live in the rank-r latent
    space, the cache holds only c_kv (B, T, r): the paper-faithful system
    character (tiny cache, extra decode FLOPs).
    """
    B, S, D = x.shape
    H, dh, r = cfg.n_heads, cfg.head_dim, cfg.kv_lora_rank
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    xq = x.astype(cdt)
    q = (xq @ p["wq"].astype(cdt)).reshape(B, S, H, dh)
    c_kv = xq @ p["w_dkv"].astype(cdt)                      # (B, S, r)

    if mode == "decode":
        c_cache = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        c_cache = logical(c_cache, "batch", "kv_seq", "lora")
        c_comp = c_cache.astype(cdt)        # may be stored quantized (fp8)
        wuk = p["w_uk"].astype(cdt).reshape(r, H, dh)
        wuv = p["w_uv"].astype(cdt).reshape(r, H, dh)
        q_lat = jnp.einsum("bshd,rhd->bshr", q, wuk)        # absorb W_uk into q
        s = jnp.einsum("bshr,btr->bhst", q_lat, c_comp,
                       preferred_element_type=jnp.float32) / math.sqrt(dh)
        idx = jnp.arange(c_cache.shape[1])
        s = jnp.where(idx[None, None, None, :] <= pos, s, -jnp.inf)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", pr.astype(cdt), c_comp)
        o = jnp.einsum("bshr,rhd->bshd", o_lat, wuv)
        new_cache = {"c_kv": c_cache}
    else:
        k = (c_kv @ p["w_uk"].astype(cdt)).reshape(B, S, H, dh)
        v = (c_kv @ p["w_uv"].astype(cdt)).reshape(B, S, H, dh)
        positions = jnp.arange(S)
        q = logical(q, "batch", "seq_shard", "heads", None)
        o = blockwise_attention(q, k, v, positions, positions, causal=True)
        new_cache = {"c_kv": c_kv} if mode == "prefill" else None
    o = logical(o, "batch", "seq_shard", "heads", None)
    y = o.reshape(B, S, H * dh) @ p["wo"].astype(cdt)
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def swiglu_defs(cfg) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wg": PDef((d, f), ("dmodel_fsdp", "dff")),
        "wu": PDef((d, f), ("dmodel_fsdp", "dff")),
        "wo": PDef((f, d), ("dff", "dmodel_fsdp")),
    }


def swiglu_apply(p, x, *, cfg):
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    xq = x.astype(cdt)
    g = jax.nn.silu(xq @ p["wg"].astype(cdt))
    u = xq @ p["wu"].astype(cdt)
    # Megatron TP: the hidden activation shards d_ff over 'model' (seq stays
    # unsharded here — it is seq-sharded only in the attention region).
    h = logical(g * u, "batch", None, "dff")
    return (h @ p["wo"].astype(cdt)).astype(x.dtype)
