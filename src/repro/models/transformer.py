"""Model assembly: pattern-scanned decoder (+ optional encoder) over all block
kinds, with a single param-def tree, cache machinery, and train/prefill/decode
entry points.

Layers are scanned by *pattern group* (cfg.pattern repeated n_groups times,
plus an optional tail) so the HLO stays compact for 61-layer/1T-param models,
and remat wraps each group in training.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (PDef, attn_apply, attn_defs, axes_from_defs,
                     blockwise_attention, init_from_defs, is_pdef, mla_apply,
                     mla_defs, rms_norm, shape_structs_from_defs, swiglu_apply,
                     swiglu_defs)
from .moe import moe_apply, moe_defs
from .recurrent import (mlstm_apply, mlstm_defs, rglru_apply, rglru_defs,
                        slstm_apply, slstm_defs)
from .sharding import logical

MIXER_DEFS = {
    "attn": attn_defs, "attn_local": attn_defs, "attn_bidir": attn_defs,
    "mla": mla_defs, "rglru": rglru_defs, "mlstm": mlstm_defs,
    "slstm": slstm_defs,
}


def _stack_defs(defs, n: int):
    return jax.tree_util.tree_map(
        lambda pd: PDef((n,) + pd.shape, (None,) + pd.axes, pd.scale, pd.init),
        defs, is_leaf=is_pdef)


def _block_defs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d = {
        "norm1": PDef((cfg.d_model,), (None,), init="ones"),
        "mixer": MIXER_DEFS[kind](cfg),
    }
    if cfg.ffn == "swiglu" and cfg.d_ff:
        d["norm2"] = PDef((cfg.d_model,), (None,), init="ones")
        d["ffn"] = swiglu_defs(cfg)
    elif cfg.ffn == "moe" and cfg.moe is not None:
        d["norm2"] = PDef((cfg.d_model,), (None,), init="ones")
        d["ffn"] = moe_defs(cfg)
    return d


def _xattn_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"norm": PDef((cfg.d_model,), (None,), init="ones"),
            "attn": attn_defs(cfg)}


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d: Dict[str, Any] = {}
    if cfg.frontend == "none":
        d["embed"] = PDef((cfg.vocab_size, cfg.d_model), ("vocab", "dmodel_fsdp"))
    d["lm_head"] = PDef((cfg.d_model, cfg.vocab_size), ("dmodel_fsdp", "vocab"))
    d["final_norm"] = PDef((cfg.d_model,), (None,), init="ones")
    main = {f"{i}:{kind}": _block_defs(cfg, kind)
            for i, kind in enumerate(cfg.pattern)}
    if cfg.encoder_layers:
        for i, _ in enumerate(cfg.pattern):
            main[f"{i}:xattn"] = _xattn_defs(cfg)
    d["blocks"] = _stack_defs(main, cfg.n_pattern_groups)
    if cfg.n_tail:
        tail = {f"{i}:{kind}": _block_defs(cfg, kind)
                for i, kind in enumerate(cfg.tail_pattern)}
        d["tail_blocks"] = _stack_defs(tail, cfg.n_tail)
    if cfg.encoder_layers:
        enc = {"0:attn_bidir": _block_defs(cfg, "attn_bidir")}
        d["encoder_blocks"] = _stack_defs(enc, cfg.encoder_layers)
        d["encoder_norm"] = PDef((cfg.d_model,), (None,), init="ones")
    return d


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _mixer_cache_defs(cfg: ModelConfig, kind: str, batch: int, seq: int):
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if kind in ("attn", "attn_local"):
        return {"k": PDef((batch, seq, Hkv, dh), ("batch", "kv_seq", "heads", None)),
                "v": PDef((batch, seq, Hkv, dh), ("batch", "kv_seq", "heads", None))}
    if kind == "mla":
        return {"c_kv": PDef((batch, seq, cfg.kv_lora_rank),
                             ("batch", "kv_seq", "lora"))}
    if kind == "rglru":
        w = cfg.rnn_state_dim or cfg.d_model
        return {"h": PDef((batch, w), ("batch", "rnn_state"))}
    if kind == "mlstm":
        return {"C": PDef((batch, H, dh, dh), ("batch", "heads", None, None)),
                "n": PDef((batch, H, dh), ("batch", "heads", None)),
                "m": PDef((batch, H), ("batch", "heads"))}
    if kind == "slstm":
        return {k: PDef((batch, H, dh), ("batch", "heads", None))
                for k in ("c", "n", "m", "h")}
    raise ValueError(kind)


def cache_defs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    main = {f"{i}:{kind}": _mixer_cache_defs(cfg, kind, batch, seq)
            for i, kind in enumerate(cfg.pattern)}
    if cfg.encoder_layers:  # decode-time cross-attn K/V from the encoder
        for i, _ in enumerate(cfg.pattern):
            main[f"{i}:xattn"] = {
                "k": PDef((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim),
                          ("batch", None, "heads", None)),
                "v": PDef((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim),
                          ("batch", None, "heads", None))}
    out = {"blocks": _stack_defs(main, cfg.n_pattern_groups)}
    if cfg.n_tail:
        tail = {f"{i}:{kind}": _mixer_cache_defs(cfg, kind, batch, seq)
                for i, kind in enumerate(cfg.tail_pattern)}
        out["tail_blocks"] = _stack_defs(tail, cfg.n_tail)
    return out


_KV_CACHE_KEYS = ("k", "v", "c_kv")   # stored in cache dtype; states stay fp32


def _cache_leaf_dtype(path, dtype):
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return dtype if name in _KV_CACHE_KEYS else jnp.float32


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    defs = cache_defs(cfg, batch, seq)
    return jax.tree_util.tree_map_with_path(
        lambda path, pd: jnp.zeros(pd.shape, _cache_leaf_dtype(path, dtype)),
        defs, is_leaf=is_pdef)


def cache_shape_structs(cfg: ModelConfig, batch: int, seq: int,
                        dtype=jnp.bfloat16):
    defs = cache_defs(cfg, batch, seq)
    return jax.tree_util.tree_map_with_path(
        lambda path, pd: jax.ShapeDtypeStruct(
            pd.shape, _cache_leaf_dtype(path, dtype)),
        defs, is_leaf=is_pdef)


def cache_axes(cfg: ModelConfig, batch: int, seq: int):
    return axes_from_defs(cache_defs(cfg, batch, seq))


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

MIXER_APPLY = {
    "attn": partial(attn_apply, local=False),
    "attn_local": partial(attn_apply, local=True),
    "mla": mla_apply,
    "rglru": rglru_apply,
    "mlstm": mlstm_apply,
    "slstm": slstm_apply,
}


def _bidir_attn_apply(p, x, *, cfg, kv=None):
    """Bidirectional (encoder) or cross attention — no mask, no cache."""
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    xq = x.astype(cdt)
    q = (xq @ p["wq"].astype(cdt)).reshape(B, S, H, dh)
    if kv is None:
        src = xq
    else:
        src = kv.astype(cdt)
    T = src.shape[1]
    k = (src @ p["wk"].astype(cdt)).reshape(B, T, Hkv, dh)
    v = (src @ p["wv"].astype(cdt)).reshape(B, T, Hkv, dh)
    pos_q = jnp.arange(S)
    pos_k = jnp.arange(T)
    o = blockwise_attention(q, k, v, pos_q, pos_k, causal=False)
    y = o.reshape(B, S, H * dh) @ p["wo"].astype(cdt)
    return y.astype(x.dtype)


def _xattn_cached(p, x, k_cache, v_cache, *, cfg):
    """Cross-attention against precomputed encoder K/V (decode path)."""
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    q = (x.astype(cdt) @ p["wq"].astype(cdt)).reshape(B, S, H, dh)
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, dh)
    s = jnp.einsum("bskgd,btkd->bskgt", qg, k_cache.astype(cdt),
                   preferred_element_type=jnp.float32) / math.sqrt(dh)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkd->bskgd", pr.astype(cdt), v_cache.astype(cdt))
    y = o.reshape(B, S, H * dh) @ p["wo"].astype(cdt)
    return y.astype(x.dtype)


def apply_block(kind: str, p, x, *, cfg, mode, cache, pos, enc_out=None):
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn_bidir":
        y = _bidir_attn_apply(p["mixer"], h, cfg=cfg)
        new_cache = None
    else:
        y, new_cache = MIXER_APPLY[kind](p["mixer"], h, cfg=cfg, mode=mode,
                                         cache=cache, pos=pos)
    x = x + y
    if "ffn" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.ffn == "moe":
            y, aux = moe_apply(p["ffn"], h, cfg=cfg)
        else:
            y = swiglu_apply(p["ffn"], h, cfg=cfg)
        x = x + y
    return x, new_cache, aux


def _group_step(cfg: ModelConfig, pattern, x, gp, gcache, *, mode, pos,
                enc_out=None):
    """Apply one pattern group (sequence of blocks)."""
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(pattern):
        key = f"{i}:{kind}"
        cache = None if gcache is None else gcache.get(key)
        x, nc, aux = apply_block(kind, gp[key], x, cfg=cfg, mode=mode,
                                 cache=cache, pos=pos)
        aux_total = aux_total + aux
        if cfg.encoder_layers and (enc_out is not None or mode == "decode"):
            xk = f"{i}:xattn"
            h = rms_norm(x, gp[xk]["norm"], cfg.norm_eps)
            if mode == "decode":
                y = _xattn_cached(gp[xk]["attn"], h, gcache[xk]["k"],
                                  gcache[xk]["v"], cfg=cfg)
                nc_x = {"k": gcache[xk]["k"], "v": gcache[xk]["v"]}
            else:
                y = _bidir_attn_apply(gp[xk]["attn"], h, cfg=cfg, kv=enc_out)
                if mode == "prefill":
                    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
                    e = enc_out.astype(cdt)
                    B, T, _ = e.shape
                    nc_x = {"k": (e @ gp[xk]["attn"]["wk"].astype(cdt)).reshape(
                                B, T, cfg.n_kv_heads, cfg.head_dim),
                            "v": (e @ gp[xk]["attn"]["wv"].astype(cdt)).reshape(
                                B, T, cfg.n_kv_heads, cfg.head_dim)}
                else:
                    nc_x = None
            x = x + y
            if nc_x is not None:
                new_caches[xk] = nc_x
        if nc is not None:
            new_caches[key] = nc
    return x, (new_caches if new_caches else None), aux_total


_REMAT_POLICIES = {
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    # §Perf: additionally save the all-gathered K/V (checkpoint_name 'kv') so
    # the backward pass re-reads them from HBM instead of re-gathering over ICI
    "dots+kv": lambda: jax.checkpoint_policies.save_from_both_policies(
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        jax.checkpoint_policies.save_only_these_names("kv")),
    "nothing": lambda: jax.checkpoint_policies.nothing_saveable,
}
_ACTIVE_REMAT_POLICY = ["dots"]


def set_remat_policy(name: str):
    assert name in _REMAT_POLICIES, name
    _ACTIVE_REMAT_POLICY[0] = name


def _scan_blocks(cfg, pattern, x, stacked_params, stacked_caches, *, mode, pos,
                 enc_out=None, remat: bool = False):
    collect = mode in ("prefill", "decode")

    def body(x, inp):
        gp, gcache = inp
        x, ncache, aux = _group_step(cfg, pattern, x, gp, gcache, mode=mode,
                                     pos=pos, enc_out=enc_out)
        return x, (ncache if collect else None, aux)

    if remat:
        body = jax.checkpoint(
            body, policy=_REMAT_POLICIES[_ACTIVE_REMAT_POLICY[0]]())
    x, (ncaches, auxes) = jax.lax.scan(body, x, (stacked_params, stacked_caches))
    return x, ncaches, jnp.sum(auxes)


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

@dataclass
class Model:
    cfg: ModelConfig

    # -- params ------------------------------------------------------------
    def param_defs(self):
        return model_defs(self.cfg)

    def init(self, key: jax.Array):
        dt = jnp.bfloat16 if self.cfg.param_dtype == "bfloat16" else jnp.float32
        return init_from_defs(self.param_defs(), key, dt)

    def param_shapes(self):
        dt = jnp.bfloat16 if self.cfg.param_dtype == "bfloat16" else jnp.float32
        return shape_structs_from_defs(self.param_defs(), dt)

    def param_axes(self):
        return axes_from_defs(self.param_defs())

    # -- embedding / head ----------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        if cfg.frontend == "none":
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
            x = x * math.sqrt(cfg.d_model)
        else:
            x = batch["embeds"]     # modality frontend stub: precomputed
        return logical(x.astype(jnp.bfloat16 if cfg.compute_dtype == "bfloat16"
                                else jnp.float32), "batch", "seq", "dmodel")

    def _encode(self, params, batch):
        cfg = self.cfg
        x = batch["enc_embeds"].astype(
            jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32)
        x, _, _ = _scan_blocks(cfg, ("attn_bidir",), x,
                               params["encoder_blocks"], None,
                               mode="train", pos=None)
        return rms_norm(x, params["encoder_norm"], cfg.norm_eps)

    def _trunk(self, params, x, caches, *, mode, pos, enc_out, remat):
        cfg = self.cfg
        x, nc_main, aux = _scan_blocks(
            cfg, cfg.pattern, x, params["blocks"],
            None if caches is None else caches["blocks"],
            mode=mode, pos=pos, enc_out=enc_out, remat=remat)
        new_caches = {"blocks": nc_main} if nc_main is not None else None
        if cfg.n_tail:
            x, nc_tail, aux2 = _scan_blocks(
                cfg, cfg.tail_pattern, x, params["tail_blocks"],
                None if caches is None else caches["tail_blocks"],
                mode=mode, pos=pos, enc_out=None, remat=remat)
            aux = aux + aux2
            if new_caches is not None:
                new_caches["tail_blocks"] = nc_tail
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, new_caches, aux

    def _logits(self, params, x):
        cfg = self.cfg
        cdt = x.dtype
        logits = x @ params["lm_head"].astype(cdt)
        return logical(logits, "batch", "seq", "vocab")

    # -- entry points --------------------------------------------------------
    def loss_fn(self, params, batch, *, remat: bool = True,
                aux_weight: float = 0.01):
        """Mean next-token cross entropy (labels provided, already shifted)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.encoder_layers else None
        x = self._embed(params, batch)
        x, _, aux = self._trunk(params, x, None, mode="train", pos=None,
                                enc_out=enc_out, remat=remat)
        logits = self._logits(params, x).astype(jnp.float32)
        labels = batch["labels"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        nll = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return nll + aux_weight * aux

    def prefill(self, params, batch, cache_len: Optional[int] = None):
        """Run the prompt; returns (last-position logits, caches)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.encoder_layers else None
        x = self._embed(params, batch)
        B, S = x.shape[0], x.shape[1]
        x, caches, _ = self._trunk(params, x, None, mode="prefill", pos=None,
                                   enc_out=enc_out, remat=False)
        logits = self._logits(params, x[:, -1:, :])
        # Prefill returns K/V for the prompt; serving pads to cache_len.
        if cache_len is not None and cache_len > S:
            def pad(leaf):
                if leaf.ndim >= 3 and leaf.shape[1] == S:   # (B, S, ...) kv
                    pad_width = [(0, 0)] * leaf.ndim
                    pad_width[1] = (0, cache_len - S)
                    return jnp.pad(leaf, pad_width)
                return leaf
            caches = jax.tree_util.tree_map(pad, caches)
        return logits, caches

    def decode_step(self, params, tokens, caches, pos):
        """One token for the whole batch.  tokens: (B, 1) int32; pos: scalar."""
        cfg = self.cfg
        if cfg.frontend == "none":
            x = jnp.take(params["embed"], tokens, axis=0) * math.sqrt(cfg.d_model)
        else:
            x = jnp.take(params["lm_head"].T, tokens, axis=0) * math.sqrt(cfg.d_model)
        x = x.astype(jnp.bfloat16 if cfg.compute_dtype == "bfloat16"
                     else jnp.float32)
        x, new_caches, _ = self._trunk(params, x, caches, mode="decode",
                                       pos=pos, enc_out=None, remat=False)
        logits = self._logits(params, x)
        return logits, new_caches
