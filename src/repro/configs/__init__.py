"""Architecture configs (one module per assigned arch) + paper-plane configs.

Importing this package registers every architecture in the model registry.
"""
ARCH_IDS = (
    "xlstm-350m", "recurrentgemma-2b", "qwen2.5-14b", "qwen1.5-32b",
    "yi-34b", "qwen3-4b", "kimi-k2-1t-a32b", "deepseek-v2-236b",
    "chameleon-34b", "whisper-small",
)


def load_all():
    import importlib
    for a in ARCH_IDS:
        importlib.import_module(f"repro.configs.{a.replace('-', '_').replace('.', '_')}")
