"""RecurrentGemma-2B / Griffin [arXiv:2402.19427]: RG-LRU + local attention, 1:2."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256_000, head_dim=256,
    pattern=("rglru", "rglru", "attn_local"), tail_pattern=("rglru",), n_tail=2,
    local_window=2048, rnn_state_dim=2560, sub_quadratic=True,
    notes="(R,R,A)x8 + (R,R) = 26 blocks; MQA (kv=1), window 2048."))
