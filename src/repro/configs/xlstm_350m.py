"""xLSTM-350M [arXiv:2405.04517]: alternating mLSTM/sLSTM blocks, no separate FFN."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, head_dim=256,
    pattern=("mlstm", "slstm"), ffn="none",
    rope_theta=0.0, sub_quadratic=True,
    notes="d_ff=0: the xLSTM blocks carry their own projections (paper config)."))
