"""Yi-34B [arXiv:2403.04652]: llama-arch GQA."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64_000, head_dim=128, rope_theta=5e6, param_dtype="bfloat16"))
