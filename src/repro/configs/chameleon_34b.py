"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM; VQ frontend is a stub —
input_specs() provides precomputed fused token embeddings."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=65_536, head_dim=128, qk_norm=True,
    frontend="embed_stub", param_dtype="bfloat16",
    notes="Backbone only; VQ image tokenizer stubbed per the brief."))
