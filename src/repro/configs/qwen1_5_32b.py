"""Qwen1.5-32B [hf:Qwen/Qwen1.5]: dense MHA (kv=40) with QKV bias."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
    vocab_size=152_064, head_dim=128, qkv_bias=True, param_dtype="bfloat16"))
