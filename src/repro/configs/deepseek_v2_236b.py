"""DeepSeek-V2 236B [arXiv:2405.04434]: MLA (kv_lora=512) + 160-expert top-6 MoE."""
from repro.models.config import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=1536,
    vocab_size=102_400, head_dim=128,
    pattern=("mla",), kv_lora_rank=512,
    ffn="moe", moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2),
    param_dtype="bfloat16",
    notes="All layers MoE (paper-table simplification; real model has 1 dense layer)."))
