"""Assigned input shapes and per-(arch × shape) input specs.

The four LM shape cells (seq_len × global_batch):
    train_4k      4,096 × 256   (training:  lowers train_step)
    prefill_32k  32,768 × 32    (inference: lowers prefill)
    decode_32k   32,768 × 128   (inference: lowers ONE decode step w/ full cache)
    long_500k   524,288 × 1     (long-context decode; sub-quadratic archs only)

``input_specs`` returns jax.ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, never allocated.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, get_config

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}

# Microbatch counts for train_4k (grad accumulation) — sized so per-microbatch
# logits/activations fit v5e HBM; see EXPERIMENTS.md §Dry-run.
TRAIN_MICROBATCHES: Dict[str, int] = {
    "xlstm-350m": 2,
    "recurrentgemma-2b": 4,
    "qwen2.5-14b": 8,
    "qwen1.5-32b": 8,
    "yi-34b": 8,
    "qwen3-4b": 4,
    "kimi-k2-1t-a32b": 16,
    "deepseek-v2-236b": 16,
    "chameleon-34b": 8,
    "whisper-small": 8,
}


def cell_is_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k dense KV decode is "
                       "architecturally quadratic (skip per docs/DESIGN.md)")
    return True, ""


def input_specs(arch: str, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    B, S = sh["global_batch"], sh["seq_len"]
    i32 = jnp.int32
    emb_dt = jnp.bfloat16
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if sh["kind"] == "train":
        if cfg.frontend == "embed_stub":
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), emb_dt)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.encoder_layers:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), emb_dt)
    elif sh["kind"] == "prefill":
        if cfg.frontend == "embed_stub":
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), emb_dt)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.encoder_layers:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), emb_dt)
    else:  # decode: one new token against a seq_len cache
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
    return specs


def reduced_config(arch: str) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (per the brief)."""
    cfg = get_config(arch)
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=len(cfg.pattern) + len(cfg.tail_pattern) * (1 if cfg.n_tail else 0),
        d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16, d_ff=128 if cfg.d_ff else 0,
        vocab_size=256, local_window=8 if cfg.local_window else 0,
        rnn_state_dim=64 if cfg.rnn_state_dim else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        n_tail=1 if cfg.n_tail else 0,
        encoder_layers=1 if cfg.encoder_layers else 0,
        encoder_seq=12 if cfg.encoder_seq else 0,
        param_dtype="float32", compute_dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=8, top_k=2,
                                        d_expert=32, n_shared=min(cfg.moe.n_shared, 1))
        kw["d_ff"] = 32
    return dataclasses.replace(cfg, **kw)
