"""Paper-plane config: the Adult ≤3-way marginal workload (paper §8).

Usage:
    from repro.configs.adult_marginals import make
    domain, workload = make(kmax=3)
"""
from repro.core import Domain, all_kway
from repro.data.tabular import ADULT_SIZES


def make(kmax: int = 3, weights: str = "cells"):
    domain = Domain.create(ADULT_SIZES, names=[f"adult{i}" for i in range(14)])
    wk = all_kway(domain, kmax, include_lower=True).reweighted(weights)
    return domain, wk
