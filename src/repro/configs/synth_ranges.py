"""Paper-plane config: Synth-n^d generalized-marginal (range/prefix) workloads
(paper §9) for ResidualPlanner+.

Usage:
    from repro.configs.synth_ranges import make
    domain, workload, schema = make(n=10, d=20, kind="range")
"""
from repro.core import all_kway
from repro.core.plus import PlusSchema
from repro.data.tabular import synth_domain


def make(n: int = 10, d: int = 20, kmax: int = 3, kind: str = "range",
         strategy_mode: str = "hier"):
    domain = synth_domain(n, d, kind="numeric")
    wk = all_kway(domain, min(kmax, d), include_lower=True)
    schema = PlusSchema.create(domain, [kind] * d, strategy_mode=strategy_mode)
    return domain, wk, schema
