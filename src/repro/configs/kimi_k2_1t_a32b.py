"""Kimi K2 (1T total / 32B active) [arXiv:2501.kimi2]: 384-expert top-8 MoE."""
from repro.models.config import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163_840, head_dim=128,
    ffn="moe", moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared=1),
    param_dtype="bfloat16",
    notes="d_ff is the per-expert width; 1 shared expert (paper-table config)."))
