"""Whisper-small [arXiv:2212.04356]: enc-dec; conv/audio frontend is a stub —
input_specs() provides precomputed frame embeddings (B, 1500, d)."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=51_865, head_dim=64, rope_theta=10_000.0,
    encoder_layers=12, encoder_seq=1500,
    notes="Decoder tokens embedded normally; encoder consumes stub frame embeds."))
