"""DP-SGD integration of the paper's privacy machinery (plane A ↔ plane B).

Per-example clipped gradients + Gaussian noise form a *linear Gaussian
mechanism* in the sense of Definition 2: sensitivity C, noise N(0, (Cσ)² I),
so each step has pcost = 1/σ², and steps compose additively (end of §2.1).
The accountant below is exactly `repro.core.accountant` — the same code that
prices the marginal mechanisms prices the training run, and budgets can be
shared between noisy-marginal releases and DP training.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.accountant import (PrivacyBudget, approx_dp_eps, gdp_mu,
                                   zcdp_rho)


@dataclass(frozen=True)
class DPSGDConfig:
    clip_norm: float = 1.0
    noise_multiplier: float = 1.0      # σ: noise stddev = C·σ

    @property
    def pcost_per_step(self) -> float:
        return 1.0 / (self.noise_multiplier ** 2)


class DPSGDAccountant:
    """Sequential-composition accounting for a DP-SGD run."""

    def __init__(self, cfg: DPSGDConfig, budget: Optional[PrivacyBudget] = None):
        self.cfg = cfg
        self.budget = budget
        self.steps = 0

    def charge_step(self):
        self.steps += 1
        if self.budget is not None:
            self.budget.charge(self.cfg.pcost_per_step)

    @property
    def pcost(self) -> float:
        return self.steps * self.cfg.pcost_per_step

    def report(self) -> dict:
        pc = self.pcost
        return {"steps": self.steps, "pcost": pc, "rho_zcdp": zcdp_rho(pc),
                "mu_gdp": gdp_mu(pc),
                "eps_at_delta_1e-6": approx_dp_eps(pc, 1e-6)}


def per_example_clipped_grad(loss_fn, params, batch, clip_norm: float):
    """Mean of per-example gradients, each clipped to L2 ≤ clip_norm (vmap'd)."""
    def single(example):
        ex = jax.tree_util.tree_map(lambda x: x[None], example)
        return jax.grad(lambda p: loss_fn(p, ex))(params)

    grads = jax.vmap(single)(batch)   # leaves: (B, *param_shape)

    def norms(g):
        return jnp.sqrt(sum(jnp.sum(jnp.square(x.reshape(x.shape[0], -1)
                                               .astype(jnp.float32)), axis=1)
                            for x in jax.tree_util.tree_leaves(g)))
    n = norms(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(n, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: jnp.mean(g * scale.reshape((-1,) + (1,) * (g.ndim - 1)),
                           axis=0), grads)


def add_dp_noise(grads, key, clip_norm: float, noise_multiplier: float,
                 batch_size: int):
    """Gaussian noise calibrated to the clipped-sum sensitivity (mean reduction)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    std = clip_norm * noise_multiplier / batch_size
    noisy = [g + std * jax.random.normal(k, g.shape, jnp.float32).astype(g.dtype)
             for g, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, noisy)
