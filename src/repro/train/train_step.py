"""Train / eval steps: microbatched gradient accumulation, remat, optional
DP-SGD (per-example clipping + calibrated noise), AdamW update.

The returned step function is pjit-ready: all inputs/outputs are global
arrays; sharding comes from in_shardings/out_shardings at jit time (see
launch/dryrun.py and launch/train.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from .dp import DPSGDConfig, add_dp_noise, per_example_clipped_grad
from .optimizer import AdamWConfig, apply_updates, init_opt_state

TrainState = Dict[str, Any]   # {'params', 'opt': {'m','v','count'}, 'rng'}


def init_train_state(model: Model, key: jax.Array, opt_cfg: AdamWConfig) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, opt_cfg),
            "rng": jax.random.PRNGKey(0)}


def _split_microbatches(batch, n: int):
    def sp(x):
        assert x.shape[0] % n == 0, f"batch {x.shape[0]} % microbatches {n} != 0"
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    microbatches: int = 1, dp: Optional[DPSGDConfig] = None,
                    remat: bool = True):
    """Build a pure train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        return model.loss_fn(params, mb, remat=remat)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, Any]]:
        params = state["params"]
        mbs = _split_microbatches(batch, microbatches)

        def micro(carry, mb):
            gacc, lacc = carry
            if dp is not None:
                g = per_example_clipped_grad(loss_fn, params, mb, dp.clip_norm)
                l = loss_fn(params, mb)
            else:
                l, g = jax.value_and_grad(loss_fn)(params, mb)
            gacc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(a.dtype) / microbatches, gacc, g)
            return (gacc, lacc + l / microbatches), None

        # Accumulate in fp32 for fp32-param models; for bf16 (1T-MoE) models
        # accumulate in bf16 — halves the accumulator HBM (see §Perf log).
        gzero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32 if p.dtype == jnp.float32
                                else jnp.bfloat16), params)
        (grads, loss), _ = jax.lax.scan(micro, (gzero, 0.0), mbs)

        rng = state["rng"]
        if dp is not None:
            rng, nk = jax.random.split(rng)
            bsz = jax.tree_util.tree_leaves(batch)[0].shape[0]
            grads = add_dp_noise(grads, nk, dp.clip_norm, dp.noise_multiplier, bsz)

        new_params, new_opt, om = apply_updates(params, grads, state["opt"], opt_cfg)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt, "rng": rng}, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        return model.loss_fn(params, batch, remat=False)
    return eval_step
