"""AdamW with optional int8 block-quantized moments (distributed-optimization
trick: for kimi-k2 the fp32 m/v alone would be ~8 TB; int8 + per-block scales
cuts optimizer state 4x and shards exactly like the params).

Quantization layout preserves parameter shape — int8 tensor of the same shape
plus an fp32 scale per 128-wide block of the last axis — so optimizer state
inherits each parameter's NamedSharding unchanged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    int8_states: bool = False
    warmup_steps: int = 100


def _block_view(x):
    last = x.shape[-1]
    if last % BLOCK == 0 and last >= BLOCK:
        nb, b = last // BLOCK, BLOCK
    else:
        nb, b = 1, last
    return x.reshape(x.shape[:-1] + (nb, b)), nb, b


def quantize_i8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xb, nb, b = _block_view(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    q = jnp.round(xb / jnp.maximum(scale, 1e-30)).astype(jnp.int8)
    return q.reshape(x.shape), scale[..., 0]


def dequantize_i8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    qb, nb, b = _block_view(q.astype(jnp.float32))
    return (qb * scale[..., None]).reshape(q.shape)


def init_opt_state(params, cfg: AdamWConfig):
    def zeros_like_moment(p):
        if cfg.int8_states:
            q = jnp.zeros(p.shape, jnp.int8)
            _, nb, b = _block_view(p)
            s = jnp.zeros(p.shape[:-1] + (nb,), jnp.float32)
            return {"q": q, "s": s}
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros_like_moment, params),
        "v": jax.tree_util.tree_map(zeros_like_moment, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _load(moment, cfg):
    if cfg.int8_states:
        return dequantize_i8(moment["q"], moment["s"])
    return moment


def _store(x, cfg):
    if cfg.int8_states:
        q, s = quantize_i8(x)
        return {"q": q, "s": s}
    return x


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    lr = cfg.lr * jnp.minimum(1.0, cf / max(cfg.warmup_steps, 1))
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        m = _load(m_, cfg)
        v = _load(v_, cfg)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** cf)
        vh = v / (1 - cfg.b2 ** cf)
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(_store(m, cfg))
        new_v.append(_store(v, cfg))

    return (jax.tree_util.tree_unflatten(treedef, new_p),
            {"m": jax.tree_util.tree_unflatten(treedef, new_m),
             "v": jax.tree_util.tree_unflatten(treedef, new_v),
             "count": count},
            {"grad_norm": gnorm, "lr": lr})
