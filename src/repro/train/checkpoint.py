"""Fault-tolerant checkpointing: step-atomic, mesh-agnostic, resumable.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, committed by writing to a
temp dir and atomically renaming (a crashed save can never be mistaken for a
complete one).  Arrays are stored as full (host-gathered) global arrays, so a
checkpoint written on one mesh restores onto *any* mesh — this is the elastic
re-mesh path (shrink/grow the pod count between runs).  Async saves run on a
background thread so the training loop is not blocked.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(tree_like, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        leaves.append(np.asarray(arr).astype(leaf.dtype) if hasattr(leaf, "dtype")
                      else arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, metadata: Optional[dict] = None,
             blocking: bool = True):
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        if blocking:
            self._write(step, host_state, metadata or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, metadata or {}))
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, metadata: dict):
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_state)
        # int8/bf16 leaves: store raw bytes + dtype names (npz has no bf16)
        arrays, dtypes = {}, {}
        for k, v in flat.items():
            v = np.asarray(v)
            dtypes[k] = str(v.dtype)
            if v.dtype.name == "bfloat16":
                arrays[k] = v.view(np.uint16)
            else:
                arrays[k] = v
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {"step": step, "time": time.time(), "dtypes": dtypes,
                    **metadata}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for n in os.listdir(self.directory):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, dict]:
        """Restore into the structure of ``state_like``; device_put with
        ``shardings`` if given (this is how a checkpoint from mesh A lands on
        mesh B — elastic scaling)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        raw = np.load(os.path.join(d, "arrays.npz"))
        import ml_dtypes
        flat = {}
        for k in raw.files:
            v = raw[k]
            if manifest["dtypes"][k] == "bfloat16":
                v = v.view(ml_dtypes.bfloat16)
            flat[k] = v
        state = _unflatten_into(state_like, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, manifest
