from .optimizer import AdamWConfig, init_opt_state, apply_updates
from .train_step import TrainState, make_train_step, make_eval_step
from .dp import DPSGDConfig, DPSGDAccountant
