r"""ReM-style local non-negativity for released marginals (DESIGN.md §11).

Raw unbiased releases are frequently negative in small cells; downstream
consumers (contingency analysis, Bayesian networks, synthetic data) need
non-negative tables.  Following the local-reconstruction observation of
"Efficient and Private Marginal Reconstruction with Local Non-Negativity"
(Mullins et al., 2024), non-negativity is enforced *per marginal* — each
table is projected onto its own scaled simplex

    Δ_A(T) = { q ≥ 0 : Σ q = T }

with T the family's common total count, so the projection never touches the
contingency table and runs at Synth-10^20 scale.  Projections are
signature-batched exactly like the serving engines: same-shape marginals
stack into one vectorized sort-based projection (jitted on device, fp64 on
host).

Per-marginal projection breaks mutual consistency; ``nonneg_release``
therefore runs consistency → projection, and optionally a multiplicative-
weights refinement loop over the workload cliques: each round re-fits the
covariance-weighted consistent family to the current non-negative tables
(:func:`repro.release.consistency.solve_consistency`) and pulls every
marginal toward it with an entropic (multiplicative, hence positivity- and
total-preserving) step — the classic MW dynamics on each simplex.

Totals are preserved *exactly* in fp64 (the secure discrete path hands an
integer total down): after projection the residual rounding defect is folded
into the largest cell, so ``q.sum() == T`` to the last ulp.
"""
from __future__ import annotations

import math
from typing import Dict, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique, Domain
from repro.core.mechanism import signature_groups
from repro.core.plantable import BasePlan

from .consistency import solve_consistency


def _simplex_rows_np(y: np.ndarray, total: np.ndarray) -> np.ndarray:
    """Euclidean projection of every row of ``y`` onto Δ(total_i), fp64.

    Sort-based: q = max(y − τ, 0) with τ from the largest prefix keeping the
    active set positive (Held–Wolfe–Crowder).  Rows with total ≤ 0 project to
    zero.
    """
    y = np.asarray(y, np.float64)
    total = np.asarray(total, np.float64)
    g, m = y.shape
    u = -np.sort(-y, axis=1)
    css = np.cumsum(u, axis=1)
    j = np.arange(1, m + 1)
    rho = np.sum(u * j > css - total[:, None], axis=1)
    rho = np.maximum(rho, 1)
    tau = (css[np.arange(g), rho - 1] - total) / rho
    q = np.maximum(y - tau[:, None], 0.0)
    return np.where(total[:, None] > 0, q, 0.0)


@jax.jit
def _simplex_rows_jnp(y: jnp.ndarray, total: jnp.ndarray) -> jnp.ndarray:
    """Device twin of :func:`_simplex_rows_np` (one jit per row shape)."""
    g, m = y.shape
    u = -jnp.sort(-y, axis=1)
    css = jnp.cumsum(u, axis=1)
    j = jnp.arange(1, m + 1)
    rho = jnp.maximum(jnp.sum(u * j > css - total[:, None], axis=1), 1)
    tau = (jnp.take_along_axis(css, rho[:, None] - 1, axis=1)[:, 0]
           - total) / rho
    q = jnp.maximum(y - tau[:, None], 0.0)
    return jnp.where(total[:, None] > 0, q, 0.0)


def simplex_project_batch(y: np.ndarray, total, backend: str = "device"
                          ) -> np.ndarray:
    """Project a (g, m) stack of tables onto their scaled simplices."""
    total = np.broadcast_to(np.asarray(total, np.float64), (y.shape[0],))
    if backend == "device":
        yj = jnp.asarray(y)
        return np.asarray(_simplex_rows_jnp(yj, jnp.asarray(total, yj.dtype)),
                          np.float64)
    return _simplex_rows_np(y, total)


def _exact_total(q: np.ndarray, total: float) -> np.ndarray:
    """Fold the fp rounding defect back into the table: Σq == total.

    Iterates against the consumer's own reduction (``q.sum()``); when the
    defect drops below the largest cell's ulp it is folded into a smaller
    cell instead.  The fixed point Σq == total is reached in a pass or two
    in practice; the worst case is one ulp of the total — in particular
    integer totals always round-trip exactly through ``round(q.sum())``.
    """
    q = np.asarray(q, np.float64)
    if total <= 0:
        return np.zeros_like(q)
    i = int(np.argmax(q))
    for _ in range(16):
        d = total - float(q.sum())     # the same reduction consumers run
        if d == 0.0:
            break
        j, nq = i, max(q[i] + d, 0.0)
        if nq == q[i]:     # defect below this cell's ulp: use a smaller cell
            pos = np.nonzero((q > 0) & (np.spacing(q) <= abs(d)))[0]
            if len(pos) == 0:
                break
            j = int(pos[np.argmin(q[pos])])
            nq = max(q[j] + d, 0.0)
            if nq == q[j]:
                break
        q[j] = nq
    return q


def project_nonneg(domain: Domain, tables: Mapping[Clique, np.ndarray],
                   total: float, backend: str = "device",
                   exact_total: bool = True) -> Dict[Clique, np.ndarray]:
    """Local non-negativity: signature-batched per-marginal simplex projection.

    Purely local (does not restore consistency); the serving entry point is
    :func:`nonneg_release`.
    """
    cliques = list(tables.keys())
    out: Dict[Clique, np.ndarray] = {}
    for group in signature_groups(domain, cliques).values():
        y = np.stack([np.asarray(tables[c], np.float64).reshape(-1)
                      for c in group])
        q = simplex_project_batch(y, total, backend)
        for i, c in enumerate(group):
            out[c] = _exact_total(q[i], total) if exact_total else q[i]
    return out


def mw_refine(plan: BasePlan, tables: Dict[Clique, np.ndarray], total: float,
              rounds: int, eta: float = 0.5,
              weights: Optional[np.ndarray] = None,
              backend: str = "device") -> Dict[Clique, np.ndarray]:
    """Multiplicative-weights refinement over the workload cliques.

    Each round re-fits the covariance-weighted consistent family to the
    current non-negative tables and takes an entropic step toward it:
    ``q ← q · exp(η (target − q)/s)`` rescaled back to total T — positive and
    total-preserving by construction, converging toward the intersection of
    the simplices with the consistent family.
    """
    if total <= 0 or rounds <= 0:
        return tables
    scale = max(total / max(np.mean([t.size for t in tables.values()]), 1.0),
                1e-12)
    q = {c: np.asarray(t, np.float64).copy() for c, t in tables.items()}
    floor = 1e-9 * scale
    for _ in range(rounds):
        cons = solve_consistency(plan, q, weights=weights, fix_total=total,
                                 backend=backend)
        target = cons.marginals()
        for c in q:
            cur = np.maximum(q[c], floor)
            step = np.clip(eta * (target[c] - cur) / scale, -40.0, 40.0)
            nxt = cur * np.exp(step)
            s = nxt.sum()
            q[c] = _exact_total(nxt * (total / s) if s > 0 else nxt, total)
    return q


def nonneg_release(plan: BasePlan, tables: Mapping[Clique, np.ndarray],
                   *, total: Optional[float] = None,
                   weights: Optional[np.ndarray] = None,
                   cell_weights: Optional[Mapping[Clique, np.ndarray]] = None,
                   mw_rounds: int = 0, eta: float = 0.5,
                   tol: float = 1e-9, maxiter: int = 200,
                   backend: str = "device",
                   cliques: Optional[Sequence[Clique]] = None
                   ) -> Dict[Clique, np.ndarray]:
    """Consistency → local non-negativity (→ optional MW refinement).

    The serving postprocessor behind ``engine.release(postprocess="nonneg")``:
    covariance-weighted consistent fit (CG on the residual coordinates,
    ``fix_total`` pinning when ``total`` is given — the secure path passes the
    measured *integer* total), then the signature-batched simplex projection,
    then ``mw_rounds`` rounds of multiplicative-weights refinement.  Every
    returned table is non-negative and sums to the common total to within
    one ulp (integer totals round-trip exactly through ``round``).
    """
    cons = solve_consistency(plan, tables, weights=weights,
                             cell_weights=cell_weights, fix_total=total,
                             tol=tol, maxiter=maxiter, backend=backend)
    t = float(total) if total is not None else cons.total
    t = max(t, 0.0)
    q = cons.marginals()       # full workload: MW re-fits need every marginal
    q = project_nonneg(plan.domain, q, t, backend=backend)
    if mw_rounds:
        q = mw_refine(plan, q, t, mw_rounds, eta, weights, backend)
    if cliques is not None:
        q = {c: q[c] for c in cliques}
    return q
