"""Release subsystem: postprocessing + synthesis downstream of the engines.

Three device-first stages (docs/DESIGN.md §11), all formulated on the
PlanTable IR / residual coordinates and never on the contingency table:

* :mod:`repro.release.consistency` — covariance-weighted least-squares
  consistency across overlapping noisy marginals (preconditioned batched CG
  over the merged Kron chains; fp64 dense WLS oracle for small domains);
* :mod:`repro.release.nonneg` — ReM-style local non-negativity
  (signature-batched simplex projection with exact total preservation,
  optional multiplicative-weights refinement);
* :mod:`repro.release.synth` — vectorized synthetic-record sampling over a
  clique junction order, with a :class:`SynthReport` audit.

The serving tier reaches it through ``engine.release(..., postprocess=...)``
and ``engine.synthesize(...)`` on :class:`~repro.engine.engine.MarginalEngine`,
:class:`~repro.engine.plus_engine.PlusEngine` and the secure
:class:`~repro.engine.discrete_engine.DiscreteEngine`, and through
``corpus_marginal_release(..., postprocess=...)`` on the sharded path.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

import numpy as np

from repro.core.domain import Clique
from repro.core.plantable import BasePlan

from .consistency import (ConsistencyOperator, ConsistentRelease,
                          dense_wls_oracle, precision_weights,
                          solve_consistency)
from .nonneg import (mw_refine, nonneg_release, project_nonneg,
                     simplex_project_batch)
from .synth import (MarginalCheck, SynthReport, junction_order, synth_report,
                    synthesize_records)

POSTPROCESS_MODES = ("consistent", "nonneg")


def measured_integer_total(measurements) -> float:
    """The secure path's total pin: the measured empty-clique answer, which
    is exact-integer by construction (integer count + integer noise), as a
    float.  One definition shared by ``DiscreteEngine`` and the sharded
    ``corpus_marginal_release`` passthrough."""
    return float(int(round(float(
        np.asarray(measurements[()].omega).reshape(-1)[0]))))


def postprocess_release(plan: BasePlan, tables: Mapping[Clique, np.ndarray],
                        mode: str, *, total: Optional[float] = None,
                        weights: Optional[np.ndarray] = None,
                        mw_rounds: int = 0, backend: str = "device",
                        tol: float = 1e-9, maxiter: int = 200
                        ) -> Dict[Clique, np.ndarray]:
    """One entry point for the engines' ``postprocess=`` kwarg.

    ``mode="consistent"`` returns the covariance-weighted consistent family;
    ``mode="nonneg"`` additionally projects each marginal onto its scaled
    simplex (and runs ``mw_rounds`` of MW refinement).  ``total`` pins the
    family's common total — the secure path passes the measured integer.
    """
    from repro.obs import TRACER
    if mode == "consistent":
        with TRACER.span("release.postprocess").set(mode=mode,
                                                    tables=len(tables)):
            cons = solve_consistency(plan, tables, weights=weights,
                                     fix_total=total, tol=tol,
                                     maxiter=maxiter, backend=backend)
            return cons.marginals()
    if mode == "nonneg":
        with TRACER.span("release.postprocess").set(mode=mode,
                                                    tables=len(tables),
                                                    mw_rounds=mw_rounds):
            return nonneg_release(plan, tables, total=total, weights=weights,
                                  mw_rounds=mw_rounds, tol=tol,
                                  maxiter=maxiter, backend=backend)
    raise ValueError(f"postprocess mode must be one of {POSTPROCESS_MODES}, "
                     f"got {mode!r}")


__all__ = [
    "ConsistencyOperator", "ConsistentRelease", "MarginalCheck",
    "POSTPROCESS_MODES", "SynthReport", "dense_wls_oracle", "junction_order",
    "measured_integer_total", "mw_refine", "nonneg_release",
    "postprocess_release", "precision_weights", "project_nonneg",
    "simplex_project_batch", "solve_consistency", "synth_report",
    "synthesize_records",
]
