r"""Scalable synthetic-record generation from released marginals (§11).

The paper motivates noisy marginals as inputs to "synthetic data
generation"; this module closes that loop.  Given a *non-negative, mutually
consistent* family of marginals (``nonneg_release``), records are sampled by
round-robin conditional sampling over a clique junction order:

* a greedy junction order visits one attribute at a time, conditioning each
  on the already-sampled attributes it co-occurs with in the workload clique
  of maximal overlap (for tree-shaped workloads this is exact: the sampled
  joint reproduces every workload marginal in expectation);
* every attribute's draw is fully vectorized across all N records — one
  parent-cell gather into the conditional table and one
  ``jax.random.categorical`` per attribute, so millions of rows per call and
  never a contingency table;
* ``SynthReport`` audits the output: per workload marginal, the sampled
  table is compared against the released one (total-variation distance,
  ℓ∞, and a χ² statistic with its degrees of freedom), so consumers can
  check the sample against the release within sampling error.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique, Domain

SamplingStep = Tuple[int, Clique, Clique]     # (attribute, clique, parents)


def junction_order(domain: Domain, cliques: Sequence[Clique],
                   attr_order: Optional[Sequence[int]] = None
                   ) -> List[SamplingStep]:
    """Greedy junction order: each attribute conditions on the sampled
    attributes of its best-overlapping workload clique.

    ``attr_order`` fixes the visiting order (default: pick the attribute
    whose best clique overlaps the sampled set the most, ties by index —
    chains and trees come out in exact Markov order).
    """
    cliques = [c for c in cliques if c]
    covered = set(i for c in cliques for i in c)
    missing = set(range(domain.n_attrs)) - covered
    if missing:
        raise ValueError(f"attributes {sorted(missing)} appear in no "
                         "workload clique; cannot sample them")
    steps: List[SamplingStep] = []
    sampled: set = set()

    def best_clique(i: int) -> Tuple[int, Clique]:
        ov, best = -1, None
        for c in cliques:
            if i not in c:
                continue
            k = len(sampled & set(c))
            if k > ov or (k == ov and len(c) < len(best)):
                ov, best = k, c
        return ov, best

    if attr_order is not None:
        order = list(attr_order)
    else:
        order = []
        remaining = set(range(domain.n_attrs))
        while remaining:
            i = max(remaining, key=lambda a: (best_clique(a)[0], -a))
            order.append(i)
            remaining.discard(i)
            sampled.add(i)
        sampled.clear()
    for i in order:
        _, c = best_clique(i)
        parents = tuple(sorted(sampled & set(c)))
        steps.append((i, c, parents))
        sampled.add(i)
    return steps


def _conditional_table(domain: Domain, table: np.ndarray, clique: Clique,
                       attr: int, parents: Clique) -> np.ndarray:
    """(Π n_parents, n_attr) conditional probability rows from a marginal.

    Marginalizes the clique down to parents ∪ {attr}, moves the attribute
    axis last, clips negatives and row-normalizes (zero rows → uniform).
    """
    sizes = domain.clique_sizes(clique)
    t = np.asarray(table, np.float64).reshape(sizes)
    keep = set(parents) | {attr}
    drop = tuple(ax for ax, a in enumerate(clique) if a not in keep)
    if drop:
        t = t.sum(axis=drop)
    kept = [a for a in clique if a in keep]          # clique order, sorted
    t = np.moveaxis(t, kept.index(attr), -1)         # parents..., attr
    t = np.maximum(t.reshape(-1, domain.attributes[attr].size), 0.0)
    s = t.sum(axis=1, keepdims=True)
    uniform = np.full(t.shape[1], 1.0 / t.shape[1])
    return np.where(s > 0, t / np.maximum(s, 1e-300), uniform)


def synthesize_records(domain: Domain, tables: Mapping[Clique, np.ndarray],
                       n_records: int, key: jax.Array,
                       order: Optional[Sequence[SamplingStep]] = None,
                       batch: Optional[int] = None) -> np.ndarray:
    """Sample (n_records, n_attrs) int32 records matching the marginals.

    ``tables`` must be non-negative (``nonneg_release`` output); the sampler
    only ever touches per-clique tables and (N,)-vectors — the contingency
    table is never materialized, so Synth-10^20 domains sample millions of
    rows per call.  ``batch`` optionally chunks the record axis to bound the
    (N, n_i) gather footprint.
    """
    if order is None:
        order = junction_order(domain, list(tables.keys()))
    n = int(n_records)
    if n <= 0:
        raise ValueError(f"n_records must be positive, got {n_records}")
    out = np.empty((n, domain.n_attrs), np.int32)
    keys = jax.random.split(key, len(order))
    for step_i, (attr, clique, parents) in enumerate(order):
        probs = _conditional_table(domain, tables[clique], clique, attr,
                                   parents)
        if parents:
            psz = domain.clique_sizes(parents)
            pidx = np.zeros(n, np.int64)
            for a, s in zip(parents, psz):
                pidx = pidx * s + out[:, a]
        else:
            pidx = np.zeros(n, np.int64)
        logits = jnp.log(jnp.asarray(probs) + 1e-300)
        ranges = [(0, n)] if batch is None else \
            [(s, min(s + batch, n)) for s in range(0, n, batch)]
        bkeys = jax.random.split(keys[step_i], len(ranges))
        for bi, (lo, hi) in enumerate(ranges):
            draw = jax.random.categorical(
                bkeys[bi], logits[jnp.asarray(pidx[lo:hi])], axis=-1)
            out[lo:hi, attr] = np.asarray(draw, np.int32)
    return out


@dataclass
class MarginalCheck:
    clique: Clique
    cells: int
    tv: float          # total-variation distance, sampled vs released
    linf: float        # max abs cell deviation (count scale)
    chi2: float        # Σ (observed − expected)² / expected over e ≥ 5 cells
    dof: int           # number of cells entering the χ² sum − 1

    def chi2_ok(self, z: float = 6.0) -> bool:
        """χ² within mean + z·sd of its asymptotic distribution (dof large)."""
        if self.dof <= 0:
            return True
        return self.chi2 <= self.dof + z * np.sqrt(2.0 * self.dof)


@dataclass
class SynthReport:
    """Per-marginal audit of sampled records against the released tables."""

    n_records: int
    total: float
    checks: List[MarginalCheck]

    @property
    def max_tv(self) -> float:
        return max((c.tv for c in self.checks), default=0.0)

    def ok(self, z: float = 6.0) -> bool:
        return all(c.chi2_ok(z) for c in self.checks)

    def summary(self) -> str:
        worst = max(self.checks, key=lambda c: c.tv, default=None)
        return (f"SynthReport(n={self.n_records}, marginals="
                f"{len(self.checks)}, max_tv={self.max_tv:.4f}"
                + (f" at {worst.clique}" if worst else "") + ")")


def synth_report(domain: Domain, tables: Mapping[Clique, np.ndarray],
                 records: np.ndarray, total: Optional[float] = None
                 ) -> SynthReport:
    """Compare the sampled records' marginals against the released tables."""
    from repro.data.tabular import marginals_from_records
    n = records.shape[0]
    cliques = [c for c in tables.keys() if c]
    sampled = marginals_from_records(domain, cliques, np.asarray(records))
    checks: List[MarginalCheck] = []
    for c in cliques:
        rel = np.asarray(tables[c], np.float64).reshape(-1)
        t = float(rel.sum()) if total is None else float(total)
        obs = sampled[c]
        if t <= 0:
            checks.append(MarginalCheck(c, rel.size, 0.0, 0.0, 0.0, 0))
            continue
        p = rel / t
        exp = p * n
        tv = 0.5 * float(np.abs(obs / n - p).sum())
        linf = float(np.abs(obs - exp).max())
        use = exp >= 5.0
        dof = max(int(use.sum()) - 1, 0)
        chi2 = float((((obs - exp) ** 2)[use] / exp[use]).sum()) if dof else 0.0
        checks.append(MarginalCheck(c, rel.size, tv, linf, chi2, dof))
    return SynthReport(n, float(total) if total is not None else -1.0, checks)
