r"""Covariance-weighted consistency across overlapping noisy marginals.

The release subsystem's first stage (docs/DESIGN.md §11).  Given noisy
marginal tables ``y_A`` for the workload cliques — the engines' own raw
release, or any externally perturbed family (e.g. after per-marginal
non-negativity projection, which breaks mutual consistency) — find the
*mutually consistent* family closest to them in the covariance-weighted
least-squares sense.

**Parameterization.**  A family of marginals over the workload is mutually
consistent iff it is the image of residual coordinates: with
``T_i = [Sub_{n_i}^† | (1/n_i)·1]`` (the merged reconstruction factors of
``core/reconstruct.py``) and the slot embedding ``E_A`` that places each
``r_{A'}``, A' ⊆ A, into its disjoint slot region,

    q_A(r) = (⊗_{i∈A} T_i) · E_A · r .

So consistency is an *unconstrained* WLS over r — never over the
``Π n_i``-sized contingency table:

    min_r  Σ_{A∈W} w_A ‖ c_A ⊙ (q_A(r) − y_A) ‖²                       (*)

with per-marginal precision weights ``w_A = Imp_A / Var_A`` straight off the
PlanTable IR (Thm 4/8 — the "covariance weighting") and optional per-cell
weights ``c_A``.

**Normal equations on the IR.**  M r = b with
``M = Σ_A w_A E_Aᵀ K_Aᵀ C_A K_A E_A``, ``K_A = ⊗T_i``.  Both the forward and
adjoint maps are signature-batched Kronecker chains over gather/scatter index
arrays — the exact machinery the serving engines use, jitted per group.

**The Kron-factored preconditioner.**  ``Sub^†`` has zero column sums, so for
uniform per-cell weights the cross-subset blocks of ``K_AᵀK_A`` vanish and M
is *block-diagonal* over the closure:

    M_{A'} = α_{A'} · ⊗_{i∈A'} (Sub_i^†ᵀ Sub_i^†),
    α_{A'} = Σ_{A ⊇ A'} w_A · Π_{i∈A∖A'} 1/n_i .

``block_jacobi`` applies the exact inverse of that block structure (tiny
per-axis inverses, batched chains), so the preconditioned CG converges in one
iteration for per-marginal weights and stays correct — with a short CG tail —
for per-cell weight overrides, where the decoupling genuinely breaks.

``dense_wls_oracle`` materializes the design matrix and solves the normal
equations in fp64 — the small-domain reference the tests and the
``release-bench`` CI gate compare against.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique, subsets
from repro.core.kron import (kron_expand, kron_matvec_batched,
                             kron_matvec_np_batched)
from repro.core.mechanism import noise_dtype, signature_groups
from repro.core.plantable import BasePlan
from repro.core.reconstruct import subset_slot_region, u_chain_factors
from repro.core.residual import sub_pinv


def precision_weights(plan: BasePlan) -> np.ndarray:
    """Per-marginal WLS weights w_A = Imp_A / Var_A from the IR (Thm 4/8).

    For plain tables ``Var_A`` is the per-cell variance; RP+ identity-basis
    tables report the Thm-8 SoV convention — any positive per-marginal
    weighting yields a valid consistent WLS fit, only the distribution of the
    disagreement across marginals changes.
    """
    var = np.asarray(plan.variances_array(), np.float64)
    imp = np.asarray(plan.workload.weight_array(), np.float64)
    return imp / np.maximum(var, 1e-300)


def _chain_np(factors: Sequence[np.ndarray], x: np.ndarray,
              dims: Sequence[int]) -> np.ndarray:
    """Batched host-fp64 Kronecker chain (B, Π dims) → (B, Π out)."""
    return kron_matvec_np_batched([np.asarray(f, np.float64) for f in factors],
                                  np.asarray(x, np.float64), dims)


@dataclass
class _WorkGroup:
    """One workload signature group of the WLS operator."""

    dims: Tuple[int, ...]
    cliques: List[Clique]
    idx: np.ndarray              # (g, Π n_i) flat-r index of every slot
    w: np.ndarray                # (g,) per-marginal precision weights
    cw: Optional[np.ndarray]     # (g, Π n_i) per-cell weights, or None
    factors: List[np.ndarray]    # T_i per axis


@dataclass
class _ClosureGroup:
    """One closure signature group of the block-Jacobi preconditioner."""

    rdims: Tuple[int, ...]       # per-axis residual sizes n_i − 1
    ridx: np.ndarray             # (g, Π rdims) flat-r index of every coord
    alpha: np.ndarray            # (g,) block scalars α_{A'}
    ginv: List[np.ndarray]       # (Sub†ᵀSub†)⁻¹ per axis


class ConsistencyOperator:
    """The WLS normal-equations operator M (and rhs/preconditioner) of (*).

    Built once per (plan, weights); ``solve`` runs the preconditioned CG on
    device (jitted batched chains) or on the host in fp64.
    """

    def __init__(self, plan: BasePlan, weights: Optional[np.ndarray] = None,
                 cell_weights: Optional[Mapping[Clique, np.ndarray]] = None):
        self.plan = plan
        dom = plan.domain
        wk = list(plan.workload.cliques)
        w = precision_weights(plan) if weights is None \
            else np.asarray(weights, np.float64)
        if w.shape != (len(wk),):
            raise ValueError(f"weights must have shape ({len(wk)},)")
        if not np.all(w > 0):
            raise ValueError("precision weights must be strictly positive")
        self.weights = w
        # flat residual-coordinate layout over the closure
        self.offsets: Dict[Clique, int] = {}
        off = 0
        for c in plan.cliques:
            self.offsets[c] = off
            off += dom.residual_size(c)
        self.n_coords = off
        wpos = {c: i for i, c in enumerate(wk)}
        self.groups: List[_WorkGroup] = []
        for dims, cliques in signature_groups(dom, wk).items():
            idx = np.stack([self._slot_index(c) for c in cliques])
            cw = None
            if cell_weights:
                cw = np.ones_like(idx, np.float64)
                for i, c in enumerate(cliques):
                    if c in cell_weights:
                        cw[i] = np.asarray(cell_weights[c],
                                           np.float64).reshape(-1)
                if not np.all(cw >= 0):
                    raise ValueError("cell weights must be non-negative")
            self.groups.append(_WorkGroup(
                dims, list(cliques), idx, w[[wpos[c] for c in cliques]], cw,
                u_chain_factors(dom, cliques[0]) if dims else []))
        # block-Jacobi: α_{A'} over the closure + per-axis Gram inverses
        alpha = np.zeros(len(plan.cliques))
        cpos = {c: i for i, c in enumerate(plan.cliques)}
        sizes = dom.sizes
        for wi, a in enumerate(wk):
            for sub in subsets(a):
                rest = set(a) - set(sub)
                alpha[cpos[sub]] += w[wi] * math.prod(
                    1.0 / sizes[i] for i in rest)
        self.pregroups: List[_ClosureGroup] = []
        for dims, cliques in signature_groups(dom, plan.cliques).items():
            rdims = tuple(n - 1 for n in dims)
            rsz = int(np.prod(rdims)) if rdims else 1
            ridx = np.stack([self.offsets[c] + np.arange(rsz)
                             for c in cliques])
            ginv = [np.linalg.inv(sub_pinv(n).T @ sub_pinv(n)) for n in dims]
            self.pregroups.append(_ClosureGroup(
                rdims, ridx, alpha[[cpos[c] for c in cliques]], ginv))
        self._device: dict = {}

    def _slot_index(self, clique: Clique) -> np.ndarray:
        """Flat-r index of every slot position of ``clique``'s merged tensor."""
        sizes = self.plan.domain.clique_sizes(clique)
        t = np.empty(sizes if sizes else (1,), np.int64)
        for sub in subsets(clique):
            region, shape = subset_slot_region(clique, sub, sizes)
            rsz = self.plan.domain.residual_size(sub)
            block = (self.offsets[sub] + np.arange(rsz)).reshape(
                shape if sizes else (1,))
            if sizes:
                t[region] = block
            else:
                t[:] = block
        return t.reshape(-1)

    # ------------------------------------------------------------- host fp64
    def matvec_np(self, r: np.ndarray) -> np.ndarray:
        out = np.zeros(self.n_coords)
        for g in self.groups:
            q = _chain_np(g.factors, r[g.idx], g.dims)
            s = g.w[:, None] * q
            if g.cw is not None:
                s = s * g.cw
            back = _chain_np([f.T for f in g.factors], s, g.dims)
            out += np.bincount(g.idx.ravel(), weights=back.ravel(),
                               minlength=self.n_coords)
        return out

    def rhs_np(self, tables: Mapping[Clique, np.ndarray]) -> np.ndarray:
        out = np.zeros(self.n_coords)
        for g in self.groups:
            y = np.stack([np.asarray(tables[c], np.float64).reshape(-1)
                          for c in g.cliques])
            s = g.w[:, None] * y
            if g.cw is not None:
                s = s * g.cw
            back = _chain_np([f.T for f in g.factors], s, g.dims)
            out += np.bincount(g.idx.ravel(), weights=back.ravel(),
                               minlength=self.n_coords)
        return out

    def precond_np(self, s: np.ndarray) -> np.ndarray:
        out = np.zeros(self.n_coords)
        for g in self.pregroups:
            z = _chain_np(g.ginv, s[g.ridx], g.rdims) / g.alpha[:, None]
            out += np.bincount(g.ridx.ravel(), weights=z.ravel(),
                               minlength=self.n_coords)
        return out

    # ---------------------------------------------------------------- device
    def _device_fns(self, dtype):
        """Jitted (matvec, precond) over the batched chains, cached per dtype."""
        key = jnp.dtype(dtype).name
        ent = self._device.get(key)
        if ent is not None:
            return ent
        wg = [(tuple(g.dims),
               jnp.asarray(g.idx, jnp.int32),
               jnp.asarray(g.w, dtype),
               None if g.cw is None else jnp.asarray(g.cw, dtype),
               [jnp.asarray(f, dtype) for f in g.factors],
               [jnp.asarray(f.T, dtype) for f in g.factors])
              for g in self.groups]
        pg = [(tuple(g.rdims),
               jnp.asarray(g.ridx, jnp.int32),
               jnp.asarray(g.alpha, dtype),
               [jnp.asarray(f, dtype) for f in g.ginv])
              for g in self.pregroups]
        n = self.n_coords

        def matvec(r):
            out = jnp.zeros(n, dtype)
            for dims, idx, w, cw, facs, facs_t in wg:
                q = kron_matvec_batched(facs, r[idx], dims)
                s = w[:, None] * q
                if cw is not None:
                    s = s * cw
                back = kron_matvec_batched(facs_t, s, dims)
                out = out.at[idx].add(back.reshape(idx.shape))
            return out

        def precond(s):
            out = jnp.zeros(n, dtype)
            for rdims, ridx, alpha, ginv in pg:
                z = kron_matvec_batched(ginv, s[ridx], rdims)
                z = z / alpha[:, None]
                out = out.at[ridx].add(z.reshape(ridx.shape))
            return out

        ent = (jax.jit(matvec), jax.jit(precond))
        self._device[key] = ent
        return ent

    # -------------------------------------------------------------- marginals
    def marginals_np(self, r: np.ndarray,
                     cliques: Optional[Sequence[Clique]] = None
                     ) -> Dict[Clique, np.ndarray]:
        """q_A(r) for the workload cliques (or any cliques in the closure)."""
        out: Dict[Clique, np.ndarray] = {}
        if cliques is None:
            for g in self.groups:
                q = _chain_np(g.factors, r[g.idx], g.dims)
                for i, c in enumerate(g.cliques):
                    out[c] = q[i]
            return out
        dom = self.plan.domain
        for c in cliques:
            idx = self._slot_index(c)
            q = _chain_np(u_chain_factors(dom, c) if c else [],
                          r[idx][None, :], dom.clique_sizes(c))
            out[c] = q[0]
        return out


@dataclass
class ConsistentRelease:
    """A consistent family of marginals: residual coordinates + provenance."""

    operator: ConsistencyOperator = field(repr=False)
    r: np.ndarray                # (n_coords,) fitted residual coordinates
    iterations: int
    rel_residual: float          # ‖Mr − b‖ / ‖b‖ at exit

    @property
    def plan(self) -> BasePlan:
        return self.operator.plan

    @property
    def total(self) -> float:
        """The common total count of every marginal in the family."""
        return float(self.r[self.operator.offsets[()]])

    def marginals(self, cliques: Optional[Sequence[Clique]] = None
                  ) -> Dict[Clique, np.ndarray]:
        return self.operator.marginals_np(self.r, cliques)

    def marginal(self, clique: Clique) -> np.ndarray:
        return self.operator.marginals_np(self.r, [clique])[clique]


def solve_consistency(plan: BasePlan, tables: Mapping[Clique, np.ndarray],
                      *, weights: Optional[np.ndarray] = None,
                      cell_weights: Optional[Mapping[Clique, np.ndarray]] = None,
                      fix_total: Optional[float] = None,
                      tol: float = 1e-9, maxiter: int = 200,
                      backend: str = "device", dtype=None,
                      operator: Optional[ConsistencyOperator] = None
                      ) -> ConsistentRelease:
    """Preconditioned-CG solve of the consistency WLS (*).

    ``backend="device"`` runs the jitted batched chains at ``dtype``
    (default :func:`repro.core.mechanism.noise_dtype`); ``"host"`` runs the
    same operator in numpy fp64.  ``fix_total`` pins the empty-clique
    coordinate — the family's common total — to an exact value (the secure
    path passes the measured integer total here); the CG then solves the
    reduced system in the complementary subspace.
    """
    op = ConsistencyOperator(plan, weights, cell_weights) \
        if operator is None else operator
    if backend == "host":
        mv, pc = op.matvec_np, op.precond_np
        xp = np
        b = op.rhs_np(tables)
    elif backend == "device":
        dtype = noise_dtype() if dtype is None else dtype
        mv, pc = op._device_fns(dtype)
        xp = jnp
        b = jnp.asarray(op.rhs_np(tables), dtype)
    else:
        raise ValueError(f"backend must be 'device' or 'host', got {backend!r}")

    e0 = op.offsets[()]
    if fix_total is not None:
        # Pin r_∅ = t0 and solve the reduced system in the complement: every
        # CG direction is masked at e0, the pinned coordinate enters via b.
        t0 = float(fix_total)
        mask_np = np.ones(op.n_coords)
        mask_np[e0] = 0.0
        unit_np = np.zeros(op.n_coords)
        unit_np[e0] = t0
        mask = mask_np if xp is np else jnp.asarray(mask_np, b.dtype)
        unit = unit_np if xp is np else jnp.asarray(unit_np, b.dtype)
        b = mask * (b - mv(unit))
        x = unit

        def amv(p):
            return mask * mv(mask * p)

        def apc(s):
            return mask * pc(mask * s)
    else:
        x = np.zeros(op.n_coords) if xp is np else jnp.zeros(op.n_coords,
                                                             b.dtype)
        amv, apc = mv, pc

    bnorm = float(xp.sqrt(xp.vdot(b, b)))
    if bnorm == 0.0:
        return ConsistentRelease(op, np.asarray(x, np.float64), 0, 0.0)
    resid = b       # the CG correction starts at zero in both branches
    z = apc(resid)
    p = z
    rz = float(xp.vdot(resid, z))
    it = 0
    rel = 1.0
    for it in range(1, maxiter + 1):  # noqa: B007 - it is reported after the loop
        ap = amv(p)
        pap = float(xp.vdot(p, ap))
        if pap <= 0:
            break
        step = rz / pap
        x = x + step * p
        resid = resid - step * ap
        rel = float(xp.sqrt(xp.vdot(resid, resid))) / bnorm
        if rel <= tol:
            break
        z = apc(resid)
        rz_new = float(xp.vdot(resid, z))
        p = z + (rz_new / rz) * p
        rz = rz_new
    return ConsistentRelease(op, np.asarray(x, np.float64), it, rel)


def dense_wls_oracle(plan: BasePlan, tables: Mapping[Clique, np.ndarray],
                     *, weights: Optional[np.ndarray] = None,
                     cell_weights: Optional[Mapping[Clique, np.ndarray]] = None,
                     fix_total: Optional[float] = None) -> ConsistentRelease:
    """fp64 dense WLS reference: materialize the design, solve the normal
    equations with LAPACK.  Small domains only (design is Σ|cells| × n_coords)."""
    op = ConsistencyOperator(plan, weights, cell_weights)
    dom = plan.domain
    wk = list(plan.workload.cliques)
    w = op.weights
    rows = sum(dom.n_cells(c) for c in wk)
    design = np.zeros((rows, op.n_coords))
    wrow = np.empty(rows)
    y = np.empty(rows)
    r0 = 0
    cellw = dict(cell_weights) if cell_weights else {}
    for wi, c in enumerate(wk):
        m = dom.n_cells(c)
        k = kron_expand(u_chain_factors(dom, c)) if c else np.ones((1, 1))
        design[r0:r0 + m, op._slot_index(c)] = k
        cw = np.asarray(cellw[c], np.float64).reshape(-1) if c in cellw \
            else np.ones(m)
        wrow[r0:r0 + m] = w[wi] * cw
        y[r0:r0 + m] = np.asarray(tables[c], np.float64).reshape(-1)
        r0 += m
    m_mat = design.T @ (wrow[:, None] * design)
    b = design.T @ (wrow * y)
    e0 = op.offsets[()]
    if fix_total is not None:
        free = np.ones(op.n_coords, bool)
        free[e0] = False
        r = np.empty(op.n_coords)
        r[e0] = float(fix_total)
        r[free] = np.linalg.solve(
            m_mat[np.ix_(free, free)],
            b[free] - m_mat[free, e0] * float(fix_total))
    else:
        r = np.linalg.solve(m_mat, b)
    resid = m_mat @ r - b
    bn = float(np.linalg.norm(b)) or 1.0
    return ConsistentRelease(op, r, 0, float(np.linalg.norm(resid)) / bn)
