"""Multi-tenant release serving tier (docs/DESIGN.md §13, docs/SERVING.md).

* :mod:`repro.serve.ledger` — durable per-tenant zCDP budget ledger
  (append-only JSONL journal, charge-before-measure, crash-recovery replay);
* :mod:`repro.serve.server` — async request queue + worker loop with
  cross-tenant signature batching over :func:`repro.engine.multi.measure_multi`;
* :mod:`repro.serve.pool` — engine warm pool (pin hot signatures, evict by
  tenant-weighted LRU) over the instrumented engine cache;
* :mod:`repro.serve.stats` — per-tenant/server counters behind ``/stats``.
"""
from .ledger import (BudgetLedger, LedgerCorrupt, LedgerError, LedgerFailed,
                     UnknownTenant)
from .pool import EnginePool
from .server import (ReleaseRequest, ReleaseResult, ReleaseServer,
                     start_stats_http)
from .stats import ServerStats, TenantStats

__all__ = [
    "BudgetLedger", "LedgerCorrupt", "LedgerError", "LedgerFailed",
    "UnknownTenant",
    "EnginePool", "ReleaseRequest", "ReleaseResult", "ReleaseServer",
    "start_stats_http", "ServerStats", "TenantStats",
]
