"""Serving observability: per-tenant and server-wide counters for /stats.

Since the obs subsystem (docs/OBSERVABILITY.md) the *store* is a
:class:`~repro.obs.MetricsRegistry` — each server owns one, so two servers in
a process never cross-pollute tenant series — and this module is the thin
view layer over it: ``TenantStats`` / ``ServerStats`` keep their historical
field surface (``completed``, ``rejected_budget``, ``batch_occupancy``, …)
while ``/metrics`` renders the identical cells in Prometheus text format.
The two endpoints cannot disagree; there is only one store.

Metric names:

* ``repro_serve_requests_total{tenant,outcome}`` —
  outcome ∈ completed / rejected_budget / failed.
* ``repro_serve_batched_requests_total{tenant}`` — served inside a fused
  multi-request batch.
* ``repro_serve_latency_seconds{tenant}`` — summary over a bounded ring
  (default 4096 samples/tenant, O(1) memory for a long-lived server);
  p50/p99 are computed over the ring on demand, exactly as /stats always did.
* ``repro_serve_batches_total``, ``repro_serve_batched_launch_groups_total``,
  ``repro_serve_queue_depth`` (gauge), ``repro_serve_queue_depth_max``.

Mutation comes from the worker thread plus the submit path while the /stats
and /metrics HTTP threads read; every cell is an atomic counter, and queue
depth additionally serializes on ``_lock`` so ``queue_depth_max`` tracks the
true high-water mark.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

import numpy as np

from repro.obs import MetricsRegistry

LATENCY_RING = 4096


def _percentiles(samples) -> dict:
    if not samples:
        return {"p50_ms": None, "p99_ms": None, "mean_ms": None}
    arr = np.asarray(samples, dtype=np.float64) * 1e3
    return {"p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "mean_ms": float(arr.mean())}


class TenantStats:
    """One tenant's serving counters — views over registry cells.

    ``requests`` is derived (completed + rejected_budget + failed): a request
    is *accepted* exactly when it resolves one way or the other, so the old
    separately-bumped field could only ever drift from the sum by a bug.
    """

    __slots__ = ("tenant", "_completed", "_rejected", "_failed", "_batched",
                 "_latency")

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 tenant: str = "default"):
        registry = MetricsRegistry() if registry is None else registry
        self.tenant = tenant
        outcomes = registry.counter(
            "repro_serve_requests_total",
            "Resolved requests by outcome", labels=("tenant", "outcome"))
        self._completed = outcomes.labels(tenant=tenant, outcome="completed")
        self._rejected = outcomes.labels(tenant=tenant,
                                         outcome="rejected_budget")
        self._failed = outcomes.labels(tenant=tenant, outcome="failed")
        self._batched = registry.counter(
            "repro_serve_batched_requests_total",
            "Requests served inside a fused multi-request batch",
            labels=("tenant",)).labels(tenant=tenant)
        self._latency = registry.summary(
            "repro_serve_latency_seconds",
            "End-to-end request latency (bounded ring)",
            labels=("tenant",), maxlen=LATENCY_RING).labels(tenant=tenant)

    # -- outcome recording (atomic) -------------------------------------
    def record(self, outcome: str, batched: bool = False,
               latency_s: Optional[float] = None) -> None:
        """Resolve one request: outcome ∈ completed/rejected_budget/failed."""
        cell = {"completed": self._completed,
                "rejected_budget": self._rejected,
                "failed": self._failed}[outcome]
        cell.inc()
        if batched:
            self._batched.inc()
        if latency_s is not None:
            self._latency.observe(float(latency_s))

    def record_latency(self, seconds: float) -> None:
        self._latency.observe(float(seconds))

    # -- legacy field views ---------------------------------------------
    @property
    def requests(self) -> int:
        return self.completed + self.rejected_budget + self.failed

    @property
    def completed(self) -> int:
        return int(self._completed.value)

    @completed.setter
    def completed(self, v: int) -> None:
        self._completed.set(v)

    @property
    def rejected_budget(self) -> int:
        return int(self._rejected.value)

    @rejected_budget.setter
    def rejected_budget(self, v: int) -> None:
        self._rejected.set(v)

    @property
    def failed(self) -> int:
        return int(self._failed.value)

    @failed.setter
    def failed(self, v: int) -> None:
        self._failed.set(v)

    @property
    def batched_requests(self) -> int:
        return int(self._batched.value)

    @batched_requests.setter
    def batched_requests(self, v: int) -> None:
        self._batched.set(v)

    def to_dict(self) -> dict:
        d = {"requests": self.requests, "completed": self.completed,
             "rejected_budget": self.rejected_budget, "failed": self.failed,
             "batched_requests": self.batched_requests}
        d.update(_percentiles(self._latency.samples()))
        return d


class ServerStats:
    """Server-wide counters + per-tenant breakdown, registry-backed.

    ``batch_occupancy`` is the running mean number of requests per worker
    drain — the direct measure of how much cross-tenant fusion the traffic
    pattern allows (1.0 = purely sequential serving).
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = MetricsRegistry() if registry is None else registry
        self._lock = threading.Lock()
        self.tenants: Dict[str, TenantStats] = {}      # guarded-by: _lock
        self._batches = self.registry.counter(
            "repro_serve_batches_total", "Worker queue drains")
        self._groups = self.registry.counter(
            "repro_serve_batched_launch_groups_total",
            "Fused signature groups launched across batches")
        self._depth = self.registry.gauge(
            "repro_serve_queue_depth", "Requests currently queued")
        self._depth_max = self.registry.gauge(
            "repro_serve_queue_depth_max", "Queue-depth high-water mark")

    # -- legacy field views ---------------------------------------------
    @property
    def batches(self) -> int:
        return int(self._batches.value)

    @property
    def batched_launch_groups(self) -> int:
        return int(self._groups.value)

    @property
    def queue_depth(self) -> int:
        return int(self._depth.value)

    @property
    def queue_depth_max(self) -> int:
        return int(self._depth_max.value)

    def tenant(self, tenant: str) -> TenantStats:
        with self._lock:
            ts = self.tenants.get(tenant)
            if ts is None:
                ts = self.tenants[tenant] = TenantStats(self.registry, tenant)
            return ts

    def enqueue(self) -> None:
        with self._lock:               # depth + max must move together
            d = self._depth.value + 1
            self._depth.set(d)
            self._depth_max.set_max(d)

    def dequeue(self, n: int) -> None:
        with self._lock:
            self._depth.set(max(0, self._depth.value - n))

    def record_batch(self, size: int, fused_groups: int = 0) -> None:
        self._batches.inc()
        if fused_groups:
            self._groups.inc(fused_groups)

    def to_dict(self, cache: Optional[object] = None,
                ledger: Optional[object] = None) -> dict:
        with self._lock:
            tenants = dict(self.tenants)
        total = sum(t.requests for t in tenants.values())
        batches = self.batches
        occ = (total / batches) if batches else 0.0
        d = {
            "requests_total": total,
            "batches": batches,
            "batch_occupancy": occ,
            "batched_launch_groups": self.batched_launch_groups,
            "queue_depth": self.queue_depth,
            "queue_depth_max": self.queue_depth_max,
            "tenants": {t: s.to_dict() for t, s in tenants.items()},
        }
        if cache is not None:
            lookups = cache.hits + cache.misses
            d["engine_cache"] = {
                "hits": cache.hits, "misses": cache.misses,
                "hit_rate": (cache.hits / lookups) if lookups else None,
                "entries": len(cache), "evictions": cache.evictions,
                "forced_evictions": cache.forced_evictions,
            }
        if ledger is not None:
            d["ledger"] = ledger.report()
        return d
