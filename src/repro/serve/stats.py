"""Serving observability: per-tenant and server-wide counters for /stats.

Latencies are kept in a bounded ring (default 4096 samples per tenant) so a
long-lived server's stats stay O(1) memory; p50/p99 are computed over the
ring on demand.  All mutation goes through the owning server's worker thread
plus the submit path, so counters use a lock only where two threads race
(queue depth at submit vs. drain; the latency ring vs. the /stats reader).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional

import numpy as np


def _percentiles(samples) -> dict:
    if not samples:
        return {"p50_ms": None, "p99_ms": None, "mean_ms": None}
    arr = np.asarray(samples, dtype=np.float64) * 1e3
    return {"p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "mean_ms": float(arr.mean())}


@dataclass
class TenantStats:
    """One tenant's serving counters.

    The latency ring is lock-guarded: the worker appends while the /stats
    HTTP thread computes percentiles, and iterating a deque that a bounded
    append mutates raises ``RuntimeError`` mid-iteration.
    """

    requests: int = 0              # accepted (completed or failed)
    completed: int = 0
    rejected_budget: int = 0       # BudgetExhausted at charge time
    failed: int = 0                # non-budget errors
    batched_requests: int = 0      # served inside a fused multi-request batch
    _latencies: Deque[float] = field(                  # guarded-by: _lat_lock
        default_factory=lambda: deque(maxlen=4096))
    _lat_lock: threading.Lock = field(default_factory=threading.Lock,
                                      repr=False)

    def record_latency(self, seconds: float) -> None:
        with self._lat_lock:
            self._latencies.append(float(seconds))

    def to_dict(self) -> dict:
        d = {"requests": self.requests, "completed": self.completed,
             "rejected_budget": self.rejected_budget, "failed": self.failed,
             "batched_requests": self.batched_requests}
        with self._lat_lock:
            samples = list(self._latencies)
        d.update(_percentiles(samples))
        return d


class ServerStats:
    """Server-wide counters + per-tenant breakdown.

    ``batch_occupancy`` is the running mean number of requests per worker
    drain — the direct measure of how much cross-tenant fusion the traffic
    pattern allows (1.0 = purely sequential serving).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.tenants: Dict[str, TenantStats] = {}      # guarded-by: _lock
        self.batches = 0               # worker drains (guarded-by: _lock)
        self.batched_launch_groups = 0  # fused groups (guarded-by: _lock)
        self.queue_depth = 0                           # guarded-by: _lock
        self.queue_depth_max = 0                       # guarded-by: _lock

    def tenant(self, tenant: str) -> TenantStats:
        with self._lock:
            ts = self.tenants.get(tenant)
            if ts is None:
                ts = self.tenants[tenant] = TenantStats()
            return ts

    def enqueue(self) -> None:
        with self._lock:
            self.queue_depth += 1
            self.queue_depth_max = max(self.queue_depth_max, self.queue_depth)

    def dequeue(self, n: int) -> None:
        with self._lock:
            self.queue_depth = max(0, self.queue_depth - n)

    def record_batch(self, size: int, fused_groups: int = 0) -> None:
        with self._lock:
            self.batches += 1
            self.batched_launch_groups += fused_groups

    def to_dict(self, cache: Optional[object] = None,
                ledger: Optional[object] = None) -> dict:
        with self._lock:
            total = sum(t.requests for t in self.tenants.values())
            occ = (total / self.batches) if self.batches else 0.0
            d = {
                "requests_total": total,
                "batches": self.batches,
                "batch_occupancy": occ,
                "batched_launch_groups": self.batched_launch_groups,
                "queue_depth": self.queue_depth,
                "queue_depth_max": self.queue_depth_max,
                "tenants": {t: s.to_dict() for t, s in self.tenants.items()},
            }
        if cache is not None:
            lookups = cache.hits + cache.misses
            d["engine_cache"] = {
                "hits": cache.hits, "misses": cache.misses,
                "hit_rate": (cache.hits / lookups) if lookups else None,
                "entries": len(cache), "evictions": cache.evictions,
                "forced_evictions": cache.forced_evictions,
            }
        if ledger is not None:
            d["ledger"] = ledger.report()
        return d
