"""Engine warm pool: pin hot engines, evict by tenant-weighted LRU.

Wraps a private :class:`~repro.engine.sharded._EngineCache` (same machinery
the sharded path uses, so instrumentation and weakref hygiene are shared) and
drives its warm-pool hooks from observed traffic:

* every serve records (cache key, tenant); an entry's score is
  ``Σ_t uses[key][t] / total_uses[t]`` — each tenant contributes the
  *fraction of its own traffic* that hit this engine, so one hyperactive
  tenant cannot starve the warm engines of everyone else;
* the ``pin_count`` highest-scoring live entries are pinned (never evicted
  while pinned — the "hot signatures" of the traffic mix);
* a full cache evicts the lowest-scoring unpinned entry (ties → LRU) via the
  cache's ``evict_score`` hook instead of pure LRU.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Dict, Optional

from repro.core.mechanism import noise_dtype
from repro.engine.sharded import _EngineCache


class EnginePool:
    """Tenant-aware engine cache with pinning + weighted eviction.

    Thread-safe: ``engine_for`` and ``stats`` serialize on one lock, so a
    tenant registration warming an engine on the caller thread can never
    corrupt the cache OrderedDict a running worker is using, and a /stats
    snapshot never iterates entries mid-mutation.
    """

    def __init__(self, maxsize: Optional[int] = None, pin_count: int = 2):
        self.cache = _EngineCache(maxsize)
        self.cache.evict_score = self._score
        self.pin_count = int(pin_count)
        self._lock = threading.Lock()
        self._uses: Dict[tuple, Dict[str, int]] = defaultdict(
            lambda: defaultdict(int))                  # guarded-by: _lock
        self._tenant_total: Dict[str, int] = defaultdict(int)  # guarded-by: _lock

    def engine_for(self, tenant: str, plan, use_kernel: bool = False,
                   dtype=None, secure: bool = False, digits: int = 4):
        """Cached compiled engine for ``plan``, accounted to ``tenant``."""
        dtype = noise_dtype() if dtype is None else dtype
        with self._lock:
            eng = self.cache.get(plan, use_kernel, dtype, secure, digits)
            if eng is None:
                eng = plan.engine(use_kernel=use_kernel, precompile=False,
                                  dtype=dtype, secure=secure, digits=digits)
                eng.stats.bump("cache_misses")
                self.cache.put(plan, use_kernel, dtype, eng, secure, digits)
            key = self.cache._key(plan, use_kernel, dtype, secure, digits)
            self._uses[key][tenant] += 1
            self._tenant_total[tenant] += 1
            self._repin()
            return eng

    def _score(self, key: tuple) -> float:  # requires-lock: _lock
        return sum(n / self._tenant_total[t]
                   for t, n in self._uses.get(key, {}).items()
                   if self._tenant_total[t])

    def _repin(self) -> None:  # requires-lock: _lock
        live = list(self.cache._entries)
        # prune use counts for evicted/dead keys so scores track live traffic
        for k in [k for k in self._uses if k not in self.cache._entries]:
            del self._uses[k]
        top = sorted(live, key=self._score, reverse=True)[:self.pin_count]
        self.cache._pinned = set(top)

    def stats(self) -> dict:
        with self._lock:
            lookups = self.cache.hits + self.cache.misses
            return {"entries": len(self.cache), "hits": self.cache.hits,
                    "misses": self.cache.misses,
                    "hit_rate": (self.cache.hits / lookups) if lookups
                    else None,
                    "evictions": self.cache.evictions,
                    "forced_evictions": self.cache.forced_evictions,
                    "pinned": len(self.cache._pinned),
                    "snapshot": self.cache.snapshot()}
