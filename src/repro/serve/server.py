"""Async multi-tenant release server: queue → charge → fuse → serve.

The worker loop drains the request queue in small batches (up to
``max_batch`` requests, waiting at most ``max_wait_ms`` after the first to
let a batch fill), then serves a batch in three phases:

1. **validate + charge** — every request's marginals are validated against
   the tenant's plan closure (keys + cell counts), and only then charged
   against the durable ledger *before anything is measured*
   (charge-before-measure, :mod:`repro.serve.ledger`) — a malformed request
   never burns budget.  Over-budget requests fail immediately with the exact
   remaining ρ; their future carries the
   :class:`~repro.core.accountant.BudgetExhausted`.
2. **fuse** — charged release requests whose plans are cross-request fusable
   (plain marginal plans, :func:`repro.engine.multi.can_fuse`) ride ONE
   fused chain launch per distinct per-axis signature across the whole batch
   (:func:`repro.engine.multi.measure_multi`); RP+/composite/secure requests
   are served per-request through the tenant-weighted engine pool.
3. **serve** — per-request reconstruction through the pooled compiled
   engines, optional postprocessing (consistency / non-negativity), and
   synthesis from the tenant's last non-negative release.

Noise keys: a request with ``seed=None`` gets a key folded from the server's
base key and a monotonically increasing request counter — two requests never
share noise unless the caller explicitly forces a seed (tests do, to check
batched/sequential bit-exactness).
"""
from __future__ import annotations

import contextlib
import json
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Mapping, Optional, Sequence

import numpy as np

import jax

from repro.core.accountant import BudgetExhausted
from repro.core.domain import Clique
from repro.core.mechanism import noise_dtype, pcost_of_plan
from repro.engine.multi import can_fuse, measure_multi
from repro.obs import REGISTRY, TRACER, exposition
from repro.serve.ledger import BudgetLedger, UnknownTenant
from repro.serve.pool import EnginePool
from repro.serve.stats import ServerStats

RELEASE_KINDS = ("marginal", "range")


@dataclass
class ReleaseRequest:
    """One tenant request.

    ``kind="marginal"`` / ``"range"`` release the tenant's registered
    workload from the supplied exact marginal tables (``"range"`` merely
    asserts the tenant holds an RP+ plan); ``kind="synthesis"`` samples
    ``n_records`` rows from the tenant's last ``postprocess="nonneg"``
    release (no new measurement → no budget charge).
    """

    tenant: str
    kind: str = "marginal"
    marginals: Optional[Mapping[Clique, np.ndarray]] = None
    postprocess: Optional[str] = None
    n_records: int = 0
    seed: Optional[int] = None
    cliques: Optional[Sequence[Clique]] = None    # reconstruct subset


@dataclass
class ReleaseResult:
    """What a resolved request future carries."""

    tenant: str
    kind: str
    tables: Optional[Dict[Clique, np.ndarray]] = None
    measurements: Optional[dict] = None
    records: Optional[np.ndarray] = None
    pcost_charged: float = 0.0
    batched: bool = False           # served inside a fused multi-request batch
    batch_size: int = 1
    latency_s: float = 0.0


@dataclass
class _TenantSession:
    plan: object
    secure: bool = False
    digits: int = 4
    synth_tables: Optional[dict] = None
    pcost_per_release: float = 0.0


@dataclass
class _Pending:
    request: ReleaseRequest
    future: Future
    t_submit: float
    index: int                       # global request counter (noise fold)
    session: Optional[_TenantSession] = None
    measurements: Optional[dict] = None
    batched: bool = False
    charged: float = 0.0
    trace: Optional[object] = None   # root serve.request span (tracing on)


class ReleaseServer:
    """Multi-tenant serving tier over the plan → measure → release pipeline.

    Parameters
    ----------
    ledger:       durable per-tenant budget ledger (charge-before-measure).
    max_batch:    worker drain size; 1 disables cross-tenant fusion.
    max_wait_ms:  how long the worker lingers after the first request to let
                  a batch fill (0 = serve whatever is already queued).
    use_kernel:   route fused chains through the Pallas kernel (TPU) or the
                  batched-jnp path (CPU default).
    pool:         engine warm pool; default ``EnginePool()`` (capacity from
                  ``REPRO_ENGINE_CACHE_SIZE``).
    noise_seed:   base key for server-assigned per-request noise keys.
    """

    def __init__(self, ledger: BudgetLedger, max_batch: int = 16,
                 max_wait_ms: float = 2.0, use_kernel: bool = False,
                 dtype=None, pool: Optional[EnginePool] = None,
                 noise_seed: int = 0):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.ledger = ledger
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.use_kernel = bool(use_kernel)
        self.dtype = noise_dtype() if dtype is None else dtype
        self.pool = EnginePool() if pool is None else pool
        self.stats = ServerStats()
        # The server-private metrics registry (tenant-scoped series); the
        # ledger mirrors its charge/reject/spend series into the same store
        # so /metrics and /ledger can never disagree.
        self.metrics = self.stats.registry
        self.ledger.bind_registry(self.metrics)
        self._started_at: Optional[float] = None
        self._base_key = jax.random.PRNGKey(noise_seed)
        self._sessions: Dict[str, _TenantSession] = {}  # guarded-by: _sessions_lock
        self._sessions_lock = threading.Lock()
        self._queue: "queue.Queue[_Pending]" = queue.Queue()
        self._counter = 0                              # guarded-by: _counter_lock
        self._counter_lock = threading.Lock()
        self._stop_evt = threading.Event()
        self._resume_evt = threading.Event()
        self._resume_evt.set()
        self._worker: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ReleaseServer":
        if self._worker is None or not self._worker.is_alive():
            self._stop_evt.clear()
            if self._started_at is None:
                self._started_at = time.monotonic()
            self._worker = threading.Thread(target=self._worker_loop,
                                            name="release-server-worker",
                                            daemon=True)
            self._worker.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        # joining the queue of a dead worker would hang forever
        if drain and self._worker is not None and self._worker.is_alive():
            self._queue.join()
        self._stop_evt.set()
        if self._worker is not None:
            self._worker.join(timeout)
            self._worker = None

    def pause(self) -> None:
        """Hold the worker so the queue can be prefilled (tests, benchmarks)."""
        self._resume_evt.clear()

    def resume(self) -> None:
        self._resume_evt.set()

    def __enter__(self) -> "ReleaseServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=not any(exc))

    # ------------------------------------------------------------- tenants
    def register_tenant(self, tenant: str, plan, rho: Optional[float] = None,
                        pcost: Optional[float] = None, secure: bool = False,
                        digits: int = 4, warm: bool = True) -> None:
        """Register a tenant: durable budget + serving plan (+ warm engine).

        ``rho``/``pcost`` set the tenant's total budget exactly as
        :meth:`BudgetLedger.register`.  ``secure=True`` serves this tenant
        through the discrete-Gaussian engine (charged the exact discrete
        pcost, always ≤ continuous).  ``warm=True`` compiles the engine into
        the pool now so the first request is a cache hit.

        Thread-safe against a running worker: the session map and the engine
        pool are lock-guarded, so tenants may be registered mid-traffic.
        """
        self.ledger.register(tenant, rho=rho, pcost=pcost)
        if secure:
            from repro.core.discrete import discrete_pcost_of_plan
            per_release = discrete_pcost_of_plan(plan)
        else:
            per_release = pcost_of_plan(plan)
        with self._sessions_lock:
            self._sessions[tenant] = _TenantSession(
                plan=plan, secure=secure, digits=digits,
                pcost_per_release=per_release)
        if warm:
            self.pool.engine_for(tenant, plan, self.use_kernel, self.dtype,
                                 secure, digits)

    def tenants(self) -> tuple:
        with self._sessions_lock:
            return tuple(self._sessions)

    # -------------------------------------------------------------- submit
    def submit(self, request: ReleaseRequest) -> Future:
        """Enqueue a request; the returned future resolves to a
        :class:`ReleaseResult` or raises the serving error (over-budget →
        :class:`~repro.core.accountant.BudgetExhausted`)."""
        if self._worker is None or not self._worker.is_alive():
            raise RuntimeError(
                "server worker is not running: call start() first (a worker "
                "that was running has died or been stopped — restarting via "
                "start() is safe; queued budget charges are already durable)")
        fut: Future = Future()
        with self._counter_lock:
            idx = self._counter
            self._counter += 1
        trace = None
        if TRACER.enabled:
            # Root span of the request's trace tree: minted here, carried on
            # the queued item, ended by the worker when the future resolves.
            trace = TRACER.span("serve.request").set(
                tenant=request.tenant, kind=request.kind, index=idx)
        self.stats.enqueue()
        self._queue.put(_Pending(request, fut, time.monotonic(), idx,
                                 trace=trace))
        return fut

    def request_sync(self, request: ReleaseRequest,
                     timeout: Optional[float] = 120.0) -> ReleaseResult:
        return self.submit(request).result(timeout)

    def stats_dict(self) -> dict:
        d = self.stats.to_dict(cache=self.pool.cache, ledger=self.ledger)
        # Kernel-tier observability (docs/DESIGN.md §14): the process-wide
        # pad/call/slice counters and the autotuner decisions in effect.
        from repro.kernels.autotune import registry_snapshot
        from repro.kernels.kron_matvec.stats import chain_stats
        d["kernels"] = chain_stats()
        d["autotune"] = registry_snapshot()
        return d

    def health(self) -> dict:
        """Liveness snapshot for /healthz: worker state, queue depth, uptime.

        ``ok`` is False exactly when the worker thread is not alive — the
        same condition under which :meth:`submit` refuses new requests — so
        a load balancer polling /healthz stops routing before clients see
        the RuntimeError.
        """
        alive = self._worker is not None and self._worker.is_alive()
        uptime = (time.monotonic() - self._started_at
                  if self._started_at is not None else 0.0)
        return {"ok": alive, "worker_alive": alive,
                "queue_depth": self.stats.queue_depth,
                "uptime_s": uptime, "tenants": list(self.tenants())}

    def metrics_text(self) -> str:
        """Prometheus exposition: server registry merged with the global one
        (kernel events, engine aggregates, launch timings)."""
        return exposition(self.metrics, REGISTRY)

    # -------------------------------------------------------------- worker
    def _worker_loop(self) -> None:
        while not self._stop_evt.is_set():
            if not self._resume_evt.wait(timeout=0.05):
                continue
            try:
                first = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            # pause() may land while we were already blocked in get() above,
            # past the resume check: hold the first request until resumed so
            # a prefilled queue always drains as one batch.
            while (not self._resume_evt.is_set()
                   and not self._stop_evt.is_set()):
                self._resume_evt.wait(timeout=0.05)
            deadline = time.monotonic() + self.max_wait_ms / 1e3
            while len(batch) < self.max_batch:
                try:
                    batch.append(self._queue.get(
                        timeout=max(0.0, deadline - time.monotonic())))
                except queue.Empty:
                    break
            self.stats.dequeue(len(batch))
            try:
                self._serve_batch(batch)
            except Exception as exc:   # noqa: BLE001 — never kill the worker
                # _serve_batch fails individual requests through their
                # futures; anything escaping it is a bug, but dying here
                # would strand every in-flight future (and deadlock
                # stop(drain=True)), so deliver the error and keep serving.
                for p in batch:
                    self._fail(p, exc)
            finally:
                for _ in batch:
                    self._queue.task_done()

    def _key_for(self, p: _Pending) -> jax.Array:
        if p.request.seed is not None:
            return jax.random.PRNGKey(p.request.seed)
        return jax.random.fold_in(self._base_key, p.index)

    def _fail(self, p: _Pending, exc: Exception) -> None:
        if p.future.done():            # already resolved (or failed) earlier
            return
        outcome = ("rejected_budget" if isinstance(exc, BudgetExhausted)
                   else "failed")
        self.stats.tenant(p.request.tenant).record(outcome)
        if p.trace:
            p.trace.set(outcome=outcome, error=type(exc).__name__)
            p.trace.end()
        p.future.set_exception(exc)

    @staticmethod
    def _validate_marginals(sess: _TenantSession, req: ReleaseRequest) -> None:
        """Reject malformed marginals BEFORE any budget is charged.

        Every clique of the tenant's plan closure must be present with the
        right cell count — the same contract every engine's ``measure``
        enforces, checked here so a malformed-but-present payload fails
        without burning the tenant's budget.
        """
        plan = sess.plan
        for c in plan.cliques:
            if c not in req.marginals:
                raise ValueError(
                    f"marginals missing clique {c!r}: the plan closure "
                    f"needs all of {list(plan.cliques)!r} (nothing charged)")
            got = int(np.asarray(req.marginals[c]).size)
            want = plan.domain.n_cells(c)
            if got != want:
                raise ValueError(
                    f"marginal for {c!r} has {got} cells, want {want} "
                    f"(nothing charged)")

    def _serve_batch(self, batch) -> None:
        # Queue wait: an interval that started on the submitting thread —
        # recorded with an explicit t0 against each request's own trace.
        if TRACER.enabled:
            t_drain = time.monotonic()
            for p in batch:
                if p.trace:
                    TRACER.span("serve.queue_wait", parent=p.trace,
                                t0=p.t_submit).set(
                        batch_size=len(batch)).end(t_drain)

        # ---- phase 1: validate, then charge-before-measure ---------------
        charged: list = []
        for p in batch:
            req = p.request
            try:
                with self._sessions_lock:
                    sess = self._sessions.get(req.tenant)
                if sess is None:
                    raise UnknownTenant(req.tenant)
                p.session = sess
                if req.kind in RELEASE_KINDS:
                    if req.marginals is None:
                        raise ValueError(
                            f"{req.kind!r} request needs marginals=")
                    if req.kind == "range" and can_fuse(sess.plan):
                        raise ValueError(
                            "kind='range' needs an RP+ plan; this tenant "
                            "registered a plain marginal plan")
                    self._validate_marginals(sess, req)
                    p.charged = sess.pcost_per_release
                    with TRACER.span("serve.charge", parent=p.trace).set(
                            tenant=req.tenant, pcost=p.charged):
                        self.ledger.charge(req.tenant, p.charged,
                                           request_id=f"req-{p.index}")
                elif req.kind == "synthesis":
                    if sess.synth_tables is None:
                        raise ValueError(
                            "no non-negative release to sample from: submit "
                            "a release with postprocess='nonneg' first")
                    p.charged = 0.0          # postprocessing only
                else:
                    raise ValueError(f"unknown request kind {req.kind!r}")
                charged.append(p)
            except Exception as exc:         # noqa: BLE001 — fail THIS request
                self._fail(p, exc)

        # ---- phase 2: fuse same-signature release traffic ----------------
        fusable = [p for p in charged
                   if p.request.kind in RELEASE_KINDS
                   and can_fuse(p.session.plan) and not p.session.secure]
        fused_groups = 0
        if len(fusable) >= 2:
            items = [(p.session.plan, p.request.marginals, self._key_for(p))
                     for p in fusable]
            # The fused launch serves every fusable request at once, but a
            # span tree needs ONE parent: the batch leader's trace hosts the
            # real serve.fuse span (kernel/group spans nest under it); every
            # other request gets a same-interval serve.fuse marker pointing
            # at the leader's trace, so its tree stays connected and its
            # critical path still accounts the fused time.
            leader = fusable[0]
            t_fuse0 = time.monotonic()
            fuse_ctx = (TRACER.activate(leader.trace) if leader.trace
                        else contextlib.nullcontext())
            try:
                with fuse_ctx, TRACER.span(
                        "serve.fuse", parent=leader.trace).set(
                        requests=len(fusable)):
                    measured = measure_multi(items,
                                             use_kernel=self.use_kernel,
                                             dtype=self.dtype)
            except Exception:          # noqa: BLE001 — fused path is optional
                # Phase-1 validation makes this unreachable for bad request
                # payloads, but an unexpected fused-path failure must not
                # strand already-charged futures: fall back to the solo path
                # (p.measurements stays None), where a genuinely bad request
                # fails alone in phase 3 and the rest of the batch serves.
                pass
            else:
                t_fuse1 = time.monotonic()
                sigs = set()
                for plan, _m, _k in items:
                    for c in plan.cliques:
                        sigs.add(tuple(plan.domain.attributes[a].size
                                       for a in c))
                fused_groups = len(sigs)
                for p, meas in zip(fusable, measured):
                    p.measurements = meas
                    p.batched = True
                    if p.trace and p is not leader:
                        TRACER.span("serve.fuse", parent=p.trace,
                                    t0=t_fuse0).set(
                            shared=True, requests=len(fusable),
                            launch_trace=leader.trace.trace_id
                            if leader.trace else None).end(t_fuse1)
        self.stats.record_batch(len(batch), fused_groups)

        # ---- phase 3: per-request serve ----------------------------------
        for p in charged:
            ctx = (TRACER.activate(p.trace) if p.trace
                   else contextlib.nullcontext())
            try:
                with ctx:
                    result = self._serve_one(p, len(batch))
            except Exception as exc:         # noqa: BLE001 — fail THIS request
                self._fail(p, exc)
            else:
                self.stats.tenant(p.request.tenant).record(
                    "completed", batched=p.batched,
                    latency_s=result.latency_s)
                if p.trace:
                    p.trace.set(outcome="completed", batched=p.batched,
                                batch_size=len(batch))
                    p.trace.end()
                p.future.set_result(result)

    def _serve_one(self, p: _Pending, batch_size: int) -> ReleaseResult:
        req, sess = p.request, p.session
        if req.kind == "synthesis":
            from repro.release import synthesize_records
            with TRACER.span("serve.synthesize").set(
                    tenant=req.tenant, n_records=req.n_records):
                records = synthesize_records(sess.plan.domain,
                                             sess.synth_tables,
                                             req.n_records, self._key_for(p))
            return ReleaseResult(req.tenant, req.kind, records=records,
                                 batch_size=batch_size,
                                 latency_s=time.monotonic() - p.t_submit)
        engine = self.pool.engine_for(req.tenant, sess.plan, self.use_kernel,
                                      self.dtype, sess.secure, sess.digits)
        meas = p.measurements
        if meas is None:                      # solo path (RP+/secure/batch=1)
            meas = engine.measure(req.marginals, self._key_for(p))
        tables = engine.reconstruct(meas, req.cliques) if req.cliques \
            else engine.reconstruct(meas)
        if req.postprocess is not None:
            engine._check_postprocess()
            from repro.release import postprocess_release
            tables = postprocess_release(
                sess.plan, tables, req.postprocess,
                total=engine._postprocess_total(meas))
            engine.stats.bump("postprocess_calls")
            if req.postprocess == "nonneg":
                sess.synth_tables = tables
        return ReleaseResult(req.tenant, req.kind, tables=tables,
                             measurements=meas, pcost_charged=p.charged,
                             batched=p.batched, batch_size=batch_size,
                             latency_s=time.monotonic() - p.t_submit)


# --------------------------------------------------------------------- http
class _StatsHandler(BaseHTTPRequestHandler):
    server_ref: Optional[ReleaseServer] = None

    def log_message(self, *args) -> None:   # silence per-request stderr spam
        pass

    def do_GET(self) -> None:               # noqa: N802 (stdlib API name)
        srv = self.server_ref
        path = self.path.split("?", 1)[0].rstrip("/") or "/"
        status = 200
        ctype = "application/json"
        if path == "/stats":
            body = json.dumps(srv.stats_dict(), indent=2, default=str)
        elif path == "/ledger":
            body = json.dumps(srv.ledger.report(), indent=2, default=str)
        elif path == "/metrics":
            body = srv.metrics_text()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif path in ("/", "/healthz"):
            health = srv.health()
            body = json.dumps(health)
            if not health["ok"]:      # dead worker: stop routing traffic here
                status = 503
        else:
            self.send_error(404)
            return
        data = body.encode()
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)


def start_stats_http(server: ReleaseServer, host: str = "127.0.0.1",
                     port: int = 0):
    """Serve ``/stats``, ``/ledger``, ``/healthz``, ``/metrics`` for
    ``server``.

    ``/metrics`` is Prometheus text format (docs/OBSERVABILITY.md);
    ``/healthz`` returns 503 while the worker thread is dead.  Returns
    ``(httpd, bound_port)``; the HTTP server runs on a daemon thread (stdlib
    only — no framework dependency).  Port 0 binds an ephemeral port.
    """
    handler = type("_Bound", (_StatsHandler,), {"server_ref": server})
    httpd = ThreadingHTTPServer((host, port), handler)
    t = threading.Thread(target=httpd.serve_forever, daemon=True,
                         name="release-server-http")
    t.start()
    return httpd, httpd.server_address[1]
