"""Durable per-tenant zCDP budget ledger (append-only JSONL journal).

The serving tier's privacy guarantee reduces to one invariant: **no tenant's
journaled spend may ever understate what was actually measured against their
data**.  The ledger enforces it with charge-before-measure ordering:

1. under the ledger lock, the charge is validated against the in-memory
   :class:`~repro.core.accountant.PrivacyBudget` (over-budget → immediate
   :class:`~repro.core.accountant.BudgetExhausted` carrying the exact
   remaining ρ — nothing is journaled, nothing is measured);
2. the charge record is appended to the journal and fsync'd;
3. only then does the in-memory budget advance, and only after ``charge``
   returns may the caller run the measurement.

A crash between (2) and (3) — or any time after (2) — replays the journal on
restart and finds the charge already durable: the tenant is charged for a
measurement that may never have produced output.  That direction is
privacy-safe (budget is wasted, never leaked).  A crash before (2) charged
nothing and measured nothing.  There is no ordering in which noise was
released but the journal missed the charge.

Journal format: one JSON object per line, ``op`` ∈ {``register``,
``charge``}.  Replay tolerates exactly one trailing partial line (a crash
mid-append); corruption anywhere else raises :class:`LedgerCorrupt`.  A
*failed* append (ENOSPC, I/O error) truncates the file back to its pre-write
length so the partial record can never become a non-trailing line; if even
the truncate fails, the ledger marks itself failed and refuses all further
charges (:class:`LedgerFailed` — availability loss, never an under-charge).
"""
from __future__ import annotations

import io
import json
import os
import threading
import time
from typing import Dict, Optional

from repro.core.accountant import BudgetExhausted, PrivacyBudget, zcdp_rho


class LedgerError(Exception):
    """Base class for ledger failures that are not budget rejections."""


class LedgerCorrupt(LedgerError):
    """A non-trailing journal line failed to parse — refuse to serve."""


class LedgerFailed(LedgerError):
    """A failed append could not be rolled back — the journal's on-disk tail
    is unknown, so the ledger refuses every further write."""


class UnknownTenant(LedgerError, KeyError):
    """Charge or query against a tenant id that was never registered."""


class BudgetLedger:
    """Per-tenant :class:`PrivacyBudget` map backed by a JSONL journal.

    Thread-safe: ``register``/``charge`` serialize on one lock, so concurrent
    worker threads can never jointly over-spend a tenant (the race test in
    tests/test_ledger.py hammers this).  ``fsync=False`` trades crash
    durability for speed (benchmarks, tests that only need replay logic).
    """

    def __init__(self, path: str, fsync: bool = True):
        self.path = str(path)
        self.fsync = bool(fsync)
        self._lock = threading.Lock()
        self._budgets: Dict[str, PrivacyBudget] = {}
        self._charges: Dict[str, int] = {}          # per-tenant charge count
        self._failed = False
        self._metrics = None         # bound via bind_registry (obs subsystem)
        self._replayed = self._replay()
        # Unbuffered binary append: tell() is a byte offset and a failed
        # write leaves no hidden buffered tail, so _append can roll a
        # partial record back with one ftruncate.
        self._fh: Optional[io.RawIOBase] = open(  # noqa: SIM115 - lives until close()
            self.path, "ab", buffering=0)

    # ------------------------------------------------------------- replay
    def _replay(self) -> int:
        """Rebuild in-memory state from the journal; returns records applied.

        Charges are applied unconditionally — even a charge that (through a
        historical budget change) now exceeds the registered total still
        counts as spent.  Replay may over-charge relative to what a crashed
        process measured; it can never under-charge, because every
        measurement was preceded by a durable charge record.
        """
        if not os.path.exists(self.path):
            return 0
        applied = 0
        with open(self.path, "r", encoding="utf-8") as fh:
            lines = fh.read().split("\n")
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                rest = [ln for ln in lines[i + 1:] if ln.strip()]
                if rest:
                    raise LedgerCorrupt(
                        f"{self.path}:{i + 1}: unparseable journal line "
                        f"followed by {len(rest)} more — refusing to serve "
                        f"from a corrupt ledger") from None
                break                      # trailing partial line: crash tail
            op = rec.get("op")
            if op == "register":
                t = rec["tenant"]
                b = self._budgets.get(t)
                if b is None:
                    self._budgets[t] = PrivacyBudget(float(rec["pcost_total"]))
                    self._charges[t] = 0
                else:                      # re-register: keep spend, new total
                    b.total_pcost = float(rec["pcost_total"])
            elif op == "charge":
                t = rec["tenant"]
                if t not in self._budgets:
                    raise LedgerCorrupt(
                        f"{self.path}:{i + 1}: charge for unregistered "
                        f"tenant {t!r}")
                self._budgets[t].spent += float(rec["pcost"])
                self._charges[t] += 1
            else:
                raise LedgerCorrupt(f"{self.path}:{i + 1}: unknown op {op!r}")
            applied += 1
        return applied

    # ------------------------------------------------------------- journal
    def _append(self, rec: dict) -> None:
        """Durably append one record (caller holds the lock).

        On any write/fsync failure the file is truncated back to its
        pre-write length, so the journal never carries a non-trailing
        partial line; the in-memory budget never advanced, so the failed
        charge simply never happened.  If the truncate itself fails the
        ledger is marked failed and every later append raises
        :class:`LedgerFailed` rather than risk appending after a partial
        record.
        """
        if self._failed:
            raise LedgerFailed(
                f"{self.path}: a failed append could not be rolled back; "
                f"refusing further writes")
        data = (json.dumps(rec, separators=(",", ":")) + "\n").encode("utf-8")
        pos = self._fh.tell()
        try:
            n = self._fh.write(data)
            if n != len(data):
                raise OSError(f"short write: {n}/{len(data)} bytes")
            if self.fsync:
                os.fsync(self._fh.fileno())
        except Exception:
            try:
                os.ftruncate(self._fh.fileno(), pos)
                self._fh.seek(pos)
            except OSError:
                self._failed = True
            raise

    # ------------------------------------------------------------- metrics
    def bind_registry(self, registry) -> None:
        """Mirror charge/reject events + spend levels into ``registry``.

        Called by the owning :class:`~repro.serve.server.ReleaseServer`; a
        standalone ledger stays metrics-free.  Only successful *journal*
        outcomes are mirrored — the gauges show the same numbers
        :meth:`report` does, because both read the same budgets.
        """
        self._metrics = {
            "charges": registry.counter(
                "repro_ledger_charges_total",
                "Durably journaled budget charges", labels=("tenant",)),
            "rejects": registry.counter(
                "repro_ledger_rejects_total",
                "Charges rejected as over-budget", labels=("tenant",)),
            "spent": registry.gauge(
                "repro_ledger_pcost_spent",
                "Journaled pcost spent", labels=("tenant",)),
            "total": registry.gauge(
                "repro_ledger_pcost_total",
                "Registered pcost budget", labels=("tenant",)),
        }
        with self._lock:
            for t, b in self._budgets.items():   # replayed state, up front
                self._metrics["spent"].labels(tenant=t).set(b.spent)
                self._metrics["total"].labels(tenant=t).set(b.total_pcost)

    def _mirror(self, kind: str, tenant: str, budget=None) -> None:
        m = self._metrics
        if m is None:
            return
        if kind in ("charges", "rejects"):
            m[kind].labels(tenant=tenant).inc()
        if budget is not None:
            m["spent"].labels(tenant=tenant).set(budget.spent)
            m["total"].labels(tenant=tenant).set(budget.total_pcost)

    # -------------------------------------------------------------- public
    @property
    def tenants(self):
        return tuple(self._budgets)

    @property
    def replayed_records(self) -> int:
        return self._replayed

    def register(self, tenant: str, rho: Optional[float] = None,
                 pcost: Optional[float] = None) -> None:
        """Create (or re-total) a tenant budget; durable before it returns.

        Exactly one of ``rho`` (zCDP) / ``pcost`` sets the total.  Registering
        an existing tenant updates the total and keeps the journaled spend —
        shrinking a total below the spend simply leaves the tenant with zero
        remaining budget.
        """
        if (rho is None) == (pcost is None):
            raise ValueError("pass exactly one of rho= / pcost=")
        total = 2.0 * float(rho) if rho is not None else float(pcost)
        if total < 0:
            raise ValueError(f"budget must be >= 0, got {total}")
        with self._lock:
            self._append({"op": "register", "tenant": tenant,
                          "pcost_total": total, "ts": time.time()})
            b = self._budgets.get(tenant)
            if b is None:
                b = self._budgets[tenant] = PrivacyBudget(total)
                self._charges[tenant] = 0
            else:
                b.total_pcost = total
            self._mirror("register", tenant, b)

    def charge(self, tenant: str, pcost: float,
               request_id: Optional[str] = None) -> None:
        """Atomically journal + apply a charge, or raise.

        Raises :class:`UnknownTenant` for unregistered tenants and
        :class:`~repro.core.accountant.BudgetExhausted` (with the exact
        remaining ρ) when the charge does not fit.  On return the charge is
        durable — the caller may measure.
        """
        pcost = float(pcost)
        if pcost < 0:
            raise ValueError(f"charge must be >= 0, got {pcost}")
        with self._lock:
            b = self._budgets.get(tenant)
            if b is None:
                raise UnknownTenant(tenant)
            if not b.can_charge(pcost):
                self._mirror("rejects", tenant)
                raise BudgetExhausted(pcost, b.remaining, tenant)
            self._append({"op": "charge", "tenant": tenant, "pcost": pcost,
                          "request_id": request_id, "ts": time.time()})
            b.spent += pcost             # after the durable append, never before
            self._charges[tenant] += 1
            self._mirror("charges", tenant, b)

    def remaining(self, tenant: str) -> float:
        b = self._budgets.get(tenant)
        if b is None:
            raise UnknownTenant(tenant)
        return b.remaining

    def remaining_rho(self, tenant: str) -> float:
        return zcdp_rho(self.remaining(tenant))

    def spent(self, tenant: str) -> float:
        b = self._budgets.get(tenant)
        if b is None:
            raise UnknownTenant(tenant)
        return b.spent

    def report(self, tenant: Optional[str] = None) -> dict:
        """Accountant report per tenant (all tenants when ``tenant=None``)."""
        with self._lock:
            if tenant is not None:
                if tenant not in self._budgets:
                    raise UnknownTenant(tenant)
                return self._report_locked(tenant)
            return {t: self._report_locked(t) for t in self._budgets}

    def _report_locked(self, tenant: str) -> dict:
        b = self._budgets[tenant]
        rep = b.report()
        rep.update(tenant=tenant, charges=self._charges[tenant],
                   pcost_remaining=b.remaining,
                   rho_remaining=zcdp_rho(b.remaining))
        return rep

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "BudgetLedger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
