r"""The SVD lower bound of Li & Miklau [34] on total matrix-mechanism error.

For a workload matrix W, the minimum total variance of any (Gaussian) matrix
mechanism with pcost budget c is bounded below by  (Σ_i s_i(W))² / (c·d) —
the squared nuclear norm of W over (budget × number of columns d).  The per-
column (per-record) privacy cost of the optimal mechanism is uniform for
marginals (the symmetrization argument of Appendix B), which is why the
average-column bound is *tight* here.  The paper uses it as the sanity
check for ResidualPlanner's optimality (Table 4: they coincide for marginals).

For a marginal workload the bound is computable *without* materializing W:
the Gram matrix  G = Σ_A Q_Aᵀ Q_A  is simultaneously diagonalized by the
residual basis (Thm 1).  On the residual subspace R_B (dimension Π_{i∈B}(n_i-1))
its eigenvalue is

    λ_B = Σ_{A ⊇ B, A ∈ Wkload}  w_A · Π_{i ∉ A} n_i

so  ‖W‖_* = tr √G = Σ_B mult_B · √λ_B  with B ranging over closure(Wkload).
(w_A re-weights workloads; w_A = 1 reproduces the plain stacked workload.)
"""
from __future__ import annotations

import math
from typing import Dict, Mapping, Optional

import numpy as np

from repro.core.domain import Clique, Domain, MarginalWorkload, closure, subsets


def svd_bound_marginals(workload: MarginalWorkload, pcost_budget: float = 1.0,
                        weights: Optional[Mapping[Clique, float]] = None) -> float:
    """Scalable SVD lower bound on total variance for a marginal workload."""
    dom = workload.domain
    lam: Dict[Clique, float] = {}
    for wc in workload.cliques:
        w = float((weights or {}).get(wc, 1.0))
        outside = 1.0
        for i in range(dom.n_attrs):
            if i not in set(wc):
                outside *= dom.attributes[i].size
        for sub in subsets(wc):
            lam[sub] = lam.get(sub, 0.0) + w * outside
    nuc = 0.0
    for b, lb in lam.items():
        nuc += dom.residual_size(b) * math.sqrt(lb)
    return nuc ** 2 / (pcost_budget * dom.universe_size())


def svd_bound_dense(W: np.ndarray, pcost_budget: float = 1.0) -> float:
    """Dense SVD bound (tests / tiny workloads)."""
    W = np.asarray(W, dtype=np.float64)
    s = np.linalg.svd(W, compute_uv=False)
    return float(s.sum() ** 2) / (pcost_budget * W.shape[1])


def svdb_rmse_marginals(workload: MarginalWorkload, pcost_budget: float = 1.0) -> float:
    tv = svd_bound_marginals(workload, pcost_budget)
    return math.sqrt(tv / workload.total_cells())
