r"""HDMM baseline (McKenna et al. [40, 41]) re-implemented in JAX.

Templates implemented (the ones the paper benchmarks against):

* ``opt_pidentity``   — the 1-D p-Identity strategy optimizer: A(θ) = [I; B(θ)]
  with nonnegative B, columns normalized to unit L2 (so pcost(A) = 1), Adam on
  ``tr(W (AᵀA)⁻¹ Wᵀ)``.  Also used by ResidualPlanner+ to produce strategy
  replacements S_i ("the 1-dimensional optimizer included with HDMM", §9).
* ``HdmmKron``        — OPT_⊗: per-axis p-Identity on a Kronecker workload;
  unit-pcost total variance is the product of per-axis traces.
* ``HdmmUnion``       — OPT_+: Cauchy–Schwarz budget split across sub-strategies.

Reconstruction is deliberately faithful to HDMM's *universe-sized* least
squares (x̂ = ⊗ A_i† y): it materializes O(Π n_i) vectors and therefore hits
the same memory wall the paper reports (Table 3: OOM at d = 10 for n = 10).
A guard raises ``MemoryError`` before the allocation so benchmarks can record
"out of memory" rather than killing the process.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.domain import Clique, Domain, MarginalWorkload

# Reconstruction guard: refuse to materialize more than this many float64s.
OOM_GUARD_ELEMS = 1 << 27  # 128M elems = 1 GiB


_PIDENTITY_CACHE: Dict[tuple, np.ndarray] = {}


def opt_pidentity(W: np.ndarray, p: Optional[int] = None, iters: int = 1000,
                  lr: float = 0.05, seed: int = 0) -> np.ndarray:
    """Optimize a p-Identity strategy for a 1-D workload W; returns A with
    unit-L2 columns (pcost(A x + N(0,I)) = 1).

    Memoized on (W bytes, p, iters, seed): union workloads re-optimize the
    same per-attribute matrices hundreds of times (e.g. prefix-100 appears in
    every Adult subworkload).
    """
    W = np.asarray(W, dtype=np.float64)
    ck = (W.shape, W.tobytes(), p, iters, seed)
    hit = _PIDENTITY_CACHE.get(ck)
    if hit is not None:
        return hit
    n = W.shape[1]
    if n == 1:
        return np.ones((1, 1))
    p = p if p is not None else max(1, n // 16 + 1)
    WtW = jnp.asarray(W.T @ W)
    eye = jnp.eye(n)

    def make_A(theta):
        B = jax.nn.softplus(theta)
        A = jnp.vstack([eye, B])
        col = jnp.sqrt(jnp.sum(A * A, axis=0))
        return A / col

    def loss(theta):
        A = make_A(theta)
        M = A.T @ A + 1e-9 * eye
        return jnp.trace(jnp.linalg.solve(M, WtW))

    @jax.jit
    def run(theta0):
        def step(carry, i):
            theta, mo, ve = carry
            g = jax.grad(loss)(theta)
            mo = 0.9 * mo + 0.1 * g
            ve = 0.999 * ve + 0.001 * g * g
            mh = mo / (1 - 0.9 ** (i + 1.0))
            vh = ve / (1 - 0.999 ** (i + 1.0))
            return (theta - lr * mh / (jnp.sqrt(vh) + 1e-9), mo, ve), None
        (theta, _, _), _ = jax.lax.scan(
            step, (theta0, jnp.zeros_like(theta0), jnp.zeros_like(theta0)),
            jnp.arange(iters))
        return theta

    key = jax.random.PRNGKey(seed)
    theta0 = jax.random.normal(key, (p, n)) * 0.5 - 1.0
    theta = run(theta0)
    out = np.asarray(make_A(theta), dtype=np.float64)
    _PIDENTITY_CACHE[ck] = out
    return out


def opt_pidentity_projected(W: np.ndarray, **kw) -> np.ndarray:
    """Strategy for W with the all-ones row projected out (paper §9 setup):
    optimize on P₁ = W - W·11ᵀ/n, then return the strategy (used as S_i)."""
    W = np.asarray(W, dtype=np.float64)
    n = W.shape[1]
    P1 = W - (W @ np.ones((n, 1))) @ np.ones((1, n)) / n
    return opt_pidentity(P1, **kw)


@dataclass
class HdmmKron:
    """OPT_⊗: a Kronecker-product strategy ⊗ A_i for a workload ⊗ W_i."""

    factors_W: List[np.ndarray]
    factors_A: List[np.ndarray] = field(default_factory=list)
    tv_unit: float = 0.0          # total variance at pcost budget 1
    maxvar_unit: float = 0.0      # max per-query variance at budget 1

    @staticmethod
    def optimize(factors_W: Sequence[np.ndarray], **kw) -> "HdmmKron":
        A, tvs, mvs = [], [], []
        for Wi in factors_W:
            Wi = np.asarray(Wi, dtype=np.float64)
            if Wi.shape == (1, Wi.shape[1]):          # all-ones (marginalized axis)
                Ai = np.ones((1, Wi.shape[1]))
                Ai = Ai / np.linalg.norm(Ai, axis=0)  # unit cols
            elif Wi.shape[0] == Wi.shape[1] and np.allclose(Wi, np.eye(Wi.shape[1])):
                Ai = np.eye(Wi.shape[1])              # identity is optimal for itself
            else:
                Ai = opt_pidentity(Wi, **kw)
            A.append(Ai)
            M = Ai.T @ Ai
            G = Wi @ np.linalg.pinv(M) @ Wi.T
            tvs.append(float(np.trace(G)))
            mvs.append(float(np.max(np.diag(G))))
        return HdmmKron(list(map(np.asarray, factors_W)), A,
                        float(np.prod(tvs)), float(np.prod(mvs)))

    @property
    def n_queries(self) -> int:
        return int(np.prod([w.shape[0] for w in self.factors_W]))


@dataclass
class HdmmUnion:
    """OPT_+: a union of Kron strategies with optimal budget allocation."""

    subs: List[HdmmKron]
    shares: np.ndarray            # fraction of pcost given to each sub-strategy
    tv_unit: float                # total variance of the whole union at budget 1

    @staticmethod
    def optimize(subs: Sequence[HdmmKron]) -> "HdmmUnion":
        tv = np.array([s.tv_unit for s in subs])
        shares = np.sqrt(tv)
        shares = shares / shares.sum()
        tv_total = float((np.sqrt(tv).sum()) ** 2)  # Σ tv_j / share_j, Σ share = 1
        return HdmmUnion(list(subs), shares, tv_total)

    def total_variance(self, pcost_budget: float = 1.0) -> float:
        return self.tv_unit / pcost_budget

    def rmse(self, pcost_budget: float = 1.0) -> float:
        cells = sum(s.n_queries for s in self.subs)
        return math.sqrt(self.total_variance(pcost_budget) / cells)

    def max_variance(self, pcost_budget: float = 1.0) -> float:
        return max(s.maxvar_unit / (sh * pcost_budget)
                   for s, sh in zip(self.subs, self.shares))


def _marginal_factors_dense(domain: Domain, clique: Clique) -> List[np.ndarray]:
    return [np.eye(a.size) if i in set(clique) else np.ones((1, a.size))
            for i, a in enumerate(domain.attributes)]


def hdmm_marginals(workload: MarginalWorkload, **kw) -> HdmmUnion:
    """HDMM (DefaultUnionKron) on a pure-marginal workload."""
    subs = [HdmmKron.optimize(_marginal_factors_dense(workload.domain, c), **kw)
            for c in workload.cliques]
    return HdmmUnion.optimize(subs)


def hdmm_generalized(workload: MarginalWorkload, kinds: Sequence[str], **kw) -> HdmmUnion:
    """HDMM on generalized marginals (per-attribute basic matrices, §9 setup)."""
    from repro.core.plus import build_w
    subs = []
    for c in workload.cliques:
        facs = []
        for i, a in enumerate(workload.domain.attributes):
            facs.append(build_w(kinds[i], a.size) if i in set(c)
                        else np.ones((1, a.size)))
        subs.append(HdmmKron.optimize(facs, **kw))
    return HdmmUnion.optimize(subs)


# ---------------------------------------------------------------------------
# Universe-sized measurement + reconstruction (the part that hits HDMM's wall)
# ---------------------------------------------------------------------------

def hdmm_measure_reconstruct(union: HdmmUnion, domain: Domain, x: np.ndarray,
                             rng: np.random.Generator,
                             pcost_budget: float = 1.0) -> List[np.ndarray]:
    """y_j = A_j x + noise;  x̂_j = ⊗ A_i† y_j;  answers = W_j x̂_j.

    Materializes universe-sized intermediates exactly like HDMM's reconstruction
    (the paper's Table 3 shows this OOMs at d = 10, n = 10).
    """
    from repro.core.kron import kron_matvec_np
    d = domain.universe_size()
    if d > OOM_GUARD_ELEMS:
        raise MemoryError(f"HDMM reconstruction needs a {d}-element universe vector")
    answers = []
    x = np.asarray(x, dtype=np.float64).reshape(-1)
    for sub, share in zip(union.subs, union.shares):
        budget = share * pcost_budget
        dims = [w.shape[1] for w in sub.factors_W]
        m = int(np.prod([a.shape[0] for a in sub.factors_A]))
        y = kron_matvec_np(sub.factors_A, x, dims)
        y = y + rng.standard_normal(m) / math.sqrt(budget)
        pinvs = [np.linalg.pinv(a) for a in sub.factors_A]
        xhat = kron_matvec_np(pinvs, y, [a.shape[0] for a in sub.factors_A])
        answers.append(kron_matvec_np(sub.factors_W, xhat, dims))
    return answers
