"""Baselines the paper compares against: HDMM templates and the SVD lower bound."""
from .hdmm import (HdmmKron, HdmmUnion, hdmm_marginals, hdmm_generalized,
                   opt_pidentity, opt_pidentity_projected)
from .svdb import svd_bound_marginals, svd_bound_dense

__all__ = [n for n in dir() if not n.startswith("_")]
