import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the production mesh, the model, the full
sharding trees (params / optimizer state / caches / inputs), lowers the real
step (train_step for train shapes, prefill / decode_step for serving shapes),
compiles it, and records memory_analysis + cost_analysis + per-collective
byte counts parsed from the post-SPMD HLO into a JSON artifact that
roofline/analyze.py consumes.

Run one cell:    python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
Run everything:  python -m repro.launch.dryrun --all          (subprocess per cell)
"""
import argparse
import json
import math
import re
import subprocess
import sys
import time
import traceback
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS
from repro.configs.shapes import (SHAPES, TRAIN_MICROBATCHES, cell_is_applicable,
                                  input_specs)
from repro.launch.mesh import make_production_mesh
from repro.models import Model, get_config
from repro.models.config import ModelConfig
from repro.models.sharding import DEFAULT_RULES, sharding_rules, spec_for
from repro.models.transformer import cache_axes, cache_shape_structs
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes_from_hlo(hlo_text: str):
    """Sum output-buffer bytes of every collective op (per-device, post-SPMD)."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    pat = re.compile(
        r"=\s*(\([^)]*\)|[a-z0-9_]+\[[0-9,]*\][^ ]*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(-start)?\(")
    shape_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for m in pat.finditer(hlo_text):
        ty, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_pat.findall(ty):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
        counts[op] += 1
    # '-done' ops carry no new bytes; '-start' counted above.
    return out, counts


def _cost_dict(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _memory_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _axes_to_sharding(mesh, axes_tree, rules=None):
    return jax.tree_util.tree_map(
        lambda axes: spec_for(mesh, *axes, rules=rules), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def _opt_axes_like(param_axes, int8: bool):
    def one(axes):
        if int8:
            return {"q": axes, "s": axes[:-1] + (None,)}
        return axes
    moment = jax.tree_util.tree_map(
        one, param_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
    return {"m": moment, "v": moment, "count": ()}


def _pick_microbatches(target: int, global_batch: int, batch_shards: int) -> int:
    m = min(target, global_batch)
    while m > 1 and (global_batch // m) % batch_shards != 0:
        m //= 2
    return max(m, 1)


def shape_rules(shape: str, cfg: ModelConfig):
    """Per-shape logical-rule overrides (divisibility-safe; docs/DESIGN.md §6)."""
    rules = dict(DEFAULT_RULES)
    if shape == "long_500k":
        rules["batch"] = None                        # batch = 1
        rules["kv_seq"] = ("data", "model")          # shard the huge state/cache
        rules["heads"] = None
    if cfg.vocab_size % 16 != 0:
        # whisper (51865): vocab indivisible by the model axis → replicate the
        # (small) embedding/head instead of sharding them.
        rules["vocab"] = None
    return rules


def build_cell(arch: str, shape: str, multi_pod: bool,
               cache_dtype: str = "bfloat16",
               microbatches_override: int = 0):
    cfg = get_config(arch)
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    sh = SHAPES[shape]
    rules = shape_rules(shape, cfg)
    batch_shards = math.prod(
        mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names)

    specs = input_specs(arch, shape)
    pdt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    params_structs = model.param_shapes()
    params_shardings = _axes_to_sharding(mesh, model.param_axes(), rules)

    def in_shard_for(name):
        if name in ("tokens", "labels"):
            return spec_for(mesh, "batch", None, rules=rules)
        if name == "embeds":
            return spec_for(mesh, "batch", None, None, rules=rules)
        if name == "enc_embeds":
            return spec_for(mesh, "batch", None, None, rules=rules)
        if name == "pos":
            return spec_for(mesh, rules=rules)
        raise KeyError(name)

    if sh["kind"] == "train":
        opt_cfg = AdamWConfig(int8_states=(cfg.param_dtype == "bfloat16"))
        micro = _pick_microbatches(
            microbatches_override or TRAIN_MICROBATCHES.get(arch, 4),
            sh["global_batch"], batch_shards)
        step = make_train_step(model, opt_cfg, microbatches=micro, remat=True)
        opt_structs = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg),
                                     params_structs)
        state_structs = {"params": params_structs, "opt": opt_structs,
                         "rng": jax.ShapeDtypeStruct((2,), jnp.uint32)}
        opt_shardings = _axes_to_sharding(
            mesh, _opt_axes_like(model.param_axes(), opt_cfg.int8_states), rules)
        state_shardings = {"params": params_shardings, "opt": opt_shardings,
                           "rng": NamedSharding(mesh, P())}
        batch_structs = {k: specs[k] for k in specs}
        batch_shardings = {k: in_shard_for(k) for k in specs}
        fn = jax.jit(step, in_shardings=(state_shardings, batch_shardings),
                     out_shardings=(state_shardings, None),
                     donate_argnums=(0,))      # state buffers update in place
        args = (state_structs, batch_structs)
        extra = {"microbatches": micro, "optimizer_int8": opt_cfg.int8_states}
    elif sh["kind"] == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch)
        batch_structs = {k: specs[k] for k in specs}
        batch_shardings = {k: in_shard_for(k) for k in specs}
        fn = jax.jit(prefill, in_shardings=(params_shardings, batch_shardings),
                     out_shardings=None)
        args = (params_structs, batch_structs)
        extra = {}
    else:  # decode
        B, S = sh["global_batch"], sh["seq_len"]
        cdt = getattr(jnp, cache_dtype)
        cache_structs = cache_shape_structs(cfg, B, S, dtype=cdt)
        cache_shardings = _axes_to_sharding(mesh, cache_axes(cfg, B, S), rules)

        def decode(params, tokens, caches, pos):
            return model.decode_step(params, tokens, caches, pos)
        fn = jax.jit(decode,
                     in_shardings=(params_shardings,
                                   spec_for(mesh, "batch", None, rules=rules),
                                   cache_shardings,
                                   NamedSharding(mesh, P())),
                     out_shardings=(None, cache_shardings),
                     donate_argnums=(2,))      # caches update in place
        args = (params_structs, specs["tokens"], cache_structs,
                jax.ShapeDtypeStruct((), jnp.int32))
        extra = {}
    return cfg, model, mesh, rules, fn, args, extra


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """MODEL_FLOPS convention: 6·N_active·tokens (train) / 2·N_active·tokens
    (inference); attention flops excluded."""
    sh = SHAPES[shape]
    n_active = cfg.active_params_estimate()
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n_active * tokens
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * n_active * tokens
    tokens = sh["global_batch"]            # one new token per sequence
    return 2.0 * n_active * tokens


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             save_hlo: bool = False, cache_dtype: str = "bfloat16",
             microbatches_override: int = 0) -> dict:
    multi_pod = mesh_kind == "multi"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "chips": 512 if multi_pod else 256,
           "cache_dtype": cache_dtype}
    ok, why = cell_is_applicable(arch, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        t0 = time.time()
        cfg, model, mesh, rules, fn, args, extra = build_cell(
            arch, shape, multi_pod, cache_dtype=cache_dtype,
            microbatches_override=microbatches_override)
        rec.update(extra)
        with sharding_rules(mesh, rules):
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["cost_analysis"] = _cost_dict(compiled)
        rec["memory_analysis"] = _memory_dict(compiled)
        hlo = compiled.as_text()
        rec["hlo_chars"] = len(hlo)
        cb, cc = collective_bytes_from_hlo(hlo)
        rec["collective_bytes_per_device"] = cb
        rec["collective_counts"] = cc
        # loop-aware accounting (XLA cost_analysis counts while bodies once)
        from repro.roofline.hlo_stats import hlo_stats
        rec["hlo_stats"] = hlo_stats(hlo)
        rec["n_params"] = cfg.n_params_estimate()
        rec["n_active_params"] = cfg.active_params_estimate()
        rec["model_flops"] = model_flops(cfg, shape)
        rec["status"] = "ok"
        if save_hlo:
            with open(os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.hlo"),
                      "w") as f:
                f.write(hlo)
        print(f"[dryrun] {arch} {shape} {mesh_kind}: OK "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
        print("  memory_analysis:", rec["memory_analysis"])
        print("  cost_analysis flops:", rec["cost_analysis"].get("flops"))
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} {shape} {mesh_kind}: FAILED {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    # §Perf hillclimb knobs
    ap.add_argument("--cache-dtype", default="bfloat16",
                    choices=["bfloat16", "float8_e4m3fn"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--remat-policy", default="dots",
                    choices=["dots", "dots+kv", "nothing"])
    ap.add_argument("--attn-shard", default="seq", choices=["seq", "heads"])
    ap.add_argument("--suffix", default="")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        cells = [(a, s, m) for a in ARCH_IDS for s in SHAPES
                 for m in ("single", "multi")]
        for a, s, m in cells:
            path = os.path.join(args.out, f"{a}__{s}__{m}.json")
            if os.path.exists(path):
                with open(path) as fh:
                    st = json.load(fh).get("status")
                if st in ("ok", "skipped"):
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
                   "--shape", s, "--mesh", m, "--out", args.out]
            try:
                subprocess.run(cmd, timeout=args.timeout, check=False)
            except subprocess.TimeoutExpired:
                with open(path, "w") as f:
                    json.dump({"arch": a, "shape": s, "mesh": m,
                               "status": "timeout"}, f)
        return

    from repro.configs import load_all
    load_all()
    from repro.models.transformer import set_remat_policy
    set_remat_policy(args.remat_policy)
    from repro.models.layers import set_attn_sharding
    set_attn_sharding(args.attn_shard)
    rec = run_cell(args.arch, args.shape, args.mesh, args.out, args.save_hlo,
                   cache_dtype=args.cache_dtype,
                   microbatches_override=args.microbatches)
    rec["remat_policy"] = args.remat_policy
    rec["attn_shard"] = args.attn_shard
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{args.mesh}{args.suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
