"""Production mesh definitions.

Functions (not module-level constants) so importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips (v5e pod); multi-pod adds a
leading 'pod' axis: 2×16×16 = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1×N (data, model) mesh — used by
    CPU examples and smoke tests."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
