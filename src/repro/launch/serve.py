"""Release-server app layer: stand up the multi-tenant serving tier.

Wires a :class:`~repro.serve.server.ReleaseServer` (async queue + worker
loop, cross-tenant signature batching), a durable
:class:`~repro.serve.ledger.BudgetLedger` (JSONL journal, crash-recovery
replay), and the stdlib ``/stats`` / ``/ledger`` HTTP endpoints into one
runnable process.  See docs/SERVING.md for the tenant lifecycle and client
walkthrough; the historical LM decode-serving driver this module used to
host lives in ``examples/serve_lm.py``.

Run (demo traffic, then keep serving /stats until interrupted)::

    PYTHONPATH=src python -m repro.launch.serve --tenants 4 --requests 8 \
        --ledger /tmp/ledger.jsonl --port 8787

``--once`` exits after the demo traffic instead of serving forever.
``--trace PATH`` turns on request tracing (docs/OBSERVABILITY.md) and
appends the span tree of every served request to PATH as JSONL — render it
with ``python tools/repro_trace.py PATH``.  The HTTP listener also serves
Prometheus ``/metrics`` and a liveness-aware ``/healthz``.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import all_kway, select
from repro.data.tabular import (adult_domain, marginals_from_records,
                                synthetic_records)
from repro.obs import TRACER
from repro.serve import (BudgetLedger, ReleaseRequest, ReleaseServer,
                         start_stats_http)


def build_server(ledger_path: str, n_tenants: int = 4, rho: float = 4.0,
                 max_batch: int = 16, max_wait_ms: float = 2.0,
                 kway: int = 2) -> ReleaseServer:
    """A server with ``n_tenants`` tenants sharing one workload *shape*.

    Every tenant gets its own plan object, its own synthetic records, and its
    own ρ budget — but the per-axis signatures coincide, so concurrent
    requests fuse into shared chain launches (docs/DESIGN.md §13).
    """
    dom = adult_domain()
    ledger = BudgetLedger(ledger_path)
    server = ReleaseServer(ledger, max_batch=max_batch,
                           max_wait_ms=max_wait_ms)
    server.start()
    for t in range(n_tenants):
        wk = all_kway(dom, kway, include_lower=True)
        plan = select(wk, pcost_budget=1.0)
        server.register_tenant(f"tenant-{t}", plan, rho=rho)
    return server


def demo_traffic(server: ReleaseServer, requests_per_tenant: int = 4,
                 n_records: int = 60_000) -> dict:
    """Submit release traffic from every tenant; returns summary metrics."""
    dom = adult_domain()
    futures = []
    t0 = time.monotonic()
    for i, tenant in enumerate(server.tenants()):
        plan = server._sessions[tenant].plan
        records = synthetic_records(dom, n_records, seed=i)
        margs = marginals_from_records(dom, plan.cliques, records)
        for _r in range(requests_per_tenant):
            futures.append(server.submit(
                ReleaseRequest(tenant=tenant, marginals=margs)))
    results = [f.result(timeout=300) for f in futures]
    wall = time.monotonic() - t0
    return {"requests": len(results), "wall_s": wall,
            "requests_per_s": len(results) / max(wall, 1e-9),
            "batched_fraction": sum(r.batched for r in results) / len(results)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ledger", default="/tmp/repro_ledger.jsonl",
                    help="JSONL journal path (replayed if it exists)")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--rho", type=float, default=4.0,
                    help="per-tenant zCDP budget")
    ap.add_argument("--requests", type=int, default=4,
                    help="demo requests per tenant")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--port", type=int, default=0,
                    help="stats HTTP port (0 = ephemeral)")
    ap.add_argument("--once", action="store_true",
                    help="exit after the demo traffic (no serve-forever)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="append request span trees to PATH as JSONL "
                         "(render with tools/repro_trace.py)")
    args = ap.parse_args()

    if args.trace:
        TRACER.enable(args.trace)
    server = build_server(args.ledger, args.tenants, rho=args.rho,
                          max_batch=args.max_batch)
    httpd, port = start_stats_http(server, port=args.port)
    print(f"[serve] {args.tenants} tenants registered; "
          f"ledger={args.ledger} (replayed "
          f"{server.ledger.replayed_records} records); "
          f"stats on http://127.0.0.1:{port}/stats, "
          f"metrics on /metrics"
          + (f"; tracing to {args.trace}" if args.trace else ""))
    summary = demo_traffic(server, args.requests)
    print(f"[serve] demo traffic: {json.dumps(summary)}")
    print("[serve] ledger:", json.dumps(server.ledger.report(), default=str))
    if args.once:
        httpd.shutdown()
        server.stop()
        if args.trace:
            TRACER.flush()
        return
    print("[serve] serving /stats until interrupted (ctrl-C)")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        httpd.shutdown()
        server.stop()


if __name__ == "__main__":
    main()
