"""Production training driver: mesh-aware pjit train loop with checkpointing,
preemption-safe resume, straggler watchdog, optional DP-SGD, and the DP
corpus-statistics release wired in.

On the CPU container this runs reduced configs end-to-end (see
examples/train_lm.py); on a real pod the same driver takes --arch <id> and
the production mesh.
"""
from __future__ import annotations

import argparse
import os
import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, load_all
from repro.configs.shapes import reduced_config
from repro.data.tokens import synthetic_lm_batches
from repro.models import Model, get_config
from repro.models.sharding import sharding_rules
from repro.train import AdamWConfig, DPSGDConfig, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.dp import DPSGDAccountant
from repro.train.train_step import init_train_state


class StragglerWatchdog:
    """Logs steps whose wall time exceeds mean + k·std of the trailing window
    (on real clusters this feeds the reschedule/hot-spare path; on CPU it
    simply reports)."""

    def __init__(self, window: int = 20, k: float = 3.0):
        self.times, self.window, self.k = [], window, k
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:-1]
        if len(hist) >= 5:
            mu, sd = np.mean(hist), np.std(hist) + 1e-9
            if dt > mu + self.k * sd:
                self.flagged += 1
                print(f"[watchdog] straggler step: {dt:.3f}s vs μ={mu:.3f}s")
                return True
        return False


def train_loop(cfg, *, steps: int, batch_size: int, seq_len: int,
               ckpt_dir: str, resume: bool, dp: DPSGDConfig | None,
               microbatches: int, ckpt_every: int, mesh=None,
               log_every: int = 10, seed: int = 0):
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20,
                          int8_states=(cfg.param_dtype == "bfloat16"))
    step_fn = jax.jit(make_train_step(model, opt_cfg, microbatches=microbatches,
                                      dp=dp, remat=False))
    mgr = CheckpointManager(ckpt_dir, keep=3)
    state = init_train_state(model, jax.random.PRNGKey(seed), opt_cfg)
    start = 0
    if resume and mgr.latest_step() is not None:
        state, manifest = mgr.restore(state)
        start = manifest["step"]
        print(f"[train] resumed from step {start}")
    acct = DPSGDAccountant(dp) if dp else None
    gen = synthetic_lm_batches(cfg.vocab_size, batch_size, seq_len, seed=seed)
    wd = StragglerWatchdog()
    losses = []
    with sharding_rules(mesh):
        for it in range(start, steps):
            b = next(gen)
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "labels": jnp.asarray(b["labels"])}
            if cfg.frontend == "embed_stub":
                batch = {"embeds": jax.random.normal(
                            jax.random.PRNGKey(it),
                            (batch_size, seq_len, cfg.d_model), jnp.float32),
                         "labels": batch["labels"]}
            if cfg.encoder_layers:
                batch["enc_embeds"] = jnp.zeros(
                    (batch_size, cfg.encoder_seq, cfg.d_model), jnp.float32)
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            wd.observe(time.time() - t0)
            if acct:
                acct.charge_step()
            losses.append(loss)
            if it % log_every == 0:
                msg = f"[train] step {it} loss {loss:.4f}"
                if acct:
                    r = acct.report()
                    msg += (f" | dp: ρ={r['rho_zcdp']:.4f} "
                            f"ε(δ=1e-6)={r['eps_at_delta_1e-6']:.2f}")
                print(msg, flush=True)
            if ckpt_every and it and it % ckpt_every == 0:
                mgr.save(it, state, {"arch": cfg.name, "loss": loss},
                         blocking=False)
    mgr.save(steps, state, {"arch": cfg.name, "loss": losses[-1]})
    mgr.wait()
    return state, losses


def main():
    load_all()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced same-family config (CPU container)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="DP-SGD noise multiplier (0 = off)")
    args = ap.parse_args()
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    dp = DPSGDConfig(noise_multiplier=args.dp_noise) if args.dp_noise else None
    _, losses = train_loop(cfg, steps=args.steps, batch_size=args.batch,
                           seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                           resume=args.resume, dp=dp,
                           microbatches=args.microbatches,
                           ckpt_every=args.ckpt_every)
    print(f"[train] done: loss {losses[0]:.4f} → {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
