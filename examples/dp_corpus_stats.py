"""Plane A + Plane B integration: release DP marginals over training-corpus
document attributes while DP-SGD training shares the same privacy budget.

Run:  PYTHONPATH=src python examples/dp_corpus_stats.py
"""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Domain, MarginalWorkload, PrivacyBudget
from repro.data.tokens import synthetic_lm_batches
from repro.engine.corpus_stats import corpus_marginal_release
from repro.train.dp import DPSGDAccountant, DPSGDConfig


def main():
    budget = PrivacyBudget.from_zcdp(rho=2.0)   # total pcost 4.0
    dom = Domain.create([8, 8], names=["source", "len_bucket"])
    wk = MarginalWorkload(dom, ((0,), (1,), (0, 1)))

    gen = synthetic_lm_batches(1000, batch=512, seq_len=8, seed=0)
    recs = np.concatenate([next(gen)["doc_attrs"] for _ in range(4)], axis=0)

    tables, variances, report = corpus_marginal_release(
        dom, wk, jnp.asarray(recs), budget, pcost=1.0,
        key=jax.random.PRNGKey(0))
    print("noisy source×length marginal (first row):",
          np.round(tables[(0, 1)].reshape(8, 8)[0], 1))
    print("per-marginal variances:", {k: round(v, 3) for k, v in variances.items()})
    print("after release:", report)

    acct = DPSGDAccountant(DPSGDConfig(noise_multiplier=1.0), budget)
    steps = 0
    with contextlib.suppress(ValueError):  # charge until the budget refuses
        while True:
            acct.charge_step()
            steps += 1
    print(f"remaining budget funds {steps} DP-SGD steps at sigma=1.0")
    print("final:", acct.report())


if __name__ == "__main__":
    main()
