"""Section 6.2 reproduction: how the weighted-SoV objective treats individual
marginals under equi / cell-size / sqrt weighting (paper Figs 1-3).

Run:  PYTHONPATH=src python examples/cell_fairness.py
"""
import numpy as np

from repro.core import all_kway, select_sum_of_variances
from repro.data.tabular import adult_domain


def main():
    dom = adult_domain()
    wk = all_kway(dom, 3, include_lower=True)
    for scheme in ("equi", "cells", "sqrt_cells"):
        wks = wk.reweighted(scheme)
        plan = select_sum_of_variances(wks, 1.0, dict(wks.weights))
        print(f"\n== weighting: {scheme} ==")
        by_k = {}
        for c, v in plan.workload_variances().items():
            by_k.setdefault(len(c), []).append((dom.n_cells(c), v))
        for k in sorted(by_k):
            vs = [v for _, v in by_k[k]]
            print(f"  {k}-way: var range [{min(vs):.4g}, {max(vs):.4g}] "
                  f"({len(vs)} marginals)")
        allv = [v for vs in by_k.values() for _, v in vs]
        print(f"  spread across marginals: {max(allv)/min(allv):.1f}x "
              f"(paper: equi is the most even)")


if __name__ == "__main__":
    main()
