"""Quickstart: the paper's full pipeline on a synthetic Adult-like dataset.

select (optimal noise plan) -> measure (Alg 1; optionally hardened discrete
Gaussian, Alg 3) -> reconstruct (Alg 2) -> confidence intervals from the
closed-form variances (Thm 4).

Run:  PYTHONPATH=src python examples/quickstart.py [--discrete]
"""
import argparse
import math
import random

import numpy as np
import jax

from repro.core import (MarginalWorkload, PrivacyBudget, all_kway,
                        pcost_of_plan, reconstruct_all, select)
from repro.core.discrete import measure_discrete
from repro.core.mechanism import measure_np
from repro.data.tabular import adult_domain, marginals_from_records, synthetic_records
from repro.engine.sharded import sharded_measure


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--discrete", action="store_true",
                    help="use the hardened discrete-Gaussian path (Alg 3)")
    ap.add_argument("--objective", default="sum_of_variances",
                    choices=["sum_of_variances", "max_variance"])
    args = ap.parse_args()

    dom = adult_domain()
    wk = all_kway(dom, 2, include_lower=True)          # all <=2-way marginals
    print(f"domain: {dom.n_attrs} attrs, universe {dom.universe_size():.2e}")
    print(f"workload: {len(wk.cliques)} marginals, {wk.total_cells()} cells")

    # 1) SELECT: optimal noise scales at total privacy cost 1 (0.5-zCDP)
    plan = select(wk, pcost_budget=1.0, objective=args.objective)
    print(f"selected {len(plan.cliques)} base mechanisms; "
          f"pcost={pcost_of_plan(plan):.6f} rmse={plan.rmse():.3f}")

    # 2) MEASURE on synthetic records
    records = synthetic_records(dom, 100_000, seed=0)
    margs = marginals_from_records(dom, plan.cliques, records)
    if args.discrete:
        meas = measure_discrete(plan, margs, random.Random(0))
        print("measured with exact discrete Gaussian noise (Alg 3)")
    else:
        meas = measure_np(plan, margs, np.random.default_rng(0))

    # 3) RECONSTRUCT + 95% CIs from closed-form variances
    tables = reconstruct_all(plan, meas)
    shown = 0
    for c in wk.cliques:
        if len(c) != 2 or shown >= 3:
            continue
        sd = math.sqrt(plan.marginal_variance(c))
        true = marginals_from_records(dom, [c], records)[c]
        cover = np.mean(np.abs(tables[c] - true) <= 1.96 * sd)
        print(f"marginal {c}: cells={len(true)} sd={sd:.2f} "
              f"95%CI coverage={cover:.3f}")
        shown += 1
    budget = PrivacyBudget.from_zcdp(0.5)
    budget.charge(pcost_of_plan(plan))
    print("privacy report:", budget.report())


if __name__ == "__main__":
    main()
