"""Quickstart: the paper's full pipeline on a synthetic Adult-like dataset.

select (optimal noise plan) -> measure (Alg 1; optionally hardened discrete
Gaussian, Alg 3) -> reconstruct (Alg 2) -> confidence intervals from the
closed-form variances (Thm 4).

``--plus`` instead runs the ResidualPlanner+ pipeline (§7, Algs 4–6) on a
range-query workload — every numeric attribute answers all contiguous-range
queries, served through the signature-batched ``PlusEngine``.

Run:  PYTHONPATH=src python examples/quickstart.py [--discrete | --plus]
"""
import argparse
import math

import numpy as np
import jax

from repro.core import (MarginalWorkload, PrivacyBudget, all_kway,
                        pcost_of_plan, reconstruct_all, select)
from repro.core.mechanism import measure_np
from repro.data.tabular import adult_domain, marginals_from_records, synthetic_records
from repro.engine.sharded import sharded_measure


def main_plus():
    """Range queries via ResidualPlanner+: select_plus -> PlusEngine."""
    from repro.core import Domain
    from repro.core.plus import PlusSchema, select_plus
    from repro.engine import PlusEngine

    # 4 attributes; the first two are numeric and answer ALL contiguous
    # ranges (n(n+1)/2 queries per axis), the rest are plain marginals.
    dom = Domain.create([16, 12, 5, 3], kinds=["numeric", "numeric",
                                               "categorical", "categorical"])
    wk = all_kway(dom, 2, include_lower=True)
    schema = PlusSchema.create(dom, ["range", "range", "identity", "identity"],
                               strategy_mode="hier")
    plan = select_plus(wk, schema, pcost_budget=1.0, objective="sov")
    print(f"RP+ plan: {len(plan.cliques)} base mechanisms, "
          f"rmse={plan.rmse():.3f} pcost={plan.pcost:.6f}")

    records = synthetic_records(dom, 50_000, seed=0)
    margs = marginals_from_records(dom, plan.cliques, records)

    engine = PlusEngine(plan)        # chains compiled once at construction
    tables, meas = engine.release(margs, jax.random.PRNGKey(0))

    # the (0, 1) table now answers every range × range query pair
    c = (0, 1)
    n_ranges = [dom.attributes[i].size * (dom.attributes[i].size + 1) // 2
                for i in c]
    print(f"marginal {c}: {tables[c].shape[0]} = {n_ranges[0]}x{n_ranges[1]} "
          f"range-pair answers, sov={plan.sov(c):.3f}")
    budget = PrivacyBudget.from_zcdp(0.5)
    budget.charge(plan.pcost)
    print("privacy report:", budget.report())


def main_serve():
    """Multi-tenant serving smoke: server + ledger, 3 tenant requests.

    The in-process tour of docs/SERVING.md: register three tenants with
    their own budgets, submit one fused batch of release requests, then a
    zero-charge synthesis, and print the ledger report.
    """
    import os
    import tempfile

    from repro.core import Domain
    from repro.serve import BudgetLedger, ReleaseRequest, ReleaseServer

    dom = Domain.create([8, 8, 8, 8])
    wk = all_kway(dom, 2, include_lower=True)
    ledger_path = os.path.join(tempfile.mkdtemp(prefix="quickstart_serve_"),
                               "budgets.jsonl")
    ledger = BudgetLedger(ledger_path)
    tenants = ("acme", "globex", "initech")

    with ReleaseServer(ledger, max_batch=8) as server:
        plans = {}
        for name in tenants:
            plans[name] = select(wk, pcost_budget=1.0)
            server.register_tenant(name, plans[name], rho=0.5)
        print(f"registered {len(tenants)} tenants, ledger at {ledger_path}")

        server.pause()                       # let the batch fill, then fuse
        futures = []
        for i, name in enumerate(tenants):
            recs = synthetic_records(dom, 20_000, seed=i)
            margs = marginals_from_records(dom, plans[name].cliques, recs)
            futures.append(server.submit(ReleaseRequest(
                tenant=name, marginals=margs, postprocess="nonneg")))
        server.resume()
        for fut in futures:
            r = fut.result(timeout=300)
            print(f"  {r.tenant}: {len(r.tables)} tables, "
                  f"charged pcost={r.pcost_charged:.4f}, "
                  f"batched={r.batched} (batch of {r.batch_size}), "
                  f"{r.latency_s * 1e3:.0f} ms")

        synth = server.request_sync(ReleaseRequest(
            tenant="acme", kind="synthesis", n_records=1000, seed=7))
        print(f"  acme synthesis: {synth.records.shape[0]} records, "
              f"charged pcost={synth.pcost_charged} (postprocessing)")

        stats = server.stats_dict()
        print(f"server: {stats['requests_total']} requests, "
              f"batch occupancy {stats['batch_occupancy']:.1f}, "
              f"engine-cache hit rate {stats['engine_cache']['hit_rate']:.2f}")
        print("ledger report:")
        for name, rep in server.ledger.report().items():
            print(f"  {name}: spent pcost {rep['pcost_spent']:.4f} of "
                  f"{rep['pcost_total']:.1f}, remaining rho "
                  f"{rep['rho_remaining']:.4f}, {rep['charges']} charges")
    ledger.close()
    replay = BudgetLedger(ledger_path)
    print(f"ledger replay: {replay.replayed_records} journal records, "
          f"spend survives restart: "
          f"{all(replay.spent(t) > 0 for t in tenants)}")
    replay.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--discrete", action="store_true",
                    help="use the hardened discrete-Gaussian path (Alg 3)")
    ap.add_argument("--plus", action="store_true",
                    help="ResidualPlanner+ range-query pipeline (PlusEngine)")
    ap.add_argument("--serve", action="store_true",
                    help="multi-tenant release server smoke: in-process "
                         "server, 3 tenant requests, durable ledger report "
                         "(docs/SERVING.md)")
    ap.add_argument("--objective", default="sum_of_variances",
                    choices=["sum_of_variances", "max_variance", "convex"])
    ap.add_argument("--variances", action="store_true",
                    help="batched per-marginal variance + covariance report "
                         "from the PlanTable IR (one segment-sum each)")
    ap.add_argument("--synth", type=int, default=0, metavar="N",
                    help="release subsystem demo (DESIGN.md §11): "
                         "consistency -> local non-negativity -> N synthetic "
                         "records (combine with --discrete for the secure "
                         "path with integer-exact totals)")
    args = ap.parse_args()
    if args.plus:
        return main_plus()
    if args.serve:
        return main_serve()

    dom = adult_domain()
    wk = all_kway(dom, 2, include_lower=True)          # all <=2-way marginals
    print(f"domain: {dom.n_attrs} attrs, universe {dom.universe_size():.2e}")
    print(f"workload: {len(wk.cliques)} marginals, {wk.total_cells()} cells")

    # 1) SELECT: optimal noise scales at total privacy cost 1 (0.5-zCDP)
    plan = select(wk, pcost_budget=1.0, objective=args.objective)
    print(f"selected {len(plan.cliques)} base mechanisms; "
          f"pcost={pcost_of_plan(plan):.6f} rmse={plan.rmse():.3f}")

    if args.variances:
        # Thm-4 machinery off the PlanTable IR: every workload marginal's
        # variance in ONE segment-sum, cross-marginal covariances batched.
        var = plan.variances_array()
        order = np.argsort(var)
        print(f"batched variances over {len(var)} marginals: "
              f"min={var.min():.3f} median={np.median(var):.3f} "
              f"max={var.max():.3f}")
        for i in (*order[:2], *order[-2:]):
            print(f"  Var[{wk.cliques[i]}] = {var[i]:.4f}")
        twoway = [c for c in wk.cliques if len(c) == 2]
        pairs = [(a, b) for a in twoway[:6] for b in twoway[:6]
                 if set(a) & set(b) and a != b][:4]
        covs = plan.workload_covariances(pairs)
        for (a, b), cv in zip(pairs, covs):
            print(f"  Cov[{a}, {b}] (aligned cells) = {cv:.4f}")

    # 2) MEASURE on synthetic records
    records = synthetic_records(dom, 100_000, seed=0)
    margs = marginals_from_records(dom, plan.cliques, records)
    if args.discrete:
        # secure release path (Alg 3) at engine tier: signature-batched
        # fused H/Y-dagger chains, batched integer-lane noise (DESIGN.md §10)
        from repro.core.discrete import discrete_pcost_of_plan
        engine = plan.engine(secure=True)
        meas = engine.measure(margs, jax.random.PRNGKey(0))
        print(f"measured with exact discrete Gaussian noise (Alg 3): "
              f"{engine.stats.measure_signatures} signature groups, "
              f"{engine.stats.device_h_groups} H groups on device, "
              f"{engine.stats.exact_h_groups} on the exact-int tier")
        print(f"discrete pcost actually spent: "
              f"{discrete_pcost_of_plan(plan):.6f} "
              f"(continuous: {pcost_of_plan(plan):.6f})")
    else:
        meas = measure_np(plan, margs, np.random.default_rng(0))

    # 3) RECONSTRUCT + 95% CIs from closed-form variances
    tables = reconstruct_all(plan, meas)
    shown = 0
    for c in wk.cliques:
        if len(c) != 2 or shown >= 3:
            continue
        sd = math.sqrt(plan.marginal_variance(c))
        true = marginals_from_records(dom, [c], records)[c]
        cover = np.mean(np.abs(tables[c] - true) <= 1.96 * sd)
        print(f"marginal {c}: cells={len(true)} sd={sd:.2f} "
              f"95%CI coverage={cover:.3f}")
        shown += 1
    budget = PrivacyBudget.from_zcdp(0.5)
    if args.discrete:
        # the secure path spends the exact discrete pcost (<= continuous)
        from repro.core.discrete import discrete_pcost_of_plan
        budget.charge(discrete_pcost_of_plan(plan))
    else:
        budget.charge(pcost_of_plan(plan))
    print("privacy report:", budget.report())

    # 4) RELEASE SUBSYSTEM (--synth N): covariance-weighted consistency ->
    #    local non-negativity -> vectorized synthetic records (DESIGN.md §11)
    if args.synth:
        from repro.release import synth_report
        engine = plan.engine(secure=args.discrete, use_kernel=False,
                             precompile=False)
        tables_nn, meas2 = engine.release(margs, jax.random.PRNGKey(1),
                                          postprocess="nonneg")
        total = float(tables_nn[wk.cliques[0]].sum())
        neg_raw = sum(int((reconstruct_all(plan, meas2)[c] < 0).sum())
                      for c in wk.cliques)
        print(f"postprocess=nonneg: {neg_raw} negative cells in the raw "
              f"release -> 0 after projection; common total "
              f"{total:.1f}" + (" (integer-exact, pinned to the measured "
                                "count)" if args.discrete else " (fitted)"))
        records_s = engine.synthesize(args.synth, jax.random.PRNGKey(2))
        report = synth_report(dom, tables_nn, records_s, total=total)
        print(f"synthesized {records_s.shape[0]} records over "
              f"{dom.n_attrs} attributes; {report.summary()}")


if __name__ == "__main__":
    main()
