"""Batched LM serving example: prefill + greedy decode with KV/state caches.

Self-contained legacy driver for the seed's LM scaffolding (models/, configs/)
— the ``repro.launch.serve`` module now hosts the *release* server app layer
(docs/SERVING.md); this example keeps the decode-loop path runnable.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --gen 12
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, load_all
from repro.configs.shapes import reduced_config
from repro.models import Model


def serve_batch(cfg, prompts: np.ndarray, gen_tokens: int, seed: int = 0):
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    B, S = prompts.shape
    cache_len = S + gen_tokens
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend == "embed_stub":
        batch = {"embeds": jax.random.normal(jax.random.PRNGKey(1),
                                             (B, S, cfg.d_model), jnp.float32)}
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                        jnp.float32)
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(model.decode_step)
    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [np.asarray(tok)]
    t1 = time.time()
    for i in range(gen_tokens - 1):
        logits, caches = decode(params, tok, caches, jnp.asarray(S + i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t2 = time.time()
    toks = np.concatenate(out, axis=1)
    return toks, {"prefill_s": t1 - t0,
                  "decode_tok_per_s": B * (gen_tokens - 1) / max(t2 - t1, 1e-9)}


def main():
    load_all()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = reduced_config(args.arch)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    toks, stats = serve_batch(cfg, prompts, args.gen)
    print(f"[serve] {args.arch}: generated {toks.shape} tokens; {stats}")


if __name__ == "__main__":
    main()
