"""Batched serving example: prefill + greedy decode with KV/state caches.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --gen 12
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
