"""End-to-end LM training driver with DP-SGD priced by the paper's accountant.

Reduced same-family config on CPU; on TPU pods drop --reduced and pick a mesh.

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen3-4b --steps 60 --dp-noise 1.0
"""
import sys
from repro.launch.train import main

if __name__ == "__main__":
    main()
