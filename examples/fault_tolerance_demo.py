"""Fault-tolerance demo: train, simulate a node failure mid-run, resume from
the latest atomic checkpoint, and verify the loss trajectory continues; then
restore the same checkpoint onto a *different* mesh (elastic re-mesh).

Run:  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import shutil

import jax
import numpy as np

from repro.configs.shapes import reduced_config
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train_loop
from repro.models import Model
from repro.train import AdamWConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import init_train_state

CKPT = "artifacts/ft_demo_ckpt"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = reduced_config("qwen3-4b")

    print("== phase 1: train 30 steps, checkpoint every 10 ==")
    train_loop(cfg, steps=30, batch_size=4, seq_len=32, ckpt_dir=CKPT,
               resume=False, dp=None, microbatches=1, ckpt_every=10)

    print("\n== simulated failure: process dies; restart with --resume ==")
    _, losses = train_loop(cfg, steps=45, batch_size=4, seq_len=32,
                           ckpt_dir=CKPT, resume=True, dp=None,
                           microbatches=1, ckpt_every=10)
    print(f"resumed and reached loss {losses[-1]:.4f}")

    print("\n== elastic re-mesh: restore checkpoint onto a fresh mesh ==")
    model = Model(cfg)
    oc = AdamWConfig()
    state_like = init_train_state(model, jax.random.PRNGKey(0), oc)
    mgr = CheckpointManager(CKPT)
    mesh = make_host_mesh()
    from repro.models.sharding import spec_for
    shardings = jax.tree_util.tree_map(lambda _: spec_for(mesh), state_like)
    restored, manifest = mgr.restore(state_like, shardings=shardings)
    print(f"restored step {manifest['step']} onto mesh {mesh.shape} — "
          f"params on {len(jax.devices())} device(s)")
    print("fault-tolerance demo OK")


if __name__ == "__main__":
    main()
