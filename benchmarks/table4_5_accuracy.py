"""Paper Tables 4 & 5: RMSE vs the SVD bound (optimality sanity) and max
variance, on the Adult / CPS / Loans schemas at pcost = 1."""
from __future__ import annotations

from repro.core import Domain, all_kway, select_max_variance, select_sum_of_variances
from repro.baselines.svdb import svdb_rmse_marginals
from repro.data.tabular import ADULT_SIZES, CPS_SIZES, LOANS_SIZES
from .common import emit, timeit

PAPER4 = {"adult": {1: 3.047, 2: 6.359, 3: 10.515, "le3": 10.665},
          "cps": {1: 1.744, 2: 2.035, 3: 2.048, "le3": 2.276},
          "loans": {1: 2.875, 2: 5.634, 3: 8.702, "le3": 8.876}}
PAPER5 = {"adult": {1: 12.047, 2: 67.802, 3: 236.843, "le3": 253.605},
          "cps": {1: 4.346, 2: 7.897, 3: 7.706, "le3": 13.216},
          "loans": {1: 10.640, 2: 52.217, 3: 156.638, "le3": 180.817}}


def run(fast: bool = True):
    for name, sizes in [("adult", ADULT_SIZES), ("cps", CPS_SIZES),
                        ("loans", LOANS_SIZES)]:
        dom = Domain.create(sizes)
        for key in (1, 2, 3, "le3"):
            k, lower = (3, True) if key == "le3" else (key, False)
            wk = all_kway(dom, k, include_lower=lower)
            cells = {c: float(dom.n_cells(c)) for c in wk.cliques}
            t = timeit(lambda: select_sum_of_variances(wk, 1.0, cells))
            plan = select_sum_of_variances(wk, 1.0, cells)
            emit(f"table4/rmse/{name}/{key}way", t,
                 f"ours={plan.rmse():.3f} svdb={svdb_rmse_marginals(wk):.3f} "
                 f"paper={PAPER4[name][key]}")
            t = timeit(lambda: select_max_variance(wk, 1.0, iters=4000), repeats=1)
            mv = select_max_variance(wk, 1.0, iters=6000)
            emit(f"table5/maxvar/{name}/{key}way", t,
                 f"ours={mv.max_variance():.3f} paper={PAPER5[name][key]}")
