"""Section 5 benchmarks: discrete-Gaussian measurement overhead and the
Example-2 privacy blow-up factor of the naive swap."""
from __future__ import annotations

import random

import numpy as np

from repro.core import Domain, MarginalWorkload, all_kway, select_sum_of_variances
from repro.core.discrete import measure_discrete, naive_discrete_rho
from repro.core.mechanism import measure_np, pcost_of_plan
from repro.data.tabular import cps_domain
from .common import emit, timeit


def run(fast: bool = True):
    dom = cps_domain()
    wk = all_kway(dom, 2, include_lower=True)
    plan = select_sum_of_variances(wk, 1.0)
    margs = {c: np.zeros(dom.n_cells(c)) for c in plan.cliques}
    nrng = np.random.default_rng(0)
    t_cont = timeit(lambda: measure_np(plan, margs, nrng), repeats=1)
    emit("discrete/continuous_measure/cps_le2", t_cont, "Alg 1")
    rng = random.Random(0)
    t_disc = timeit(lambda: measure_discrete(plan, margs, rng), repeats=1)
    emit("discrete/discrete_measure/cps_le2", t_disc,
         f"Alg 3 exact sampler; overhead={t_disc / max(t_cont, 1e-9):.0f}x")
    # Example 2 blow-up across k (per k-way base mechanism on binary attrs)
    from repro.core.residual import p_coeff
    for k in (1, 2, 3, 6):
        dom2 = Domain.create([2] * k)
        top = tuple(range(k))
        ratio = 1.0 / p_coeff(dom2, top)   # naive rho / Alg-3 rho for M_top
        emit(f"discrete/naive_blowup/k={k}", 0.0,
             f"naive/alg3_rho={ratio:.1f} (paper Example 2: 2^k = {2**k})")
