"""Shared benchmark utilities.  Output format: ``name,us_per_call,derived``.

``emit`` also records a structured row (plus any keyword metrics) so
``run.py --json`` can dump machine-readable results (BENCH_kernels.json).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

ROWS = []
JSON_ROWS = []


def emit(name: str, us_per_call: float, derived: str = "", **metrics):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    JSON_ROWS.append(dict(name=name, us_per_call=round(float(us_per_call), 1),
                          derived=derived, **metrics))
    print(row, flush=True)


def timeit(fn: Callable, repeats: int = 3, warmup: int = 0) -> float:
    """Median wall time in µs."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


class ZeroRng:
    def standard_normal(self, n):
        import numpy as np
        return np.zeros(n)
