"""Shared benchmark utilities.  Output format: ``name,us_per_call,derived``."""
from __future__ import annotations

import time
from typing import Callable, Optional

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn: Callable, repeats: int = 3, warmup: int = 0) -> float:
    """Median wall time in µs."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


class ZeroRng:
    def standard_normal(self, n):
        import numpy as np
        return np.zeros(n)
