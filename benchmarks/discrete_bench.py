"""Secure release path benchmarks (Section 5 / Alg 3, docs/DESIGN.md §10):
batched integer-lane sampler vs the serial Fraction sampler, big-γ²
completion, and the DiscreteEngine's fused H/Y† measure vs the per-clique
host reference.  Gated in CI (discrete-bench job): the batched sampler must
hold a ≥10× per-sample speedup at γ² ~ 10⁶."""
from __future__ import annotations

import math
import random
from fractions import Fraction

import numpy as np

from repro.core import Domain, MarginalWorkload, all_kway, select_sum_of_variances
from repro.core import dgauss
from repro.core.discrete import (measure_discrete, rationalize_sigma,
                                 sample_discrete_gaussian)
from repro.data.tabular import cps_domain
from .common import emit, timeit


def _sampler_rows(fast: bool) -> None:
    # γ² ~ 10⁶ with a realistic rationalized σ̄ (denominator from digits=4)
    sigma_bar = rationalize_sigma(math.sqrt(2.37))
    gamma2 = sigma_bar ** 2 * 1000 ** 2
    lanes = 4096 if fast else 16384
    n_serial = 40 if fast else 200

    srng = random.Random(0)
    t_serial = timeit(lambda: [sample_discrete_gaussian(gamma2, srng)
                               for _ in range(n_serial)], repeats=1) / n_serial
    emit("discrete/sampler_serial/g2_1e6", t_serial,
         f"CKS Fraction sampler, per sample ({n_serial} draws)")

    nrng = np.random.default_rng(0)
    dgauss.sample(gamma2, 256, nrng)              # warm allocator
    t_batched = timeit(lambda: dgauss.sample(gamma2, lanes, nrng),
                       repeats=3) / lanes
    speedup = t_serial / max(t_batched, 1e-9)
    emit("discrete/sampler_batched/g2_1e6", t_batched,
         f"int64 lanes x{lanes}; speedup={speedup:.1f}x vs serial",
         sampler_speedup_vs_serial=round(speedup, 1), lanes=lanes)

    # Πn_i = 10²⁰-scale γ² (≥ 10⁴⁰): big-int lanes, must simply complete —
    # the seed-era float-sqrt path raised OverflowError here.
    g2_big = Fraction(17 * 10 ** 40, 4)
    t_big = timeit(lambda: dgauss.sample(g2_big, 256, nrng), repeats=1) / 256
    emit("discrete/sampler_bigint/g2_1e40", t_big,
         "object lanes x256 at gamma2 >= 1e40 (PIn_i ~ 1e20)",
         completes_at_1e40=True)


def _measure_rows(fast: bool) -> None:
    import jax
    dom = cps_domain()
    wk = all_kway(dom, 2, include_lower=True)
    plan = select_sum_of_variances(wk, 1.0)
    margs = {c: np.zeros(dom.n_cells(c)) for c in plan.cliques}

    srng = random.Random(0)
    t_ref = timeit(lambda: measure_discrete(plan, margs, srng,
                                            sampler="legacy"), repeats=1)
    emit("discrete/measure_reference/cps_le2", t_ref,
         "per-clique kron_matvec_np + serial sampler (host oracle)")

    eng = plan.engine(secure=True)                # chains compiled once
    key = jax.random.PRNGKey(0)
    eng.measure(margs, key)                       # warm jit caches
    # Count real kron_matvec_np traffic during the timed serve: the "no
    # per-clique host oracle on the hot path" claim is measured, not asserted.
    import repro.core.kron as kron_mod
    calls = {"n": 0}
    orig_kron_np = kron_mod.kron_matvec_np
    def _counting(*a, **k):                       # noqa: E306
        calls["n"] += 1
        return orig_kron_np(*a, **k)
    kron_mod.kron_matvec_np = _counting
    try:
        t_eng = timeit(lambda: eng.measure(margs, key), repeats=3)
    finally:
        kron_mod.kron_matvec_np = orig_kron_np
    speedup = t_ref / max(t_eng, 1e-9)
    chains = eng.chain_plans()
    emit("discrete/measure_engine/cps_le2", t_eng,
         f"DiscreteEngine fused H/Ydag; speedup={speedup:.1f}x vs reference",
         measure_speedup_vs_reference=round(speedup, 1),
         engine_chains=len(chains),
         h_groups_device=eng.stats.device_h_groups,
         h_groups_exact=eng.stats.exact_h_groups,
         hot_path_per_clique_kron_np=calls["n"] > 0,
         kron_np_calls_during_measure=calls["n"],
         measure_signatures=eng.stats.measure_signatures)

    # big-γ² clique end to end through the engine (completion row)
    dom2 = Domain.create([10, 10, 10])
    plan2 = select_sum_of_variances(MarginalWorkload(dom2, ((0, 1, 2),)), 1.0)
    plan2.sigma[plan2.table.index[(0, 1, 2)]] = 1e34   # γ² = 1e40
    margs2 = {c: np.zeros(dom2.n_cells(c)) for c in plan2.cliques}
    eng2 = plan2.engine(secure=True)
    t_big = timeit(lambda: eng2.measure(margs2, key), repeats=1)
    emit("discrete/measure_engine/g2_1e40", t_big,
         "1000-cell clique at gamma2 = 1e40: completes, finite",
         completes_at_1e40=True)


def run(fast: bool = True):
    _sampler_rows(fast)
    _measure_rows(fast)
