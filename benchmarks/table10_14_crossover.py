"""Paper Tables 10–14: the HDMM / ResidualPlanner+ accuracy crossover.

k = d Kronecker workloads (HDMM's optimal regime) and k-way sweeps showing
RP+ wins at low query order and HDMM takes over as k → d (§9.4)."""
from __future__ import annotations

import itertools
import math

import numpy as np

from repro.core import Domain, MarginalWorkload
from repro.core.plus import PlusSchema, build_w, select_plus
from repro.baselines.hdmm import HdmmKron, HdmmUnion
from repro.data.tabular import synth_domain
from .common import emit, timeit


def _kron_rmse_hdmm(kind, n, d, iters):
    kron = HdmmKron.optimize([build_w(kind, n)] * d, iters=iters)
    return math.sqrt(kron.tv_unit / kron.n_queries)


def _kway_union_hdmm(kind, n, d, k, iters):
    subs = []
    w = build_w(kind, n)
    ones = np.ones((1, n))
    for comb in itertools.combinations(range(d), k):
        facs = [w if i in comb else ones for i in range(d)]
        subs.append(HdmmKron.optimize(facs, iters=iters))
    return HdmmUnion.optimize(subs)


def run(fast: bool = True):
    iters = 300 if fast else 1200
    # Tables 10/11: k = d, range and prefix, growing n
    for kind, table in (("range", "table10"), ("prefix", "table11")):
        for d in (3, 4) if fast else (3, 4, 5):
            for n in ((2, 4, 8) if fast else (2, 4, 8, 16, 32, 64)):
                dom = synth_domain(n, d, kind="numeric")
                wk = MarginalWorkload(dom, (tuple(range(d)),))
                schema = PlusSchema.create(dom, [kind] * d, strategy_mode="auto")
                t = timeit(lambda: select_plus(wk, schema, 1.0, "sov"), repeats=1)
                rp = select_plus(wk, schema, 1.0, "sov")
                hd = _kron_rmse_hdmm(kind, n, d, iters)
                emit(f"{table}/kron_{kind}/n={n}/d={d}", t,
                     f"rp+={rp.rmse():.3f} hdmm={hd:.3f} "
                     f"(paper: HDMM optimal here)")
    # Tables 12/13: k-way prefix sweeps (crossover point)
    for d, n, table in ((5, 10, "table12"), (10, 10, "table13")):
        if fast and table == "table13":
            continue
        dom = synth_domain(n, d, kind="numeric")
        for k in range(1, min(d, 5) + 1):
            wk = MarginalWorkload(
                dom, tuple(itertools.combinations(range(d), k)))
            schema = PlusSchema.create(dom, ["prefix"] * d, strategy_mode="auto")
            rp = select_plus(wk, schema, 1.0, "sov")
            hd = _kway_union_hdmm("prefix", n, d, k, iters)
            emit(f"{table}/kway_prefix/d={d}/k={k}", 0.0,
                 f"rp+={rp.rmse():.3f} hdmm_opt+={hd.rmse(1.0):.3f}")
