"""Paper Tables 6–9 (and Figs 6–7): ResidualPlanner+ on generalized-marginal
workloads — selection/reconstruction scaling on Synth-10^d all-≤3-way range
queries, prefix-sum accuracy vs HDMM on Adult/CPS/Loans, and the PlusEngine
device path (signature-batched fused chains) vs the per-clique numpy loops
(``plus_speedup_vs_numpy`` rows gate CI at a ≥3× floor)."""
from __future__ import annotations

import math

import numpy as np

from repro.core import Domain, MarginalWorkload, all_kway
from repro.core.mechanism import Measurement
from repro.core.plus import (PlusSchema, measure_plus_np, reconstruct_plus,
                             select_plus)
from repro.baselines.hdmm import hdmm_generalized
from repro.data.tabular import ADULT_SIZES, CPS_SIZES, LOANS_SIZES, synth_domain
from .common import emit, timeit

PAPER8 = {"adult": 48.903, "cps": 8.392, "loans": 36.651}   # ≤3-way prefix RMSE
PAPER9 = {"adult": 165.942, "cps": 28.526, "loans": 124.318}

# numeric attributes per the paper §9 (Adult: 5 numeric; CPS: 2; Loans: 4)
NUMERIC = {"adult": (0, 1, 2, 3, 4), "cps": (0, 1), "loans": (0, 1, 2, 3)}


def run(fast: bool = True):
    rng = np.random.default_rng(0)
    # Tables 6/7: scaling on Synth-10^d, all range queries on <=3 attrs
    for d in ((2, 6, 10, 15) if fast else (2, 6, 10, 12, 14, 15, 20, 30, 50)):
        dom = synth_domain(10, d, kind="numeric")
        wk = all_kway(dom, min(3, d), include_lower=True)
        schema = PlusSchema.create(dom, ["range"] * d, strategy_mode="hier")
        t_sel = timeit(lambda: select_plus(wk, schema, 1.0, "sov"), repeats=1)
        emit(f"table6/rplus_select_rmse/d={d}", t_sel, "paper Tbl6 col2")
        plan = select_plus(wk, schema, 1.0, "sov")
        margs = {c: np.zeros(int(np.prod([dom.attributes[i].size for i in c]))
                             if c else 1) for c in plan.cliques}
        meas = measure_plus_np(plan, margs, rng)
        t_rec = timeit(lambda: [reconstruct_plus(plan, meas, c)
                                for c in wk.cliques], repeats=1)
        emit(f"table7/rplus_reconstruct/d={d}", t_rec, "paper Tbl7 col4")
        # the smoothed max-variance solver differentiates a (total cells ×
        # closure) sparse grid per Adam step — minutes at d=6, so the fast
        # (CI) profile keeps only the d=2 representative row.
        if d <= (2 if fast else 6):
            t_mv = timeit(lambda: select_plus(wk, schema, 1.0, "max_variance",
                                              steps=800), repeats=1)
            emit(f"table6/rplus_select_maxvar/d={d}", t_mv, "paper Tbl6 col3")

    # Tables 8/9: prefix-sum accuracy vs HDMM on the real schemas.  The fast
    # profile runs CPS only (~1 min); Adult/Loans max-variance grids are
    # paper-scale and belong to --full.
    for name, sizes in ([("cps", CPS_SIZES)] if fast else
                        [("adult", ADULT_SIZES), ("cps", CPS_SIZES),
                         ("loans", LOANS_SIZES)]):
        dom = Domain.create(sizes)
        kinds = ["prefix" if i in NUMERIC[name] else "identity"
                 for i in range(dom.n_attrs)]
        wk = all_kway(dom, 3, include_lower=True)
        schema = PlusSchema.create(dom, kinds, strategy_mode="auto")
        t = timeit(lambda: select_plus(wk, schema, 1.0, "sov"), repeats=1)
        plan = select_plus(wk, schema, 1.0, "sov")
        hd = hdmm_generalized(wk, kinds, iters=60 if fast else 1000)
        emit(f"table8/prefix_rmse/{name}/le3", t,
             f"rp+={plan.rmse():.3f} hdmm={hd.rmse(1.0):.3f} "
             f"paper_rp+={PAPER8[name]}")
        mv = select_plus(wk, schema, 1.0, "max_variance",
                         steps=300 if fast else 3000)
        emit(f"table9/prefix_maxvar/{name}/le3", 0.0,
             f"rp+={mv.max_cell_variance():.3f} hdmm={hd.max_variance(1.0):.3f} "
             f"paper_rp+={PAPER9[name]}")

    # PlusEngine (docs/DESIGN.md §8): signature-batched device Algs 5/6 vs the
    # per-clique numpy loops on all-range workloads.  The emitted
    # ``plus_speedup_vs_numpy`` metrics are the CI regression floor (≥3×);
    # the fast profile uses the many-small-cliques serving shape (d=20,
    # ≤2-way), the full profile adds the paper's ≤3-way shape.
    engine_bench(d=20, kway=2)
    if not fast:
        engine_bench(d=12, kway=3)


def engine_bench(d: int, kway: int) -> None:
    import jax
    from repro.engine.plus_engine import PlusEngine

    rng = np.random.default_rng(1)
    dom = synth_domain(10, d, kind="numeric")
    wk = all_kway(dom, min(kway, d), include_lower=True)
    schema = PlusSchema.create(dom, ["range"] * d, strategy_mode="hier")
    plan = select_plus(wk, schema, 1.0, "sov")
    margs = {c: rng.random(int(np.prod([dom.attributes[i].size for i in c]))
                           if c else 1) for c in plan.cliques}
    key = jax.random.PRNGKey(0)

    eng = PlusEngine(plan)           # use_kernel resolves per backend
    meas_dev = eng.measure(margs, key)          # warm the jit caches
    eng.reconstruct(meas_dev)

    t_np_meas = timeit(lambda: measure_plus_np(plan, margs, rng), repeats=1)
    t_dev_meas = timeit(lambda: eng.measure(margs, key), repeats=3)
    meas_np = measure_plus_np(plan, margs, rng)
    t_np_rec = timeit(lambda: [reconstruct_plus(plan, meas_np, c)
                               for c in wk.cliques], repeats=1)
    t_dev_rec = timeit(lambda: eng.reconstruct(meas_dev), repeats=3)

    emit(f"table7/plus_engine_measure/d={d}", t_dev_meas,
         f"numpy_per_clique={t_np_meas:.1f}us "
         f"groups={eng.stats.measure_signatures} cliques={len(plan.cliques)}",
         plus_speedup_vs_numpy=round(t_np_meas / t_dev_meas, 2))
    emit(f"table7/plus_engine_reconstruct/d={d}", t_dev_rec,
         f"numpy_per_clique={t_np_rec:.1f}us "
         f"groups={eng.stats.reconstruct_signatures} "
         f"cliques={len(wk.cliques)}",
         plus_speedup_vs_numpy=round(t_np_rec / t_dev_rec, 2))
