"""Paper Tables 6–9 (and Figs 6–7): ResidualPlanner+ on generalized-marginal
workloads — selection/reconstruction scaling on Synth-10^d all-≤3-way range
queries, and prefix-sum accuracy vs HDMM on Adult/CPS/Loans."""
from __future__ import annotations

import math

import numpy as np

from repro.core import Domain, MarginalWorkload, all_kway
from repro.core.mechanism import Measurement
from repro.core.plus import (PlusSchema, measure_plus_np, reconstruct_plus,
                             select_plus)
from repro.baselines.hdmm import hdmm_generalized
from repro.data.tabular import ADULT_SIZES, CPS_SIZES, LOANS_SIZES, synth_domain
from .common import emit, timeit

PAPER8 = {"adult": 48.903, "cps": 8.392, "loans": 36.651}   # ≤3-way prefix RMSE
PAPER9 = {"adult": 165.942, "cps": 28.526, "loans": 124.318}

# numeric attributes per the paper §9 (Adult: 5 numeric; CPS: 2; Loans: 4)
NUMERIC = {"adult": (0, 1, 2, 3, 4), "cps": (0, 1), "loans": (0, 1, 2, 3)}


def run(fast: bool = True):
    rng = np.random.default_rng(0)
    # Tables 6/7: scaling on Synth-10^d, all range queries on <=3 attrs
    for d in ((2, 6, 10, 15) if fast else (2, 6, 10, 12, 14, 15, 20, 30, 50)):
        dom = synth_domain(10, d, kind="numeric")
        wk = all_kway(dom, min(3, d), include_lower=True)
        schema = PlusSchema.create(dom, ["range"] * d, strategy_mode="hier")
        t_sel = timeit(lambda: select_plus(wk, schema, 1.0, "sov"), repeats=1)
        emit(f"table6/rplus_select_rmse/d={d}", t_sel, "paper Tbl6 col2")
        plan = select_plus(wk, schema, 1.0, "sov")
        margs = {c: np.zeros(int(np.prod([dom.attributes[i].size for i in c]))
                             if c else 1) for c in plan.cliques}
        meas = measure_plus_np(plan, margs, rng)
        t_rec = timeit(lambda: [reconstruct_plus(plan, meas, c)
                                for c in wk.cliques], repeats=1)
        emit(f"table7/rplus_reconstruct/d={d}", t_rec, "paper Tbl7 col4")
        if d <= 6:
            t_mv = timeit(lambda: select_plus(wk, schema, 1.0, "max_variance",
                                              steps=800), repeats=1)
            emit(f"table6/rplus_select_maxvar/d={d}", t_mv, "paper Tbl6 col3")

    # Tables 8/9: prefix-sum accuracy vs HDMM on the real schemas
    for name, sizes in [("adult", ADULT_SIZES), ("cps", CPS_SIZES),
                        ("loans", LOANS_SIZES)]:
        dom = Domain.create(sizes)
        kinds = ["prefix" if i in NUMERIC[name] else "identity"
                 for i in range(dom.n_attrs)]
        wk = all_kway(dom, 3, include_lower=True)
        schema = PlusSchema.create(dom, kinds, strategy_mode="auto")
        t = timeit(lambda: select_plus(wk, schema, 1.0, "sov"), repeats=1)
        plan = select_plus(wk, schema, 1.0, "sov")
        hd = hdmm_generalized(wk, kinds, iters=60 if fast else 1000)
        emit(f"table8/prefix_rmse/{name}/le3", t,
             f"rp+={plan.rmse():.3f} hdmm={hd.rmse(1.0):.3f} "
             f"paper_rp+={PAPER8[name]}")
        mv = select_plus(wk, schema, 1.0, "max_variance",
                         steps=300 if fast else 3000)
        emit(f"table9/prefix_maxvar/{name}/le3", 0.0,
             f"rp+={mv.max_cell_variance():.3f} hdmm={hd.max_variance(1.0):.3f} "
             f"paper_rp+={PAPER9[name]}")
