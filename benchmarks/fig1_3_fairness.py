"""Paper Figures 1–3 (§6.2): cell-fairness of the weighted-SoV objective on
Adult ≤3-way marginals under equi / cell-size / sqrt weighting — per-band
variance summaries instead of scatter plots."""
from __future__ import annotations

import math

import numpy as np

from repro.core import Domain, all_kway, select_sum_of_variances
from repro.data.tabular import ADULT_SIZES
from .common import emit, timeit


def run(fast: bool = True):
    dom = Domain.create(ADULT_SIZES)
    wk = all_kway(dom, 3, include_lower=True)
    for scheme, fig in (("equi", "fig1"), ("cells", "fig2"),
                        ("sqrt_cells", "fig3")):
        wks = wk.reweighted(scheme)
        t = timeit(lambda: select_sum_of_variances(
            wks, 1.0, dict(wks.weights)), repeats=1)
        plan = select_sum_of_variances(wks, 1.0, dict(wks.weights))
        by_k = {}
        for c, v in plan.workload_variances().items():
            by_k.setdefault(len(c), []).append(v)
        bands = " ".join(
            f"{k}way[{min(vs):.3g},{max(vs):.3g}]"
            for k, vs in sorted(by_k.items()))
        spread = max(max(vs) for vs in by_k.values()) / min(
            min(vs) for vs in by_k.values())
        emit(f"{fig}/fairness/{scheme}", t,
             f"{bands} spread={spread:.1f}x")
