"""Paper Tables 2 & 3 (and Figs 4–5): selection + measurement/reconstruction
time on Synth-10^d, all ≤3-way marginals, d ∈ {2,…,100}; HDMM comparison up to
its memory wall."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (all_kway, measure_np, reconstruct_marginal,
                        select_max_variance, select_sum_of_variances)
from repro.core.mechanism import measure_np_batched
from repro.data.tabular import synth_domain
from .common import emit, timeit

DS_FULL = (2, 6, 10, 12, 14, 15, 20, 30, 50, 100)
DS_FAST = (2, 6, 10, 15, 20, 30)
HDMM_DS = (2, 6, 10)            # HDMM reconstruction wall: universe 10^d


def run(fast: bool = True):
    rng = np.random.default_rng(0)
    for d in (DS_FAST if fast else DS_FULL):
        dom = synth_domain(10, d)
        wk = all_kway(dom, min(3, d), include_lower=True)
        cells = {c: float(dom.n_cells(c)) for c in wk.cliques}

        t_sel = timeit(lambda wk=wk, cells=cells: select_sum_of_variances(
            wk, 1.0, cells), repeats=3)
        emit(f"table2/select_rmse/d={d}", t_sel, "paper Tbl2 col2")
        t_mv = timeit(lambda wk=wk, d=d: select_max_variance(
            wk, 1.0, iters=300 if d >= 50 else 2000), repeats=1)
        emit(f"table2/select_maxvar/d={d}", t_mv, "paper Tbl2 col3")

        plan = select_sum_of_variances(wk, 1.0, cells)
        margs = {c: np.zeros(dom.n_cells(c)) for c in plan.cliques}
        t_meas = timeit(lambda plan=plan, margs=margs: measure_np_batched(
            plan, margs, rng), repeats=1)
        t_meas_loop = timeit(lambda plan=plan, margs=margs: measure_np(
            plan, margs, rng), repeats=1)
        meas = measure_np_batched(plan, margs, rng)
        t_rec = timeit(lambda plan=plan, meas=meas, wk=wk: [
            reconstruct_marginal(plan, meas, c) for c in wk.cliques], repeats=1)
        emit(f"table3/measure/d={d}", t_meas,
             f"Alg1 batched (per-clique loop: {t_meas_loop:.0f}us, "
             f"{t_meas_loop / max(t_meas, 1e-9):.1f}x slower)")
        emit(f"table3/reconstruct/d={d}", t_rec, "paper Tbl3 col4")

    # HDMM wall demonstration
    from repro.baselines.hdmm import hdmm_marginals, hdmm_measure_reconstruct
    for d in HDMM_DS:
        dom = synth_domain(10, d)
        wk = all_kway(dom, min(3, d), include_lower=True)
        t_sel = timeit(lambda: hdmm_marginals(wk, iters=150), repeats=1)
        emit(f"table2/hdmm_select/d={d}", t_sel, "OPT_+ re-impl")
        union = hdmm_marginals(wk, iters=50)
        try:
            x = np.zeros(dom.universe_size())
            t_rec = timeit(lambda: hdmm_measure_reconstruct(
                union, dom, x, rng), repeats=1)
            emit(f"table3/hdmm_reconstruct/d={d}", t_rec, "universe-sized LS")
        except MemoryError:
            emit(f"table3/hdmm_reconstruct/d={d}", float("nan"),
                 "OOM (paper Tbl3: HDMM OOM at d=10)")
