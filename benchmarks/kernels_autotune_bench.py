"""Autotuned vs fixed-default fused-chain launch configs (docs/DESIGN.md §14).

The CI-gated rows: the fused measurement chains of the paper's Synth-10^20
all-≤3-way workload (one ⊗ᵢSub_{n_i} chain per signature group at its
serving batch — 2·g stacked [v; z] lanes), run with the historical fixed
``block_l=128`` default and with the autotuner's per-signature configs.  On
the CPU interpret backend the Pallas kernel body executes in Python once per
grid step, so the tuner's grid-step minimization (the 3-way group's 2·1140 =
2280 lanes drop from 18 grid steps to 1) is directly visible as wall-clock.
The gate asserts ≥1.15× on the chain measure and fp32 BIT-exactness between
the two configs (row independence: block_l/padding cannot change per-row
results).  The end-to-end ``measure()`` phase — which adds the
config-invariant marginal stacking + noise draws — is emitted as a secondary
row.
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import measure
from repro.core.mechanism import signature_groups
from repro.core.residual import sub_matrix
from repro.kernels.autotune import registry_snapshot, reset_registry, tune_chain
from repro.kernels.kron_matvec.fused import fused_chain_matvec
from .common import emit, timeit
from .kernels_bench import _measurement_workload


def _with_mode(mode: str, fn):
    prev = os.environ.get("REPRO_KERNEL_AUTOTUNE")
    os.environ["REPRO_KERNEL_AUTOTUNE"] = mode
    try:
        return fn()
    finally:
        if prev is None:
            os.environ.pop("REPRO_KERNEL_AUTOTUNE", None)
        else:
            os.environ["REPRO_KERNEL_AUTOTUNE"] = prev


def run(fast: bool = True):
    d = 20
    plan, margs = _measurement_workload(d)
    key = jax.random.PRNGKey(0)
    tag = f"synth10^{d}_le3way"
    rng = np.random.default_rng(0)

    # One measurement chain per signature group, at the serving batch the
    # engine registers (2·g stacked [v; z] lanes — docs/DESIGN.md §4).
    chains = []
    for dims, cliques in signature_groups(plan.domain, plan.cliques).items():
        if not dims:
            continue
        facs = [sub_matrix(n) for n in dims]
        b = 2 * len(cliques)
        x = jnp.asarray(rng.standard_normal((b, int(np.prod(dims)))),
                        jnp.float32)
        chains.append((facs, dims, b, x))

    cfgs = [tune_chain(facs, dims, batch=b, persist=False)
            for facs, dims, b, _x in chains]

    def run_default():
        # mode is pinned to "off" around every call, so the unparametrized
        # call takes the historical fixed block_l=128 plan, not the registry.
        return [np.asarray(fused_chain_matvec(facs, x, dims))
                for facs, dims, _b, x in chains]

    def run_tuned():
        return [np.asarray(fused_chain_matvec(
            facs, x, dims, block_l=c.block_l, vmem_budget=c.vmem_budget))
            for (facs, dims, _b, x), c in zip(chains, cfgs)]

    y_def = _with_mode("off", run_default)    # warm jit/pallas caches
    y_tun = run_tuned()
    bit_exact = all(np.array_equal(a, b) for a, b in zip(y_def, y_tun))
    t_def = _with_mode("off", lambda: timeit(run_default, repeats=3))
    t_tun = timeit(run_tuned, repeats=3)

    def_steps = sum(-(-b // min(128, -(-b // 8) * 8)) for _f, _d, b, _x in chains)
    blocks = sorted({c.block_l for c in cfgs})
    steps = sorted({c.grid_steps for c in cfgs})
    intensity = round(float(np.mean([c.intensity for c in cfgs])), 3)
    emit(f"autotune/chains_default/{tag}", t_def,
         f"block_l=128 default, {def_steps} grid steps total",
         grid_steps_total=def_steps)
    emit(f"autotune/chains_tuned/{tag}", t_tun,
         f"tuned block_l={blocks} grid_steps={steps}, "
         f"{'bit-exact' if bit_exact else 'MISMATCH'} vs default",
         tuned_block_l=blocks, tuned_grid_steps=steps,
         predicted_intensity=intensity,
         speedup_autotuned_vs_default=round(t_def / t_tun, 2),
         bit_exact_fp32=bool(bit_exact))

    # Secondary: the full measure() phase end-to-end (adds config-invariant
    # marginal stacking + noise draws, so the ratio is diluted).
    def measure_fused():
        return measure(plan, margs, key, use_kernel=True, batched=True)

    meas_def = _with_mode("off", measure_fused)
    t_mdef = _with_mode("off", lambda: timeit(measure_fused, repeats=3))
    reset_registry()
    meas_tun = _with_mode("model", measure_fused)
    t_mtun = _with_mode("model", lambda: timeit(measure_fused, repeats=3))
    e2e_exact = all(np.array_equal(meas_def[c].omega, meas_tun[c].omega)
                    for c in plan.cliques)
    snap = registry_snapshot()
    emit(f"autotune/measure_e2e_tuned/{tag}", t_mtun,
         f"vs {t_mdef / 1e3:.0f}ms default, "
         f"{'bit-exact' if e2e_exact else 'MISMATCH'}, "
         f"{len(snap['entries'])} registry entries",
         speedup_e2e=round(t_mdef / t_mtun, 2),
         bit_exact_e2e=bool(e2e_exact),
         registry_entries=len(snap["entries"]))
