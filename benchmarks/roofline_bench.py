"""§Roofline summary rows from the dry-run artifacts (deliverable g)."""
from __future__ import annotations

import os

from repro.roofline.analyze import ARTIFACT_DIR, analyze_all
from .common import emit


def run(fast: bool = True):
    if not os.path.isdir(ARTIFACT_DIR):
        emit("roofline/missing", 0.0, "run `python -m repro.launch.dryrun --all`")
        return
    for c in analyze_all(ARTIFACT_DIR, "single"):
        if c.status != "ok":
            emit(f"roofline/{c.arch}/{c.shape}", 0.0, f"{c.status}")
            continue
        t_dom = max(c.t_compute, c.t_memory, c.t_collective)
        emit(f"roofline/{c.arch}/{c.shape}", t_dom * 1e6,
             f"bottleneck={c.bottleneck} compute={c.t_compute:.2e}s "
             f"memory={c.t_memory:.2e}s coll={c.t_collective:.2e}s "
             f"useful={c.useful_ratio:.2f} mfu_bound={c.mfu_bound:.2%}")
