"""Release-server load benchmark → BENCH_serve.json (CI-gated).

Eight tenants share one workload *shape* (uniform attribute sizes, so every
tenant's ≤2-way closure collapses to two chain signatures) but hold their own
plans, their own data, and their own budgets.  The benchmark drives the same
request stream through the server twice:

* ``sequential`` — ``max_batch=1``: the worker serves one request per drain,
  one full set of chain launches per request (the pre-serving-tier cost);
* ``batched``    — ``max_batch=16``: the worker fuses same-signature traffic
  across tenants into shared chain launches (engine/multi.py).

CI gates (ci.yml serve-bench): batched throughput ≥ 2× sequential at 8
tenants; batched p99 latency under the committed ceiling; batched and
sequential serving bit-identical on fixed seeds (the fusion is a pure
re-batching, never a different mechanism).

The observability A/B (``serve/obs_overhead/8tenants``) holds the tracing
subsystem to its zero-cost-when-off contract: with tracing disabled the
per-request cost of the instrumentation (span call sites hitting the no-op
fast path) must stay ≤ 2% of request latency, and a fully traced run (ring
sink) must stay within 10% of the untraced batched throughput — and remain
bit-identical, because tracing only observes.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from .common import emit

N_TENANTS = 8
ATTR_SIZES = [8] * 6          # uniform sizes -> 2 fused signatures (w=1, w=2)
RECORDS = 20_000


def _setup(max_batch: int, ledger_path: str, rho: float = 1e6):
    from repro.core import Domain, all_kway, select
    from repro.data.tabular import marginals_from_records, synthetic_records
    from repro.serve import BudgetLedger, ReleaseServer

    dom = Domain.create(ATTR_SIZES)
    ledger = BudgetLedger(ledger_path, fsync=False)
    server = ReleaseServer(ledger, max_batch=max_batch, max_wait_ms=4.0)
    server.start()
    tenant_margs = {}
    for t in range(N_TENANTS):
        wk = all_kway(dom, 2, include_lower=True)
        plan = select(wk, pcost_budget=1.0)
        name = f"tenant-{t}"
        server.register_tenant(name, plan, rho=rho)
        recs = synthetic_records(dom, RECORDS, seed=t)
        tenant_margs[name] = marginals_from_records(dom, plan.cliques, recs)
    return server, tenant_margs


def _drive(server, tenant_margs, requests_per_tenant: int, seed0: int):
    """Prefill the paused queue, release, drain; returns (wall_s, results)."""
    from repro.serve import ReleaseRequest

    server.pause()
    futures = []
    s = seed0
    for _r in range(requests_per_tenant):
        for tenant, margs in tenant_margs.items():
            futures.append(server.submit(ReleaseRequest(
                tenant=tenant, marginals=margs, seed=s)))
            s += 1
    t0 = time.perf_counter()
    server.resume()
    results = [f.result(timeout=600) for f in futures]
    wall = time.perf_counter() - t0
    return wall, results


def run(fast: bool = True) -> None:
    reps = 6 if fast else 25
    tmp = tempfile.mkdtemp(prefix="serve_bench_")

    seq_srv, margs = _setup(1, os.path.join(tmp, "seq.jsonl"))
    _drive(seq_srv, margs, 1, seed0=10_000)          # warm compile caches
    seq_wall, seq_res = _drive(seq_srv, margs, reps, seed0=0)
    seq_srv.stop()

    bat_srv, margs_b = _setup(16, os.path.join(tmp, "bat.jsonl"))
    _drive(bat_srv, margs_b, 2, seed0=10_000)        # warm the 16-drain shapes
    bat_wall, bat_res = _drive(bat_srv, margs_b, reps, seed0=0)
    stats = bat_srv.stats_dict()
    bat_srv.stop()

    n = N_TENANTS * reps
    seq_rps = n / seq_wall
    bat_rps = n / bat_wall

    # same seeds, same tenants: the fused path must be bit-identical
    bit_exact = all(
        set(a.tables) == set(b.tables) and all(
            np.array_equal(a.tables[c], b.tables[c]) for c in a.tables)
        for a, b in zip(seq_res, bat_res))

    lat = np.asarray([r.latency_s for r in bat_res]) * 1e3
    emit("serve/throughput/8tenants", bat_wall / n * 1e6,
         f"{bat_rps:.1f} rps batched vs {seq_rps:.1f} sequential",
         requests=n, tenants=N_TENANTS,
         batched_rps=round(bat_rps, 2), sequential_rps=round(seq_rps, 2),
         speedup_batched_vs_sequential=round(bat_rps / seq_rps, 3),
         batch_occupancy=round(stats["batch_occupancy"], 3),
         batched_launch_groups=stats["batched_launch_groups"],
         p50_ms=round(float(np.percentile(lat, 50)), 3),
         p99_ms=round(float(np.percentile(lat, 99)), 3),
         bit_exact_vs_sequential=bool(bit_exact))

    cache = stats["engine_cache"]
    emit("serve/engine_cache/8tenants", 0.0,
         f"hit rate {cache['hit_rate']:.3f}",
         cache_hit_rate=round(cache["hit_rate"], 4),
         cache_entries=cache["entries"], cache_evictions=cache["evictions"])

    led = np.asarray([stats["ledger"][t]["pcost_spent"]
                      for t in margs_b])
    emit("serve/ledger/8tenants", 0.0,
         f"{int(stats['ledger'][next(iter(margs_b))]['charges'])} charges/tenant",
         charges_per_tenant=int(
             stats["ledger"][next(iter(margs_b))]["charges"]),
         pcost_spent_per_tenant=round(float(led[0]), 6),
         all_tenants_equal_spend=bool(np.allclose(led, led[0])))

    # ---- observability overhead A/B (CI gates: off <=2%, on <=10%) -----
    from repro.obs import TRACER

    n_noop = 200_000                     # disabled fast path, ns per call
    t0 = time.perf_counter()
    for _ in range(n_noop):
        TRACER.span("bench.noop")
    noop_ns = (time.perf_counter() - t0) / n_noop * 1e9

    # A/B on ONE server, alternating tracing per round and taking the min
    # wall per mode: the server, its engine cache, and every compile cache
    # are identical across modes, so the delta isolates the tracing cost
    # from run-to-run scheduler noise (which exceeds the 10% gate).
    ab_srv, margs_a = _setup(16, os.path.join(tmp, "ab.jsonl"))
    _drive(ab_srv, margs_a, 2, seed0=10_000)
    walls = {False: [], True: []}
    results = {}
    spans = []
    for _round in range(3 if fast else 5):
        for traced in (False, True):
            if traced:
                TRACER.enable()          # in-memory ring, no file sink
            try:
                w, res = _drive(ab_srv, margs_a, reps, seed0=0)
            finally:
                if traced:
                    spans = TRACER.drain()
                    TRACER.disable()
            walls[traced].append(w)
            results[traced] = res
    ab_srv.stop()

    off_wall, on_wall = min(walls[False]), min(walls[True])
    spans_per_request = len(spans) / n
    # Disabled-mode cost model: every span call site a request crosses pays
    # one no-op dispatch; as a fraction of measured request latency.
    disabled_pct = spans_per_request * noop_ns * 1e-9 / (off_wall / n) * 100
    traced_pct = (on_wall - off_wall) / off_wall * 100
    traced_exact = all(
        set(a.tables) == set(b.tables) and all(
            np.array_equal(a.tables[c], b.tables[c]) for c in a.tables)
        for a, b in zip(results[False], results[True]))
    emit("serve/obs_overhead/8tenants", on_wall / n * 1e6,
         f"off {disabled_pct:.4f}% / on {traced_pct:+.1f}% vs untraced",
         noop_span_ns=round(noop_ns, 1),
         spans_per_request=round(spans_per_request, 2),
         disabled_overhead_pct=round(disabled_pct, 4),
         traced_rps=round(n / on_wall, 2),
         untraced_rps=round(n / off_wall, 2),
         traced_overhead_pct=round(traced_pct, 2),
         bit_exact_vs_untraced=bool(traced_exact))
