"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` runs the complete
paper grids (d up to 100 etc.); the default profile keeps CI runtime modest.
``--json [PATH]`` additionally writes every recorded row (with structured
metrics such as speedups) to PATH — default ``BENCH_kernels.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full paper grids (slow: d up to 100)")
    ap.add_argument("--only", default=None, help="substring filter on module")
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                    default=None, metavar="PATH",
                    help="write structured results (default BENCH_kernels.json)")
    args, _ = ap.parse_known_args()

    from . import (table2_3_marginals_scaling, table4_5_accuracy,
                   table6_9_rplus, table10_14_crossover, fig1_3_fairness,
                   discrete_overhead, discrete_bench, kernels_bench,
                   kernels_autotune_bench, planner_bench, release_bench,
                   roofline_bench, serve_bench)
    modules = [table2_3_marginals_scaling, table4_5_accuracy, table6_9_rplus,
               table10_14_crossover, fig1_3_fairness, discrete_overhead,
               discrete_bench, kernels_bench, kernels_autotune_bench,
               planner_bench, release_bench, roofline_bench, serve_bench]
    print("name,us_per_call,derived")
    failed = 0
    for mod in modules:
        if args.only and args.only not in mod.__name__:
            continue
        try:
            mod.run(fast=not args.full)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{mod.__name__},nan,EXCEPTION", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        from .common import JSON_ROWS
        with open(args.json, "w") as fh:
            json.dump({"profile": "full" if args.full else "fast",
                       "rows": JSON_ROWS}, fh, indent=2)
        print(f"wrote {len(JSON_ROWS)} rows to {args.json}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
