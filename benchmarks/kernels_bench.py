"""Mechanism hot-path bench: per-clique loop vs signature-batched vs fused.

Two layers (docs/DESIGN.md §3–5):

* micro: the Kronecker matvec itself (ref jnp path timed on CPU; Pallas
  kernels are TPU-target and validated in interpret mode — their CPU
  interpret timing measures launch/layout overhead, not MXU throughput);
* macro: the full measurement + reconstruction phases on the paper's
  Synth-10^d all-≤3-way workload (d=20), comparing the historical per-clique
  loop against the signature-batched engine paths.  These rows carry the
  ``speedup_*`` metrics recorded in BENCH_kernels.json.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (all_kway, measure, reconstruct_all,
                        reconstruct_all_batched, select_sum_of_variances)
from repro.core.mechanism import signature_groups
from repro.core.residual import sub_matrix
from repro.data.tabular import synth_domain
from repro.kernels.kron_matvec.fused import fused_chain_matvec
from repro.kernels.kron_matvec.ops import kron_matvec_kernel
from repro.kernels.kron_matvec.ref import kron_matvec_ref
from repro.kernels.kron_matvec.stats import chain_stats, reset_chain_stats
from .common import emit, timeit


def _micro(fast: bool):
    for dims in ([50, 50, 40], [100, 100], [10] * 6):
        facs = [sub_matrix(n) for n in dims]
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            int(np.prod(dims))), jnp.float32)
        ref = jax.jit(lambda x, facs=facs, dims=dims: kron_matvec_ref(facs, x, dims))
        ref(x).block_until_ready()
        t = timeit(lambda ref=ref, x=x: ref(x).block_until_ready(), repeats=5)
        gflops = 2 * sum((n - 1) * np.prod(dims) / n for n in dims) / 1e9
        emit(f"kernel/kron_ref/dims={'x'.join(map(str, dims))}", t,
             f"~{gflops / (t / 1e6):.2f} GFLOP/s on CPU")
        if int(np.prod(dims)) <= 100_000:   # interpret mode is pure Python
            got = np.asarray(kron_matvec_kernel(facs, np.asarray(x), dims))
            want = np.asarray(ref(x))
            emit(f"kernel/kron_pallas_interpret_check/dims={'x'.join(map(str, dims))}",
                 0.0, f"max_err={np.max(np.abs(got - want)):.2e}")
            got_f = np.asarray(fused_chain_matvec(facs, np.asarray(x), dims))
            emit(f"kernel/kron_fused_interpret_check/dims={'x'.join(map(str, dims))}",
                 0.0, f"max_err={np.max(np.abs(got_f - want)):.2e}")


def _measurement_workload(d: int):
    """Synth-10^d, all ≤3-way marginals (the paper's scaling workload)."""
    dom = synth_domain(10, d)
    wk = all_kway(dom, 3, include_lower=True)
    plan = select_sum_of_variances(wk, 1.0)
    rng = np.random.default_rng(0)
    margs = {c: rng.random(plan.domain.n_cells(c)) for c in plan.cliques}
    return plan, margs


def _macro_measure(fast: bool):
    d = 20
    plan, margs = _measurement_workload(d)
    key = jax.random.PRNGKey(0)
    n_cliques = len(plan.cliques)
    n_sigs = len(signature_groups(plan.domain, plan.cliques))
    tag = f"synth10^{d}_le3way"

    def loop_jnp():
        measure(plan, margs, key, use_kernel=False, batched=False)

    def batched_jnp():
        measure(plan, margs, key, use_kernel=False, batched=True)

    def loop_kernel():
        measure(plan, margs, key, use_kernel=True, batched=False)

    def batched_fused():
        measure(plan, margs, key, use_kernel=True, batched=True)

    t_loop = timeit(loop_jnp, repeats=2, warmup=1)
    t_bat = timeit(batched_jnp, repeats=2, warmup=1)
    emit(f"measure/per_clique_jnp/{tag}", t_loop,
         f"{n_cliques} cliques, 1 chain each", cliques=n_cliques)
    emit(f"measure/batched_jnp/{tag}", t_bat,
         f"{n_sigs} signature groups", signatures=n_sigs,
         speedup_vs_per_clique=round(t_loop / t_bat, 2))

    # CPU interpret mode: the Pallas chains run their kernel bodies in
    # Python, so absolute numbers measure launch/pad/slice overhead — which
    # is exactly what batching and fusion remove.  The per-clique interpret
    # baseline is ~1 min/call; the fast profile skips it and scores the fused
    # path against the per-clique jnp loop instead.
    t_loopk = None
    if not fast:
        t_loopk = timeit(loop_kernel, repeats=1, warmup=1)
        emit(f"measure/per_clique_pallas_interpret/{tag}", t_loopk,
             f"{n_cliques} cliques, pad+slice per factor", cliques=n_cliques)
    batched_fused()                     # warm the jit/pallas caches
    reset_chain_stats()
    t_fused = timeit(batched_fused, repeats=1)
    st = chain_stats()
    emit(f"measure/batched_fused_interpret/{tag}", t_fused,
         f"{st['pallas_calls']} pallas_calls, {st['pads']} pads, "
         f"{st['slices']} slices",
         pallas_calls=st["pallas_calls"], pads=st["pads"], slices=st["slices"],
         speedup_vs_per_clique=round((t_loopk or t_loop) / t_fused, 2))

    # reconstruction: 2^|A| subset matvecs per marginal vs batched merged chains
    meas = measure(plan, margs, key)
    t_rec = timeit(lambda: reconstruct_all(plan, meas), repeats=2, warmup=1)
    t_recb = timeit(lambda: reconstruct_all_batched(plan, meas, use_kernel=False),
                    repeats=2, warmup=1)
    reconstruct_all_batched(plan, meas, use_kernel=True)   # warm caches
    reset_chain_stats()
    t_reck = timeit(lambda: reconstruct_all_batched(plan, meas, use_kernel=True),
                    repeats=1)
    st = chain_stats()
    n_marg = len(plan.workload.cliques)
    emit(f"reconstruct/subset_loop_np/{tag}", t_rec,
         f"{n_marg} marginals, 2^|A| matvecs each", marginals=n_marg)
    emit(f"reconstruct/batched_jnp/{tag}", t_recb, "merged subset embedding",
         speedup_vs_subset_loop=round(t_rec / t_recb, 2))
    emit(f"reconstruct/batched_fused_interpret/{tag}", t_reck,
         f"{st['pallas_calls']} pallas_calls for {n_marg} marginals",
         pallas_calls=st["pallas_calls"],
         speedup_vs_subset_loop=round(t_rec / t_reck, 2))


def _engine_serving(fast: bool):
    from repro.engine import MarginalEngine
    d = 8 if fast else 20
    plan, margs = _measurement_workload(d)
    eng = MarginalEngine(plan, use_kernel=True)   # compiles every chain up front
    key = jax.random.PRNGKey(1)
    t = timeit(lambda: eng.release(margs, key), repeats=2, warmup=1)
    emit(f"engine/release/synth10^{d}_le3way", t,
         f"{len(eng.chain_plans())} precompiled chains",
         chains=len(eng.chain_plans()))


def run(fast: bool = True):
    _micro(fast)
    _macro_measure(fast)
    _engine_serving(fast)
