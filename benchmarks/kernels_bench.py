"""Mechanism hot-path micro-bench: Kronecker matvec (ref jnp path timed on
CPU; the Pallas kernel is TPU-target, validated in interpret mode — its CPU
interpret timing is not meaningful and is reported only as a checksum)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.residual import sub_matrix
from repro.kernels.kron_matvec.ops import kron_matvec_kernel
from repro.kernels.kron_matvec.ref import kron_matvec_ref
from .common import emit, timeit


def run(fast: bool = True):
    for dims in ([50, 50, 40], [100, 100], [10] * 6):
        facs = [sub_matrix(n) for n in dims]
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            int(np.prod(dims))), jnp.float32)
        ref = jax.jit(lambda x: kron_matvec_ref(facs, x, dims))
        ref(x).block_until_ready()
        t = timeit(lambda: ref(x).block_until_ready(), repeats=5)
        gflops = 2 * sum((n - 1) * np.prod(dims) / n for n in dims) / 1e9
        emit(f"kernel/kron_ref/dims={'x'.join(map(str, dims))}", t,
             f"~{gflops / (t / 1e6):.2f} GFLOP/s on CPU")
        if int(np.prod(dims)) <= 100_000:   # interpret mode is pure Python
            got = np.asarray(kron_matvec_kernel(facs, np.asarray(x), dims))
            want = np.asarray(ref(x))
            emit(f"kernel/kron_pallas_interpret_check/dims={'x'.join(map(str, dims))}",
                 0.0, f"max_err={np.max(np.abs(got - want)):.2e}")
