"""Release-subsystem benchmarks (docs/DESIGN.md §11) → BENCH_release.json.

Four CI-gated claims:

* ``release/consistency_cg/d12`` — the IR-CG consistency solve vs the fp64
  dense WLS oracle at Synth-3^12 (all ≤3-way): the preconditioned CG on the
  batched Kron chains must be ≥5× faster than forming/solving the dense
  normal equations;
* ``release/consistency/synth20`` — consistency + non-negativity at a
  Synth-10^20 all-≤3-way workload *completes* without densifying anything
  (the contingency table alone would be 8e14 GB) under a peak-RSS guard;
* ``release/nonneg_error/synth20`` — the postprocessed release's workload-
  weighted error is ≤ the raw unbiased release's against the true marginals;
* ``release/synthesize/synth20`` — 1M synthetic rows sampled from the
  Synth-10^20 release, rows/sec recorded.
"""
from __future__ import annotations

import resource
import time

import numpy as np

import jax

from repro.core import all_kway, select
from repro.data.tabular import marginals_from_records, synth_domain, \
    synthetic_records
from repro.release import (dense_wls_oracle, nonneg_release,
                           precision_weights, solve_consistency,
                           synth_report)

from .common import emit, timeit


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _perturbed_tables(plan, rng, scale=4.0):
    """A mutually *inconsistent* noisy family (what the solver exists for)."""
    out = {}
    for c in plan.workload.cliques:
        m = plan.domain.n_cells(c)
        base = rng.uniform(20.0, 60.0, m)
        out[c] = base * (1000.0 / base.sum()) + rng.normal(0, scale, m)
    return out


def bench_cg_vs_dense(fast: bool) -> None:
    dom = synth_domain(3, 12)
    wk = all_kway(dom, 3, include_lower=True)
    plan = select(wk, pcost_budget=1.0)
    rng = np.random.default_rng(0)
    tables = _perturbed_tables(plan, rng)
    cg = solve_consistency(plan, tables, backend="device")   # warm the jits
    us_cg = timeit(lambda: solve_consistency(plan, tables, backend="device",
                                             operator=cg.operator),
                   repeats=3, warmup=1)
    t0 = time.perf_counter()
    dense = dense_wls_oracle(plan, tables)
    us_dense = (time.perf_counter() - t0) * 1e6
    scale = max(1.0, float(np.abs(dense.r).max()))
    agree = float(np.abs(cg.r - dense.r).max() / scale)
    emit("release/consistency_cg/d12", us_cg,
         f"{us_dense / us_cg:.1f}x vs dense WLS",
         speedup_vs_dense=round(us_dense / us_cg, 2),
         dense_us=round(us_dense, 1), cg_iterations=cg.iterations,
         max_rel_diff_vs_dense=agree, n_coords=cg.operator.n_coords)


def bench_synth20(fast: bool) -> None:
    n_records = 50_000 if fast else 200_000
    dom = synth_domain(10, 20)
    wk = all_kway(dom, 3, include_lower=True)
    plan = select(wk, pcost_budget=1.0)
    records = synthetic_records(dom, n_records, seed=0)
    margs = marginals_from_records(dom, plan.cliques, records)
    engine = plan.engine(use_kernel=False, precompile=False)
    raw, meas = engine.release(margs, jax.random.PRNGKey(0))

    t0 = time.perf_counter()
    nn = nonneg_release(plan, raw)          # total fitted from the release
    post_s = time.perf_counter() - t0
    total = float(nn[wk.cliques[0]].sum())
    rss = _peak_rss_mb()
    dense_table_gb = dom.universe_size() * 8 / 2 ** 30
    emit("release/consistency/synth20", post_s * 1e6,
         f"peak_rss={rss:.0f}MB vs dense {dense_table_gb:.1e}GB",
         completes=True, peak_rss_mb=round(rss, 1),
         workload_marginals=len(wk.cliques),
         densify_impossible=bool(rss / 1024 < dense_table_gb))

    # workload-weighted error: postprocessed must beat the raw release
    w = precision_weights(plan)
    true = marginals_from_records(dom, wk.cliques, records)
    err_raw = err_nn = 0.0
    nonneg_violation = 0.0
    for wi, c in enumerate(wk.cliques):
        err_raw += w[wi] * float(((raw[c] - true[c]) ** 2).sum())
        err_nn += w[wi] * float(((nn[c] - true[c]) ** 2).sum())
        nonneg_violation = min(nonneg_violation, float(nn[c].min()))
    ratio = err_nn / err_raw
    emit("release/nonneg_error/synth20", post_s * 1e6,
         f"weighted err ratio {ratio:.3f} (<=1 required)",
         error_ratio=round(ratio, 4), min_cell=nonneg_violation,
         raw_weighted_err=err_raw, nonneg_weighted_err=err_nn)

    n_rows = 1_000_000
    t0 = time.perf_counter()
    recs = engine.synthesize(n_rows, jax.random.PRNGKey(1), tables=nn)
    synth_s = time.perf_counter() - t0
    report = synth_report(dom, nn, recs, total=total)
    emit("release/synthesize/synth20", synth_s * 1e6,
         f"{n_rows / synth_s:.0f} rows/s, max_tv={report.max_tv:.3f}",
         completes=True, rows=n_rows,
         rows_per_sec=round(n_rows / synth_s, 1),
         max_tv=round(report.max_tv, 4),
         peak_rss_mb=round(_peak_rss_mb(), 1))


def run(fast: bool = True) -> None:
    bench_cg_vs_dense(fast)
    bench_synth20(fast)
