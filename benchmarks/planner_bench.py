"""Planner benchmark: the arrayized PlanTable IR vs the legacy dict path.

Two configurations:

* a legacy-feasible comparison domain (d=40 fast / d=60 full, all ≤3-way)
  where both paths run and the speedup is gated in CI (BENCH_planner.json,
  floor 3×) — SoV selection (closure + coefficients + Lemma-2 closed form)
  and batched ``workload_variances`` vs the per-subset dict loop;
* the paper's headline scale: 100 attributes, all ≤3-way (166 751 closure
  cliques, ~1.3M incidence entries) — IR build, SoV selection, device
  ``lax.scan`` max-variance ascent, batched variances and batched
  cross-marginal covariances, each recorded in seconds.
"""
from __future__ import annotations

import resource
from itertools import combinations

import numpy as np

from repro.core.composite import compare_with_monolithic, select_dnc
from repro.core.domain import Domain, MarginalWorkload, all_kway, subsets
from repro.core.plantable import PlanTable, plan_table
from repro.core.residual import variance_coeff
from repro.core.select import (legacy_maxvar_sigmas, legacy_sov_sigmas,
                               select_max_variance, select_sum_of_variances)

from .common import emit, timeit


def _domain(d: int) -> Domain:
    """Synth-style mixed domain: sizes cycle 2..10."""
    return Domain.create([(i % 9) + 2 for i in range(d)])


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _blocked_workload(d: int, bs: int, k: int) -> MarginalWorkload:
    """Disjoint groups of ``bs`` attributes, all ≤k-way inside each group —
    the interaction graph decomposes exactly, so D&C must match monolithic."""
    cl = [()]
    for g in range(0, d, bs):
        attrs = range(g, min(g + bs, d))
        for w in range(1, k + 1):
            cl.extend(combinations(attrs, w))
    return MarginalWorkload(_domain(d), tuple(cl))


def _legacy_workload_variances(plan, wk):
    sig = plan.sigmas
    dom = wk.domain
    return {c: sum(sig[s] * variance_coeff(dom, s, c) for s in subsets(c))
            for c in wk.cliques}


def run(fast: bool = True) -> None:
    # ---------------- arrayized vs legacy (gated speedups) ----------------
    d_cmp = 40 if fast else 60
    dom = _domain(d_cmp)
    wk = all_kway(dom, 3, include_lower=True)

    t_leg_sov = timeit(lambda: legacy_sov_sigmas(wk, 1.0), repeats=3)

    def arrayized_sov():
        table = PlanTable.for_workload(wk)      # real build, no memo
        return select_sum_of_variances(wk, 1.0, table=table)

    t_arr_sov = timeit(arrayized_sov, repeats=3)
    emit(f"planner_sov_d{d_cmp}", t_arr_sov,
         f"speedup={t_leg_sov / t_arr_sov:.1f}x_vs_legacy",
         speedup_vs_legacy=round(t_leg_sov / t_arr_sov, 2),
         legacy_us=round(t_leg_sov, 1))

    table = plan_table(wk)
    plan = select_sum_of_variances(wk, 1.0, table=table)
    t_leg_var = timeit(lambda: _legacy_workload_variances(plan, wk), repeats=3)
    t_arr_var = timeit(lambda: plan.variances_array(), repeats=3)
    emit(f"planner_variances_d{d_cmp}", t_arr_var,
         f"speedup={t_leg_var / t_arr_var:.1f}x_vs_legacy",
         speedup_vs_legacy=round(t_leg_var / t_arr_var, 2),
         legacy_us=round(t_leg_var, 1))

    iters = 150
    t_leg_mv = timeit(lambda: legacy_maxvar_sigmas(wk, 1.0, iters=iters,
                                                   tol=0.0), repeats=1)
    t_arr_mv = timeit(lambda: select_max_variance(
        wk, 1.0, iters=iters, tol=0.0, table=table), repeats=1, warmup=1)
    emit(f"planner_maxvar_d{d_cmp}", t_arr_mv,
         f"speedup={t_leg_mv / t_arr_mv:.1f}x_vs_legacy_{iters}it",
         speedup_vs_legacy=round(t_leg_mv / t_arr_mv, 2),
         legacy_us=round(t_leg_mv, 1))
    # device lax.scan coverage (TPU path; CPU XLA scatter makes it slow here)
    t_dev_mv = timeit(lambda: select_max_variance(
        wk, 1.0, iters=iters, tol=0.0, table=table, backend="device",
        chunk=50), repeats=1, warmup=1)
    emit(f"planner_maxvar_scan_d{d_cmp}", t_dev_mv,
         f"lax.scan_{iters}it_warm",
         seconds=round(t_dev_mv / 1e6, 3))

    # ---------------- 100-attribute headline scale ----------------
    d = 100
    dom100 = _domain(d)
    wk100 = all_kway(dom100, 3, include_lower=True)

    t_build = timeit(lambda: PlanTable.for_workload(wk100), repeats=1)
    table100 = PlanTable.for_workload(wk100)
    emit(f"planner_build_d{d}", t_build,
         f"closure={table100.n}_nnz={table100.inc_vals.size}",
         seconds=round(t_build / 1e6, 3), closure=table100.n,
         nnz=int(table100.inc_vals.size))

    t_sov = timeit(lambda: select_sum_of_variances(wk100, 1.0, table=table100),
                   repeats=1)
    plan100 = select_sum_of_variances(wk100, 1.0, table=table100)
    emit(f"planner_sov_d{d}", t_sov, "closed_form",
         seconds=round(t_sov / 1e6, 3))

    mv_iters = 100
    t_mv = timeit(lambda: select_max_variance(
        wk100, 1.0, iters=mv_iters, tol=1e-6, table=table100), repeats=1)
    emit(f"planner_maxvar_d{d}", t_mv,
         f"auto_backend_{mv_iters}it",
         seconds=round(t_mv / 1e6, 3), iters=mv_iters)

    t_var = timeit(lambda: plan100.variances_array(), repeats=3)
    emit(f"planner_variances_d{d}", t_var,
         f"batched_{table100.m}_marginals",
         seconds=round(t_var / 1e6, 3), marginals=table100.m)

    rng = np.random.default_rng(0)
    wcl = wk100.cliques
    pairs = [(wcl[i], wcl[j]) for i, j in
             rng.integers(0, len(wcl), size=(1000, 2))]
    t_cov = timeit(lambda: plan100.workload_covariances(pairs), repeats=3)
    emit(f"planner_covariances_d{d}", t_cov, "batched_1000_pairs",
         seconds=round(t_cov / 1e6, 3), pairs=1000)

    # ---------------- divide-and-conquer: past the monolithic ceiling ------
    # parity gate at a scale where both routes run: 8 disjoint 5-attribute
    # groups, all ≤3-way — no clique straddles a cut, so the D&C SoV plan
    # must reproduce the monolithic optimum to fp accuracy (CI gates ≤1%)
    wk40 = _blocked_workload(40, 5, 3)
    rep = compare_with_monolithic(wk40, 1.0)
    t_par = timeit(lambda: select_dnc(wk40, 1.0), repeats=3)
    emit("planner_dnc_parity_d40", t_par,
         f"ratio={rep['ratio']:.6f}_blocks={int(rep['n_blocks'])}",
         ratio=round(rep["ratio"], 9),
         max_rel_marginal_diff=float(rep["max_rel_marginal_diff"]),
         exact_partition=bool(rep["exact_partition"]),
         n_blocks=int(rep["n_blocks"]))

    # d=200 all ≤3-way: ~10.6M estimated incidence entries — past the
    # strategy="auto" threshold; one connected component, split at
    # DEFAULT_MAX_BLOCK, straddlers answered by the product correction
    wk200 = all_kway(_domain(200), 3, include_lower=True)
    t200 = timeit(lambda: select_dnc(wk200, 1.0), repeats=1)
    emit("planner_dnc_build_d200", t200, "sov_end_to_end",
         seconds=round(t200 / 1e6, 3), peak_rss_mb=round(_peak_rss_mb(), 1))

    # d=500 all ≤2-way: the headline D&C scale (the monolithic closure would
    # not fit); select + the full per-marginal variance sweep
    wk500 = all_kway(_domain(500), 2, include_lower=True)

    def dnc500():
        p = select_dnc(wk500, 1.0)
        p.variances_array()
        return p

    t500 = timeit(dnc500, repeats=1)
    emit("planner_dnc_sov_d500", t500, "sov_plus_variances_end_to_end",
         seconds=round(t500 / 1e6, 3), peak_rss_mb=round(_peak_rss_mb(), 1))
