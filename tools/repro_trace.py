#!/usr/bin/env python
"""repro-trace CLI — render JSONL span trees from the tracing subsystem.

Input is the sink written by ``REPRO_TRACE=/path`` or
``python -m repro.launch.serve --trace /path`` (one JSON span per line, see
src/repro/obs/trace.py for the schema).  Usage:

    python tools/repro_trace.py trace.jsonl              # waterfall per trace
    python tools/repro_trace.py trace.jsonl --list       # one line per trace
    python tools/repro_trace.py trace.jsonl --trace-id 8f3c0a...
    python tools/repro_trace.py trace.jsonl --kernels    # per-chain timing
    python tools/repro_trace.py trace.jsonl --json       # machine-readable

The waterfall shows, for every request trace, the span tree (indent =
parent link) with a time bar scaled to the trace's wall clock, plus a
critical-path breakdown: how much of the root span went to queue wait,
ledger charge, fused measurement (with kernel time called out separately),
release postprocessing, and synthesis.  See docs/OBSERVABILITY.md.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List, Optional

BAR_WIDTH = 40

# Span names that make up the serve critical path, in pipeline order.
# "kernel" is reported as a sub-bucket of "measure" (kernel.chain spans are
# children of serve.fuse / engine.measure, so their time is already inside
# the measure bucket — double counting it in the sum would overshoot 100%).
PHASES = (
    ("queue_wait", ("serve.queue_wait",)),
    ("charge", ("serve.charge",)),
    ("measure", ("serve.fuse", "engine.measure")),
    ("release", ("engine.reconstruct", "release.postprocess")),
    ("synthesize", ("serve.synthesize",)),
)


def load_spans(path: str) -> List[dict]:
    spans = []
    with open(path, encoding="utf-8") as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                print(f"warning: {path}:{i + 1}: unparseable line skipped",
                      file=sys.stderr)
                continue
            if "trace" in rec and "span" in rec:
                spans.append(rec)
    return spans


def group_traces(spans: List[dict]) -> Dict[str, List[dict]]:
    traces: Dict[str, List[dict]] = defaultdict(list)
    for s in spans:
        traces[s["trace"]].append(s)
    return traces


def find_root(spans: List[dict]) -> Optional[dict]:
    """The root is the span whose parent is absent from this trace."""
    ids = {s["span"] for s in spans}
    roots = [s for s in spans if not s.get("parent") or s["parent"] not in ids]
    if not roots:
        return None
    return min(roots, key=lambda s: s["t0"])


def children_index(spans: List[dict]) -> Dict[Optional[str], List[dict]]:
    kids: Dict[Optional[str], List[dict]] = defaultdict(list)
    ids = {s["span"] for s in spans}
    for s in spans:
        parent = s.get("parent")
        kids[parent if parent in ids else None].append(s)
    for v in kids.values():
        v.sort(key=lambda s: (s["t0"], s["t1"]))
    return kids


def _fmt_ms(us: float) -> str:
    return f"{us / 1000.0:8.3f}ms"


def _attr_str(attrs: dict) -> str:
    if not attrs:
        return ""
    parts = [f"{k}={v}" for k, v in attrs.items()]
    return " [" + " ".join(parts) + "]"


def render_waterfall(trace_id: str, spans: List[dict], out=sys.stdout) -> None:
    t_min = min(s["t0"] for s in spans)
    t_max = max(s["t1"] for s in spans)
    wall = max(t_max - t_min, 1e-12)
    kids = children_index(spans)
    root = find_root(spans)
    out.write(f"trace {trace_id}  ({len(spans)} spans, "
              f"{wall * 1e3:.3f}ms wall)\n")

    def bar(s: dict) -> str:
        lo = int((s["t0"] - t_min) / wall * BAR_WIDTH)
        hi = int((s["t1"] - t_min) / wall * BAR_WIDTH)
        hi = max(hi, lo + 1)
        return " " * lo + "#" * (hi - lo) + " " * (BAR_WIDTH - hi)

    def walk(s: dict, depth: int) -> None:
        name = "  " * depth + s["name"]
        out.write(f"  {name:<34} |{bar(s)}| {_fmt_ms(s['dur_us'])}"
                  f"{_attr_str(s.get('attrs') or {})}\n")
        for c in kids.get(s["span"], ()):
            walk(c, depth + 1)

    top = kids.get(None, [])
    if root is not None and root not in top:
        top = [root] + top
    for s in top:
        walk(s, 0)
    breakdown = critical_path(spans)
    if breakdown:
        out.write("  critical path: " + "  ".join(
            f"{k}={v / 1000.0:.3f}ms" for k, v in breakdown.items()) + "\n")
    out.write("\n")


def critical_path(spans: List[dict]) -> Dict[str, float]:
    """Phase breakdown of one trace in microseconds.

    Each bucket sums the spans listed in :data:`PHASES`; ``kernel`` reports
    the kernel.chain time nested inside the measure bucket; ``other`` is the
    root duration not covered by any top-level bucket (scheduling, python
    glue).  Buckets with zero time are omitted.
    """
    root = find_root(spans)
    by_phase: Dict[str, float] = {}
    for phase, names in PHASES:
        t = sum(s["dur_us"] for s in spans if s["name"] in names)
        if t > 0:
            by_phase[phase] = t
    kern = sum(s["dur_us"] for s in spans if s["name"] == "kernel.chain")
    if kern > 0:
        by_phase["kernel"] = kern
    if root is not None:
        covered = sum(v for k, v in by_phase.items() if k != "kernel")
        other = root["dur_us"] - covered
        if other > 0.05 * root["dur_us"]:
            by_phase["other"] = other
        by_phase["total"] = root["dur_us"]
    return by_phase


def kernel_table(spans: List[dict], out=sys.stdout) -> List[dict]:
    """Per-chain kernel launch timing, aggregated over every trace."""
    groups: Dict[tuple, List[dict]] = defaultdict(list)
    for s in spans:
        if s["name"] != "kernel.chain":
            continue
        attrs = s.get("attrs") or {}
        groups[(str(attrs.get("chain", "?")),
                bool(attrs.get("fused", False)))].append(s)
    rows = []
    for (chain, fused), ss in sorted(groups.items()):
        durs = sorted(s["dur_us"] for s in ss)
        rows.append({
            "chain": chain, "fused": fused, "launches": len(ss),
            "total_ms": sum(durs) / 1000.0,
            "mean_us": sum(durs) / len(durs),
            "min_us": durs[0], "max_us": durs[-1],
            "tune_source": (ss[0].get("attrs") or {}).get("tune_source"),
        })
    if out is not None:
        out.write(f"{'chain':<20} {'fused':>5} {'n':>5} {'total':>10} "
                  f"{'mean':>10} {'min':>10} {'max':>10}  tune\n")
        for r in rows:
            out.write(f"{r['chain']:<20} {str(r['fused']):>5} "
                      f"{r['launches']:>5} {r['total_ms']:>9.3f}m "
                      f"{r['mean_us']:>9.1f}u {r['min_us']:>9.1f}u "
                      f"{r['max_us']:>9.1f}u  {r['tune_source']}\n")
    return rows


def list_traces(traces: Dict[str, List[dict]], out=sys.stdout) -> List[dict]:
    rows = []
    for tid, spans in sorted(traces.items(),
                             key=lambda kv: min(s["t0"] for s in kv[1])):
        root = find_root(spans)
        attrs = (root.get("attrs") or {}) if root else {}
        rows.append({
            "trace": tid, "spans": len(spans),
            "root": root["name"] if root else "?",
            "dur_ms": (root["dur_us"] / 1000.0) if root else None,
            "tenant": attrs.get("tenant"), "outcome": attrs.get("outcome"),
        })
    if out is not None:
        out.write(f"{'trace':<18} {'spans':>5} {'root':<16} {'dur':>10} "
                  f"{'tenant':<12} outcome\n")
        for r in rows:
            dur = f"{r['dur_ms']:.3f}ms" if r["dur_ms"] is not None else "?"
            out.write(f"{r['trace']:<18} {r['spans']:>5} {r['root']:<16} "
                      f"{dur:>10} {str(r['tenant']):<12} {r['outcome']}\n")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render JSONL traces from the repro obs subsystem")
    ap.add_argument("path", help="JSONL trace file (REPRO_TRACE sink)")
    ap.add_argument("--trace-id", default=None,
                    help="render only this trace (prefix match)")
    ap.add_argument("--list", action="store_true",
                    help="one summary line per trace, no waterfalls")
    ap.add_argument("--kernels", action="store_true",
                    help="per-chain kernel timing table only")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the report as JSON instead of text")
    args = ap.parse_args(argv)

    spans = load_spans(args.path)
    if not spans:
        print(f"no spans in {args.path}", file=sys.stderr)
        return 1
    traces = group_traces(spans)
    if args.trace_id:
        traces = {tid: ss for tid, ss in traces.items()
                  if tid.startswith(args.trace_id)}
        if not traces:
            print(f"no trace matching {args.trace_id!r}", file=sys.stderr)
            return 1

    if args.as_json:
        report = {
            "traces": list_traces(traces, out=None),
            "critical_path": {tid: critical_path(ss)
                              for tid, ss in traces.items()},
            "kernels": kernel_table(spans, out=None),
        }
        json.dump(report, sys.stdout, indent=2)
        sys.stdout.write("\n")
        return 0
    if args.kernels:
        kernel_table(spans)
        return 0
    if args.list:
        list_traces(traces)
        return 0
    for tid in sorted(traces,
                      key=lambda t: min(s["t0"] for s in traces[t])):
        render_waterfall(tid, traces[tid])
    if any(s["name"] == "kernel.chain" for s in spans):
        print("kernel launches:")
        kernel_table(spans)
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:            # e.g. `repro_trace.py --json | head`
        import os
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(0)
