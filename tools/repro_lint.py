#!/usr/bin/env python
"""repro-lint CLI — static analysis gate for the repro tree.

Thin wrapper so CI and developers can run the analyzer without installing
the package:

    python tools/repro_lint.py --gate          # CI: zero new findings
    python tools/repro_lint.py src/repro/serve # one subtree
    python tools/repro_lint.py --rules         # rule catalog

See docs/ANALYSIS.md for the rule catalog, the annotation syntax, and the
baseline workflow.
"""
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis.driver import main  # noqa: E402

if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:            # e.g. `repro_lint.py --rules | head`
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(0)
