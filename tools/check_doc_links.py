"""Fail on broken intra-repo links in markdown docs (CI: docs job).

Checks every ``[text](target)`` and bare ``<target>`` link in the given
markdown files.  External links (http/https/mailto) are skipped — CI must not
flake on the network.  Relative targets are resolved against the containing
file; ``#anchor`` fragments are validated against the GitHub-style slugs of
the target file's headings.

Usage::

    python tools/check_doc_links.py README.md docs/*.md
    python tools/check_doc_links.py            # default: README.md + docs/*.md
"""
from __future__ import annotations

import glob
import os
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
IMAGE_RE = re.compile(r"\!\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces -> dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)   # linkified headings
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def heading_slugs(path: str) -> set:
    with open(path, encoding="utf-8") as fh:
        body = CODE_FENCE_RE.sub("", fh.read())
    slugs, seen = set(), {}
    for m in HEADING_RE.finditer(body):
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_file(path: str, repo_root: str) -> list:
    errors = []
    with open(path, encoding="utf-8") as fh:
        raw = fh.read()
    body = CODE_FENCE_RE.sub("", raw)
    targets = LINK_RE.findall(body) + IMAGE_RE.findall(body)
    for target in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, frag = target.partition("#")
        if not base:                                     # same-file anchor
            dest = path
        else:
            dest = os.path.normpath(os.path.join(os.path.dirname(path), base))
        rel = os.path.relpath(dest, repo_root)
        in_repo = not os.path.relpath(os.path.abspath(path),
                                      repo_root).startswith("..")
        if in_repo and rel.startswith(".."):
            errors.append(f"{path}: link escapes the repo: {target}")
            continue
        if not os.path.exists(dest):
            errors.append(f"{path}: broken link target: {target}")
            continue
        if frag and dest.endswith(".md"):
            if frag not in heading_slugs(dest):
                errors.append(f"{path}: missing anchor #{frag} in {rel} "
                              f"(from link {target})")
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if args:
        files = args
    else:
        files = ([os.path.join(repo_root, "README.md")]
                 + sorted(glob.glob(os.path.join(repo_root, "docs", "*.md"))))
    errors = []
    for f in files:
        errors.extend(check_file(f, repo_root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
