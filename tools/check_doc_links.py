"""Fail on broken intra-repo links in markdown docs (CI: docs job).

Checks every ``[text](target)`` and bare ``<target>`` link in the given
markdown files.  External links (http/https/mailto) are skipped — CI must not
flake on the network.  Relative targets are resolved against the containing
file; ``#anchor`` fragments are validated against the GitHub-style slugs of
the target file's headings.

Section references are validated too: ``DESIGN.md §14`` (named file) and
bare ``§3.2`` (same file) must point at an existing ``## §N``-numbered
heading — a renumbered or deleted section turns every stale textual
reference into a CI failure, not a silent lie.  Code fences are exempt.

Usage::

    python tools/check_doc_links.py README.md docs/*.md
    python tools/check_doc_links.py            # default: README.md + docs/*.md
"""
from __future__ import annotations

import glob
import os
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
IMAGE_RE = re.compile(r"\!\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
SECTION_HEADING_RE = re.compile(r"^#{1,6}\s+§(\d+(?:\.\d+)*)\b", re.MULTILINE)
SECTION_REF_RE = re.compile(r"(?:([\w./-]+\.md)\s+)?§(\d+(?:\.\d+)*)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces -> dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)   # linkified headings
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def heading_slugs(path: str) -> set:
    with open(path, encoding="utf-8") as fh:
        body = CODE_FENCE_RE.sub("", fh.read())
    slugs, seen = set(), {}
    for m in HEADING_RE.finditer(body):
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def section_numbers(path: str) -> set:
    """§ numbers ('14', '3.2') declared by a file's ``## §N`` headings."""
    with open(path, encoding="utf-8") as fh:
        body = CODE_FENCE_RE.sub("", fh.read())
    return set(SECTION_HEADING_RE.findall(body))


def check_section_refs(path: str, body: str) -> list:
    errors = []
    own = None                                       # lazy: most files have none
    for m in SECTION_REF_RE.finditer(body):
        named, num = m.group(1), m.group(2)
        if named:
            dest = os.path.normpath(
                os.path.join(os.path.dirname(path), named))
            if not os.path.exists(dest):
                # try docs/ for README-style "DESIGN.md §10" shorthand
                alt = os.path.join(os.path.dirname(path), "docs", named)
                if os.path.exists(alt):
                    dest = alt
                else:
                    errors.append(f"{path}: §-reference to missing file: "
                                  f"{named} §{num}")
                    continue
            declared = section_numbers(dest)
            if declared and num not in declared:
                errors.append(f"{path}: dangling reference {named} §{num} "
                              f"(no '§{num}' heading there)")
        else:
            if own is None:
                own = section_numbers(path)
            if own and num not in own:
                errors.append(f"{path}: dangling same-file reference §{num}")
    return errors


def check_file(path: str, repo_root: str) -> list:
    errors = []
    with open(path, encoding="utf-8") as fh:
        raw = fh.read()
    body = CODE_FENCE_RE.sub("", raw)
    targets = LINK_RE.findall(body) + IMAGE_RE.findall(body)
    for target in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, frag = target.partition("#")
        dest = path if not base else os.path.normpath(   # bare #frag: same file
            os.path.join(os.path.dirname(path), base))
        rel = os.path.relpath(dest, repo_root)
        in_repo = not os.path.relpath(os.path.abspath(path),
                                      repo_root).startswith("..")
        if in_repo and rel.startswith(".."):
            errors.append(f"{path}: link escapes the repo: {target}")
            continue
        if not os.path.exists(dest):
            errors.append(f"{path}: broken link target: {target}")
            continue
        if frag and dest.endswith(".md") and frag not in heading_slugs(dest):
            errors.append(f"{path}: missing anchor #{frag} in {rel} "
                          f"(from link {target})")
    errors.extend(check_section_refs(path, body))
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = args or (
        [os.path.join(repo_root, "README.md")]
        + sorted(glob.glob(os.path.join(repo_root, "docs", "*.md"))))
    errors = []
    for f in files:
        errors.extend(check_file(f, repo_root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
