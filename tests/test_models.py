"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness asserts, decode/prefill consistency, mLSTM oracle check."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, load_all
from repro.configs.shapes import SHAPES, cell_is_applicable, input_specs, reduced_config
from repro.models import Model

load_all()


def _batch(cfg, key, B=2, S=16):
    batch = {}
    if cfg.frontend == "embed_stub":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_step(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, remat=False)))(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))
    # one optimizer step moves the loss
    from repro.train import AdamWConfig, apply_updates, init_opt_state
    oc = AdamWConfig(lr=1e-2, warmup_steps=1)
    new_p, _, _ = apply_updates(params, grads, init_opt_state(params, oc), oc)
    loss2 = float(model.loss_fn(new_p, batch, remat=False))
    assert np.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode logits from the cache match teacher-forced forward."""
    cfg = reduced_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    pf = {k: v for k, v in batch.items() if k != "labels"}
    logits_last, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=S + 4))(params, pf)
    assert logits_last.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits_last[:, -1], axis=-1).astype(jnp.int32)[:, None]
    logits_d, caches2 = jax.jit(model.decode_step)(params, tok, caches,
                                                   jnp.asarray(S))
    assert logits_d.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits_d, np.float32)))
    leaves1 = jax.tree_util.tree_leaves(caches)
    leaves2 = jax.tree_util.tree_leaves(caches2)
    assert len(leaves1) == len(leaves2)
    for a, b in zip(leaves1, leaves2):
        assert a.shape == b.shape


def test_mlstm_chunkwise_matches_recurrent_oracle():
    from repro.models.recurrent import mlstm_apply, mlstm_recurrent_oracle, mlstm_defs
    from repro.models.layers import init_from_defs
    cfg = reduced_config("xlstm-350m")
    key = jax.random.PRNGKey(3)
    p = init_from_defs(mlstm_defs(cfg), key, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32) * 0.5
    got, _ = mlstm_apply(p, x, cfg=cfg, mode="train", chunk=8)
    want = mlstm_recurrent_oracle(p, x, cfg=cfg)
    err = np.max(np.abs(np.asarray(got, np.float32) - np.asarray(want)))
    assert err < 2e-2 * (np.max(np.abs(np.asarray(want))) + 1e-6)


def test_rglru_decode_matches_prefill_tail():
    from repro.models.recurrent import rglru_apply, rglru_defs
    from repro.models.layers import init_from_defs
    cfg = reduced_config("recurrentgemma-2b")
    key = jax.random.PRNGKey(4)
    p = init_from_defs(rglru_defs(cfg), key, jnp.float32)
    x = jax.random.normal(key, (2, 9, cfg.d_model), jnp.float32)
    full, cache_full = rglru_apply(p, x, cfg=cfg, mode="prefill")
    part, cache = rglru_apply(p, x[:, :8], cfg=cfg, mode="prefill")
    step, _ = rglru_apply(p, x[:, 8:9], cfg=cfg, mode="decode", cache=cache)
    assert np.allclose(np.asarray(step), np.asarray(full[:, 8:9]), atol=1e-4)


def test_local_attention_matches_masked_full():
    from repro.models.layers import blockwise_attention, local_attention
    key = jax.random.PRNGKey(5)
    B, S, H, dh, W = 2, 32, 4, 8, 8
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, dh))
    pos = jnp.arange(S)
    a = local_attention(q, k, v, pos, pos, window=W)
    b = blockwise_attention(q, k, v, pos, pos, causal=True, window=W,
                            kv_block=16)
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_blockwise_attention_matches_naive():
    key = jax.random.PRNGKey(6)
    from repro.models.layers import blockwise_attention
    B, S, H, dh = 2, 24, 4, 8
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 2, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 2, dh))
    pos = jnp.arange(S)
    got = blockwise_attention(q, k, v, pos, pos, causal=True, kv_block=8)
    # naive
    G = H // 2
    qg = np.asarray(q).reshape(B, S, 2, G, dh)
    s = np.einsum("bskgd,btkd->bskgt", qg, np.asarray(k)) / np.sqrt(dh)
    mask = pos[None, :] <= pos[:, None]
    s = np.where(mask[None, :, None, None, :], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bskgt,btkd->bskgd", p, np.asarray(v)).reshape(B, S, H, dh)
    assert np.allclose(np.asarray(got), want, atol=1e-4)


def test_input_specs_cover_all_cells():
    n_cells = 0
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, why = cell_is_applicable(arch, shape)
            n_cells += 1
            if ok:
                specs = input_specs(arch, shape)
                assert specs, (arch, shape)
                for s in specs.values():
                    assert isinstance(s, jax.ShapeDtypeStruct)
    assert n_cells == 40
