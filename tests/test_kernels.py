"""Pallas kron kernels vs the pure-jnp oracle: shape/dtype sweeps (per the
brief) in interpret mode."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.residual import sub_matrix, sub_pinv
from repro.kernels.kron_matvec.ops import (kron_matvec_kernel,
                                           residual_measure_kernel)
from repro.kernels.kron_matvec.ref import kron_matvec_ref, residual_measure_ref


def _rand_factor(rng, n, kind):
    if kind == 0:
        return None
    if kind == 1:
        return "ones"
    if kind == 2:
        return sub_matrix(n)
    if kind == 3:
        return sub_pinv(n).T if n > 1 else np.ones((1, 1))
    return rng.standard_normal((rng.integers(1, n + 2), n))


@settings(deadline=None, max_examples=30)
@given(st.lists(st.tuples(st.integers(2, 9), st.integers(0, 4)),
                min_size=1, max_size=4),
       st.integers(0, 10 ** 6))
def test_kron_kernel_matches_ref(spec, seed):
    rng = np.random.default_rng(seed)
    dims = [n for n, _ in spec]
    facs = [_rand_factor(rng, n, k) for n, k in spec]
    x = rng.standard_normal(int(np.prod(dims))).astype(np.float32)
    got = np.asarray(kron_matvec_kernel(facs, x, dims))
    want = np.asarray(kron_matvec_ref(facs, jnp.asarray(x), dims))
    assert got.shape == want.shape
    scale = max(np.abs(want).max(), 1e-6)
    assert np.max(np.abs(got - want)) / scale < 2e-5


@pytest.mark.parametrize("dims", [[2], [100], [2, 2, 2, 2], [3, 4, 5],
                                  [17, 6], [2, 50, 3]])
def test_residual_measure_fused(dims, rng):
    facs = [sub_matrix(n) for n in dims]
    v = rng.standard_normal(int(np.prod(dims))).astype(np.float32)
    z = rng.standard_normal(int(np.prod(dims))).astype(np.float32)
    got = np.asarray(residual_measure_kernel(facs, v, z, 1.3, dims))
    want = np.asarray(residual_measure_ref(facs, jnp.asarray(v),
                                           jnp.asarray(z), 1.3, dims))
    scale = max(np.abs(want).max(), 1e-6)
    assert np.max(np.abs(got - want)) / scale < 2e-5


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_kernel_dtype_sweep(dtype, rng):
    dims = [4, 7]
    facs = [sub_matrix(4), sub_matrix(7)]
    x = rng.standard_normal(28).astype(dtype)
    got = np.asarray(kron_matvec_kernel(facs, x, dims))
    want = np.asarray(kron_matvec_ref(facs, jnp.asarray(x, jnp.float32), dims))
    assert np.allclose(got, want, atol=1e-4)


def test_kernel_in_measurement_path(rng):
    """`measure(..., use_kernel=True)` equals the jnp path bit-for-bit in fp32."""
    import jax
    from repro.core import (Domain, MarginalWorkload, exact_marginals_from_x,
                            measure, select_sum_of_variances)
    dom = Domain.create([3, 4, 2])
    wk = MarginalWorkload(dom, ((0, 1), (1, 2)))
    plan = select_sum_of_variances(wk, 1.0)
    x = rng.integers(0, 9, 24).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    key = jax.random.PRNGKey(7)
    a = measure(plan, margs, key, use_kernel=False)
    b = measure(plan, margs, key, use_kernel=True)
    for c in plan.cliques:
        assert np.allclose(a[c].omega, b[c].omega, atol=1e-4)
