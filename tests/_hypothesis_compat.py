"""Import hypothesis if available; otherwise a minimal deterministic fallback.

``hypothesis`` is a dev-extra (pyproject.toml ``[project.optional-dependencies]
dev``), but the suite must collect and run without it — CI images and the
hermetic benchmark container don't ship it.  The fallback implements just the
strategy surface these tests use (``integers``, ``floats``, ``lists``,
``tuples``) and a
``@given`` that replays a fixed number of seeded pseudo-random examples, so
property tests degrade to deterministic fuzzing instead of import errors.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised when hypothesis absent
    import functools
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 15

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _Strategies:
        """The subset of hypothesis.strategies used by this suite."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value,
                                                          max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                           max_value)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(size)]
            return _Strategy(sample)

        @staticmethod
        def tuples(*parts):
            return _Strategy(lambda rng: tuple(p.example(rng) for p in parts))

    st = _Strategies()

    def given(*strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", _DEFAULT_EXAMPLES)
                # crc32, not hash(): str hashing is salted per process and
                # would make failing examples unreproducible across runs.
                rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(*args, *(s.example(rng) for s in strategies), **kwargs)
            # Hide the wrapped signature: pytest must not try to resolve the
            # strategy-filled parameters as fixtures.
            del wrapper.__wrapped__
            return wrapper
        return decorate

    def settings(deadline=None, max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def decorate(fn):
            fn._compat_max_examples = max_examples
            return fn
        return decorate
