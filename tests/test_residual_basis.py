"""Theorem 1 & Lemma 1: residual bases are orthogonal, complete, closed-form."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Domain, all_kway, closure, subsets
from repro.core.residual import (expand_marginal, expand_residual, sub_matrix,
                                 sub_pinv, sub_gram)

doms = st.lists(st.integers(2, 5), min_size=1, max_size=4)


@given(st.integers(2, 40))
def test_sub_pinv_closed_form(m):
    s = sub_matrix(m)
    sp = sub_pinv(m)
    assert np.allclose(sp, np.linalg.pinv(s), atol=1e-10)
    assert np.allclose(s @ sp, np.eye(m - 1), atol=1e-10)     # right inverse


@given(st.integers(2, 30))
def test_sub_gram(m):
    s = sub_matrix(m)
    assert np.allclose(s @ s.T, sub_gram(m))


@settings(deadline=None, max_examples=25)
@given(doms)
def test_residual_orthogonality(sizes):
    dom = Domain.create(sizes)
    cliques = closure([tuple(range(dom.n_attrs))])
    mats = {c: expand_residual(dom, c) for c in cliques}
    for a in cliques:
        for b in cliques:
            if a != b:
                assert np.allclose(mats[a] @ mats[b].T, 0.0, atol=1e-8), (a, b)


@settings(deadline=None, max_examples=25)
@given(doms)
def test_residual_spans_marginal(sizes):
    """Rows of {R_A' : A' ⊆ A} form a basis of rowspace(Q_A) with matching count."""
    dom = Domain.create(sizes)
    A = tuple(range(dom.n_attrs))
    Q = expand_marginal(dom, A)
    R = np.vstack([expand_residual(dom, c) for c in subsets(A)])
    assert R.shape[0] == Q.shape[0]
    assert np.linalg.matrix_rank(R) == R.shape[0]             # independent
    # every row of Q is a combination of rows of R
    proj = R.T @ np.linalg.solve(R @ R.T, R @ Q.T)
    assert np.allclose(proj.T, Q, atol=1e-8)


def test_residual_size_counts():
    dom = Domain.create([3, 4, 2])
    wk = all_kway(dom, 2, include_lower=True)
    total = sum(dom.residual_size(c) for c in closure(wk.cliques))
    # Thm 2: number of noisy scalars equals total basis size; for the full
    # closure of all attrs it equals the universe size.
    full = sum(dom.residual_size(c) for c in closure([(0, 1, 2)]))
    assert full == dom.universe_size()
    assert total <= full
