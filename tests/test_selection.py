"""Selection phase: Lemma 2 closed form, Appendix A numbers, paper Tables 4/5
constants, max-variance dual solver optimality, SVD-bound tightness."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Domain, MarginalWorkload, all_kway, pcost_of_plan
from repro.core.select import (_coefficients, select_max_variance,
                               select_sum_of_variances)
from repro.baselines.svdb import (svd_bound_dense, svd_bound_marginals,
                                  svdb_rmse_marginals)
from repro.core.residual import expand_marginal
from repro.data.tabular import ADULT_SIZES, CPS_SIZES, LOANS_SIZES


def test_appendix_a_runthrough():
    """The paper's full worked example (Appendix A.5–A.6)."""
    dom = Domain.create([2, 2, 3])
    wk = MarginalWorkload(dom, ((0,), (0, 1), (1, 2)),
                          {(0,): 2.0, (0, 1): 4.0, (1, 2): 6.0})
    cl, p, v = _coefficients(wk)
    want_p = {(): 1, (0,): .5, (1,): .5, (2,): 2 / 3, (0, 1): .25, (1, 2): 1 / 3}
    want_v = {(): 11 / 12, (0,): 1.5, (1,): 5 / 6, (2,): 1.0, (0, 1): 1.0,
              (1, 2): 2.0}
    for c, pi, vi in zip(cl, p, v):
        assert math.isclose(pi, want_p[c], rel_tol=1e-12)
        assert math.isclose(vi, want_v[c], rel_tol=1e-12)
    T = float(np.sqrt(p * v).sum()) ** 2
    assert abs(T - 21.18) < 0.01                      # paper: ≈ 21.18
    plan = select_sum_of_variances(wk, 1.0)
    assert abs(plan.sigmas[()] - 4.8) < 0.02          # paper: ≈ 4.8
    assert math.isclose(pcost_of_plan(plan), 1.0, rel_tol=1e-9)


PAPER_TABLE4 = {  # RMSE at pcost=1 — ResidualPlanner == SVD bound
    "adult": (ADULT_SIZES, {1: 3.047, 2: 6.359, 3: 10.515, "le3": 10.665}),
    "cps": (CPS_SIZES, {1: 1.744, 2: 2.035, 3: 2.048, "le3": 2.276}),
    "loans": (LOANS_SIZES, {1: 2.875, 2: 5.634, 3: 8.702, "le3": 8.876}),
}


@pytest.mark.parametrize("name", list(PAPER_TABLE4))
def test_paper_table4_rmse_and_svdb(name):
    sizes, want = PAPER_TABLE4[name]
    dom = Domain.create(sizes)
    for key, val in want.items():
        k, lower = (3, True) if key == "le3" else (key, False)
        wk = all_kway(dom, k, include_lower=lower)
        plan = select_sum_of_variances(
            wk, 1.0, {c: float(dom.n_cells(c)) for c in wk.cliques})
        assert abs(plan.rmse() - val) < 2e-3, (name, key)
        assert abs(svdb_rmse_marginals(wk) - plan.rmse()) < 1e-9  # optimal


PAPER_TABLE5 = {  # Max variance at pcost=1 (ResPlan column)
    "adult": (ADULT_SIZES, {1: 12.047, 2: 67.802, 3: 236.843}),
    "cps": (CPS_SIZES, {1: 4.346, 2: 7.897, 3: 7.706}),
    "loans": (LOANS_SIZES, {1: 10.640, 2: 52.217, 3: 156.638}),
}


@pytest.mark.parametrize("name", list(PAPER_TABLE5))
def test_paper_table5_maxvar(name):
    sizes, want = PAPER_TABLE5[name]
    dom = Domain.create(sizes)
    for k, val in want.items():
        wk = all_kway(dom, k)
        plan = select_max_variance(wk, 1.0)
        assert abs(plan.max_variance() - val) / val < 2e-3, (name, k)
        assert abs(pcost_of_plan(plan) - 1.0) < 1e-6


def test_maxvar_never_worse_than_sov_plan():
    dom = Domain.create([7, 3, 5, 2])
    wk = all_kway(dom, 2, include_lower=True)
    mv = select_max_variance(wk, 1.0)
    sov = select_sum_of_variances(wk, 1.0)
    assert mv.max_variance() <= sov.max_variance() + 1e-9


@settings(deadline=None, max_examples=15)
@given(st.lists(st.integers(2, 5), min_size=2, max_size=4),
       st.integers(1, 2))
def test_svdb_matches_dense_and_is_tight(sizes, k):
    dom = Domain.create(sizes)
    k = min(k, dom.n_attrs)
    wk = all_kway(dom, k, include_lower=True)
    W = np.vstack([expand_marginal(dom, c) for c in wk.cliques])
    dense = svd_bound_dense(W)
    scal = svd_bound_marginals(wk)
    assert math.isclose(dense, scal, rel_tol=1e-9)
    plan = select_sum_of_variances(
        wk, 1.0, {c: float(dom.n_cells(c)) for c in wk.cliques})
    assert math.isclose(plan.total_variance(), scal, rel_tol=1e-9)


def test_budget_scaling():
    """σ² scale linearly in 1/c; loss scales as 1/c (homogeneity)."""
    dom = Domain.create([4, 3])
    wk = all_kway(dom, 2, include_lower=True)
    p1 = select_sum_of_variances(wk, 1.0)
    p2 = select_sum_of_variances(wk, 2.0)
    for c in p1.cliques:
        assert math.isclose(p1.sigmas[c], 2 * p2.sigmas[c], rel_tol=1e-9)


def test_utility_constrained_eq2():
    """Eq. 2 (min pcost s.t. loss <= gamma) via exact homogeneity rescaling."""
    from repro.core.select import select_utility_constrained
    from repro.core.mechanism import pcost_of_plan
    dom = Domain.create([5, 3, 4])
    wk = all_kway(dom, 2, include_lower=True)
    gamma = 7.5
    plan = select_utility_constrained(wk, gamma)
    loss = sum(wk.weight(c) * plan.marginal_variance(c) for c in wk.cliques)
    assert math.isclose(loss, gamma, rel_tol=1e-9)
    # optimality: the privacy-constrained problem at this pcost returns the
    # same loss (the two formulations are inverses)
    back = select_sum_of_variances(wk, pcost_of_plan(plan))
    loss_back = sum(wk.weight(c) * back.marginal_variance(c) for c in wk.cliques)
    assert math.isclose(loss_back, gamma, rel_tol=1e-9)
    # max-variance flavour
    mv = select_utility_constrained(wk, 3.0, objective="max_variance")
    assert math.isclose(mv.max_variance(), 3.0, rel_tol=1e-6)
