"""Durable budget ledger: crash recovery, charge races, over-budget errors.

The serving tier's privacy invariant is that the journal can never
*under*-state a tenant's spend relative to what was measured: every
measurement is preceded by a durable charge record (charge-before-measure),
so replay after a crash restores at least the spend of every measurement
that could have produced output.
"""
import json
import os
import tempfile
import threading

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.accountant import BudgetExhausted
from repro.serve.ledger import (BudgetLedger, LedgerCorrupt, LedgerFailed,
                                UnknownTenant)


def _path(tmp_path, name="ledger.jsonl"):
    return os.path.join(str(tmp_path), name)


def test_register_charge_report(tmp_path):
    led = BudgetLedger(_path(tmp_path))
    led.register("acme", rho=0.5)             # pcost_total = 1.0
    led.charge("acme", 0.25, request_id="r1")
    led.charge("acme", 0.25)
    assert led.spent("acme") == pytest.approx(0.5)
    assert led.remaining("acme") == pytest.approx(0.5)
    assert led.remaining_rho("acme") == pytest.approx(0.25)
    rep = led.report("acme")
    assert rep["charges"] == 2
    assert rep["rho_zcdp"] == pytest.approx(0.25)
    assert set(led.report()) == {"acme"}
    led.close()


def test_register_validation(tmp_path):
    led = BudgetLedger(_path(tmp_path))
    with pytest.raises(ValueError):
        led.register("t", rho=1.0, pcost=1.0)      # both
    with pytest.raises(ValueError):
        led.register("t")                          # neither
    with pytest.raises(ValueError):
        led.register("t", rho=-1.0)
    with pytest.raises(UnknownTenant):
        led.charge("ghost", 0.1)
    with pytest.raises(UnknownTenant):
        led.remaining("ghost")
    led.close()


def test_over_budget_carries_exact_remaining_rho(tmp_path):
    led = BudgetLedger(_path(tmp_path))
    led.register("t", rho=0.5)
    led.charge("t", 0.75)
    with pytest.raises(BudgetExhausted) as ei:
        led.charge("t", 0.5)
    err = ei.value
    assert err.tenant == "t"
    assert err.requested_pcost == pytest.approx(0.5)
    assert err.remaining_pcost == pytest.approx(0.25)
    assert err.remaining_rho == pytest.approx(0.125)   # exact remaining ρ
    assert "0.125" in str(err)                         # ... and in the message
    # the rejected charge was NOT journaled and NOT applied
    assert led.spent("t") == pytest.approx(0.75)
    led.close()
    assert BudgetLedger(_path(tmp_path)).spent("t") == pytest.approx(0.75)


def test_replay_restores_spend(tmp_path):
    p = _path(tmp_path)
    with BudgetLedger(p) as led:
        led.register("a", rho=2.0)
        led.register("b", pcost=1.0)
        led.charge("a", 0.5)
        led.charge("b", 0.25)
        led.charge("a", 0.125)
    led2 = BudgetLedger(p)
    assert led2.replayed_records == 5
    assert led2.spent("a") == pytest.approx(0.625)
    assert led2.spent("b") == pytest.approx(0.25)
    # budgets still enforced after replay
    with pytest.raises(BudgetExhausted):
        led2.charge("b", 0.80)
    led2.close()


def test_crash_between_journal_and_memory_never_undercharges(tmp_path):
    """A charge that reached the journal counts after replay even if the
    in-memory apply (and the measurement) never happened."""
    p = _path(tmp_path)
    led = BudgetLedger(p)
    led.register("t", pcost=10.0)
    led.charge("t", 1.0)
    # simulate the crash window: journal append succeeded, process died
    # before the in-memory budget advanced / the measurement ran
    led._append({"op": "charge", "tenant": "t", "pcost": 2.0,
                 "request_id": "crashed"})
    led.close()
    led2 = BudgetLedger(p)
    assert led2.spent("t") == pytest.approx(3.0)   # ≥ every measured charge
    led2.close()


def test_replay_tolerates_trailing_partial_line_only(tmp_path):
    p = _path(tmp_path)
    with BudgetLedger(p) as led:
        led.register("t", pcost=4.0)
        led.charge("t", 1.0)
    with open(p, "a") as fh:                      # crash mid-append
        fh.write('{"op": "charge", "tenant": "t", "pc')
    led2 = BudgetLedger(p)
    assert led2.spent("t") == pytest.approx(1.0)  # tail dropped, rest intact
    led2.close()

    # ... but corruption FOLLOWED by more records refuses to serve
    with open(p, "w") as fh:
        fh.write('{"op": "register", "tenant": "t", "pcost_total": 4.0}\n')
        fh.write("GARBAGE\n")
        fh.write('{"op": "charge", "tenant": "t", "pcost": 1.0}\n')
    with pytest.raises(LedgerCorrupt):
        BudgetLedger(p)


def test_charge_for_unregistered_tenant_in_journal_is_corruption(tmp_path):
    p = _path(tmp_path)
    with open(p, "w") as fh:
        fh.write('{"op": "charge", "tenant": "ghost", "pcost": 1.0}\n')
    with pytest.raises(LedgerCorrupt):
        BudgetLedger(p)


def test_reregister_keeps_spend(tmp_path):
    led = BudgetLedger(_path(tmp_path))
    led.register("t", pcost=1.0)
    led.charge("t", 0.75)
    led.register("t", pcost=2.0)                  # top-up
    assert led.spent("t") == pytest.approx(0.75)
    assert led.remaining("t") == pytest.approx(1.25)
    led.register("t", pcost=0.5)                  # shrink below spend
    assert led.remaining("t") == 0.0
    with pytest.raises(BudgetExhausted):
        led.charge("t", 0.1)
    led.close()


class _FlakyFH:
    """Wraps the ledger's raw journal handle; fails the next write partway
    through (half the bytes land, then OSError — the ENOSPC shape)."""

    def __init__(self, fh):
        self._fh = fh
        self.fail_next = False

    def write(self, data):
        if self.fail_next:
            self.fail_next = False
            self._fh.write(data[: len(data) // 2])
            raise OSError(28, "No space left on device")
        return self._fh.write(data)

    def __getattr__(self, name):
        return getattr(self._fh, name)


def test_failed_append_truncates_partial_record(tmp_path):
    """A mid-record write failure rolls the file back to the pre-write
    length: no partial line is left to become non-trailing corruption, the
    in-memory budget never advanced, and both retry and replay work."""
    p = _path(tmp_path)
    led = BudgetLedger(p)
    led.register("t", pcost=4.0)
    led.charge("t", 1.0)
    flaky = _FlakyFH(led._fh)
    led._fh = flaky
    flaky.fail_next = True
    with pytest.raises(OSError):
        led.charge("t", 1.0)
    assert led.spent("t") == pytest.approx(1.0)   # memory did not advance
    # the journal holds only complete lines — a restart replays cleanly
    # (before the truncate fix this was the LedgerCorrupt availability loss)
    with open(p) as fh:
        recs = [json.loads(ln) for ln in fh if ln.strip()]
    assert sum(1 for r in recs if r["op"] == "charge") == 1
    led.charge("t", 1.0)                          # handle still usable
    assert led.spent("t") == pytest.approx(2.0)
    led.close()
    led2 = BudgetLedger(p)
    assert led2.spent("t") == pytest.approx(2.0)
    led2.close()


def test_unrollable_append_failure_marks_ledger_failed(tmp_path, monkeypatch):
    """If the rollback truncate ALSO fails, the on-disk tail is unknown:
    the ledger refuses every further charge instead of appending after a
    possible partial record."""
    led = BudgetLedger(_path(tmp_path))
    led.register("t", pcost=4.0)
    flaky = _FlakyFH(led._fh)
    led._fh = flaky
    flaky.fail_next = True
    monkeypatch.setattr(os, "ftruncate",
                        lambda fd, n: (_ for _ in ()).throw(OSError(5, "io")))
    with pytest.raises(OSError):
        led.charge("t", 1.0)
    monkeypatch.undo()
    assert led.spent("t") == 0.0
    with pytest.raises(LedgerFailed):
        led.charge("t", 1.0)
    assert led.spent("t") == 0.0                  # still nothing applied
    led.close()


def test_concurrent_tenant_charge_race(tmp_path):
    """32 threads fight over a budget that admits exactly 10 unit charges:
    exactly 10 succeed, the journal agrees, and replay agrees."""
    p = _path(tmp_path)
    led = BudgetLedger(p, fsync=False)
    led.register("t", pcost=10.0)
    led.register("u", pcost=5.0)
    wins, losses = [], []
    barrier = threading.Barrier(32)

    def worker(i):
        barrier.wait()
        for _ in range(4):
            try:
                led.charge("t" if i % 2 else "u", 1.0, request_id=f"w{i}")
            except BudgetExhausted:
                losses.append(i)
            else:
                wins.append(i)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 15                        # 10 on "t" + 5 on "u"
    assert led.spent("t") == pytest.approx(10.0)
    assert led.spent("u") == pytest.approx(5.0)
    led.close()
    with open(p) as fh:
        recs = [json.loads(ln) for ln in fh if ln.strip()]
    assert sum(1 for r in recs if r["op"] == "charge") == 15
    led2 = BudgetLedger(p)
    assert led2.spent("t") == pytest.approx(10.0)
    assert led2.spent("u") == pytest.approx(5.0)
    led2.close()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(min_value=0.01, max_value=2.0),
                          st.integers(min_value=0, max_value=1)),
                min_size=1, max_size=12))
def test_crash_recovery_property_never_undercharges(charges):
    """Kill the process at ANY point between journal-append and memory-apply:
    the replayed spend is >= the sum of every charge whose measurement could
    have run (i.e. every charge() that returned + every journaled crash).

    No pytest fixtures here: the hypothesis-compat fallback hides the test
    signature from fixture resolution, so the temp dir is made by hand."""
    tmp = tempfile.mkdtemp(prefix="ledger_prop_")
    p = os.path.join(tmp, "j.jsonl")
    led = BudgetLedger(p, fsync=False)
    led.register("t", pcost=1e6)
    measured = 0.0           # spend of charges a measurement could follow
    for pcost, crash_here in charges:
        if crash_here:
            # journal reached disk; process dies before memory apply
            led._append({"op": "charge", "tenant": "t", "pcost": pcost})
            measured += 0.0  # measurement never ran — still must be charged
            break
        led.charge("t", pcost)
        measured += pcost
    led.close()
    led2 = BudgetLedger(p)
    assert led2.spent("t") >= measured - 1e-9
    led2.close()
