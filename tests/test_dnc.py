"""Divide-and-conquer planning (docs/DESIGN.md §12): partitioner, workload
decomposition, the unified-SoV exactness property, maxvar/convex parity
tolerances, the CompositePlan protocol, the CompositeEngine release path,
and the composite-aware engine-cache keying."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import (Domain, MarginalWorkload, all_kway, select,
                        select_convex, select_max_variance,
                        select_sum_of_variances)
from repro.core.composite import (CompositePlan, allocate_budget,
                                  compare_with_monolithic, select_dnc)
from repro.core.partition import (ROW_STRADDLER, decompose,
                                  interaction_weights, partition_attributes)


def _two_component_workload(weights=None):
    """Attributes {0,1,2} and {3,4,5} never co-occur → exactly 2 components."""
    dom = Domain.create([2, 3, 4, 2, 3, 4])
    cl = ((), (0,), (1,), (0, 1), (1, 2), (0, 2), (3,), (3, 4), (4, 5))
    return MarginalWorkload(dom, cl, weights or {(0, 1): 2.0, (3, 4): 3.0})


def _straddling_workload():
    """One clique crosses the {0,1}/{2,3} cut when forced into two blocks."""
    dom = Domain.create([2, 3, 4, 2])
    cl = ((0,), (0, 1), (1, 2), (2, 3), (3,))
    return MarginalWorkload(dom, cl, {(1, 2): 2.0})


# ---------------------------------------------------------------------------
# Partitioner
# ---------------------------------------------------------------------------

def test_partition_connected_components_are_exact():
    wk = _two_component_workload()
    part = partition_attributes(wk)
    assert part.blocks == ((0, 1, 2), (3, 4, 5))
    assert part.cut_weight == 0.0
    bo = part.block_of_array()
    assert bo.tolist() == [0, 0, 0, 1, 1, 1]


def test_partition_singleton_only_attrs_stay_active():
    # a 1-clique has no interaction edges but must land in some block
    dom = Domain.create([2, 2, 2])
    wk = MarginalWorkload(dom, ((0, 1), (2,)))
    part = partition_attributes(wk)
    assert sorted(a for b in part.blocks for a in b) == [0, 1, 2]


def test_partition_max_block_caps_block_size():
    dom = Domain.create([2] * 10)
    wk = all_kway(dom, 2)                      # one connected component
    part = partition_attributes(wk, max_block=4)
    assert all(len(b) <= 4 for b in part.blocks)
    assert part.n_blocks == math.ceil(10 / 4)
    assert sorted(a for b in part.blocks for a in b) == list(range(10))


def test_partition_blocks_int_splits_largest_first():
    wk = _two_component_workload()
    part = partition_attributes(wk, blocks=4)
    assert part.n_blocks >= 4
    assert sorted(a for b in part.blocks for a in b) == list(range(6))


def test_partition_explicit_blocks_validated():
    wk = _two_component_workload()
    part = partition_attributes(wk, blocks=[[0, 1, 2], [3, 4, 5]])
    assert part.blocks == ((0, 1, 2), (3, 4, 5))
    with pytest.raises(ValueError, match="overlap"):
        partition_attributes(wk, blocks=[[0, 1, 2], [2, 3, 4, 5]])
    with pytest.raises(ValueError, match="cover"):
        partition_attributes(wk, blocks=[[0, 1, 2], [3, 4]])
    with pytest.raises(ValueError, match="empty"):
        partition_attributes(wk, blocks=[[0, 1, 2], [], [3, 4, 5]])


def test_interaction_weights_accumulate_importance():
    wk = _two_component_workload()
    active, adj = interaction_weights(wk)
    assert active[:6].all()
    assert adj[0, 1] == pytest.approx(2.0)     # weight of (0,1)
    assert adj[3, 4] == pytest.approx(3.0)
    assert adj[0, 3] == 0.0                    # never co-occur
    assert np.allclose(adj, adj.T)


# ---------------------------------------------------------------------------
# Decomposition index arrays
# ---------------------------------------------------------------------------

def test_decompose_in_block_rows_round_trip():
    wk = _two_component_workload()
    d = decompose(wk, partition_attributes(wk))
    assert d.n_straddlers == 0
    for r, c in enumerate(wk.cliques):
        b = int(d.row_block[r])
        if not c:
            # ∅ rides with block 0 so its importance constrains σ²_∅
            assert b == 0
        assert d.block_workloads[b].cliques[int(d.row_pos[r])] == c
        assert d.block_workloads[b].weight(c) == pytest.approx(wk.weight(c))
    assert d.empty_weight == 0.0               # folded into block 0, not lost


def test_decompose_straddler_parts_merge_back():
    wk = _straddling_workload()
    part = partition_attributes(wk, blocks=[[0, 1], [2, 3]])
    d = decompose(wk, part)
    assert d.n_straddlers == 1
    r = wk.cliques.index((1, 2))
    assert int(d.row_block[r]) == ROW_STRADDLER
    parts = d.parts_of(r)
    assert sorted(pc for _, pc in parts) == [(1,), (2,)]
    # the union of part cliques is the straddling clique
    assert tuple(sorted(a for _, pc in parts for a in pc)) == (1, 2)
    # part_cells matches the projected tables' sizes
    sel = np.nonzero(d.part_row == r)[0]
    assert sorted(d.part_cells[sel].tolist()) == [3.0, 4.0]


def test_decompose_straddler_weight_accumulates_on_projection():
    # (1,2) straddles; its projection (2,) onto block 1 coincides with no
    # in-block clique, but (2,3) lives there — both weights must survive
    wk = _straddling_workload()
    d = decompose(wk, partition_attributes(wk, blocks=[[0, 1], [2, 3]]))
    bw1 = d.block_workloads[1]
    assert bw1.weight((2,)) == pytest.approx(2.0)      # straddler importance
    assert bw1.weight((2, 3)) == pytest.approx(1.0)
    bw0 = d.block_workloads[0]
    assert bw0.weight((1,)) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# allocate_budget
# ---------------------------------------------------------------------------

def test_allocate_budget_closed_forms():
    V = np.array([4.0, 1.0])
    cb = allocate_budget(V, 10.0, "max")       # c_b ∝ V_b equalizes V_b/c_b
    assert cb.sum() == pytest.approx(10.0)
    assert cb[0] / cb[1] == pytest.approx(4.0, rel=1e-9)
    cb = allocate_budget(V, 10.0, "sum")       # c_b ∝ √V_b (Cauchy–Schwarz)
    assert cb.sum() == pytest.approx(10.0)
    assert cb[0] / cb[1] == pytest.approx(2.0, rel=1e-9)
    with pytest.raises(ValueError):
        allocate_budget(V, -1.0)
    with pytest.raises(ValueError):
        allocate_budget(V, 1.0, combine="median")


def test_allocate_budget_degenerate_blocks_get_slivers():
    cb = allocate_budget(np.array([0.0, 5.0]), 2.0, "max")
    assert cb.sum() == pytest.approx(2.0)
    assert 0 < cb[0] < cb[1]


# ---------------------------------------------------------------------------
# SoV exactness on decomposable workloads (the tentpole property)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=15)
@given(st.lists(st.integers(2, 5), min_size=2, max_size=3),
       st.lists(st.integers(2, 5), min_size=2, max_size=3),
       st.floats(0.5, 8.0))
def test_dnc_sov_exact_on_decomposable(sizes_a, sizes_b, budget):
    """No straddlers → the unified closed form IS the monolithic optimum."""
    dom = Domain.create(sizes_a + sizes_b)
    na = len(sizes_a)
    ca = all_kway(Domain.create(sizes_a), 2, include_lower=True).cliques
    cb = tuple(tuple(a + na for a in c)
               for c in all_kway(Domain.create(sizes_b), 2,
                                 include_lower=True).cliques if c)
    wk = MarginalWorkload(dom, tuple(ca) + cb, {ca[1]: 2.0})
    mono = select_sum_of_variances(wk, budget)
    dnc = select_dnc(wk, budget)
    assert isinstance(dnc, CompositePlan)
    assert dnc.n_blocks == 2
    assert dnc.pcost == pytest.approx(budget, rel=1e-9)
    vm, vd = mono.variances_array(), dnc.variances_array()
    assert np.allclose(vd, vm, rtol=1e-10)
    assert dnc.total_variance() == pytest.approx(mono.total_variance(),
                                                 rel=1e-10)
    assert dnc.loss_value == pytest.approx(mono.loss_value, rel=1e-10)


def test_dnc_sov_exact_per_clique_sigmas_and_covariance():
    wk = _two_component_workload()
    mono = select_sum_of_variances(wk, 2.0)
    dnc = select_dnc(wk, 2.0)
    # σ² agree clique-for-clique across the composite closure
    for c in dnc.cliques:
        assert dnc.sigma2(c) == pytest.approx(mono.sigma2(c), rel=1e-10)
    # same-block covariance delegates to the block plan's Thm-4 value,
    # cross-block covariance is the shared-∅ value — both monolithic-exact
    for a, b in [((0, 1), (1, 2)), ((0, 1), (3, 4)), ((3,), (4, 5))]:
        assert dnc.marginal_covariance(a, b) == pytest.approx(
            mono.marginal_covariance(a, b), rel=1e-10)
    assert dnc.rmse() == pytest.approx(mono.rmse(), rel=1e-10)


def test_dnc_single_block_matches_monolithic():
    dom = Domain.create([2, 3, 4])
    wk = all_kway(dom, 2, include_lower=True)
    mono = select_sum_of_variances(wk, 1.0)
    dnc = select_dnc(wk, 1.0)                  # one component → one block
    assert dnc.n_blocks == 1
    assert np.allclose(dnc.variances_array(), mono.variances_array(),
                       rtol=1e-10)


def test_compare_harness_reports_exact_partition():
    rep = compare_with_monolithic(_two_component_workload(), 1.5)
    assert rep["exact_partition"] == 1.0
    assert rep["ratio"] == pytest.approx(1.0, rel=1e-9)
    assert rep["max_rel_marginal_diff"] < 1e-9
    assert rep["pcost_dnc"] == pytest.approx(rep["pcost_monolithic"],
                                             rel=1e-9)


# ---------------------------------------------------------------------------
# Maxvar / convex: within tolerance of monolithic, budget tight
# ---------------------------------------------------------------------------

def test_dnc_maxvar_within_tolerance():
    wk = _two_component_workload()
    mono = select_max_variance(wk, 1.7)
    dnc = select_dnc(wk, 1.7, objective="max_variance")
    assert dnc.pcost == pytest.approx(1.7, rel=1e-6)
    assert dnc.loss_value <= mono.loss_value * 1.10       # measured ≈1.05
    # block plans expose the warm-startable dual point
    assert any(getattr(bp, "mu", None) is not None for bp in dnc.block_plans)


def test_dnc_convex_within_tolerance():
    wk = _two_component_workload()
    mono = select_convex(wk, 1.3, loss="max_variance", steps=300)
    dnc = select_dnc(wk, 1.3, objective="convex", loss="max_variance",
                     steps=300)
    assert dnc.pcost == pytest.approx(1.3, rel=1e-6)
    assert dnc.loss_value <= mono.loss_value * 1.20


def test_dnc_maxvar_warm_start_reuses_same_shape_duals():
    # two identically-shaped blocks: the second solve warm-starts from the
    # first block's dual point (same closure size)
    dom = Domain.create([2, 3, 2, 3])
    cl = ((0,), (1,), (0, 1), (2,), (3,), (2, 3))
    wk = MarginalWorkload(dom, cl)
    dnc = select_dnc(wk, 1.0, objective="max_variance")
    assert dnc.n_blocks == 2
    for bp in dnc.block_plans:
        assert bp.mu is not None
        assert len(bp.mu) == bp.table.m


# ---------------------------------------------------------------------------
# Straddling cliques: product-of-blocks correction
# ---------------------------------------------------------------------------

def test_dnc_forced_split_straddler_is_sane():
    wk = _straddling_workload()
    dnc = select_dnc(wk, 1.0, blocks=[[0, 1], [2, 3]])
    assert dnc.decomposition.n_straddlers == 1
    assert dnc.pcost == pytest.approx(1.0, rel=1e-9)
    v = dnc.variances_array()
    assert np.isfinite(v).all() and (v > 0).all()
    # the straddler's covariance against anything is undefined on the proxy
    with pytest.raises(ValueError, match="straddles"):
        dnc.marginal_covariance((1, 2), (3,))


# ---------------------------------------------------------------------------
# CompositePlan protocol conformance
# ---------------------------------------------------------------------------

def test_composite_plan_protocol():
    wk = _two_component_workload()
    dnc = select_dnc(wk, 1.0)
    # closure: shared ∅ first, then per-block non-∅ cliques, no duplicates
    assert dnc.cliques[0] == ()
    assert len(dnc.cliques) == len(set(dnc.cliques))
    assert dnc.cliques.count(()) == 1
    assert set(dnc.sigmas) == set(dnc.cliques)
    assert dnc.sigma2(()) == pytest.approx(float(dnc.sigma[0]), rel=1e-12)
    assert dnc.domain is wk.domain
    assert dnc.workload is wk
    with pytest.raises(KeyError):
        dnc.marginal_variance((0, 5))          # not a workload clique
    # workload_variances comes from BasePlan over the composite overrides
    wv = dnc.workload_variances()
    assert set(wv) == set(wk.cliques)
    va = dnc.variances_array()
    for r, c in enumerate(wk.cliques):
        assert wv[c] == pytest.approx(va[r], rel=1e-12)
    assert dnc.max_variance() == pytest.approx(va.max(), rel=1e-12)
    with pytest.raises(ValueError, match="secure"):
        dnc.engine(secure=True)


def test_strategy_routing():
    from repro.core.select import Plan
    wk = _two_component_workload()
    assert isinstance(select(wk, 1.0), Plan)             # auto, small → mono
    assert isinstance(select(wk, 1.0, strategy="dnc"), CompositePlan)
    assert isinstance(select(wk, 1.0, strategy="auto", max_block=3),
                      CompositePlan)                     # explicit split
    with pytest.raises(ValueError, match="strategy"):
        select(wk, 1.0, strategy="monolithic", blocks=2)
    with pytest.raises(ValueError, match="strategy"):
        select(wk, 1.0, strategy="bogus")
    # all three objectives route
    for obj in ("sum_of_variances", "max_variance", "convex"):
        p = select(wk, 1.0, objective=obj, strategy="dnc")
        assert isinstance(p, CompositePlan)
        assert p.objective == obj


# ---------------------------------------------------------------------------
# CompositeEngine: measure → reconstruct → release/synthesize
# ---------------------------------------------------------------------------

def _exact_marginals_for(plan, records):
    from repro.engine.sharded import sharded_marginals
    return sharded_marginals(plan.domain, plan.cliques,
                             jnp.asarray(records))


def test_composite_engine_reconstructs_exactly_at_huge_budget():
    from repro.data.tabular import synthetic_records
    from repro.core.mechanism import exact_marginals_from_x
    wk = _two_component_workload()
    dnc = select_dnc(wk, 1e12)                 # σ² ≈ 0: noiseless
    recs = synthetic_records(wk.domain, 300, seed=1)
    eng = dnc.engine(precompile=False)
    meas = eng.measure(_exact_marginals_for(dnc, recs), jax.random.PRNGKey(0))
    assert set(meas) == set(dnc.cliques)
    tables = eng.reconstruct(meas)
    assert set(tables) == set(wk.cliques)
    x = np.zeros(wk.domain.universe_size())
    flat = np.ravel_multi_index(recs.T, wk.domain.sizes)
    np.add.at(x, flat, 1.0)
    truth = exact_marginals_from_x(wk.domain, wk.cliques, x)
    for c in wk.cliques:
        assert np.allclose(np.asarray(tables[c]).ravel(),
                           np.asarray(truth[c]).ravel(), atol=1e-3), c


def test_composite_engine_straddler_is_product_of_blocks():
    from repro.data.tabular import synthetic_records
    wk = _straddling_workload()
    dnc = select_dnc(wk, 1e12, blocks=[[0, 1], [2, 3]])
    recs = synthetic_records(wk.domain, 400, seed=2)
    eng = dnc.engine(precompile=False)
    tables, meas = eng.release(_exact_marginals_for(dnc, recs),
                               jax.random.PRNGKey(1))
    m1 = np.zeros(3)
    np.add.at(m1, recs[:, 1], 1.0)             # exact (1,) marginal
    m2 = np.zeros(4)
    np.add.at(m2, recs[:, 2], 1.0)             # exact (2,) marginal
    want = np.multiply.outer(m1, m2).ravel() / len(recs)
    assert np.allclose(np.asarray(tables[(1, 2)]).ravel(), want, atol=1e-2)


def test_composite_engine_release_nonneg_and_synthesize():
    from repro.data.tabular import synthetic_records
    wk = _two_component_workload()
    dnc = select_dnc(wk, 50.0)
    recs = synthetic_records(wk.domain, 500, seed=3)
    eng = dnc.engine(precompile=False)
    margs = _exact_marginals_for(dnc, recs)
    tables, _ = eng.release(margs, jax.random.PRNGKey(2),
                            postprocess="nonneg")
    for c in wk.cliques:
        assert (np.asarray(tables[c]) >= -1e-9).all(), c
    synth = eng.synthesize(200, jax.random.PRNGKey(3))
    assert synth.shape == (200, wk.domain.n_attrs)
    assert (synth >= 0).all()
    for a in range(wk.domain.n_attrs):
        assert synth[:, a].max() < wk.domain.sizes[a]
    # consistency postprocess also runs per block and stitches
    tables, _ = eng.release(margs, jax.random.PRNGKey(4),
                            postprocess="consistent")
    assert set(tables) == set(wk.cliques)
    with pytest.raises(ValueError, match="weights"):
        eng.release(margs, jax.random.PRNGKey(5), postprocess="consistent",
                    weights={(0, 1): 2.0})


def test_composite_engine_shares_empty_measurement():
    from repro.data.tabular import synthetic_records
    wk = _two_component_workload()
    dnc = select_dnc(wk, 2.0)
    recs = synthetic_records(wk.domain, 100, seed=4)
    eng = dnc.engine(precompile=False)
    meas = eng.measure(_exact_marginals_for(dnc, recs), jax.random.PRNGKey(6))
    # exactly one ∅ measurement serves every block (pcost counts it once)
    assert meas[()] is not None
    assert len([c for c in meas if c == ()]) == 1
    assert eng.variances() == dnc.workload_variances()
    assert len(eng.block_engines()) == dnc.n_blocks


# ---------------------------------------------------------------------------
# Engine cache: composite-aware keying (satellite fix + regression)
# ---------------------------------------------------------------------------

def test_engine_cache_composite_keying_regression():
    from repro.engine.sharded import _EngineCache

    class _P:
        def __init__(self, children=()):
            self.block_plans = tuple(children)

    cache = _EngineCache(maxsize=8)
    kids = [_P(), _P()]
    parent = _P(kids)
    for i, k in enumerate(kids):
        cache.put(k, False, np.float32, f"kid{i}")
    cache.put(parent, False, np.float32, "composite")
    assert len(cache) == 3
    assert cache.get(parent, False, np.float32) == "composite"
    # a parent with the SAME id but different children must never hit
    parent.block_plans = (kids[0],)
    assert cache.get(parent, False, np.float32) is None
    parent.block_plans = (kids[0], kids[1])

    # child death invalidates the parent entry but never the sibling's
    cache.put(parent, False, np.float32, "composite")
    cache._drop_plan(id(kids[1]))
    assert cache.get(kids[0], False, np.float32) == "kid0"
    assert cache.get(parent, False, np.float32) is None
    # parent death never touches the children's own entries
    cache.put(parent, False, np.float32, "composite")
    cache._drop_plan(id(parent))
    assert cache.get(kids[0], False, np.float32) == "kid0"


def test_engine_for_composite_registers_block_engines():
    from repro.core.mechanism import noise_dtype
    from repro.engine.sharded import _ENGINE_CACHE, _engine_for
    from repro.engine.composite import CompositeEngine
    wk = _two_component_workload()
    dnc = select_dnc(wk, 1.0)
    eng = _engine_for(dnc, False, noise_dtype())
    assert isinstance(eng, CompositeEngine)
    # parent + each block engine live in the shared cache
    assert _ENGINE_CACHE.get(dnc, False, noise_dtype()) is eng
    for bp, be in zip(dnc.block_plans, eng.block_engines()):
        assert _ENGINE_CACHE.get(bp, False, noise_dtype()) is be
    # dropping the composite's entries leaves the block entries serving
    # (cached engines pin their plan, so we exercise _drop_plan directly)
    _ENGINE_CACHE._drop_plan(id(dnc))
    assert _ENGINE_CACHE.get(dnc, False, noise_dtype()) is None
    for bp, be in zip(dnc.block_plans, eng.block_engines()):
        assert _ENGINE_CACHE.get(bp, False, noise_dtype()) is be
