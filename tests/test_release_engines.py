"""Serving-tier wiring of the release subsystem + sharded-tier satellites.

Covers ``release(postprocess=...)`` / ``synthesize`` on all three engines
(continuous, RP+, secure discrete — integer-exact totals), the
``corpus_marginal_release`` passthrough, the configurable ``_EngineCache``
(constructor arg, ``REPRO_ENGINE_CACHE_SIZE`` env, hit/miss counters on
``EngineStats``) and the ``_local_marginal`` dtype-threading fix.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Domain, MarginalWorkload, PrivacyBudget, all_kway, select
from repro.data.tabular import marginals_from_records, synthetic_records
from repro.engine.engine import EngineStats
from repro.engine import sharded
from repro.engine.corpus_stats import corpus_marginal_release
from repro.engine.sharded import (_EngineCache, _clique_strides,
                                  _local_marginal, sharded_measure)


@pytest.fixture
def small():
    dom = Domain.create([4, 3, 5, 2])
    wk = all_kway(dom, 2, include_lower=True)
    plan = select(wk, pcost_budget=1.0)
    records = synthetic_records(dom, 5000, seed=0)
    margs = marginals_from_records(dom, plan.cliques, records)
    return dom, wk, plan, records, margs


# --------------------------------------------------------------- MarginalEngine

def test_marginal_engine_postprocess_nonneg(small):
    dom, wk, plan, records, margs = small
    eng = plan.engine(use_kernel=False, precompile=False)
    tables, meas = eng.release(margs, jax.random.PRNGKey(0),
                               postprocess="nonneg")
    total = float(tables[wk.cliques[0]].sum())
    for c in wk.cliques:
        assert np.all(tables[c] >= 0)
        assert abs(tables[c].sum() - total) <= 1e-6 * max(total, 1.0)
    assert eng.stats.postprocess_calls == 1
    # consistency: shared sub-marginals of overlapping cliques agree
    m01 = tables[(0, 1)].reshape(4, 3)
    m12 = tables[(1, 2)].reshape(3, 5)
    # (nonneg projection is local, so only approximate consistency: within
    # a few counts on a 5000-record release)
    assert np.abs(m01.sum(axis=0) - m12.sum(axis=1)).max() < 50


def test_marginal_engine_postprocess_consistent_is_exact_consistent(small):
    dom, wk, plan, records, margs = small
    eng = plan.engine(use_kernel=False, precompile=False)
    tables, _ = eng.release(margs, jax.random.PRNGKey(0),
                            postprocess="consistent")
    m01 = tables[(0, 1)].reshape(4, 3)
    m12 = tables[(1, 2)].reshape(3, 5)
    np.testing.assert_allclose(m01.sum(axis=0), m12.sum(axis=1), atol=1e-3)


def test_marginal_engine_synthesize(small):
    dom, wk, plan, records, margs = small
    eng = plan.engine(use_kernel=False, precompile=False)
    with pytest.raises(ValueError):
        eng.synthesize(100, jax.random.PRNGKey(0))   # no nonneg release yet
    eng.release(margs, jax.random.PRNGKey(0), postprocess="nonneg")
    recs = eng.synthesize(20_000, jax.random.PRNGKey(1))
    assert recs.shape == (20_000, dom.n_attrs) and recs.dtype == np.int32
    for i, a in enumerate(dom.attributes):
        assert recs[:, i].min() >= 0 and recs[:, i].max() < a.size
    assert eng.stats.synthesize_calls == 1


def test_raw_release_unchanged(small):
    """postprocess=None keeps the historical unbiased (tables, meas) output."""
    dom, wk, plan, records, margs = small
    eng = plan.engine(use_kernel=False, precompile=False)
    t1, m1 = eng.release(margs, jax.random.PRNGKey(0))
    meas2 = eng.measure(margs, jax.random.PRNGKey(0))
    t2 = eng.reconstruct(meas2)
    for c in wk.cliques:
        np.testing.assert_allclose(t1[c], t2[c], rtol=1e-6)
    assert eng.stats.postprocess_calls == 0


# --------------------------------------------------------------- DiscreteEngine

def test_discrete_engine_integer_exact_totals(small):
    dom, wk, plan, records, margs = small
    eng = plan.engine(secure=True, use_kernel=False, precompile=False)
    tables, meas = eng.release(margs, jax.random.PRNGKey(3),
                               postprocess="nonneg")
    measured = float(np.asarray(meas[()].omega).reshape(-1)[0])
    assert measured.is_integer()
    for c in wk.cliques:
        assert np.all(tables[c] >= 0)
        assert round(float(tables[c].sum())) == int(measured)
    recs = eng.synthesize(5000, jax.random.PRNGKey(4))
    assert recs.shape == (5000, dom.n_attrs)


# ------------------------------------------------------------------- PlusEngine

def test_plus_engine_identity_postprocess(small):
    from repro.core.plus import PlusSchema, select_plus
    dom, wk, plan, records, margs = small
    schema = PlusSchema.create(dom, ["identity"] * dom.n_attrs)
    pplan = select_plus(wk, schema, pcost_budget=1.0)
    margs_p = marginals_from_records(dom, pplan.cliques, records)
    eng = pplan.engine(precompile=False)
    tables, _ = eng.release(margs_p, jax.random.PRNGKey(0),
                            postprocess="nonneg")
    total = float(tables[wk.cliques[0]].sum())
    for c in wk.cliques:
        assert np.all(tables[c] >= 0)
        assert abs(tables[c].sum() - total) <= 1e-4 * max(total, 1.0)
    recs = eng.synthesize(2000, jax.random.PRNGKey(1))
    assert recs.shape == (2000, dom.n_attrs)


def test_plus_engine_non_identity_rejected():
    from repro.core.plus import PlusSchema, select_plus
    dom = Domain.create([8, 3], kinds=["numeric", "categorical"])
    wk = all_kway(dom, 2, include_lower=True)
    schema = PlusSchema.create(dom, ["range", "identity"],
                               strategy_mode="hier")
    pplan = select_plus(wk, schema, pcost_budget=1.0)
    records = synthetic_records(dom, 1000, seed=1)
    margs = marginals_from_records(dom, pplan.cliques, records)
    eng = pplan.engine(precompile=False)
    with pytest.raises(ValueError, match="identity-basis"):
        eng.release(margs, jax.random.PRNGKey(0), postprocess="nonneg")
    with pytest.raises(ValueError, match="identity-basis"):
        eng.release(margs, jax.random.PRNGKey(0), postprocess="consistent")


# -------------------------------------------------------- sharded passthrough

def test_corpus_release_postprocess_passthrough(small):
    dom, wk, plan, records, margs = small
    budget = PrivacyBudget.from_zcdp(2.0)
    tables, variances, report = corpus_marginal_release(
        dom, wk, jnp.asarray(records), budget, 1.0, jax.random.PRNGKey(0),
        postprocess="nonneg")
    assert set(tables) == set(wk.cliques)
    for c in wk.cliques:
        assert np.all(np.asarray(tables[c]) >= 0)
    assert set(variances) == set(wk.cliques)


def test_corpus_release_secure_postprocess_integer_totals(small):
    dom, wk, plan, records, margs = small
    budget = PrivacyBudget.from_zcdp(2.0)
    tables, _, _ = corpus_marginal_release(
        dom, wk, jnp.asarray(records), budget, 1.0, jax.random.PRNGKey(0),
        secure=True, postprocess="nonneg")
    sums = {round(float(np.asarray(t).sum())) for t in tables.values()}
    assert len(sums) == 1          # one common integer total, exactly


# -------------------------------------------------------------- engine cache

class _FakePlan:
    """Weakref-able stand-in for a plan."""


class _FakeEngine:
    def __init__(self):
        self.stats = EngineStats()


def test_engine_cache_counters_and_lru():
    cache = _EngineCache(maxsize=2)
    plans = [_FakePlan() for _ in range(3)]
    engines = [_FakeEngine() for _ in range(3)]
    assert cache.get(plans[0], False, jnp.float32) is None
    assert cache.misses == 1 and cache.hits == 0
    for p, e in zip(plans[:2], engines[:2]):
        cache.put(p, False, jnp.float32, e)
    assert cache.get(plans[0], False, jnp.float32) is engines[0]
    assert cache.hits == 1
    assert engines[0].stats.cache_hits == 1
    cache.put(plans[2], False, jnp.float32, engines[2])   # evicts LRU (plans[1])
    assert cache.get(plans[1], False, jnp.float32) is None
    assert cache.get(plans[0], False, jnp.float32) is engines[0]
    assert len(cache) == 2


def test_engine_cache_env_capacity(monkeypatch):
    monkeypatch.setenv("REPRO_ENGINE_CACHE_SIZE", "3")
    assert _EngineCache().maxsize == 3
    monkeypatch.setenv("REPRO_ENGINE_CACHE_SIZE", "not-a-number")
    assert _EngineCache().maxsize == 16
    monkeypatch.delenv("REPRO_ENGINE_CACHE_SIZE", raising=False)
    assert _EngineCache().maxsize == 16
    assert _EngineCache(maxsize=5).maxsize == 5           # arg wins over env
    with pytest.raises(ValueError):
        _EngineCache(maxsize=0)


def test_sharded_measure_records_cache_hits(small):
    dom, wk, plan, records, margs = small
    before_hits, before_misses = (sharded._ENGINE_CACHE.hits,
                                  sharded._ENGINE_CACHE.misses)
    sharded_measure(plan, jnp.asarray(records), jax.random.PRNGKey(0))
    sharded_measure(plan, jnp.asarray(records), jax.random.PRNGKey(1))
    eng = sharded._engine_for(plan, False, jnp.float32)
    assert eng.stats.cache_misses == 1        # constructed exactly once
    assert eng.stats.cache_hits >= 2          # served from cache afterwards
    assert sharded._ENGINE_CACHE.misses >= before_misses + 1
    assert sharded._ENGINE_CACHE.hits >= before_hits + 2


# ------------------------------------------------------- _local_marginal dtype

def test_local_marginal_dtype_threads_from_noise_dtype():
    from repro.core.mechanism import noise_dtype
    dom = Domain.create([2, 3])
    n = 3001            # odd and > 2048: not representable in float16
    recs = jnp.zeros((n, 2), jnp.int32)      # every record in cell 0
    strides, n_cells = _clique_strides(dom, (0, 1))
    h = _local_marginal(recs, [0, 1], strides, n_cells)
    assert h.dtype == noise_dtype()          # was hard-coded float32
    # low-precision accumulation visibly drifts (3001 has no fp16 encoding) …
    h16 = _local_marginal(recs, [0, 1], strides, n_cells, jnp.float16)
    assert float(h16[0]) != float(n)
    # … while the threaded fp64 path is exact at the same domain
    old = jax.config.read("jax_enable_x64")
    try:
        jax.config.update("jax_enable_x64", True)
        h64 = _local_marginal(recs, [0, 1], strides, n_cells)
        assert h64.dtype == jnp.float64
        assert float(h64[0]) == float(n)
    finally:
        jax.config.update("jax_enable_x64", old)


def test_sharded_marginals_default_dtype_matches_engine_path(small):
    dom, wk, plan, records, margs = small
    out = sharded.sharded_marginals(dom, plan.cliques, jnp.asarray(records))
    from repro.core.mechanism import noise_dtype
    for c, t in out.items():
        assert t.dtype == noise_dtype()
        np.testing.assert_allclose(np.asarray(t, np.float64), margs[c],
                                   rtol=1e-6)
