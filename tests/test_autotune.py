"""Autotuner, cost model, dtype-aware planning and tuning-cache tests
(docs/DESIGN.md §14, docs/TUNING.md)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.kron import kron_matvec_np
from repro.kernels.autotune import (TuningCache, autotune_mode, chain_key,
                                    pretune, registry_snapshot,
                                    reset_registry, resolve_config,
                                    tune_chain)
from repro.kernels.autotune.cache import CACHE_VERSION
from repro.kernels.kron_matvec.fused import fused_chain_matvec, plan_chain
from repro.roofline.cost_model import DEVICE_TABLE, CostModel, DeviceSpec


@pytest.fixture(autouse=True)
def _isolated_tuner(tmp_path, monkeypatch):
    """Every test sees a fresh registry and a throwaway on-disk cache."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "att"))
    reset_registry()
    yield
    reset_registry()


def _mode_on(monkeypatch):
    """Tests asserting tuner activity force a tuning mode when the ambient
    env (e.g. an off-mode CI shard) disabled it."""
    if autotune_mode() == "off":
        monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE", "model")


def _rand_chain(rng, n_axes, sizes):
    dims = tuple(int(s) for s in sizes[:n_axes])
    facs = []
    for n in dims:
        if rng.random() < 0.25:
            facs.append(None)                       # identity axis
        else:
            m = int(rng.integers(1, n + 1))
            facs.append(rng.standard_normal((m, n)))
    return facs, dims


# --------------------------------------------------------------- bit-exactness
@settings(deadline=None, max_examples=12)
@given(st.integers(1, 3), st.tuples(st.integers(2, 12), st.integers(2, 12),
                                    st.integers(2, 12)),
       st.integers(1, 40), st.integers(0, 2 ** 31 - 1))
def test_tuned_fp32_bit_identical_to_default(n_axes, sizes, batch, seed):
    """Rows are independent under any block_l/padding: the tuned fp32 launch
    must be BIT-identical to the untuned default, not merely close."""
    rng = np.random.default_rng(seed)
    facs, dims = _rand_chain(rng, n_axes, sizes)
    n_in = int(np.prod(dims))
    x = rng.standard_normal((batch, n_in)).astype(np.float32)
    y_default = np.asarray(fused_chain_matvec(
        facs, x, dims, block_l=None, vmem_budget=None))   # explicit: no tuner
    cfg = tune_chain(facs, dims, batch=batch)
    y_tuned = np.asarray(fused_chain_matvec(
        facs, x, dims, block_l=cfg.block_l, vmem_budget=cfg.vmem_budget))
    assert np.array_equal(y_default, y_tuned)


def test_resolved_path_bit_identical_to_off(monkeypatch):
    rng = np.random.default_rng(7)
    facs, dims = _rand_chain(rng, 3, (5, 4, 6))
    x = rng.standard_normal((11, int(np.prod(dims)))).astype(np.float32)
    monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE", "off")
    y_off = np.asarray(fused_chain_matvec(facs, x, dims))
    monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE", "model")
    y_on = np.asarray(fused_chain_matvec(facs, x, dims))
    assert np.array_equal(y_off, y_on)


# ------------------------------------------------------------- mixed precision
def _oracle(facs, dims, x):
    full = [np.eye(n) if f is None else np.asarray(f, np.float64)
            for f, n in zip(facs, dims)]
    return np.stack([kron_matvec_np(full, row.astype(np.float64), dims)
                     for row in x])


def test_bf16_compute_fp32_accumulate_bounded_drift():
    rng = np.random.default_rng(3)
    dims = (6, 5, 4)
    facs = [rng.standard_normal((4, 6)), None, rng.standard_normal((3, 4))]
    x = rng.standard_normal((9, 120)).astype(np.float32)
    ref = _oracle(facs, dims, x)
    y = np.asarray(fused_chain_matvec(facs, x, dims, block_l=16,
                                      compute_dtype="bfloat16"))
    assert y.dtype == np.float32
    scale = np.abs(ref).max()
    # bf16 has 8 mantissa bits (~4e-3 ulp); fp32 accumulation keeps the
    # error at the operand-rounding level instead of growing with depth.
    assert np.abs(y - ref).max() / scale < 3e-2


def test_fp16_compute_fp32_accumulate_bounded_drift():
    rng = np.random.default_rng(4)
    dims = (5, 7)
    facs = [rng.standard_normal((5, 5)), rng.standard_normal((4, 7))]
    x = rng.standard_normal((6, 35)).astype(np.float32)
    ref = _oracle(facs, dims, x)
    y = np.asarray(fused_chain_matvec(facs, x, dims, block_l=16,
                                      compute_dtype="float16"))
    scale = np.abs(ref).max()
    assert np.abs(y - ref).max() / scale < 4e-3   # 10 mantissa bits


def test_plan_rejects_unknown_compute_dtype():
    with pytest.raises((ValueError, TypeError)):
        plan_chain([np.ones((2, 3))], (3,), compute_dtype="int8")


# ------------------------------------------------------ itemsize-aware VMEM
def test_vmem_accounting_is_itemsize_correct():
    rng = np.random.default_rng(0)
    facs = [rng.standard_normal((3, 4)), rng.standard_normal((5, 5))]
    dims = (4, 5)
    p32 = plan_chain(facs, dims, batch=16, block_l=16)
    pbf = plan_chain(facs, dims, batch=16, block_l=16,
                     compute_dtype="bfloat16")
    # Same block: the bf16 input tile and factors halve; fp32 accumulator
    # tiles stay — strictly smaller, but not half.
    assert pbf.vmem_bytes < p32.vmem_bytes
    assert pbf.vmem_bytes > p32.vmem_bytes // 2
    assert pbf.signature != p32.signature          # dtype is a jit-cache key


def test_tril_epilogue_accounted_at_compute_dtype():
    facs = [np.ones((4, 4))]
    base32 = plan_chain(facs, (4,), batch=16, block_l=16)
    epi32 = plan_chain(facs, (4,), batch=16, block_l=16,
                       epilogue=("cumsum",))
    basebf = plan_chain(facs, (4,), batch=16, block_l=16,
                        compute_dtype="bfloat16")
    epibf = plan_chain(facs, (4,), batch=16, block_l=16,
                       epilogue=("cumsum",), compute_dtype="bfloat16")
    assert epi32.vmem_bytes - base32.vmem_bytes == 4 * 4 * 4
    assert epibf.vmem_bytes - basebf.vmem_bytes == 2 * 4 * 4


# ------------------------------------------------------------------ cost model
def test_cost_model_bytes_monotone_in_block_l():
    model = CostModel(DEVICE_TABLE["cpu"])
    facs = [np.ones((3, 4)), np.ones((2, 5))]
    dims = (4, 5)
    last = -1.0
    for bl in (8, 16, 32, 64, 128):
        plan = plan_chain(facs, dims, batch=20, block_l=bl,
                          vmem_budget=1 << 30)
        cost = model.chain_cost(plan, batch=20)
        # Padded-batch traffic never shrinks as the block grows (20 rows pad
        # to 24, 32, ..., 128): rounding waste is visible to the tuner.
        assert cost.hbm_bytes >= last
        last = cost.hbm_bytes
    p24 = model.chain_cost(plan_chain(facs, dims, batch=20, block_l=24,
                                      vmem_budget=1 << 30), batch=20)
    p128 = model.chain_cost(plan_chain(facs, dims, batch=20, block_l=128,
                                       vmem_budget=1 << 30), batch=20)
    assert p24.hbm_bytes < p128.hbm_bytes


def test_fused_never_chosen_when_tile_exceeds_device_limit():
    tiny = DeviceSpec("tiny", peak_flops=1e12, peak_flops_f32=1e12,
                      hbm_bw=1e11, ici_bw=1e10, vmem_limit=1024,
                      default_vmem_budget=1024, step_overhead_s=1e-6)
    rng = np.random.default_rng(1)
    facs = [rng.standard_normal((64, 64))]
    cfg = tune_chain(facs, (64,), batch=32, device=tiny, persist=False)
    assert cfg.fused is False


def test_tuner_minimizes_grid_steps_in_interpret_mode():
    """On CPU (interpret) the per-step Python overhead dominates: the tuner
    must pick the exact-padded-batch block (grid == 1), not the 128 default
    (18 steps for the Synth-10^20 3-way group's 2280 lanes)."""
    rng = np.random.default_rng(2)
    facs = [rng.standard_normal((1, 20))] * 3
    cfg = tune_chain(facs, (20, 20, 20), batch=2280,
                     device=DEVICE_TABLE["cpu"], persist=False)
    assert cfg.fused
    assert cfg.grid_steps == 1
    assert cfg.block_l == 2280


# ---------------------------------------------------------------- cache + env
def test_tuning_cache_round_trip(tmp_path):
    c = TuningCache("cpu", path=str(tmp_path / "t.json"))
    c.put("k1", {"block_l": 64, "vmem_budget": 123, "fused": True})
    c2 = TuningCache("cpu", path=str(tmp_path / "t.json"))
    assert c2.get("k1")["block_l"] == 64
    assert c2.get("nope") is None


def test_tuning_cache_concurrent_puts(tmp_path):
    """Racing puts must not lose entries (lazy load + mutate is locked)."""
    import threading
    c = TuningCache("cpu", path=str(tmp_path / "t.json"))
    threads = [threading.Thread(target=c.put, args=(f"k{i}", {"block_l": 8}))
               for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(c.load()) == 16


def test_tuning_cache_invalidation(tmp_path):
    path = str(tmp_path / "t.json")
    TuningCache("cpu", path=path).put("k", {"block_l": 64})
    # another device kind: whole file invalid
    assert TuningCache("tpu v5 lite", path=path).get("k") is None
    # version bump: whole file invalid
    import json
    with open(path) as fh:
        blob = json.load(fh)
    blob["version"] = CACHE_VERSION + 1
    with open(path, "w") as fh:
        json.dump(blob, fh)
    assert TuningCache("cpu", path=path).get("k") is None
    # corrupt file: empty cache, no raise
    with open(path, "w") as fh:
        fh.write("{not json")
    assert TuningCache("cpu", path=path).get("k") is None


def test_resolve_config_hits_disk_cache_after_registry_reset(monkeypatch):
    _mode_on(monkeypatch)
    rng = np.random.default_rng(5)
    facs = [rng.standard_normal((2, 6))]
    cfg = tune_chain(facs, (6,), batch=10)          # persists
    reset_registry()
    got = resolve_config(facs, (6,), batch=10)
    assert got is not None
    assert got.source == "cache"
    assert got.block_l == cfg.block_l


def test_mode_env_gate(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE", "off")
    assert autotune_mode() == "off"
    assert resolve_config([np.ones((2, 3))], (3,), batch=4) is None
    monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE", "bogus")
    assert autotune_mode() == "model"              # unknown → default
    monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE", "measure")
    assert autotune_mode() == "measure"


def test_off_mode_keeps_untuned_default_plan():
    plan = plan_chain([np.ones((2, 5))], (5,), batch=40)
    assert plan.block_l == min(128, 40)            # pad_to(40, 8) == 40
    assert plan.compute_dtype == "float32"
    assert plan.block_l % 8 == 0


def test_measure_mode_refines_and_tags_source():
    rng = np.random.default_rng(6)
    facs = [rng.standard_normal((3, 4)), rng.standard_normal((2, 5))]
    cfg = tune_chain(facs, (4, 5), batch=24, mode="measure", persist=False)
    assert cfg.source == "measure"
    assert cfg.predicted_s > 0


def test_chain_key_discriminates():
    f = [(2, 3)]
    k1 = chain_key("cpu", (3,), f, None, 8)
    assert k1 != chain_key("cpu", (3,), f, None, 16)          # batch
    assert k1 != chain_key("cpu", (3,), [None], None, 8)      # factor shape
    assert k1 != chain_key("cpu", (3,), f, ("cumsum",), 8)    # epilogue
    assert k1 != chain_key("tpu v5 lite", (3,), f, None, 8)   # device


# ---------------------------------------------------------- engine integration
def _plan(sizes=(3, 4, 5)):
    from repro.core import Domain, MarginalWorkload, select_sum_of_variances
    dom = Domain.create(list(sizes))
    cliques = tuple((i, j) for i in range(len(sizes))
                    for j in range(i + 1, len(sizes)))
    return select_sum_of_variances(MarginalWorkload(dom, cliques), 10.0)


def test_engine_registers_tuned_chains(monkeypatch):
    _mode_on(monkeypatch)
    from repro.engine import MarginalEngine
    eng = MarginalEngine(_plan(), use_kernel=True)
    assert eng.stats.tuned_chains == len(eng.chain_plans())
    assert eng.stats.fallback_chains == 0
    for row in eng.chain_plans():
        assert row["compute_dtype"] == "float32"
        assert row["tuned"] is True
        assert row["tune_source"] in ("model", "measure", "cache")
        assert row["intensity"] is not None
    snap = registry_snapshot()
    assert len(snap["entries"]) >= len(eng.chain_plans())
    assert snap["mode"] in ("model", "measure")


def test_engine_off_mode_untouched(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE", "off")
    from repro.engine import MarginalEngine
    eng = MarginalEngine(_plan((3, 4)), use_kernel=True)
    assert eng.stats.tuned_chains == 0
    for row in eng.chain_plans():
        assert row["tuned"] is False
        assert row["tune_source"] == "default"


def test_pretune_batch():
    rng = np.random.default_rng(8)
    chains = [([rng.standard_normal((2, 4))], (4,), 6, None),
              ([rng.standard_normal((3, 5))], (5,), 12, None)]
    out = pretune(chains)
    assert len(out) == 2
    assert all(c.block_l % 8 == 0 for c in out)


def test_server_stats_surface_kernels_and_autotune(tmp_path):
    from repro.serve import BudgetLedger, ReleaseServer
    ledger = BudgetLedger(str(tmp_path / "ledger.jsonl"), fsync=False)
    srv = ReleaseServer(ledger).start()
    try:
        srv.register_tenant("t1", _plan((3, 4)), rho=10.0)
        d = srv.stats_dict()
        assert "pallas_calls" in d["kernels"]
        assert d["autotune"]["mode"] in ("off", "model", "measure")
        assert isinstance(d["autotune"]["entries"], dict)
    finally:
        srv.stop()


def test_narrow_clamped_without_allow_narrow(monkeypatch):
    """A tuned narrow dtype never reaches a noise-carrying call site."""
    _mode_on(monkeypatch)
    monkeypatch.setenv("REPRO_KERNEL_COMPUTE_DTYPES", "float32,bfloat16")
    rng = np.random.default_rng(9)
    facs = [rng.standard_normal((3, 4))]
    dims = (4,)
    tune_chain(facs, dims, batch=8, dtypes=("bfloat16",))
    x = rng.standard_normal((8, 4)).astype(np.float32)
    y_clamped = np.asarray(fused_chain_matvec(facs, x, dims))
    y_fp32 = np.asarray(fused_chain_matvec(facs, x, dims, block_l=8,
                                           compute_dtype="float32"))
    assert np.array_equal(y_clamped, y_fp32)
