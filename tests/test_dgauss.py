"""Statistical acceptance tests for the batched integer-lane discrete
Gaussian sampler (core/dgauss.py): exact-vs-batched distributional
agreement, big-int fallback boundaries, and seed determinism."""
import math
import random
from fractions import Fraction

import numpy as np
import pytest

from repro.core import dgauss
from repro.core.discrete import sample_discrete_gaussian

# chi-square critical values at alpha = 1e-3 (loose: seeds are fixed, so a
# failure here means a real distribution change, not flakiness)
_CHI2_CRIT = {11: 31.26, 12: 32.91, 13: 34.53}


def _exact_pmf(sigma2: float, k: int) -> float:
    z = sum(math.exp(-x * x / (2.0 * sigma2)) for x in range(-200, 201))
    return math.exp(-k * k / (2.0 * sigma2)) / z


def test_chi_square_small_gamma2():
    """Batched sampler matches the exact pmf on a small-γ² support grid."""
    s2 = 2
    n = 20000
    xs = dgauss.sample(s2, n, np.random.default_rng(0))
    assert xs.dtype == np.int64
    lo, hi = -5, 5
    counts = {k: int(np.sum(xs == k)) for k in range(lo, hi + 1)}
    chi = 0.0
    tail_obs = n - sum(counts.values())
    tail_p = 1.0
    for k in range(lo, hi + 1):
        p = _exact_pmf(s2, k)
        tail_p -= p
        e = n * p
        chi += (counts[k] - e) ** 2 / e
    chi += (tail_obs - n * tail_p) ** 2 / (n * tail_p)
    assert chi < _CHI2_CRIT[11], chi


def test_batched_matches_legacy_moments():
    """Batched and serial samplers draw the same distribution (both exact):
    means and variances agree within sampling error on a rational γ²."""
    s2 = Fraction(25, 4)
    n = 3000
    srng = random.Random(0)
    legacy = np.array([sample_discrete_gaussian(s2, srng)
                       for _ in range(n)], dtype=float)
    batched = dgauss.sample(s2, n, np.random.default_rng(0)).astype(float)
    se_mean = math.sqrt(float(s2) / n)
    assert abs(legacy.mean() - batched.mean()) < 8 * se_mean
    assert abs(legacy.var() / batched.var() - 1.0) < 0.25
    # var(N_Z(0, σ²)) ≤ σ² (CKS Fact 21), both implementations
    assert batched.var() <= float(s2) * 1.1
    assert batched.var() >= float(s2) * 0.8


def test_large_gamma2_moments():
    """Πn_i = 10²⁰-scale γ² (the regression regime): big-int lanes, sane
    moments — the seed-era float path raised OverflowError long before."""
    gamma2 = Fraction(10 ** 40 * 17, 4)      # σ ≈ 1.03e20
    xs = dgauss.sample(gamma2, 400, np.random.default_rng(1))
    assert xs.dtype == object                # beyond int64 lanes
    assert all(isinstance(int(v), int) for v in xs)
    vals = np.array([float(v) for v in xs])
    sigma = math.sqrt(float(gamma2))
    assert abs(vals.mean()) < 5 * sigma / math.sqrt(len(vals))
    assert 0.6 < vals.var() / sigma ** 2 < 1.5


def test_int64_bigint_fallback_boundary():
    """Either side of the 2^62 lane boundary: values and dtypes stay sane."""
    below = dgauss.sample((1 << 61) - 3, 200, np.random.default_rng(2))
    above = dgauss.sample((1 << 70) + 5, 200, np.random.default_rng(2))
    assert below.dtype == np.int64
    sd_below = np.std(below.astype(float))
    sd_above = np.std(np.array([float(v) for v in above]))
    assert 0.5 < sd_below / math.sqrt(float(1 << 61)) < 1.5
    assert 0.5 < sd_above / math.sqrt(float(1 << 70)) < 1.5


def test_uniform_below_paths_agree():
    """The int64 and big-int uniform generators are both uniform: matching
    first moments across the path boundary."""
    n = 4000
    small = dgauss._uniform_below(1 << 40, n, np.random.default_rng(3))
    big = dgauss._uniform_below(1 << 80, n, np.random.default_rng(3))
    assert small.dtype == np.int64 and big.dtype == object
    m_small = float(np.mean(small)) / float(1 << 40)
    m_big = float(sum(int(v) for v in big)) / n / float(1 << 80)
    assert abs(m_small - 0.5) < 0.02
    assert abs(m_big - 0.5) < 0.02
    assert all(0 <= int(v) < (1 << 80) for v in big)


def test_seed_determinism():
    for g2 in (10 ** 6, Fraction(10 ** 41, 7)):
        a = dgauss.sample(g2, 64, np.random.default_rng(9))
        b = dgauss.sample(g2, 64, np.random.default_rng(9))
        assert np.array_equal(a, b)
    # random.Random seeds deterministically too
    a = dgauss.sample(100, 32, random.Random(5))
    b = dgauss.sample(100, 32, random.Random(5))
    assert np.array_equal(a, b)


def test_rejects_inexact_variance():
    with pytest.raises(TypeError):
        dgauss.sample(2.5, 4, np.random.default_rng(0))
    with pytest.raises(ValueError):
        dgauss.sample(0, 4, np.random.default_rng(0))
    with pytest.raises(ValueError):
        dgauss.sample(Fraction(-1, 2), 4, np.random.default_rng(0))


def test_empty_draw():
    out = dgauss.sample(4, 0, np.random.default_rng(0))
    assert out.shape == (0,) and out.dtype == np.int64
