"""Sharded measurement engine + corpus-stats integration + HDMM baseline."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Domain, MarginalWorkload, PrivacyBudget, all_kway,
                        reconstruct_all, select_sum_of_variances)
from repro.data.tabular import (adult_domain, marginals_from_records,
                                synth_domain, synthetic_records)
from repro.engine.sharded import sharded_marginals, sharded_measure
from repro.engine.corpus_stats import corpus_marginal_release
from repro.launch.mesh import make_host_mesh


def test_sharded_marginals_match_numpy():
    dom = synth_domain(4, 3)
    wk = all_kway(dom, 2, include_lower=True)
    recs = synthetic_records(dom, 500, seed=1)
    want = marginals_from_records(dom, wk.closure(), recs)
    got = sharded_marginals(dom, wk.closure(), jnp.asarray(recs))
    for c in wk.closure():
        assert np.allclose(np.asarray(got[c]), want[c]), c
    mesh = make_host_mesh()
    got_mesh = sharded_marginals(dom, wk.closure(), jnp.asarray(recs), mesh)
    for c in wk.closure():
        assert np.allclose(np.asarray(got_mesh[c]), want[c]), c


def test_sharded_measure_end_to_end():
    dom = synth_domain(3, 4)
    wk = all_kway(dom, 2)
    plan = select_sum_of_variances(wk, 10.0)
    recs = synthetic_records(dom, 2000, seed=2)
    meas = sharded_measure(plan, jnp.asarray(recs), jax.random.PRNGKey(0))
    tables = reconstruct_all(plan, meas)
    want = marginals_from_records(dom, wk.cliques, recs)
    for c in wk.cliques:
        sd = np.sqrt(plan.marginal_variance(c))
        assert np.all(np.abs(tables[c] - want[c]) < 6 * sd + 1e-6)


def test_corpus_stats_budget_sharing():
    dom = Domain.create([8, 8], names=["source", "len_bucket"])
    wk = MarginalWorkload(dom, ((0,), (1,), (0, 1)))
    recs = synthetic_records(dom, 1000, seed=3)
    budget = PrivacyBudget.from_zcdp(rho=1.0)   # pcost 2.0 total
    tables, variances, report = corpus_marginal_release(
        dom, wk, jnp.asarray(recs), budget, pcost=0.5, key=jax.random.PRNGKey(1))
    assert set(tables) == set(wk.cliques)
    assert report["pcost_spent"] == pytest.approx(0.5, rel=1e-6)
    assert budget.remaining == pytest.approx(1.5, rel=1e-6)
    # DP-SGD then charges the same budget
    from repro.train.dp import DPSGDAccountant, DPSGDConfig
    acc = DPSGDAccountant(DPSGDConfig(noise_multiplier=2.0), budget)
    for _ in range(5):
        acc.charge_step()
    assert budget.remaining == pytest.approx(1.5 - 5 * 0.25, rel=1e-6)
    with pytest.raises(ValueError):
        for _ in range(2):
            acc.charge_step()


def test_hdmm_sanity_and_crossover_direction():
    """RP is optimal for marginals (HDMM ≥ RP); HDMM wins on the k=d Kron
    range workload (paper §9.4 crossover)."""
    from repro.baselines.hdmm import HdmmKron, hdmm_marginals
    from repro.core.plus import PlusSchema, build_w, select_plus
    dom = Domain.create([10, 10])
    wk = all_kway(dom, 2, include_lower=True)
    plan = select_sum_of_variances(
        wk, 1.0, {c: float(dom.n_cells(c)) for c in wk.cliques})
    union = hdmm_marginals(wk, iters=300)
    assert union.rmse(1.0) >= plan.rmse() * 0.999
    # k = d Kron ranges: HDMM(OPT_kron) should beat RP+ (Table 10 direction)
    n, d = 8, 2
    dom2 = Domain.create([n] * d)
    wk2 = MarginalWorkload(dom2, (tuple(range(d)),))
    schema = PlusSchema.create(dom2, ["range"] * d, strategy_mode="hier")
    rp = select_plus(wk2, schema, 1.0, "sov")
    kron = HdmmKron.optimize([build_w("range", n)] * d, iters=800)
    import math
    hd_rmse = math.sqrt(kron.tv_unit / kron.n_queries)
    assert hd_rmse < rp.rmse() * 1.05


def test_hdmm_reconstruction_oom_guard():
    from repro.baselines.hdmm import hdmm_measure_reconstruct, hdmm_marginals
    dom = synth_domain(10, 10)   # universe 10^10 > guard
    wk = all_kway(dom, 1)
    union = hdmm_marginals(wk, iters=10)
    with pytest.raises(MemoryError):
        hdmm_measure_reconstruct(union, dom, np.zeros(1), np.random.default_rng(0))
