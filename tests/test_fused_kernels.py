"""Fused chain kernel + signature-batched device paths vs float64 oracles.

Covers the docs/DESIGN.md §3.4 layout contract (exactly one pad and one slice
per fused chain), odd/non-padded attribute sizes, the VMEM fallback, and the
batched measurement/reconstruction paths against ``kron_matvec_np`` /
``measure_np`` / the subset-loop reconstruction.
"""
import math

import numpy as np
import pytest

import jax

from repro.core import (Domain, MarginalWorkload, exact_marginals_from_x,
                        measure, measure_np, reconstruct_all,
                        reconstruct_all_batched, reconstruct_marginal,
                        reconstruct_marginal_fast, select_sum_of_variances)
from repro.core.kron import kron_matvec_batched, kron_matvec_np
from repro.core.reconstruct import embed_subset_answers, u_chain_factors
from repro.core.residual import sub_matrix
from repro.kernels.kron_matvec.fused import fused_chain_matvec, plan_chain
from repro.kernels.kron_matvec.ops import residual_measure_kernel
from repro.kernels.kron_matvec.stats import chain_stats, reset_chain_stats


class _ZeroRng:
    def standard_normal(self, n):
        return np.zeros(n)


def _plan(sizes, cliques, budget=1.0):
    dom = Domain.create(sizes)
    wk = MarginalWorkload(dom, tuple(cliques))
    return select_sum_of_variances(wk, budget)


# --------------------------------------------------------------- fused chain

@pytest.mark.parametrize("dims,batch", [
    ([2], 1), ([3], 5), ([2, 3], 4), ([5, 7, 3], 2), ([17, 6], 9),
    ([9, 2, 4], 1), ([10, 10, 10], 3), ([13], 130),
])
def test_fused_chain_matches_np_oracle(dims, batch, rng):
    """Odd / non-padded sizes: fused chain vs the float64 numpy oracle."""
    facs = [sub_matrix(n) for n in dims]
    x = rng.standard_normal((batch, int(np.prod(dims)))).astype(np.float32)
    got = np.asarray(fused_chain_matvec(facs, x, dims))
    want = np.stack([kron_matvec_np(facs, x[i], dims) for i in range(batch)])
    scale = max(np.abs(want).max(), 1e-6)
    assert np.max(np.abs(got - want)) / scale < 2e-5


def test_fused_chain_mixed_factor_kinds(rng):
    """None (identity), 'ones' (marginalize) and rectangular factors fuse."""
    dims = [4, 5, 3]
    facs = [None, "ones", rng.standard_normal((7, 3))]
    x = rng.standard_normal((6, 60)).astype(np.float32)
    got = np.asarray(fused_chain_matvec(facs, x, dims))
    want = np.stack([kron_matvec_np(facs, x[i], dims) for i in range(6)])
    assert got.shape == want.shape == (6, 4 * 1 * 7)
    scale = max(np.abs(want).max(), 1e-6)
    assert np.max(np.abs(got - want)) / scale < 2e-5


def test_fused_chain_exactly_one_pad_and_slice(rng):
    """The acceptance contract: ONE pad, ONE pallas_call, ONE slice per chain."""
    dims = [5, 7, 3]
    facs = [sub_matrix(n) for n in dims]
    x = rng.standard_normal((10, 105)).astype(np.float32)
    reset_chain_stats()
    fused_chain_matvec(facs, x, dims)
    st = chain_stats()
    assert st["pads"] == 1 and st["slices"] == 1 and st["pallas_calls"] == 1, st
    assert st["fused_chains"] == 1 and st["fallback_chains"] == 0


def test_per_axis_fallback_pays_one_pad_per_factor(rng):
    """Contrast case: the per-axis oracle path pads/slices once per factor."""
    from repro.kernels.kron_matvec.ops import kron_matvec_kernel
    dims = [5, 7, 3]
    facs = [sub_matrix(n) for n in dims]
    x = rng.standard_normal(105).astype(np.float32)
    reset_chain_stats()
    kron_matvec_kernel(facs, x, dims)
    st = chain_stats()
    assert st["pads"] == len(dims) and st["slices"] == len(dims)


def test_fused_vmem_guard_falls_back(rng):
    """Chains over the VMEM budget fall back to the per-axis kernel, exactly."""
    dims = [8, 9]
    facs = [sub_matrix(n) for n in dims]
    x = rng.standard_normal((4, 72)).astype(np.float32)
    reset_chain_stats()
    got = np.asarray(fused_chain_matvec(facs, x, dims, vmem_budget=16))
    st = chain_stats()
    assert st["fallback_chains"] == 1 and st["fused_chains"] == 0
    want = np.stack([kron_matvec_np(facs, x[i], dims) for i in range(4)])
    assert np.max(np.abs(got - want)) / np.abs(want).max() < 2e-5


def test_plan_chain_layout():
    plan = plan_chain([sub_matrix(10)] * 3, [10, 10, 10], batch=64)
    assert plan.n_in == 1000 and plan.n_out == 9 ** 3
    assert plan.w_in % 128 == 0 and plan.w_out % 128 == 0
    assert plan.block_l % 8 == 0 and plan.fused_ok
    # identity factors are dropped from the contraction list
    plan2 = plan_chain([None, sub_matrix(4)], [6, 4], batch=1)
    assert plan2.fshapes == (None, (3, 4))
    assert plan2.out_dims == (6, 3)


# ----------------------------------------------- residual_measure_kernel

@pytest.mark.parametrize("dims", [[2], [3], [4, 7], [5, 3, 2], [17, 6]])
def test_residual_measure_kernel_vs_np_oracle(dims, rng):
    """Fused [v;z] measurement kernel vs the float64 numpy oracle."""
    facs = [sub_matrix(n) for n in dims]
    m = int(np.prod(dims))
    v = rng.standard_normal(m).astype(np.float32)
    z = rng.standard_normal(m).astype(np.float32)
    sigma = 0.7
    got = np.asarray(residual_measure_kernel(facs, v, z, sigma, dims))
    want = (kron_matvec_np(facs, v.astype(np.float64), dims)
            + sigma * kron_matvec_np(facs, z.astype(np.float64), dims))
    scale = max(np.abs(want).max(), 1e-6)
    assert np.max(np.abs(got - want)) / scale < 2e-5


# --------------------------------------------------- batched measurement

def test_batched_measure_matches_loop_and_np_oracle(rng):
    """Signature-batched device measurement == per-clique loop == fp64 oracle."""
    plan = _plan([3, 4, 2, 3], [(0, 1), (1, 2), (2, 3), (0, 3), (1,)])
    x = rng.integers(0, 9, plan.domain.universe_size()).astype(float)
    margs = exact_marginals_from_x(plan.domain, plan.cliques, x)
    key = jax.random.PRNGKey(11)
    loop = measure(plan, margs, key, use_kernel=False, batched=False)
    bat = measure(plan, margs, key, use_kernel=False, batched=True)
    fus = measure(plan, margs, key, use_kernel=True, batched=True)
    # float64 oracle: replay the same per-clique key folds on the host
    keys = jax.random.split(key, len(plan.cliques))
    for k, c in zip(keys, plan.cliques):
        dims = plan.domain.clique_sizes(c)
        m = int(np.prod(dims)) if c else 1
        z = np.asarray(jax.random.normal(k, (m,)), np.float64)
        v = np.asarray(margs[c], np.float64).reshape(-1)
        sig = math.sqrt(plan.sigmas[c])
        if c:
            facs = [sub_matrix(n) for n in dims]
            want = (kron_matvec_np(facs, v, dims)
                    + sig * kron_matvec_np(facs, z, dims))
        else:
            want = v + sig * z
        scale = max(np.abs(want).max(), 1.0)
        for got in (loop, bat, fus):
            assert np.max(np.abs(got[c].omega - want)) / scale < 2e-4, c


def test_batched_measure_one_chain_per_signature(rng):
    """The fused path issues one pad/call/slice per signature group, not per clique."""
    from repro.core.mechanism import signature_groups
    plan = _plan([3, 3, 3, 4], [(0, 1), (1, 2), (0, 2), (2, 3)])
    margs = exact_marginals_from_x(
        plan.domain, plan.cliques,
        rng.integers(0, 5, plan.domain.universe_size()).astype(float))
    groups = signature_groups(plan.domain, plan.cliques)
    n_nonempty = sum(1 for dims in groups if dims)
    reset_chain_stats()
    measure(plan, margs, jax.random.PRNGKey(0), use_kernel=True, batched=True)
    st = chain_stats()
    assert st["pallas_calls"] == n_nonempty
    assert st["pads"] == n_nonempty and st["slices"] == n_nonempty
    assert n_nonempty < len(plan.cliques)   # batching actually collapsed work


# ------------------------------------------------- batched reconstruction

def test_merged_embedding_identity_fp64(rng):
    """Σ_{A'⊆A} U_{A←A'} ω_{A'}  ==  (⊗ T_i) Σ e_{A'}  exactly in float64."""
    plan = _plan([3, 4, 2], [(0, 1, 2), (0, 1), (1, 2)])
    x = rng.integers(0, 9, plan.domain.universe_size()).astype(float)
    margs = exact_marginals_from_x(plan.domain, plan.cliques, x)
    meas = measure_np(plan, margs, rng)
    for c in plan.workload.cliques:
        want = reconstruct_marginal(plan, meas, c)       # subset-loop oracle
        sizes = plan.domain.clique_sizes(c)
        merged = kron_matvec_np(u_chain_factors(plan.domain, c),
                                embed_subset_answers(plan, meas, c).reshape(-1),
                                sizes)
        assert np.allclose(want, merged, atol=1e-9), c


def test_reconstruct_fast_and_batched_vs_oracle(rng):
    plan = _plan([3, 4, 2, 4], [(0, 1), (1, 2), (2, 3), (0, 3)])
    x = rng.integers(0, 9, plan.domain.universe_size()).astype(float)
    margs = exact_marginals_from_x(plan.domain, plan.cliques, x)
    meas = measure_np(plan, margs, _ZeroRng())
    ref = reconstruct_all(plan, meas)
    fused = reconstruct_all_batched(plan, meas, use_kernel=True)
    jnp_b = reconstruct_all_batched(plan, meas, use_kernel=False)
    for c in plan.workload.cliques:
        truth = exact_marginals_from_x(plan.domain, [c], x)[c]
        assert np.allclose(ref[c], truth, atol=1e-8)     # zero noise: exact
        scale = max(np.abs(ref[c]).max(), 1.0)
        assert np.max(np.abs(fused[c] - ref[c])) / scale < 2e-5, c
        assert np.max(np.abs(jnp_b[c] - ref[c])) / scale < 2e-5, c
        single = reconstruct_marginal_fast(plan, meas, c, use_kernel=True)
        assert np.max(np.abs(single - ref[c])) / scale < 2e-5, c


def test_reconstruct_batched_groups_same_signature(rng):
    """Same-signature marginals share ONE fused chain."""
    plan = _plan([3, 3, 3], [(0, 1), (1, 2), (0, 2)])
    margs = exact_marginals_from_x(
        plan.domain, plan.cliques,
        rng.integers(0, 5, plan.domain.universe_size()).astype(float))
    meas = measure_np(plan, margs, _ZeroRng())
    reset_chain_stats()
    reconstruct_all_batched(plan, meas, use_kernel=True)
    st = chain_stats()
    assert st["pallas_calls"] == 1    # three 3×3 marginals, one signature
    assert st["pads"] == 1 and st["slices"] == 1


def test_empty_clique_paths(rng):
    dom = Domain.create([4])
    wk = MarginalWorkload(dom, ((),))
    plan = select_sum_of_variances(wk, 1.0)
    margs = {(): np.array([7.0]), (0,): np.arange(4, dtype=float)}
    meas = measure(plan, margs, jax.random.PRNGKey(0), batched=True)
    assert meas[()].omega.shape == (1,)
    out = reconstruct_all_batched(plan, meas)
    assert out[()].shape == (1,)
