"""Privacy accountant boundary behavior: δ clamped to [0, 1], log-space
stability at large ε, and the documented pcost_for_eps_delta contract."""
import math

import pytest

from repro.core.accountant import (PrivacyBudget, approx_dp_delta,
                                   approx_dp_eps, pcost_for_eps_delta,
                                   zcdp_rho)


def test_delta_clamped_to_unit_interval():
    # the historical version returned small negative δ from catastrophic
    # cancellation at large pcost/ε, and nan beyond exp overflow
    for pcost in (1e-6, 0.1, 1.0, 100.0, 1e4, 1e6):
        for eps in (0.0, 0.5, 5.0, 80.0, 500.0, 1000.0):
            d = approx_dp_delta(pcost, eps)
            assert 0.0 <= d <= 1.0, (pcost, eps, d)
            assert not math.isnan(d)


def test_delta_monotone_decreasing_in_eps():
    for pcost in (0.5, 10.0, 1e4):
        deltas = [approx_dp_delta(pcost, e) for e in (0.0, 1.0, 4.0, 16.0)]
        assert all(a >= b - 1e-15 for a, b in zip(deltas, deltas[1:]))


def test_delta_large_pcost_saturates_at_one():
    assert approx_dp_delta(1e8, 1.0) == 1.0


def test_pcost_for_eps_delta_roundtrip():
    for eps, delta in ((0.5, 1e-9), (1.0, 1e-6), (8.0, 1e-4)):
        pc = pcost_for_eps_delta(eps, delta)
        assert approx_dp_delta(pc, eps) == pytest.approx(delta, rel=1e-6)
        assert approx_dp_eps(pc, delta) == pytest.approx(eps, rel=1e-5)


def test_pcost_for_eps_delta_large_eps():
    # exp(eps) overflows float64 beyond eps ~ 709: the doubling loop used to
    # run on nan and silently bisect garbage; now it brackets correctly
    pc = pcost_for_eps_delta(800.0, 1e-6)
    assert math.isfinite(pc) and pc > 0.0
    assert approx_dp_delta(pc, 800.0) == pytest.approx(1e-6, rel=1e-3)


def test_pcost_for_eps_delta_contract():
    for bad in (0.0, 1.0, 1.5, -1e-3):
        with pytest.raises(ValueError):
            pcost_for_eps_delta(1.0, bad)
    with pytest.raises(ValueError):
        pcost_for_eps_delta(-0.1, 1e-6)
    # unreachable under a tight cap raises instead of bisecting a lie
    with pytest.raises(ValueError):
        pcost_for_eps_delta(1.0, 0.5, hi_cap=1e-9)


def test_budget_from_approx_dp():
    b = PrivacyBudget.from_approx_dp(1.0, 1e-6)
    assert b.total_pcost > 0
    b.charge(b.total_pcost / 2)
    rep = b.report()
    assert rep["rho_zcdp"] == pytest.approx(zcdp_rho(b.spent))
    assert 0.0 <= rep["eps_at_delta_1e-6"] <= 1.0
