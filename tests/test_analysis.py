"""repro-lint analyzer tests: fixture corpus, baseline round-trip, CLI exit
codes, and the two gate-flip guarantees (deleting a ledger charge or a lock
guard in serve/ must turn the gate red)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis import (Baseline, Finding, analyze_file, analyze_paths,
                            analyze_source, iter_py_files)
from repro.analysis.registry import ALL_RULES, kernel_limits

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"
CLI = [sys.executable, str(REPO / "tools" / "repro_lint.py")]


def fixture_findings(name):
    return analyze_file(str(FIXTURES / name), repo_root=str(REPO))


# ------------------------------------------------------------------ fixtures
@pytest.mark.parametrize("name, expected", [
    ("privacy_violation.py",
     {("PF001", 12), ("PF001", 17), ("PF001", 21)}),
    ("charge_violation.py", {("PF002", 13)}),
    ("kernel_violation.py",
     {("KN001", 13), ("KN002", 17), ("KN003", 22),
      ("KN004", 28), ("KN004", 34), ("KN005", 39)}),
    ("lock_violation.py",
     {("LK001", 16), ("LK001", 22), ("LK002", 25)}),
])
def test_violation_fixture(name, expected):
    got = {(f.rule, f.line) for f in fixture_findings(name)}
    assert got == expected


@pytest.mark.parametrize("name", [
    "privacy_clean.py", "kernel_clean.py", "lock_clean.py"])
def test_clean_fixture(name):
    assert fixture_findings(name) == []


def test_every_fixture_rule_is_cataloged():
    findings = analyze_paths([str(FIXTURES)], repo_root=str(REPO))
    assert findings, "fixture corpus must exercise the analyzer"
    assert {f.rule for f in findings} <= set(ALL_RULES)


def test_parse_error_yields_lint000(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def broken(:\n")
    (finding,) = analyze_file(str(bad))
    assert finding.rule == "LINT000"


def test_inline_ignore_pragma():
    src = ("def f(fut, records):\n"
           "    h = exact_marginals_from_x(records)\n"
           "    fut.set_result(h)  # repro-lint: ignore[PF001]\n")
    assert analyze_source(src, "x/a.py") == []


def test_scope_pragma_gates_serve_rules():
    # same source WITHOUT the pragma, outside serve/: PF002 must not fire
    text = (FIXTURES / "charge_violation.py").read_text()
    no_pragma = "\n".join(text.splitlines()[1:])
    assert analyze_source(no_pragma, "tests/fixtures/lint/x.py") == []


# ------------------------------------------------------------------ baseline
def test_baseline_round_trip(tmp_path):
    findings = fixture_findings("lock_violation.py")
    path = tmp_path / "baseline.json"
    Baseline.from_findings(findings, reason="fixture").save(str(path))
    loaded = Baseline.load(str(path))
    new, waived = loaded.split(findings)
    assert new == [] and len(waived) == len(findings)
    assert loaded.stale(findings) == []
    assert loaded.stale([]) == sorted(f.fingerprint for f in findings)


def test_fingerprint_is_line_independent():
    a = Finding("LK001", "p.py", 10, "C.m:_n", "x")
    b = Finding("LK001", "p.py", 99, "C.m:_n", "y")
    assert a.fingerprint == b.fingerprint


def test_baseline_version_mismatch(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 999, "waivers": []}))
    with pytest.raises(ValueError):
        Baseline.load(str(path))


# ----------------------------------------------------------------------- CLI
def run_cli(*args, cwd=None):
    return subprocess.run(CLI + list(args), capture_output=True, text=True,
                          cwd=cwd or str(REPO))


def test_cli_gate_clean_on_tree():
    proc = run_cli("--gate")
    assert proc.returncode == 0, proc.stdout + proc.stderr


@pytest.mark.parametrize("name", [
    "privacy_violation.py", "charge_violation.py",
    "kernel_violation.py", "lock_violation.py"])
def test_cli_gate_fails_each_violation_class(name):
    proc = run_cli("--gate", str(FIXTURES / name))
    assert proc.returncode == 1


def test_cli_no_such_path():
    assert run_cli("--gate", "definitely/not/here").returncode == 2


def test_cli_rules_lists_catalog():
    proc = run_cli("--rules")
    assert proc.returncode == 0
    for rule in ALL_RULES:
        assert rule in proc.stdout


def test_cli_json_output():
    proc = run_cli("--json", str(FIXTURES / "lock_violation.py"))
    assert proc.returncode == 1
    blob = json.loads(proc.stdout)
    assert {f["rule"] for f in blob} == {"LK001", "LK002"}
    assert all("fingerprint" in f for f in blob)


def test_cli_write_baseline_then_gate(tmp_path):
    base = tmp_path / "b.json"
    target = str(FIXTURES / "kernel_violation.py")
    proc = run_cli("--write-baseline", "--baseline", str(base), target)
    assert proc.returncode == 0 and base.exists()
    assert run_cli("--gate", "--baseline", str(base), target).returncode == 0


# ----------------------------------------------------------------- gate flip
def test_deleting_ledger_charge_flips_gate():
    text = (REPO / "src/repro/serve/server.py").read_text()
    mutated = text.replace("self.ledger.charge(", "self._audit(")
    assert mutated != text
    rules = {f.rule for f in analyze_source(mutated,
                                            "src/repro/serve/server.py")}
    assert "PF002" in rules
    assert analyze_source(text, "src/repro/serve/server.py") == []


def test_deleting_lock_guard_flips_gate():
    text = (REPO / "src/repro/serve/pool.py").read_text()
    mutated = text.replace(
        "        with self._lock:\n            eng = self.cache.get",
        "        if True:\n            eng = self.cache.get")
    assert mutated != text
    rules = {f.rule for f in analyze_source(mutated,
                                            "src/repro/serve/pool.py")}
    assert "LK001" in rules
    assert analyze_source(text, "src/repro/serve/pool.py") == []


# ------------------------------------------------------------------- plumbing
def test_iter_py_files_skips_pycache(tmp_path):
    (tmp_path / "__pycache__").mkdir()
    (tmp_path / "__pycache__" / "x.py").write_text("")
    (tmp_path / "a.py").write_text("")
    names = [os.path.basename(p) for p in iter_py_files(str(tmp_path))]
    assert names == ["a.py"]


def test_kernel_limits_bind_to_live_tables():
    lim = kernel_limits()
    assert lim.sublane_for("float32") == 8
    assert lim.sublane_for("bfloat16") == 16
    assert lim.lane == 128
    assert lim.vmem_limit_real == 32 * 1024 * 1024
