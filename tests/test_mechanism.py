"""Measurement + reconstruction: pcost (Thm 3), unbiasedness (Thm 4),
variances, consistency — against dense brute-force linear algebra."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax

from repro.core import (Domain, MarginalWorkload, exact_marginals_from_x,
                        measure, measure_np, pcost_of_plan,
                        reconstruct_marginal, select_sum_of_variances)
from repro.core.kron import kron_expand
from repro.core.reconstruct import marginal_covariance_dense
from repro.core.residual import expand_marginal, expand_residual, sub_gram


class _ZeroRng:
    def standard_normal(self, n):
        return np.zeros(n)


def _plan(sizes, cliques, budget=1.0):
    dom = Domain.create(sizes)
    wk = MarginalWorkload(dom, tuple(cliques))
    return select_sum_of_variances(wk, budget,
                                   {c: float(dom.n_cells(c)) for c in cliques})


def _dense_pcost_matrix(plan):
    dom = plan.domain
    total = np.zeros((dom.universe_size(), dom.universe_size()))
    for c in plan.cliques:
        R = expand_residual(dom, c)
        cov = plan.sigmas[c] * (kron_expand(
            [sub_gram(dom.attributes[i].size) for i in c]) if c else np.ones((1, 1)))
        total += R.T @ np.linalg.inv(cov) @ R
    return total


def test_pcost_formula_vs_dense():
    plan = _plan([2, 3, 4], [(0,), (0, 1), (1, 2)])
    dense = _dense_pcost_matrix(plan)
    assert np.allclose(np.diag(dense).max(), pcost_of_plan(plan), atol=1e-9)
    # marginals ⇒ uniform per-record privacy cost (the symmetry of Appendix B)
    assert np.allclose(np.diag(dense), np.diag(dense)[0], atol=1e-9)


def test_reconstruction_exact_no_noise(rng):
    plan = _plan([3, 2, 4, 2], [(0, 2), (1, 3), (2, 3)])
    x = rng.integers(0, 9, plan.domain.universe_size()).astype(float)
    margs = exact_marginals_from_x(plan.domain, plan.cliques, x)
    meas = measure_np(plan, margs, _ZeroRng())
    for c in plan.workload.cliques:
        got = reconstruct_marginal(plan, meas, c)
        want = exact_marginals_from_x(plan.domain, [c], x)[c]
        assert np.allclose(got, want, atol=1e-8)


def test_reconstruction_consistency(rng):
    """Reconstructed marginals agree on shared sub-marginals (paper §4.3)."""
    plan = _plan([3, 3, 2], [(0, 1), (1, 2)])
    x = rng.integers(0, 9, plan.domain.universe_size()).astype(float)
    margs = exact_marginals_from_x(plan.domain, plan.cliques, x)
    meas = measure_np(plan, margs, rng)
    q01 = reconstruct_marginal(plan, meas, (0, 1)).reshape(3, 3)
    q12 = reconstruct_marginal(plan, meas, (1, 2)).reshape(3, 2)
    assert np.allclose(q01.sum(axis=0), q12.sum(axis=1), atol=1e-8)


def test_variance_formula_vs_dense_blue(rng):
    """Thm 4 variances == covariance of the dense BLUE estimator."""
    plan = _plan([2, 3, 2], [(0, 1), (1, 2), (0, 2)])
    dom = plan.domain
    pc = _dense_pcost_matrix(plan)
    for c in plan.workload.cliques:
        Q = expand_marginal(dom, c)
        cov = Q @ np.linalg.pinv(pc) @ Q.T
        assert np.allclose(np.diag(cov), plan.marginal_variance(c), atol=1e-8)
        assert np.allclose(cov, marginal_covariance_dense(plan, c), atol=1e-8)


def test_measurement_covariance_empirical(rng):
    """ω_A has covariance σ²_A · Sub Subᵀ (empirically, 3σ band)."""
    dom = Domain.create([4])
    wk = MarginalWorkload(dom, ((0,),))
    plan = select_sum_of_variances(wk, 1.0, {(0,): 4.0})
    margs = {(): np.array([0.0]), (0,): np.zeros(4)}
    n = 4000
    samples = np.array([measure_np(plan, margs, rng)[(0,)].omega
                        for _ in range(n)])
    emp = samples.T @ samples / n
    want = plan.sigmas[(0,)] * sub_gram(4)
    assert np.allclose(emp, want, atol=4 * want.max() / np.sqrt(n) * 3)


def test_jax_measure_matches_shapes():
    plan = _plan([3, 4], [(0, 1)])
    x = np.arange(12, dtype=float)
    margs = exact_marginals_from_x(plan.domain, plan.cliques, x)
    meas = measure(plan, margs, jax.random.PRNGKey(0))
    for c in plan.cliques:
        assert meas[c].omega.shape[0] == plan.domain.residual_size(c)


def test_unbiasedness_monte_carlo(rng):
    plan = _plan([2, 3], [(0, 1)], budget=50.0)
    x = rng.integers(0, 20, 6).astype(float)
    margs = exact_marginals_from_x(plan.domain, plan.cliques, x)
    want = exact_marginals_from_x(plan.domain, [(0, 1)], x)[(0, 1)]
    acc = np.zeros(6)
    n = 3000
    for _ in range(n):
        meas = measure_np(plan, margs, rng)
        acc += reconstruct_marginal(plan, meas, (0, 1))
    got = acc / n
    sd = np.sqrt(plan.marginal_variance((0, 1)) / n)
    assert np.all(np.abs(got - want) < 5 * sd + 1e-9)


def test_batched_measurement_matches_loop(rng):
    """§Perf M2: chunked-batched measurement is a drop-in for the loop."""
    from repro.core.mechanism import measure_np_batched
    plan = _plan([5, 3, 4, 2], [(0, 1), (1, 2), (2, 3), (0, 3)])
    x = rng.integers(0, 9, plan.domain.universe_size()).astype(float)
    margs = exact_marginals_from_x(plan.domain, plan.cliques, x)
    za = measure_np(plan, margs, _ZeroRng())
    zb = measure_np_batched(plan, margs, _ZeroRng(), chunk=3)
    for c in plan.cliques:
        assert np.allclose(za[c].omega, zb[c].omega, atol=1e-10)
    # with noise: same marginal statistics (variance within 4 sigma)
    meas = measure_np_batched(plan, margs, rng)
    for c in plan.workload.cliques:
        q = reconstruct_marginal(plan, meas, c)
        want = exact_marginals_from_x(plan.domain, [c], x)[c]
        sd = np.sqrt(plan.marginal_variance(c))
        assert np.all(np.abs(q - want) < 6 * sd + 1e-9)
