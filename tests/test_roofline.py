"""Roofline machinery: hlo_stats loop-aware accounting against known-FLOPs
programs, and coherence of the committed dry-run artifacts."""
import glob
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.roofline.hlo_stats import hlo_stats
from repro.roofline.analyze import analyze_cell

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_hlo_stats_counts_dot_flops():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 256), jnp.float32)
    st = hlo_stats(_compiled_text(lambda a, b: a @ b, a, b))
    want = 2 * 64 * 128 * 256
    assert st["flops"] == pytest.approx(want, rel=1e-6)


def test_hlo_stats_multiplies_scan_trip_count():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ a, None
        y, _ = jax.lax.scan(body, x, None, length=17)
        return y

    st = hlo_stats(_compiled_text(f, a))
    want = 17 * 2 * 64 * 64 * 64
    assert st["flops"] == pytest.approx(want, rel=0.05)


def test_hlo_stats_nested_loops():
    a = jnp.zeros((32, 32), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ a, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    st = hlo_stats(_compiled_text(f, a))
    want = 15 * 2 * 32 ** 3
    assert st["flops"] == pytest.approx(want, rel=0.05)


@pytest.mark.skipif(not os.path.isdir(ART), reason="dry-run artifacts absent")
def test_dryrun_artifacts_complete_and_coherent():
    files = glob.glob(os.path.join(ART, "*__single.json")) \
        + glob.glob(os.path.join(ART, "*__multi.json"))
    base = [f for f in files if "_fp8kv" not in f and "_kvsave" not in f
            and "_mb" not in f]
    assert len(base) == 80, f"expected 40 cells × 2 meshes, got {len(base)}"
    n_ok = n_skip = 0
    for f in base:
        with open(f) as fh:
            rec = json.load(fh)
        assert rec["status"] in ("ok", "skipped"), (f, rec.get("error"))
        if rec["status"] == "ok":
            n_ok += 1
            assert rec["memory_analysis"].get("argument_size_in_bytes", 0) > 0
            if "__single" in f:
                cell = analyze_cell(rec)
                assert cell.t_compute > 0 and cell.t_memory > 0
                assert cell.bottleneck in ("compute", "memory", "collective")
        else:
            n_skip += 1
            assert "full-attention" in rec["reason"]
    assert n_ok == 64 and n_skip == 16
