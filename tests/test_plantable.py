"""PlanTable IR (docs/DESIGN.md §9): arrayized closure vs the legacy dict
path, batched variance/covariance vs fp64 brute force, the unified plan
protocol, and the sharded engine LRU cache."""
import gc
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax

from repro.core import Domain, MarginalWorkload, all_kway, pcost_of_plan
from repro.core.plantable import PlanTable, plan_table, sov_closed_form
from repro.core.reconstruct import (cross_marginal_covariance_dense,
                                    marginal_covariance_dense)
from repro.core.select import (_coefficients, _variance_matrix,
                               legacy_maxvar_sigmas, legacy_sov_sigmas,
                               select, select_convex, select_max_variance,
                               select_sum_of_variances)


def _random_workload(sizes, k):
    dom = Domain.create(sizes)
    k = min(k, dom.n_attrs)
    return all_kway(dom, k, include_lower=True)


# ---------------------------------------------------------------------------
# IR arrays vs legacy dict/itertools coefficients
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(st.lists(st.integers(2, 6), min_size=2, max_size=5),
       st.integers(1, 3))
def test_table_matches_legacy_coefficients(sizes, k):
    wk = _random_workload(sizes, k)
    cl, p, v = _coefficients(wk)
    t = PlanTable.for_workload(wk)
    assert t.cliques == cl                      # identical (len, lex) order
    assert np.allclose(t.p, p, rtol=1e-12)
    assert np.allclose(t.v, v, rtol=1e-12)
    # the COO incidence is the legacy variance matrix, entry for entry
    rows, cols, vals = _variance_matrix(wk, cl)
    legacy = {(r, c): val for r, c, val in zip(rows, cols, vals)}
    table = {(r, c): val for r, c, val in
             zip(t.inc_rows, t.inc_cols, t.inc_vals)}
    assert set(legacy) == set(table)
    for key, val in legacy.items():
        assert math.isclose(table[key], val, rel_tol=1e-12)


def test_table_weight_override_modes():
    dom = Domain.create([3, 4, 2])
    wk = MarginalWorkload(dom, ((0,), (0, 1), (1, 2)), {(0,): 5.0})
    t = plan_table(wk)
    override = {(0, 1): 3.0}
    _, _, v_leg = _coefficients(wk, override)   # historical default-1.0 mode
    assert np.allclose(t.sov_coeffs(override), v_leg, rtol=1e-12)
    w = t.weight_vector(override, default_to_workload=True)
    assert w.tolist() == [5.0, 3.0, 1.0]


# ---------------------------------------------------------------------------
# Three objectives: IR path vs legacy dict path
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(st.lists(st.integers(2, 5), min_size=2, max_size=4),
       st.integers(1, 2))
def test_sov_ir_matches_legacy(sizes, k):
    wk = _random_workload(sizes, k)
    plan = select_sum_of_variances(wk, 1.0)
    leg = legacy_sov_sigmas(wk, 1.0)
    for c in plan.cliques:
        assert math.isclose(plan.sigmas[c], leg[c], rel_tol=1e-12), c


@settings(deadline=None, max_examples=6)
@given(st.lists(st.integers(2, 5), min_size=2, max_size=4))
def test_maxvar_ir_matches_legacy(sizes):
    wk = _random_workload(sizes, 2)
    plan = select_max_variance(wk, 1.0, iters=1500, backend="numpy")
    _, primal = legacy_maxvar_sigmas(wk, 1.0, iters=1500)
    assert math.isclose(plan.loss_value, primal, rel_tol=1e-6)
    assert plan.pcost == pytest.approx(1.0, rel=1e-6)


def test_maxvar_device_backend_matches_numpy():
    dom = Domain.create([5, 3, 4, 2])
    wk = all_kway(dom, 2, include_lower=True)
    a = select_max_variance(wk, 1.0, iters=1200, backend="numpy")
    b = select_max_variance(wk, 1.0, iters=1200, backend="device", chunk=100)
    assert math.isclose(a.loss_value, b.loss_value, rel_tol=1e-4)
    assert b.pcost == pytest.approx(1.0, rel=1e-6)


def test_convex_ir_matches_maxvar_dual():
    dom = Domain.create([4, 3, 5])
    wk = all_kway(dom, 2, include_lower=True)
    cv = select_convex(wk, 1.0, loss="max_variance", steps=2500)
    mv = select_max_variance(wk, 1.0)
    assert cv.loss_value <= mv.loss_value * 1.02
    assert cv.loss_value >= mv.loss_value * 0.999   # mv is the exact optimum
    assert cv.pcost == pytest.approx(1.0, rel=1e-9)


# ---------------------------------------------------------------------------
# select() dispatcher: convex objective + user-supplied losses (satellite)
# ---------------------------------------------------------------------------

def test_select_dispatch_convex_and_callable_loss():
    import jax.numpy as jnp
    dom = Domain.create([4, 3])
    wk = all_kway(dom, 2, include_lower=True)
    plan = select(wk, 1.0, objective="convex")       # defaults to max_variance
    assert plan.objective == "max_variance"
    assert plan.loss_value > 0.0                     # set at construction

    def l2_of_variances(var):                        # positively 1-homogeneous
        return jnp.sqrt(jnp.sum(var * var))

    p2 = select(wk, 1.0, objective="convex", loss=l2_of_variances, steps=1500)
    assert p2.objective == "l2_of_variances"
    got = float(np.sqrt(np.sum(p2.variances_array() ** 2)))
    assert p2.loss_value == pytest.approx(got, rel=1e-5)  # callable precision
    assert p2.pcost == pytest.approx(1.0, rel=1e-9)
    # callable objective shorthand routes the same way
    p3 = select(wk, 1.0, objective=l2_of_variances, steps=1500)
    assert p3.loss_value == pytest.approx(p2.loss_value, rel=1e-3)


# ---------------------------------------------------------------------------
# Zero-weight sliver path: no overflow at tiny budgets (satellite regression)
# ---------------------------------------------------------------------------

def test_sliver_path_finite_at_tiny_budget():
    dom = Domain.create([3, 4])
    wk = MarginalWorkload(dom, ((0,), (0, 1)))
    weights = {(0,): 1.0, (0, 1): 0.0}       # (1,) and (0,1) get v_A == 0
    for budget in (1.0, 1e-6, 1e-300):
        plan = select_sum_of_variances(wk, budget, weights)
        sig = plan.sigma
        assert np.all(np.isfinite(sig)) and np.all(sig > 0), budget
        assert np.isfinite(plan.pcost) and np.isfinite(plan.loss_value)
        assert plan.pcost <= budget * (1 + 1e-9)
    # the closed form itself: historic p/eps_share overflowed to inf here
    sig = sov_closed_form(np.array([0.5, 0.5]), np.array([1.0, 0.0]), 1e-300)
    assert np.all(np.isfinite(sig))


# ---------------------------------------------------------------------------
# Batched variances / covariances vs fp64 brute force (satellite)
# ---------------------------------------------------------------------------

def test_workload_variances_vs_dense_oracle():
    dom = Domain.create([2, 3, 2])
    wk = all_kway(dom, 2, include_lower=True)
    plan = select_sum_of_variances(wk, 1.0)
    var = plan.variances_array()
    for i, c in enumerate(wk.cliques):
        dense = marginal_covariance_dense(plan, c)
        assert np.allclose(np.diag(dense), var[i], atol=1e-10), c
        assert plan.marginal_variance(c) == pytest.approx(var[i], rel=1e-12)


def test_cross_covariance_vs_dense_oracle(rng):
    dom = Domain.create([3, 2, 4])
    wk = all_kway(dom, 2, include_lower=True)
    plan = select_max_variance(wk, 1.0, iters=500)   # non-uniform sigmas
    pairs = [((0, 1), (1, 2)), ((0, 1), (0, 1)), ((0,), (1, 2)),
             ((0, 2), (1,)), ((0,), (0, 1)), ((2,), (2,))]
    got = plan.workload_covariances(pairs)
    for g, (a, b) in zip(got, pairs):
        dense = cross_marginal_covariance_dense(plan, a, b)
        # aligned cell pair: coordinates agree on every shared axis
        coords = {i: int(rng.integers(dom.attributes[i].size))
                  for i in set(a) | set(b)}
        ia = int(np.ravel_multi_index([coords[i] for i in a],
                                      dom.clique_sizes(a))) if a else 0
        ib = int(np.ravel_multi_index([coords[i] for i in b],
                                      dom.clique_sizes(b))) if b else 0
        assert g == pytest.approx(dense[ia, ib], rel=1e-9, abs=1e-12), (a, b)
        assert plan.marginal_covariance(a, b) == pytest.approx(g, rel=1e-12)
    # self-covariance degenerates to the Thm-4 variance
    assert plan.marginal_covariance((0, 1), (0, 1)) == pytest.approx(
        plan.marginal_variance((0, 1)), rel=1e-12)


def test_cross_covariance_empirical(rng):
    """Monte-Carlo: reconstructed marginals correlate exactly as the IR says."""
    from repro.core.mechanism import exact_marginals_from_x, measure_np
    from repro.core.reconstruct import reconstruct_marginal
    dom = Domain.create([2, 3])
    wk = all_kway(dom, 2, include_lower=True)
    plan = select_sum_of_variances(wk, 2.0)
    x = rng.integers(0, 9, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    a, b = (0,), (0, 1)
    n = 3000
    sa = np.empty((n, 2))
    sb = np.empty((n, 6))
    for t in range(n):
        meas = measure_np(plan, margs, rng)
        sa[t] = reconstruct_marginal(plan, meas, a)
        sb[t] = reconstruct_marginal(plan, meas, b)
    emp = (sa - sa.mean(0)).T @ (sb - sb.mean(0)) / n
    want = plan.marginal_covariance(a, b)
    # aligned cells: a-cell i vs b-cell (i, j)
    for i in range(2):
        for j in range(3):
            band = 6 * plan.marginal_variance(b) / math.sqrt(n)
            assert abs(emp[i, 3 * i + j] - want) < band


# ---------------------------------------------------------------------------
# Unified plan protocol
# ---------------------------------------------------------------------------

def test_plus_plan_carries_the_same_protocol():
    from repro.core.plus import PlusSchema, select_plus, sov_coeff_plus
    from repro.core.domain import subsets
    dom = Domain.create([3, 4, 2])
    wk = MarginalWorkload(dom, ((0,), (0, 1), (1, 2)))
    schema = PlusSchema.create(dom, ["prefix", "identity", "prefix"],
                               strategy_mode="w")
    plan = select_plus(wk, schema, 1.0, "sov")
    assert plan.domain is dom                       # protocol property
    for c in wk.cliques:                            # IR sov == legacy Thm-8 sum
        legacy = sum(plan.sigmas[s] * sov_coeff_plus(schema, s, c)
                     for s in subsets(c))
        assert plan.sov(c) == pytest.approx(legacy, rel=1e-9)
    assert plan.sigma2((0,)) == pytest.approx(plan.sigmas[(0,)], rel=1e-15)
    assert set(plan.workload_variances()) == set(wk.cliques)


def test_no_plan_type_branching_in_engines():
    """Acceptance: engines consume the plan protocol, never the concrete type."""
    import pathlib
    import repro.engine as eng
    root = pathlib.Path(eng.__file__).parent
    for path in root.glob("*.py"):
        src = path.read_text()
        assert "isinstance(plan, PlusPlan)" not in src, path.name


def test_discrete_consumes_protocol_and_rejects_plus():
    import random
    from repro.core.discrete import measure_discrete
    from repro.core.plus import PlusSchema, select_plus
    dom = Domain.create([3, 2])
    wk = all_kway(dom, 2, include_lower=True)
    schema = PlusSchema.create(dom, ["prefix", "identity"], strategy_mode="w")
    pplan = select_plus(wk, schema, 1.0, "sov")
    with pytest.raises(ValueError):
        measure_discrete(pplan, {}, random.Random(0))


# ---------------------------------------------------------------------------
# Sharded engine cache: LRU + weak-safe plan keying (satellite)
# ---------------------------------------------------------------------------

def test_engine_cache_lru_and_weak_keys():
    from repro.engine.sharded import _EngineCache

    class _P:                      # stand-in plan (weakref-able, id-hashable)
        pass

    cache = _EngineCache(maxsize=3)
    plans = [_P() for _ in range(4)]
    for i, p in enumerate(plans[:3]):
        cache.put(p, False, np.float32, f"eng{i}")
    assert len(cache) == 3
    assert cache.get(plans[0], False, np.float32) == "eng0"   # now MRU
    cache.put(plans[3], False, np.float32, "eng3")
    # exactly ONE entry evicted (the LRU: plans[1]), not a wholesale clear
    assert len(cache) == 3
    assert cache.get(plans[1], False, np.float32) is None
    assert cache.get(plans[0], False, np.float32) == "eng0"
    assert cache.get(plans[3], False, np.float32) == "eng3"
    # weak keying: collecting a plan drops its entries immediately
    del plans[3]
    gc.collect()
    assert len(cache) == 2


def test_sharded_measure_uses_protocol_dispatch():
    import jax.numpy as jnp
    from repro.data.tabular import synth_domain, synthetic_records
    from repro.engine.sharded import _ENGINE_CACHE, sharded_measure
    from repro.engine.engine import MarginalEngine
    dom = synth_domain(3, 3)
    wk = all_kway(dom, 2)
    plan = select_sum_of_variances(wk, 5.0)
    recs = synthetic_records(dom, 200, seed=0)
    meas = sharded_measure(plan, jnp.asarray(recs), jax.random.PRNGKey(0))
    assert set(meas) == set(plan.cliques)
    # plain plans route through MarginalEngine via plan.engine()
    from repro.core.mechanism import noise_dtype
    eng = _ENGINE_CACHE.get(plan, False, noise_dtype())
    assert isinstance(eng, MarginalEngine)
