"""ResidualPlanner+ (Algs 4/5/6, Thms 7/8) against dense brute force."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Domain, MarginalWorkload, select_sum_of_variances
from repro.core.kron import kron_expand, kron_matvec_np
from repro.core.mechanism import exact_marginals_from_x
from repro.core.plus import (PlusSchema, attr_basis, build_w,
                             cell_variances_plus, measure_plus_np,
                             p_coeff_plus, reconstruct_plus, s_hierarchical,
                             select_plus, sov_coeff_plus, w_prefix, w_range)


class _ZeroRng:
    def standard_normal(self, n):
        return np.zeros(n)


def _brute(plan, schema, dom, clique):
    Bs, covs = [], []
    for c in plan.cliques:
        facs = [schema.bases[i].Sub if i in set(c) else
                np.ones((1, dom.attributes[i].size)) for i in range(dom.n_attrs)]
        R = kron_expand(facs)
        G = kron_expand([schema.bases[i].Gamma for i in c]) if c else np.ones((1, 1))
        Bs.append(R)
        covs.append(plan.sigmas[c] * G @ G.T)
    B = np.vstack(Bs)
    Sig = np.zeros((B.shape[0],) * 2)
    o = 0
    for cv in covs:
        k = cv.shape[0]
        Sig[o:o + k, o:o + k] = cv
        o += k
    pc = B.T @ np.linalg.inv(Sig) @ B
    facs = [schema.bases[i].W if i in set(clique) else
            np.ones((1, dom.attributes[i].size)) for i in range(dom.n_attrs)]
    Q = kron_expand(facs)
    return Q @ np.linalg.pinv(pc) @ Q.T, pc


@pytest.mark.parametrize("kinds,mode", [
    (["prefix", "identity", "prefix"], "w"),
    (["range", "identity", "range"], "w"),
    (["prefix", "prefix", "identity"], "hier"),
])
def test_thm7_thm8_vs_dense(kinds, mode):
    dom = Domain.create([3, 4, 2])
    wk = MarginalWorkload(dom, ((0,), (0, 1), (1, 2)))
    schema = PlusSchema.create(dom, kinds, strategy_mode=mode)
    plan = select_plus(wk, schema, 1.0, "sov")
    for c in wk.cliques:
        cov, pc = _brute(plan, schema, dom, c)
        assert math.isclose(plan.sov(c), np.trace(cov), rel_tol=1e-7)
        cells = cell_variances_plus(schema, plan.sigmas, c)
        assert np.allclose(cells, np.diag(cov), atol=1e-8)
    pcost = sum(p_coeff_plus(schema, c) / plan.sigmas[c] for c in plan.cliques)
    _, pc = _brute(plan, schema, dom, wk.cliques[0])
    assert math.isclose(pcost, np.diag(pc).max(), rel_tol=1e-7)


def test_alg4_properties():
    """Sub·1 = 0; rowspace(Sub) = rowspace(P1); identity branch = Section 4.2."""
    for n in (2, 3, 7, 16):
        for kind in ("prefix", "range"):
            b = attr_basis(build_w(kind, n))
            assert np.allclose(b.Sub @ np.ones(n), 0.0, atol=1e-8)
            P1 = b.S - (b.S @ np.ones((n, 1))) @ np.ones((1, n)) / n
            assert np.linalg.matrix_rank(np.vstack([b.Sub, P1]),
                                         tol=1e-8) == b.Sub.shape[0]
    bi = attr_basis(np.eye(5))
    assert bi.identity and math.isclose(bi.beta, 4 / 5, rel_tol=1e-12)


def test_rplus_reconstruction_exact(rng):
    dom = Domain.create([4, 3, 5])
    wk = MarginalWorkload(dom, ((0,), (0, 2), (1, 2)))
    schema = PlusSchema.create(dom, ["prefix", "identity", "range"],
                               strategy_mode="hier")
    plan = select_plus(wk, schema, 1.0, "sov")
    x = rng.integers(0, 7, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    meas = measure_plus_np(plan, margs, _ZeroRng())
    for c in wk.cliques:
        got = reconstruct_plus(plan, meas, c)
        wfacs = [schema.bases[i].W for i in c]
        want = kron_matvec_np(wfacs, margs[c],
                              [dom.attributes[i].size for i in c])
        assert np.allclose(got, want, atol=1e-7)


def test_identity_rplus_equals_rp():
    dom = Domain.create([3, 4, 2])
    wk = MarginalWorkload(dom, ((0,), (0, 1), (1, 2)))
    schema = PlusSchema.create(dom, ["identity"] * 3)
    p_plus = select_plus(wk, schema, 1.0, "sov")
    p_rp = select_sum_of_variances(
        wk, 1.0, {c: float(dom.n_cells(c)) for c in wk.cliques})
    for c in p_rp.cliques:
        assert math.isclose(p_plus.sigmas[c], p_rp.sigmas[c], rel_tol=1e-9)


def test_hier_strategy_beats_w_for_prefix():
    """A good strategy replacement lowers RMSE at fixed budget (the point of §7)."""
    dom = Domain.create([64, 3])
    wk = MarginalWorkload(dom, ((0,), (0, 1)))
    rmse_w = select_plus(wk, PlusSchema.create(dom, ["prefix", "identity"],
                                               strategy_mode="w"), 1.0).rmse()
    rmse_h = select_plus(wk, PlusSchema.create(dom, ["prefix", "identity"],
                                               strategy_mode="hier"), 1.0).rmse()
    assert rmse_h < rmse_w


def test_maxvar_plus_solver():
    dom = Domain.create([8, 3])
    wk = MarginalWorkload(dom, ((0,), (1,), (0, 1)))
    schema = PlusSchema.create(dom, ["prefix", "identity"], strategy_mode="w")
    mv = select_plus(wk, schema, 1.0, "max_variance", steps=1500)
    sov = select_plus(wk, schema, 1.0, "sov")
    assert mv.max_cell_variance() <= sov.max_cell_variance() * 1.02
    pcost = sum(p_coeff_plus(schema, c) / mv.sigmas[c] for c in mv.cliques)
    assert math.isclose(pcost, 1.0, rel_tol=1e-6)
