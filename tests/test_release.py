"""Release subsystem (docs/DESIGN.md §11): consistency, non-negativity, synth.

The consistency solver is validated against the fp64 dense WLS oracle (both
for per-marginal precision weights, where the normal equations are block-
diagonal and the preconditioned CG converges in one iteration, and for
per-cell weight overrides, where the decoupling breaks and the CG genuinely
iterates); non-negativity and totals are property-tested; synthesis is
χ²-checked against the released marginals on a tree workload, where junction
sampling is exact.
"""
import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st

from repro.core import (Domain, MarginalWorkload, all_kway, measure_np,
                        reconstruct_all, select)
from repro.core.mechanism import exact_marginals_from_x
from repro.release import (dense_wls_oracle, junction_order, mw_refine,
                           nonneg_release, postprocess_release,
                           precision_weights, project_nonneg,
                           simplex_project_batch, solve_consistency,
                           synth_report, synthesize_records)


def _setup(sizes, seed=0, kmax=2, pcost=1.0, workload=None):
    dom = Domain.create(list(sizes))
    wk = all_kway(dom, min(kmax, dom.n_attrs), include_lower=True) \
        if workload is None else workload
    plan = select(wk, pcost_budget=pcost)
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 40, dom.universe_size()).astype(np.float64)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    meas = measure_np(plan, margs, rng)
    tables = reconstruct_all(plan, meas)
    return dom, wk, plan, x, tables, rng


def _perturb(tables, rng, scale=5.0):
    return {c: t + rng.normal(0, scale, t.shape) for c, t in tables.items()}


def _assert_total(t, total):
    """Total preserved to within one ulp; integer totals round-trip exactly."""
    assert abs(t.sum() - total) <= 2 * np.spacing(max(abs(total), 1.0))
    if float(total).is_integer():
        assert round(float(t.sum())) == int(total)


# ---------------------------------------------------------------- consistency

def test_cg_matches_dense_oracle():
    _, wk, plan, _, tables, rng = _setup([3, 4, 2, 3])
    pert = _perturb(tables, rng)
    cg = solve_consistency(plan, pert, backend="host")
    dense = dense_wls_oracle(plan, pert)
    np.testing.assert_allclose(cg.r, dense.r, rtol=1e-9, atol=1e-9)
    # the fitted family is mutually consistent: shared sub-marginals agree
    fit = cg.marginals()
    m01 = fit[(0, 1)].reshape(3, 4)
    m12 = fit[(1, 2)].reshape(4, 2)
    np.testing.assert_allclose(m01.sum(axis=0), m12.sum(axis=1), atol=1e-8)


def test_cg_single_iteration_with_marginal_weights():
    """Per-marginal precision weights: M is block-diagonal over the closure,
    the Kron-factored preconditioner is exact, CG converges in 1 step."""
    _, _, plan, _, tables, rng = _setup([3, 4, 2, 3])
    cg = solve_consistency(plan, _perturb(tables, rng), backend="host")
    assert cg.iterations <= 2
    assert cg.rel_residual < 1e-9


def test_cg_cell_weights_vs_oracle():
    """Per-cell weights break the block-diagonal decoupling: CG must iterate
    and still reach the dense WLS optimum."""
    _, wk, plan, _, tables, rng = _setup([3, 4, 2])
    pert = _perturb(tables, rng)
    cw = {c: rng.uniform(0.2, 2.0, tables[c].size) for c in wk.cliques}
    cg = solve_consistency(plan, pert, cell_weights=cw, backend="host",
                           tol=1e-12, maxiter=500)
    dense = dense_wls_oracle(plan, pert, cell_weights=cw)
    assert cg.iterations > 2
    scale = max(1.0, float(np.abs(dense.r).max()))
    np.testing.assert_allclose(cg.r / scale, dense.r / scale, atol=1e-8)


def test_device_backend_matches_host():
    _, _, plan, _, tables, rng = _setup([3, 4, 2, 3])
    pert = _perturb(tables, rng)
    host = solve_consistency(plan, pert, backend="host")
    dev = solve_consistency(plan, pert, backend="device")
    scale = max(1.0, float(np.abs(host.r).max()))
    np.testing.assert_allclose(dev.r / scale, host.r / scale, atol=5e-5)


def test_fix_total_pins_every_marginal_sum():
    _, wk, plan, _, tables, rng = _setup([3, 4, 2])
    cg = solve_consistency(plan, _perturb(tables, rng), fix_total=1234.0,
                           backend="host")
    assert cg.total == 1234.0
    for q in cg.marginals().values():
        assert abs(q.sum() - 1234.0) < 1e-6 * 1234.0
    dense = dense_wls_oracle(plan, _perturb(tables, rng), fix_total=777.0)
    assert dense.total == 777.0


def test_consistency_weight_validation():
    _, wk, plan, _, tables, _ = _setup([3, 4])
    with pytest.raises(ValueError):
        solve_consistency(plan, tables, weights=np.zeros(len(wk.cliques)))
    with pytest.raises(ValueError):
        solve_consistency(plan, tables, weights=np.ones(len(wk.cliques) + 1))
    with pytest.raises(ValueError):
        solve_consistency(plan, tables, backend="nope")
    assert np.all(precision_weights(plan) > 0)


@settings(deadline=None, max_examples=6)
@given(st.lists(st.integers(2, 4), min_size=2, max_size=4),
       st.integers(0, 10 ** 6))
def test_idempotent_on_consistent_inputs(sizes, seed):
    """Engine reconstructions are already mutually consistent — the WLS fit
    must return them unchanged (the fit residual is exactly zero)."""
    _, wk, plan, _, tables, _ = _setup(sizes, seed=seed)
    cons = solve_consistency(plan, tables, backend="host")
    fit = cons.marginals()
    scale = max(1.0, max(float(np.abs(t).max()) for t in tables.values()))
    for c in wk.cliques:
        np.testing.assert_allclose(fit[c] / scale, tables[c] / scale,
                                   atol=1e-9)


# ------------------------------------------------------------- non-negativity

def test_simplex_projection_matches_reference():
    rng = np.random.default_rng(3)
    y = rng.normal(2.0, 5.0, (7, 11))
    for backend in ("host", "device"):
        q = simplex_project_batch(y, 10.0, backend=backend)
        assert np.all(q >= 0)
        np.testing.assert_allclose(q.sum(axis=1), 10.0, atol=1e-4)
        # projection optimality: q is the closest point of the simplex, so
        # moving mass between any two cells with q_i > 0 must not improve
        d = q - y
        for g in range(y.shape[0]):
            active = q[g] > 1e-9
            grad = d[g][active]
            assert grad.max() - grad.min() < 1e-4


def test_nonneg_release_properties():
    _, wk, plan, x, tables, rng = _setup([3, 4, 2, 3], pcost=0.05)
    total = float(x.sum())
    out = nonneg_release(plan, tables, total=total)
    for c in wk.cliques:
        assert np.all(out[c] >= 0)
        _assert_total(out[c], total)       # fp64 total preservation
    raw_err = sum(np.abs(tables[c] - exact_marginals_from_x(
        plan.domain, [c], x)[c]).sum() for c in wk.cliques)
    nn_err = sum(np.abs(out[c] - exact_marginals_from_x(
        plan.domain, [c], x)[c]).sum() for c in wk.cliques)
    assert nn_err <= raw_err               # projection toward the truth helps


@settings(deadline=None, max_examples=6)
@given(st.lists(st.integers(2, 4), min_size=2, max_size=3),
       st.integers(0, 10 ** 6))
def test_nonneg_property(sizes, seed):
    _, wk, plan, x, tables, rng = _setup(sizes, seed=seed, pcost=0.2)
    pert = _perturb(tables, rng, scale=3.0)
    total = float(x.sum())
    out = nonneg_release(plan, pert, total=total, mw_rounds=1)
    for c in wk.cliques:
        assert np.all(out[c] >= 0)
        _assert_total(out[c], total)


def test_project_nonneg_local_only():
    dom = Domain.create([3, 4])
    tables = {(0,): np.array([5.0, -2.0, 3.0]),
              (1,): np.array([-1.0, 2.0, 2.0, 1.0])}
    out = project_nonneg(dom, tables, total=6.0)
    for t in out.values():
        assert np.all(t >= 0)
        _assert_total(t, 6.0)


def test_mw_refine_reduces_inconsistency():
    _, wk, plan, x, tables, rng = _setup([3, 4, 2], pcost=0.1)
    total = float(x.sum())
    projected = project_nonneg(plan.domain, tables, total)

    def inconsistency(q):
        cons = solve_consistency(plan, q, fix_total=total, backend="host")
        fit = cons.marginals()
        return sum(float(np.abs(fit[c] - q[c]).sum()) for c in wk.cliques)

    refined = mw_refine(plan, projected, total, rounds=3, eta=0.8)
    for c in wk.cliques:
        assert np.all(refined[c] >= 0)
        _assert_total(refined[c], total)
    assert inconsistency(refined) <= inconsistency(projected) + 1e-6


def test_zero_total_projects_to_zero():
    dom = Domain.create([3, 2])
    out = project_nonneg(dom, {(0,): np.array([1.0, -2.0, 0.5])}, total=-4.0)
    assert np.all(out[(0,)] == 0.0)


# -------------------------------------------------------------------- synth

def test_junction_order_chain_is_markov():
    dom = Domain.create([3, 4, 2, 3])
    steps = junction_order(dom, [(0, 1), (1, 2), (2, 3)])
    assert [s[0] for s in steps] == [0, 1, 2, 3]
    assert steps[1][2] == (0,) and steps[2][2] == (1,) and steps[3][2] == (2,)


def test_junction_order_rejects_uncovered_attribute():
    dom = Domain.create([3, 4, 2])
    with pytest.raises(ValueError):
        junction_order(dom, [(0, 1)])


def test_synthesize_chi_square_on_tree_workload():
    """On a tree workload junction sampling is exact: sampled marginals must
    match the released ones within sampling error (χ² check, z=6)."""
    dom = Domain.create([3, 4, 2, 3])
    wk = MarginalWorkload(dom, ((0, 1), (1, 2), (2, 3)))
    _, _, plan, x, tables, rng = _setup([3, 4, 2, 3], seed=1, pcost=2.0,
                                        workload=wk)
    total = float(x.sum())
    nn = nonneg_release(plan, tables, total=total)
    recs = synthesize_records(dom, nn, 120_000, jax.random.PRNGKey(0))
    assert recs.shape == (120_000, 4) and recs.dtype == np.int32
    for i, a in enumerate(dom.attributes):
        assert recs[:, i].min() >= 0 and recs[:, i].max() < a.size
    report = synth_report(dom, nn, recs, total=total)
    assert report.ok(z=6.0), report.summary()
    assert report.max_tv < 0.05


def test_synthesize_batched_matches_unbatched_shapes():
    dom = Domain.create([3, 4])
    wk = MarginalWorkload(dom, ((0, 1),))
    _, _, plan, x, tables, _ = _setup([3, 4], pcost=2.0, workload=wk)
    nn = nonneg_release(plan, tables, total=float(x.sum()))
    r1 = synthesize_records(dom, nn, 5000, jax.random.PRNGKey(7))
    r2 = synthesize_records(dom, nn, 5000, jax.random.PRNGKey(7), batch=1024)
    assert r1.shape == r2.shape == (5000, 2)
    with pytest.raises(ValueError):
        synthesize_records(dom, nn, 0, jax.random.PRNGKey(0))


# ----------------------------------------------------------- postprocess glue

def test_postprocess_release_modes():
    _, wk, plan, x, tables, rng = _setup([3, 4, 2])
    pert = _perturb(tables, rng)
    cons = postprocess_release(plan, pert, "consistent")
    assert set(cons) == set(wk.cliques)
    nn = postprocess_release(plan, pert, "nonneg", total=float(x.sum()))
    assert all(np.all(t >= 0) for t in nn.values())
    with pytest.raises(ValueError):
        postprocess_release(plan, pert, "fancy")
