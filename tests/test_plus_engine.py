"""PlusEngine (signature-batched device Algs 5/6) vs the numpy oracles.

Covers the generalized-signature batching, the staged [v; z] measurement
chains, the merged T_i reconstruction with implicit prefix/range W epilogues,
identity/prefix/range/custom bases, odd attribute sizes, and the empty
clique (docs/DESIGN.md §8).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax

from repro.core import Domain, MarginalWorkload
from repro.core.mechanism import exact_marginals_from_x, noise_dtype
from repro.core.plus import (PlusSchema, attr_basis, measure_plus_np,
                             plus_signature_groups, reconstruct_plus,
                             reconstruct_plus_merged, select_plus)
from repro.engine.plus_engine import PlusEngine, expand_range_axis
from repro.engine.sharded import sharded_marginals, sharded_measure
from repro.kernels.kron_matvec.stats import chain_stats, reset_chain_stats

# "total" is excluded: its basis is rank-0 (Sub has no rows) and select_plus
# does not support degenerate bases — the custom basis below covers the
# dense-W fold path instead.
KINDS = ["identity", "prefix", "range", "custom"]


class _ReplayRng:
    """Feeds the engine's exact jax noise draws into the numpy oracle."""

    def __init__(self, draws, order):
        self._queue = [np.asarray(draws[c], np.float64) for c in order]

    def standard_normal(self, n):
        z = self._queue.pop(0)
        assert z.shape == (n,), (z.shape, n)
        return z


def _mk_schema(dom, kinds, mode="hier"):
    base_kinds = ["identity" if k == "custom" else k for k in kinds]
    schema = PlusSchema.create(dom, base_kinds, strategy_mode=mode)
    if "custom" in kinds:
        # custom basic matrix: identity rows + the total row (1ᵀ is trivially
        # in the row space); exercises the dense-W fold path of the engine.
        bases = list(schema.bases)
        for i, kind in enumerate(kinds):
            if kind == "custom":
                n = dom.attributes[i].size
                bases[i] = attr_basis(np.vstack([np.eye(n), np.ones((1, n))]))
        schema = PlusSchema(dom, tuple(bases))
    return schema


def _engine_vs_oracles(sizes, kinds, mode, rng, use_kernel,
                       cliques=None, atol=1e-4):
    dom = Domain.create(list(sizes))
    if cliques is None:
        cliques = tuple((i,) for i in range(len(sizes)))
        if len(sizes) >= 2:
            cliques += ((0, 1),)
        if len(sizes) >= 3:
            cliques += ((1, 2), (0, 1, 2))
    wk = MarginalWorkload(dom, tuple(cliques))
    schema = _mk_schema(dom, kinds, mode)
    plan = select_plus(wk, schema, 1.0, "sov")
    x = rng.integers(0, 7, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng = PlusEngine(plan, use_kernel=use_kernel, precompile=False)
    key = jax.random.PRNGKey(7)
    meas = eng.measure(margs, key)
    oracle = measure_plus_np(plan, margs,
                             _ReplayRng(eng.noise_draws(key), plan.cliques))
    for c in plan.cliques:
        scale = max(np.abs(oracle[c].omega).max(), 1.0)
        assert np.abs(meas[c].omega - oracle[c].omega).max() / scale < atol, c
    tables = eng.reconstruct(meas)
    for c in wk.cliques:
        want = reconstruct_plus(plan, oracle, c)
        scale = max(np.abs(want).max(), 1.0)
        assert np.abs(tables[c] - want).max() / scale < atol, c
    return plan, eng


@pytest.mark.parametrize("use_kernel", [False, True])
def test_plus_engine_mixed_workload_matches_oracles(use_kernel, rng):
    """Acceptance: mixed marginal+range+prefix workload, ≤1e-4 (float32)."""
    _engine_vs_oracles([4, 3, 5], ["prefix", "identity", "range"], "hier",
                       rng, use_kernel,
                       cliques=((0,), (0, 2), (1, 2), (0, 1, 2), ()))


@pytest.mark.parametrize("kinds,mode", [
    (["range", "range", "range"], "hier"),       # all-general: no stage B
    (["identity", "identity", "identity"], "w"),  # all-identity: PR-1 chain
    (["identity", "prefix", "custom"], "w"),
    (["custom", "range", "identity"], "hier"),
])
def test_plus_engine_basis_mixes(kinds, mode, rng):
    _engine_vs_oracles([3, 4, 2], kinds, mode, rng, use_kernel=True)


def test_plus_engine_odd_sizes_and_empty_clique(rng):
    plan, eng = _engine_vs_oracles(
        [7, 2, 5], ["range", "identity", "prefix"], "hier", rng,
        use_kernel=True, cliques=((), (0,), (2,), (0, 2), (0, 1)))
    assert () in plan.cliques   # empty clique measured and reconstructible


def test_merged_chain_oracle_exact_fp64(rng):
    """Σ_{A'⊆A} U ω == (⊗ W_i T_i) Σ e_{A'} exactly, generalized bases."""
    dom = Domain.create([4, 3, 5])
    wk = MarginalWorkload(dom, ((0, 1, 2), (0, 2), (1,)))
    schema = PlusSchema.create(dom, ["prefix", "identity", "range"],
                               strategy_mode="hier")
    plan = select_plus(wk, schema, 1.0, "sov")
    margs = exact_marginals_from_x(
        dom, plan.cliques,
        rng.integers(0, 9, dom.universe_size()).astype(float))
    meas = measure_plus_np(plan, margs, rng)
    for c in wk.cliques:
        want = reconstruct_plus(plan, meas, c)
        got = reconstruct_plus_merged(plan, meas, c)
        assert np.allclose(want, got, atol=1e-9), c


def test_range_expansion_matches_dense_w(rng):
    from repro.core.plus import w_range
    for n in (2, 3, 6, 9):
        x = rng.standard_normal((4, n))
        p = np.cumsum(x, axis=1)
        got = np.asarray(expand_range_axis(jax.numpy.asarray(p), 1, n))
        want = x @ w_range(n).T
        assert np.allclose(got, want, atol=1e-6), n


def test_plus_engine_batches_by_generalized_signature(rng):
    """Same sizes, different bases ⇒ different groups; equal bases batch."""
    dom = Domain.create([4, 4, 4, 4])
    schema = PlusSchema.create(dom, ["range", "range", "prefix", "prefix"],
                               strategy_mode="hier")
    cliques = [(0,), (1,), (2,), (3,), (0, 1), (2, 3), (0, 2)]
    groups = plus_signature_groups(schema, cliques)
    sizes = sorted(len(g) for g in groups.values())
    # (0,)+(1,) batch, (2,)+(3,) batch, the pairs stay separate
    assert sizes == [1, 1, 1, 2, 2]
    # size-keyed grouping would have collapsed everything per arity
    from repro.core.mechanism import signature_groups
    assert len(signature_groups(dom, cliques)) == 2


def test_plus_engine_serving_chain_counts(rng):
    """Serving issues one fused chain per planned group stage, not per clique."""
    dom = Domain.create([3, 3, 3])
    wk = MarginalWorkload(dom, ((0, 1), (1, 2), (0, 2)))
    schema = PlusSchema.create(dom, ["range"] * 3, strategy_mode="hier")
    plan = select_plus(wk, schema, 1.0, "sov")
    margs = {c: np.arange(dom.n_cells(c), dtype=float) for c in plan.cliques}
    eng = PlusEngine(plan, use_kernel=True)
    reset_chain_stats()
    eng.release(margs, jax.random.PRNGKey(0))
    st = chain_stats()
    # measurement: all-general bases ⇒ stage A only, one chain per non-empty
    # group (arity 1 and 2); reconstruction: one merged chain for the three
    # same-signature pairs.
    assert st["pallas_calls"] == 3
    assert st["fallback_chains"] == 0
    assert eng.stats.compile_warmups == len(eng.chain_plans()) > 0


def test_plus_engine_precompile_covers_serving(rng):
    plan, eng = _engine_vs_oracles([4, 3, 5],
                                   ["prefix", "identity", "range"], "hier",
                                   rng, use_kernel=True)
    eng2 = PlusEngine(plan, use_kernel=True, precompile=True)
    assert eng2.stats.compile_warmups == len(eng2.chain_plans()) > 0
    assert eng2.stats.measure_signatures <= len(plan.cliques)
    for row in eng2.chain_plans():
        assert row["w_in"] % 128 == 0 and row["batch_padded"] % 8 == 0


def test_sharded_measure_plus_plan_path(rng):
    """sharded_measure accepts a PlusPlan and matches the engine transform."""
    dom = Domain.create([3, 4, 2])
    wk = MarginalWorkload(dom, ((0, 1), (1, 2)))
    schema = PlusSchema.create(dom, ["prefix", "identity", "identity"],
                               strategy_mode="w")
    plan = select_plus(wk, schema, 1.0, "sov")
    records = rng.integers(0, 2, size=(50, 3)).astype(np.int32)
    key = jax.random.PRNGKey(4)
    meas = sharded_measure(plan, jax.numpy.asarray(records), key)
    margs = sharded_marginals(dom, plan.cliques, jax.numpy.asarray(records))
    want = PlusEngine(plan, use_kernel=False,
                      precompile=False).measure(margs, key)
    for c in plan.cliques:
        assert np.allclose(meas[c].omega, want[c].omega, atol=1e-5), c


def test_sharded_measure_dtype_threading(rng):
    """Noise/marginal dtype defaults to noise_dtype() and is overridable."""
    from repro.core import select_sum_of_variances
    dom = Domain.create([3, 4])
    wk = MarginalWorkload(dom, ((0, 1),))
    plan = select_sum_of_variances(wk, 5.0)
    records = rng.integers(0, 3, size=(40, 2)).astype(np.int32)
    rj = jax.numpy.asarray(records)
    margs = sharded_marginals(dom, plan.cliques, rj)
    assert all(m.dtype == noise_dtype() for m in margs.values())
    m32 = sharded_marginals(dom, plan.cliques, rj, dtype=jax.numpy.float32)
    assert all(m.dtype == jax.numpy.float32 for m in m32.values())
    # default draw == the core loop's draw (same fold order, same dtype)
    from repro.core.mechanism import measure
    got = sharded_measure(plan, rj, jax.random.PRNGKey(1))
    want = measure(plan, margs, jax.random.PRNGKey(1), batched=False)
    for c in plan.cliques:
        assert np.allclose(got[c].omega, want[c].omega, atol=1e-5), c


@settings(deadline=None, max_examples=12)
@given(st.lists(st.integers(2, 6), min_size=1, max_size=3),
       st.lists(st.integers(0, len(KINDS) - 1), min_size=3, max_size=3),
       st.integers(0, 1))
def test_plus_engine_property_random_bases(sizes, kind_ids, mode_id):
    """Property: engine == oracles across random sizes/bases/strategies."""
    kinds = [KINDS[k] for k in kind_ids[:len(sizes)]]
    kinds += ["identity"] * (len(sizes) - len(kinds))
    mode = ["w", "hier"][mode_id]
    rng = np.random.default_rng(0)   # data rng; fixtures can't cross @given
    _engine_vs_oracles(sizes, kinds, mode, rng, use_kernel=False)
